"""Unit + property tests for the collapsible bounds (paper §3.1).

The exactness of FlyMC rests on 0 < B_n ≤ L_n everywhere and on the collapsed
quadratic form equaling the dense product — both are property-tested here.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.bounds import (
    GLMData,
    LogisticBound,
    SoftmaxBound,
    StudentTBound,
)

jax.config.update("jax_platform_name", "cpu")


def _rng(seed):
    return np.random.default_rng(seed)


def _logistic_data(seed, n=32, d=5):
    r = _rng(seed)
    x = r.normal(size=(n, d)).astype(np.float32)
    t = np.where(r.random(n) < 0.5, 1.0, -1.0).astype(np.float32)
    xi = np.abs(r.normal(size=n)).astype(np.float32) * 2 + 1e-3
    return GLMData(jnp.asarray(x), jnp.asarray(t), jnp.asarray(xi))


class TestLogisticBound:
    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_lower_bounds_likelihood(self, seed):
        data = _logistic_data(seed)
        theta = jnp.asarray(_rng(seed + 1).normal(size=5).astype(np.float32))
        ll = LogisticBound.log_lik(theta, data)
        lb = LogisticBound.log_bound(theta, data)
        assert np.all(np.asarray(lb) <= np.asarray(ll) + 1e-5)

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_collapsed_matches_dense_product(self, seed):
        data = _logistic_data(seed)
        theta = jnp.asarray(_rng(seed + 1).normal(size=5).astype(np.float32))
        stats = LogisticBound.suffstats(data)
        dense = jnp.sum(LogisticBound.log_bound(theta, data))
        collapsed = LogisticBound.collapsed(theta, stats)
        np.testing.assert_allclose(collapsed, dense, rtol=2e-4, atol=2e-4)

    def test_tight_at_xi(self):
        # B is tight where |t·θᵀx| = ξ (both signs).
        data = _logistic_data(0, n=16)
        theta = jnp.asarray(_rng(7).normal(size=5).astype(np.float32))
        tuned = LogisticBound.tighten(theta, data)
        ll = LogisticBound.log_lik(theta, tuned)
        lb = LogisticBound.log_bound(theta, tuned)
        np.testing.assert_allclose(lb, ll, rtol=1e-4, atol=1e-5)

    def test_xi_zero_limit_is_finite_and_valid(self):
        data = _logistic_data(3)._replace(xi=jnp.zeros(32))
        theta = jnp.asarray(_rng(5).normal(size=5).astype(np.float32))
        lb = LogisticBound.log_bound(theta, data)
        ll = LogisticBound.log_lik(theta, data)
        assert np.all(np.isfinite(np.asarray(lb)))
        assert np.all(np.asarray(lb) <= np.asarray(ll) + 1e-5)


def _softmax_data(seed, n=32, d=4, k=3, tuned=False):
    r = _rng(seed)
    x = r.normal(size=(n, d)).astype(np.float32)
    t = r.integers(0, k, size=n).astype(np.int32)
    xi = (
        r.normal(size=(n, k)).astype(np.float32)
        if tuned
        else np.zeros((n, k), np.float32)
    )
    return GLMData(jnp.asarray(x), jnp.asarray(t), jnp.asarray(xi))


class TestSoftmaxBound:
    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1), tuned=st.booleans())
    def test_lower_bounds_likelihood(self, seed, tuned):
        data = _softmax_data(seed, tuned=tuned)
        theta = jnp.asarray(_rng(seed + 1).normal(size=(3, 4)).astype(np.float32))
        ll = SoftmaxBound.log_lik(theta, data)
        lb = SoftmaxBound.log_bound(theta, data)
        assert np.all(np.asarray(lb) <= np.asarray(ll) + 1e-5)

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_collapsed_matches_dense_product(self, seed):
        data = _softmax_data(seed, tuned=True)
        theta = jnp.asarray(_rng(seed + 1).normal(size=(3, 4)).astype(np.float32))
        stats = SoftmaxBound.suffstats(data)
        dense = jnp.sum(SoftmaxBound.log_bound(theta, data))
        collapsed = SoftmaxBound.collapsed(theta, stats)
        np.testing.assert_allclose(collapsed, dense, rtol=2e-4, atol=2e-4)

    def test_tight_at_map_logits(self):
        data = _softmax_data(11)
        theta = jnp.asarray(_rng(12).normal(size=(3, 4)).astype(np.float32))
        tuned = SoftmaxBound.tighten(theta, data)
        ll = SoftmaxBound.log_lik(theta, tuned)
        lb = SoftmaxBound.log_bound(theta, tuned)
        np.testing.assert_allclose(lb, ll, rtol=1e-4, atol=1e-5)


def _robust_data(seed, n=32, d=5):
    r = _rng(seed)
    x = r.normal(size=(n, d)).astype(np.float32)
    y = r.normal(size=n).astype(np.float32) * 3
    xi = r.normal(size=n).astype(np.float32)
    return GLMData(jnp.asarray(x), jnp.asarray(y), jnp.asarray(xi))


class TestStudentTBound:
    @settings(deadline=None, max_examples=25)
    @given(
        seed=st.integers(0, 2**31 - 1),
        nu=st.floats(1.5, 10.0),
        sigma=st.floats(0.5, 3.0),
    )
    def test_lower_bounds_likelihood(self, seed, nu, sigma):
        bound = StudentTBound(nu=nu, sigma=sigma)
        data = _robust_data(seed)
        theta = jnp.asarray(_rng(seed + 1).normal(size=5).astype(np.float32))
        ll = bound.log_lik(theta, data)
        lb = bound.log_bound(theta, data)
        assert np.all(np.asarray(lb) <= np.asarray(ll) + 1e-5)

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_collapsed_matches_dense_product(self, seed):
        bound = StudentTBound(nu=4.0)
        data = _robust_data(seed)
        theta = jnp.asarray(_rng(seed + 1).normal(size=5).astype(np.float32))
        stats = bound.suffstats(data)
        dense = jnp.sum(bound.log_bound(theta, data))
        collapsed = bound.collapsed(theta, stats)
        np.testing.assert_allclose(collapsed, dense, rtol=2e-4, atol=2e-4)

    def test_tight_at_map_residual(self):
        bound = StudentTBound(nu=4.0)
        data = _robust_data(21)
        theta = jnp.asarray(_rng(22).normal(size=5).astype(np.float32))
        tuned = bound.tighten(theta, data)
        ll = bound.log_lik(theta, tuned)
        lb = bound.log_bound(theta, tuned)
        np.testing.assert_allclose(lb, ll, rtol=1e-4, atol=1e-5)

    def test_matches_scipy_logpdf(self):
        from scipy import stats as sps

        bound = StudentTBound(nu=4.0, sigma=1.3)
        data = _robust_data(31)
        theta = jnp.asarray(_rng(32).normal(size=5).astype(np.float32))
        ours = np.asarray(bound.log_lik(theta, data))
        r = np.asarray(data.t) - np.asarray(data.x) @ np.asarray(theta)
        ref = sps.t.logpdf(r, df=4.0, scale=1.3)
        np.testing.assert_allclose(ours, ref, rtol=1e-4, atol=1e-5)


def test_marginalization_identity():
    """Σ_z p(x,z|θ) == L_n(θ): the bound partition is exact (paper §2)."""
    data = _logistic_data(5, n=16)
    theta = jnp.asarray(_rng(6).normal(size=5).astype(np.float32))
    ll = np.asarray(LogisticBound.log_lik(theta, data), np.float64)
    lb = np.asarray(LogisticBound.log_bound(theta, data), np.float64)
    # (L - B) + B == L, in log space:
    recon = np.logaddexp(lb, np.log(np.maximum(np.exp(ll) - np.exp(lb), 1e-300)))
    np.testing.assert_allclose(recon, ll, rtol=1e-6)
