"""Distributed training features on a host-local 8-device mesh:
sharded train step, elastic checkpoint reshard, compressed pod gradients.

Run via tests/test_distributed_runner.py (needs 8 fake devices).
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced
from repro.launch import steps
from repro.launch.elastic import StragglerMonitor, plan_mesh
from repro.models.config import ShapeConfig
from repro.models import transformer as T
from repro.distributed import par as parlib
from repro.optim.adamw import AdamWState

pytestmark = pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 fake devices"
)

SHAPE = ShapeConfig("train_tiny", 64, 8, "train")


def _mesh(shape=(2, 4), axes=("data", "model")):
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def _materialize(sds_tree, seed=0):
    """Random arrays for param/opt SDS; zeros for int, ids for batch."""
    leaves, treedef = jax.tree_util.tree_flatten(sds_tree)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    out = []
    for sd, k in zip(leaves, keys):
        if jnp.issubdtype(sd.dtype, jnp.integer):
            a = jax.random.randint(k, sd.shape, 0, 100).astype(sd.dtype)
        else:
            a = (0.02 * jax.random.normal(k, sd.shape)).astype(sd.dtype)
        out.append(jax.device_put(a, sd.sharding))
    return jax.tree_util.tree_unflatten(treedef, out)


def test_sharded_train_step_runs_and_descends():
    mesh = _mesh()
    cfg = get_reduced("llama3.2-3b")
    fn, sds, specs = steps.make_sharded_train_step(
        cfg, mesh, SHAPE, dtype=jnp.float32
    )
    params_sds, opt_sds, batch_sds = sds
    params = _materialize(params_sds, 0)
    opt = _materialize(opt_sds, 1)
    opt = AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(jnp.zeros_like, opt.m),
        v=jax.tree.map(jnp.zeros_like, opt.v),
    )
    k = jax.random.key(2)
    batch = {
        "tokens": jax.device_put(
            jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
            batch_sds["tokens"].sharding,
        ),
        "labels": jax.device_put(
            jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
            batch_sds["labels"].sharding,
        ),
    }
    losses = []
    for _ in range(3):
        params, opt, metrics = fn(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]


def test_sharded_matches_single_device():
    """Same init, same batch: distributed loss == single-device loss."""
    mesh = _mesh()
    cfg = get_reduced("llama3.2-3b")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    par = steps.make_par(mesh)

    specs = T.build_specs(cfg, sizes, par.mp)
    params_global = parlib.init_tree(jax.random.key(0), specs)
    k = jax.random.key(1)
    batch = {
        "tokens": jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
        "labels": jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
    }

    # single-device reference — trivial Par, same logical params
    from repro.distributed.par import Par

    specs0 = T.build_specs(cfg, {}, None)
    loss0, _ = T.loss_fn(
        params_global, specs0, cfg, Par(), batch, dtype=jnp.float32,
        remat=False,
    )

    fn, sds, _ = steps.make_sharded_train_step(
        cfg, mesh, SHAPE, dtype=jnp.float32
    )
    params_sds, opt_sds, batch_sds = sds
    params = jax.tree.map(
        lambda a, s: jax.device_put(a, s.sharding), params_global, params_sds
    )
    opt = AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt_sds.m),
        v=jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt_sds.v),
    )
    batch_dev = jax.tree.map(
        lambda a, s: jax.device_put(a, s.sharding), batch, batch_sds
    )
    _, _, metrics = fn(params, opt, batch_dev)
    np.testing.assert_allclose(
        float(metrics["loss"]), float(loss0), rtol=2e-3
    )


def test_elastic_checkpoint_reshard(tmp_path):
    """Save on a (2,4) mesh, restore onto (1,4) — elastic downscale."""
    cfg = get_reduced("llama3.2-3b")
    mesh_a = _mesh((2, 4))
    fn_a, sds_a, _ = steps.make_sharded_train_step(cfg, mesh_a, SHAPE)
    params = _materialize(sds_a[0], 0)
    ck = Checkpointer(tmp_path)
    ck.save(1, params, blocking=True)

    mesh_b = _mesh((1, 4))
    fn_b, sds_b, _ = steps.make_sharded_train_step(cfg, mesh_b, SHAPE)
    target = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds_b[0])
    shardings = jax.tree.map(lambda s: s.sharding, sds_b[0])
    restored, m = ck.restore(target, shardings=shardings)
    assert m["step"] == 1
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        params, restored,
    )


def test_compressed_pod_gradients_converge():
    """3-axis mesh with a pod axis: int8+error-feedback pod reduction keeps
    the loss trajectory close to the uncompressed one."""
    mesh = _mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_reduced("llama3.2-3b")
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    par = steps.make_par(mesh)
    from jax.sharding import NamedSharding, PartitionSpec as PS

    results = {}
    for compress in (False, True):
        compress_axes = ("pod",) if compress else ()
        step, specs = T.make_train_step(
            cfg, sizes, par, dtype=jnp.float32, remat=False,
            compress_axes=compress_axes, peak_lr=1e-3,
        )
        params_ps = parlib.spec_tree_to_pspecs(specs, par.mp)
        opt_ps = AdamWState(step=PS(), m=params_ps, v=params_ps)
        b_ps = {"tokens": PS(("pod", "data"), None),
                "labels": PS(("pod", "data"), None)}
        metrics_ps = {k: PS() for k in
                      ("loss", "nll", "lb_loss", "drop_frac", "grad_norm", "lr")}
        in_specs = [params_ps, opt_ps]
        out_specs = [params_ps, opt_ps]
        if compress:
            in_specs.append(params_ps)  # error feedback tree
            out_specs.append(params_ps)
        in_specs.append(b_ps)
        out_specs.append(metrics_ps)
        fn = jax.jit(jax.shard_map(
            step, mesh=mesh, in_specs=tuple(in_specs),
            out_specs=tuple(out_specs), check_vma=False,
        ))
        params = parlib.init_tree(jax.random.key(0), specs)
        params = jax.tree.map(
            lambda a, sp: jax.device_put(
                a, NamedSharding(mesh, sp)
            ),
            params, params_ps,
        )
        opt = AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            v=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        )
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        k = jax.random.key(1)
        batch = {
            "tokens": jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
            "labels": jax.random.randint(k, (8, 64), 0, cfg.vocab_size),
        }
        losses = []
        for _ in range(4):
            if compress:
                params, opt, err, metrics = fn(params, opt, err, batch)
            else:
                params, opt, metrics = fn(params, opt, batch)
            losses.append(float(metrics["loss"]))
        results[compress] = losses
    # both descend; compressed trajectory within 5% of exact per step
    assert results[True][-1] < results[True][0]
    np.testing.assert_allclose(results[True], results[False], rtol=0.05)


def test_plan_mesh_shapes():
    m = plan_mesh(8, model_parallel=4)
    assert m.devices.size == 8 and m.axis_names == ("data", "model")
    m2 = plan_mesh(7, model_parallel=4)  # lost a device → 1 group
    assert m2.devices.size == 4


def test_straggler_monitor():
    mon = StragglerMonitor()
    for _ in range(10):
        for h in ("a", "b", "c", "d"):
            mon.record(h, 1.0 if h != "d" else 2.5)
    assert mon.stragglers() == ["d"]
