"""Per-kernel validation (brief: sweep shapes/dtypes, assert_allclose vs the
pure-jnp ref.py oracle, interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_platform_name", "cpu")

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# bright_glm — the FlyMC hot loop
# ---------------------------------------------------------------------------

_K = 5  # softmax classes for the kernel tests


def _glm_case(family, n, d):
    x = jnp.asarray(RNG.normal(size=(n, d)).astype(np.float32))
    if family == "logistic":
        t = jnp.asarray(np.where(RNG.random(n) < 0.5, 1.0, -1.0).astype(np.float32))
        xi = jnp.asarray((np.abs(RNG.normal(size=n)) + 0.1).astype(np.float32))
        theta = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    elif family == "student_t":
        t = jnp.asarray((RNG.normal(size=n) * 2).astype(np.float32))
        xi = jnp.asarray((np.abs(RNG.normal(size=n)) + 0.1).astype(np.float32))
        theta = jnp.asarray(RNG.normal(size=d).astype(np.float32))
    else:
        t = jnp.asarray(RNG.integers(0, _K, n).astype(np.int32))
        xi = jnp.asarray((RNG.normal(size=(n, _K)) * 0.5).astype(np.float32))
        theta = jnp.asarray((RNG.normal(size=(_K, d)) * 0.3).astype(np.float32))
    return x, t, xi, theta


@pytest.mark.parametrize("n,d,c,nb", [(64, 51, 16, 12), (128, 57, 32, 32),
                                      (32, 7, 8, 0), (256, 130, 64, 40)])
@pytest.mark.parametrize("family", ["logistic", "student_t", "softmax"])
def test_bright_glm(n, d, c, nb, family):
    from repro.kernels.bright_glm.ops import bright_glm
    from repro.kernels.bright_glm.ref import bright_glm_ref

    x, t, xi, theta = _glm_case(family, n, d)
    idx = jnp.asarray(RNG.choice(n, c, replace=False).astype(np.int32))
    mask = jnp.arange(c) < nb

    delta, total = bright_glm(x, t, xi, idx, jnp.int32(nb), theta, family=family)
    d_ref, c_ref = bright_glm_ref(x, t, xi, idx, mask, theta, family=family)
    np.testing.assert_allclose(delta, d_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(total, c_ref.sum(), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("family", ["logistic", "student_t", "softmax"])
def test_bright_glm_grad_matches_ref(family):
    """MALA/HMC route: ∇_θ of the fused total via the custom VJP."""
    from repro.kernels.bright_glm.ops import bright_glm
    from repro.kernels.bright_glm.ref import bright_glm_ref

    n, d, c, nb = 96, 23, 24, 17
    x, t, xi, theta = _glm_case(family, n, d)
    idx = jnp.asarray(RNG.choice(n, c, replace=False).astype(np.int32))
    mask = jnp.arange(c) < nb

    def f_pallas(th):
        delta, total = bright_glm(x, t, xi, idx, jnp.int32(nb), th,
                                  family=family)
        return total, delta

    def f_ref(th):
        delta, contrib = bright_glm_ref(x, t, xi, idx, mask, th,
                                        family=family)
        return jnp.sum(contrib), delta

    (tot_p, aux_p), g_p = jax.value_and_grad(f_pallas, has_aux=True)(theta)
    (tot_r, aux_r), g_r = jax.value_and_grad(f_ref, has_aux=True)(theta)
    np.testing.assert_allclose(tot_p, tot_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_p, g_r, rtol=2e-4, atol=1e-5)
    # and under jit, as the samplers call it
    g_jit = jax.jit(jax.grad(lambda th: f_pallas(th)[0]))(theta)
    np.testing.assert_allclose(g_jit, g_r, rtol=2e-4, atol=1e-5)


def test_bright_glm_full_capacity_padded_buffer():
    """Regression: padding slots carrying out-of-range ids (bright_buffer /
    jnp.pad fill, the candidate buffer's N sentinel) must be clamped before
    the in-kernel DMA, at every fill level up to full capacity."""
    from repro.kernels.bright_glm.ops import bright_glm
    from repro.kernels.bright_glm.ref import bright_glm_ref

    n, d, c = 40, 11, 40  # capacity == N: every row bright + ragged padding
    x, t, xi, theta = _glm_case("logistic", n, d)
    perm = RNG.permutation(n).astype(np.int32)
    for nb in (0, 1, 39, 40):
        # invalid tail slots hold the out-of-range sentinel N, as the
        # implicit z-update's candidate buffer does
        idx = jnp.asarray(np.where(np.arange(c) < nb, perm, n))
        mask = jnp.arange(c) < nb
        delta, total = bright_glm(x, t, xi, idx, jnp.int32(nb), theta)
        d_ref, c_ref = bright_glm_ref(x, t, xi, idx, mask, theta)
        assert np.all(np.isfinite(np.asarray(delta)))
        np.testing.assert_allclose(
            np.where(mask, delta, 0.0), np.where(mask, d_ref, 0.0),
            rtol=1e-4, atol=1e-5,
        )
        np.testing.assert_allclose(total, c_ref.sum(), rtol=1e-4, atol=1e-5)


def test_bright_glm_ragged_c_not_multiple_of_block_rows():
    from repro.kernels.bright_glm.ops import bright_glm
    from repro.kernels.bright_glm.ref import bright_glm_ref

    n, d, c, nb = 64, 13, 21, 21  # C % block_rows != 0 → internal padding
    x, t, xi, theta = _glm_case("student_t", n, d)
    idx = jnp.asarray(RNG.choice(n, c, replace=False).astype(np.int32))
    mask = jnp.arange(c) < nb
    delta, total = bright_glm(x, t, xi, idx, jnp.int32(nb), theta,
                              family="student_t")
    d_ref, c_ref = bright_glm_ref(x, t, xi, idx, mask, theta,
                                  family="student_t")
    assert delta.shape == (c,)
    np.testing.assert_allclose(delta, d_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(total, c_ref.sum(), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attention — flash decode over ring cache
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,hk,d,w,t,window",
    [
        (2, 8, 2, 128, 256, 200, None),
        (1, 4, 4, 128, 384, 380, 128),
        (2, 16, 2, 128, 256, 100, None),
        (1, 8, 1, 128, 512, 511, 256),  # MQA + window
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(b, h, hk, d, w, t, window, dtype):
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32)).astype(dtype)
    k = jnp.asarray(RNG.normal(size=(b, w, hk, d)).astype(np.float32)).astype(dtype)
    v = jnp.asarray(RNG.normal(size=(b, w, hk, d)).astype(np.float32)).astype(dtype)
    pos = jnp.asarray(
        np.where(np.arange(w) < t + 1, np.arange(w), -1).astype(np.int32)
    )
    out, m, l = decode_attention(q, k, v, pos, jnp.int32(t), window=window)
    ref_out, _, ref_l = decode_attention_ref(q, k, v, pos, t, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref_out, rtol=tol, atol=tol)
    np.testing.assert_allclose(l, ref_l, rtol=tol, atol=tol)


def test_decode_attention_ring_wraparound():
    """Ring semantics: only entries with pos in (t-window, t] participate."""
    from repro.kernels.decode_attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    b, h, hk, d, w = 1, 2, 1, 128, 128
    t, window = 300, 128
    q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, w, hk, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, w, hk, d)).astype(np.float32))
    slots = np.arange(w)
    pos = jnp.asarray(
        (slots + ((t - slots) // w) * w).astype(np.int32)
    )  # wrapped ring positions ≤ t
    out, _, _ = decode_attention(q, k, v, pos, jnp.int32(t), window=window)
    ref_out, _, _ = decode_attention_ref(q, k, v, pos, t, window=window)
    np.testing.assert_allclose(out, ref_out, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# rwkv6_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,h,s,d,chunk", [(2, 3, 64, 16, 16), (1, 2, 128, 64, 64), (2, 1, 96, 32, 32)]
)
def test_rwkv6_scan(b, h, s, d, chunk):
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.kernels.rwkv6_scan.ref import rwkv6_ref

    r = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    lw = jnp.asarray(-RNG.uniform(0.01, 0.9, size=(b, h, s, d)).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, d)).astype(np.float32))
    y, st = rwkv6_scan(r, k, v, lw, u, chunk=chunk)
    y_ref, st_ref = rwkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(y, y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(st, st_ref, rtol=3e-4, atol=3e-4)


def test_rwkv6_matches_model_layer_chunking():
    """Kernel agrees with the model's chunked _wkv_chunk implementation."""
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan
    from repro.models.layers import _wkv_chunk

    b, h, s, d, c = 1, 2, 64, 16, 16
    r = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
    lw = jnp.asarray(-RNG.uniform(0.01, 0.9, size=(b, h, s, d)).astype(np.float32))
    u = jnp.asarray(RNG.normal(size=(h, d)).astype(np.float32))
    y_k, _ = rwkv6_scan(r, k, v, lw, u, chunk=c)
    state = jnp.zeros((b, h, d, d), jnp.float32)
    ys = []
    for i in range(s // c):
        sl = slice(i * c, (i + 1) * c)
        y, state = _wkv_chunk(
            r[:, :, sl], k[:, :, sl], v[:, :, sl], lw[:, :, sl], u, state
        )
        ys.append(y)
    np.testing.assert_allclose(
        y_k, jnp.concatenate(ys, axis=2), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# rglru_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,c,chunk", [(2, 64, 96, 16), (1, 128, 256, 64), (3, 96, 130, 32)]
)
def test_rglru_scan(b, s, c, chunk):
    from repro.kernels.rglru_scan.ops import rglru_scan
    from repro.kernels.rglru_scan.ref import rglru_ref

    la = jnp.asarray(-RNG.uniform(0.001, 2.0, size=(b, s, c)).astype(np.float32))
    bx = jnp.asarray(RNG.normal(size=(b, s, c)).astype(np.float32))
    y, hf = rglru_scan(la, bx, chunk=chunk)
    y_ref, hf_ref = rglru_ref(la, bx)
    np.testing.assert_allclose(y, y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(hf, hf_ref, rtol=3e-4, atol=3e-4)


# ---------------------------------------------------------------------------
# fused_ce
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "t,d,v,bt,bv",
    [(16, 64, 512, 8, 128), (24, 128, 1024, 8, 256), (8, 32, 256, 8, 256)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_ce(t, d, v, bt, bv, dtype):
    from repro.kernels.fused_ce.ops import fused_ce
    from repro.kernels.fused_ce.ref import fused_ce_ref

    x = jnp.asarray(RNG.normal(size=(t, d)).astype(np.float32)).astype(dtype)
    w = jnp.asarray(
        (RNG.normal(size=(d, v)) / np.sqrt(d)).astype(np.float32)
    ).astype(dtype)
    lab = jnp.asarray(RNG.integers(0, v, t).astype(np.int32))
    nll = fused_ce(x, w, lab, block_t=bt, block_v=bv)
    ref = fused_ce_ref(x, w, lab)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(nll, ref, rtol=tol, atol=tol)
