"""The repro.api surface: chain-law equivalence, multi-chain, sync counts.

The driver's contract (ISSUE 1 acceptance criteria):
  * zero host syncs inside a chunk — ≤ 1 device_get per chunk_size iters;
  * the realized chain is bitwise independent of chunk size and of buffer
    capacity, including across mid-chain capacity-doubling re-runs;
  * the legacy ``run_chain`` shim reproduces ``sample()`` exactly;
  * ``num_chains > 1`` vmaps chains and feeds split-R̂ diagnostics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import brightness, diagnostics, samplers
from repro.core import bounds as bounds_lib
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D = 400, 4


@pytest.fixture(scope="module")
def model():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    return GLMModel.logistic(data, prior_scale=2.0, xi=1.5)


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------


def test_kernel_registry_uniform_interface():
    for name in ("rwmh", "mala", "slice", "hmc"):
        ks = samplers.get_kernel(name)
        assert callable(ks.step_fn)
        assert ks.scale_param in ("step_size", "width")
    with pytest.raises(KeyError, match="unknown θ-kernel"):
        samplers.get_kernel("nuts")


def test_bound_registry_resolves_names_and_instances():
    assert isinstance(bounds_lib.get_bound("logistic"), bounds_lib.LogisticBound)
    assert isinstance(
        bounds_lib.get_bound("jaakkola-jordan"), bounds_lib.LogisticBound
    )
    b = bounds_lib.StudentTBound(nu=3.0)
    assert bounds_lib.get_bound(b) is b
    with pytest.raises(KeyError, match="unknown bound"):
        bounds_lib.get_bound("no-such-bound")
    with pytest.raises(TypeError, match="Bound protocol"):
        bounds_lib.get_bound(object())


def test_firefly_rejects_unknown_kernel(model):
    with pytest.raises(KeyError, match="unknown θ-kernel"):
        api.firefly(model, kernel="not-a-kernel")


# ---------------------------------------------------------------------------
# Chain-law equivalence
# ---------------------------------------------------------------------------


def test_sample_matches_explicit_step_loop(model):
    """sample() == a hand-rolled host loop over alg.step with the same keys."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(11)
    trace = api.sample(alg, key, 40, chunk_size=16)

    k_init, k_steps = jax.random.split(key)
    state = jax.jit(alg.init)(k_init, alg.default_position)
    step = jax.jit(alg.step)  # jit: eager op-by-op float fusion differs
    thetas = []
    for i in range(40):
        state, _ = step(jax.random.fold_in(k_steps, i), state)
        thetas.append(np.asarray(state.sampler.theta))
    np.testing.assert_array_equal(np.asarray(trace.theta[0]), np.stack(thetas))


def test_chunk_size_invariance(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(3)
    t1 = api.sample(alg, key, 60, chunk_size=7)
    t2 = api.sample(alg, key, 60, chunk_size=60)
    np.testing.assert_array_equal(np.asarray(t1.theta), np.asarray(t2.theta))
    np.testing.assert_array_equal(
        np.asarray(t1.stats.n_bright), np.asarray(t2.stats.n_bright)
    )


def test_capacity_overflow_mid_chain_is_exact(model):
    """A chain that overflows mid-run (capacity just above the initial
    bright set) must bitwise match one run at ample capacity throughout:
    per-datum RNG makes the trajectory capacity-invariant, and the driver
    re-runs the overflowed chunk from the saved pre-chunk state."""
    key = jax.random.key(9)

    def run(cap):
        alg = api.firefly(
            model, kernel="rwmh", capacity=cap, cand_capacity=cap,
            q_db=0.02, step_size=0.1,
        )
        return api.sample(alg, key, 300, chunk_size=32)

    t_small = run(24)
    grown = t_small.algorithm.spec.capacity
    assert grown > 24, "test must exercise a mid-chain capacity overflow"
    t_big = run(N)  # full capacity: can never overflow
    np.testing.assert_array_equal(
        np.asarray(t_small.theta), np.asarray(t_big.theta)
    )


def test_legacy_run_chain_shim_matches_sample(model):
    spec = model.flymc_spec(
        kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1
    )
    state, _, spec = model.init_chain(
        spec, jnp.zeros(D), jax.random.key(5), step_size=0.1
    )
    samples, trace_dicts, total_q, _ = model.run_chain(spec, state, 30)

    alg = api.algorithm_from_spec(spec, model.data, model.stats)
    trace = api.sample(alg, state.rng, 30, init_state=state)
    np.testing.assert_array_equal(np.stack(samples), np.asarray(trace.theta[0]))
    assert total_q == int(trace.total_queries)
    assert [t["n_bright"] for t in trace_dicts] == list(
        np.asarray(trace.stats.n_bright[0])
    )


def test_thinning(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(4)
    full = api.sample(alg, key, 40, chunk_size=20)
    thinned = api.sample(alg, key, 40, chunk_size=20, thin=4)
    assert thinned.theta.shape == (1, 10, D)
    np.testing.assert_array_equal(
        np.asarray(thinned.theta[0]), np.asarray(full.theta[0][3::4])
    )
    # stats stay per-iteration
    assert thinned.stats.lik_queries.shape == (1, 40)


# ---------------------------------------------------------------------------
# Host-sync accounting
# ---------------------------------------------------------------------------


def test_at_most_one_device_get_per_chunk(model, monkeypatch):
    alg = api.firefly(
        model, kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.05,
        step_size=0.1,
    )
    api.sample(alg, jax.random.key(2), 8, chunk_size=8)  # warm / pre-grow
    calls = {"n": 0}
    orig = jax.device_get

    def counting(x):
        calls["n"] += 1
        return orig(x)

    monkeypatch.setattr(jax, "device_get", counting)
    num_samples, chunk_size = 128, 32
    api.sample(alg, jax.random.key(2), num_samples, chunk_size=chunk_size)
    n_chunks = num_samples // chunk_size
    # one overflow check per chunk + one init-overflow check + one final
    # stats transfer for the int64 query total (post-sampling)
    assert calls["n"] <= n_chunks + 2, calls["n"]


# ---------------------------------------------------------------------------
# Multi-chain
# ---------------------------------------------------------------------------


def test_multi_chain_shapes_and_rhat(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.05,
        step_size=0.12, adapt_target="auto",
    )
    n_chains, iters = 4, 400
    trace = api.sample(
        alg, jax.random.key(8), iters, num_chains=n_chains, chunk_size=100
    )
    assert trace.theta.shape == (n_chains, iters, D)
    assert trace.stats.lik_queries.shape == (n_chains, iters)
    # chains differ (independent keys) ...
    assert not np.allclose(trace.theta[0], trace.theta[1])
    # ... but target the same posterior: split-R̂ sane on each coordinate
    s = np.asarray(trace.theta)[:, iters // 2 :, :]
    rhats = [diagnostics.split_r_hat(s[:, :, j]) for j in range(D)]
    assert all(r < 1.5 for r in rhats), rhats
    # single chain is reproduced exactly by chain 0 of the vmapped run
    one = api.sample(alg, jax.random.key(8), iters, num_chains=1)
    assert one.theta.shape == (1, iters, D)


def test_multi_chain_distinct_positions(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.05,
        step_size=0.1,
    )
    pos = jnp.stack([jnp.zeros(D), 0.5 * jnp.ones(D)])
    trace = api.sample(
        alg, jax.random.key(1), 10, num_chains=2, init_position=pos,
        chunk_size=10,
    )
    assert trace.theta.shape == (2, 10, D)


# ---------------------------------------------------------------------------
# Regular-MCMC baseline through the same driver
# ---------------------------------------------------------------------------


def test_regular_mcmc_through_driver(model):
    alg = api.regular_mcmc(model, kernel="rwmh", step_size=0.1,
                           adapt_target="auto")
    trace = api.sample(alg, jax.random.key(6), 50, chunk_size=25)
    assert trace.theta.shape == (1, 50, D)
    # full-data cost model: every iteration queries all N likelihoods
    assert np.all(np.asarray(trace.stats.lik_queries) == N)
    assert int(trace.total_queries) == 50 * N


def test_regular_mcmc_slice_kernel(model):
    """Slice kernel through the registry: no width/step_size special-casing."""
    alg = api.regular_mcmc(model, kernel="slice", step_size=0.5)
    trace = api.sample(alg, jax.random.key(6), 20, chunk_size=10)
    # slice makes a variable number of evaluations per iteration, all ≥ 2
    assert np.all(np.asarray(trace.stats.lik_queries) >= 2 * N)


def test_trace_resume(model):
    """final_state + algorithm allow seamless continuation."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(12)
    t1 = api.sample(alg, key, 30, chunk_size=15)
    t2 = api.sample(
        t1.algorithm, jax.random.key(13), 20, init_state=t1.final_state
    )
    assert t2.theta.shape == (1, 20, D)
    # resumed chain continues from where t1 ended
    state = t1.final_state
    assert np.allclose(
        np.asarray(t1.theta[0, -1]), np.asarray(state.sampler.theta)
    )


def test_bright_state_invariants_preserved(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    trace = api.sample(alg, jax.random.key(14), 25)
    assert brightness.check_invariants(trace.final_state.bright)


# ---------------------------------------------------------------------------
# Exactness regressions: warmup-only adaptation & resume key stream
# ---------------------------------------------------------------------------


def test_flymc_step_size_frozen_after_warmup(model):
    """Step-size adaptation must be warmup-only: adapting forever means the
    post-warmup chain never follows a fixed Markov kernel. log_step moves
    during warmup and is bitwise constant afterward."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.05,
        step_size=0.1, adapt_target=0.234, num_warmup=20,
    )
    key = jax.random.key(21)

    def log_step_after(iters):
        return np.asarray(api.sample(alg, key, iters).final_state.log_step)

    ls5, ls20, ls60 = log_step_after(5), log_step_after(20), log_step_after(60)
    assert not np.array_equal(ls5, ls20), "must adapt during warmup"
    np.testing.assert_array_equal(ls20, ls60)  # bitwise frozen after warmup


def test_regular_mcmc_step_size_frozen_after_warmup(model):
    alg = api.regular_mcmc(
        model, kernel="rwmh", step_size=0.1, adapt_target=0.234, num_warmup=10
    )
    key = jax.random.key(22)

    def log_step_after(iters):
        return np.asarray(api.sample(alg, key, iters).final_state.log_step)

    ls3, ls10, ls40 = log_step_after(3), log_step_after(10), log_step_after(40)
    assert not np.array_equal(ls3, ls10)
    np.testing.assert_array_equal(ls10, ls40)


def test_resume_continues_key_stream_not_replays_it(model):
    """sample(..., init_state=s) must offset the per-iteration fold-in
    counter by s.iteration: two 20-step segments resumed with the same key
    are bitwise one contiguous 40-step run, instead of the second segment
    replaying the first segment's exact key stream."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(23)
    state0 = jax.jit(alg.init)(jax.random.key(24), alg.default_position)

    contiguous = api.sample(alg, key, 40, init_state=state0, chunk_size=16)
    seg1 = api.sample(alg, key, 20, init_state=state0, chunk_size=16)
    seg2 = api.sample(alg, key, 20, init_state=seg1.final_state, chunk_size=16)
    np.testing.assert_array_equal(
        np.concatenate(
            [np.asarray(seg1.theta[0]), np.asarray(seg2.theta[0])]
        ),
        np.asarray(contiguous.theta[0]),
    )
    # ... which in particular means the resumed segment is not a replay:
    # replaying seg1's keys from seg1's final state would re-use its
    # uniforms; pin the counter offset explicitly via a hand-rolled loop.
    state, thetas = seg1.final_state, []
    step = jax.jit(alg.step)
    for i in range(20, 40):
        state, _ = step(jax.random.fold_in(key, i), state)
        thetas.append(np.asarray(state.sampler.theta))
    np.testing.assert_array_equal(np.asarray(seg2.theta[0]), np.stack(thetas))


def test_multi_chain_resume_split_equals_contiguous(model):
    """init_state with a leading (num_chains,) axis: two resumed 20-step
    segments must be bitwise one contiguous 40-step run, per chain (the
    vmap'd step already supported it; the driver now accepts the state and
    offsets every chain's fold-in counter by the shared iteration)."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    key = jax.random.key(31)
    init_keys = jax.random.split(jax.random.key(30), 2)
    pos = jnp.broadcast_to(
        alg.default_position, (2,) + alg.default_position.shape
    )
    state0 = jax.jit(jax.vmap(alg.init))(init_keys, pos)

    contiguous = api.sample(
        alg, key, 40, num_chains=2, init_state=state0, chunk_size=16
    )
    seg1 = api.sample(
        alg, key, 20, num_chains=2, init_state=state0, chunk_size=16
    )
    seg2 = api.sample(
        alg, key, 20, num_chains=2, init_state=seg1.final_state, chunk_size=16
    )
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(seg1.theta), np.asarray(seg2.theta)], 1),
        np.asarray(contiguous.theta),
    )


def test_multi_chain_resume_from_final_state(model):
    """A previous multi-chain run's final_state resumes directly."""
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    t1 = api.sample(alg, jax.random.key(32), 30, num_chains=3, chunk_size=15)
    t2 = api.sample(
        alg, jax.random.key(33), 20, num_chains=3, init_state=t1.final_state
    )
    assert t2.theta.shape == (3, 20, D)
    np.testing.assert_array_equal(  # continues where t1 ended
        np.asarray(t1.final_state.iteration), np.full(3, 30)
    )


def test_multi_chain_resume_rejects_bad_states(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )
    single = jax.jit(alg.init)(jax.random.key(34), alg.default_position)
    with pytest.raises(ValueError, match="leading"):
        api.sample(
            alg, jax.random.key(0), 10, num_chains=2, init_state=single
        )
    two = api.sample(alg, jax.random.key(35), 10, num_chains=2).final_state
    with pytest.raises(ValueError, match="leading"):
        api.sample(alg, jax.random.key(0), 10, num_chains=3, init_state=two)
    skewed = two._replace(iteration=jnp.asarray([10, 7], jnp.int32))
    with pytest.raises(ValueError, match="different iterations"):
        api.sample(alg, jax.random.key(0), 10, num_chains=2, init_state=skewed)


def test_resume_offset_also_fixes_legacy_host_loop(model):
    """run_chain's collect= host-loop fallback shares the resume contract."""
    from repro.core import flymc

    spec = model.flymc_spec(
        kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1
    )
    state, _, spec = model.init_chain(
        spec, jnp.zeros(D), jax.random.key(25), step_size=0.1
    )
    collect = lambda s: np.asarray(s.sampler.theta)
    full, *_ = flymc.run_chain(
        spec, model.data, model.stats, state, 30, collect=collect
    )
    first, *_ = flymc.run_chain(
        spec, model.data, model.stats, state, 15, collect=collect
    )
    # state after 15 steps, then resume 15 more through the host loop
    mid = state
    step = jax.jit(api.algorithm_from_spec(spec, model.data, model.stats).step)
    for i in range(15):
        mid, _ = step(jax.random.fold_in(state.rng, i), mid)
    rest, *_ = flymc.run_chain(
        spec, model.data, model.stats, mid._replace(rng=state.rng), 15,
        collect=collect,
    )
    np.testing.assert_array_equal(
        np.stack(full), np.concatenate([np.stack(first), np.stack(rest)])
    )
