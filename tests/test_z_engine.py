"""The fused z-update engine (``FlyMCSpec.z_backend = "fused"``).

Four layers of guarantee, cheapest to strongest:
  * RNG/compaction parity: the streaming candidate kernel (interpret mode)
    must reproduce the pure-jnp reference's per-datum counter draws and
    cumsum compaction bit-for-bit, across capacities and overflow;
  * cost model: the fused step's jaxpr contains NO length-N uniform
    generation and NO full-N cumsum re-partition — the O(N) work the
    engine exists to kill — while the jnp engine's jaxpr (sanity check)
    trips both detectors;
  * exactness mechanics: the fused trajectory is bitwise invariant to
    buffer capacity and driver chunk size, including across mid-chain
    capacity-doubling re-runs, and maintains the partition invariants;
  * chain law: fused vs jnp engines produce statistically equivalent
    bright-count trajectories and posterior moments (they follow different
    — law-equal — uniform streams, so only distributions can match).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis, api
from repro.analysis import rules as analysis_rules
from repro.core import brightness, numerics
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D = 400, 4


@pytest.fixture(scope="module")
def model():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    return GLMModel.logistic(data, prior_scale=2.0, xi=1.5)


# ---------------------------------------------------------------------------
# In-kernel RNG & compaction parity (interpret mode vs per-datum reference)
# ---------------------------------------------------------------------------


def test_threefry_matches_jax_prng_bits():
    """The shared counter cipher is bit-compatible with jax's Threefry-2x32,
    so the in-kernel stream has exactly the PRNG quality of jax.random."""
    # jax._src is not a stable API: skip (not fail) if the reference cipher
    # moves — every other z-engine guarantee is pinned by the public-surface
    # tests below, this one only cross-checks the cipher constants.
    prng = pytest.importorskip("jax._src.prng")
    threefry_2x32 = prng.threefry_2x32

    k = jnp.array([123456789, 987654321], dtype=jnp.uint32)
    x = jnp.arange(64).astype(jnp.uint32)
    ours, _ = numerics.threefry2x32(
        jnp.int32(123456789),
        jnp.int32(987654321),
        jnp.zeros(64, jnp.int32),
        jnp.arange(64).astype(jnp.int32),
    )
    theirs = threefry_2x32(k, jnp.concatenate([jnp.zeros(64, jnp.uint32), x]))
    np.testing.assert_array_equal(
        np.asarray(ours).view(np.uint32), np.asarray(theirs[:64])
    )


@pytest.mark.parametrize("n,num_frac,q_db,cap", [
    (1000, 0.2, 0.05, 256),   # typical
    (1000, 0.2, 0.05, 8),     # candidate overflow (count ≫ cap)
    (1000, 0.0, 0.02, 64),    # all dark
    (1000, 1.0, 0.5, 64),     # all bright — no candidates
    (997, 0.3, 0.1, 128),     # N not a multiple of the tile
    (64, 0.5, 0.3, 16),       # N smaller than one tile
])
def test_z_candidates_kernel_matches_ref(n, num_frac, q_db, cap):
    from repro.kernels.z_update.ops import z_candidates
    from repro.kernels.z_update.ref import z_candidates_ref

    z0 = jax.random.bernoulli(jax.random.key(1), num_frac, (n,))
    st = brightness.from_z(z0)
    kw = numerics.key_words_of(jax.random.key(7))
    c_k, n_k = z_candidates(st.arr, st.num, kw, q_db, cap, interpret=True)
    c_r, n_r = z_candidates_ref(st.arr, st.num, kw, q_db, cap)
    assert int(n_k) == int(n_r)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_z_candidates_parity_under_jit_and_capacity():
    """Same (key, partition) ⇒ same candidate SET at every capacity: the
    counter RNG keys on datum ids, so capacity only truncates, never
    re-randomizes."""
    from repro.kernels.z_update.ops import z_candidates

    z0 = jax.random.bernoulli(jax.random.key(2), 0.1, (1000,))
    st = brightness.from_z(z0)
    kw = numerics.key_words_of(jax.random.key(3))
    f = jax.jit(
        lambda a, num, kw: z_candidates(a, num, kw, 0.05, 128, interpret=True)
    )
    c128, n128 = f(st.arr, st.num, kw)
    c512, n512 = z_candidates(st.arr, st.num, kw, 0.05, 512, interpret=True)
    assert int(n128) == int(n512)
    k = int(n128)
    np.testing.assert_array_equal(np.asarray(c128)[:k], np.asarray(c512)[:k])


def test_q_threshold_never_rounds_positive_q_to_zero():
    """A sub-grid q_db (< 2⁻²⁵) must still propose with the smallest
    representable probability, never zero — a zero threshold would stop all
    dark→bright moves and break irreducibility while the jnp engine keeps
    proposing."""
    from repro.kernels.z_update.ref import q_threshold_bits

    assert q_threshold_bits(1e-9) == 1
    assert q_threshold_bits(0.0) == 0
    assert q_threshold_bits(1.0) == 1 << 24
    assert q_threshold_bits(0.01) == round(0.01 * (1 << 24))


def test_counter_uniforms_are_per_datum_functions():
    """u(key, draw, datum) gathered on any buffer equals the corresponding
    slice of the full per-datum array — the capacity/chunk-invariance
    contract of flymc._implicit_z_update, without the (N,) materialization."""
    kw = numerics.key_words_of(jax.random.key(11))
    full = numerics.counter_uniform(kw, numerics.DRAW_DARKEN, jnp.arange(500))
    idx = jnp.asarray([3, 499, 0, 17, 256], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(numerics.counter_uniform(kw, numerics.DRAW_DARKEN, idx)),
        np.asarray(full)[np.asarray(idx)],
    )
    # distinct draw streams really are distinct
    other = numerics.counter_uniform(kw, numerics.DRAW_BRIGHT, jnp.arange(500))
    assert not np.array_equal(np.asarray(full), np.asarray(other))
    # crude uniformity sanity on the 24-bit grid
    assert abs(float(full.mean()) - 0.5) < 0.05
    assert 0.0 <= float(full.min()) and float(full.max()) < 1.0


# ---------------------------------------------------------------------------
# Cost model: no (N,) uniforms, no full-N cumsum in the fused step
# ---------------------------------------------------------------------------

# The ad-hoc _walk_eqns/_subjaxprs/_max_eqn_size helpers that used to live
# here are now repro.analysis.walker — the one shared jaxpr-inspection
# substrate (the analyzer's cost-model rule runs the same sweep over the
# registered step entry points in CI).
_RNG_PRIMS = analysis_rules.RNG_PRIMS
_max_eqn_size = analysis.walker.max_eqn_size


def _step_jaxpr(z_backend, n=4096, capacity=256):
    data = logistic_data(jax.random.key(0), n=n, d=D, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    alg = api.firefly(
        model, kernel="rwmh", capacity=capacity, cand_capacity=capacity,
        q_db=0.01, step_size=0.1, z_backend=z_backend,
    )
    state = jax.eval_shape(alg.init, jax.random.key(1), alg.default_position)
    return jax.make_jaxpr(alg.step)(jax.random.key(2), state), n


def test_fused_step_has_no_length_n_rng_or_cumsum():
    """Acceptance criterion: the fused engine's per-step non-likelihood work
    contains no length-N uniform materialization and no full-N cumsum
    re-partition, verified on the step's jaxpr (pallas inner jaxprs
    included — the kernel's tile-shaped threefry lanes are ≪ N)."""
    jaxpr, n = _step_jaxpr("fused")
    assert _max_eqn_size(jaxpr.jaxpr, _RNG_PRIMS) < n
    assert _max_eqn_size(jaxpr.jaxpr, ("cumsum",)) < n


def test_jnp_step_trips_both_detectors():
    """Sanity: the detectors are real — the jnp engine's (N,) uniforms and
    from_z cumsum must be visible to the same inspection."""
    jaxpr, n = _step_jaxpr("jnp")
    assert _max_eqn_size(jaxpr.jaxpr, _RNG_PRIMS) >= n
    assert _max_eqn_size(jaxpr.jaxpr, ("cumsum",)) >= n


# ---------------------------------------------------------------------------
# Exactness mechanics: capacity / chunk / overflow invariance
# ---------------------------------------------------------------------------


def test_fused_chain_capacity_and_chunk_invariant(model):
    def run(cap, chunk):
        alg = api.firefly(
            model, kernel="rwmh", capacity=cap, cand_capacity=cap,
            q_db=0.05, step_size=0.12, z_backend="fused",
        )
        return api.sample(alg, jax.random.key(9), 120, chunk_size=chunk)

    t_ref = run(N, 120)  # full capacity, single chunk
    for cap, chunk in ((64, 30), (64, 7), (128, 120)):
        t = run(cap, chunk)
        np.testing.assert_array_equal(
            np.asarray(t.theta), np.asarray(t_ref.theta)
        )
        np.testing.assert_array_equal(
            np.asarray(t.stats.n_bright), np.asarray(t_ref.stats.n_bright)
        )


def test_fused_chain_overflow_rerun_is_exact(model):
    """Mid-chain capacity overflow (tiny initial buffers) must re-run the
    chunk at doubled capacity and land bitwise on the ample-capacity
    trajectory — apply_flips' arr is capacity-invariant, so the fused
    engine keeps the driver's exactness contract."""
    def run(cap):
        alg = api.firefly(
            model, kernel="rwmh", capacity=cap, cand_capacity=cap,
            q_db=0.02, step_size=0.1, z_backend="fused",
        )
        return api.sample(alg, jax.random.key(9), 300, chunk_size=32)

    t_small = run(24)
    assert t_small.algorithm.spec.capacity > 24, "must exercise an overflow"
    t_big = run(N)
    np.testing.assert_array_equal(
        np.asarray(t_small.theta), np.asarray(t_big.theta)
    )


def test_fused_chain_preserves_partition_invariants(model):
    alg = api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1, z_backend="fused",
    )
    trace = api.sample(alg, jax.random.key(14), 25)
    assert brightness.check_invariants(trace.final_state.bright)


# ---------------------------------------------------------------------------
# Chain law: fused vs jnp engines target the same posterior
# ---------------------------------------------------------------------------


def test_fused_chain_statistically_equivalent(model):
    """Acceptance: fused vs jnp z-engine chain-law equivalence — posterior
    moments and bright-count trajectories match in distribution (the
    engines follow different, law-equal uniform streams)."""
    key = jax.random.key(5)
    moments, brights = {}, {}
    for zb in ("jnp", "fused"):
        # Slice θ-kernel: low autocorrelation, so the comparison between two
        # independent uniform streams resolves the moments without a huge
        # run; 4 chains also exercise the fused step vmapped.
        alg = api.firefly(
            model, kernel="slice", capacity=128, cand_capacity=128,
            q_db=0.05, step_size=0.5, z_backend=zb,
        )
        trace = api.sample(alg, key, 800, num_chains=4, chunk_size=200)
        s = np.asarray(trace.theta)[:, 200:].reshape(-1, D)
        moments[zb] = (s.mean(0), s.std(0))
        brights[zb] = np.asarray(trace.stats.n_bright)[:, 200:]
        assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))
    mean_j, std_j = moments["jnp"]
    mean_f, std_f = moments["fused"]
    np.testing.assert_allclose(mean_f, mean_j, atol=4.0 * std_j.max() / 10)
    np.testing.assert_allclose(std_f, std_j, rtol=0.5)
    # bright-count trajectory law: same stationary occupancy
    np.testing.assert_allclose(
        brights["fused"].mean(), brights["jnp"].mean(), rtol=0.25
    )


def test_fused_with_pallas_backend_covers_whole_step(model):
    """backend='pallas' + z_backend='fused': candidate δ routes through the
    fused bright-GLM kernel and gradients (MALA) flow through its VJP."""
    alg = api.firefly(
        model, kernel="mala", capacity=128, cand_capacity=128, q_db=0.05,
        step_size=0.05, backend="pallas", z_backend="fused",
    )
    trace = api.sample(alg, jax.random.key(6), 60, chunk_size=30)
    assert np.all(np.isfinite(np.asarray(trace.theta)))
    assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_fused_z_other_families_smoke(backend):
    """Candidate δ dispatch handles the matrix-θ softmax and the Student-t
    bound on both likelihood backends."""
    from repro.data import robust_data, softmax_data

    cases = []
    sm = softmax_data(jax.random.key(2), n=300, d=16, k=3)
    cases.append(GLMModel.softmax(sm, n_classes=3))
    rd, _ = robust_data(jax.random.key(3), n=300, d=8)
    cases.append(GLMModel.robust(rd, nu=4.0, sigma=1.0, prior_scale=2.0))
    for m in cases:
        alg = api.firefly(
            m, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
            step_size=0.05, backend=backend, z_backend="fused",
        )
        trace = api.sample(alg, jax.random.key(4), 25, chunk_size=25)
        assert np.all(np.isfinite(np.asarray(trace.theta)))
        assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))
        assert brightness.check_invariants(trace.final_state.bright)


# ---------------------------------------------------------------------------
# API contract
# ---------------------------------------------------------------------------


def test_unknown_z_backend_rejected(model):
    with pytest.raises(ValueError, match="z_backend"):
        api.firefly(model, z_backend="cuda")


def test_fused_requires_implicit_mode(model):
    with pytest.raises(ValueError, match="implicit"):
        api.firefly(model, mode="explicit", z_backend="fused")
