"""Exactness pins for the posterior-sampling service (:mod:`repro.serve`).

The contract under test: a job's sampled trajectory — θ trace, per-step
stats, every collector result — is bitwise identical to a solo
``api.sample`` run with the same seed, REGARDLESS of how the service packs
it: which neighbors share its group engine, jobs joining or leaving
between chunks, a neighbor auto-terminating mid-flight, a checkpoint/
restore cycle, or a device-loss suspend/resume. Packing is performance
geometry, never statistics.

The workload comes from :func:`benchmarks._util.job_mix` — the same mix
the serving benchmark times and the example streams, shrunk to test sizes.
"""

import dataclasses

import jax
import numpy as np
import pytest

from benchmarks._util import job_mix
from repro import api
from repro.api import collectors as C
from repro.checkpoint import Checkpointer
from repro.data.synthetic import logistic_data
from repro.serve import (
    GroupEngine,
    Job,
    JobStatus,
    Service,
    TerminationPolicy,
    group_key,
)
from repro.serve import job as job_lib

jax.config.update("jax_platform_name", "cpu")

CHUNK = 16
MAX = 48
N, D = 96, 5
WARM = 10


def _mix():
    """The shared 5-kind workload at test sizes, fixed length (no auto-
    termination) so every job has a full-length solo reference."""
    return job_mix(0, 5, n=N, d=D, max_samples=MAX, num_warmup=WARM,
                   auto_terminate=False)


def _solo(job, on_chunk=None):
    """The reference: one plain api.sample run of the job, same seed/chunk
    discipline, fresh default collectors."""
    alg = job_lib.build_algorithm(job)
    tr = api.sample(
        alg, jax.random.key(job.seed), job.policy.max_samples,
        num_chains=job.num_chains, chunk_size=CHUNK,
        collectors={"trace": C.FullTrace(), "rhat": C.RHat()},
        on_chunk=on_chunk,
    )
    return tr.results


def _eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb)
    )


@pytest.fixture(scope="module")
def solo_refs():
    """Solo results for the shared mix, computed once per module."""
    return {j.job_id: _solo(j) for j in _mix()}


def _logistic_job(i, *, num_chains=1, policy=None, seed=None):
    return Job(
        job_id=f"log{i}", family="logistic",
        data=logistic_data(jax.random.key(100 + i), n=N, d=D),
        seed=(7 * i + 1 if seed is None else seed), num_chains=num_chains,
        capacity=32, cand_capacity=32, num_warmup=WARM,
        policy=policy or TerminationPolicy(max_samples=MAX),
    )


# ---------------------------------------------------------------- packing


def test_mixed_mix_bitwise_vs_solo(solo_refs):
    """Every job of the heterogeneous mix — K=1 and K=2, three GLM
    families, packed into shared group engines — retires with results
    bitwise equal to its solo run."""
    svc = Service(slot_budget=16, chunk_size=CHUNK)
    for j in _mix():
        svc.submit(j)
    res = svc.run(max_steps=MAX // CHUNK + 4)
    assert len(svc.scheduler.engines) == 0
    for job_id, ref in solo_refs.items():
        r = res[job_id]
        assert r.reason == "max_samples"
        assert r.committed == MAX
        assert _eq(r.results["trace"], ref["trace"]), job_id
        assert _eq(r.results["rhat"], ref["rhat"]), job_id


def test_join_between_chunks_is_bitwise_invisible(solo_refs):
    """Continuous batching: a job joining a running group mid-flight
    neither perturbs the incumbents nor loses its own solo trajectory."""
    jobs = {j.job_id: j for j in _mix()}
    late_ids = [i for i in jobs if i.startswith(("softmax", "robust"))]
    svc = Service(slot_budget=16, chunk_size=CHUNK)
    for job_id, j in jobs.items():
        if job_id not in late_ids:
            svc.submit(j)
    svc.step()  # incumbents commit one chunk
    for job_id in late_ids:
        svc.submit(jobs[job_id])
    res = svc.run(max_steps=MAX // CHUNK + 4)
    for job_id, ref in solo_refs.items():
        assert _eq(res[job_id].results["trace"], ref["trace"]), job_id


def test_same_group_jobs_share_one_engine():
    jobs = [_logistic_job(i) for i in range(3)]
    assert len({group_key(j) for j in jobs}) == 1
    svc = Service(slot_budget=8, chunk_size=CHUNK)
    for j in jobs:
        svc.submit(j)
    svc.step()
    assert len(svc.scheduler.engines) == 1
    (eng,) = svc.scheduler.engines.values()
    assert sorted(eng.job_ids) == sorted(j.job_id for j in jobs)
    assert eng.num_slots == 3


def test_auto_terminated_neighbor_leaves_others_bitwise():
    """A converging job leaving its group early must not shift a single
    bit of its fixed-length neighbors — and its own committed prefix is
    the solo run's prefix."""
    fixed = [_logistic_job(i) for i in range(2)]
    conv = _logistic_job(
        9,
        policy=TerminationPolicy(
            max_samples=MAX, min_samples=CHUNK, target_rhat=50.0,
        ),
    )
    assert group_key(conv) == group_key(fixed[0])  # same engine
    svc = Service(slot_budget=8, chunk_size=CHUNK)
    for j in (*fixed, conv):
        svc.submit(j)
    res = svc.run(max_steps=MAX // CHUNK + 4)

    r = res[conv.job_id]
    assert r.reason == "converged"
    assert CHUNK <= r.committed < MAX
    solo_conv = _solo(conv)
    np.testing.assert_array_equal(
        np.asarray(r.samples()),
        np.asarray(solo_conv["trace"]["theta"][:, : r.committed]),
    )
    for j in fixed:
        assert res[j.job_id].committed == MAX
        assert _eq(res[j.job_id].results["trace"], _solo(j)["trace"])


# ------------------------------------------------------------- streaming


def test_peek_matches_solo_on_chunk_peek():
    """Service-side peeks ARE the driver's chunk-boundary peeks: the R̂
    peeked from a running group at committed==2·CHUNK equals the solo
    run's ``event.peek`` at the same boundary, bit for bit — and peeking
    does not perturb the final results."""
    job = _logistic_job(4, num_chains=2)
    svc = Service(slot_budget=8, chunk_size=CHUNK)
    svc.submit(job)
    svc.step()
    svc.step()
    assert svc.committed(job.job_id) == 2 * CHUNK
    served = svc.peek(job.job_id, "rhat")

    captured = {}

    def hook(ev):
        if ev.committed == 2 * CHUNK:
            captured["rhat"] = ev.peek("rhat")
        return False

    ref = _solo(job, on_chunk=hook)
    assert _eq(served, captured["rhat"])
    res = svc.run(max_steps=MAX // CHUNK + 2)
    assert _eq(res[job.job_id].results["trace"], ref["trace"])


def test_stream_updates_arrive_each_boundary():
    job = _logistic_job(5)
    svc = Service(slot_budget=4, chunk_size=CHUNK)
    svc.submit(job, stream=("rhat",))
    seen = []
    svc.run(on_update=seen.append, max_steps=MAX // CHUNK + 2)
    assert [u.committed for u in seen] == [CHUNK, 2 * CHUNK, 3 * CHUNK]
    assert all("rhat" in u.peeks for u in seen)
    assert [u.done for u in seen] == [False, False, True]
    assert seen[-1].reason == "max_samples"


# ---------------------------------------------------- checkpoint / elastic


def test_checkpoint_restore_continues_bitwise(tmp_path, solo_refs):
    """Kill the service after one chunk, restore from the checkpoint
    alone (datasets travel in the checkpoint), drain — every job's
    results are still bitwise the solo run's."""
    svc = Service(slot_budget=16, chunk_size=CHUNK)
    for j in _mix():
        svc.submit(j)
    svc.step()
    ck = Checkpointer(str(tmp_path), keep_last=2)
    svc.checkpointer = ck
    svc.checkpoint()
    del svc

    svc2 = Service.restore(ck)
    for job_id in solo_refs:
        assert svc2.status(job_id) is JobStatus.SUSPENDED
        assert svc2.committed(job_id) == CHUNK
    res = svc2.run(max_steps=MAX // CHUNK + 4)
    for job_id, ref in solo_refs.items():
        assert res[job_id].committed == MAX
        assert _eq(res[job_id].results["trace"], ref["trace"]), job_id
        assert _eq(res[job_id].results["rhat"], ref["rhat"]), job_id


def test_device_loss_suspend_resume_bitwise(solo_refs):
    """Shrinking the slot budget mid-flight suspends the newest jobs;
    they drain later, time-sliced through the reduced budget, every
    trajectory still bitwise solo."""
    svc = Service(slot_budget=16, chunk_size=CHUNK)
    for j in _mix():
        svc.submit(j)
    svc.step()
    suspended = svc.handle_device_loss(n_devices=1, slots_per_device=2)
    assert svc.scheduler.slot_budget == 2
    assert suspended  # the mix needs 7 slots, so some jobs must yield
    for job_id in suspended:
        assert svc.status(job_id) is JobStatus.SUSPENDED
    res = svc.run(max_steps=12 * (MAX // CHUNK + 4))
    for job_id, ref in solo_refs.items():
        assert _eq(res[job_id].results["trace"], ref["trace"]), job_id


# ----------------------------------------------------------- service edges


def test_cancel_returns_committed_prefix():
    jobs = [_logistic_job(i) for i in range(2)]
    svc = Service(slot_budget=8, chunk_size=CHUNK)
    for j in jobs:
        svc.submit(j)
    svc.step()
    assert svc.cancel(jobs[0].job_id)
    r = svc.result(jobs[0].job_id)
    assert svc.status(jobs[0].job_id) is JobStatus.CANCELLED
    assert r.reason == "cancelled" and r.committed == CHUNK
    np.testing.assert_array_equal(
        np.asarray(r.samples()),
        np.asarray(_solo(jobs[0])["trace"]["theta"][:, :CHUNK]),
    )
    assert not svc.cancel(jobs[0].job_id)  # idempotent on retired jobs
    res = svc.run(max_steps=MAX // CHUNK + 2)  # the survivor drains
    assert res[jobs[1].job_id].reason == "max_samples"


def test_submit_validation():
    svc = Service(slot_budget=2, chunk_size=CHUNK)
    job = _logistic_job(0)
    svc.submit(job)
    with pytest.raises(ValueError, match="already submitted"):
        svc.submit(_logistic_job(0))
    with pytest.raises(ValueError, match="chain slots"):
        svc.submit(_logistic_job(1, num_chains=4))
    with pytest.raises(ValueError, match="not\\s+collectors"):
        svc.submit(_logistic_job(2), stream=("nope",))


def test_lane_backend_default_is_map():
    """lax.map over lanes is the exactness-bearing default — vmap is the
    opt-in fast path. Pinned so a perf patch cannot silently flip it."""
    import inspect

    sig = inspect.signature(GroupEngine.__init__)
    assert sig.parameters["lane_backend"].default == "map"
    svc = Service(slot_budget=4)
    assert svc.scheduler.lane_backend == "map"


def test_job_validation():
    with pytest.raises(ValueError):
        _logistic_job(0, policy=TerminationPolicy(max_samples=0))
    with pytest.raises(ValueError):
        dataclasses.replace(_logistic_job(0), num_chains=0)
    with pytest.raises(ValueError):
        dataclasses.replace(_logistic_job(0), family="nope")


def test_group_key_separates_incompatible_jobs():
    base = _logistic_job(0)
    assert group_key(base) == group_key(_logistic_job(1))
    assert group_key(base) != group_key(_logistic_job(2, num_chains=2))
    assert group_key(base) != group_key(
        _logistic_job(3, policy=TerminationPolicy(max_samples=2 * MAX))
    )
    small = dataclasses.replace(
        base, job_id="small",
        data=jax.tree.map(lambda l: l[: N // 2], base.data),
    )
    assert group_key(base) != group_key(small)
