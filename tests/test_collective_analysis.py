"""SPMD collective verification (repro.analysis.collectives).

Every analysis is tested from both sides — a known-good sharded program
it must pass and a known-bad fixture it must catch. The known-bad
fixtures encode the bug classes this verifier exists for:

  collective-budget        a naive z-phase that psums once PER DATUM
                           inside the scan (the O(N) communication the
                           paper's brightness variables eliminate)
  replication-consistency  a per-shard value escaping through
                           out_specs=P() under check_vma=False — shard
                           0's value silently overwrites the rest (the
                           real ``BrightState.num`` pspec bug)
  comm-bytes               a wire-bytes pin drifting from the program
  shard-shape              indivisible axes / stale per-shard geometry

The dist step's contract is pinned END-TO-END here: the static census
must equal the declared budget, the derived wire bytes must equal the
registry pin, and (in a subprocess with 8 forced host devices) the
compiled program's HLO-parsed wire bytes must equal the static model
EXACTLY.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import analysis
from repro.analysis import registry
from repro.analysis.collectives.census import census, census_counts
from repro.analysis.collectives.extract import (
    ShardedRegion,
    find_sharded_regions,
)
from repro.analysis.collectives.replication import (
    check_replication,
    output_variance,
)
from repro.analysis.collectives.rules import (
    CommBytesRule,
    ReplicationRule,
    ShardShapeRule,
    collective_rules,
)
from repro.analysis.collectives.shapes import check_shapes
from repro.analysis.collectives.wire_bytes import wire_model

jax.config.update("jax_platform_name", "cpu")

MESH = jax.sharding.AbstractMesh((("data", 8),))
X64 = jax.ShapeDtypeStruct((64,), jnp.float32)  # 8 rows per shard


def _shard(f, in_specs=(P("data"),), out_specs=P()):
    return jax.shard_map(f, mesh=MESH, in_specs=in_specs,
                         out_specs=out_specs, check_vma=False)


def _regions(fn, *args):
    return find_sharded_regions(jax.make_jaxpr(fn)(*args))


def _psum_mean(x):
    """The canonical good program: one scalar psum, replicated out."""
    return _shard(lambda xs: jax.lax.psum(jnp.sum(xs), "data"))(x)


# ---------------------------------------------------------------------------
# extraction + census
# ---------------------------------------------------------------------------


def test_extract_finds_region_under_abstract_mesh():
    (region,) = _regions(_psum_mean, X64)
    assert region.mesh_axes == {"data": 8}
    assert region.in_names == ({0: ("data",)},)
    assert region.out_names == ({},)
    assert region.global_in_avals[0].shape == (64,)


def test_census_scalar_psum():
    (region,) = _regions(_psum_mean, X64)
    (site,) = census(region)
    assert site.key == "psum@data" and site.scalar
    assert not site.in_loop and not site.unbounded
    assert census_counts([site]) == {"psum@data": 1}


def test_census_trip_multiplies_scan_collectives():
    def f(x):
        def body(xs):
            def step(c, xi):
                return c + jax.lax.psum(xi, "data"), xi

            out, _ = jax.lax.scan(step, 0.0, xs)
            return out

        return _shard(body)(x)

    (region,) = _regions(f, X64)
    (site,) = census(region)
    assert site.in_loop and site.trip_multiplier == 8  # 8 local rows
    assert census_counts([site]) == {"psum@data": 8}


def test_census_while_collective_is_unbounded():
    def f(x):
        def body(xs):
            def cond(c):
                return c[0] < 10.0

            def step(c):
                return (c[0] + jax.lax.psum(jnp.sum(xs), "data"), c[1])

            return jax.lax.while_loop(cond, step, (0.0, jnp.sum(xs)))[0]

        return _shard(body)(x)

    (region,) = _regions(f, X64)
    (site,) = census(region)
    assert site.unbounded
    model = wire_model([site])
    assert model["unbounded_sites"] == 1 and model["total"] == 0


# ---------------------------------------------------------------------------
# collective-budget rule
# ---------------------------------------------------------------------------


def test_budget_rule_passes_declared_program():
    report = analysis.check(
        _psum_mean, X64, rules=collective_rules({"psum@data": 1}),
        name="good",
    )
    assert report.ok, "\n".join(map(str, report.findings))


def test_budget_rule_catches_zphase_scan_psum():
    """The O(N)-communication z-phase: one psum per candidate datum."""

    def naive(x):
        def body(xs):
            def step(c, xi):
                return c + jax.lax.psum(xi, "data"), xi

            out, _ = jax.lax.scan(step, 0.0, xs)
            return out + jax.lax.psum(jnp.sum(xs), "data")

        return _shard(body)(x)

    report = analysis.check(
        naive, X64, rules=collective_rules({"psum@data": 1}), name="bad",
    )
    msgs = " ".join(f.message for f in report.findings)
    assert not report.ok
    assert "exceed the declared budget" in msgs
    assert "inside a scan body" in msgs


def test_budget_rule_catches_stale_pin():
    report = analysis.check(
        _psum_mean, X64, rules=collective_rules({"psum@data": 2}),
        name="stale",
    )
    assert not report.ok
    assert any("stale" in f.message for f in report.findings)


def test_budget_rule_catches_nonscalar_reduction():
    def f(x):
        return _shard(lambda xs: jax.lax.psum(xs, "data"),
                      out_specs=P())(x)

    report = analysis.check(
        f, X64, rules=collective_rules({"psum@data": 1}), name="wide",
    )
    assert not report.ok
    assert any("non-scalar" in f.message for f in report.findings)


def test_collective_rules_require_a_sharded_region():
    """A de-meshed entry point must FAIL, not silently pass (the sweep
    going blind to the sharded program is itself a regression)."""
    report = analysis.check(
        jnp.sum, X64, rules=collective_rules({}), name="demeshed",
    )
    assert not report.ok
    assert any("no shard_map region" in f.message
               for f in report.findings)


def test_collective_xpass_fails_the_report():
    report = analysis.check(
        _psum_mean, X64, rules=collective_rules({"psum@data": 1}),
        name="twin", expect_fail=("collective-budget",),
    )
    assert not report.ok
    assert report.rule_status("collective-budget") == "xpass"


# ---------------------------------------------------------------------------
# replication-consistency rule
# ---------------------------------------------------------------------------


def test_replication_passes_psum_output():
    (region,) = _regions(_psum_mean, X64)
    assert check_replication(region) == []
    (varies,) = output_variance(region)
    assert varies == frozenset()


def test_replication_catches_varying_as_replicated():
    """The ``BrightState.num`` bug class: a per-shard count declared
    replicated; with check_vma=False shard 0's value wins silently."""

    def leak(x):
        return _shard(lambda xs: jnp.sum((xs > 0).astype(jnp.int32)))(x)

    (region,) = _regions(leak, X64)
    (v,) = check_replication(region)
    assert v.leaked_axes == ("data",) and v.declared_axes == ()
    assert "shard 0" in v.message()

    report = analysis.check(leak, X64, rules=[ReplicationRule()],
                            name="leak")
    assert not report.ok


def test_replication_axis_index_introduces_variance():
    def f(x):
        return _shard(
            lambda xs: jnp.sum(xs) * 0 + jax.lax.axis_index("data")
        )(x)

    (region,) = _regions(f, X64)
    assert len(check_replication(region)) == 1


def test_replication_scan_carry_fixpoint():
    def folded(x):  # carry absorbs sharded xs: varies
        def body(xs):
            def step(c, xi):
                return c + xi, xi

            return jax.lax.scan(step, 0.0, xs)[0]

        return _shard(body)(x)

    def cleared(x):  # psum inside the body re-replicates the carry
        def body(xs):
            def step(c, xi):
                return c + jax.lax.psum(xi, "data"), xi

            return jax.lax.scan(step, 0.0, xs)[0]

        return _shard(body)(x)

    (bad,) = _regions(folded, X64)
    assert len(check_replication(bad)) == 1
    (good,) = _regions(cleared, X64)
    assert check_replication(good) == []


# ---------------------------------------------------------------------------
# comm-bytes rule
# ---------------------------------------------------------------------------


def test_wire_formulas_psum_and_all_gather():
    def f(x):
        def body(xs):
            s = jax.lax.psum(jnp.sum(xs), "data")  # 2 * 4 B
            g = jax.lax.all_gather(xs, "data")     # out - in = 256 - 32
            return s + jnp.sum(g)

        return _shard(body)(x)

    (region,) = _regions(f, X64)
    model = wire_model(census(region))
    assert model["per_kind"]["psum"] == 8
    assert model["per_kind"]["all_gather"] == 224
    assert model["total"] == 232


def test_comm_bytes_rule_catches_drifted_pin():
    good = analysis.check(_psum_mean, X64,
                          rules=[CommBytesRule(expected_total=8)],
                          name="pinned")
    assert good.ok
    bad = analysis.check(_psum_mean, X64,
                         rules=[CommBytesRule(expected_total=16)],
                         name="drift")
    assert not bad.ok
    assert any("diverged" in f.message for f in bad.findings)


# ---------------------------------------------------------------------------
# shard-shape rule
# ---------------------------------------------------------------------------


def _fake_region(in_shapes, in_names):
    return ShardedRegion(
        origin="synthetic", mesh_axes={"data": 8},
        in_names=tuple(in_names), out_names=(),
        jaxpr=None, check_rep=False,
        global_in_avals=tuple(
            jax.ShapeDtypeStruct(s, jnp.float32) for s in in_shapes
        ),
        global_out_avals=(),
    )


def test_shard_shapes_indivisible_and_zero_local():
    region = _fake_region([(12,), (0,)],
                          [{0: ("data",)}, {0: ("data",)}])
    issues = check_shapes(region)
    kinds = sorted(i.kind for i in issues)
    assert kinds == ["indivisible", "zero-local"]
    assert "not divisible" in issues[0].message()


def test_shard_shapes_local_pin_drift():
    (region,) = _regions(_psum_mean, X64)
    assert check_shapes(region, {0: {0: 8}}) == []
    (issue,) = check_shapes(region, {0: {0: 16}})
    assert issue.kind == "local-pin"

    report = analysis.check(
        _psum_mean, X64, rules=[ShardShapeRule(pin_locals={0: {0: 16}})],
        name="geometry",
    )
    assert not report.ok


# ---------------------------------------------------------------------------
# the real sharded programs, pinned through the same API
# ---------------------------------------------------------------------------


def test_dist_step_collective_contract():
    """dist.step: exactly one scalar psum per θ-proposal (4 psums per
    full step incl. refresh + stats), one pmax, one axis_index, ZERO
    collectives in the z-update scan, 40 wire bytes — and every
    replicated output proven replicated."""
    step_fn, data_s, stats_s, state_s = registry._dist_step_fixture()
    closed = jax.make_jaxpr(step_fn)(data_s, stats_s, state_s)
    regions = find_sharded_regions(closed)
    assert regions, "dist step lost its shard_map region"
    sites = [s for r in regions for s in census(r)]
    assert census_counts(sites) == registry.DIST_STEP_BUDGET
    assert not any(s.in_loop or s.unbounded for s in sites)
    assert all(s.scalar for s in sites
               if s.kind in ("psum", "pmax", "pmin"))
    assert wire_model(sites)["total"] == registry.DIST_STEP_WIRE_BYTES
    for r in regions:
        assert check_replication(r) == [], r.origin


def test_chain_fleet_has_zero_cross_chain_collectives():
    """Chains are independent: the fleet step must not communicate."""
    fleet = registry._fleet()
    keys, states = registry._fleet_keys_states(fleet, 8)
    closed = jax.make_jaxpr(fleet.step_chains_data)(
        keys, states, fleet.data, fleet.stats
    )
    regions = find_sharded_regions(closed)
    assert regions
    assert [s for r in regions for s in census(r)] == []
    for r in regions:
        assert check_replication(r) == [], r.origin


def test_sweep_covers_every_sharded_surface():
    names = [
        "dist.step", "dist.step.zphase_psum", "dist.step.wire_drift",
        "dist.fleet.rep_leak", "dist.chain_fleet",
        "dist.chain_fleet.closure", "dist.collector_fold",
        "serve.fleet_probe",
    ]
    for n in names:
        assert n in registry.REGISTRY, n
    summary = registry.run_registry(names)
    assert summary.ok, summary.format_table()
    by_name = {r.entry_point: r for r in summary.reports}
    assert (by_name["dist.step.zphase_psum"]
            .rule_status("collective-budget") == "xfail")
    assert (by_name["dist.step.wire_drift"]
            .rule_status("comm-bytes") == "xfail")
    assert (by_name["dist.fleet.rep_leak"]
            .rule_status("replication-consistency") == "xfail")
    record = summary.to_record()
    step = record["entry_points"]["dist.step"]
    assert step["collective_census"] == registry.DIST_STEP_BUDGET
    assert (step["collective_wire_bytes"]["total"]
            == registry.DIST_STEP_WIRE_BYTES)
    fleet = record["entry_points"]["dist.chain_fleet"]
    assert fleet["collective_census"] == {}
    assert fleet["collective_wire_bytes"]["total"] == 0


# ---------------------------------------------------------------------------
# HLO cross-validation: static model == compiled program, exactly
# ---------------------------------------------------------------------------

_FALLBACK_HLO = textwrap.dedent("""\
    ENTRY %main (p0: f32[8]) -> f32[8] {
      %p0 = f32[8]{0} parameter(0)
      ROOT %w = f32[8]{0} while(%p0), condition=%cond, body=%body
    }

    %body (b: f32[8]) -> f32[8] {
      %bp = f32[8]{0} parameter(0)
      ROOT %ar = f32[8]{0} all-reduce(%bp), replica_groups={}
    }

    %cond (c: f32[8]) -> pred[] {
      %cp = f32[8]{0} parameter(0)
      ROOT %done = pred[] custom-call(%cp)
    }
    """)


def test_hlo_trip_fallback_is_a_structured_flag():
    from repro.launch.hlo_analysis import analyze_hlo, collective_wire_bytes

    rec = analyze_hlo(_FALLBACK_HLO)
    assert rec["trip_counts_ok"] is False
    assert rec["trip_count_fallbacks"] == ["body"]
    assert rec["collective_total"] == 64.0  # 2 * 32 B, trip guessed as 1

    wire = collective_wire_bytes(_FALLBACK_HLO, axis_sizes={"data": 8})
    assert wire["total"] == 64.0 and not wire["trip_counts_ok"]
    assert wire["ring_total"] == 64.0 * 7 / 8 and wire["n_devices"] == 8


_CROSSVAL_CHILD = textwrap.dedent("""\
    import json, os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    jax.config.update("jax_platform_name", "cpu")
    import jax.numpy as jnp
    from repro.analysis.collectives.census import census
    from repro.analysis.collectives.extract import find_sharded_regions
    from repro.analysis.collectives.wire_bytes import wire_model
    from repro.data import logistic_data
    from repro.distributed.flymc_dist import make_dist_flymc
    from repro.launch.hlo_analysis import collective_wire_bytes
    from repro.models.bayes_glm import GLMModel

    data = logistic_data(jax.random.key(0), n=1024, d=4, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    mesh = jax.make_mesh((8,), ("data",))
    _, init_fn, step_fn, _ = make_dist_flymc(
        model.bound, model.log_prior, mesh, 1024,
        kernel="rwmh", capacity=64, cand_capacity=64, q_db=0.01,
    )
    stats = model.bound.suffstats(data)
    theta = jnp.zeros((4,), jnp.float32)
    state, _ = jax.jit(init_fn)(data, stats, theta, jax.random.key(1))

    closed = jax.make_jaxpr(step_fn)(data, stats, state)
    sites = [s for r in find_sharded_regions(closed) for s in census(r)]
    static = wire_model(sites)

    text = jax.jit(step_fn).lower(data, stats, state).compile().as_text()
    hlo = collective_wire_bytes(text, axis_sizes={"data": 8})
    print(json.dumps({"static": static["total"], "hlo": hlo["total"],
                      "trip_ok": hlo["trip_counts_ok"]}))
    """)


def test_static_wire_model_matches_compiled_hlo_exactly():
    """The acceptance pin: the aval-derived model and the HLO-parsed
    accounting of the COMPILED 8-device dist step agree to the byte.
    Subprocess because XLA_FLAGS must be set before jax initializes."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ["src", env.get("PYTHONPATH", "")] if p
    )
    out = subprocess.run(
        [sys.executable, "-c", _CROSSVAL_CHILD],
        capture_output=True, text=True, timeout=540, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["trip_ok"], rec
    assert rec["static"] == registry.DIST_STEP_WIRE_BYTES
    assert rec["hlo"] == rec["static"], rec
