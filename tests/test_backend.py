"""Backend dispatch: the fused Pallas θ-update vs the jnp reference path.

Three layers of guarantee, cheapest to strongest:
  * joint-log-posterior parity (value, δ cache, and ∇θ) at fixed θ for
    every fused family, including the matrix-θ softmax;
  * chain-level equivalence: ``backend="pallas"`` (interpret off-TPU) run
    through ``repro.api.sample`` produces statistically equivalent
    posteriors to ``backend="jnp"`` on the quickstart problem;
  * API contract: unknown backends and non-fused bounds are rejected
    up front.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import brightness, flymc
from repro.data import logistic_data, softmax_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D = 400, 4


@pytest.fixture(scope="module")
def tuned_model():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(9), steps=300)
    return model.map_tuned(theta_map)


def _joint_pair(model, capacity=128, kernel="rwmh"):
    """(f_jnp, f_pallas) over the same bright buffer, plus a θ to probe."""
    fs = {}
    for backend in ("jnp", "pallas"):
        alg = api.firefly(model, kernel=kernel, capacity=capacity,
                          backend=backend)
        state = jax.jit(alg.init)(jax.random.key(1), alg.default_position)
        idx, mask = brightness.bright_buffer(state.bright, capacity)
        fs[backend] = flymc.make_joint_logpost(
            alg.spec, model.data, model.stats, idx, mask
        )
    return fs["jnp"], fs["pallas"], mask


def test_joint_logpost_parity_logistic(tuned_model):
    f_jnp, f_pallas, mask = _joint_pair(tuned_model)
    theta = 0.3 * jnp.ones(D)
    (lp_j, d_j) = f_jnp(theta)
    (lp_p, d_p) = f_pallas(theta)
    np.testing.assert_allclose(float(lp_j), float(lp_p), rtol=1e-5)
    m = np.asarray(mask)
    np.testing.assert_allclose(
        np.where(m, d_j, 0.0), np.where(m, d_p, 0.0), rtol=1e-4, atol=1e-5
    )
    g_j = jax.grad(lambda t: f_jnp(t)[0])(theta)
    g_p = jax.grad(lambda t: f_pallas(t)[0])(theta)
    np.testing.assert_allclose(g_j, g_p, rtol=1e-3, atol=1e-4)


def test_joint_logpost_parity_softmax():
    data = softmax_data(jax.random.key(2), n=300, d=16, k=3)
    model = GLMModel.softmax(data, n_classes=3)
    f_jnp, f_pallas, mask = _joint_pair(model, capacity=256)
    theta = 0.1 * jnp.ones((3, 16))
    lp_j, _ = f_jnp(theta)
    lp_p, _ = f_pallas(theta)
    np.testing.assert_allclose(float(lp_j), float(lp_p), rtol=1e-4)
    g_j = jax.grad(lambda t: f_jnp(t)[0])(theta)
    g_p = jax.grad(lambda t: f_pallas(t)[0])(theta)
    np.testing.assert_allclose(g_j, g_p, rtol=1e-3, atol=1e-4)


def test_joint_logpost_parity_student_t():
    from repro.data import robust_data

    data, _ = robust_data(jax.random.key(3), n=300, d=8)
    model = GLMModel.robust(data, nu=4.0, sigma=1.0, prior_scale=2.0)
    f_jnp, f_pallas, _ = _joint_pair(model, capacity=256)
    theta = 0.05 * jnp.ones(8)
    lp_j, _ = f_jnp(theta)
    lp_p, _ = f_pallas(theta)
    np.testing.assert_allclose(float(lp_j), float(lp_p), rtol=1e-4)


def test_pallas_chain_statistically_equivalent(tuned_model):
    """Acceptance: the full quickstart chain through the fused kernel
    (interpret off-TPU) matches the jnp backend's posterior."""
    key = jax.random.key(5)
    moments = {}
    for backend in ("jnp", "pallas"):
        alg = api.firefly(
            tuned_model, kernel="rwmh", capacity=128, cand_capacity=128,
            q_db=0.05, step_size=0.12, adapt_target="auto", backend=backend,
        )
        trace = api.sample(alg, key, 800, chunk_size=200)
        s = np.asarray(trace.theta[0])[200:]
        moments[backend] = (s.mean(0), s.std(0))
        assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))
    mean_j, std_j = moments["jnp"]
    mean_p, std_p = moments["pallas"]
    # Same key → same proposals; fp-level lp differences can flip an accept
    # decision, so compare posteriors statistically, not trajectories.
    np.testing.assert_allclose(mean_p, mean_j, atol=4.0 * std_j.max() / 10)
    np.testing.assert_allclose(std_p, std_j, rtol=0.5)


def test_pallas_chain_mala_grads():
    """Gradient kernels drive the chain through the custom VJP."""
    data = logistic_data(jax.random.key(11), n=200, d=3, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    alg = api.firefly(model, kernel="mala", capacity=128, cand_capacity=128,
                      q_db=0.1, step_size=0.05, backend="pallas")
    trace = api.sample(alg, jax.random.key(6), 60, chunk_size=30)
    assert np.all(np.isfinite(np.asarray(trace.theta)))
    assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))


def test_unknown_backend_rejected(tuned_model):
    with pytest.raises(ValueError, match="backend"):
        api.firefly(tuned_model, backend="cuda")


def test_pallas_requires_fused_bound(tuned_model):
    class MinimalBound:
        """Implements Bound but not the fused hook."""

        name = "minimal"

        def log_lik(self, theta, data):
            return jnp.zeros(data.x.shape[0])

        def log_bound(self, theta, data):
            return jnp.full(data.x.shape[0], -0.1)

        def suffstats(self, data):
            from repro.core.bounds import CollapsedStats

            d = data.x.shape[1]
            return CollapsedStats(
                jnp.zeros((d, d)), jnp.zeros(d), jnp.zeros(())
            )

        def collapsed(self, theta, stats):
            return jnp.zeros(())

        def tighten(self, theta_map, data):
            return data

    with pytest.raises(ValueError, match="FusedBound"):
        api.firefly(
            tuned_model, bound=MinimalBound(), backend="pallas"
        )
    # ...and the same bound is fine on the jnp path.
    api.firefly(tuned_model, bound=MinimalBound(), backend="jnp")


def test_pallas_rejects_inherited_hook_with_overridden_math(tuned_model):
    """A subclass changing log_lik must not silently inherit the parent's
    fused kernel — the kernel hard-codes the parent's math."""
    from repro.core.bounds import LogisticBound, fused_family_of

    class TemperedLogistic(LogisticBound):
        @staticmethod
        def log_lik(theta, data):
            return 0.5 * LogisticBound.log_lik(theta, data)

    assert fused_family_of(TemperedLogistic()) is None
    with pytest.raises(ValueError, match="FusedBound"):
        api.firefly(tuned_model, bound=TemperedLogistic(), backend="pallas")

    # Re-declaring the hook is an explicit opt-in and is honored.
    class RenamedLogistic(LogisticBound):
        name = "renamed"
        fused_family = "logistic"

    assert fused_family_of(RenamedLogistic()) == "logistic"
    api.firefly(tuned_model, bound=RenamedLogistic(), backend="pallas")


def test_pallas_rejects_mixin_supplied_math(tuned_model):
    """A sibling mixin ahead of the declarer in the MRO changes the math
    without subclassing it — the guard must catch that route too, not just
    direct subclass overrides."""
    from repro.core.bounds import LogisticBound, fused_family_of

    class TemperedMixin:
        @staticmethod
        def log_lik(theta, data):
            return 0.5 * LogisticBound.log_lik(theta, data)

    class MixedIn(TemperedMixin, LogisticBound):
        pass

    assert fused_family_of(MixedIn()) is None
    with pytest.raises(ValueError, match="FusedBound"):
        api.firefly(tuned_model, bound=MixedIn(), backend="pallas")
