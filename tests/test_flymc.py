"""FlyMC exactness and mechanics (the paper's central claim, §2).

The money test: the FlyMC chain's θ-marginal must match the full-data
posterior. We check it on a small logistic problem by comparing posterior
moments against a long full-data MCMC run, for both implicit (Alg. 2) and
explicit (Alg. 1) z-kernels, untuned and MAP-tuned bounds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import brightness, flymc
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel, run_regular_mcmc

jax.config.update("jax_platform_name", "cpu")

N, D = 400, 4


@pytest.fixture(scope="module")
def model():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    return GLMModel.logistic(data, prior_scale=2.0, xi=1.5)


@pytest.fixture(scope="module")
def reference_moments(model):
    """Long full-data RWMH chain — the ground-truth posterior moments."""
    theta0 = jnp.zeros(D)
    samples, _ = run_regular_mcmc(
        model, theta0, jax.random.key(1), 6000, kernel="rwmh", step_size=0.12
    )
    s = np.stack(samples)[1500:]
    return s.mean(0), s.std(0)


def _flymc_moments(model, kernel, mode, tuned, key, iters=6000, burn=1500):
    from repro.core import samplers

    m = model
    if tuned:
        theta_map = m.map_estimate(jax.random.key(9), steps=400)
        m = m.map_tuned(theta_map)
    spec = m.flymc_spec(
        kernel=kernel,
        capacity=128,
        cand_capacity=128,
        q_db=0.05 if tuned else 0.1,
        mode=mode,
        resample_fraction=0.2,
        adapt_target=(
            None if kernel == "slice" else samplers.TARGET_ACCEPT[kernel]
        ),
    )
    step0 = 0.03 if kernel == "mala" else 0.12
    state, _, spec = m.init_chain(spec, jnp.zeros(D), key, step_size=step0)
    samples, trace, total_q, spec = m.run_chain(spec, state, iters)
    s = np.stack(samples)[burn:]
    return s.mean(0), s.std(0), trace, total_q


@pytest.mark.parametrize("mode", ["implicit", "explicit"])
def test_flymc_matches_full_posterior(model, reference_moments, mode):
    ref_mean, ref_std = reference_moments
    mean, std, trace, _ = _flymc_moments(
        model, "rwmh", mode, tuned=False, key=jax.random.key(2)
    )
    np.testing.assert_allclose(mean, ref_mean, atol=3.5 * ref_std.max() / 10)
    np.testing.assert_allclose(std, ref_std, rtol=0.5)


def test_map_tuned_flymc_matches_and_is_cheap(model, reference_moments):
    ref_mean, ref_std = reference_moments
    mean, std, trace, total_q = _flymc_moments(
        model, "rwmh", "implicit", tuned=True, key=jax.random.key(3)
    )
    np.testing.assert_allclose(mean, ref_mean, atol=3.5 * ref_std.max() / 10)
    np.testing.assert_allclose(std, ref_std, rtol=0.5)
    # Tuned bounds ⇒ few bright points after burn-in (paper §4.1).
    brights = [t["n_bright"] for t in trace[1500:]]
    assert np.mean(brights) < 0.25 * N
    # Each iteration must query far fewer than N likelihoods on average.
    assert total_q / len(trace) < 0.6 * N


def test_mala_flymc_matches(model, reference_moments):
    ref_mean, ref_std = reference_moments
    mean, std, _, _ = _flymc_moments(
        model, "mala", "implicit", tuned=True, key=jax.random.key(4),
        iters=4000, burn=1000,
    )
    np.testing.assert_allclose(mean, ref_mean, atol=3.5 * ref_std.max() / 10)
    np.testing.assert_allclose(std, ref_std, rtol=0.5)


def test_slice_flymc_matches(model, reference_moments):
    ref_mean, ref_std = reference_moments
    mean, std, _, _ = _flymc_moments(
        model, "slice", "implicit", tuned=True, key=jax.random.key(5),
        iters=3000, burn=800,
    )
    np.testing.assert_allclose(mean, ref_mean, atol=3.5 * ref_std.max() / 10)
    np.testing.assert_allclose(std, ref_std, rtol=0.5)


def test_explicit_z_update_law_without_replacement(model):
    """Pin the explicit (Alg. 1) resampling law: the subset is a permutation
    slice — no duplicate indices, so the z/δ scatters are deterministic —
    and the realized z follows p(z=1) = -expm1(-δ) under the split keys."""
    spec = model.flymc_spec(mode="explicit", resample_fraction=0.2)
    n = model.data.x.shape[0]
    r = max(1, int(round(n * spec.resample_fraction)))
    theta = 0.1 * jnp.ones(D)
    key = jax.random.key(42)
    z0 = jax.random.bernoulli(jax.random.key(1), 0.3, (n,))
    bright = brightness.from_z(z0)
    delta_full = jnp.zeros(n)
    z_new, delta_new, queries, overflow = flymc._explicit_z_update(
        spec, model.data, key, theta, bright, delta_full
    )
    # Law re-derivation with the same key splits (this IS the pinned law:
    # change the sampling scheme and this fails).
    k_idx, k_z = jax.random.split(key)
    idx = np.asarray(
        jax.random.permutation(k_idx, jnp.arange(n, dtype=jnp.int32))[:r]
    )
    assert len(np.unique(idx)) == r  # without replacement
    delta = model.bound.log_lik(theta, model.data) - model.bound.log_bound(
        theta, model.data
    )
    p_bright = -jnp.expm1(-jnp.maximum(delta[idx], 1e-10))
    z_exp = np.asarray(z0).copy()
    z_exp[idx] = np.asarray(
        jax.random.uniform(k_z, (r,), p_bright.dtype) < p_bright
    )
    np.testing.assert_array_equal(np.asarray(z_new), z_exp)
    np.testing.assert_allclose(
        np.asarray(delta_new)[idx], np.asarray(delta[idx]), rtol=1e-6
    )
    assert int(queries) == r and not bool(overflow)
    # Determinism: same inputs, same realized update.
    z2, d2, _, _ = flymc._explicit_z_update(
        spec, model.data, key, theta, bright, delta_full
    )
    np.testing.assert_array_equal(np.asarray(z_new), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(delta_new), np.asarray(d2))


def test_capacity_overflow_is_exact(model):
    """A chain run at tiny capacity (forcing growth) must equal one run at
    large capacity with the same keys — overflow handling may not change the
    realized chain."""
    theta0 = jnp.zeros(D)
    out = {}
    for cap in (16, 256):
        spec = model.flymc_spec(
            kernel="rwmh", capacity=cap, cand_capacity=cap, q_db=0.2
        )
        state, _, spec2 = model.init_chain(
            spec, theta0, jax.random.key(7), step_size=0.1
        )
        samples, trace, _, _ = model.run_chain(spec2, state, 60)
        out[cap] = np.stack(samples)
    np.testing.assert_allclose(out[16], out[256], rtol=1e-4, atol=1e-5)


def test_queries_counted(model):
    spec = model.flymc_spec(kernel="rwmh", capacity=256, cand_capacity=256)
    state, n0, spec = model.init_chain(
        spec, jnp.zeros(D), jax.random.key(8), step_size=0.1
    )
    _, trace, total_q, _ = model.run_chain(spec, state, 20)
    assert total_q > 0
    assert total_q == sum(t["lik_queries"] for t in trace)
    # implicit mode: per-iter queries ≤ bright evals + candidates ≤ N + N
    assert all(t["lik_queries"] <= 2 * N for t in trace)


def test_joint_lp_consistent_with_dense_eval(model):
    """The padded-buffer joint lp must equal a dense masked evaluation."""
    spec = model.flymc_spec(kernel="rwmh", capacity=256, cand_capacity=256)
    state, _, spec = model.init_chain(
        spec, 0.1 * jnp.ones(D), jax.random.key(10), step_size=0.1
    )
    z = brightness.z_of(state.bright)
    theta = state.sampler.theta
    delta = model.bound.log_lik(theta, model.data) - model.bound.log_bound(
        theta, model.data
    )
    dense = (
        model.log_prior(theta)
        + model.bound.collapsed(theta, model.stats)
        + jnp.sum(jnp.where(z, flymc.log_expm1(delta), 0.0))
    )
    np.testing.assert_allclose(
        float(state.sampler.lp), float(dense), rtol=1e-4, atol=1e-4
    )
