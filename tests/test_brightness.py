"""Tests for the bright/dark partition structure (paper §3.3, Fig. 3)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import brightness

jax.config.update("jax_platform_name", "cpu")


def test_init_all_dark():
    s = brightness.init(10)
    assert int(s.num) == 0
    assert not np.any(np.asarray(brightness.z_of(s)))
    assert brightness.check_invariants(s)


def test_brighten_darken_roundtrip():
    s = brightness.init(8)
    s = brightness.brighten(s, jnp.int32(3))
    s = brightness.brighten(s, jnp.int32(5))
    z = np.asarray(brightness.z_of(s))
    assert z[3] and z[5] and z.sum() == 2
    assert brightness.check_invariants(s)
    s = brightness.darken(s, jnp.int32(3))
    z = np.asarray(brightness.z_of(s))
    assert (not z[3]) and z[5] and z.sum() == 1
    assert brightness.check_invariants(s)


def test_brighten_idempotent():
    s = brightness.init(6)
    s = brightness.brighten(s, jnp.int32(2))
    s2 = brightness.brighten(s, jnp.int32(2))
    assert int(s2.num) == 1
    assert brightness.check_invariants(s2)


def test_darken_idempotent_on_dark():
    s = brightness.init(6)
    s2 = brightness.darken(s, jnp.int32(4))
    assert int(s2.num) == 0
    assert brightness.check_invariants(s2)


@settings(deadline=None, max_examples=50)
@given(st.lists(st.booleans(), min_size=1, max_size=64))
def test_from_z_invariants(bits):
    z = jnp.asarray(np.array(bits))
    s = brightness.from_z(z)
    assert brightness.check_invariants(s)
    np.testing.assert_array_equal(np.asarray(brightness.z_of(s)), np.array(bits))
    assert int(s.num) == sum(bits)


@settings(deadline=None, max_examples=30)
@given(
    st.integers(1, 40),
    st.lists(st.tuples(st.integers(0, 39), st.booleans()), max_size=30),
)
def test_sequential_ops_match_batch(n, ops):
    """O(1) paper ops and the vectorized rebuild yield the same z set."""
    ops = [(i % n, b) for i, b in ops]
    s = brightness.init(n)
    z_ref = np.zeros(n, bool)
    for i, b in ops:
        if b:
            s = brightness.brighten(s, jnp.int32(i))
        else:
            s = brightness.darken(s, jnp.int32(i))
        z_ref[i] = b
    assert brightness.check_invariants(s)
    np.testing.assert_array_equal(np.asarray(brightness.z_of(s)), z_ref)
    s_batch = brightness.from_z(jnp.asarray(z_ref))
    np.testing.assert_array_equal(
        np.asarray(brightness.z_of(s_batch)), z_ref
    )


def test_bright_buffer_padding():
    z = jnp.asarray([True, False, True, False, False, True])
    s = brightness.from_z(z)
    idx, mask = brightness.bright_buffer(s, 4)
    assert idx.shape == (4,) and mask.shape == (4,)
    assert set(np.asarray(idx)[np.asarray(mask)]) == {0, 2, 5}
    assert int(mask.sum()) == 3


@settings(deadline=None, max_examples=50)
@given(st.integers(1, 12), st.integers(1, 20), st.integers(0, 12))
def test_dark_buffer_small_n_edge_cases(n, capacity, n_bright):
    """dark_buffer must stay well-defined for capacity > N and any bright
    count (the old min(num, n - capacity) start went negative there)."""
    n_bright = min(n_bright, n)
    z = np.zeros(n, bool)
    z[:n_bright] = True
    s = brightness.from_z(jnp.asarray(z))
    idx, mask = brightness.dark_buffer(s, capacity)
    assert idx.shape == (capacity,) and mask.shape == (capacity,)
    idx, mask = np.asarray(idx), np.asarray(mask)
    assert np.all((idx >= 0) & (idx < n))
    # Every masked-valid slot is genuinely dark…
    z_of = np.asarray(brightness.z_of(s))
    assert not np.any(z_of[idx[mask]])
    # …and the buffer exposes the whole dark tail whenever it fits.
    n_dark = n - n_bright
    if capacity >= n_dark:
        assert set(idx[mask]) == set(np.arange(n)[~z_of])
    else:
        assert mask.sum() == capacity


def test_dark_buffer_capacity_exceeds_n_under_jit():
    s = brightness.from_z(jnp.asarray([True, False, True]))
    idx, mask = jax.jit(
        lambda st_: brightness.dark_buffer(st_, 8)
    )(s)
    assert idx.shape == (8,)
    assert set(np.asarray(idx)[np.asarray(mask)]) == {1}


def test_bright_buffer_under_jit():
    @jax.jit
    def f(z):
        s = brightness.from_z(z)
        return brightness.bright_buffer(s, 4)

    idx, mask = f(jnp.asarray([False, True, False, True, False, False]))
    assert set(np.asarray(idx)[np.asarray(mask)]) == {1, 3}


# ---------------------------------------------------------------------------
# apply_flips — the fused z-engine's O(changed) incremental partition update
# ---------------------------------------------------------------------------


def _random_flip_case(rng, n):
    """(state, darken, brighten_idx, brighten_mask, expected_z) respecting
    the apply_flips contract: capacity >= num, darken over bright-buffer
    slots, brighten ids dark & distinct (masked tail may be garbage)."""
    z = rng.random(n) < rng.random()
    s = brightness.from_z(jnp.asarray(z))
    num = int(s.num)
    cap = int(rng.integers(max(1, num), n + 3))
    sb = int(rng.integers(1, n + 3))
    darken = rng.random(cap) < 0.4
    dark_ids = np.flatnonzero(~np.asarray(brightness.z_of(s)))
    nb = int(min(len(dark_ids), rng.integers(0, sb + 1)))
    chosen = (
        rng.choice(dark_ids, nb, replace=False).astype(np.int32)
        if nb else np.empty(0, np.int32)
    )
    b_idx = np.full(sb, n + 5, np.int32)  # out-of-range padding on purpose
    b_idx[:nb] = chosen
    b_mask = np.arange(sb) < nb
    expected = np.asarray(brightness.z_of(s)).copy()
    slots = np.arange(cap)
    eff = darken & (slots < num)
    expected[np.asarray(s.arr)[slots[eff]]] = False
    expected[chosen] = True
    return s, darken, b_idx, b_mask, expected


@settings(deadline=None, max_examples=60)
@given(st.integers(0, 10_000), st.integers(4, 48))
def test_apply_flips_matches_from_z_set(seed, n):
    """apply_flips realizes exactly the flipped z (as a set) while keeping
    the permutation/inverse/num invariants — the from_z contract without
    the O(N) rebuild."""
    rng = np.random.default_rng(seed)
    s, darken, b_idx, b_mask, expected = _random_flip_case(rng, n)
    out = brightness.apply_flips(
        s, jnp.asarray(darken), jnp.asarray(b_idx), jnp.asarray(b_mask)
    )
    assert brightness.check_invariants(out)
    np.testing.assert_array_equal(np.asarray(brightness.z_of(out)), expected)
    assert int(out.num) == int(expected.sum())


def test_apply_flips_arr_is_capacity_invariant():
    """The realized partition ARRAY (not just the z set) must not depend on
    the darken/brighten buffer sizes: the fused chain's θ-update sums in
    arr order, so capacity-doubling re-runs stay bitwise exact only if
    apply_flips is order-stable across capacities."""
    rng = np.random.default_rng(7)
    n = 40
    z = rng.random(n) < 0.3
    s = brightness.from_z(jnp.asarray(z))
    num = int(s.num)
    dark_ids = np.flatnonzero(~np.asarray(brightness.z_of(s)))
    chosen = rng.choice(dark_ids, 4, replace=False).astype(np.int32)
    dk = rng.random(num) < 0.5
    outs = []
    for cap, sb in ((num, 4), (num + 7, 9), (n, n)):
        darken = np.zeros(cap, bool)
        darken[:num] = dk
        b_idx = np.full(sb, n, np.int32)
        b_idx[:4] = chosen
        b_mask = np.arange(sb) < 4
        outs.append(
            brightness.apply_flips(
                s, jnp.asarray(darken), jnp.asarray(b_idx),
                jnp.asarray(b_mask),
            )
        )
    for o in outs[1:]:
        np.testing.assert_array_equal(np.asarray(outs[0].arr), np.asarray(o.arr))
        np.testing.assert_array_equal(np.asarray(outs[0].tab), np.asarray(o.tab))
        assert int(outs[0].num) == int(o.num)


def test_apply_flips_noop_round():
    s = brightness.from_z(jnp.asarray([True, False, True, False, False]))
    out = brightness.apply_flips(
        s, jnp.zeros(3, bool), jnp.full(2, 5, jnp.int32), jnp.zeros(2, bool)
    )
    np.testing.assert_array_equal(np.asarray(out.arr), np.asarray(s.arr))
    np.testing.assert_array_equal(np.asarray(out.tab), np.asarray(s.tab))
    assert int(out.num) == int(s.num)
