"""Optional-hypothesis shim: property tests degrade to seeded spot checks.

``hypothesis`` is not part of the runtime environment everywhere the tier-1
suite runs. When it is installed we re-export the real ``given``/``settings``
/``strategies``; when it is not, a tiny deterministic stand-in runs each
property test on a fixed number of seeded random examples. That keeps the
properties exercised (far better than skipping the modules wholesale) while
the full generative search still runs wherever hypothesis is available.
"""

from __future__ import annotations

try:  # pragma: no cover - depends on environment
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    import random
    import types

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: r.randint(lo, hi))

    def _booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def _floats(lo, hi):
        return _Strategy(lambda r: r.uniform(lo, hi))

    def _lists(elem, min_size=0, max_size=10):
        def draw(r):
            k = r.randint(min_size, max_size)
            return [elem.draw(r) for _ in range(k)]

        return _Strategy(draw)

    def _tuples(*elems):
        return _Strategy(lambda r: tuple(e.draw(r) for e in elems))

    st = types.SimpleNamespace(
        integers=_integers,
        booleans=_booleans,
        floats=_floats,
        lists=_lists,
        tuples=_tuples,
    )

    def settings(**kw):
        max_examples = kw.get("max_examples", _FALLBACK_EXAMPLES)

        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strat_args, **strat_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = min(
                    getattr(wrapper, "_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES,
                )
                for i in range(n):
                    r = random.Random(0xF1EF1E + i)
                    drawn = [s.draw(r) for s in strat_args]
                    drawn_kw = {k: s.draw(r) for k, s in strat_kwargs.items()}
                    fn(*args, *drawn, **kwargs, **drawn_kw)

            # Copy identity but NOT __wrapped__: pytest must see the
            # zero-argument wrapper signature, not the strategy params
            # (it would otherwise look for fixtures named like them).
            for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
                setattr(wrapper, attr, getattr(fn, attr))
            return wrapper

        return deco
