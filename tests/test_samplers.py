"""θ-kernel correctness: each operator must sample its target (paper §4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import samplers

jax.config.update("jax_platform_name", "cpu")

# Anisotropic 2-D Gaussian target.
TRUE_MEAN = np.array([1.0, -2.0], np.float32)
TRUE_STD = np.array([1.0, 0.5], np.float32)


def _target(theta):
    z = (theta - jnp.asarray(TRUE_MEAN)) / jnp.asarray(TRUE_STD)
    return -0.5 * jnp.sum(z * z), jnp.zeros((), theta.dtype)


def _run(kernel_name, n_iters, step, **kw):
    f = _target
    state = samplers.init_state(
        f, jnp.zeros(2), with_grad=samplers.NEEDS_GRAD[kernel_name]
    )
    kern = samplers.make_kernel(kernel_name, f, **kw)

    @jax.jit
    def step_fn(key, st):
        if kernel_name == "slice":
            return kern(key, st, width=jnp.asarray(step))
        return kern(key, st, step_size=jnp.asarray(step))

    key = jax.random.key(0)
    out = []
    for _ in range(n_iters):
        key, sub = jax.random.split(key)
        state, info = step_fn(sub, state)
        out.append(np.asarray(state.theta))
    return np.stack(out)


@pytest.mark.parametrize(
    "kernel,step,iters",
    [("rwmh", 0.7, 4000), ("mala", 0.6, 3000), ("slice", 2.0, 1500),
     ("hmc", 0.35, 1500)],
)
def test_kernel_recovers_gaussian_moments(kernel, step, iters):
    samples = _run(kernel, iters, step)
    burn = iters // 4
    mean = samples[burn:].mean(0)
    std = samples[burn:].std(0)
    np.testing.assert_allclose(mean, TRUE_MEAN, atol=0.25)
    np.testing.assert_allclose(std, TRUE_STD, rtol=0.3)


def test_rwmh_rejects_keep_state():
    # With an enormous step size almost everything is rejected; state must
    # remain finite and the cached lp consistent.
    samples = _run("rwmh", 200, 100.0)
    assert np.all(np.isfinite(samples))


def test_slice_counts_evals():
    f = _target
    state = samplers.init_state(f, jnp.zeros(2))
    key = jax.random.key(1)
    new, info = jax.jit(
        lambda k, s: samplers.slice_step(f, k, s, jnp.asarray(1.0))
    )(key, state)
    assert int(info.n_evals) >= 3  # two edges + at least one shrink eval
    assert np.isfinite(float(new.lp))


def test_adapt_step_size_moves_toward_target():
    ls = jnp.log(0.1)
    ls_up = samplers.adapt_step_size(ls, jnp.asarray(1.0), 0.234, jnp.asarray(0))
    ls_dn = samplers.adapt_step_size(ls, jnp.asarray(0.0), 0.234, jnp.asarray(0))
    assert float(ls_up) > float(ls) > float(ls_dn)
