"""Chain-batched megakernels: batched-vs-vmap parity, bitwise.

``num_chains`` is a leading kernel-grid dimension: under ``jax.vmap`` over
the chain axis, the ``bright_glm`` and ``z_candidates`` wrappers dispatch
ONE ``pallas_call`` covering every chain (``custom_vmap`` rules in
``kernels/*/ops``), instead of jax's default per-chain pallas batching.
``repro.kernels.common.chain_batching(False)`` restores the default
lowering — the baseline every test here pins the megakernels against:

  * op level: vmapped ``bright_glm`` (all three GLM families, values and
    grads) and ``z_candidates`` are bitwise identical between the two
    dispatches AND to a per-chain python loop over the single-chain entry
    points;
  * chain level: a multi-chain fused trajectory (``backend="pallas"`` +
    ``z_backend="fused"``) through ``api.sample`` is bitwise identical
    batched vs vmap for all three families, including a mid-chunk
    capacity-doubling overflow re-run;
  * driver: the committed-chunk fold is keyed capacity-independently, so
    an overflow retry reuses the compiled fold instead of recompiling it.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import numerics
from repro.data import logistic_data, robust_data, softmax_data
from repro.kernels import common
from repro.kernels.bright_glm.ops import bright_glm
from repro.kernels.z_update.ops import z_candidates
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D, K = 400, 4, 3


# ---------------------------------------------------------------------------
# Op level: one megakernel launch ≡ per-chain dispatch, bitwise
# ---------------------------------------------------------------------------


def _family_operands(family):
    key = jax.random.key(0)
    x = jax.random.normal(key, (N, D))
    if family == "softmax":
        k_cls = 3
        t = jax.random.randint(jax.random.key(1), (N,), 0, k_cls)
        xi = 0.5 * jax.random.normal(jax.random.key(2), (N, k_cls))
        theta = 0.1 * jax.random.normal(jax.random.key(3), (K, k_cls, D))
    else:
        t = jnp.sign(jax.random.normal(jax.random.key(1), (N,)))
        xi = 1.5 * jnp.ones(N)
        theta = 0.1 * jax.random.normal(jax.random.key(3), (K, D))
    idx = jax.random.randint(jax.random.key(4), (K, 40), 0, N)
    nb = jnp.asarray([40, 17, 0], jnp.int32)
    return x, t, xi, idx, nb, theta


@pytest.mark.parametrize("family", ["logistic", "student_t", "softmax"])
def test_bright_glm_batched_matches_vmap_and_loop(family):
    x, t, xi, idx, nb, theta = _family_operands(family)
    f = lambda i, n, th: bright_glm(x, t, xi, i, n, th, family=family,
                                    interpret=True)
    with common.chain_batching(True):
        d_b, t_b = jax.vmap(f)(idx, nb, theta)
    with common.chain_batching(False):
        d_v, t_v = jax.vmap(f)(idx, nb, theta)
    np.testing.assert_array_equal(np.asarray(d_b), np.asarray(d_v))
    np.testing.assert_array_equal(np.asarray(t_b), np.asarray(t_v))
    for c in range(K):  # ... and to the single-chain entry point
        d_1, t_1 = f(idx[c], nb[c], theta[c])
        np.testing.assert_array_equal(np.asarray(d_b[c]), np.asarray(d_1))
        np.testing.assert_array_equal(np.asarray(t_b[c]), np.asarray(t_1))


def test_bright_glm_batched_grads_match():
    """MALA/HMC path: grads through the custom VJP under vmap are identical
    whichever dispatch the forward used (the backward is the shared jnp
    reference either way)."""
    x, t, xi, idx, nb, theta = _family_operands("logistic")
    f = lambda th, i, n: bright_glm(x, t, xi, i, n, th, family="logistic",
                                    interpret=True)[1]
    with common.chain_batching(True):
        g_b = jax.vmap(jax.grad(f))(theta, idx, nb)
    with common.chain_batching(False):
        g_v = jax.vmap(jax.grad(f))(theta, idx, nb)
    np.testing.assert_array_equal(np.asarray(g_b), np.asarray(g_v))


def test_z_candidates_batched_matches_vmap_and_loop():
    from repro.core import brightness

    arrs, nums, kws = [], [], []
    for c in range(K):
        z0 = jax.random.bernoulli(jax.random.key(c), 0.15 * (c + 1), (997,))
        st = brightness.from_z(z0)
        arrs.append(jnp.pad(st.arr, (0, 0)))
        nums.append(st.num)
        kws.append(numerics.key_words_of(jax.random.key(40 + c)))
    arrs, nums, kws = jnp.stack(arrs), jnp.stack(nums), jnp.stack(kws)
    f = lambda a, n, k: z_candidates(a, n, k, 0.05, 64, interpret=True)
    with common.chain_batching(True):
        c_b, n_b = jax.vmap(f)(arrs, nums, kws)
    with common.chain_batching(False):
        c_v, n_v = jax.vmap(f)(arrs, nums, kws)
    np.testing.assert_array_equal(np.asarray(c_b), np.asarray(c_v))
    np.testing.assert_array_equal(np.asarray(n_b), np.asarray(n_v))
    for c in range(K):
        c_1, n_1 = f(arrs[c], nums[c], kws[c])
        np.testing.assert_array_equal(np.asarray(c_b[c]), np.asarray(c_1))
        assert int(n_b[c]) == int(n_1)


# ---------------------------------------------------------------------------
# Chain level: fused multi-chain trajectories, batched ≡ vmap, bitwise
# ---------------------------------------------------------------------------


def _fused_model(family):
    if family == "softmax":
        sm = softmax_data(jax.random.key(2), n=300, d=8, k=3)
        return GLMModel.softmax(sm, n_classes=3)
    if family == "student_t":
        rd, _ = robust_data(jax.random.key(3), n=300, d=6)
        return GLMModel.robust(rd, nu=4.0, sigma=1.0, prior_scale=2.0)
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    return GLMModel.logistic(data, prior_scale=2.0, xi=1.5)


def _run_fused(model, batched, *, capacity=96, iters=40, chunk=20,
               q_db=0.05, kernel="rwmh"):
    with common.chain_batching(batched):
        alg = api.firefly(
            model, kernel=kernel, capacity=capacity, cand_capacity=capacity,
            q_db=q_db, step_size=0.08, backend="pallas", z_backend="fused",
        )
        return api.sample(alg, jax.random.key(11), iters, num_chains=K,
                          chunk_size=chunk)


@pytest.mark.parametrize("family", ["logistic", "student_t", "softmax"])
def test_fused_multichain_batched_matches_vmap(family):
    model = _fused_model(family)
    t_b = _run_fused(model, True)
    t_v = _run_fused(model, False)
    np.testing.assert_array_equal(np.asarray(t_b.theta), np.asarray(t_v.theta))
    np.testing.assert_array_equal(
        np.asarray(t_b.stats.n_bright), np.asarray(t_v.stats.n_bright)
    )
    np.testing.assert_array_equal(
        np.asarray(t_b.stats.lik_queries), np.asarray(t_v.stats.lik_queries)
    )
    # chains genuinely differ (independent keys), so the equality is not
    # comparing K copies of one chain
    assert not np.array_equal(np.asarray(t_b.theta[0]),
                              np.asarray(t_b.theta[1]))


def test_fused_multichain_overflow_rerun_batched_matches_vmap():
    """Mid-chunk capacity-doubling re-run through the megakernel path lands
    bitwise on the vmap path's trajectory (and both grew)."""
    model = _fused_model("logistic")
    t_b = _run_fused(model, True, capacity=24, iters=120, chunk=24, q_db=0.02)
    assert t_b.algorithm.spec.capacity > 24, "must exercise an overflow"
    t_v = _run_fused(model, False, capacity=24, iters=120, chunk=24, q_db=0.02)
    assert t_v.algorithm.spec.capacity == t_b.algorithm.spec.capacity
    np.testing.assert_array_equal(np.asarray(t_b.theta), np.asarray(t_v.theta))


def test_mala_multichain_batched_matches_vmap():
    """Gradient kernel end-to-end: the θ-update differentiates through the
    megakernel forward under vmap."""
    model = _fused_model("logistic")
    t_b = _run_fused(model, True, kernel="mala", iters=20, chunk=10)
    t_v = _run_fused(model, False, kernel="mala", iters=20, chunk=10)
    np.testing.assert_array_equal(np.asarray(t_b.theta), np.asarray(t_v.theta))


# ---------------------------------------------------------------------------
# Driver: overflow retries reuse the compiled committed-chunk fold
# ---------------------------------------------------------------------------


def test_overflow_rerun_reuses_fold_executable():
    from repro.api import driver as driver_lib

    model = _fused_model("logistic")
    driver_lib._JIT_CACHE.clear()
    trace = _run_fused(model, True, capacity=24, iters=120, chunk=24,
                       q_db=0.02)
    assert trace.algorithm.spec.capacity > 24  # the run really overflowed
    folds = [k for k in driver_lib._JIT_CACHE if k[0] == "fold"]
    scans = [k for k in driver_lib._JIT_CACHE if k[0] == "scan"]
    assert len(folds) == 1, folds  # one fold serves every capacity
    # the scan re-traced per grown capacity (shape change), keyed on it
    assert len({k[6] for k in scans}) == len(scans) and len(scans) >= 2, scans
