"""Pseudo-marginal special case (paper §5): joint (θ, z) MH with z~Bern(½).

Its θ-marginal must equal the full-data posterior, like FlyMC's.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pseudo_marginal as pm
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel, run_regular_mcmc

jax.config.update("jax_platform_name", "cpu")


def test_pseudo_marginal_matches_full_posterior():
    # Tiny N: with z' ~ Bernoulli(½)^N redrawn jointly, the likelihood
    # estimator variance grows with N and the chain becomes arbitrarily
    # sticky — the known pseudo-marginal pathology that FlyMC's incremental
    # z-updates avoid (paper §5). N=8 keeps acceptance workable so we can
    # check the chain targets the right marginal; the rigorous exactness
    # check is the enumeration test below.
    n, d = 8, 2
    data = logistic_data(jax.random.key(0), n=n, d=d, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)

    ref_samples, _ = run_regular_mcmc(
        model, jnp.zeros(d), jax.random.key(1), 20_000, step_size=0.6
    )
    ref = np.stack(ref_samples)[5000:]

    state = pm.init(
        model.bound, model.log_prior, model.data, model.stats,
        jnp.zeros(d), jax.random.key(2),
    )

    def body(s, _):
        s2, acc = pm.step(
            model.bound, model.log_prior, model.data, model.stats, s, 0.6
        )
        return s2, (s2.theta, acc)

    _, (thetas, acc) = jax.jit(
        lambda s: jax.lax.scan(body, s, None, length=200_000)
    )(state)
    ours = np.asarray(thetas)[50_000:]
    assert float(np.mean(np.asarray(acc))) > 0.005

    np.testing.assert_allclose(
        ours.mean(0), ref.mean(0), atol=5.0 * ref.std(0).max() / 10
    )
    np.testing.assert_allclose(ours.std(0), ref.std(0), rtol=0.6)


def test_joint_density_marginalizes_exactly():
    """Enumerate z for tiny N: logsumexp over z == full posterior + const."""
    import itertools

    n, d = 6, 2
    data = logistic_data(jax.random.key(3), n=n, d=d)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.0)

    for seed in range(3):
        theta = jax.random.normal(jax.random.key(10 + seed), (d,))
        lps = []
        for bits in itertools.product([False, True], repeat=n):
            z = jnp.asarray(bits)
            lps.append(
                float(
                    pm.joint_log_density(
                        model.bound, model.log_prior, model.data, model.stats,
                        theta, z,
                    )
                )
            )
        marginal = np.logaddexp.reduce(lps)
        full = float(model.full_log_posterior(theta))
        np.testing.assert_allclose(marginal, full, rtol=1e-4, atol=1e-3)
