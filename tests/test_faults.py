"""Fault tolerance: retry exactness, quarantine, kill points, chaos seeds.

The hardening contract (README "Fault tolerance", ISSUE PR 10), pinned:

  * a retried chunk is THE chunk — results after a transient chunk failure
    are bitwise identical to the fault-free run;
  * exhausted retries retire a group FAILED with clean committed prefixes;
  * the numerical-health sentinel quarantines exactly the poisoned lane;
    neighbors finish bitwise identical both to the fault-free run and to a
    run where the poisoned job was never admitted;
  * a crash at ANY checkpointer kill point leaves an intact checkpoint on
    disk (the new step or the previous one — never neither, never a torn
    one);
  * straggler escalation is opt-in, deduplicated, and event-typed;
  * total device loss (zero devices) suspends every job cleanly and the
    fleet resumes bitwise once capacity returns;
  * the seeded chaos schedule (repro.testing.chaos) holds all of the above
    under composed faults.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointCorruptError, Checkpointer
from repro.data.synthetic import logistic_data
from repro.launch import elastic
from repro.serve import (
    FaultEvent,
    Job,
    JobStatus,
    RetryPolicy,
    Service,
    TerminationPolicy,
)
from repro.serve import faults as faults_lib
from repro.testing import chaos

jax.config.update("jax_platform_name", "cpu")

CHUNK = 8
MAX = 32
N, D = 64, 3
WARM = 8
CAP = 16


def _job(i, seed=None, n=N):
    return Job(
        job_id=f"j{i}", family="logistic", seed=5 + i if seed is None else seed,
        data=logistic_data(jax.random.key(40 + i), n=n, d=D, separation=1.5),
        capacity=CAP, cand_capacity=CAP, num_warmup=WARM,
        policy=TerminationPolicy(max_samples=MAX),
    )


def _service(**kw):
    kw.setdefault("slot_budget", 8)
    kw.setdefault("chunk_size", CHUNK)
    return Service(**kw)


def _run_clean(jobs):
    svc = _service()
    for j in jobs:
        svc.submit(j)
    return svc.run()


def _tree_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(x)), np.asarray(jax.device_get(y))
        )


def _engine_of(svc, job_id):
    eng = svc.scheduler.engine_of(job_id)
    assert eng is not None
    return eng


# ---------------------------------------------------------------- taxonomy


def test_fault_event_rejects_unknown_kind():
    with pytest.raises(ValueError):
        FaultEvent(kind="gremlins", step=0)


def test_retry_policy_backoff_schedule():
    p = RetryPolicy(max_retries=3, backoff_s=0.1, multiplier=2.0)
    assert p.delay(1) == pytest.approx(0.1)
    assert p.delay(2) == pytest.approx(0.2)
    assert p.delay(3) == pytest.approx(0.4)
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)


def test_group_label_is_stable():
    svc = _service()
    svc.submit(_job(0))
    svc.step()
    (key,) = svc.scheduler.engines
    label = faults_lib.group_label(key)
    assert label.startswith("logistic-n") and "-K" in label


# ------------------------------------------------------- retry exactness


def test_transient_chunk_error_retries_bitwise():
    """One injected chunk failure + retry → results bitwise identical to
    the fault-free run, with chunk_error events on the update stream."""
    jobs = [_job(0), _job(1)]
    ref = _run_clean([_job(0), _job(1)])

    svc = _service(retry=RetryPolicy(max_retries=2, backoff_s=0.0))
    for j in jobs:
        svc.submit(j)
    svc.step()  # admit + first clean chunk
    eng = _engine_of(svc, "j0")
    real, left = eng.run_chunk, {"n": 1}

    def flaky(cs):
        if left["n"]:
            left["n"] -= 1
            raise RuntimeError("transient launch failure")
        return real(cs)

    eng.run_chunk = flaky
    res = svc.run()
    for j in ("j0", "j1"):
        assert res[j].reason == "max_samples"
        _tree_equal(res[j].results, ref[j].results)
    errs = [e for e in svc.faults if e.kind == "chunk_error"]
    assert len(errs) == 1 and errs[0].detail["retrying"] is True


def test_retry_exhaustion_fails_group_with_clean_prefix():
    """A persistent fault retires the whole group FAILED after max_retries,
    each member holding a bitwise clean prefix of its fault-free run."""
    jobs = [_job(0), _job(1)]
    ref = _run_clean([_job(0), _job(1)])

    svc = _service(retry=RetryPolicy(max_retries=1, backoff_s=0.0))
    for j in jobs:
        svc.submit(j)
    svc.step()
    eng = _engine_of(svc, "j0")

    def broken(cs):
        raise RuntimeError("persistent fault")

    eng.run_chunk = broken
    svc.step()
    assert not svc.active()
    kinds = [e.kind for e in svc.faults]
    assert kinds.count("chunk_error") == 2  # attempt + final
    assert kinds.count("group_failed") == 1
    for j in ("j0", "j1"):
        res = svc.result(j)
        assert svc.status(j) is JobStatus.FAILED
        assert res.reason == "failed" and 0 < res.committed < MAX
        got = np.asarray(jax.device_get(res.samples()))
        want = np.asarray(jax.device_get(
            ref[j].results["trace"]["theta"]
        ))[:, : res.committed]
        np.testing.assert_array_equal(got, want)


# ------------------------------------------------------------- quarantine


@pytest.mark.parametrize("what", ["theta", "data"])
def test_nan_poison_quarantines_only_the_sick_lane(what):
    """NaN in one job's θ-lane or dataset → that lane alone retires
    "quarantined" with a finite bitwise-clean prefix; its group neighbor
    finishes bitwise identical to the fault-free run AND to a run where
    the poisoned job was never admitted."""
    ref = _run_clean([_job(0), _job(1)])
    solo_ref = _run_clean([_job(1)])

    svc = _service()
    for j in (_job(0), _job(1)):
        svc.submit(j)
    svc.step()
    harness = chaos.ChaosHarness(svc, random.Random(0))
    assert harness.poison("j0", what=what)
    res = svc.run()

    assert svc.status("j0") is JobStatus.FAILED
    assert res["j0"].reason == "quarantined"
    ev = [e for e in svc.faults if e.kind == "nonfinite"]
    assert len(ev) == 1 and ev[0].job_id == "j0"
    got = np.asarray(jax.device_get(res["j0"].samples()))
    assert np.isfinite(got).all()
    want = np.asarray(jax.device_get(
        ref["j0"].results["trace"]["theta"]
    ))[:, : res["j0"].committed]
    np.testing.assert_array_equal(got, want)

    # The neighbor never noticed: bitwise vs fault-free, bitwise vs solo.
    assert res["j1"].reason == "max_samples"
    _tree_equal(res["j1"].results, ref["j1"].results)
    _tree_equal(res["j1"].results, solo_ref["j1"].results)


def test_quarantine_is_not_triggered_by_healthy_runs():
    svc = _service()
    svc.submit(_job(0))
    res = svc.run()
    assert res["j0"].reason == "max_samples"
    assert svc.faults == []


# -------------------------------------------------------------- stragglers


def test_straggler_monitor_flags_slow_host():
    mon = elastic.StragglerMonitor(threshold=2.0)
    mon.record("a", 1.0)
    assert mon.stragglers() == []  # <2 entries: no median to compare
    mon.record("b", 1.0)
    mon.record("c", 1.0)
    for _ in range(30):
        mon.record("c", 10.0)
    assert mon.stragglers() == ["c"]


def test_straggler_escalation_is_opt_in_and_deduplicated():
    """Three groups on a fake clock, one 10× slower: with a threshold the
    service emits ONE straggler event (deduped across steps); without,
    recording still happens but nothing escalates."""

    def build(threshold):
        svc = _service(slot_budget=16, straggler_threshold=threshold)
        fake = {"t": 0.0}
        svc._clock = lambda: fake["t"]
        svc.submit(_job(0))
        svc.submit(Job(
            job_id="k2", family="logistic", seed=9, num_chains=2,
            data=logistic_data(jax.random.key(77), n=N, d=D, separation=1.5),
            capacity=CAP, cand_capacity=CAP, num_warmup=WARM,
            policy=TerminationPolicy(max_samples=MAX),
        ))
        svc.submit(Job(
            job_id="s0", family="softmax", seed=8, n_classes=3,
            data=__import__("repro.data", fromlist=["softmax_data"])
            .softmax_data(jax.random.key(88), n=N, d=D, k=3),
            capacity=CAP, cand_capacity=CAP, num_warmup=WARM,
            policy=TerminationPolicy(max_samples=MAX),
        ))
        svc.step()  # admit all three groups
        slow = faults_lib.group_label(
            svc.scheduler.engine_of("s0").group_key
        )
        for key in svc.scheduler.engines:
            eng = svc.scheduler.engines[key]
            label = faults_lib.group_label(key)
            cost = 10.0 if label == slow else 1.0
            real = eng.run_chunk

            def timed(cs, real=real, cost=cost):
                out = real(cs)
                fake["t"] += cost
                return out

            eng.run_chunk = timed
        return svc, slow

    svc, slow = build(threshold=4.0)
    svc.run()
    ev = [e for e in svc.faults if e.kind == "straggler"]
    assert len(ev) == 1 and ev[0].group == slow  # deduplicated

    svc2, _ = build(threshold=None)
    svc2.run()
    assert [e for e in svc2.faults if e.kind == "straggler"] == []
    assert len(svc2.monitor.ewma) == 3  # recording is always on


# ------------------------------------------------------------- device loss


def test_device_loss_to_zero_suspends_all_then_resumes_bitwise(tmp_path):
    ref = _run_clean([_job(0), _job(1)])
    svc = _service(checkpointer=Checkpointer(tmp_path))
    for j in (_job(0), _job(1)):
        svc.submit(j)
    svc.step()

    suspended = svc.handle_device_loss(0)
    assert sorted(suspended) == ["j0", "j1"]
    assert not svc.scheduler.engines
    assert all(svc.status(j) is JobStatus.SUSPENDED for j in ("j0", "j1"))
    assert svc.active()  # suspended ≠ lost
    ev = [e for e in svc.faults if e.kind == "device_loss"]
    assert len(ev) == 1 and ev[0].detail["new_budget"] == 0
    # Stepping a zero-budget service is a clean no-op, not a crash.
    svc.step()

    svc.handle_device_loss(1)  # capacity returns
    res = svc.run()
    for j in ("j0", "j1"):
        assert res[j].reason == "max_samples"
        _tree_equal(res[j].results, ref[j].results)


def test_plan_chain_slots_zero_is_legal_negative_is_not():
    assert elastic.plan_chain_slots(0) == 0
    assert elastic.plan_chain_slots(2, slots_per_device=4) == 8
    with pytest.raises(ValueError):
        elastic.plan_chain_slots(-1)


# ----------------------------------------------------- checkpoint crashes


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (6, 4)),
            "b": jnp.arange(5, dtype=jnp.int32)}


def _arm(ck, point):
    def hook(p):
        if p == point:
            raise chaos.InjectedKill(p)
    ck._kill_hook = hook


@pytest.mark.parametrize("point", chaos._KILL_POINTS)
def test_kill_point_leaves_an_intact_checkpoint(tmp_path, point):
    """Crash the writer at every kill point between tmp-write and rename:
    after sweep recovery, restore always lands on an intact step — the new
    one if the rename committed, the previous one otherwise."""
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(1), blocking=True)
    _arm(ck, point)
    with pytest.raises(chaos.InjectedKill):
        ck.save(2, _tree(2), blocking=True)

    ck2 = Checkpointer(tmp_path)  # restarted process: sweep recovery
    step = ck2.latest_intact_step()
    assert step == (2 if point == "renamed" else 1)
    restored, man = ck2.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert man["step"] == step
    _tree_equal(restored, _tree(step))


def test_kill_while_parked_rolls_back_the_previous_step(tmp_path):
    """A same-step re-save parks the existing dir at ``.old``; dying right
    there must roll the previous intact copy back into place."""
    ck = Checkpointer(tmp_path)
    ck.save(3, _tree(1), blocking=True)
    _arm(ck, "parked")
    with pytest.raises(chaos.InjectedKill):
        ck.save(3, _tree(2), blocking=True)
    assert (ck.dir / "step_00000003.old").exists()

    ck2 = Checkpointer(tmp_path)
    assert not (ck2.dir / "step_00000003.old").exists()
    assert ck2.latest_intact_step() == 3
    restored, _ = ck2.restore(jax.tree.map(jnp.zeros_like, _tree()))
    _tree_equal(restored, _tree(1))  # the FIRST save's contents


def test_async_save_failure_surfaces_in_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    _arm(ck, "manifest_written")
    ck.save(1, _tree(), blocking=False)
    with pytest.raises(chaos.InjectedKill):
        ck.wait()
    ck._kill_hook = None
    ck.save(1, _tree(), blocking=True)  # the checkpointer is still usable
    assert ck.verify(1) == []


# ----------------------------------------------------------- chaos seeds


@pytest.mark.parametrize("seed", [2, 3])
def test_chaos_schedule_holds_the_exactness_contract(tmp_path, seed):
    """End-to-end seeded chaos (NaN poison + chunk errors for seed 2, a
    checkpoint kill + cold restart for seed 3): run_schedule raises on any
    contract violation, so a report IS the certificate."""
    report = chaos.run_schedule(
        seed, n=48, d=3, max_samples=24, num_warmup=6, chunk_size=8,
        directory=tmp_path / "ckpt", n_faults=3,
    )
    assert report.fired  # the schedule actually attacked the run
    assert len(report.survivors) + len(report.prefix_ok) + len(
        report.lost
    ) == 4
