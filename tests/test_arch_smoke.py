"""Per-architecture smoke tests (brief: reduced config, one forward/train
step on CPU, assert output shapes + no NaNs) + recurrence oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.distributed.par import Par
from repro.models import transformer as T
from repro.models import layers as L

jax.config.update("jax_platform_name", "cpu")

PAR = Par()


def _batch(cfg, b=2, s=64, key=0):
    k1, k2, k3 = jax.random.split(jax.random.key(key), 3)
    batch = {
        "tokens": jax.random.randint(k1, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(k2, (b, s), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = 0.1 * jax.random.normal(
            k3, (b, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        batch["patches"] = 0.1 * jax.random.normal(
            k3, (b, cfg.patch_positions, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params, specs = T.init_model(cfg, jax.random.key(0))
    batch = _batch(cfg)
    h, aux = T.forward_hidden(
        params, specs, cfg, PAR, batch, dtype=jnp.float32, remat=False
    )
    assert h.shape == (2, 64, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_descends(arch):
    cfg = get_reduced(arch)
    params, specs = T.init_model(cfg, jax.random.key(0))
    opt = T.init_opt(params)
    step, _ = T.make_train_step(
        cfg, {}, PAR, dtype=jnp.float32, remat=False, peak_lr=1e-3
    )
    step = jax.jit(step)
    batch = _batch(cfg)
    losses = []
    for _ in range(3):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(metrics["grad_norm"]))
    # same batch thrice: loss must drop
    assert losses[-1] < losses[0]


def test_moe_aux_metrics_present():
    cfg = get_reduced("mixtral-8x7b")
    params, specs = T.init_model(cfg, jax.random.key(0))
    loss, m = T.loss_fn(
        params, specs, cfg, PAR, _batch(cfg), dtype=jnp.float32, remat=False
    )
    assert "lb_loss" in m and "drop_frac" in m
    assert 0.0 <= float(m["drop_frac"]) < 1.0
    # balanced-ish router at init: lb_loss ≈ 1
    assert 0.5 < float(m["lb_loss"]) < 2.0


# ---------------------------------------------------------------------------
# Recurrence oracles: chunked implementations vs naive sequential scans
# ---------------------------------------------------------------------------


def _naive_wkv(r, k, v, logw, u):
    """Sequential WKV6: S_t = diag(w_t) S_{t-1} + k_t v_tᵀ;
    y_t = r_tᵀ(S_{t-1} + diag(u) k_t v_tᵀ)."""
    b, h, s, d = r.shape
    y = np.zeros((b, h, s, d), np.float64)
    S = np.zeros((b, h, d, d), np.float64)
    for t in range(s):
        kt, vt, rt = k[:, :, t], v[:, :, t], r[:, :, t]
        wt = np.exp(logw[:, :, t])
        y[:, :, t] = np.einsum("bhd,bhde->bhe", rt, S) + np.einsum(
            "bhd,hd,bhd,bhe->bhe", rt, u, kt, vt
        )
        S = wt[..., None] * S + np.einsum("bhd,bhe->bhde", kt, vt)
    return y, S


@pytest.mark.parametrize("s,chunk", [(32, 8), (48, 16), (64, 64)])
def test_wkv_chunked_matches_naive(s, chunk):
    rng = np.random.default_rng(0)
    b, h, d = 2, 3, 4
    r = rng.normal(size=(b, h, s, d)).astype(np.float32)
    k = rng.normal(size=(b, h, s, d)).astype(np.float32)
    v = rng.normal(size=(b, h, s, d)).astype(np.float32)
    logw = -rng.uniform(0.01, 0.9, size=(b, h, s, d)).astype(np.float32)
    u = rng.normal(size=(h, d)).astype(np.float32)

    y_ref, s_ref = _naive_wkv(r, k, v, logw, u)

    state = jnp.zeros((b, h, d, d), jnp.float32)
    n = s // chunk
    ys = []
    for i in range(n):
        sl = slice(i * chunk, (i + 1) * chunk)
        y, state = L._wkv_chunk(
            jnp.asarray(r[:, :, sl]), jnp.asarray(k[:, :, sl]),
            jnp.asarray(v[:, :, sl]), jnp.asarray(logw[:, :, sl]),
            jnp.asarray(u), state,
        )
        ys.append(np.asarray(y))
    y_ours = np.concatenate(ys, axis=2)
    np.testing.assert_allclose(y_ours, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), s_ref, rtol=2e-4, atol=2e-4)


def test_rglru_scan_matches_naive():
    rng = np.random.default_rng(1)
    b, s, c = 2, 37, 5
    log_a = -rng.uniform(0.001, 2.0, size=(b, s, c)).astype(np.float32)
    bx = rng.normal(size=(b, s, c)).astype(np.float32)

    h_ref = np.zeros((b, s, c), np.float64)
    hp = np.zeros((b, c), np.float64)
    for t in range(s):
        hp = np.exp(log_a[:, t]) * hp + bx[:, t]
        h_ref[:, t] = hp

    h = L._rglru_scan(jnp.asarray(log_a), jnp.asarray(bx))
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_chunked_attention_matches_dense():
    rng = np.random.default_rng(2)
    b, sq, sk, h, hk, d = 2, 16, 48, 8, 2, 16
    q = rng.normal(size=(b, sq, h, d)).astype(np.float32)
    k = rng.normal(size=(b, sk, hk, d)).astype(np.float32)
    v = rng.normal(size=(b, sk, hk, d)).astype(np.float32)
    q_pos = np.arange(32, 32 + sq, dtype=np.int32)
    k_pos = np.arange(sk, dtype=np.int32)

    for window in (None, 24):
        out = L.chunked_attention(
            jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
            jnp.asarray(q_pos), jnp.asarray(k_pos),
            causal=True, window=window, chunk=16,
        )
        # dense reference
        g = h // hk
        qg = q.reshape(b, sq, hk, g, d) / np.sqrt(d)
        s = np.einsum("bqhgd,bchd->bhgqc", qg, k)
        mask = k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = np.where(mask[None, None, None], s, -1e30)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        ref = np.einsum("bhgqc,bchd->bhgqd", p, v)
        ref = ref.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)
