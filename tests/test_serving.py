"""Serving correctness: prefill + decode must reproduce the training forward.

For every architecture: run prefill on a prompt, decode the next token, and
check the decode logits match the full forward over (prompt + token) at the
last position. This exercises ring caches, recurrent state carry-over,
cross-attention caches and vocab-parallel sampling on a single device.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.distributed.par import Par
from repro.models import serving as SV
from repro.models import transformer as T

jax.config.update("jax_platform_name", "cpu")

PAR = Par()
S_PROMPT = 32
SEQ_CAP = 64  # decode cache capacity


def _inputs(cfg, b=2, s=S_PROMPT, key=0):
    k1, k2 = jax.random.split(jax.random.key(key))
    tokens = jax.random.randint(k1, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": tokens[:, :s]}
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = 0.1 * jax.random.normal(
            k2, (b, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        extras["patches"] = 0.1 * jax.random.normal(
            k2, (b, cfg.patch_positions, cfg.d_model)
        )
    return tokens, {**batch, **extras}, extras


def _full_forward_logits(params, specs, cfg, tokens, extras):
    h, _ = T.forward_hidden(
        params, specs, cfg, PAR, {"tokens": tokens, **extras},
        dtype=jnp.float32, remat=False,
    )
    head = params["embed"]["head"].astype(jnp.float32)
    return (h[:, -1:] @ head).astype(jnp.float32)  # (B, 1, V)


def _no_drop(cfg):
    """Capacity-based MoE drops tokens differently for batched-prefill vs
    single-token decode (same model, different dispatch groups) — that is
    inherent to the algorithm, not a serving bug. For exact path comparison,
    raise capacity so nothing drops."""
    import dataclasses

    if cfg.moe is None:
        return cfg
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = _no_drop(get_reduced(arch))
    params, specs = T.init_model(cfg, jax.random.key(0))
    tokens, batch, extras = _inputs(cfg)

    cache, _ = SV.prefill(
        params, specs, batch, cfg, PAR, SEQ_CAP,
        dtype=jnp.float32, kv_dtype=jnp.float32,
    )
    assert int(cache["t"]) == S_PROMPT

    next_tok, logits, cache2 = SV.decode_step(
        params, specs, cache, tokens[:, S_PROMPT : S_PROMPT + 1],
        cfg, PAR, SEQ_CAP, dtype=jnp.float32,
    )
    ref = _full_forward_logits(
        params, specs, cfg, tokens[:, : S_PROMPT + 1], extras
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(ref), rtol=2e-3, atol=2e-3
    )
    assert int(cache2["t"]) == S_PROMPT + 1
    # greedy sample equals argmax of the reference logits
    np.testing.assert_array_equal(
        np.asarray(next_tok)[:, 0], np.asarray(jnp.argmax(ref[:, 0], -1))
    )


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "rwkv6-7b", "recurrentgemma-9b"])
def test_multistep_decode_stays_consistent(arch):
    """Decode 4 tokens autoregressively; each step must match the full
    forward — exercises ring wraparound bookkeeping and state updates."""
    cfg = _no_drop(get_reduced(arch))
    params, specs = T.init_model(cfg, jax.random.key(1))
    tokens, batch, extras = _inputs(cfg, key=1)

    cache, _ = SV.prefill(
        params, specs, batch, cfg, PAR, SEQ_CAP,
        dtype=jnp.float32, kv_dtype=jnp.float32,
    )
    step = jax.jit(
        lambda c, tok: SV.decode_step(
            params, specs, c, tok, cfg, PAR, SEQ_CAP, dtype=jnp.float32
        )
    )
    toks = tokens[:, S_PROMPT : S_PROMPT + 1]
    all_tokens = tokens[:, :S_PROMPT]
    for i in range(4):
        all_tokens = jnp.concatenate([all_tokens, toks], axis=1)
        next_tok, logits, cache = step(cache, toks)
        ref = _full_forward_logits(params, specs, cfg, all_tokens, extras)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref), rtol=5e-3, atol=5e-3,
            err_msg=f"step {i}",
        )
        toks = next_tok
