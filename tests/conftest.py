def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess integration tests"
    )
