"""Run the multi-device test modules in subprocesses with 8 fake devices.

The main pytest process must keep jax at 1 device (smoke tests and kernels
assume it, and the brief forbids a global XLA_FLAGS override), so the
distributed suites execute in child processes that set the flag before jax
initializes.
"""

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent


def _run_in_subprocess(test_file: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(ROOT / "src")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", str(ROOT / "tests" / test_file),
         "-q", "-x", "--no-header"],
        env=env,
        capture_output=True,
        text=True,
        timeout=1200,
        cwd=ROOT,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"{test_file} failed in 8-device subprocess:\n"
            f"{proc.stdout[-4000:]}\n{proc.stderr[-2000:]}"
        )


@pytest.mark.slow
def test_flymc_distributed_8dev():
    _run_in_subprocess("test_flymc_distributed.py")


@pytest.mark.slow
def test_distributed_training_8dev():
    _run_in_subprocess("test_distributed_training.py")
