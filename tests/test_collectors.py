"""Streaming observables (ISSUE 4 acceptance criteria).

  * collector-vs-offline equivalence: every streaming estimate matches the
    same quantity computed offline from the dense trace (bitwise for exact
    reductions — thinning, query counts; fp tolerance for Welford moments);
  * the default path (no ``collectors=``) reproduces the dense
    ``Trace.theta``/``Trace.stats`` via the FullTrace collector bitwise;
  * overflow-chunk-re-run invariance: every built-in collector's result is
    bitwise identical between a chain that grows capacity mid-run and one
    at ample capacity throughout;
  * memory: a collectors-only ``sample`` traces no O(num_samples) buffer
    (asserted on the chunk jaxpr) and returns ``theta=None``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis, api
from repro.api import driver as driver_lib
from repro.core import diagnostics
from repro.core.flymc import StepStats
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D = 400, 4


@pytest.fixture(scope="module")
def model():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    return GLMModel.logistic(data, prior_scale=2.0, xi=1.5)


@pytest.fixture(scope="module")
def alg(model):
    return api.firefly(
        model, kernel="rwmh", capacity=128, cand_capacity=128, q_db=0.1,
        step_size=0.1,
    )


def _all_builtins(model):
    return {
        "full": api.FullTrace(),
        "thin": api.ThinnedTrace(4),
        "moments": api.OnlineMoments(),
        "rhat": api.RHat(),
        "ess": api.BatchMeansESS(num_batches=8),
        "pp": api.PosteriorPredictive(x_eval=model.data.x[:7]),
        "queries": api.QueryBudget(),
    }


# ---------------------------------------------------------------------------
# Back-compat: the default path IS the FullTrace collector
# ---------------------------------------------------------------------------


def test_default_path_is_fulltrace_bitwise(alg):
    key = jax.random.key(1)
    default = api.sample(alg, key, 50, chunk_size=16)
    explicit = api.sample(
        alg, key, 50, chunk_size=16, collectors={"trace": api.FullTrace()}
    )
    assert explicit.theta is None and explicit.stats is None
    np.testing.assert_array_equal(
        np.asarray(default.theta),
        np.asarray(explicit.results["trace"]["theta"]),
    )
    for a, b in zip(default.stats, explicit.results["trace"]["stats"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_thinned_trace_matches_host_slice_bitwise(alg):
    key = jax.random.key(2)
    full = api.sample(alg, key, 43, chunk_size=17)  # 43: partial tail window
    thinned = api.sample(
        alg, key, 43, chunk_size=17, collectors={"t": api.ThinnedTrace(4)}
    )
    got = np.asarray(thinned.results["t"]["theta"])
    assert got.shape == (1, 43 // 4, D)
    np.testing.assert_array_equal(got[0], np.asarray(full.theta[0])[3::4])
    # degenerate: fewer samples than the thinning stride keeps nothing
    tiny = api.sample(alg, key, 3, collectors={"t": api.ThinnedTrace(4)})
    assert tiny.results["t"]["theta"].shape == (1, 0, D)


def test_thin_kwarg_with_collectors_raises(alg):
    with pytest.raises(ValueError, match="ThinnedTrace"):
        api.sample(
            alg, jax.random.key(0), 10, thin=2, collectors={"m": api.OnlineMoments()}
        )


# ---------------------------------------------------------------------------
# Collector-vs-offline equivalence
# ---------------------------------------------------------------------------


def test_online_moments_match_offline(alg):
    key = jax.random.key(3)
    mom = api.OnlineMoments()
    tr = api.sample(
        alg, key, 300, num_chains=2, chunk_size=64,
        collectors={"m": mom, "full": api.FullTrace()},
    )
    off = np.asarray(tr.results["full"]["theta"], np.float64)  # (2, T, D)
    res = tr.results["m"]
    assert res["mean"].shape == (2, D) and res["cov"].shape == (2, D, D)
    np.testing.assert_array_equal(res["count"], [300, 300])
    np.testing.assert_allclose(res["mean"], off.mean(1), rtol=0, atol=1e-4)
    for c in range(2):
        np.testing.assert_allclose(
            res["cov"][c], np.cov(off[c].T, ddof=1), rtol=1e-3, atol=1e-5
        )


def test_online_rhat_matches_split_r_hat(alg):
    key = jax.random.key(4)
    tr = api.sample(
        alg, key, 301, num_chains=4, chunk_size=50,  # odd: tail-drop path
        collectors={"r": api.RHat(), "full": api.FullTrace()},
    )
    off = np.asarray(tr.results["full"]["theta"], np.float64)
    res = tr.results["r"]
    expected = diagnostics.split_r_hat(off)
    per_coord = [
        diagnostics.split_r_hat(off[:, :, j]) for j in range(D)
    ]
    np.testing.assert_allclose(res["per_coordinate"], per_coord, rtol=1e-5)
    np.testing.assert_allclose(res["r_hat"], expected, rtol=1e-5)


def test_batch_means_ess_matches_offline_and_geyer(alg):
    key = jax.random.key(5)
    tr = api.sample(
        alg, key, 512, chunk_size=128,
        collectors={"e": api.BatchMeansESS(num_batches=16),
                    "full": api.FullTrace()},
    )
    off = np.asarray(tr.results["full"]["theta"][0], np.float64)
    res = tr.results["e"]
    expected = diagnostics.batch_means_ess(off, num_batches=16)
    # f32 on-device (sum, sum_sq) vs f64 two-pass variance: ~1e-5 relative
    np.testing.assert_allclose(res["ess"][0], expected, rtol=1e-3)
    # coarse-vs-Geyer cross-check: same order of magnitude on a real chain
    geyer = diagnostics.effective_sample_size(off)
    assert 0.1 < res["ess"][0] / geyer < 10.0, (res["ess"][0], geyer)


def test_batch_means_ess_stable_on_long_offcenter_chain():
    """A long chain with mean ≫ sd is exactly where a raw f32 (sum, sum_sq)
    variance cancels catastrophically; the running-mean/Welford carry must
    track the f64 offline estimate on 64k iterations at mean 50, sd 0.5."""
    col = api.BatchMeansESS(num_batches=16)
    n = 64_000
    xs = 50.0 + 0.5 * jax.random.normal(jax.random.key(0), (n, 1))
    carry = col.init(n, jax.ShapeDtypeStruct((1,), jnp.float32), None)
    carry, _ = jax.lax.scan(
        lambda c, x: (col.update(c, x, None), None), carry, xs
    )
    res = col.finalize(jax.tree.map(lambda l: l[None], carry))
    expected = diagnostics.batch_means_ess(np.asarray(xs, np.float64), 16)
    np.testing.assert_allclose(res["ess"][0], expected, rtol=0.1)


def test_posterior_predictive_matches_offline(model, alg):
    key = jax.random.key(6)
    x_eval = model.data.x[:9]
    tr = api.sample(
        alg, key, 200, chunk_size=64,
        collectors={"pp": api.PosteriorPredictive(x_eval=x_eval),
                    "full": api.FullTrace()},
    )
    off = np.asarray(tr.results["full"]["theta"][0])
    expected = np.mean(
        [jax.nn.sigmoid(np.asarray(x_eval) @ t) for t in off], axis=0
    )
    np.testing.assert_allclose(
        tr.results["pp"]["mean_prob"][0], expected, rtol=0, atol=1e-5
    )
    assert int(tr.results["pp"]["count"][0]) == 200


def test_query_budget_matches_host_sum_exactly(alg):
    key = jax.random.key(7)
    tr = api.sample(
        alg, key, 150, num_chains=3, chunk_size=64,
        collectors={"q": api.QueryBudget(), "full": api.FullTrace()},
    )
    stats = tr.results["full"]["stats"]
    offline = int(
        np.asarray(jax.device_get(stats.lik_queries), np.int64).sum()
    )
    assert tr.results["q"] == offline
    assert tr.total_queries == offline  # QueryBudget feeds Trace.total_queries


def test_query_budget_two_lane_uint32_does_not_wrap():
    """The on-device lo-lane wraps at 2³²; the hi-lane must carry it so the
    reassembled total is the exact int64 a host sum would produce."""
    qb = api.QueryBudget()
    carry = qb.init(0, None, None)
    big = np.int32(2**31 - 1)
    update = jax.jit(qb.update)
    steps = 5  # 5 × (2³¹-1) ≈ 1.07e10 > 2³²
    stats = StepStats(
        n_bright=jnp.int32(0), lik_queries=jnp.asarray(big),
        accept_prob=jnp.float32(0), overflow=jnp.bool_(False),
        joint_lp=jnp.float32(0),
    )
    for _ in range(steps):
        carry = update(carry, None, stats)
    total = qb.finalize(jax.tree.map(lambda l: l[None], carry))
    assert total == steps * int(big) > 2**32


# ---------------------------------------------------------------------------
# Overflow-chunk-re-run invariance of every built-in
# ---------------------------------------------------------------------------


def test_all_collectors_bitwise_invariant_to_capacity_overflow(model):
    """Collector carries are saved with the pre-chunk state, so a mid-run
    capacity-doubling re-run replays identical updates: each built-in's
    result must be bitwise the ample-capacity one."""
    key = jax.random.key(9)

    def run(cap):
        alg = api.firefly(
            model, kernel="rwmh", capacity=cap, cand_capacity=cap,
            q_db=0.02, step_size=0.1,
        )
        return api.sample(
            alg, key, 300, chunk_size=32, collectors=_all_builtins(model)
        )

    t_small = run(24)
    assert t_small.algorithm.spec.capacity > 24, (
        "test must exercise a mid-chain capacity overflow"
    )
    t_big = run(N)  # full capacity: can never overflow
    small, big = t_small.results, t_big.results
    assert small.keys() == big.keys()
    for name in small:
        leaves_s = jax.tree.leaves(small[name])
        leaves_b = jax.tree.leaves(big[name])
        assert len(leaves_s) == len(leaves_b), name
        for ls, lb in zip(leaves_s, leaves_b):
            np.testing.assert_array_equal(
                np.asarray(ls), np.asarray(lb), err_msg=f"collector {name}"
            )


def test_collectors_bitwise_invariant_to_chunk_size(model, alg):
    key = jax.random.key(10)
    colls = _all_builtins(model)
    t1 = api.sample(alg, key, 60, chunk_size=7, collectors=colls)
    t2 = api.sample(alg, key, 60, chunk_size=60, collectors=colls)
    for name in colls:
        for ls, lb in zip(
            jax.tree.leaves(t1.results[name]), jax.tree.leaves(t2.results[name])
        ):
            np.testing.assert_array_equal(
                np.asarray(ls), np.asarray(lb), err_msg=f"collector {name}"
            )


# ---------------------------------------------------------------------------
# Memory: collectors-only sampling materializes no O(num_samples) buffer
# ---------------------------------------------------------------------------


# The local _walk_eqns/_subjaxprs/_max_dim copies migrated to
# repro.analysis.walker — the same traversal the static-analysis CLI sweep
# runs over the registered driver entry points.
_max_dim = analysis.walker.max_dim


def test_collectors_only_chunk_traces_no_num_samples_buffer(model, alg):
    """Neither the jitted chain-scan chunk nor a collectors-only carry fold
    may contain any array with a dimension of size num_samples — the trace
    buffer is simply absent from the program, not merely discarded. A
    FullTrace fold (sanity) trips the same detector."""
    num_samples = 50_000  # ≫ N and every state/buffer dim
    cs = 64
    colls = {
        "m": api.OnlineMoments(), "r": api.RHat(), "q": api.QueryBudget(),
        "e": api.BatchMeansESS(),
    }
    state = jax.jit(alg.init)(jax.random.key(0), alg.default_position)
    pos_struct, stats_struct = alg.output_structs(state)

    # the chain scan emits chunk-local O(cs) outputs regardless of collectors
    scan = driver_lib._make_scan_fn(alg, False, cs)
    operands = (alg.data, alg.stats) if driver_lib._threads_data(alg) else ()
    scan_jaxpr = jax.make_jaxpr(scan)(
        state, jax.random.key(1), jnp.int32(0), *operands
    )
    assert _max_dim(scan_jaxpr.jaxpr) < num_samples

    # a collectors-only fold carries nothing O(num_samples) either
    pos = jnp.zeros((cs,) + pos_struct.shape, pos_struct.dtype)
    infos = jax.tree.map(
        lambda s: jnp.zeros((cs,) + s.shape, s.dtype), stats_struct
    )
    carries = {
        n: c.init(num_samples, pos_struct, stats_struct)
        for n, c in colls.items()
    }
    fold = driver_lib.make_collector_fold(colls, False)
    jaxpr = jax.make_jaxpr(fold)(carries, pos, infos)
    assert _max_dim(jaxpr.jaxpr) < num_samples

    full = {"full": api.FullTrace()}
    carries_f = {"full": full["full"].init(num_samples, pos_struct, stats_struct)}
    fold_f = driver_lib.make_collector_fold(full, False)
    jaxpr_f = jax.make_jaxpr(fold_f)(carries_f, pos, infos)
    assert _max_dim(jaxpr_f.jaxpr) >= num_samples  # the detector is real


def test_collectors_only_trace_fields_are_none(alg):
    tr = api.sample(
        alg, jax.random.key(11), 20, collectors={"m": api.OnlineMoments()}
    )
    assert tr.theta is None and tr.stats is None
    assert tr.total_queries is None  # no QueryBudget passed
    # final_state still resumable
    again = api.sample(
        alg, jax.random.key(12), 10, init_state=tr.final_state,
        collectors={"m": api.OnlineMoments()},
    )
    assert int(again.results["m"]["count"][0]) == 10


def test_empty_collectors_dict_collects_nothing(alg):
    tr = api.sample(alg, jax.random.key(13), 10, collectors={})
    assert tr.results == {}
    assert tr.theta is None and tr.total_queries is None


# ---------------------------------------------------------------------------
# Protocol validation & misc
# ---------------------------------------------------------------------------


def test_validate_collectors_rejects_bad_inputs(alg):
    with pytest.raises(TypeError, match="dict"):
        api.sample(alg, jax.random.key(0), 5, collectors=[api.RHat()])
    with pytest.raises(TypeError, match="strings"):
        api.sample(alg, jax.random.key(0), 5, collectors={1: api.RHat()})
    with pytest.raises(TypeError, match="protocol"):
        api.sample(alg, jax.random.key(0), 5, collectors={"x": object()})
    with pytest.raises(ValueError, match="x_eval"):
        api.PosteriorPredictive()
    with pytest.raises(ValueError, match="num_batches"):
        api.BatchMeansESS(num_batches=1)


def test_collectors_work_with_regular_mcmc(model):
    """The protocol is algorithm-agnostic: the full-data baseline streams
    through the same collectors (overflow always False, n_bright = N)."""
    alg = api.regular_mcmc(model, kernel="rwmh", step_size=0.1)
    tr = api.sample(
        alg, jax.random.key(14), 40, chunk_size=20,
        collectors={"m": api.OnlineMoments(cov=False), "q": api.QueryBudget()},
    )
    assert tr.results["q"] == 40 * N
    assert tr.results["m"]["mean"].shape == (1, D)
    assert "cov" not in tr.results["m"]


# ---------------------------------------------------------------------------
# Chunk-boundary peeks (the serve streaming contract)
# ---------------------------------------------------------------------------


def _eq_trees(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("num_chains", [1, 2])
def test_peek_then_continue_is_bitwise(model, alg, num_chains):
    """Peeking EVERY built-in collector at EVERY chunk boundary leaves the
    run bitwise identical to one that never peeked — peek finalizes a deep
    copy, so neither the carry values nor the donated-buffer aliasing are
    disturbed. This is what makes serve-side streaming free."""
    num_samples, cs = 48, 16
    ref = api.sample(
        alg, jax.random.key(3), num_samples, chunk_size=cs,
        num_chains=num_chains, collectors=_all_builtins(model),
    )
    peeked = {}

    def hook(ev):
        peeked[ev.committed] = {n: ev.peek(n) for n in _all_builtins(model)}
        return False

    tr = api.sample(
        alg, jax.random.key(3), num_samples, chunk_size=cs,
        num_chains=num_chains, collectors=_all_builtins(model),
        on_chunk=hook,
    )
    assert sorted(peeked) == [16, 32, 48]  # every boundary peeked
    for name in ref.results:
        _eq_trees(ref.results[name], tr.results[name])


def test_final_boundary_peek_matches_finalize(model, alg):
    """At the last boundary a peek IS the result: identical values for
    every collector (R̂'s mid-run monitor pools full-length splits there,
    so even its guarded path lands on the finalize value)."""
    num_samples, cs = 48, 16
    last = {}

    def hook(ev):
        if ev.committed == num_samples:
            last.update({n: ev.peek(n) for n in _all_builtins(model)})
        return False

    tr = api.sample(
        alg, jax.random.key(3), num_samples, chunk_size=cs,
        collectors=_all_builtins(model), on_chunk=hook,
    )
    for name, res in tr.results.items():
        got = last[name]
        if isinstance(res, dict) and isinstance(got, dict):
            common = set(res) & set(got)
            assert common  # peek may add keys (e.g. splits_used), not drop
            res = {k: res[k] for k in common if res[k] is not None}
            got = {k: got[k] for k in common if got[k] is not None}
        _eq_trees(res, got)


def test_peek_result_never_aliases_live_carry(model, alg):
    """Mutating a peeked FullTrace buffer in place must not leak into the
    run's final results — the peek contract is copy-on-read."""
    num_samples, cs = 32, 16
    grabbed = []

    def hook(ev):
        if ev.committed == cs:
            pk = ev.peek("full")
            pk["theta"].block_until_ready()
            # numpy view of the device buffer would be unsafe to write; the
            # contract is stronger: the peeked arrays are fresh buffers, so
            # even deleting them cannot perturb the carry.
            grabbed.append(jax.tree.map(np.asarray, pk))
        return False

    ref = api.sample(
        alg, jax.random.key(5), num_samples, chunk_size=cs,
        collectors={"full": api.FullTrace()},
    )
    tr = api.sample(
        alg, jax.random.key(5), num_samples, chunk_size=cs,
        collectors={"full": api.FullTrace()}, on_chunk=hook,
    )
    _eq_trees(ref.results["full"], tr.results["full"])
    # the peek saw exactly the first chunk's committed prefix
    np.testing.assert_array_equal(
        grabbed[0]["theta"][:, :cs],
        np.asarray(ref.results["full"]["theta"][:, :cs]),
    )
