"""Distributed FlyMC on a host-local 8-device mesh.

The sharded chain must (a) run, (b) target the same posterior as regular
full-data MCMC, (c) keep the paper's cost profile (queries ≪ N per iter
after MAP tuning).
"""

import os

# 8 fake CPU devices for this test module only (pytest-forked not needed:
# this file is the only one touching multi-device jax state... it must run
# in its own process — enforced via pytest-xdist isolation OR first-import).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import diagnostics
from repro.data import logistic_data
from repro.distributed.flymc_dist import run_dist_chain
from repro.models.bayes_glm import GLMModel, run_regular_mcmc

N, D = 512, 4


@pytest.fixture(scope="module")
def mesh():
    if jax.device_count() < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    return jax.make_mesh(
        (8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
    )


@pytest.fixture(scope="module")
def problem():
    data = logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)
    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    theta_map = model.map_estimate(jax.random.key(1), steps=400)
    tuned = model.map_tuned(theta_map)
    samples, _ = run_regular_mcmc(
        model, jnp.zeros(D), jax.random.key(2), 6000, step_size=0.1
    )
    ref = np.stack(samples)[1500:]
    return tuned, ref.mean(0), ref.std(0)


def test_distributed_matches_reference(mesh, problem):
    tuned, ref_mean, ref_std = problem
    thetas, trace, total_q = run_dist_chain(
        tuned.bound, tuned.log_prior, mesh, tuned.data,
        jnp.zeros(D), jax.random.key(3), 6000,
        kernel="rwmh", capacity=64, cand_capacity=64, q_db=0.05,
        adapt_target=0.234,
    )
    s = np.stack(thetas)[1500:]
    np.testing.assert_allclose(s.mean(0), ref_mean, atol=3.5 * ref_std.max() / 10)
    np.testing.assert_allclose(s.std(0), ref_std, rtol=0.5)
    # the paper's speed claim at pod scale: queries ≪ N per iteration
    brights = [t["n_bright"] for t in trace[1500:]]
    assert np.mean(brights) < 0.3 * N
    assert total_q / len(trace) < 0.6 * N


def test_distributed_pallas_backend_matches_jnp(mesh, problem):
    """backend="pallas" runs shard-local inside shard_map and yields the
    same realized chain as the jnp path (same keys, interpret off-TPU)."""
    from repro import api
    from repro.distributed.flymc_dist import dist_algorithm, shard_data

    tuned, _, _ = problem
    data = shard_data(tuned.data, mesh)
    outs = {}
    for backend in ("jnp", "pallas"):
        alg = dist_algorithm(
            tuned.bound, tuned.log_prior, mesh, data,
            capacity=64, cand_capacity=64, q_db=0.05, backend=backend,
        )
        trace = api.sample(alg, jax.random.key(7), 40, chunk_size=20)
        outs[backend] = np.asarray(trace.theta[0])
        assert np.all(np.isfinite(outs[backend]))
    np.testing.assert_allclose(
        outs["pallas"], outs["jnp"], rtol=1e-4, atol=1e-5
    )


def test_distributed_fused_z_engine_runs_shard_local(mesh, problem):
    """z_backend="fused" inside shard_map: the candidate kernel streams each
    shard's partition array locally (per-shard folded keys, no collectives)
    and composes with the fused θ-backend — the whole step's per-datum work
    runs through Pallas kernels, one shard at a time."""
    from repro import api
    from repro.distributed.flymc_dist import dist_algorithm, shard_data

    tuned, _, _ = problem
    data = shard_data(tuned.data, mesh)
    alg = dist_algorithm(
        tuned.bound, tuned.log_prior, mesh, data,
        capacity=64, cand_capacity=64, q_db=0.05,
        backend="pallas", z_backend="fused",
    )
    trace = api.sample(alg, jax.random.key(11), 40, chunk_size=20)
    theta = np.asarray(trace.theta[0])
    assert np.all(np.isfinite(theta))
    assert np.all(np.isfinite(np.asarray(trace.stats.joint_lp)))
    # z-moves really happen across shards
    nb = np.asarray(trace.stats.n_bright[0])
    assert nb.min() != nb.max()


def test_chain_fleet_matches_single_device_batched(problem):
    """chain_fleet: the chain axis sharded over 8 devices via shard_map is
    bitwise the single-device chain-batched run — chains are independent,
    so the fleet step needs zero collectives and placement cannot change
    the realized trajectories."""
    from repro import api
    from repro.distributed.flymc_dist import chain_fleet

    if jax.device_count() < 8:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count=8")
    chains_mesh = jax.make_mesh((8,), ("chains",))
    tuned, _, _ = problem
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=64, cand_capacity=64, q_db=0.05,
        step_size=0.1, backend="pallas", z_backend="fused",
    )
    fleet = chain_fleet(alg, chains_mesh)
    t_fleet = api.sample(fleet, jax.random.key(21), 30, num_chains=8,
                         chunk_size=15)
    t_local = api.sample(alg, jax.random.key(21), 30, num_chains=8,
                         chunk_size=15)
    np.testing.assert_array_equal(
        np.asarray(t_fleet.theta), np.asarray(t_local.theta)
    )
    np.testing.assert_array_equal(
        np.asarray(t_fleet.stats.n_bright), np.asarray(t_local.stats.n_bright)
    )


def test_collective_budgets_via_census_api(mesh, problem):
    """The communication claims the docstrings above lean on ("no
    collectives in the z-phase", "zero cross-chain collectives"), pinned
    through the static census API (repro.analysis.collectives) instead
    of ad-hoc jaxpr-string grepping: the data-sharded step spends its
    exact declared budget — one scalar psum per θ-proposal, nothing
    inside the z-update scan — and the chain fleet communicates not at
    all."""
    from repro import api
    from repro.analysis import registry
    from repro.analysis.collectives.census import census, census_counts
    from repro.analysis.collectives.extract import find_sharded_regions
    from repro.analysis.collectives.replication import check_replication
    from repro.distributed.flymc_dist import chain_fleet, make_dist_flymc

    tuned, _, _ = problem
    _, init_fn, step_fn, _ = make_dist_flymc(
        tuned.bound, tuned.log_prior, mesh, N,
        kernel="rwmh", capacity=64, cand_capacity=64, q_db=0.05,
    )
    stats = tuned.bound.suffstats(tuned.data)
    state, _ = jax.jit(init_fn)(
        tuned.data, stats, jnp.zeros(D), jax.random.key(5)
    )
    closed = jax.make_jaxpr(step_fn)(tuned.data, stats, state)
    regions = find_sharded_regions(closed)
    sites = [s for r in regions for s in census(r)]
    assert census_counts(sites) == registry.DIST_STEP_BUDGET
    assert not any(s.in_loop or s.unbounded for s in sites)
    for r in regions:  # every replicated output provably replicated
        assert check_replication(r) == [], r.origin

    alg = api.firefly(
        tuned, kernel="rwmh", capacity=64, cand_capacity=64, q_db=0.05,
        step_size=0.1,
    )
    fleet = chain_fleet(alg, jax.make_mesh((8,), ("chains",)))
    keys, states = registry._fleet_keys_states(fleet, 8)
    closed = jax.make_jaxpr(fleet.step_chains_data)(
        keys, states, fleet.data, fleet.stats
    )
    regions = find_sharded_regions(closed)
    assert regions
    assert [s for r in regions for s in census(r)] == []


def test_distributed_collectors_match_offline(mesh, problem):
    """Streaming collectors under shard_map: carries are replicated (θ and
    the psum'd StepStats come out of the sharded step replicated), so the
    streamed moments / R̂ / query totals must equal the offline values from
    the dense trace of the same sharded chain — including across a
    capacity-growth re-run (tiny per-shard capacity)."""
    from repro import api
    from repro.core import diagnostics
    from repro.distributed.flymc_dist import dist_algorithm, shard_data

    tuned, _, _ = problem
    data = shard_data(tuned.data, mesh)
    alg = dist_algorithm(
        tuned.bound, tuned.log_prior, mesh, data,
        capacity=8, cand_capacity=8, q_db=0.1,
    )
    trace = api.sample(
        alg, jax.random.key(21), 60, chunk_size=16,
        collectors={
            "moments": api.OnlineMoments(),
            "rhat": api.RHat(),
            "queries": api.QueryBudget(),
            "trace": api.FullTrace(),
        },
    )
    assert trace.algorithm.spec.capacity > 8  # growth really happened
    off = np.asarray(trace.results["trace"]["theta"], np.float64)
    st = trace.results["trace"]["stats"]
    np.testing.assert_allclose(
        trace.results["moments"]["mean"], off.mean(1), atol=1e-4
    )
    np.testing.assert_allclose(
        trace.results["rhat"]["r_hat"], diagnostics.split_r_hat(off),
        rtol=1e-4,
    )
    assert trace.results["queries"] == int(
        np.asarray(jax.device_get(st.lik_queries), np.int64).sum()
    )
    assert trace.total_queries == trace.results["queries"]


def test_distributed_counts_and_overflow(mesh, problem):
    tuned, _, _ = problem
    # tiny per-shard capacity forces global growth; chain must still run
    thetas, trace, total_q = run_dist_chain(
        tuned.bound, tuned.log_prior, mesh, tuned.data,
        jnp.zeros(D), jax.random.key(4), 50,
        kernel="rwmh", capacity=8, cand_capacity=8, q_db=0.2,
    )
    assert len(thetas) == 50
    assert total_q == sum(t["lik_queries"] for t in trace)
    assert all(np.isfinite(t) for th in thetas for t in np.ravel(th))
