"""End-to-end behaviour tests: the three user-facing paths all work.

1. FlyMC posterior sampling beats full-data MCMC on likelihood queries while
   matching the posterior (the paper's claim, end to end).
2. LM training driver: loss descends with checkpoint/resume.
3. LM serving driver: prefill + autoregressive decode produce tokens.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel, run_regular_mcmc

jax.config.update("jax_platform_name", "cpu")


def test_flymc_end_to_end_beats_regular_on_queries():
    n, d = 2000, 11
    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)

    ref, queries = run_regular_mcmc(
        model, jnp.zeros(d), jax.random.key(1), 1500, step_size=0.05
    )
    ref = np.stack(ref)[400:]
    q_reg = np.mean(queries[400:])

    theta_map = model.map_estimate(jax.random.key(2), steps=300)
    tuned = model.map_tuned(theta_map)
    spec = tuned.flymc_spec(
        kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.01,
        adapt_target=0.234,
    )
    state, _, spec = tuned.init_chain(
        spec, jnp.zeros(d), jax.random.key(3), step_size=0.05
    )
    samples, trace, total_q, _ = tuned.run_chain(spec, state, 1500)
    fly = np.stack(samples)[400:]

    # same posterior...
    np.testing.assert_allclose(
        fly.mean(0), ref.mean(0), atol=4 * ref.std(0).max() / 10
    )
    # ...at a fraction of the likelihood queries (paper's claim)
    assert total_q / 1500 < 0.25 * q_reg


def test_lm_training_driver(tmp_path):
    from repro.launch.train import train_reduced

    _, history = train_reduced(
        "llama3.2-3b", steps=40, batch=4, seq=65,
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100, peak_lr=3e-3,
        warmup_steps=5,
    )
    assert np.isfinite(history).all()
    # fresh random batch per step: compare averaged ends of the trajectory
    assert np.mean(history[-8:]) < np.mean(history[:8])
    # resume picks up from the checkpoint
    _, history2 = train_reduced(
        "llama3.2-3b", steps=45, batch=4, seq=65,
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100, peak_lr=3e-3,
        warmup_steps=5,
    )
    assert len(history2) == 5  # 40 → 45


def test_lm_serving_driver():
    from repro.launch.serve import serve_reduced

    gen, stats = serve_reduced("llama3.2-3b", batch=2, prompt_len=16, gen=6)
    assert gen.shape == (2, 6)
    assert stats["decode_s"] > 0
