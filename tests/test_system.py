"""End-to-end behaviour tests: the three user-facing paths all work.

1. FlyMC posterior sampling beats full-data MCMC on likelihood queries while
   matching the posterior (the paper's claim, end to end).
2. LM training driver: loss descends with checkpoint/resume.
3. LM serving driver: prefill + autoregressive decode produce tokens.
"""

import jax
import numpy as np

from repro import api
from repro.core import diagnostics
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")


def test_flymc_end_to_end_beats_regular_on_queries():
    n, d, iters, burn = 2000, 11, 4000, 1000
    data = logistic_data(jax.random.key(0), n=n, d=d, separation=2.0)
    model = GLMModel.logistic(data, prior_scale=1.0, xi=1.5)

    baseline = api.regular_mcmc(
        model, kernel="rwmh", step_size=0.05, adapt_target="auto"
    )
    ref_tr = api.sample(baseline, jax.random.key(1), iters)
    ref = np.asarray(ref_tr.theta[0])[burn:]
    q_reg = np.asarray(ref_tr.stats.lik_queries[0])[burn:].mean()

    theta_map = model.map_estimate(jax.random.key(2), steps=300)
    tuned = model.map_tuned(theta_map)
    alg = api.firefly(
        tuned, kernel="rwmh", capacity=256, cand_capacity=256, q_db=0.01,
        step_size=0.05, adapt_target="auto",
    )
    trace = api.sample(alg, jax.random.key(3), iters)
    fly = np.asarray(trace.theta[0])[burn:]
    total_q = int(trace.total_queries)

    # same posterior — tolerance calibrated to the chains' own Monte-Carlo
    # error (3 joint standard errors from the measured ESS; a fixed fraction
    # of the posterior std is mis-calibrated at any finite chain length)
    se = ref.std(0).max() * (
        1.0 / np.sqrt(diagnostics.effective_sample_size(ref))
        + 1.0 / np.sqrt(diagnostics.effective_sample_size(fly))
    )
    np.testing.assert_allclose(fly.mean(0), ref.mean(0), atol=3 * float(se))
    # ...at a fraction of the likelihood queries (paper's claim)
    assert total_q / iters < 0.25 * q_reg


def test_lm_training_driver(tmp_path):
    from repro.launch.train import train_reduced

    _, history = train_reduced(
        "llama3.2-3b", steps=40, batch=4, seq=65,
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100, peak_lr=3e-3,
        warmup_steps=5,
    )
    assert np.isfinite(history).all()
    # fresh random batch per step: compare averaged ends of the trajectory
    assert np.mean(history[-8:]) < np.mean(history[:8])
    # resume picks up from the checkpoint
    _, history2 = train_reduced(
        "llama3.2-3b", steps=45, batch=4, seq=65,
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=100, peak_lr=3e-3,
        warmup_steps=5,
    )
    assert len(history2) == 5  # 40 → 45


def test_lm_serving_driver():
    from repro.launch.serve import serve_reduced

    gen, stats = serve_reduced("llama3.2-3b", batch=2, prompt_len=16, gen=6)
    assert gen.shape == (2, 6)
    assert stats["decode_s"] > 0
