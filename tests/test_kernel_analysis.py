"""The kernel-level static verifier (repro.analysis.kernels).

Same both-sides discipline as ``tests/test_analysis.py``: every analysis
is exercised on a known-BAD fixture it must catch AND the known-good twin
it must pass — a verifier whose detectors go quiet is worse than none.
The fixtures encode the failure classes the kernel analyses exist for:

  kernel-bounds    an unclamped scalar-prefetch index driving a ref read
                   (what ``kernels.common.clamp_index`` exists to prevent)
  kernel-padding   an unmasked reduction over ``pad_to`` sentinel lanes
  kernel-race      a revisited-block accumulator under ``parallel``
                   dimension semantics, and an undeclared accumulator
                   (the sequential-grid contract in ``kernels/common.py``)
  kernel-bytes     an expected-total drift between the BlockSpec-derived
                   traffic model and the pinned number

Plus the expected-pass pins for the repo's real kernels: every
``kernel.*`` registry entry stays green, the race classifications match
the declared accumulator contracts, and the derived bytes model
reproduces the hand-written ``_bytes_model`` formulas it replaced in
``benchmarks/bright_glm.py`` and ``benchmarks/z_update.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import analysis
from repro.analysis import registry
from repro.analysis.kernels import (
    BytesModelRule,
    GridRaceRule,
    derive,
    derive_traffic,
    find_kernel_calls,
    kernel_rules,
)
from repro.analysis.kernels.intervals import check_bounds
from repro.analysis.kernels.race import classify_outputs
from repro.analysis.kernels.taint import check_taint
from repro.kernels import common

jax.config.update("jax_platform_name", "cpu")


def _first_call(fn, *args):
    (call, *rest) = find_kernel_calls(jax.make_jaxpr(fn)(*args))
    assert not rest
    return call


# ---------------------------------------------------------------------------
# kernel-bounds: interval abstract interpretation of ref indices
# ---------------------------------------------------------------------------


def _gather_fn(clamp: bool):
    """One row gathered by a scalar-prefetch index into an (8, 128) block.

    The bad twin indexes with the raw prefetched scalar — nothing bounds
    it below the 8-row block — exactly the bug class
    ``kernels.common.clamp_index`` guards the real kernels against.
    """

    def kernel(s_ref, x_ref, o_ref):
        i = s_ref[0]
        if clamp:
            i = jnp.clip(i, 0, 7)
        o_ref[0, :] = x_ref[i, :]

    def fn(s, x):
        return pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(1,),
                in_specs=[pl.BlockSpec((8, 128), lambda g, s: (0, 0))],
                out_specs=pl.BlockSpec((1, 128), lambda g, s: (0, 0)),
            ),
            out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
            interpret=True,
        )(s, x)

    return fn


def _gather_args():
    return jnp.zeros((4,), jnp.int32), jnp.zeros((8, 128), jnp.float32)


def test_bounds_catches_unclamped_prefetch_index():
    call = _first_call(_gather_fn(clamp=False), *_gather_args())
    findings = check_bounds(call)
    assert findings, "unclamped dynamic index must be flagged"
    assert any(f.ref == "x_ref" and f.dim == 8 for f in findings)


def test_bounds_passes_clamped_index():
    call = _first_call(_gather_fn(clamp=True), *_gather_args())
    assert check_bounds(call) == []


def test_bounds_rule_through_engine():
    report = analysis.check(
        _gather_fn(False), *_gather_args(),
        rules=kernel_rules(), name="fixture.bounds",
    )
    assert report.rule_status("kernel-bounds") == "fail"


# ---------------------------------------------------------------------------
# kernel-padding: sentinel taint through unmasked reductions
# ---------------------------------------------------------------------------


def _pad_reduce_fn(masked: bool):
    """Sum over a lane axis padded 100 → 128 with sentinel 7.0."""

    def kernel(v_ref, o_ref):
        v = v_ref[...]
        if masked:
            lane = jax.lax.broadcasted_iota(jnp.int32, v.shape, 1)
            v = jnp.where(lane < 100, v, 0.0)
        o_ref[0, 0] = jnp.sum(v)

    def fn(vals):
        padded = jnp.pad(vals, ((0, 0), (0, 28)), constant_values=7.0)
        return pl.pallas_call(
            kernel,
            out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
            interpret=True,
        )(padded)

    return fn


def test_taint_catches_unmasked_padded_reduction():
    call = _first_call(_pad_reduce_fn(masked=False),
                       jnp.zeros((8, 100), jnp.float32))
    findings = check_taint(call)
    assert findings and any(1 in f.axes for f in findings)


def test_taint_passes_iota_masked_reduction():
    call = _first_call(_pad_reduce_fn(masked=True),
                       jnp.zeros((8, 100), jnp.float32))
    assert check_taint(call) == []


# ---------------------------------------------------------------------------
# kernel-race: revisited output blocks vs grid semantics
# ---------------------------------------------------------------------------


def _accum_fn(parallel: bool):
    """Classic revisited-block accumulator over a 4-step grid."""

    def kernel(x_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += x_ref[...]

    params = {}
    if parallel:
        params["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)
        )

    def fn(x):
        return pl.pallas_call(
            kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8, 128), lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            interpret=True,
            **params,
        )(x)

    return fn


_ACCUM_X = jnp.zeros((32, 128), jnp.float32)


def test_race_classifies_revisited_output():
    call = _first_call(_accum_fn(parallel=False), _ACCUM_X)
    (cls,) = classify_outputs(call)
    assert cls.dep_axes == () and cls.revisited == (0,)


def test_race_flags_undeclared_accumulator():
    report = analysis.check(
        _accum_fn(False), _ACCUM_X,
        rules=kernel_rules(), name="fixture.race",
    )
    assert report.rule_status("kernel-race") == "fail"
    assert any(f.details.get("kind") == "undeclared-accumulator"
               for f in report.findings)


def test_race_passes_declared_accumulator():
    report = analysis.check(
        _accum_fn(False), _ACCUM_X,
        rules=kernel_rules(accumulators={0: (0,)}), name="fixture.race",
    )
    assert report.rule_status("kernel-race") == "pass"


def test_race_flags_parallel_accumulator_even_when_declared():
    """Declaring an accumulator never excuses parallel semantics — the
    write-write race is real regardless of intent (see the
    sequential-grid-accumulator contract in ``kernels/common.py``)."""
    report = analysis.check(
        _accum_fn(True), _ACCUM_X,
        rules=kernel_rules(accumulators={0: (0,)}), name="fixture.race",
    )
    assert report.rule_status("kernel-race") == "fail"
    assert any(f.details.get("kind") == "parallel-race"
               for f in report.findings)


# ---------------------------------------------------------------------------
# kernel-bytes: BlockSpec-derived traffic model
# ---------------------------------------------------------------------------


def test_bytes_model_accumulator_fixture():
    call = _first_call(_accum_fn(False), _ACCUM_X)
    model = derive(call)
    # input: 4 distinct (8,128) f32 blocks; output: ONE revisited block.
    assert model["per_operand"]["x_ref"]["bytes"] == 4 * 8 * 128 * 4
    assert model["per_operand"]["outputs"]["bytes"] == 8 * 128 * 4
    assert model["total"] == 5 * 8 * 128 * 4


def test_bytes_rule_catches_expected_total_drift():
    report = analysis.check(
        _accum_fn(False), _ACCUM_X,
        rules=kernel_rules(accumulators={0: (0,)},
                           expected_bytes={"kernel": 123}),
        name="fixture.bytes",
    )
    assert report.rule_status("kernel-bytes") == "fail"


def test_bytes_rule_records_metrics():
    report = analysis.check(
        _accum_fn(False), _ACCUM_X,
        rules=kernel_rules(accumulators={0: (0,)},
                           expected_bytes={"kernel": 5 * 8 * 128 * 4}),
        name="fixture.bytes",
    )
    assert report.ok
    assert report.metrics["kernel_bytes"]["kernel"]["total"] == 5 * 8 * 128 * 4


# ---------------------------------------------------------------------------
# derived model == the retired hand-written benchmark models
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,c", [(5000, 21, 1024), (2000, 21, 512)])
def test_bright_derived_bytes_reproduce_hand_model(n, d, c):
    """PR 8 deleted the hand pallas term from benchmarks/bright_glm.py;
    the derived model must reproduce it exactly at the benchmark shapes:
    C·D·4 row DMAs + lane-padded θ + 3 C-vectors + the scalar total."""
    from benchmarks.bright_glm import _bytes_model

    dp = common.pad_to(d, 128)
    model = _bytes_model(n, d, c)
    assert model["pallas"] == c * d * 4 + dp * 4 + 3 * c * 4 + 4


@pytest.mark.parametrize("n,c", [(4096, 1024), (2048, 512)])
def test_z_derived_bytes_reproduce_hand_model_when_tiled(n, c):
    """benchmarks/z_update.py's retired hand terms, at exactly-tiled N
    (the hand model ignored tile padding; the derived model charges the
    real padded stream, so they agree only when pad_to is a no-op):
    arr streams once (4N), the candidate writeback + count is 4·Cp + 4."""
    from benchmarks.z_update import _bytes_model

    assert common.pad_to(max(n, 1024), 1024) == n  # tiled: models comparable
    terms = _bytes_model(n, c, 0.01)["fused"]["terms"]
    assert terms["kernel_arr_ref"] == 4 * n
    candp = common.pad_to(max(c, 8), 8)
    assert (terms["kernel_outputs[0]"] + terms["kernel_outputs[1]"]
            == 4 * candp + 4)
    # the retired 10·4·C O(C) term = derived cand writeback + retained glue
    assert 4 * candp + terms["bright_buffers_O(C)"] == 10 * 4 * c


def test_z_derived_bytes_charge_real_padding():
    """At the benchmark's untiled N=5000 the kernel streams the padded
    (5120,) array — the derived model says so; the hand model lied by
    120 rows. This is the point of deriving from BlockSpecs."""
    from benchmarks.z_update import _bytes_model

    terms = _bytes_model(5000, 1024, 0.01)["fused"]["terms"]
    assert terms["kernel_arr_ref"] == 4 * common.pad_to(5000, 1024)


# ---------------------------------------------------------------------------
# expected-pass pins: the repo's real kernels stay green
# ---------------------------------------------------------------------------

_KERNEL_ENTRIES = [n for n in registry.REGISTRY if n.startswith("kernel.")]


def test_every_pallas_entry_point_is_registered():
    assert len(_KERNEL_ENTRIES) == 10


@pytest.mark.parametrize("name", _KERNEL_ENTRIES)
def test_kernel_entry_point_passes(name):
    report = registry.REGISTRY[name]()
    assert report.ok, [str(f) for f in report.unexpected_failures]
    for rule in ("kernel-bounds", "kernel-race",
                 "kernel-padding", "kernel-bytes"):
        assert rule in report.rules_run


def test_bright_race_classification_pin():
    """bright-GLM: δ follows the row axis; the total accumulates over it
    (output 1 revisits grid axis 1 — the declared accumulator)."""
    call = _first_call(registry._bright_fn("logistic"),
                       *registry._bright_args("logistic"))
    classes = classify_outputs(call)
    by_io = {c.io_index: c for c in classes}
    assert by_io[1].revisited == (1,)
    assert not by_io[0].revisited


def test_z_race_classification_pin():
    """z-update: candidate buffer AND count both accumulate across the
    row-block sweep (grid axis 1)."""
    call = _first_call(registry._z_fn(), registry._s((4096,), jnp.int32),
                       registry._s((), jnp.int32),
                       registry._s((2,), jnp.int32))
    classes = classify_outputs(call)
    assert {c.io_index: c.revisited for c in classes} == {0: (1,), 1: (1,)}


def test_chain_megakernel_bytes_scale_linearly():
    """The chain-batched dispatch must cost exactly K× one chain — the
    shared operands are re-streamed per chain step, nothing is K²."""
    one = registry.REGISTRY["kernel.bright_glm.logistic"]()
    k = registry.REGISTRY["kernel.bright_glm.chains"]()
    assert (k.metrics["kernel_bytes"]["kernel"]["total"]
            == 4 * one.metrics["kernel_bytes"]["kernel"]["total"])


def test_derive_traffic_names_every_pallas_call():
    models = derive_traffic(registry._bright_fn("logistic"),
                            *registry._bright_args("logistic"))
    assert list(models) == ["kernel"]


# ---------------------------------------------------------------------------
# sweep integration: coverage + xpass discipline
# ---------------------------------------------------------------------------


def test_sweep_stays_green_and_covers_kernels():
    summary = registry.run_registry()
    assert summary.ok, summary.format_table()
    names = [r.entry_point for r in summary.reports]
    assert len(names) >= 17
    assert all(n in names for n in _KERNEL_ENTRIES)
    # the jnp z-engine's O(N) xfail must still be observed, not quiet
    step_jnp = next(r for r in summary.reports if r.entry_point == "step.jnp")
    assert step_jnp.rule_status("cost-model") == "xfail"


def test_kernel_xpass_fails_report():
    """An expected-fail kernel rule that passes is a blind detector."""
    report = analysis.check(
        _gather_fn(True), *_gather_args(),
        rules=kernel_rules(), name="fixture.xpass",
        expect_fail={"kernel-bounds"},
    )
    assert not report.ok
    assert report.rule_status("kernel-bounds") == "xpass"
