"""The static analyzer itself (repro.analysis).

Every rule is tested from both sides: a known-GOOD program it must pass
and a known-BAD fixture it must catch — a linter whose detectors can go
quiet without anyone noticing is worse than no linter (which is also why
the registry's expected-fail entries fail the sweep on xpass). The
known-bad fixtures encode the repo's actual historical bug classes:

  cost-model            the jnp z-engine's (N,) uniforms + full-N cumsum
  closure-constant      a dataset captured by a jitted step's closure (PR 6)
  rng-lineage           a replayed fold_in counter in a scan (PR 3), key
                        reuse across jax.random's pjit-wrapped draws
  capacity-independence a fold whose jaxpr bakes in the buffer capacity
                        (what the PR 5 retrace-avoidance pin forbids)
  donation              a donated carry whose shape/dtype drifted, turning
                        the in-place fold update into a silent copy
"""

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import registry, rules, walker
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")

N, D = 512, 4


@pytest.fixture(scope="module")
def data():
    return logistic_data(jax.random.key(0), n=N, d=D, separation=1.5)


def _alg(data, z_backend, capacity=64):
    from repro import api

    model = GLMModel.logistic(data, prior_scale=2.0, xi=1.5)
    return api.firefly(
        model, kernel="rwmh", capacity=capacity, cand_capacity=capacity,
        q_db=0.01, step_size=0.1, z_backend=z_backend,
    )


def _key_struct():
    return jax.eval_shape(lambda: jax.random.key(0))


def _step_report(data, z_backend, rule):
    alg = _alg(data, z_backend)
    state = jax.eval_shape(alg.init, _key_struct(), alg.default_position)
    return analysis.check(
        alg.step_data, _key_struct(), state, alg.data, alg.stats,
        rules=[rule], name=f"step.{z_backend}",
    )


# ---------------------------------------------------------------------------
# walker
# ---------------------------------------------------------------------------


def test_walker_descends_into_scan_and_pjit():
    def f(x):
        def body(c, v):
            return c + jnp.cumsum(v).sum(), None
        return jax.lax.scan(jax.jit(body), 0.0, x)[0]

    closed = jax.make_jaxpr(f)(jnp.ones((8, 16)))
    prims = set(walker.primitive_counts(closed))
    assert "cumsum" in prims and "scan" in prims
    assert walker.max_eqn_size(closed, ("cumsum",)) == 16
    assert walker.max_dim(closed) == 16
    assert walker.count_eqns(closed) > 2


def test_walker_descends_into_pallas_kernels(data):
    """pallas_call carries its kernel as a raw Jaxpr param; the in-kernel
    eqns must be visible to the same sweep as the surrounding program."""
    alg = _alg(data, "fused")
    state = jax.eval_shape(alg.init, _key_struct(), alg.default_position)
    closed = jax.make_jaxpr(alg.step)(_key_struct(), state)
    counts = walker.primitive_counts(closed)
    assert counts.get("pallas_call", 0) >= 1
    # eqns strictly increase when the walk crosses the pallas boundary
    outer_only = sum(1 for _ in closed.jaxpr.eqns)
    assert walker.count_eqns(closed) > outer_only


def test_walker_scatter_sized_by_updates():
    """Scatter outputs alias the full operand — work is the updates."""
    def f(arr, idx, upd):
        return arr.at[idx].set(upd)

    closed = jax.make_jaxpr(f)(
        jnp.zeros(1000), jnp.arange(10), jnp.ones(10)
    )
    assert walker.max_eqn_size(closed, ("scatter",)) == 10


def test_walker_finds_nested_consts():
    big = jnp.arange(4096, dtype=jnp.float32)

    def f(x):
        return (x * big).sum()

    consts = walker.const_bytes(jax.make_jaxpr(f)(jnp.ones(4096)))
    assert any(nbytes == 4096 * 4 for _, _, _, nbytes in consts)


# ---------------------------------------------------------------------------
# cost-model
# ---------------------------------------------------------------------------


def test_cost_model_passes_fused_step(data):
    report = _step_report(data, "fused", rules.CostModelRule(n=N))
    assert report.ok, [str(f) for f in report.findings]
    assert report.metrics["max_rng_size"] < N
    assert report.metrics["max_cumsum_size"] < N


def test_cost_model_catches_jnp_step(data):
    """Known-bad: the jnp z-engine draws (N,) uniforms and re-partitions
    with a full-N cumsum — the exact O(N) work class the rule forbids."""
    report = _step_report(data, "jnp", rules.CostModelRule(n=N))
    classes = {f.details["cls"] for f in report.findings}
    assert "rng" in classes and "cumsum" in classes
    assert not report.ok


def test_cost_model_expected_fail_is_first_class(data):
    """expect_fail makes the known-bad case OK — and a quiet detector NOT
    ok (xpass = the linter went blind, itself a regression)."""
    alg = _alg(data, "jnp")
    state = jax.eval_shape(alg.init, _key_struct(), alg.default_position)
    report = analysis.check(
        alg.step_data, _key_struct(), state, alg.data, alg.stats,
        rules=[rules.CostModelRule(n=N)], name="step.jnp",
        expect_fail=("cost-model",),
    )
    assert report.ok and report.rule_status("cost-model") == "xfail"
    blind = analysis.check(
        alg.step_data, _key_struct(), state, alg.data, alg.stats,
        rules=[rules.CostModelRule(n=10 * N)], name="step.jnp",
        expect_fail=("cost-model",),
    )
    assert not blind.ok and blind.rule_status("cost-model") == "xpass"


def test_cost_model_per_class_budgets():
    def f(x):
        return jnp.cumsum(x)

    tight = analysis.check(
        f, jnp.ones(128), rules=[rules.CostModelRule(n=1 << 20,
                                                     budgets={"cumsum": 64})],
        name="budget",
    )
    assert {fd.details["cls"] for fd in tight.findings} == {"cumsum"}


# ---------------------------------------------------------------------------
# closure-constant
# ---------------------------------------------------------------------------


def test_closure_constant_catches_captured_dataset(data):
    """Known-bad: the PR 6 bug class — a step that closes over the dataset
    bakes it into the jaxpr as a const, changing XLA reduction rounding."""
    x = jnp.tile(data.x, (2, 1))  # (2N, D) f32: 2·N·D·4 bytes, over threshold

    def captured_step(theta):
        return jnp.dot(x, theta).sum()

    report = analysis.check(
        captured_step, jnp.zeros(D), rules=[rules.ClosureConstRule()],
        name="bad.closure",
    )
    assert report.findings and all(
        f.rule == "closure-constant" for f in report.findings
    )
    assert any(f.details["nbytes"] == 2 * N * D * 4 for f in report.findings)


def test_closure_constant_passes_operand_form(data):
    def operand_step(x, theta):
        return jnp.dot(x, theta).sum()

    report = analysis.check(
        operand_step, data.x, jnp.zeros(D), rules=[rules.ClosureConstRule()],
        name="good.operand",
    )
    assert report.ok
    assert report.metrics["const_bytes_max"] <= 8192


def test_closure_constant_threshold_spares_small_captures():
    small = jnp.arange(16, dtype=jnp.float32)

    def f(x):
        return (x * small).sum()

    assert analysis.check(
        f, jnp.ones(16), rules=[rules.ClosureConstRule()], name="small"
    ).ok


# ---------------------------------------------------------------------------
# rng-lineage
# ---------------------------------------------------------------------------

_LINEAGE = rules.RngLineageRule


def test_rng_lineage_catches_key_reuse():
    """Two draws from one key replay the stream — caught even though
    jax.random wraps each draw in its own pjit sub-jaxpr."""
    def reuse(key):
        return jax.random.uniform(key) + jax.random.normal(key)

    report = analysis.check(
        reuse, _key_struct(), rules=[_LINEAGE()], name="bad.reuse"
    )
    assert any("reused" in f.message for f in report.findings)


def test_rng_lineage_catches_replayed_fold_in_counter():
    """Known-bad: the PR 3 resume-prefix bug class — a scan body keying on
    a constant fold_in counter draws the SAME randomness every iteration."""
    def loop(key, xs):
        def body(c, v):
            u = jax.random.uniform(jax.random.fold_in(key, 3))
            return c + u * v, None

        return jax.lax.scan(body, 0.0, xs)[0]

    report = analysis.check(
        loop, _key_struct(), jnp.ones(4), rules=[_LINEAGE()], name="bad.loop"
    )
    assert any("does not vary" in f.message for f in report.findings)


def test_rng_lineage_passes_iteration_folded_loop():
    """The driver's own discipline — fold_in(key, iteration) — is clean,
    and domain-separation folds of a varying key don't false-positive."""
    def loop(key, xs):
        def body(c, i):
            k = jax.random.fold_in(key, i)
            u = jax.random.uniform(jax.random.fold_in(k, 1))
            v = jax.random.uniform(jax.random.fold_in(k, 2))
            return c + u + v, None

        return jax.lax.scan(body, 0.0, xs)[0]

    report = analysis.check(
        loop, _key_struct(), jnp.arange(4), rules=[_LINEAGE()], name="good"
    )
    assert report.ok, [str(f) for f in report.findings]


def test_rng_lineage_split_then_draw_is_clean():
    def f(key):
        k1, k2 = jax.random.split(key)
        return jax.random.uniform(k1) + jax.random.normal(k2)

    assert analysis.check(
        f, _key_struct(), rules=[_LINEAGE()], name="good.split"
    ).ok


def test_rng_lineage_cond_branches_are_exclusive():
    """One draw per branch from the same key executes at most once — the
    rule must not report it as reuse."""
    def f(key, p):
        return jax.lax.cond(
            p > 0, jax.random.uniform, jax.random.normal, key
        )

    assert analysis.check(
        f, _key_struct(), jnp.float32(0.5), rules=[_LINEAGE()], name="cond"
    ).ok


def test_rng_lineage_passes_real_steps(data):
    for zb in ("jnp", "fused"):
        report = _step_report(data, zb, _LINEAGE())
        assert report.ok, (zb, [str(f) for f in report.findings])


# ---------------------------------------------------------------------------
# capacity-independence
# ---------------------------------------------------------------------------


def test_capacity_independence_catches_capacity_keyed_fold():
    """Known-bad: a fold whose program depends on the buffer capacity —
    exactly what would silently break the PR 5 'overflow re-runs never
    retrace the fold' guarantee."""
    def fold_at(cap):
        def fold(carry, x):
            return carry + jnp.pad(x, (0, cap - x.shape[0])).sum()

        return lambda: jax.make_jaxpr(fold)(jnp.float32(0), jnp.ones(16))

    rule = rules.CapacityIndependenceRule(
        {"capacity-64": fold_at(64), "capacity-128": fold_at(128)}
    )
    report = analysis.check(
        lambda c, x: c + x.sum(), jnp.float32(0), jnp.ones(16),
        rules=[rule], name="bad.cap",
    )
    assert [f.rule for f in report.findings] == ["capacity-independence"]


def test_capacity_independence_passes_driver_fold(data):
    """The real committed-chunk fold is capacity-independent: identical
    jaxprs from algorithms built at different capacities."""
    from repro.api import collectors as collectors_lib
    from repro.api import driver

    colls = {"m": collectors_lib.OnlineMoments()}
    fold = driver.make_collector_fold(colls, multi=False)

    def variant(capacity):
        alg = _alg(data, "fused", capacity=capacity)
        state = jax.eval_shape(alg.init, _key_struct(), alg.default_position)
        pos_s, stats_s = alg.output_structs(state)
        carries = {"m": colls["m"].init(32, pos_s, stats_s)}
        chunked = lambda s: jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((8,) + l.shape, l.dtype), s
        )
        return lambda: jax.make_jaxpr(fold)(
            carries, chunked(pos_s), chunked(stats_s)
        )

    rule = rules.CapacityIndependenceRule(
        {"capacity-32": variant(32), "capacity-64": variant(64)}
    )
    args_thunk = variant(32)
    # run the rule directly on the variants (check() needs fn+args; reuse
    # the 32-capacity trace as the context program)
    ctx = rules.Context(name="driver.fold", closed=args_thunk())
    assert rule.check(ctx) == []


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


def test_donation_catches_shape_drift():
    """Known-bad: the donated carry has no alias-compatible output, so the
    'in-place' update silently became a copy."""
    def fold(carry, x):
        return jnp.concatenate([carry, x])  # (8,) -> (16,): no alias

    report = analysis.check(
        fold, jnp.zeros(8), jnp.ones(8),
        rules=[rules.DonationRule(donate_argnums=(0,))], name="bad.donate",
    )
    assert any(f.rule == "donation" for f in report.findings)


def test_donation_catches_dtype_drift():
    def fold(carry, x):
        return (carry + x.sum()).astype(jnp.int32)

    report = analysis.check(
        fold, jnp.zeros(128, jnp.float32), jnp.ones(4),
        rules=[rules.DonationRule(donate_argnums=(0,))], name="bad.dtype",
    )
    assert any(f.rule == "donation" for f in report.findings)


def test_donation_passes_real_collector_fold(data):
    from repro.api import collectors as collectors_lib
    from repro.api import driver

    colls = {"trace": collectors_lib.FullTrace(),
             "m": collectors_lib.OnlineMoments()}
    fold = driver.make_collector_fold(colls, multi=False)
    alg = _alg(data, "fused")
    state = jax.eval_shape(alg.init, _key_struct(), alg.default_position)
    pos_s, stats_s = alg.output_structs(state)
    carries = {n: c.init(32, pos_s, stats_s) for n, c in colls.items()}
    chunked = lambda s: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((8,) + l.shape, l.dtype), s
    )
    report = analysis.check(
        fold, carries, chunked(pos_s), chunked(stats_s),
        rules=[rules.DonationRule(donate_argnums=(0,))], name="driver.fold",
    )
    assert report.ok, [str(f) for f in report.findings]


# ---------------------------------------------------------------------------
# report / registry / CLI surfaces
# ---------------------------------------------------------------------------


def test_report_rule_status_vocabulary():
    rep = analysis.Report(
        entry_point="e", findings=[analysis.Finding("a", "e", "boom")],
        rules_run=["a", "b", "c"], expect_fail=frozenset({"a", "c"}),
    )
    assert rep.rule_status("a") == "xfail"
    assert rep.rule_status("b") == "pass"
    assert rep.rule_status("c") == "xpass"
    assert not rep.ok  # c was expected to fail and didn't


def test_registry_sweep_is_green_and_covers_the_hot_paths():
    """The acceptance sweep: >= 6 entry points, all OK, the jnp engine
    registered as expected-fail for cost-model."""
    summary = registry.run_registry()
    assert len(summary.reports) >= 6
    assert summary.ok, summary.format_table()
    by_name = {r.entry_point: r for r in summary.reports}
    assert by_name["step.jnp"].rule_status("cost-model") == "xfail"
    for expected in ("step.fused", "driver.chunk", "driver.fold",
                     "serve.run_chunk", "dist.step", "dist.chain_fleet",
                     "dist.chain_fleet.closure", "dist.collector_fold",
                     "serve.fleet_probe"):
        assert expected in by_name
    # the collective twins are first-class expected-fails in the sweep
    for twin, rule in (("dist.step.zphase_psum", "collective-budget"),
                       ("dist.step.wire_drift", "comm-bytes"),
                       ("dist.fleet.rep_leak", "replication-consistency")):
        assert by_name[twin].rule_status(rule) == "xfail"
    record = summary.to_record()
    assert record["ok"] and "step.fused" in record["entry_points"]
    assert "max_rng_size" in record["entry_points"]["step.fused"]


def test_cli_main_exit_codes(capsys):
    from repro.analysis.__main__ import main

    assert main(["--list"]) == 0
    assert "step.fused" in capsys.readouterr().out
    assert main(["step.fused"]) == 0
    out = capsys.readouterr().out
    assert "static-analysis: OK" in out


def test_summary_table_marks_failures():
    bad = analysis.Report(
        entry_point="e", findings=[analysis.Finding("a", "e", "boom")],
        rules_run=["a"],
    )
    table = analysis.Summary(reports=[bad]).format_table()
    assert "FAIL" in table and "boom" in table
    assert not analysis.Summary(reports=[bad]).ok
