"""Checkpointing: atomicity, round-trip, chain-state resume, GC."""

import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.core import flymc
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_round_trip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(7, tree, extra_metadata={"note": "x"}, blocking=True)
    restored, manifest = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored,
    )


def test_async_save_and_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(1))
    ck.wait()
    assert ck.latest_step() == 1


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_partial_write_is_invisible(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(), blocking=True)
    # simulate a crash mid-write of step 6
    tmp = Path(tmp_path) / "step_00000006.tmp"
    tmp.mkdir()
    (tmp / "leaf_0000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    restored, m = ck.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert m["step"] == 5


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"a": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.zeros((5,))})


def test_flymc_chain_resume_is_exact(tmp_path):
    """Checkpoint/restart must resume the exact Markov chain (bit-equal θ
    trajectory vs an uninterrupted run)."""
    data = logistic_data(jax.random.key(0), n=200, d=3)
    model = GLMModel.logistic(data, prior_scale=2.0)
    spec = model.flymc_spec(kernel="rwmh", capacity=128, cand_capacity=128,
                            q_db=0.1)
    state, _, spec = model.init_chain(
        spec, jnp.zeros(3), jax.random.key(1), step_size=0.1
    )

    # uninterrupted: 30 steps
    s_ref = state
    ref = []
    for _ in range(30):
        s_ref, _ = flymc.flymc_step(spec, model.data, model.stats, s_ref)
        ref.append(np.asarray(s_ref.sampler.theta))

    # interrupted at 15 + checkpoint + restore + 15 more
    s = state
    for _ in range(15):
        s, _ = flymc.flymc_step(spec, model.data, model.stats, s)
    ck = Checkpointer(tmp_path)
    ck.save(15, s._asdict(), blocking=True)
    restored, _ = ck.restore(jax.tree.map(jnp.zeros_like, s._asdict()))
    s2 = flymc.FlyMCState(**restored)
    out = []
    for _ in range(15):
        s2, _ = flymc.flymc_step(spec, model.data, model.stats, s2)
        out.append(np.asarray(s2.sampler.theta))
    np.testing.assert_array_equal(np.stack(ref[15:]), np.stack(out))
