"""Checkpointing: atomicity, round-trip, chain-state resume, GC, and the
integrity properties — any single corrupted byte is detected, restore never
silently loads damaged state."""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.checkpoint import CheckpointCorruptError, Checkpointer
from repro.core import flymc
from repro.data import logistic_data
from repro.models.bayes_glm import GLMModel

jax.config.update("jax_platform_name", "cpu")


def _tree(key=0):
    k = jax.random.key(key)
    return {
        "a": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.float32(3.5)},
    }


def test_round_trip(tmp_path):
    ck = Checkpointer(tmp_path)
    tree = _tree()
    ck.save(7, tree, extra_metadata={"note": "x"}, blocking=True)
    restored, manifest = ck.restore(jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        tree, restored,
    )


def test_async_save_and_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(1))
    ck.wait()
    assert ck.latest_step() == 1


def test_gc_keeps_last_k(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=True)
    assert ck.all_steps() == [3, 4]


def test_keep_last_alias_and_zero_disables_gc(tmp_path):
    ck = Checkpointer(Path(tmp_path) / "a", keep_last=1)
    for s in (1, 2, 3):
        ck.save(s, _tree(s), blocking=True)
    assert ck.all_steps() == [3]
    ck0 = Checkpointer(Path(tmp_path) / "b", keep_last=0)
    for s in (1, 2, 3):
        ck0.save(s, _tree(s), blocking=True)
    assert ck0.all_steps() == [1, 2, 3]


def test_startup_sweeps_stale_tmp_dirs(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(3, _tree(), blocking=True)
    stale = Path(tmp_path) / "step_00000009.tmp"
    stale.mkdir()
    (stale / "leaf_0000.npy").write_bytes(b"garbage")
    ck2 = Checkpointer(tmp_path)  # a restarted process
    assert not stale.exists()
    assert ck2.all_steps() == [3]


def test_partial_write_is_invisible(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree(), blocking=True)
    # simulate a crash mid-write of step 6
    tmp = Path(tmp_path) / "step_00000006.tmp"
    tmp.mkdir()
    (tmp / "leaf_0000.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    restored, m = ck.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert m["step"] == 5


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"a": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.zeros((5,))})


def test_flymc_chain_resume_is_exact(tmp_path):
    """Checkpoint/restart must resume the exact Markov chain (bit-equal θ
    trajectory vs an uninterrupted run)."""
    data = logistic_data(jax.random.key(0), n=200, d=3)
    model = GLMModel.logistic(data, prior_scale=2.0)
    spec = model.flymc_spec(kernel="rwmh", capacity=128, cand_capacity=128,
                            q_db=0.1)
    state, _, spec = model.init_chain(
        spec, jnp.zeros(3), jax.random.key(1), step_size=0.1
    )

    # uninterrupted: 30 steps
    s_ref = state
    ref = []
    for _ in range(30):
        s_ref, _ = flymc.flymc_step(spec, model.data, model.stats, s_ref)
        ref.append(np.asarray(s_ref.sampler.theta))

    # interrupted at 15 + checkpoint + restore + 15 more
    s = state
    for _ in range(15):
        s, _ = flymc.flymc_step(spec, model.data, model.stats, s)
    ck = Checkpointer(tmp_path)
    ck.save(15, s._asdict(), blocking=True)
    restored, _ = ck.restore(jax.tree.map(jnp.zeros_like, s._asdict()))
    s2 = flymc.FlyMCState(**restored)
    out = []
    for _ in range(15):
        s2, _ = flymc.flymc_step(spec, model.data, model.stats, s2)
        out.append(np.asarray(s2.sampler.theta))
    np.testing.assert_array_equal(np.stack(ref[15:]), np.stack(out))


def _tiny_firefly():
    """Deliberately undersized buffers: the init grow loop takes capacity
    8 → 32 before the first sample, so every checkpoint of this chain holds
    an overflow-grown state — larger than anything a fresh build has."""
    from repro import api

    data = logistic_data(jax.random.key(0), n=150, d=3)
    model = GLMModel.logistic(data, prior_scale=2.0)
    return api.firefly(model, kernel="rwmh", capacity=8, cand_capacity=8,
                       q_db=0.1, resample_fraction=0.5, num_warmup=5)


@pytest.mark.parametrize("num_chains", [1, 2])
def test_driver_checkpoint_roundtrip_is_bitwise(tmp_path, num_chains):
    """Checkpointer round trip at the api.sample level: run half, save the
    final_state, restore it into a FRESHLY BUILT algorithm (capacity 8 —
    the saved buffers are overflow-grown to 32, so the driver must
    normalize the algorithm up to the state's capacity), resume with
    ``init_state``. θ of (half + resumed half) is bitwise the
    uninterrupted run's."""
    from repro import api

    key = jax.random.key(1)
    k_steps = jax.random.split(key)[1]  # resume passes the chain key
    full = api.sample(_tiny_firefly(), key, 40, chunk_size=10,
                      num_chains=num_chains)
    half = api.sample(_tiny_firefly(), key, 20, chunk_size=10,
                      num_chains=num_chains)
    assert half.final_state.sampler.aux.shape[-1] > 8  # overflow-grown

    ck = Checkpointer(tmp_path)
    ck.save(20, half.final_state._asdict(), blocking=True)
    restored, _ = ck.restore(
        jax.tree.map(jnp.zeros_like, half.final_state._asdict())
    )
    resumed = api.sample(_tiny_firefly(), k_steps, 20, chunk_size=10,
                         num_chains=num_chains,
                         init_state=flymc.FlyMCState(**restored))
    np.testing.assert_array_equal(
        np.asarray(full.theta[:, :20]), np.asarray(half.theta)
    )
    np.testing.assert_array_equal(
        np.asarray(full.theta[:, 20:]), np.asarray(resumed.theta)
    )


# -------------------------------------------------------------- integrity


def test_manifest_records_file_byte_crcs(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _tree(), blocking=True)
    man = ck.manifest(1)
    assert all(isinstance(m["crc32"], int) for m in man["leaves"])
    assert ck.verify(1) == []


def _two_step_dir():
    """A fresh directory with two intact checkpoints (steps 1 and 2) —
    property examples mutate the newest, so each needs its own copy."""
    d = tempfile.mkdtemp(prefix="ckpt_prop_")
    ck = Checkpointer(d)
    ck.save(1, _tree(1), blocking=True)
    ck.save(2, _tree(2), blocking=True)
    return d


def _assert_refuses_and_falls_back(d):
    """The integrity contract after damaging step 2: verify reports it,
    explicit restore raises, and a step=None restore falls back to the
    intact step 1 — never silently loading the damaged bytes."""
    ck = Checkpointer(d)
    assert ck.verify(2) != []
    assert ck.latest_intact_step() == 1
    assert ck.last_skipped == [2]
    with pytest.raises(CheckpointCorruptError):
        ck.restore(jax.tree.map(jnp.zeros_like, _tree()), step=2)
    restored, man = ck.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert man["step"] == 1
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        _tree(1), restored,
    )


@settings(max_examples=25, deadline=None)
@given(leaf_frac=st.floats(0.0, 1.0), pos_frac=st.floats(0.0, 1.0),
       bit=st.integers(0, 7))
def test_any_single_bit_flip_is_refused(leaf_frac, pos_frac, bit):
    """Flip ANY single bit of ANY leaf file — npy magic, header padding,
    or array data — and restore must refuse the step and fall back."""
    d = _two_step_dir()
    cdir = Path(d) / "step_00000002"
    leaves = sorted(cdir.glob("leaf_*.npy"))
    target = leaves[min(int(leaf_frac * len(leaves)), len(leaves) - 1)]
    raw = bytearray(target.read_bytes())
    pos = min(int(pos_frac * len(raw)), len(raw) - 1)
    raw[pos] ^= 1 << bit
    target.write_bytes(bytes(raw))
    _assert_refuses_and_falls_back(d)


@settings(max_examples=10, deadline=None)
@given(frac=st.floats(0.0, 0.99))
def test_truncated_manifest_is_refused(frac):
    d = _two_step_dir()
    mpath = Path(d) / "step_00000002" / "manifest.json"
    raw = mpath.read_bytes()
    mpath.write_bytes(raw[: int(frac * len(raw))])
    _assert_refuses_and_falls_back(d)


@settings(max_examples=10, deadline=None)
@given(leaf_frac=st.floats(0.0, 1.0), keep_frac=st.floats(0.0, 0.99))
def test_truncated_leaf_is_refused(leaf_frac, keep_frac):
    d = _two_step_dir()
    cdir = Path(d) / "step_00000002"
    leaves = sorted(cdir.glob("leaf_*.npy"))
    target = leaves[min(int(leaf_frac * len(leaves)), len(leaves) - 1)]
    raw = target.read_bytes()
    target.write_bytes(raw[: int(keep_frac * len(raw))])
    _assert_refuses_and_falls_back(d)


def test_missing_leaf_is_refused(tmp_path):
    d = _two_step_dir()
    next(iter(sorted((Path(d) / "step_00000002").glob("leaf_*.npy")))).unlink()
    _assert_refuses_and_falls_back(d)


def test_all_steps_corrupt_refuses_loudly():
    d = _two_step_dir()
    for s in (1, 2):
        (Path(d) / f"step_{s:08d}" / "manifest.json").write_bytes(b"{tor")
    ck = Checkpointer(d)
    assert ck.latest_intact_step() is None
    with pytest.raises(CheckpointCorruptError):
        ck.restore(jax.tree.map(jnp.zeros_like, _tree()))


def test_verify_off_still_checks_shapes(tmp_path):
    """verify=False skips integrity (CRC) checks but the structural shape
    validation of restore still applies."""
    ck = Checkpointer(tmp_path)
    ck.save(1, {"a": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"a": jnp.zeros((5,))}, verify=False)
