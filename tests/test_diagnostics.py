"""ESS estimator validation against analytic AR(1) autocorrelation time."""

import numpy as np

from repro.core import diagnostics


def _ar1(phi, n, seed=0):
    r = np.random.default_rng(seed)
    x = np.zeros(n)
    eps = r.normal(size=n) * np.sqrt(1 - phi**2)
    for i in range(1, n):
        x[i] = phi * x[i - 1] + eps[i]
    return x


def test_iid_chain_tau_is_one():
    x = np.random.default_rng(0).normal(size=20000)
    tau = diagnostics.integrated_autocorr_time(x)
    assert 0.8 < tau < 1.3


def test_ar1_tau_matches_analytic():
    # AR(1): τ = (1 + φ) / (1 - φ)
    for phi in (0.5, 0.8, 0.95):
        x = _ar1(phi, 200_000, seed=int(phi * 100))
        tau = diagnostics.integrated_autocorr_time(x)
        expected = (1 + phi) / (1 - phi)
        assert abs(tau - expected) / expected < 0.25, (phi, tau, expected)


def test_ess_per_1000():
    x = _ar1(0.9, 100_000, seed=3)
    # τ = 19 → ≈ 52.6 effective samples per 1000 iterations
    e = diagnostics.ess_per_1000_iters(x)
    assert 35 < e < 75


def test_multidim_ess_takes_min():
    r = np.random.default_rng(1)
    a = r.normal(size=50_000)
    b = _ar1(0.95, 50_000, seed=2)
    ess = diagnostics.effective_sample_size(np.stack([a, b], 1))
    assert ess < 5_000  # dominated by the sticky coordinate


def test_degenerate_chain():
    assert diagnostics.integrated_autocorr_time(np.ones(100)) == 100.0


def test_split_r_hat_converged_vs_not():
    r = np.random.default_rng(5)
    good = r.normal(size=(4, 5000))
    assert diagnostics.split_r_hat(good) < 1.02
    bad = good + np.arange(4)[:, None] * 3.0
    assert diagnostics.split_r_hat(bad) > 1.5
