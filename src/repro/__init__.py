"""repro — Firefly Monte Carlo (FlyMC) at pod scale, in JAX.

Layers:
  repro.api          — public sampling surface: (init, step) algorithms +
                       the device-resident multi-chain driver
  repro.core         — the paper's contribution: exact MCMC with data subsets
  repro.models       — GLM zoo (paper's experiments) + assigned LM architectures
  repro.data         — synthetic data generators + sharded global-array builders
  repro.optim        — AdamW/SGD/SGLD, gradient compression, microbatching
  repro.kernels      — Pallas TPU kernels for the compute hot spots
  repro.distributed  — mesh conventions, sharded FlyMC, parallelism rules
  repro.checkpoint   — atomic, elastic, multi-host checkpointing
  repro.launch       — mesh/dryrun/train/serve entry points
  repro.configs      — one config per assigned architecture + paper experiments
"""

from repro import compat  # noqa: F401  (jax forward-compat polyfills)

__version__ = "1.1.0"
