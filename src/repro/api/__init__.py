"""repro.api — the public sampling surface (blackjax-style).

Algorithms are pairs of pure functions bundled in a
:class:`SamplingAlgorithm`:

    init(key, position) -> State
    step(key, state)    -> (State, StepStats)

built by :func:`firefly` (the paper's exact-subset chain) or
:func:`regular_mcmc` (the full-data baseline). Both are driven by
:func:`sample`, a device-resident multi-chain driver: chunked ``lax.scan``
with the capacity-overflow flag checked only at chunk boundaries, ``vmap``
over chains, and a single :class:`Trace` pytree out.

    >>> alg = firefly(model, kernel="rwmh", q_db=0.01, step_size=0.05)
    >>> trace = sample(alg, jax.random.key(0), 2000, num_chains=4)
    >>> trace.theta.shape           # (4, 2000, D)

Output is pluggable via :mod:`repro.api.collectors` — streaming on-device
reductions (online moments, split-R̂, batch-means ESS, posterior predictive,
query accounting) whose memory does not scale with ``num_samples``:

    >>> trace = sample(alg, key, 1_000_000, num_chains=4, collectors={
    ...     "moments": OnlineMoments(), "rhat": RHat(),
    ...     "queries": QueryBudget(),
    ... })
    >>> trace.results["moments"]["mean"]   # (4, D), no trace materialized
"""

from repro.api.algorithm import (
    MCMCState,
    SamplingAlgorithm,
    algorithm_from_spec,
    firefly,
    regular_mcmc,
)
from repro.api.collectors import (
    BatchMeansESS,
    Collector,
    FullTrace,
    OnlineMoments,
    PosteriorPredictive,
    QueryBudget,
    RHat,
    ThinnedTrace,
    peek,
)
from repro.api.driver import ChunkEvent, Trace, sample

__all__ = [
    "BatchMeansESS",
    "ChunkEvent",
    "Collector",
    "FullTrace",
    "MCMCState",
    "OnlineMoments",
    "PosteriorPredictive",
    "QueryBudget",
    "RHat",
    "SamplingAlgorithm",
    "ThinnedTrace",
    "Trace",
    "algorithm_from_spec",
    "firefly",
    "peek",
    "regular_mcmc",
    "sample",
]
