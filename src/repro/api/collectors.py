"""Streaming observables: on-device reductions over the sampling trajectory.

A :class:`Collector` is a pure ``(init, update, finalize)`` pytree-carry
reduction that the :func:`repro.api.sample` driver threads through its jitted
``lax.scan`` chunks:

  * ``init(num_samples, position, stats) -> carry`` — build the carry pytree
    (device arrays). ``position``/``stats`` are ``jax.ShapeDtypeStruct``
    pytrees describing one chain's θ and one step's
    :class:`~repro.core.flymc.StepStats`; only shapes/dtypes may be read.
  * ``update(carry, position, stats) -> carry`` — consume one post-step
    ``(θ, StepStats)`` pair. Runs *inside* the scan body (traced), is
    ``vmap``'d over chains, and composes with ``shard_map`` (θ and the psum'd
    stats are replicated across shards, so carries stay replicated too).
  * ``finalize(carry) -> result`` — host-side post-processing. The carry
    always arrives with a leading ``(num_chains, ...)`` axis (added for
    single-chain runs), so cross-chain reductions (R̂) happen here.
  * ``peek(carry) -> result`` — OPTIONAL non-destructive mid-run read
    (default: ``finalize`` on a deep copy of the carry, via the
    :class:`Collector` base class or the module-level :func:`peek`
    fallback). This is how the driver's chunk-boundary hook and the
    :mod:`repro.serve` scheduler stream R̂/ESS out of an in-flight chain;
    a peek never perturbs the run (bitwise, pinned in tests).

The driver folds carries only over *committed* chunks — a chunk that
overflowed its capacity is re-run (bitwise, from the saved pre-chunk state)
before any collector sees it — so every built-in reduction is bitwise
invariant to capacity growth, chunking, and buffer doubling, exactly like
the trajectory itself.

Memory is O(what-you-ask-for): a ``sample`` call whose collectors carry no
trace buffer materializes nothing that scales with ``num_samples``.

Estimator math is shared with :mod:`repro.core.diagnostics`
(``rhat_from_split_moments``, ``tau_from_batch_means``) so the streaming and
offline paths cannot drift.

Collectors hash by identity; reuse the same instances across ``sample`` calls
to reuse the driver's compiled chunk executables.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diagnostics


def _zeros(struct):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), struct)


def _flat_dim(struct) -> int:
    return int(np.prod(struct.shape, dtype=np.int64)) if struct.shape else 1


def _copy_carry(carry):
    return jax.tree.map(lambda l: jnp.array(l, copy=True), carry)


class Collector:
    """Optional base class for collectors: supplies the default ``peek``.

    The protocol itself stays duck-typed — ``validate_collectors`` checks for
    ``(init, update, finalize)`` only, and ``peek`` is optional everywhere
    (:func:`peek` falls back for collectors that don't define it).
    """

    def peek(self, carry):
        """Non-destructively read the would-be result of ``finalize(carry)``.

        ``finalize`` may hand back device buffers that *alias* the live carry
        (``FullTrace`` returns the trace buffer itself), and the driver's
        committed-chunk fold donates that carry — so finalizing mid-run and
        keeping the result would read memory the next chunk overwrites in
        place. ``peek`` finalizes a deep COPY of the carry instead: the live
        carry is never touched, nothing in the returned result aliases it,
        and a peek-then-continue run is bitwise identical to one that never
        peeked (pinned in ``tests/test_collectors.py``).
        """
        return self.finalize(_copy_carry(carry))


def peek(collector, carry):
    """``collector.peek(carry)`` with a safe fallback for bare-protocol
    collectors: finalize a deep copy of the carry (never the carry itself).

    This is the chunk-boundary read used by schedulers and the
    :mod:`repro.serve` service to stream R̂/ESS/moments out of an in-flight
    chain without consuming — or aliasing — the collector state.
    """
    fn = getattr(collector, "peek", None)
    if callable(fn):
        return fn(carry)
    return collector.finalize(_copy_carry(carry))


@dataclasses.dataclass(eq=False)
class FullTrace(Collector):
    """Today's dense output: every θ sample plus per-iteration StepStats.

    This is the default collector — ``sample()`` without ``collectors=``
    behaves exactly as before, reproducing ``Trace.theta`` / ``Trace.stats``
    bitwise. The buffers are written in-place inside the scan
    (``buf.at[n].set``), so the carry is the only O(num_samples) allocation.
    """

    with_stats: bool = True

    def init(self, num_samples, position, stats):
        buf = lambda s: jnp.zeros((num_samples,) + s.shape, s.dtype)
        carry = {"n": jnp.int32(0), "theta": buf(position)}
        if self.with_stats:
            carry["stats"] = jax.tree.map(buf, stats)
        return carry

    def update(self, carry, position, stats):
        n = carry["n"]
        out = {"n": n + 1, "theta": carry["theta"].at[n].set(position)}
        if self.with_stats:
            out["stats"] = jax.tree.map(
                lambda b, leaf: b.at[n].set(leaf), carry["stats"], stats
            )
        return out

    def finalize(self, carry):
        result = {"theta": carry["theta"]}
        if self.with_stats:
            result["stats"] = carry["stats"]
        return result


@dataclasses.dataclass(eq=False)
class ThinnedTrace(Collector):
    """Every ``thin``-th θ, decimated on device: ``theta[thin-1::thin]``.

    Entry ``i`` is iteration ``(i+1)·thin - 1`` (the LAST iteration of each
    thin window; a trailing partial window contributes nothing) — bitwise the
    slice the host-side ``thin=`` path takes, at 1/thin the memory.
    """

    thin: int = 1

    def __post_init__(self):
        if self.thin < 1:
            raise ValueError("thin must be >= 1")

    def init(self, num_samples, position, stats):
        del stats
        kept = num_samples // self.thin
        return {
            "n": jnp.int32(0),
            "theta": jnp.zeros((kept,) + position.shape, position.dtype),
        }

    def update(self, carry, position, stats):
        del stats
        n = carry["n"]
        kept = carry["theta"].shape[0]
        if kept == 0:  # num_samples < thin: nothing ever kept
            return {"n": n + 1, "theta": carry["theta"]}
        keep = (n % self.thin) == (self.thin - 1)
        slot = jnp.minimum(n // self.thin, kept - 1)
        row = jnp.where(keep, position, carry["theta"][slot])
        return {"n": n + 1, "theta": carry["theta"].at[slot].set(row)}

    def finalize(self, carry):
        return {"theta": carry["theta"]}


@dataclasses.dataclass(eq=False)
class OnlineMoments(Collector):
    """Welford running mean (and covariance) of θ — constant memory.

    The carry is ``(count, mean, M2)`` with θ flattened to ``(D,)``; the
    covariance co-moment matrix is O(D²) and optional. ``finalize`` returns
    per-chain ``{"count", "mean", "cov"}`` (mean reshaped to θ's shape, cov
    over the flattened coordinates, ``ddof=1``).
    """

    cov: bool = True

    def init(self, num_samples, position, stats):
        del num_samples, stats
        d = _flat_dim(position)
        carry = {
            "count": jnp.int32(0),
            "mean": jnp.zeros((d,), position.dtype),
            "shape": jnp.zeros(position.shape, jnp.int8),  # shape token only
        }
        if self.cov:
            carry["m2"] = jnp.zeros((d, d), position.dtype)
        return carry

    def update(self, carry, position, stats):
        del stats
        x = position.reshape(-1)
        n1 = carry["count"] + 1
        delta = x - carry["mean"]
        mean = carry["mean"] + delta / n1.astype(x.dtype)
        out = {"count": n1, "mean": mean, "shape": carry["shape"]}
        if self.cov:
            out["m2"] = carry["m2"] + jnp.outer(delta, x - mean)
        return out

    def finalize(self, carry):
        count = np.asarray(jax.device_get(carry["count"]))
        mean = np.asarray(jax.device_get(carry["mean"]))
        shape = carry["shape"].shape[1:]  # per-chain θ shape
        result = {
            "count": count,
            "mean": mean.reshape(mean.shape[:1] + shape),
        }
        if self.cov:
            m2 = np.asarray(jax.device_get(carry["m2"]), np.float64)
            denom = np.maximum(count - 1, 1).astype(np.float64)
            result["cov"] = m2 / denom[:, None, None]
        return result


@dataclasses.dataclass(eq=False)
class RHat(Collector):
    """Split-chain R̂ accumulators, matching ``diagnostics.split_r_hat``.

    Each chain streams Welford moments for its first and second half
    (``half = num_samples // 2``, iterations beyond ``2·half`` ignored —
    the same tail-drop as the offline estimator). ``finalize`` pools the
    ``2 × num_chains`` split moments through the shared
    :func:`repro.core.diagnostics.rhat_from_split_moments`, so the streaming
    and offline R̂ agree to accumulation rounding. Works with a single chain
    (two splits), sharpens with more.
    """

    def init(self, num_samples, position, stats):
        del stats
        d = _flat_dim(position)
        half = num_samples // 2
        return {
            "half": jnp.int32(half),
            "n": jnp.int32(0),
            "count": jnp.zeros((2,), jnp.int32),
            "mean": jnp.zeros((2, d), position.dtype),
            "m2": jnp.zeros((2, d), position.dtype),
        }

    def update(self, carry, position, stats):
        del stats
        x = position.reshape(-1)
        half = carry["half"]
        n = carry["n"]
        split = jnp.where(n < half, 0, 1)
        active = n < 2 * half
        cnt = carry["count"][split] + jnp.where(active, 1, 0)
        delta = x - carry["mean"][split]
        mean = carry["mean"][split] + jnp.where(
            active, delta / jnp.maximum(cnt, 1).astype(x.dtype), 0.0
        )
        m2 = carry["m2"][split] + jnp.where(
            active, delta * (x - mean), 0.0
        )
        return {
            "half": half,
            "n": n + 1,
            "count": carry["count"].at[split].set(cnt),
            "mean": carry["mean"].at[split].set(mean),
            "m2": carry["m2"].at[split].set(m2),
        }

    def finalize(self, carry):
        count = np.asarray(jax.device_get(carry["count"]))  # (C, 2)
        mean = np.asarray(jax.device_get(carry["mean"]), np.float64)
        m2 = np.asarray(jax.device_get(carry["m2"]), np.float64)
        h = int(count.flat[0])
        if h < 2:
            return {"r_hat": float("nan"), "per_coordinate": None}
        c, _, d = mean.shape
        means = mean.reshape(2 * c, d)  # k = 2·C splits, length h each
        variances = m2.reshape(2 * c, d) / (h - 1)
        per_coord = diagnostics.rhat_from_split_moments(h, means, variances)
        per_coord = np.atleast_1d(per_coord)
        return {"r_hat": float(per_coord.max()), "per_coordinate": per_coord}

    def peek(self, carry):
        """Mid-run R̂ over the splits that have data, for convergence polling.

        ``finalize`` assumes both splits of every chain ran to ``half``
        iterations; a peek mid-run sees the second split partially filled (or
        empty). The monitor pools every split with ≥ 2 samples at the length
        of the *shortest* such split's count — a slight length mismatch is
        acceptable for a termination check, and with k ≥ 2 usable splits
        (always true for ≥ 2 chains, even early on) the estimate tightens as
        the run proceeds. Returns ``r_hat = inf`` when fewer than two splits
        are usable (i.e. "not converged yet", never a premature stop). The
        carry is only read, never consumed: peek-then-continue stays bitwise
        identical to never-peeked.
        """
        count = np.asarray(jax.device_get(carry["count"]))  # (C, 2)
        mean = np.asarray(jax.device_get(carry["mean"]), np.float64)
        m2 = np.asarray(jax.device_get(carry["m2"]), np.float64)
        c, _, d = mean.shape
        counts = count.reshape(2 * c)
        means = mean.reshape(2 * c, d)
        m2s = m2.reshape(2 * c, d)
        usable = counts >= 2
        if int(usable.sum()) < 2:
            return {
                "r_hat": float("inf"),
                "per_coordinate": None,
                "splits_used": int(usable.sum()),
            }
        h = int(counts[usable].min())
        variances = m2s[usable] / (counts[usable, None] - 1)
        per_coord = np.atleast_1d(
            diagnostics.rhat_from_split_moments(h, means[usable], variances)
        )
        return {
            "r_hat": float(per_coord.max()),
            "per_coordinate": per_coord,
            "splits_used": int(usable.sum()),
        }


@dataclasses.dataclass(eq=False)
class BatchMeansESS(Collector):
    """On-device batch-means estimate of τ (and ESS) per coordinate.

    The carry holds ``num_batches`` per-batch *running means* plus Welford
    chain moments (never raw sum-of-squares, which cancels catastrophically
    in f32 on long off-center chains); iterations past
    ``num_batches · batch_len`` are ignored (the same truncation as the
    offline :func:`repro.core.diagnostics.batch_means_ess`, which shares
    the ``tau_from_batch_means`` math). Batch means are asymptotically
    independent, so ``τ ≈ batch_len · Var(batch means) / Var(chain)`` — a
    coarser but streaming alternative to the Geyer estimator; the two agree
    on well-behaved chains (cross-checked in tests).
    """

    num_batches: int = 32

    def __post_init__(self):
        if self.num_batches < 2:
            raise ValueError("num_batches must be >= 2")

    def init(self, num_samples, position, stats):
        del stats
        d = _flat_dim(position)
        b = self.num_batches
        batch_len = max(1, num_samples // b)
        # Per-batch RUNNING means and Welford chain moments — never raw
        # (sum, sum_sq), whose f32 cancellation makes the variance garbage
        # at exactly the million-iteration scale this collector targets.
        return {
            "batch_len": jnp.int32(batch_len),
            "n": jnp.int32(0),
            "batch_mean": jnp.zeros((b, d), position.dtype),
            "count": jnp.int32(0),
            "mean": jnp.zeros((d,), position.dtype),
            "m2": jnp.zeros((d,), position.dtype),
        }

    def update(self, carry, position, stats):
        del stats
        x = position.reshape(-1)
        b = carry["batch_mean"].shape[0]
        n = carry["n"]
        batch_len = carry["batch_len"]
        active = n < b * batch_len
        idx = jnp.minimum(n // batch_len, b - 1)
        j = (n - idx * batch_len + 1).astype(x.dtype)  # 1-based, in-batch
        cur = carry["batch_mean"][idx]
        new_bm = cur + jnp.where(active, (x - cur) / j, 0.0)
        cnt = carry["count"] + jnp.where(active, 1, 0)
        delta = x - carry["mean"]
        mean = carry["mean"] + jnp.where(
            active, delta / jnp.maximum(cnt, 1).astype(x.dtype), 0.0
        )
        m2 = carry["m2"] + jnp.where(active, delta * (x - mean), 0.0)
        return {
            "batch_len": batch_len,
            "n": n + 1,
            "batch_mean": carry["batch_mean"].at[idx].set(new_bm),
            "count": cnt,
            "mean": mean,
            "m2": m2,
        }

    def finalize(self, carry):
        batch_len = int(np.asarray(jax.device_get(carry["batch_len"])).flat[0])
        bm = np.asarray(jax.device_get(carry["batch_mean"]), np.float64)
        m2 = np.asarray(jax.device_get(carry["m2"]), np.float64)
        n_used = np.asarray(jax.device_get(carry["count"]))  # (C,)
        c, b, d = bm.shape
        out_tau = np.full((c, d), np.nan)
        out_ess = np.full((c,), np.nan)
        for i in range(c):
            nu = int(n_used[i])
            nb = nu // batch_len
            if nb < 2 or nu < 2:
                continue
            chain_var = m2[i] / (nu - 1)
            tau = diagnostics.tau_from_batch_means(
                bm[i, :nb], batch_len, chain_var
            )
            out_tau[i] = np.maximum(tau, 1.0)
            out_ess[i] = (nu / out_tau[i]).min()
        return {"tau": out_tau, "ess": out_ess, "count": n_used}


def _default_predict(theta, x_eval):
    return jax.nn.sigmoid(x_eval @ theta)


@dataclasses.dataclass(eq=False)
class PosteriorPredictive(Collector):
    """Running posterior-mean predictive probability at fixed eval points.

    The serving workload: ``E_posterior[p(y | x, θ)]`` for each row of
    ``x_eval``, streamed as a running mean — no trace, no post-hoc pass.
    ``predict_fn(theta, x_eval)`` defaults to the logistic-GLM
    ``sigmoid(x_eval @ θ)``.
    """

    x_eval: Any = None
    predict_fn: Callable[[Any, Any], jax.Array] | None = None

    def __post_init__(self):
        if self.x_eval is None:
            raise ValueError("PosteriorPredictive needs x_eval")
        self.x_eval = jnp.asarray(self.x_eval)

    def init(self, num_samples, position, stats):
        del num_samples, stats
        fn = self.predict_fn or _default_predict
        p = jax.eval_shape(fn, position, self.x_eval)
        return {"count": jnp.int32(0), "mean": jnp.zeros(p.shape, p.dtype)}

    def update(self, carry, position, stats):
        del stats
        fn = self.predict_fn or _default_predict
        p = fn(position, self.x_eval)
        n1 = carry["count"] + 1
        mean = carry["mean"] + (p - carry["mean"]) / n1.astype(p.dtype)
        return {"count": n1, "mean": mean}

    def finalize(self, carry):
        return {
            "count": np.asarray(jax.device_get(carry["count"])),
            "mean_prob": np.asarray(jax.device_get(carry["mean"])),
        }


@dataclasses.dataclass(eq=False)
class QueryBudget(Collector):
    """Exact on-device int64 likelihood-query accounting.

    Replaces the host-side int64 sum over materialized per-step stats.
    Without ``jax_enable_x64`` a device int64 silently becomes int32 — which
    wraps at paper scale (N=1.8M × slice × 1200 iters ≈ 2.6e10 > 2³¹) — so
    the carry is a two-lane uint32 (lo, hi) emulating uint64: per-step
    ``lik_queries`` (int32, ≥ 0) adds into ``lo`` with the wrap carried into
    ``hi``. ``finalize`` reassembles exact Python ints and sums chains.
    """

    def init(self, num_samples, position, stats):
        del num_samples, position, stats
        return {"lo": jnp.uint32(0), "hi": jnp.uint32(0)}

    def update(self, carry, position, stats):
        del position
        q = stats.lik_queries.astype(jnp.uint32)
        lo = carry["lo"] + q  # uint32 add wraps mod 2³²
        wrapped = (lo < carry["lo"]).astype(jnp.uint32)
        return {"lo": lo, "hi": carry["hi"] + wrapped}

    def finalize(self, carry):
        lo = np.asarray(jax.device_get(carry["lo"]), np.uint64)
        hi = np.asarray(jax.device_get(carry["hi"]), np.uint64)
        per_chain = [(int(h) << 32) + int(l) for h, l in zip(hi, lo)]
        return sum(per_chain)


def validate_collectors(collectors: dict) -> dict:
    """Check a user-supplied ``{name: Collector}`` dict (driver entry gate)."""
    if not isinstance(collectors, dict):
        raise TypeError("collectors must be a {name: Collector} dict")
    for name, col in collectors.items():
        if not isinstance(name, str):
            raise TypeError(f"collector names must be strings, got {name!r}")
        for attr in ("init", "update", "finalize"):
            if not callable(getattr(col, attr, None)):
                raise TypeError(
                    f"collector {name!r} ({type(col).__name__}) does not "
                    f"implement the (init, update, finalize) protocol"
                )
    return dict(collectors)


__all__ = [
    "BatchMeansESS",
    "Collector",
    "FullTrace",
    "OnlineMoments",
    "PosteriorPredictive",
    "QueryBudget",
    "RHat",
    "ThinnedTrace",
    "peek",
    "validate_collectors",
]
