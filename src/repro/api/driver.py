"""Device-resident multi-chain sampling driver.

The chain lives on device end to end, and the chain axis is carried
NATIVELY: a multi-chain run is one chunked ``lax.scan`` whose carry is the
chain-stacked state and whose body applies a chain-batched step — not a
``vmap`` of per-chain scans. Batching the step batches its kernels: the
Pallas kernels coalesce the chain axis into a leading kernel-grid
dimension (one launch for all chains — ``custom_vmap`` rules in
``kernels/*/ops``). Algorithms that provide ``step_chains`` (e.g. the
distributed chain fleet, which shard_maps the chain axis) are dispatched
directly; for the rest the driver batches ``alg.step`` itself.
Each chunk of ``chunk_size`` iterations is one jitted scan, and the only
host synchronization is a single overflow-flag read per chunk. Output is
produced by :mod:`repro.api.collectors` — pure ``(init, update, finalize)``
reductions whose carries thread through the scan, so memory is
O(what-you-ask-for): the default :class:`~repro.api.collectors.FullTrace`
materializes the dense trajectory exactly as before, while a collectors-only
call (online moments, split-R̂, query accounting, …) allocates nothing that
scales with ``num_samples`` — zero per-iteration ``device_get``s, unlike the
legacy host loop (~4 syncs/step).

Exactness under bounded buffers (DESIGN.md §3.1) is preserved at chunk
granularity: the pre-chunk state is kept alive, and if any step in the chunk
overflowed its bright/candidate capacity, the *whole chunk* is re-run from
that saved state with doubled capacities and the identical per-iteration RNG
keys (``fold_in(chain_key, iteration)``), so the realized chain is bitwise
the one an infinite-capacity sampler would have produced. Collector carries
only ever fold *committed* chunks (the fold runs after the overflow check
passes), so every streamed reduction is bitwise capacity/chunk-invariant
too — with no carry rollback needed.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import collectors as collectors_lib
from repro.api.algorithm import SamplingAlgorithm
from repro.core.flymc import StepStats
from repro.kernels import common as kernels_common


# jit cache for the driver's chunk functions, keyed on the algorithm's
# stable function identities plus ``(num_chains, chunk_size, capacity)``
# (and the collector set / chain-batching flag where they shape the trace):
# repeated sample() calls on the same algorithm reuse compiled chunk/init
# executables, and a capacity-doubling overflow re-run re-traces ONLY the
# chain scan at the grown capacity — the committed-chunk fold is keyed
# capacity-independently (chunk outputs are O(cs) θ/stats, no buffer-shaped
# operands), so an overflow retry never recompiles it. Collectors hash by
# identity, so reusing collector instances across calls is what makes the
# cache hit. LRU-bounded: entries keep the algorithm's closed-over data
# arrays alive, so stale algorithms must age out (and hot ones must not be
# mass-evicted).
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 64

# The back-compat default collector set: one shared instance so repeated
# sample() calls without collectors= hit the same compiled chunk fn.
_DEFAULT_TRACE = collectors_lib.FullTrace()


def _cached(key, build):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        while len(_JIT_CACHE) >= _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
        fn = _JIT_CACHE[key] = build()
    else:
        _JIT_CACHE.move_to_end(key)
    return fn


def cached_jit(key, build):
    """The driver's LRU jit cache, for layers that extend the driver.

    ``repro.serve``'s group engines key their chain-scan executables here so
    a service restart (or an engine torn down and repacked after device
    loss) re-enters a warm cache instead of recompiling — the same policy,
    same LRU, same eviction as the driver's own chunk functions.
    """
    return _cached(key, build)


class NonFiniteError(RuntimeError):
    """A chunk produced non-finite chain state (NaN/Inf in θ, log-joint, or
    the δ cache). Raised at the chunk boundary BEFORE the fold, so the
    collector carries still hold the last healthy committed prefix.

    Non-finiteness must be trapped, not tolerated: a NaN'd proposal
    log-ratio compares False, so a poisoned chain can keep "running" —
    always rejecting, θ frozen or silently diverged from its law — while
    every summary statistic still looks plausible. The serve engines run
    the same predicate per lane and quarantine just the sick lane
    (:meth:`repro.serve.engine.GroupEngine.run_chunk`).
    """


def finite_lanes(arrays, lane_axis: int = 0):
    """Per-lane all-finite mask over floating-point ``arrays`` sharing a
    common ``lane_axis``: a lane is healthy iff every float entry of every
    array is finite. Non-float arrays are ignored (counters, flags, int
    z-partitions cannot go NaN). Returns a bool vector over the lane axis,
    or None if no array is floating-point. Pure jnp — usable inside jit
    (the serve chunk computes it on-device so health rides the existing
    per-chunk host sync instead of adding one)."""
    ok = None
    for a in arrays:
        if not jnp.issubdtype(a.dtype, jnp.floating):
            continue
        lanes = jnp.moveaxis(a, lane_axis, 0)
        this = jnp.all(
            jnp.isfinite(lanes.reshape(lanes.shape[0], -1)), axis=1
        )
        ok = this if ok is None else (ok & this)
    return ok


class Trace(NamedTuple):
    """Everything one `sample()` call produced.

    theta         : (num_chains, num_samples // thin, *theta_shape) — the
                    ``theta[thin - 1 :: thin]`` slice of the per-iteration
                    trajectory, i.e. entry ``i`` is iteration
                    ``(i + 1)·thin - 1`` (the LAST iteration of each thin
                    window, not the first), and a trailing partial window
                    contributes nothing. None when ``collectors=`` was given
                    (ask for a FullTrace/ThinnedTrace collector instead).
    stats         : StepStats with (num_chains, num_samples) leaves
                    (unthinned); None when ``collectors=`` was given
    total_queries : int — total per-datum likelihood evaluations, all chains
                    (an int64 total: per-step counts are int32 and would wrap
                    at paper scale, e.g. N=1.8M × slice × 1200 iters ≈ 2.6e10
                    > 2^31). From the on-device QueryBudget collector when one
                    was passed; from a host-side sum over materialized stats
                    on the default path; None otherwise.
    final_state   : chain state pytree (leading chain axis iff num_chains > 1),
                    suitable for resuming via sample(..., init_state=...)
    algorithm     : the (possibly capacity-grown) SamplingAlgorithm
    results       : {name: finalized result} for the ``collectors=`` dict
                    passed in; None on the default (FullTrace) path
    """

    theta: jax.Array | None
    stats: StepStats | None
    total_queries: Any
    final_state: Any
    algorithm: SamplingAlgorithm
    results: dict | None = None


def _broadcast_positions(position, num_chains: int, reference):
    """Give every chain a starting position: accepts one position (shared)
    or a pytree with a leading (num_chains, ...) axis. ``reference`` (the
    algorithm's default position) disambiguates the two when shapes collide."""
    shape_of = lambda tree: jax.tree.map(jnp.shape, tree)
    if reference is not None and shape_of(position) == shape_of(reference):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)),
            position,
        )
    leaves = jax.tree.leaves(position)
    if leaves and all(
        hasattr(l, "shape") and l.shape[:1] == (num_chains,) for l in leaves
    ):
        return position
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)), position
    )


def _identity(state):
    return state


def _capacity_of(alg: SamplingAlgorithm):
    spec = alg.spec
    return (getattr(spec, "capacity", None), getattr(spec, "cand_capacity", None))


def _threads_data(alg: SamplingAlgorithm) -> bool:
    """Whether the chunk scan takes the dataset as a traced operand.

    True for algorithms providing the ``step_data`` form (and no custom
    ``step_chains`` dispatch, which owns its own data placement). The
    operand form is shared bit-for-bit with the :mod:`repro.serve` group
    engines — baking the dataset in as a jit constant changes XLA's
    low-bit rounding of the likelihood reductions, so the form is part of
    the exactness contract, not an implementation detail.
    """
    return (
        alg.step_data is not None
        and alg.data is not None
        and alg.step_chains is None
    )


def _threads_data_chains(alg: SamplingAlgorithm) -> bool:
    """Whether a MULTI-chain chunk scan takes the dataset as an operand via
    the algorithm's own chain-batched dispatch (``step_chains_data``, e.g.
    the distributed fleet's shard_map with replicated data). Same exactness
    rationale as :func:`_threads_data`; this form wins over it when both
    are available and ``num_chains > 1``."""
    return alg.step_chains_data is not None and alg.data is not None


def _make_scan_fn(alg: SamplingAlgorithm, num_chains: int, cs: int):
    """One jitted chunk of the chain: cs steps, carrying the chain-stacked
    state natively when num_chains > 1 (one scan whose body is the
    chain-batched step — no per-chain scans). Emits the per-step
    (θ, StepStats) as chunk-local O(cs) scan outputs (time axis leading,
    chain axis second) plus (final_state, any_overflow). Algorithms with
    the ``step_data`` form get the dataset threaded as a trailing operand
    (see :func:`_threads_data`); the chunk signature grows accordingly."""
    multi = num_chains > 1
    if multi and _threads_data_chains(alg):
        step = alg.step_chains_data
    elif _threads_data(alg):
        step = (
            jax.vmap(alg.step_data, in_axes=(0, 0, None, None))
            if multi else alg.step_data
        )
    else:
        step = alg.batched_step() if multi else alg.step
    if multi:
        fold_keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))
        position = jax.vmap(alg.position_of)
    else:
        fold_keys, position = jax.random.fold_in, alg.position_of

    def chunk(state, keys, start, *operands):
        def body(carry, i):
            new_state, info = step(fold_keys(keys, i), carry, *operands)
            return new_state, (position(new_state), info)

        iters = start + jnp.arange(cs, dtype=jnp.int32)
        final, (pos, infos) = jax.lax.scan(body, state, iters)
        return final, pos, infos, jnp.any(infos.overflow)

    return jax.jit(chunk)


class ChunkEvent:
    """What the driver exposes to ``on_chunk`` at each committed boundary.

    ``start``/``size`` locate the chunk (``start`` counts committed samples
    before it, so ``start + size`` is the total committed so far);
    ``num_samples`` is the run's target; ``state`` the post-chunk chain
    state. ``peek(name)`` reads the named collector's would-be result
    through :func:`repro.api.collectors.peek` — non-destructive, never
    aliasing the live carry, so peeking cannot perturb the run.
    """

    def __init__(self, start, size, num_samples, state, colls, carries, multi):
        self.start = start
        self.size = size
        self.num_samples = num_samples
        self.state = state
        self._colls = colls
        self._carries = carries
        self._multi = multi

    @property
    def committed(self) -> int:
        return self.start + self.size

    def peek(self, name: str):
        carry = self._carries[name]
        if not self._multi:  # finalize/peek contract: leading chain axis
            carry = jax.tree.map(lambda l: l[None], carry)
        return collectors_lib.peek(self._colls[name], carry)


def make_collector_fold(colls: dict, multi: bool, max_count: int | None = None):
    """Fold one COMMITTED chunk's (θ, StepStats) outputs into the collector
    carries, in step order. The chunk outputs arrive time-major
    ((cs, K, ...) for multi); the fold is one scan over the time axis whose
    body batches each collector's per-chain ``update`` over the chain axis,
    so the carries keep their leading (K, ...) layout.

    A separate jit from the chain scan for two reasons: (a) it runs only
    after the chunk's overflow check passes, so an overflowed chunk never
    touches collector state and capacity re-runs need no carry rollback —
    and its cache key is capacity-independent, so a capacity-doubling
    re-run never recompiles it; (b) the carry argument is donated (where
    the backend supports input-output aliasing), so a trace-type
    collector's O(num_samples) buffer is updated in place instead of being
    copied at every chunk boundary.

    Public because the :mod:`repro.serve` group engines fold the identical
    protocol over their slot axis — one encoding of the committed-chunk
    fold, shared by the driver and the service.

    ``max_count`` is the serve engines' masked variant: the fold signature
    becomes ``fold(carries, counts, pos, infos) -> (carries, counts)`` with
    int32 ``counts`` of samples folded so far (per-chain ``(K,)`` when
    ``multi``, scalar otherwise), and updates stop being absorbed once the
    count reaches ``max_count``. In a packed serve group every member runs
    the same chunk, so a job whose ``max_samples`` is not chunk-aligned
    overshoots by up to one chunk — the mask discards exactly the overshoot
    updates, making the carry bitwise the carry of a solo run of
    ``max_count`` samples (the kept updates see identical inputs in
    identical order; collector updates are pure, so discarded applications
    leave no residue).
    """
    names = tuple(colls)
    updates = {
        n: (jax.vmap(colls[n].update) if multi else colls[n].update)
        for n in names
    }

    if max_count is None:

        def fold(carries, pos, infos):
            def body(cars, x):
                p, inf = x
                return {n: updates[n](cars[n], p, inf) for n in names}, None

            cars, _ = jax.lax.scan(body, carries, (pos, infos))
            return cars

    else:
        limit = jnp.int32(max_count)

        def fold(carries, counts, pos, infos):
            def body(carry, x):
                cars, cnt = carry
                p, inf = x
                new = {n: updates[n](cars[n], p, inf) for n in names}
                active = cnt < limit

                def sel(a, b):
                    m = active.reshape(
                        active.shape + (1,) * (a.ndim - active.ndim)
                    )
                    return jnp.where(m, a, b)

                cars = jax.tree.map(sel, new, cars)
                return (cars, cnt + active.astype(cnt.dtype)), None

            (cars, cnt), _ = jax.lax.scan(body, (carries, counts), (pos, infos))
            return cars, cnt

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fold, donate_argnums=donate)


def sample(
    alg: SamplingAlgorithm,
    key: jax.Array,
    num_samples: int,
    *,
    num_chains: int = 1,
    thin: int = 1,
    chunk_size: int = 128,
    init_position=None,
    init_state=None,
    collectors: dict | None = None,
    on_chunk=None,
    health_check: bool = False,
) -> Trace:
    """Run ``num_samples`` iterations of ``alg`` on device; return a Trace.

    ``init_position`` seeds ``alg.init`` (default: ``alg.default_position``);
    pass a (num_chains, ...) array for per-chain starts. ``init_state``
    resumes from an existing chain state instead — single chain, or
    ``num_chains > 1`` with a leading-axis state (e.g. a previous multi-chain
    run's ``final_state``) — using ``key`` as the per-iteration key root with
    the fold-in counter offset by the state's ``iteration``: resuming with
    the prefix's key continues its exact stream (split == contiguous,
    bitwise) instead of replaying it.

    ``num_chains > 1`` runs the chains inside ONE chunked scan over
    chain-stacked state: the step is the algorithm's ``step_chains`` when it
    has one (the distributed fleet's shard_maps the chain axis), else
    ``alg.step`` batched here — each Pallas kernel then dispatches as a
    single launch with a leading chain grid dimension. Either way the
    realized trajectories are bitwise those of per-chain execution with
    keys ``split(key, num_chains)``.

    ``collectors`` maps names to :mod:`repro.api.collectors` instances; their
    ``update`` runs inside the jitted chunk scans (batched over the chain
    axis) and their finalized results land on ``Trace.results``. Without it,
    the default :class:`~repro.api.collectors.FullTrace` reproduces the dense
    ``Trace.theta``/``Trace.stats`` bitwise; with it, nothing O(num_samples)
    is materialized unless a trace collector asks for it. ``thin`` keeps
    every thin-th θ sample on the default path (the last of each window;
    stats stay per-iteration) — with explicit collectors use
    :class:`~repro.api.collectors.ThinnedTrace` instead. Host syncs: one per
    chunk (plus one at resume).

    ``on_chunk`` is the chunk-boundary hook: called with a
    :class:`ChunkEvent` after every COMMITTED chunk (never for an overflowed
    chunk awaiting its capacity re-run). ``event.peek(name)`` streams any
    collector's current value without consuming its carry — peeking leaves
    the run bitwise unchanged. Returning a truthy value stops the run early
    at that boundary (convergence-based termination): the Trace then holds
    only the committed samples (``theta``/``stats`` sliced on the default
    path; streaming collectors simply saw fewer updates).

    ``health_check`` raises :class:`NonFiniteError` at any chunk boundary
    whose outputs or post-chunk state contain NaN/Inf, BEFORE the fold — the
    collector carries then hold exactly the last healthy committed prefix.
    Off by default (it costs one extra device round-trip per chunk); the
    serve engines run the per-lane equivalent unconditionally because a
    multi-tenant group must contain one tenant's poison.
    """
    if num_samples <= 0:
        raise ValueError("num_samples must be positive")
    if num_chains < 1:
        raise ValueError("num_chains must be >= 1")
    chunk_size = max(1, min(int(chunk_size), num_samples))
    multi = num_chains > 1

    if collectors is None:
        colls = {"trace": _DEFAULT_TRACE}
        default_path = True
    else:
        if thin != 1:
            raise ValueError(
                "thin applies to the default trace only; with collectors= "
                "use ThinnedTrace(thin) instead"
            )
        colls = collectors_lib.validate_collectors(collectors)
        default_path = False

    start_offset = 0
    if init_state is not None:
        state = init_state
        if multi:
            leading = {
                jnp.shape(l)[:1] for l in jax.tree.leaves(state)
            }
            if leading != {(num_chains,)}:
                raise ValueError(
                    f"init_state resume with num_chains={num_chains} needs a "
                    f"state with a leading ({num_chains},) chain axis on "
                    f"every leaf (e.g. a previous multi-chain final_state)"
                )
        # Resume must NOT replay the prefix's key stream: per-iteration keys
        # are fold_in(chain_key, iteration), so a resumed segment continues
        # the counter at the state's iteration instead of restarting at 0.
        # With the same chain key, split runs are bitwise identical to one
        # contiguous run; with a fresh key, the segment is at least not a
        # replay of the original run's randomness. One host sync, up front.
        it = getattr(state, "iteration", None)
        if it is not None:
            vals = np.asarray(jax.device_get(it))
            if vals.ndim and not (vals == vals.flat[0]).all():
                raise ValueError(
                    "init_state chains are at different iterations "
                    f"({vals.tolist()}); resume needs a uniform offset"
                )
            start_offset = int(vals.flat[0] if vals.ndim else vals)
        # A checkpointed state may carry buffers grown past the algorithm's
        # built capacity (overflow doubles them mid-run), so the two can
        # disagree on buffer shapes at resume. Normalize the algorithm UP
        # to the state's capacity — growing is lossless, and trajectories
        # are bitwise capacity-invariant — then (if the doubling overshot)
        # resize the state up to the algorithm's capacity so they agree.
        if alg.resize is not None:
            struct = jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(
                    jnp.shape(l)[1:] if multi else jnp.shape(l), l.dtype
                ),
                state,
            )

            def _alg_undersized(a):
                tgt = jax.eval_shape(a.resize, struct)
                return any(
                    np.prod(t.shape) < np.prod(c.shape)
                    for t, c in zip(
                        jax.tree.leaves(tgt), jax.tree.leaves(struct)
                    )
                )

            while alg.grow is not None and _alg_undersized(alg):
                alg = _grown(alg)
            tgt = jax.eval_shape(alg.resize, struct)
            if any(
                t.shape != c.shape
                for t, c in zip(jax.tree.leaves(tgt), jax.tree.leaves(struct))
            ):
                resize = alg.resize
                state = _cached(
                    ("resize", resize, multi),
                    lambda: jax.jit(jax.vmap(resize) if multi else resize),
                )(state)
        k_steps = key
    else:
        k_init, k_steps = jax.random.split(key)
        position = init_position if init_position is not None else alg.default_position
        if position is None:
            raise ValueError(
                "no init_position given and the algorithm has no default"
            )
        def init_fn(alg):
            build = lambda: jax.jit(alg.batched_init() if multi else alg.init)
            return _cached(
                ("init", alg.init, alg.init_chains, multi), build
            )

        if multi:
            init_keys = jax.random.split(k_init, num_chains)
            positions = _broadcast_positions(
                position, num_chains, alg.default_position
            )
            state = init_fn(alg)(init_keys, positions)
        else:
            state = init_fn(alg)(k_init, position)
        # Grow until the initial bright set fits (deterministic re-init from
        # the same keys) — one host sync, before any sampling starts.
        while alg.init_overflow is not None and bool(
            jax.device_get(
                jnp.any(
                    (jax.vmap(alg.init_overflow) if multi else alg.init_overflow)(
                        state
                    )
                )
            )
        ):
            alg = _grown(alg)
            if multi:
                state = init_fn(alg)(init_keys, positions)
            else:
                state = init_fn(alg)(k_init, position)

    chain_keys = jax.random.split(k_steps, num_chains) if multi else k_steps

    # Collector carries, built from shape/dtype structs only (no compute):
    # one carry per chain, broadcast over the leading chain axis.
    pos_struct, stats_struct = alg.output_structs(
        jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                jnp.shape(l)[1:] if multi else jnp.shape(l), l.dtype
            ),
            state,
        )
    )
    carries = {
        name: col.init(num_samples, pos_struct, stats_struct)
        for name, col in colls.items()
    }
    if multi:
        carries = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_chains,) + l.shape), carries
        )

    def scan_fn_for(alg, cs):
        # Keyed on (num_chains, chunk_size, capacity) plus the step/dispatch
        # identities: an overflow re-run at a grown capacity traces its own
        # entry, and a later sample() call that reaches the same capacity
        # (memoized alg.grow() → same step identity) reuses it.
        return _cached(
            ("scan", alg.step, alg.step_chains, alg.position, num_chains,
             cs, _capacity_of(alg), kernels_common.chain_batching_enabled(),
             alg.step_data, alg.step_chains_data),
            lambda: _make_scan_fn(alg, num_chains, cs),
        )

    # Capacity-independent on purpose: chunk outputs are (cs, K) θ/stats
    # with no buffer-shaped operand, so one fold serves every capacity and
    # an overflow retry never recompiles it.
    fold_fn = _cached(
        ("fold", tuple(colls.items()), multi),
        lambda: make_collector_fold(colls, multi),
    )

    def scan_operands(alg):
        threads = _threads_data(alg) or (multi and _threads_data_chains(alg))
        return (alg.data, alg.stats) if threads else ()

    start = 0
    while start < num_samples:
        cs = min(chunk_size, num_samples - start)
        # Keep the pre-chunk state alive for the exact re-run on overflow.
        prev = state
        final, pos, infos, overflow = scan_fn_for(alg, cs)(
            state, chain_keys, jnp.int32(start_offset + start),
            *scan_operands(alg)
        )
        while bool(jax.device_get(overflow)):  # the chunk's one host sync
            alg = _grown(alg)
            resize = alg.resize if alg.resize is not None else _identity
            prev = _cached(
                ("resize", resize, multi),
                lambda: jax.jit(jax.vmap(resize) if multi else resize),
            )(prev)
            final, pos, infos, overflow = scan_fn_for(alg, cs)(
                prev, chain_keys, jnp.int32(start_offset + start),
                *scan_operands(alg)
            )
        if health_check:
            floats = [pos] + [
                l for l in jax.tree.leaves((infos, final))
                if jnp.issubdtype(l.dtype, jnp.floating)
            ]
            ok = _cached(
                ("health", len(floats)),
                lambda: jax.jit(lambda ls: jnp.all(
                    jnp.stack([jnp.all(jnp.isfinite(l)) for l in ls])
                )),
            )(floats)
            if not bool(jax.device_get(ok)):
                raise NonFiniteError(
                    f"non-finite chain state in iterations "
                    f"[{start_offset + start}, {start_offset + start + cs}); "
                    f"committed prefix of {start} samples is intact"
                )
        # Only a committed (non-overflowed) chunk reaches the collectors, so
        # capacity re-runs never need a carry rollback; the donated carry is
        # updated in place on backends with input-output aliasing.
        if colls:
            carries = fold_fn(carries, pos, infos)
        state = final
        start += cs
        if on_chunk is not None and on_chunk(
            ChunkEvent(start - cs, cs, num_samples, state, colls, carries,
                       multi)
        ):
            break

    committed = start

    # finalize() always sees a leading (num_chains, ...) carry axis.
    if not multi:
        carries = jax.tree.map(lambda l: l[None], carries)
    results = {name: colls[name].finalize(carries[name]) for name in colls}

    if default_path:
        tr = results["trace"]
        theta, stats = tr["theta"], tr["stats"]
        if committed < num_samples:  # on_chunk stopped the run early
            theta = theta[:, :committed]
            stats = jax.tree.map(lambda l: l[:, :committed], stats)
        if thin > 1:
            theta = theta[:, thin - 1 :: thin]
        total_queries = int(
            np.asarray(jax.device_get(stats.lik_queries), dtype=np.int64).sum()
        )
        results = None
    else:
        theta = stats = None
        total_queries = next(
            (
                results[name]
                for name, col in colls.items()
                if isinstance(col, collectors_lib.QueryBudget)
            ),
            None,
        )
    return Trace(
        theta=theta,
        stats=stats,
        total_queries=total_queries,
        final_state=state,
        algorithm=alg,
        results=results,
    )


def _grown(alg: SamplingAlgorithm) -> SamplingAlgorithm:
    if alg.grow is None:
        raise RuntimeError(
            "capacity overflow reported but the algorithm cannot grow "
            "(buffers already at data size, or a non-growing algorithm "
            "emitted overflow=True)"
        )
    return alg.grow()
