"""Device-resident multi-chain sampling driver.

The chain lives on device end to end, and the chain axis is carried
NATIVELY: a multi-chain run is one chunked ``lax.scan`` whose carry is the
chain-stacked state and whose body applies a chain-batched step — not a
``vmap`` of per-chain scans. Batching the step batches its kernels: the
Pallas kernels coalesce the chain axis into a leading kernel-grid
dimension (one launch for all chains — ``custom_vmap`` rules in
``kernels/*/ops``). Algorithms that provide ``step_chains`` (e.g. the
distributed chain fleet, which shard_maps the chain axis) are dispatched
directly; for the rest the driver batches ``alg.step`` itself.
Each chunk of ``chunk_size`` iterations is one jitted scan, and the only
host synchronization is a single overflow-flag read per chunk. Output is
produced by :mod:`repro.api.collectors` — pure ``(init, update, finalize)``
reductions whose carries thread through the scan, so memory is
O(what-you-ask-for): the default :class:`~repro.api.collectors.FullTrace`
materializes the dense trajectory exactly as before, while a collectors-only
call (online moments, split-R̂, query accounting, …) allocates nothing that
scales with ``num_samples`` — zero per-iteration ``device_get``s, unlike the
legacy host loop (~4 syncs/step).

Exactness under bounded buffers (DESIGN.md §3.1) is preserved at chunk
granularity: the pre-chunk state is kept alive, and if any step in the chunk
overflowed its bright/candidate capacity, the *whole chunk* is re-run from
that saved state with doubled capacities and the identical per-iteration RNG
keys (``fold_in(chain_key, iteration)``), so the realized chain is bitwise
the one an infinite-capacity sampler would have produced. Collector carries
only ever fold *committed* chunks (the fold runs after the overflow check
passes), so every streamed reduction is bitwise capacity/chunk-invariant
too — with no carry rollback needed.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import collectors as collectors_lib
from repro.api.algorithm import SamplingAlgorithm
from repro.core.flymc import StepStats
from repro.kernels import common as kernels_common


# jit cache for the driver's chunk functions, keyed on the algorithm's
# stable function identities plus ``(num_chains, chunk_size, capacity)``
# (and the collector set / chain-batching flag where they shape the trace):
# repeated sample() calls on the same algorithm reuse compiled chunk/init
# executables, and a capacity-doubling overflow re-run re-traces ONLY the
# chain scan at the grown capacity — the committed-chunk fold is keyed
# capacity-independently (chunk outputs are O(cs) θ/stats, no buffer-shaped
# operands), so an overflow retry never recompiles it. Collectors hash by
# identity, so reusing collector instances across calls is what makes the
# cache hit. LRU-bounded: entries keep the algorithm's closed-over data
# arrays alive, so stale algorithms must age out (and hot ones must not be
# mass-evicted).
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 64

# The back-compat default collector set: one shared instance so repeated
# sample() calls without collectors= hit the same compiled chunk fn.
_DEFAULT_TRACE = collectors_lib.FullTrace()


def _cached(key, build):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        while len(_JIT_CACHE) >= _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
        fn = _JIT_CACHE[key] = build()
    else:
        _JIT_CACHE.move_to_end(key)
    return fn


class Trace(NamedTuple):
    """Everything one `sample()` call produced.

    theta         : (num_chains, num_samples // thin, *theta_shape) — the
                    ``theta[thin - 1 :: thin]`` slice of the per-iteration
                    trajectory, i.e. entry ``i`` is iteration
                    ``(i + 1)·thin - 1`` (the LAST iteration of each thin
                    window, not the first), and a trailing partial window
                    contributes nothing. None when ``collectors=`` was given
                    (ask for a FullTrace/ThinnedTrace collector instead).
    stats         : StepStats with (num_chains, num_samples) leaves
                    (unthinned); None when ``collectors=`` was given
    total_queries : int — total per-datum likelihood evaluations, all chains
                    (an int64 total: per-step counts are int32 and would wrap
                    at paper scale, e.g. N=1.8M × slice × 1200 iters ≈ 2.6e10
                    > 2^31). From the on-device QueryBudget collector when one
                    was passed; from a host-side sum over materialized stats
                    on the default path; None otherwise.
    final_state   : chain state pytree (leading chain axis iff num_chains > 1),
                    suitable for resuming via sample(..., init_state=...)
    algorithm     : the (possibly capacity-grown) SamplingAlgorithm
    results       : {name: finalized result} for the ``collectors=`` dict
                    passed in; None on the default (FullTrace) path
    """

    theta: jax.Array | None
    stats: StepStats | None
    total_queries: Any
    final_state: Any
    algorithm: SamplingAlgorithm
    results: dict | None = None


def _broadcast_positions(position, num_chains: int, reference):
    """Give every chain a starting position: accepts one position (shared)
    or a pytree with a leading (num_chains, ...) axis. ``reference`` (the
    algorithm's default position) disambiguates the two when shapes collide."""
    shape_of = lambda tree: jax.tree.map(jnp.shape, tree)
    if reference is not None and shape_of(position) == shape_of(reference):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)),
            position,
        )
    leaves = jax.tree.leaves(position)
    if leaves and all(
        hasattr(l, "shape") and l.shape[:1] == (num_chains,) for l in leaves
    ):
        return position
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)), position
    )


def _identity(state):
    return state


def _capacity_of(alg: SamplingAlgorithm):
    spec = alg.spec
    return (getattr(spec, "capacity", None), getattr(spec, "cand_capacity", None))


def _make_scan_fn(alg: SamplingAlgorithm, num_chains: int, cs: int):
    """One jitted chunk of the chain: cs steps, carrying the chain-stacked
    state natively when num_chains > 1 (one scan whose body is the
    chain-batched step — no per-chain scans). Emits the per-step
    (θ, StepStats) as chunk-local O(cs) scan outputs (time axis leading,
    chain axis second) plus (final_state, any_overflow)."""
    multi = num_chains > 1
    if multi:
        step = alg.batched_step()
        fold_keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))
        position = jax.vmap(alg.position_of)
    else:
        step, fold_keys, position = (
            alg.step, jax.random.fold_in, alg.position_of
        )

    def chunk(state, keys, start):
        def body(carry, i):
            new_state, info = step(fold_keys(keys, i), carry)
            return new_state, (position(new_state), info)

        iters = start + jnp.arange(cs, dtype=jnp.int32)
        final, (pos, infos) = jax.lax.scan(body, state, iters)
        return final, pos, infos, jnp.any(infos.overflow)

    return jax.jit(chunk)


def _make_fold_fn(colls: dict, multi: bool):
    """Fold one COMMITTED chunk's (θ, StepStats) outputs into the collector
    carries, in step order. The chunk outputs arrive time-major
    ((cs, K, ...) for multi); the fold is one scan over the time axis whose
    body batches each collector's per-chain ``update`` over the chain axis,
    so the carries keep their leading (K, ...) layout.

    A separate jit from the chain scan for two reasons: (a) it runs only
    after the chunk's overflow check passes, so an overflowed chunk never
    touches collector state and capacity re-runs need no carry rollback —
    and its cache key is capacity-independent, so a capacity-doubling
    re-run never recompiles it; (b) the carry argument is donated (where
    the backend supports input-output aliasing), so a trace-type
    collector's O(num_samples) buffer is updated in place instead of being
    copied at every chunk boundary.
    """
    names = tuple(colls)
    updates = {
        n: (jax.vmap(colls[n].update) if multi else colls[n].update)
        for n in names
    }

    def fold(carries, pos, infos):
        def body(cars, x):
            p, inf = x
            return {n: updates[n](cars[n], p, inf) for n in names}, None

        cars, _ = jax.lax.scan(body, carries, (pos, infos))
        return cars

    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(fold, donate_argnums=donate)


def sample(
    alg: SamplingAlgorithm,
    key: jax.Array,
    num_samples: int,
    *,
    num_chains: int = 1,
    thin: int = 1,
    chunk_size: int = 128,
    init_position=None,
    init_state=None,
    collectors: dict | None = None,
) -> Trace:
    """Run ``num_samples`` iterations of ``alg`` on device; return a Trace.

    ``init_position`` seeds ``alg.init`` (default: ``alg.default_position``);
    pass a (num_chains, ...) array for per-chain starts. ``init_state``
    resumes from an existing chain state instead — single chain, or
    ``num_chains > 1`` with a leading-axis state (e.g. a previous multi-chain
    run's ``final_state``) — using ``key`` as the per-iteration key root with
    the fold-in counter offset by the state's ``iteration``: resuming with
    the prefix's key continues its exact stream (split == contiguous,
    bitwise) instead of replaying it.

    ``num_chains > 1`` runs the chains inside ONE chunked scan over
    chain-stacked state: the step is the algorithm's ``step_chains`` when it
    has one (the distributed fleet's shard_maps the chain axis), else
    ``alg.step`` batched here — each Pallas kernel then dispatches as a
    single launch with a leading chain grid dimension. Either way the
    realized trajectories are bitwise those of per-chain execution with
    keys ``split(key, num_chains)``.

    ``collectors`` maps names to :mod:`repro.api.collectors` instances; their
    ``update`` runs inside the jitted chunk scans (batched over the chain
    axis) and their finalized results land on ``Trace.results``. Without it,
    the default :class:`~repro.api.collectors.FullTrace` reproduces the dense
    ``Trace.theta``/``Trace.stats`` bitwise; with it, nothing O(num_samples)
    is materialized unless a trace collector asks for it. ``thin`` keeps
    every thin-th θ sample on the default path (the last of each window;
    stats stay per-iteration) — with explicit collectors use
    :class:`~repro.api.collectors.ThinnedTrace` instead. Host syncs: one per
    chunk (plus one at resume).
    """
    if num_samples <= 0:
        raise ValueError("num_samples must be positive")
    if num_chains < 1:
        raise ValueError("num_chains must be >= 1")
    chunk_size = max(1, min(int(chunk_size), num_samples))
    multi = num_chains > 1

    if collectors is None:
        colls = {"trace": _DEFAULT_TRACE}
        default_path = True
    else:
        if thin != 1:
            raise ValueError(
                "thin applies to the default trace only; with collectors= "
                "use ThinnedTrace(thin) instead"
            )
        colls = collectors_lib.validate_collectors(collectors)
        default_path = False

    start_offset = 0
    if init_state is not None:
        state = init_state
        if multi:
            leading = {
                jnp.shape(l)[:1] for l in jax.tree.leaves(state)
            }
            if leading != {(num_chains,)}:
                raise ValueError(
                    f"init_state resume with num_chains={num_chains} needs a "
                    f"state with a leading ({num_chains},) chain axis on "
                    f"every leaf (e.g. a previous multi-chain final_state)"
                )
        # Resume must NOT replay the prefix's key stream: per-iteration keys
        # are fold_in(chain_key, iteration), so a resumed segment continues
        # the counter at the state's iteration instead of restarting at 0.
        # With the same chain key, split runs are bitwise identical to one
        # contiguous run; with a fresh key, the segment is at least not a
        # replay of the original run's randomness. One host sync, up front.
        it = getattr(state, "iteration", None)
        if it is not None:
            vals = np.asarray(jax.device_get(it))
            if vals.ndim and not (vals == vals.flat[0]).all():
                raise ValueError(
                    "init_state chains are at different iterations "
                    f"({vals.tolist()}); resume needs a uniform offset"
                )
            start_offset = int(vals.flat[0] if vals.ndim else vals)
        k_steps = key
    else:
        k_init, k_steps = jax.random.split(key)
        position = init_position if init_position is not None else alg.default_position
        if position is None:
            raise ValueError(
                "no init_position given and the algorithm has no default"
            )
        def init_fn(alg):
            build = lambda: jax.jit(alg.batched_init() if multi else alg.init)
            return _cached(
                ("init", alg.init, alg.init_chains, multi), build
            )

        if multi:
            init_keys = jax.random.split(k_init, num_chains)
            positions = _broadcast_positions(
                position, num_chains, alg.default_position
            )
            state = init_fn(alg)(init_keys, positions)
        else:
            state = init_fn(alg)(k_init, position)
        # Grow until the initial bright set fits (deterministic re-init from
        # the same keys) — one host sync, before any sampling starts.
        while alg.init_overflow is not None and bool(
            jax.device_get(
                jnp.any(
                    (jax.vmap(alg.init_overflow) if multi else alg.init_overflow)(
                        state
                    )
                )
            )
        ):
            alg = _grown(alg)
            if multi:
                state = init_fn(alg)(init_keys, positions)
            else:
                state = init_fn(alg)(k_init, position)

    chain_keys = jax.random.split(k_steps, num_chains) if multi else k_steps

    # Collector carries, built from shape/dtype structs only (no compute):
    # one carry per chain, broadcast over the leading chain axis.
    pos_struct, stats_struct = alg.output_structs(
        jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                jnp.shape(l)[1:] if multi else jnp.shape(l), l.dtype
            ),
            state,
        )
    )
    carries = {
        name: col.init(num_samples, pos_struct, stats_struct)
        for name, col in colls.items()
    }
    if multi:
        carries = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_chains,) + l.shape), carries
        )

    def scan_fn_for(alg, cs):
        # Keyed on (num_chains, chunk_size, capacity) plus the step/dispatch
        # identities: an overflow re-run at a grown capacity traces its own
        # entry, and a later sample() call that reaches the same capacity
        # (memoized alg.grow() → same step identity) reuses it.
        return _cached(
            ("scan", alg.step, alg.step_chains, alg.position, num_chains,
             cs, _capacity_of(alg), kernels_common.chain_batching_enabled()),
            lambda: _make_scan_fn(alg, num_chains, cs),
        )

    # Capacity-independent on purpose: chunk outputs are (cs, K) θ/stats
    # with no buffer-shaped operand, so one fold serves every capacity and
    # an overflow retry never recompiles it.
    fold_fn = _cached(
        ("fold", tuple(colls.items()), multi),
        lambda: _make_fold_fn(colls, multi),
    )

    start = 0
    while start < num_samples:
        cs = min(chunk_size, num_samples - start)
        # Keep the pre-chunk state alive for the exact re-run on overflow.
        prev = state
        final, pos, infos, overflow = scan_fn_for(alg, cs)(
            state, chain_keys, jnp.int32(start_offset + start)
        )
        while bool(jax.device_get(overflow)):  # the chunk's one host sync
            alg = _grown(alg)
            resize = alg.resize if alg.resize is not None else _identity
            prev = _cached(
                ("resize", resize, multi),
                lambda: jax.jit(jax.vmap(resize) if multi else resize),
            )(prev)
            final, pos, infos, overflow = scan_fn_for(alg, cs)(
                prev, chain_keys, jnp.int32(start_offset + start)
            )
        # Only a committed (non-overflowed) chunk reaches the collectors, so
        # capacity re-runs never need a carry rollback; the donated carry is
        # updated in place on backends with input-output aliasing.
        if colls:
            carries = fold_fn(carries, pos, infos)
        state = final
        start += cs

    # finalize() always sees a leading (num_chains, ...) carry axis.
    if not multi:
        carries = jax.tree.map(lambda l: l[None], carries)
    results = {name: colls[name].finalize(carries[name]) for name in colls}

    if default_path:
        tr = results["trace"]
        theta, stats = tr["theta"], tr["stats"]
        if thin > 1:
            theta = theta[:, thin - 1 :: thin]
        total_queries = int(
            np.asarray(jax.device_get(stats.lik_queries), dtype=np.int64).sum()
        )
        results = None
    else:
        theta = stats = None
        total_queries = next(
            (
                results[name]
                for name, col in colls.items()
                if isinstance(col, collectors_lib.QueryBudget)
            ),
            None,
        )
    return Trace(
        theta=theta,
        stats=stats,
        total_queries=total_queries,
        final_state=state,
        algorithm=alg,
        results=results,
    )


def _grown(alg: SamplingAlgorithm) -> SamplingAlgorithm:
    if alg.grow is None:
        raise RuntimeError(
            "capacity overflow reported but the algorithm cannot grow "
            "(buffers already at data size, or a non-growing algorithm "
            "emitted overflow=True)"
        )
    return alg.grow()
