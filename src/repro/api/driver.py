"""Device-resident multi-chain sampling driver.

The chain lives on device end to end: each chunk of ``chunk_size``
iterations is one jitted ``lax.scan`` (``vmap``'d over chains), and the only
host synchronization is a single overflow-flag read per chunk. Samples and
per-step stats accumulate as device arrays and are concatenated once at the
end — zero per-iteration ``device_get``s, unlike the legacy host loop
(~4 syncs/step).

Exactness under bounded buffers (DESIGN.md §3.1) is preserved at chunk
granularity: the pre-chunk state is kept alive, and if any step in the chunk
overflowed its bright/candidate capacity, the *whole chunk* is re-run from
that saved state with doubled capacities and the identical per-iteration RNG
keys (``fold_in(chain_key, iteration)``), so the realized chain is bitwise
the one an infinite-capacity sampler would have produced.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.algorithm import SamplingAlgorithm
from repro.core.flymc import StepStats


# jit cache keyed on the algorithm's stable function identities: repeated
# sample() calls on the same algorithm (or the same grown capacity) reuse
# compiled chunk/init executables instead of re-tracing fresh closures.
# LRU-bounded: entries keep the algorithm's closed-over data arrays alive,
# so stale algorithms must age out (and hot ones must not be mass-evicted).
_JIT_CACHE: OrderedDict = OrderedDict()
_JIT_CACHE_MAX = 64


def _cached(key, build):
    fn = _JIT_CACHE.get(key)
    if fn is None:
        while len(_JIT_CACHE) >= _JIT_CACHE_MAX:
            _JIT_CACHE.popitem(last=False)
        fn = _JIT_CACHE[key] = build()
    else:
        _JIT_CACHE.move_to_end(key)
    return fn


class Trace(NamedTuple):
    """Everything one `sample()` call produced, as stacked device arrays.

    theta         : (num_chains, num_samples // thin, *theta_shape) — the
                    ``theta[thin - 1 :: thin]`` slice of the per-iteration
                    trajectory, i.e. entry ``i`` is iteration
                    ``(i + 1)·thin - 1`` (the LAST iteration of each thin
                    window, not the first), and a trailing partial window
                    contributes nothing
    stats         : StepStats with (num_chains, num_samples) leaves (unthinned)
    total_queries : int — total per-datum likelihood evaluations, all chains
                    (a host int64 sum: per-step counts are int32 and an
                    on-device total would wrap at paper scale, e.g.
                    N=1.8M × slice × 1200 iters ≈ 2.6e10 > 2^31)
    final_state   : chain state pytree (leading chain axis iff num_chains > 1),
                    suitable for resuming via sample(..., init_state=...)
    algorithm     : the (possibly capacity-grown) SamplingAlgorithm
    """

    theta: jax.Array
    stats: StepStats
    total_queries: jax.Array
    final_state: Any
    algorithm: SamplingAlgorithm


def _broadcast_positions(position, num_chains: int, reference):
    """Give every chain a starting position: accepts one position (shared)
    or a pytree with a leading (num_chains, ...) axis. ``reference`` (the
    algorithm's default position) disambiguates the two when shapes collide."""
    shape_of = lambda tree: jax.tree.map(jnp.shape, tree)
    if reference is not None and shape_of(position) == shape_of(reference):
        return jax.tree.map(
            lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)),
            position,
        )
    leaves = jax.tree.leaves(position)
    if leaves and all(
        hasattr(l, "shape") and l.shape[:1] == (num_chains,) for l in leaves
    ):
        return position
    return jax.tree.map(
        lambda l: jnp.broadcast_to(l, (num_chains,) + jnp.shape(l)), position
    )


def sample(
    alg: SamplingAlgorithm,
    key: jax.Array,
    num_samples: int,
    *,
    num_chains: int = 1,
    thin: int = 1,
    chunk_size: int = 128,
    init_position=None,
    init_state=None,
) -> Trace:
    """Run ``num_samples`` iterations of ``alg`` on device; return a Trace.

    ``init_position`` seeds ``alg.init`` (default: ``alg.default_position``);
    pass a (num_chains, ...) array for per-chain starts. ``init_state``
    resumes from an existing chain state instead (single chain only), using
    ``key`` as the per-iteration key root with the fold-in counter offset by
    the state's ``iteration`` — resuming with the prefix's key continues its
    exact stream (split == contiguous, bitwise) instead of replaying it.
    ``thin`` keeps every thin-th θ sample (the last of each window); stats
    stay per-iteration. Host syncs: one per chunk (plus one at resume).
    """
    if num_samples <= 0:
        raise ValueError("num_samples must be positive")
    if num_chains < 1:
        raise ValueError("num_chains must be >= 1")
    chunk_size = max(1, min(int(chunk_size), num_samples))
    multi = num_chains > 1

    start_offset = 0
    if init_state is not None:
        if multi:
            raise ValueError("init_state resume supports num_chains=1 only")
        state = init_state
        k_steps = key
        # Resume must NOT replay the prefix's key stream: per-iteration keys
        # are fold_in(chain_key, iteration), so a resumed segment continues
        # the counter at the state's iteration instead of restarting at 0.
        # With the same chain key, split runs are bitwise identical to one
        # contiguous run; with a fresh key, the segment is at least not a
        # replay of the original run's randomness. One host sync, up front.
        it = getattr(state, "iteration", None)
        if it is not None:
            start_offset = int(jax.device_get(it))
    else:
        k_init, k_steps = jax.random.split(key)
        position = init_position if init_position is not None else alg.default_position
        if position is None:
            raise ValueError(
                "no init_position given and the algorithm has no default"
            )
        def init_fn(alg):
            return _cached(
                ("init", alg.init, multi),
                lambda: jax.jit(jax.vmap(alg.init) if multi else alg.init),
            )

        if multi:
            init_keys = jax.random.split(k_init, num_chains)
            positions = _broadcast_positions(
                position, num_chains, alg.default_position
            )
            state = init_fn(alg)(init_keys, positions)
        else:
            state = init_fn(alg)(k_init, position)
        # Grow until the initial bright set fits (deterministic re-init from
        # the same keys) — one host sync, before any sampling starts.
        while alg.init_overflow is not None and bool(
            jax.device_get(
                jnp.any(
                    (jax.vmap(alg.init_overflow) if multi else alg.init_overflow)(
                        state
                    )
                )
            )
        ):
            alg = _grown(alg)
            if multi:
                state = init_fn(alg)(init_keys, positions)
            else:
                state = init_fn(alg)(k_init, position)

    chain_keys = jax.random.split(k_steps, num_chains) if multi else k_steps

    def make_chunk_fn(alg: SamplingAlgorithm, cs: int):
        def scan_chain(state, chain_key, start):
            def body(carry, i):
                new_state, info = alg.step(
                    jax.random.fold_in(chain_key, i), carry
                )
                return new_state, (alg.position_of(new_state), info)

            iters = start + jnp.arange(cs, dtype=jnp.int32)
            return jax.lax.scan(body, state, iters)

        def chunk(state, keys, start):
            if multi:
                final, (th, inf) = jax.vmap(
                    scan_chain, in_axes=(0, 0, None)
                )(state, keys, start)
            else:
                final, (th, inf) = scan_chain(state, keys, start)
            return final, th, inf, jnp.any(inf.overflow)

        return jax.jit(chunk)

    def chunk_fn_for(alg, cs):
        return _cached(
            ("chunk", alg.step, alg.position, multi, cs),
            lambda: make_chunk_fn(alg, cs),
        )

    thetas, infos = [], []
    start = 0
    while start < num_samples:
        cs = min(chunk_size, num_samples - start)
        chunk_fn = chunk_fn_for(alg, cs)
        # Keep the pre-chunk state alive for the exact re-run on overflow.
        prev = state
        final, th, inf, overflow = chunk_fn(
            state, chain_keys, jnp.int32(start_offset + start)
        )
        while bool(jax.device_get(overflow)):  # the chunk's one host sync
            alg = _grown(alg)
            resize = alg.resize if alg.resize is not None else (lambda s: s)
            prev = _cached(
                ("resize", resize, multi),
                lambda: jax.jit(jax.vmap(resize) if multi else resize),
            )(prev)
            final, th, inf, overflow = chunk_fn_for(alg, cs)(
                prev, chain_keys, jnp.int32(start_offset + start)
            )
        state = final
        thetas.append(th)
        infos.append(inf)
        start += cs

    t_axis = 1 if multi else 0
    theta = jnp.concatenate(thetas, axis=t_axis) if len(thetas) > 1 else thetas[0]
    stats = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=t_axis) if len(xs) > 1 else xs[0],
        *infos,
    )
    if not multi:
        theta = theta[None]
        stats = jax.tree.map(lambda a: a[None], stats)
    if thin > 1:
        theta = theta[:, thin - 1 :: thin]
    total_queries = int(
        np.asarray(jax.device_get(stats.lik_queries), dtype=np.int64).sum()
    )
    return Trace(
        theta=theta,
        stats=stats,
        total_queries=total_queries,
        final_state=state,
        algorithm=alg,
    )


def _grown(alg: SamplingAlgorithm) -> SamplingAlgorithm:
    if alg.grow is None:
        raise RuntimeError(
            "capacity overflow reported but the algorithm cannot grow "
            "(buffers already at data size, or a non-growing algorithm "
            "emitted overflow=True)"
        )
    return alg.grow()
