"""Sampling algorithms as pure ``(init, step)`` pairs.

:func:`firefly` builds the paper's exact-subset chain; :func:`regular_mcmc`
the full-data baseline. Both return a :class:`SamplingAlgorithm` whose
``step`` emits :class:`~repro.core.flymc.StepStats` — the same Info pytree —
so the :mod:`repro.api.driver` treats them identically.

Kernels are resolved through :data:`repro.core.samplers.KERNEL_REGISTRY`
(no stringly-typed special cases) and bounds through
:data:`repro.core.bounds.BOUND_REGISTRY` (explicit :class:`Bound` protocol).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bounds as bounds_lib
from repro.core import flymc, samplers
from repro.core.bounds import CollapsedStats, GLMData
from repro.core.flymc import FlyMCSpec, StepStats


@dataclasses.dataclass(frozen=True)
class SamplingAlgorithm:
    """A pure (init, step) pair plus the hooks the driver needs.

    init(key, position) -> State
    step(key, state)    -> (State, StepStats)

    ``step_chains``/``init_chains`` are the optional chain-batched
    counterparts — ``step_chains(keys (K,), state (K, ...))`` advances all
    K chains in one application. The driver dispatches them directly when
    ``num_chains > 1``; when None it batches the per-chain functions
    itself, which is already optimal for single-device algorithms — the
    Pallas kernels coalesce the chain axis into one leading-grid-dimension
    launch under batching regardless (``custom_vmap`` rules in
    ``kernels/*/ops``). Provide them only when batching must be something
    other than vmap: :func:`repro.distributed.flymc_dist.chain_fleet`
    supplies a pair that shard_maps the chain axis across devices.

    ``grow``/``resize``/``init_overflow`` exist only for algorithms with
    bounded on-device buffers (FlyMC's bright capacity): ``grow()`` returns
    the same algorithm with doubled capacities, ``resize(state)`` re-shapes a
    state for the grown buffers without new likelihood queries, and
    ``init_overflow(state)`` flags an initial state that does not fit. All
    three are None for algorithms that cannot overflow.

    ``step_data``/``data``/``stats`` are the dataset-as-operand form of the
    step: ``step_data(key, state, data, stats)`` is ``step`` with the
    dataset and its sufficient statistics passed as arguments instead of
    closed over. When present, the driver threads ``alg.data``/``alg.stats``
    through the jitted chunk as traced operands rather than baking them in
    as compile-time constants. ``step_chains_data`` is the chain-batched
    counterpart (``(keys (K,), state (K, ...), data, stats)``) for
    algorithms whose batching is not vmap — the distributed fleet supplies
    one that shard_maps the chain axis with the dataset replicated as an
    operand, so even a sharded fleet's chunk jit carries no dataset
    constant (the :mod:`repro.analysis` closure-constant rule pins this). This is a bitwise-visible choice, not a
    plumbing detail: XLA's constant folding rounds data-dependent
    reductions differently for a baked-in dataset than for the identical
    values passed as an operand (low-bit ``joint_lp``/``accept_prob``
    differences on CPU, observed at e.g. N=512, D=8). The operand form is
    the ONE form shared by solo runs and the :mod:`repro.serve` group
    engines — whose lanes must take data as operands to pack jobs into a
    shared executable — which is what makes a packed job's trajectory
    bitwise its solo run's.
    """

    init: Callable[[jax.Array, Any], Any]
    step: Callable[[jax.Array, Any], tuple[Any, StepStats]]
    grow: Callable[[], "SamplingAlgorithm"] | None = None
    resize: Callable[[Any], Any] | None = None
    init_overflow: Callable[[Any], jax.Array] | None = None
    position: Callable[[Any], jax.Array] | None = None
    default_position: Any = None
    spec: Any = None  # engine config (e.g. FlyMCSpec), for introspection
    step_chains: Callable[[jax.Array, Any], tuple[Any, StepStats]] | None = None
    init_chains: Callable[[jax.Array, Any], Any] | None = None
    step_data: Callable[..., tuple[Any, StepStats]] | None = None
    step_chains_data: Callable[..., tuple[Any, StepStats]] | None = None
    data: Any = None
    stats: Any = None

    def position_of(self, state) -> jax.Array:
        if self.position is not None:
            return self.position(state)
        return state.sampler.theta

    def batched_step(self):
        """The chain-batched step: (keys (K,), state (K, ...)) -> same.

        ``step_chains`` when provided, else ``step`` batched over the
        chain axis — the ONE encoding of this fallback (driver and fleet
        wrappers both call it), under which the Pallas kernels coalesce
        into a single chain-grid launch via their custom_vmap rules.
        """
        if self.step_chains is not None:
            return self.step_chains
        return jax.vmap(self.step)

    def batched_init(self):
        """Chain-batched init: ``init_chains`` or ``init`` batched."""
        if self.init_chains is not None:
            return self.init_chains
        return jax.vmap(self.init)

    def output_structs(self, state):
        """Shape/dtype structs of one chain's per-step outputs, no compute.

        Returns ``(position_struct, stats_struct)`` — ``jax.ShapeDtypeStruct``
        pytrees for ``position_of(state)`` and the ``StepStats`` that ``step``
        emits. ``state`` may be a concrete single-chain state or itself a
        struct pytree; everything runs under ``jax.eval_shape``. This is what
        lets :mod:`repro.api.collectors` size their carries before the first
        step executes.
        """
        key = jax.eval_shape(lambda: jax.random.key(0))
        pos = jax.eval_shape(self.position_of, state)
        _, stats = jax.eval_shape(self.step, key, state)
        return pos, stats


def _spec_from(
    model,
    *,
    bound,
    log_prior,
    data,
    stats,
    kernel,
    capacity,
    cand_capacity,
    q_db,
    mode,
    resample_fraction,
    adapt_target,
    kernel_params,
    axis_names,
    backend,
    z_backend,
    num_warmup,
):
    """Normalize (model | explicit pieces) into (FlyMCSpec, data, stats)."""
    if model is not None:
        bound = bound if bound is not None else model.bound
        log_prior = log_prior if log_prior is not None else model.log_prior
        data = data if data is not None else model.data
        stats = stats if stats is not None else getattr(model, "stats", None)
    if data is None or log_prior is None or bound is None:
        raise ValueError(
            "firefly() needs a model, or explicit bound=, log_prior=, data="
        )
    bound = bounds_lib.get_bound(bound)
    if backend not in ("jnp", "pallas"):
        raise ValueError(
            f"unknown backend {backend!r}; expected 'jnp' or 'pallas'"
        )
    if backend == "pallas" and bounds_lib.fused_family_of(bound) is None:
        raise ValueError(
            f"backend='pallas' requires a FusedBound "
            f"(fused_family + fused_kernel_kwargs, not invalidated by "
            f"log_lik/log_bound overrides); "
            f"{type(bound).__name__} only implements the jnp path"
        )
    if z_backend not in ("jnp", "fused"):
        raise ValueError(
            f"unknown z_backend {z_backend!r}; expected 'jnp' or 'fused'"
        )
    if z_backend == "fused" and mode != "implicit":
        raise ValueError(
            "z_backend='fused' requires mode='implicit' (the fused engine "
            "streams Algorithm 2's sparse dark→bright candidate proposals; "
            "Algorithm 1's explicit Gibbs resampling has no such stream)"
        )
    if stats is None:
        stats = bound.suffstats(data)
    samplers.get_kernel(kernel)  # fail fast on unknown kernels
    if adapt_target == "auto":
        adapt_target = samplers.get_kernel(kernel).target_accept
        if adapt_target >= 1.0:  # slice: no accept rate to adapt on
            adapt_target = None
    n = data.x.shape[0]
    spec = FlyMCSpec(
        bound=bound,
        log_prior=log_prior,
        kernel=kernel,
        capacity=min(int(capacity), n),
        cand_capacity=min(int(cand_capacity), n),
        q_db=q_db,
        mode=mode,
        resample_fraction=resample_fraction,
        kernel_kwargs=tuple(kernel_params),
        axis_names=tuple(axis_names),
        adapt_target=adapt_target,
        backend=backend,
        z_backend=z_backend,
        num_warmup=int(num_warmup),
    )
    return spec, data, stats


def firefly(
    model=None,
    *,
    bound=None,
    log_prior=None,
    data: GLMData | None = None,
    stats: CollapsedStats | None = None,
    kernel: str = "rwmh",
    capacity: int = 1024,
    cand_capacity: int = 1024,
    q_db: float = 0.01,
    mode: str = "implicit",
    resample_fraction: float = 0.1,
    step_size: float = 0.1,
    adapt_target: float | str | None = None,
    num_warmup: int = 1000,
    kernel_params=(),
    axis_names=(),
    backend: str = "jnp",
    z_backend: str = "jnp",
) -> SamplingAlgorithm:
    """Build the FlyMC sampling algorithm (paper §2–3) as an (init, step) pair.

    ``model`` is anything carrying ``.bound/.log_prior/.data`` (and optionally
    ``.stats``), e.g. :class:`repro.models.bayes_glm.GLMModel`; individual
    pieces can be overridden by keyword. ``bound`` accepts a
    :class:`~repro.core.bounds.Bound` instance or a registered name
    ("logistic", "softmax", "student-t"). ``kernel`` names a registered
    θ-kernel ("rwmh", "mala", "slice", "hmc"); pass ``adapt_target="auto"``
    to adapt the step size toward the kernel's standard accept rate.
    Adaptation runs for the first ``num_warmup`` iterations only — after
    warmup the step size freezes bitwise, so the sampling-phase chain is a
    fixed Markov kernel (exactness requires it).

    ``backend`` selects the θ-update likelihood engine: ``"jnp"`` (gather +
    bound evaluation in plain XLA) or ``"pallas"`` (the fused
    ``kernels/bright_glm`` gather+δ+reduction kernel; interpret-mode
    fallback off-TPU). All three built-in bounds support ``"pallas"``;
    custom bounds need the :class:`~repro.core.bounds.FusedBound` hook.

    ``z_backend`` selects the z-update engine (implicit mode): ``"jnp"``
    (per-datum length-N uniforms + full cumsum re-partition) or ``"fused"``
    (the ``kernels/z_update`` streaming candidate kernel with in-kernel
    counter RNG + O(changed) incremental partition maintenance). The two
    engines are law-equivalent but follow different uniform streams, so
    their realized trajectories differ bitwise.
    """
    spec, data, stats = _spec_from(
        model,
        bound=bound, log_prior=log_prior, data=data, stats=stats,
        kernel=kernel, capacity=capacity, cand_capacity=cand_capacity,
        q_db=q_db, mode=mode, resample_fraction=resample_fraction,
        adapt_target=adapt_target, kernel_params=kernel_params,
        axis_names=axis_names, backend=backend, z_backend=z_backend,
        num_warmup=num_warmup,
    )
    return _firefly_from_spec(spec, data, stats, step_size)


def _firefly_from_spec(
    spec: FlyMCSpec, data: GLMData, stats: CollapsedStats, step_size: float
) -> SamplingAlgorithm:
    n = data.x.shape[0]

    def init(key, position):
        return flymc.init_chain_state(
            spec, data, stats, position, key, step_size=step_size
        )

    def step(key, state):
        # The chain state's rng slot is overwritten with the driver's key so
        # the kernel stays a pure function of (key, state).
        return flymc.flymc_step(spec, data, stats, state._replace(rng=key))

    def step_data(key, state, data_, stats_):
        # The operand-data form the driver and the serve engines both jit
        # (see the SamplingAlgorithm docstring for why the form matters).
        return flymc.flymc_step(spec, data_, stats_, state._replace(rng=key))

    # Memoized: repeated growth (e.g. across sample() calls that hit the
    # same overflow) must yield the *same* algorithm object so the driver's
    # jit cache keys on a stable step identity and never re-traces.
    grown = []

    def grow():
        if not grown:
            grown.append(
                _firefly_from_spec(flymc._grow(spec, n), data, stats, step_size)
            )
        return grown[0]

    def resize(state):
        return flymc.resize_state(spec, state)

    def init_overflow(state):
        return state.bright.num > spec.capacity

    theta_dim = data.x.shape[-1]
    if isinstance(spec.bound, bounds_lib.SoftmaxBound):
        default_position = jnp.zeros((data.xi.shape[-1], theta_dim))
    else:
        default_position = jnp.zeros((theta_dim,))

    can_grow = spec.capacity < n or spec.cand_capacity < n
    return SamplingAlgorithm(
        init=init,
        step=step,
        grow=grow if can_grow else None,
        resize=resize,
        init_overflow=init_overflow,
        default_position=default_position,
        spec=spec,
        step_data=step_data,
        data=data,
        stats=stats,
    )


def algorithm_from_spec(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    step_size: float = 0.1,
) -> SamplingAlgorithm:
    """Wrap a legacy FlyMCSpec as a SamplingAlgorithm (shim entry point)."""
    return _firefly_from_spec(spec, data, stats, step_size)


# ---------------------------------------------------------------------------
# Full-data baseline
# ---------------------------------------------------------------------------


class MCMCState(NamedTuple):
    sampler: samplers.SamplerState
    log_step: jax.Array
    iteration: jax.Array


def regular_mcmc(
    model=None,
    *,
    logdensity_fn=None,
    n_data: int | None = None,
    kernel: str = "rwmh",
    step_size: float = 0.1,
    adapt_target: float | str | None = None,
    num_warmup: int = 1000,
    kernel_params=(),
    theta_shape=None,
) -> SamplingAlgorithm:
    """Full-data MCMC baseline as an (init, step) pair.

    ``model`` supplies the exact log posterior and the likelihood-query
    accounting (every density evaluation costs N queries — Table 1's cost
    model); alternatively pass ``logdensity_fn`` (θ -> (lp, aux)) plus
    ``n_data`` directly. Emits the same StepStats as firefly (overflow is
    always False, n_bright = N) so the driver and diagnostics are shared.
    Step-size adaptation (``adapt_target``) is warmup-only, exactly like
    :func:`firefly`: the update freezes after ``num_warmup`` iterations.
    """
    if model is not None:
        logdensity_fn = logdensity_fn or model.full_logpdf_fn()
        n_data = n_data if n_data is not None else model.data.x.shape[0]
        theta_shape = theta_shape or model.theta_shape
    if logdensity_fn is None or n_data is None:
        raise ValueError("regular_mcmc() needs a model or logdensity_fn + n_data")
    ks = samplers.get_kernel(kernel)
    if adapt_target == "auto":
        adapt_target = None if ks.target_accept >= 1.0 else ks.target_accept
    kern = samplers.bind(kernel, logdensity_fn, kernel_params)
    n = jnp.int32(n_data)

    def init(key, position):
        del key
        st = samplers.init_state(logdensity_fn, position, with_grad=ks.needs_grad)
        return MCMCState(
            sampler=st,
            log_step=jnp.log(jnp.asarray(step_size, st.lp.dtype)),
            iteration=jnp.int32(0),
        )

    def step(key, state):
        new, info = kern(key, state.sampler, jnp.exp(state.log_step))
        log_step = state.log_step
        if adapt_target is not None:
            # Warmup-only (see flymc_step): adapt-forever would mean the
            # post-warmup chain never follows a fixed Markov kernel.
            adapted = samplers.adapt_step_size(
                log_step, info.accept_prob, adapt_target, state.iteration
            )
            log_step = jnp.where(
                state.iteration < num_warmup, adapted, log_step
            )
        out = MCMCState(new, log_step, state.iteration + 1)
        stats = StepStats(
            n_bright=n,
            lik_queries=info.n_evals * n,
            accept_prob=info.accept_prob,
            overflow=jnp.bool_(False),
            joint_lp=new.lp,
        )
        return out, stats

    default_position = (
        jnp.zeros(theta_shape) if theta_shape is not None else None
    )
    return SamplingAlgorithm(
        init=init, step=step, default_position=default_position
    )
