"""Pure-jnp oracle for the streamed z-candidate kernel.

Evaluates the SAME counter-based Threefry draws as the Pallas kernel
(:func:`repro.core.numerics.counter_bits24` — one shared definition) over
the whole partition array at once, then compacts with the familiar cumsum
scatter. This is the O(N)-materializing formulation the kernel replaces;
it exists so interpret-mode parity tests can pin the in-kernel RNG and
compaction bit-for-bit against per-datum reference draws.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.numerics import DRAW_CAND, counter_bits24


def q_threshold_bits(q_db: float) -> int:
    """Static 24-bit integer threshold: bits24 < q_bits ⇔ u < q_db.

    Any positive ``q_db`` maps to a threshold of at least 1 (proposal
    probability 2⁻²⁴): rounding a sub-grid q_db to zero would silently kill
    every dark→bright proposal and break the chain's irreducibility, while
    the jnp engine kept proposing — the worst kind of engine divergence.
    Only ``q_db == 0`` exactly disables proposals.
    """
    q = float(q_db)
    if q <= 0.0:
        return 0
    return min(1 << 24, max(1, int(round(q * (1 << 24)))))


def z_candidates_ref(
    arr: jnp.ndarray,  # (N,) int32 partition array
    num: jnp.ndarray,  # () int32 bright count (arr[:num] bright)
    key_words: jnp.ndarray,  # (2,) int32 counter-RNG key words
    q_db: float,
    cand_capacity: int,
):
    """Returns (cand (cand_capacity,) int32 padded with N, n_cand ())."""
    n = arr.shape[0]
    pos = jnp.arange(n, dtype=jnp.int32)
    bits24 = counter_bits24(key_words, DRAW_CAND, arr)
    cand = (pos >= num) & (bits24 < q_threshold_bits(q_db))
    n_cand = jnp.sum(cand).astype(jnp.int32)
    dest = jnp.where(cand, jnp.cumsum(cand) - 1, cand_capacity)
    out = (
        jnp.full(cand_capacity, n, jnp.int32)
        .at[dest]
        .set(arr.astype(jnp.int32), mode="drop")
    )
    return out, n_cand
