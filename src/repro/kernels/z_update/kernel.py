"""Pallas TPU kernel: streamed dark-set candidate selection (FlyMC z-update).

Algorithm 2's dark→bright proposal is a Bernoulli(q_db) per dark datum —
the only part of the z-update whose work is inherently Ω(N). The jnp
engine pays for it with three materialized (N,) uniform arrays, an (N,)
boolean z, and a full cumsum compaction; this kernel replaces all of that
with ONE streamed pass over the partition array:

  * ``arr`` (reshaped to (P/128, 128) int32 lane tiles) is the only
    length-N operand that moves — 4 bytes per datum, delivered by the
    pipelined grid in ``(block_rows, 128)`` tiles;
  * per-datum uniforms are generated *in-kernel* with counter-based
    Threefry-2x32 bits keyed on (step_key, DRAW_CAND, datum_index)
    (:mod:`repro.core.numerics` — the same function the jnp reference
    evaluates, so the streams are bit-identical). Keying on the datum
    index, not the buffer slot, keeps the realized chain bitwise invariant
    to capacity and chunk size, exactly like the jnp engine's per-datum
    draws;
  * candidate selection compares the 24-bit lanes against a static integer
    threshold ``q_bits = round(q_db · 2²⁴)`` — pure int compare, no float
    round-trip;
  * selected datum ids are compacted in-kernel into a
    ``(cand_capacity_padded, 1)`` output buffer: TPU grid steps run
    sequentially, so the buffer and a (1, 1) running count are race-free
    accumulators (the same trick as ``bright_glm``'s total). Within a tile
    the expected candidate count is ``q_db · block`` (≈ 10 for the default
    tile), so extraction loops ``fori_loop``-many times over a masked
    argmin — O(candidates) reductions, not O(block²) scatter matrices.

Chain batching: the grid's LEADING dimension is ``num_chains`` — one
launch streams every chain's partition array back to back, and the
counter-RNG keying gains its chain lane through the per-chain
``(num, key_word0, key_word1)`` rows of the scalar-prefetched ``meta``
operand: each chain keeps the exact per-chain key words the vmap path
derived from its own chain key, so trajectories stay bitwise identical to
per-chain dispatch. :func:`z_candidates_pallas` is the single-chain entry
point — the ``num_chains == 1`` case of :func:`z_candidates_pallas_chains`.

The kernel emits only the compacted candidate ids + total count; the δ
evaluation for those candidates is the job of the *existing* FusedBound
machinery (``kernels/bright_glm``) on the O(cand_capacity) buffer, and the
darken/brighten accept decisions are O(C) jnp math on the same counter RNG
(:func:`repro.core.flymc._fused_z_update`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import DRAW_CAND, threefry2x32

_LANES = 128
_UNIFORM_SHIFT = 8  # int32 >> 8 (logical) = 24-bit uniform lanes


def z_candidates_pallas_chains(
    arr3d: jax.Array,  # (K, P//128, 128) int32 partition arrays, padded w/ n
    meta: jax.Array,  # (K, 3) int32 rows: [num, key_word0, key_word1]
    n: int,  # true datum count (ids >= n are padding)
    q_bits: int,  # candidate threshold: bits24 < q_bits ⇔ u < q_db
    cand_cap_padded: int,  # output buffer rows (>= cand_capacity, mult. of 8)
    block_rows: int = 8,
    interpret: bool = False,
):
    """Returns (cand (K, cand_cap_padded, 1) int32 padded with n,
    count (K, 1, 1)).

    Candidates appear in ``arr``-position order per chain (the same order
    the jnp reference's cumsum compaction produces). Writes past a chain's
    padded buffer are dropped, and ``count`` keeps each chain's *true*
    total so the caller can raise the overflow flag that triggers the
    driver's capacity-doubling re-run.
    """
    k_chains, rows, lanes = arr3d.shape
    assert lanes == _LANES and rows % block_rows == 0, arr3d.shape
    assert meta.shape == (k_chains, 3), meta.shape
    br = block_rows

    def kernel(meta_ref, arr_ref, cand_ref, count_ref):
        ch = pl.program_id(0)
        i = pl.program_id(1)
        num = meta_ref[ch, 0]

        @pl.when(i == 0)
        def _init():
            cand_ref[...] = jnp.full_like(cand_ref, n)
            count_ref[0, 0, 0] = 0

        tile = arr_ref[0]  # (br, 128) datum ids of this chain
        row = jax.lax.broadcasted_iota(jnp.int32, (br, _LANES), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (br, _LANES), 1)
        pos = (i * br + row) * _LANES + col  # position in this chain's arr

        x0 = jnp.full((br, _LANES), DRAW_CAND, jnp.int32)
        bits, _ = threefry2x32(meta_ref[ch, 1], meta_ref[ch, 2], x0, tile)
        bits24 = jax.lax.shift_right_logical(bits, _UNIFORM_SHIFT)
        cand = (pos >= num) & (pos < n) & (bits24 < q_bits)

        cnt_tile = jnp.sum(cand.astype(jnp.int32))
        base = count_ref[0, 0, 0]

        def extract(j, live):
            # j-th candidate of this tile = masked position-argmin sweep.
            p = jnp.min(jnp.where(live, pos, jnp.int32(2**30)))
            datum = jnp.sum(jnp.where(live & (pos == p), tile, 0))
            slot = base + j

            @pl.when(slot < cand_cap_padded)
            def _store():
                cand_ref[0, slot, 0] = datum

            return live & (pos != p)

        jax.lax.fori_loop(0, cnt_tile, extract, cand)
        count_ref[0, 0, 0] = base + cnt_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # meta
        grid=(k_chains, rows // br),
        in_specs=[pl.BlockSpec((1, br, _LANES), lambda ch, i, *_: (ch, i, 0))],
        out_specs=[
            pl.BlockSpec((1, cand_cap_padded, 1), lambda ch, i, *_: (ch, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda ch, i, *_: (ch, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((k_chains, cand_cap_padded, 1), jnp.int32),
            jax.ShapeDtypeStruct((k_chains, 1, 1), jnp.int32),
        ),
        cost_estimate=pl.CostEstimate(
            flops=50 * k_chains * rows * _LANES,  # ~threefry rounds per lane
            bytes_accessed=k_chains * (rows * _LANES * 4
                                       + cand_cap_padded * 4),
            transcendentals=0,
        ),
        interpret=interpret,
    )(meta, arr3d)


def z_candidates_pallas(
    arr2d: jax.Array,  # (P//128, 128) int32 partition array, padded with n
    meta: jax.Array,  # (3,) int32: [num, key_word0, key_word1]
    n: int,  # true datum count (ids >= n are padding)
    q_bits: int,  # candidate threshold: bits24 < q_bits ⇔ u < q_db
    cand_cap_padded: int,  # output buffer rows (>= cand_capacity, mult. of 8)
    block_rows: int = 8,
    interpret: bool = False,
):
    """Single-chain entry point: the ``num_chains == 1`` case of
    :func:`z_candidates_pallas_chains`. Returns
    (cand (cand_cap_padded, 1) int32 padded with n, count (1, 1))."""
    cand, count = z_candidates_pallas_chains(
        arr2d[None], meta[None], n=n, q_bits=q_bits,
        cand_cap_padded=cand_cap_padded, block_rows=block_rows,
        interpret=interpret,
    )
    return cand[0], count[0]
