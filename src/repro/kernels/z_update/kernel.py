"""Pallas TPU kernel: streamed dark-set candidate selection (FlyMC z-update).

Algorithm 2's dark→bright proposal is a Bernoulli(q_db) per dark datum —
the only part of the z-update whose work is inherently Ω(N). The jnp
engine pays for it with three materialized (N,) uniform arrays, an (N,)
boolean z, and a full cumsum compaction; this kernel replaces all of that
with ONE streamed pass over the partition array:

  * ``arr`` (reshaped to (P/128, 128) int32 lane tiles) is the only
    length-N operand that moves — 4 bytes per datum, delivered by the
    pipelined grid in ``(block_rows, 128)`` tiles;
  * per-datum uniforms are generated *in-kernel* with counter-based
    Threefry-2x32 bits keyed on (step_key, DRAW_CAND, datum_index)
    (:mod:`repro.core.numerics` — the same function the jnp reference
    evaluates, so the streams are bit-identical). Keying on the datum
    index, not the buffer slot, keeps the realized chain bitwise invariant
    to capacity and chunk size, exactly like the jnp engine's per-datum
    draws;
  * candidate selection compares the 24-bit lanes against a static integer
    threshold ``q_bits = round(q_db · 2²⁴)`` — pure int compare, no float
    round-trip;
  * selected datum ids are compacted in-kernel into a
    ``(cand_capacity_padded, 1)`` output buffer: TPU grid steps run
    sequentially, so the buffer and a (1, 1) running count are race-free
    accumulators (the same trick as ``bright_glm``'s total). Within a tile
    the expected candidate count is ``q_db · block`` (≈ 10 for the default
    tile), so extraction loops ``fori_loop``-many times over a masked
    argmin — O(candidates) reductions, not O(block²) scatter matrices.

The kernel emits only the compacted candidate ids + total count; the δ
evaluation for those candidates is the job of the *existing* FusedBound
machinery (``kernels/bright_glm``) on the O(cand_capacity) buffer, and the
darken/brighten accept decisions are O(C) jnp math on the same counter RNG
(:func:`repro.core.flymc._fused_z_update`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import DRAW_CAND, threefry2x32

_LANES = 128
_UNIFORM_SHIFT = 8  # int32 >> 8 (logical) = 24-bit uniform lanes


def z_candidates_pallas(
    arr2d: jax.Array,  # (P//128, 128) int32 partition array, padded with n
    meta: jax.Array,  # (3,) int32: [num, key_word0, key_word1]
    n: int,  # true datum count (ids >= n are padding)
    q_bits: int,  # candidate threshold: bits24 < q_bits ⇔ u < q_db
    cand_cap_padded: int,  # output buffer rows (>= cand_capacity, mult. of 8)
    block_rows: int = 8,
    interpret: bool = False,
):
    """Returns (cand (cand_cap_padded, 1) int32 padded with n, count (1,1)).

    Candidates appear in ``arr``-position order (the same order the jnp
    reference's cumsum compaction produces). Writes past the padded buffer
    are dropped, and ``count`` keeps the *true* total so the caller can
    raise the overflow flag that triggers the driver's capacity-doubling
    re-run.
    """
    rows, lanes = arr2d.shape
    assert lanes == _LANES and rows % block_rows == 0, arr2d.shape
    br = block_rows

    def kernel(meta_ref, arr_ref, cand_ref, count_ref):
        i = pl.program_id(0)
        num = meta_ref[0]

        @pl.when(i == 0)
        def _init():
            cand_ref[...] = jnp.full_like(cand_ref, n)
            count_ref[0, 0] = 0

        tile = arr_ref[...]  # (br, 128) datum ids
        row = jax.lax.broadcasted_iota(jnp.int32, (br, _LANES), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (br, _LANES), 1)
        pos = (i * br + row) * _LANES + col  # position in arr

        x0 = jnp.full((br, _LANES), DRAW_CAND, jnp.int32)
        bits, _ = threefry2x32(meta_ref[1], meta_ref[2], x0, tile)
        bits24 = jax.lax.shift_right_logical(bits, _UNIFORM_SHIFT)
        cand = (pos >= num) & (pos < n) & (bits24 < q_bits)

        cnt_tile = jnp.sum(cand.astype(jnp.int32))
        base = count_ref[0, 0]

        def extract(j, live):
            # j-th candidate of this tile = masked position-argmin sweep.
            p = jnp.min(jnp.where(live, pos, jnp.int32(2**30)))
            datum = jnp.sum(jnp.where(live & (pos == p), tile, 0))
            slot = base + j

            @pl.when(slot < cand_cap_padded)
            def _store():
                cand_ref[slot, 0] = datum

            return live & (pos != p)

        jax.lax.fori_loop(0, cnt_tile, extract, cand)
        count_ref[0, 0] = base + cnt_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # meta
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, _LANES), lambda i, *_: (i, 0))],
        out_specs=[
            pl.BlockSpec((cand_cap_padded, 1), lambda i, *_: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, *_: (0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((cand_cap_padded, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        cost_estimate=pl.CostEstimate(
            flops=50 * rows * _LANES,  # ~threefry rounds per streamed lane
            bytes_accessed=rows * _LANES * 4 + cand_cap_padded * 4,
            transcendentals=0,
        ),
        interpret=interpret,
    )(meta, arr2d)
