"""Wrapper for the z-candidate kernel: layout, padding, interpret fallback.

Entry point for ``FlyMCSpec.z_backend = "fused"``
(:func:`repro.core.flymc._fused_z_update`). The partition array is padded
to a whole number of ``(block_rows, 128)`` tiles with the sentinel id ``N``
(masked in-kernel by ``pos < N``) and handed to the streaming kernel; the
compacted candidate buffer comes back sliced to ``cand_capacity`` with the
true (possibly overflowing) candidate count alongside.

Candidate selection is pure integer work on non-differentiable operands
(indices and RNG bits), so unlike ``bright_glm`` no custom VJP is needed —
gradients never flow through z-moves.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bright_glm.ops import _pad_to, default_interpret
from repro.kernels.z_update.kernel import z_candidates_pallas
from repro.kernels.z_update.ref import q_threshold_bits


def z_candidates(
    arr: jax.Array,  # (N,) int32 partition array (bright prefix first)
    num: jax.Array,  # () int32 bright count
    key_words: jax.Array,  # (2,) int32 counter-RNG key words (step key)
    q_db: float,
    cand_capacity: int,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Fused dark→bright candidate selection. Returns (cand_idx, n_cand).

    ``cand_idx`` is (cand_capacity,) int32 in arr-position order, padded
    with the sentinel ``N``; ``n_cand`` is the true candidate count (it may
    exceed ``cand_capacity``, in which case the caller must raise the
    overflow flag). ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    if interpret is None:
        interpret = default_interpret()
    n = arr.shape[0]
    block = block_rows * 128
    p = _pad_to(max(n, block), block)
    arr2d = jnp.pad(
        arr.astype(jnp.int32), (0, p - n), constant_values=n
    ).reshape(p // 128, 128)
    meta = jnp.concatenate(
        [jnp.reshape(num.astype(jnp.int32), (1,)), key_words.astype(jnp.int32)]
    )
    candp = _pad_to(max(int(cand_capacity), 8), 8)
    cand, count = z_candidates_pallas(
        arr2d,
        meta,
        n=n,
        q_bits=q_threshold_bits(q_db),
        cand_cap_padded=candp,
        block_rows=block_rows,
        interpret=bool(interpret),
    )
    return cand[:cand_capacity, 0], count[0, 0]
