"""Wrapper for the z-candidate kernel: layout, padding, interpret fallback.

Entry point for ``FlyMCSpec.z_backend = "fused"``
(:func:`repro.core.flymc._fused_z_update`). The partition array is padded
to a whole number of ``(block_rows, 128)`` tiles with the sentinel id ``N``
(masked in-kernel by ``pos < N``) and handed to the streaming kernel; the
compacted candidate buffer comes back sliced to ``cand_capacity`` with the
true (possibly overflowing) candidate count alongside.

Batching over the chain axis goes through a ``custom_vmap`` rule (the same
scheme as ``kernels/bright_glm/ops``): the driver's multi-chain step
lowers to ONE :func:`~repro.kernels.z_update.kernel
.z_candidates_pallas_chains` launch whose grid leads with ``num_chains``
and whose scalar-prefetched ``meta`` rows carry each chain's
``(num, key_word0, key_word1)`` — the per-chain counter-RNG key lane that
keeps the batched trajectories bitwise identical to per-chain dispatch.

Candidate selection is pure integer work on non-differentiable operands
(indices and RNG bits), so unlike ``bright_glm`` no custom VJP is needed —
gradients never flow through z-moves.
"""

from __future__ import annotations

from functools import lru_cache

import jax  # annotations only (jax.Array); dispatch goes through common
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.z_update.kernel import (
    z_candidates_pallas,
    z_candidates_pallas_chains,
)
from repro.kernels.z_update.ref import q_threshold_bits


@lru_cache(maxsize=None)
def _pallas_dispatch(n, q_bits, cand_cap_padded, block_rows, interpret):
    """The pallas_call dispatch as a ``custom_vmap`` function (memoized on
    the static config): plain call = single-chain kernel; vmap over chains
    = one chain-grid megakernel launch
    (:func:`repro.kernels.common.make_chain_dispatch`)."""
    kw = dict(n=n, q_bits=q_bits, cand_cap_padded=cand_cap_padded,
              block_rows=block_rows, interpret=interpret)

    def plain(arr2d, meta):
        return z_candidates_pallas(arr2d, meta, **kw)

    def chains(arr3d, meta):
        return z_candidates_pallas_chains(arr3d, meta, **kw)

    return common.make_chain_dispatch(plain, chains)


def z_candidates(
    arr: jax.Array,  # (N,) int32 partition array (bright prefix first)
    num: jax.Array,  # () int32 bright count
    key_words: jax.Array,  # (2,) int32 counter-RNG key words (step key)
    q_db: float,
    cand_capacity: int,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Fused dark→bright candidate selection. Returns (cand_idx, n_cand).

    ``cand_idx`` is (cand_capacity,) int32 in arr-position order, padded
    with the sentinel ``N``; ``n_cand`` is the true candidate count (it may
    exceed ``cand_capacity``, in which case the caller must raise the
    overflow flag). ``interpret=None`` auto-selects interpret mode off-TPU.
    Under ``jax.vmap`` over the chain axis the dispatch batches into a
    single chain-grid megakernel (see :mod:`repro.kernels.common`).
    """
    if interpret is None:
        interpret = common.default_interpret()
    n = arr.shape[0]
    block = block_rows * 128
    p = common.pad_to(max(n, block), block)
    arr2d = jnp.pad(
        arr.astype(jnp.int32), (0, p - n), constant_values=n
    ).reshape(p // 128, 128)
    meta = jnp.concatenate(
        [jnp.reshape(num.astype(jnp.int32), (1,)), key_words.astype(jnp.int32)]
    )
    candp = common.pad_to(max(int(cand_capacity), 8), 8)
    call = _pallas_dispatch(
        n, q_threshold_bits(q_db), candp, block_rows, bool(interpret)
    )
    cand, count = call(arr2d, meta)
    return cand[:cand_capacity, 0], count[0, 0]
