"""Fused z-update engine: streamed dark-set candidate selection.

``ops.z_candidates`` is the ``FlyMCSpec.z_backend = "fused"`` entry point;
``ref.z_candidates_ref`` the pure-jnp oracle sharing the counter-based RNG.
"""
