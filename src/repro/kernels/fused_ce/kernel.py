"""Pallas TPU kernel: fused streaming softmax cross-entropy.

The train-step hot spot at 152k vocab: materializing (T, V) logits costs
T·V·4 bytes of HBM; this kernel never leaves VMEM. Grid (T/BT, V/BV) with
the vocab dimension innermost; per step one (BT, D)×(D, BV) MXU matmul and
an online logsumexp update (m, se scratch), plus target-logit extraction
against the prefetched labels. Output per token: (lse, target logit).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def fused_ce_pallas(
    x: jax.Array,  # (T, D)
    w: jax.Array,  # (D, V)
    labels: jax.Array,  # (T,) int32
    block_t: int = 8,
    block_v: int = 512,
    interpret: bool = True,
):
    t, d = x.shape
    v = w.shape[1]
    assert t % block_t == 0 and v % block_v == 0
    grid = (t // block_t, v // block_v)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # labels
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_t, d), lambda ti, vi, lab: (ti, 0)),
            pl.BlockSpec((d, block_v), lambda ti, vi, lab: (0, vi)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, 1), lambda ti, vi, lab: (ti, 0)),
            pl.BlockSpec((block_t, 1), lambda ti, vi, lab: (ti, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_t, 1), jnp.float32),  # running max
            pltpu.VMEM((block_t, 1), jnp.float32),  # running sumexp
            pltpu.VMEM((block_t, 1), jnp.float32),  # target logit
        ],
    )

    def kernel(lab_ref, x_ref, w_ref, lse_ref, tgt_ref, m_scr, se_scr, tg_scr):
        ti, vi = pl.program_id(0), pl.program_id(1)

        @pl.when(vi == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG)
            se_scr[...] = jnp.zeros_like(se_scr)
            tg_scr[...] = jnp.zeros_like(tg_scr)

        logits = jax.lax.dot_general(
            x_ref[...].astype(jnp.float32), w_ref[...].astype(jnp.float32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32,
        )  # (BT, BV)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
        se_scr[...] = se_scr[...] * jnp.exp(m_prev - m_new) + jnp.sum(
            jnp.exp(logits - m_new), -1, keepdims=True
        )
        m_scr[...] = m_new

        rows = ti * block_t + jax.lax.broadcasted_iota(
            jnp.int32, (block_t, 1), 0
        )
        local = lab_ref[rows[:, 0]][:, None] - vi * block_v
        cols = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_v), 1)
        hit = cols == local
        tg_scr[...] += jnp.sum(jnp.where(hit, logits, 0.0), -1, keepdims=True)

        @pl.when(vi == pl.num_programs(1) - 1)
        def _out():
            lse_ref[...] = m_scr[...] + jnp.log(se_scr[...])
            tgt_ref[...] = tg_scr[...]

    out_shape = [
        jax.ShapeDtypeStruct((t, 1), jnp.float32),
        jax.ShapeDtypeStruct((t, 1), jnp.float32),
    ]
    return pl.pallas_call(
        kernel, grid_spec=grid_spec, out_shape=out_shape, interpret=interpret
    )(labels, x, w)
