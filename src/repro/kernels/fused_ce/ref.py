"""Pure-jnp oracle: per-token NLL = logsumexp(logits) - logits[label]."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_ce_ref(x, w, labels):
    """x: (T, D); w: (D, V); labels: (T,). Returns per-token NLL (T,)."""
    logits = (x @ w).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return lse - tgt
