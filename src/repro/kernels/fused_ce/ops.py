"""jit'd wrapper for the fused-CE kernel (padding + NLL assembly)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.fused_ce.kernel import fused_ce_pallas


@partial(jax.jit, static_argnames=("block_t", "block_v", "interpret"))
def fused_ce(
    x: jax.Array,  # (T, D)
    w: jax.Array,  # (D, V)
    labels: jax.Array,  # (T,)
    block_t: int = 8,
    block_v: int = 512,
    interpret: bool | None = None,
):
    """Per-token NLL (T,) without materializing (T, V) logits in HBM."""
    if interpret is None:
        interpret = common.default_interpret()
    t, d = x.shape
    v = w.shape[1]
    tp = common.pad_to(t, block_t)
    bv = min(block_v, v)
    vp = common.pad_to(v, bv)
    xp = jnp.pad(x, ((0, tp - t), (0, 0)))
    # pad vocab with -inf-producing zero columns? zero columns would join the
    # logsumexp; instead pad W with a very negative bias via zero weights and
    # mask: zero columns give logit 0 which corrupts lse — so pad weights
    # with 0 and subtract their contribution by masking: simplest correct
    # approach is requiring V % block_v == 0 after choosing bv = gcd-friendly
    # size; we pad with columns equal to the first column and ignore them in
    # lse by relying on exact divisibility instead.
    assert vp == v, "choose block_v dividing V (vocabs are 256-multiples)"
    lse, tgt = fused_ce_pallas(
        xp, w, jnp.pad(labels.astype(jnp.int32), (0, tp - t)),
        block_t=block_t, block_v=bv, interpret=interpret,
    )
    return (lse[:t, 0] - tgt[:t, 0])
