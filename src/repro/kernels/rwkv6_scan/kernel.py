"""Pallas TPU kernel: chunked WKV6 recurrence.

Grid (B, H, S/c) with the chunk dimension innermost; the (D, D) per-head
state lives in f32 VMEM scratch across the chunk sweep. Within a chunk the
recurrence is re-expressed as two (c, c)/(c, D) matmuls with cumulative
decay factors (DESIGN.md §6) — MXU work instead of a length-c scalar chain:

    y = tril_strict(rq·kkᵀ)·v + rq·S₀ + diag(r·u·k)·v
    S' = diag(P_c)·S₀ + (k·P_c/P_j)ᵀ·v

with rq = r·P_{i-1}, kk = k/P_j, P = exp(cumsum(log w)). Per-step log-decay
is clamped to [-1, 0) upstream so exp(±c·|log w|) stays in f32 range.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def rwkv6_pallas(
    r: jax.Array,  # (B, H, n, c, D) f32
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # ≤ 0
    u: jax.Array,  # (H, D)
    interpret: bool = True,
):
    b, h, n, c, d = r.shape
    grid = (b, h, n)

    io_spec = pl.BlockSpec(
        (1, 1, 1, c, d), lambda bi, hi, ci: (bi, hi, ci, 0, 0)
    )
    u_spec = pl.BlockSpec((1, d), lambda bi, hi, ci: (hi, 0))

    def kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, s_scr):
        ci = pl.program_id(2)

        @pl.when(ci == 0)
        def _init():
            s_scr[...] = jnp.zeros_like(s_scr)

        rv = r_ref[0, 0, 0]  # (c, D)
        kv = k_ref[0, 0, 0]
        vv = v_ref[0, 0, 0]
        lw = w_ref[0, 0, 0]
        uv = u_ref[...][0]  # (D,)
        state = s_scr[...]

        logp = jnp.cumsum(lw, axis=0)  # (c, D) inclusive
        logp_excl = logp - lw
        rq = rv * jnp.exp(logp_excl)
        kk = kv * jnp.exp(-logp)
        a = jax.lax.dot_general(
            rq, kk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (c, c)
        ii = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
        jj = jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
        a = jnp.where(jj < ii, a, 0.0)  # strictly lower triangular
        y = jax.lax.dot_general(
            a, vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        y += jax.lax.dot_general(
            rq, state, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        diag = jnp.sum(rv * uv[None, :] * kv, axis=-1, keepdims=True)
        y += diag * vv
        y_ref[0, 0, 0] = y

        p_end = jnp.exp(logp[-1:, :])  # (1, D)
        k2 = kv * jnp.exp(logp[-1:, :] - logp)
        s_scr[...] = state * p_end.T + jax.lax.dot_general(
            k2, vv, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

        @pl.when(ci == pl.num_programs(2) - 1)
        def _out():
            s_out_ref[0, 0] = s_scr[...]

    out_shape = [
        jax.ShapeDtypeStruct((b, h, n, c, d), jnp.float32),
        jax.ShapeDtypeStruct((b, h, d, d), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[io_spec, io_spec, io_spec, io_spec, u_spec],
        out_specs=[
            io_spec,
            pl.BlockSpec((1, 1, d, d), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
