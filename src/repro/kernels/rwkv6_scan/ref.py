"""Pure-jnp oracle: sequential WKV6 recurrence (data-dependent decay)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rwkv6_ref(r, k, v, logw, u, state0=None):
    """r,k,v,logw: (B, H, S, D); u: (H, D). Returns (y (B,H,S,D), state)."""
    b, h, s, d = r.shape
    state = (
        jnp.zeros((b, h, d, d), jnp.float32) if state0 is None else state0
    )

    def step(st, inp):
        rt, kt, vt, wt = inp  # (B, H, D) each
        y = jnp.einsum("bhd,bhde->bhe", rt, st) + jnp.einsum(
            "bhd,hd,bhd,bhe->bhe", rt, u, kt, vt
        )
        st2 = jnp.exp(wt)[..., None] * st + jnp.einsum(
            "bhd,bhe->bhde", kt, vt
        )
        return st2, y

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (r, k, v, logw))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 2, 0, 3), state
