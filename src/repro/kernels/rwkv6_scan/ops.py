"""jit'd wrapper for the chunked WKV6 kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.rwkv6_scan.kernel import rwkv6_pallas


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(
    r: jax.Array,  # (B, H, S, D)
    k: jax.Array,
    v: jax.Array,
    logw: jax.Array,  # ≤ 0 per-step log decay
    u: jax.Array,  # (H, D)
    chunk: int = 64,
    interpret: bool | None = None,
):
    """Returns (y (B, H, S, D), final state (B, H, D, D))."""
    if interpret is None:
        interpret = common.default_interpret()
    b, h, s, d = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    n = s // c

    def split(t):
        return t.astype(jnp.float32).reshape(b, h, n, c, d)

    y, state = rwkv6_pallas(
        split(r), split(k), split(v), split(logw), u.astype(jnp.float32),
        interpret=interpret,
    )
    return y.reshape(b, h, s, d), state
