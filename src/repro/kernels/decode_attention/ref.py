"""Pure-jnp oracle for flash-decode over a (possibly partial) ring cache."""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, pos, t, window=None):
    """q: (B, H, D); k/v: (B, W, Hk, D); pos: (W,) absolute (-1 = empty).

    Returns (out (B, H, D), m (B, Hk, G), l (B, Hk, G)) — the local
    softmax statistics for cross-shard merging.
    """
    b, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qf = q.astype(jnp.float32).reshape(b, hk, g, d) / jnp.sqrt(d)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, k.astype(jnp.float32))
    valid = (pos >= 0) & (pos <= t)
    if window is not None:
        valid &= pos > t - window
    s = jnp.where(valid[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p, v.astype(jnp.float32))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, d), m, l
