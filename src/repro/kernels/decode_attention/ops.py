"""jit'd wrapper: layout (GQA grouping, padding) for flash-decode."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.decode_attention.kernel import decode_attention_pallas


@partial(jax.jit, static_argnames=("window", "block_k", "interpret"))
def decode_attention(
    q: jax.Array,  # (B, H, D)
    k: jax.Array,  # (B, W, Hk, D)
    v: jax.Array,  # (B, W, Hk, D)
    pos: jax.Array,  # (W,)
    t: jax.Array,  # ()
    window: int | None = None,
    block_k: int = 128,
    interpret: bool | None = None,
):
    """Returns (out (B, H, D), m (B, Hk, G), l (B, Hk, G)) — local softmax
    stats exposed for cross-shard (context-parallel) merging."""
    if interpret is None:
        interpret = common.default_interpret()
    b, h, d = q.shape
    w, hk = k.shape[1], k.shape[2]
    g = h // hk
    bk = min(block_k, w)
    pad_w = common.pad_to(w, bk) - w
    if pad_w:
        k = jnp.pad(k, ((0, 0), (0, pad_w), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_w), (0, 0), (0, 0)))
        pos = jnp.pad(pos, (0, pad_w), constant_values=-1)
    qg = q.reshape(b, hk, g, d)
    out, m, l = decode_attention_pallas(
        qg, k, v, pos.astype(jnp.int32), t.astype(jnp.int32),
        window=window, block_k=bk, interpret=interpret,
    )
    return out.reshape(b, h, d), m[..., 0], l[..., 0]
