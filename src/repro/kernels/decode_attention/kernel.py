"""Pallas TPU kernel: flash-decode attention over a sharded ring KV cache.

One new token attends a ring cache shard (B, W_loc, Hk, D). Grid:
(B, Hk, W_loc/BK) with the KV-block dimension innermost, so the online
softmax accumulators (m, l, acc) live in VMEM scratch across the sequential
KV sweep — the classic flash-decode schedule mapped to the TPU grid.

Block layout: q group block (G, D) padded to ≥8 sublanes; KV blocks
(BK, D) with D a 128-lane multiple. Position masking (ring validity,
causality, optional sliding window) uses a prefetched position buffer.
Outputs include the local (m, l) statistics so the caller can merge
partial softmaxes across context-parallel shards with two psums
(DESIGN.md §5 / serving._decode_attend).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def decode_attention_pallas(
    q: jax.Array,  # (B, Hk, G, D)
    k: jax.Array,  # (B, W, Hk, D)
    v: jax.Array,  # (B, W, Hk, D)
    pos: jax.Array,  # (W,) int32
    t: jax.Array,  # () int32
    window: int | None = None,
    block_k: int = 128,
    interpret: bool = True,
):
    b, hk, g, d = q.shape
    w = k.shape[1]
    assert w % block_k == 0, (w, block_k)
    grid = (b, hk, w // block_k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,  # t
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ci, t_ref: (bi, hi, 0, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, ci, t_ref: (bi, ci, hi, 0)),
            pl.BlockSpec((1, block_k, 1, d), lambda bi, hi, ci, t_ref: (bi, ci, hi, 0)),
            pl.BlockSpec((1, block_k), lambda bi, hi, ci, t_ref: (0, ci)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, ci, t_ref: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci, t_ref: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, ci, t_ref: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )

    def kernel(t_ref, q_ref, k_ref, v_ref, pos_ref, o_ref, m_ref, l_ref,
               m_scr, l_scr, acc_scr):
        ci = pl.program_id(2)
        nck = pl.num_programs(2)

        @pl.when(ci == 0)
        def _init():
            m_scr[...] = jnp.full_like(m_scr, NEG)
            l_scr[...] = jnp.zeros_like(l_scr)
            acc_scr[...] = jnp.zeros_like(acc_scr)

        qv = q_ref[0, 0].astype(jnp.float32) / math.sqrt(d)  # (G, D)
        kv = k_ref[0, :, 0].astype(jnp.float32)  # (BK, D)
        vv = v_ref[0, :, 0].astype(jnp.float32)
        posv = pos_ref[0]  # (BK,)
        tv = t_ref[0]

        s = jax.lax.dot_general(
            qv, kv, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        valid = (posv >= 0) & (posv <= tv)
        if window is not None:
            valid &= posv > tv - window
        s = jnp.where(valid[None, :], s, NEG)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

        @pl.when(ci == nck - 1)
        def _finalize():
            l = l_scr[...]
            o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(
                o_ref.dtype
            )
            m_ref[0, 0] = m_scr[...]
            l_ref[0, 0] = l

    out_shape = [
        jax.ShapeDtypeStruct((b, hk, g, d), jnp.float32),
        jax.ShapeDtypeStruct((b, hk, g, 1), jnp.float32),
        jax.ShapeDtypeStruct((b, hk, g, 1), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(jnp.reshape(t, (1,)), q, k, v, pos[None, :])
