"""Wrapper for the bright-GLM kernel: padding, layout, clamping, custom VJP.

This is the ``backend="pallas"`` entry point used by
:func:`repro.core.flymc.make_joint_logpost`. It

  * pads θ (and K for softmax) to 128-lane multiples and the index buffer
    to a ``block_rows`` multiple — the feature matrix itself is handed to
    the kernel unpadded and padded per-tile in VMEM by the DMA,
  * **clamps** every index into ``[0, N)`` before the ``pallas_call`` —
    padded buffer slots (``bright_buffer`` capacity padding, ``jnp.pad``
    fill, the candidate buffer's out-of-range sentinel ``N``) would
    otherwise reach the in-kernel DMA as reads past the end of ``x``,
    which is undefined; clamped rows are computed and then masked to zero
    by ``n_bright`` exactly like the jnp reference path,
  * pre-gathers the O(C) per-row scalars (t, ξ) so the kernel only fuses
    the O(C·D) feature gather,
  * defines a ``jax.custom_vjp`` so gradient kernels (MALA/HMC) work
    through the fused forward: the backward pass re-evaluates the gathered
    rows with the pure-jnp reference (same O(C·D) cost class, shared
    numerics) and scatters row cotangents back — Pallas forward speed,
    reference-exact gradients.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bright_glm.kernel import FAMILIES, bright_glm_pallas
from repro.kernels.bright_glm.ref import bright_glm_ref


def _pad_to(d: int, mult: int) -> int:
    return ((d + mult - 1) // mult) * mult


def default_interpret() -> bool:
    """Interpret-mode fallback: compile for real only on TPU backends."""
    return jax.default_backend() != "tpu"


def _forward(cfg, x, t, xi, idx, n_bright, theta):
    family, nu, sigma, block_rows, interpret = cfg
    n, d = x.shape
    dp = _pad_to(d, 128)
    c = idx.shape[0]
    cp = _pad_to(max(c, block_rows), block_rows)

    # Satellite fix: indices ≥ N (buffer padding / candidate sentinels) are
    # undefined for the in-kernel row DMA — clamp, never trust the caller.
    idxp = jnp.clip(
        jnp.pad(idx.astype(jnp.int32), (0, cp - c)), 0, n - 1
    )
    # x goes to the kernel UNPADDED (the DMA pads into VMEM): lane-padding
    # here would materialize a Dp/D-times copy of the dataset in HBM on
    # every evaluation.
    xp = x.astype(jnp.float32)
    nb = jnp.reshape(n_bright.astype(jnp.int32), (1,))

    if family == "softmax":
        k = theta.shape[0]
        kp = _pad_to(k, 128)
        tb = jnp.take(t.astype(jnp.int32), idxp)[:, None]  # (cp, 1)
        xib = jnp.pad(
            jnp.take(xi.astype(jnp.float32), idxp, axis=0),
            ((0, 0), (0, kp - k)),
        )  # (cp, Kp)
        thetap = jnp.pad(
            theta.astype(jnp.float32), ((0, kp - k), (0, dp - d))
        )  # (Kp, Dp)
        n_classes = k
    else:
        tb = jnp.take(t.astype(jnp.float32), idxp)[:, None]
        xib = jnp.take(xi.astype(jnp.float32), idxp)[:, None]
        thetap = jnp.pad(theta.astype(jnp.float32), (0, dp - d))[None, :]
        n_classes = 0

    delta, total = bright_glm_pallas(
        xp, tb, xib, idxp, nb, thetap,
        family=family, nu=nu, sigma=sigma, n_classes=n_classes,
        block_rows=block_rows, interpret=interpret,
    )
    return delta[:c, 0], total[0, 0]


def _ref_outputs(cfg, x, t, xi, idx, n_bright, theta):
    """(delta, total) via the pure-jnp reference — the VJP's forward."""
    family = cfg[0]
    n = x.shape[0]
    idxc = jnp.clip(idx.astype(jnp.int32), 0, n - 1)
    mask = jnp.arange(idx.shape[0]) < n_bright
    delta, contrib = bright_glm_ref(
        x, t, xi, idxc, mask, theta, family=family, nu=cfg[1], sigma=cfg[2]
    )
    return delta, jnp.sum(contrib)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bright_glm_vjp(cfg, x, t, xi, idx, n_bright, theta):
    return _forward(cfg, x, t, xi, idx, n_bright, theta)


def _vjp_fwd(cfg, x, t, xi, idx, n_bright, theta):
    out = _forward(cfg, x, t, xi, idx, n_bright, theta)
    return out, (x, t, xi, idx, n_bright, theta)


def _vjp_bwd(cfg, res, cts):
    x, t, xi, idx, n_bright, theta = res
    t_is_int = jnp.issubdtype(t.dtype, jnp.integer)
    if t_is_int:
        fn = lambda x_, xi_, th: _ref_outputs(cfg, x_, t, xi_, idx, n_bright, th)
        _, vjp = jax.vjp(fn, x, xi, theta)
        dx, dxi, dth = vjp(cts)
        dt = None
    else:
        fn = lambda x_, t_, xi_, th: _ref_outputs(
            cfg, x_, t_, xi_, idx, n_bright, th
        )
        _, vjp = jax.vjp(fn, x, t, xi, theta)
        dx, dt, dxi, dth = vjp(cts)
    return dx, dt, dxi, None, None, dth


_bright_glm_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def bright_glm(
    x: jax.Array,  # (N, D) features
    t: jax.Array,  # (N,) labels / responses / class ids
    xi: jax.Array,  # (N,) bound tightness, or (N, K) tangency logits
    idx: jax.Array,  # (C,) bright row ids (padding slots may be ≥ N)
    n_bright: jax.Array,  # () int — first n_bright slots of idx are valid
    theta: jax.Array,  # (D,), or (K, D) for softmax
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Fused bright-point evaluation. Returns (delta (C,), total scalar).

    Differentiable (custom VJP); ``interpret=None`` auto-selects interpret
    mode off-TPU so the same call sites run everywhere.
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; expected {FAMILIES}")
    if interpret is None:
        interpret = default_interpret()
    cfg = (family, float(nu), float(sigma), int(block_rows), bool(interpret))
    return _bright_glm_vjp(cfg, x, t, xi, idx, n_bright, theta)
