"""jit'd wrapper for the bright-GLM kernel: padding, layout, reduction."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bright_glm.kernel import bright_glm_pallas


def _pad_lanes(d: int, mult: int = 128) -> int:
    return ((d + mult - 1) // mult) * mult


@partial(
    jax.jit,
    static_argnames=("family", "nu", "sigma", "block_rows", "interpret"),
)
def bright_glm(
    x: jax.Array,  # (N, D)
    t: jax.Array,  # (N,)
    xi: jax.Array,  # (N,)
    idx: jax.Array,  # (C,)
    n_bright: jax.Array,  # ()
    theta: jax.Array,  # (D,)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    block_rows: int = 8,
    interpret: bool = True,
):
    """Fused bright-point evaluation. Returns (delta (C,), total scalar)."""
    n, d = x.shape
    dp = _pad_lanes(d)
    c = idx.shape[0]
    cp = ((c + block_rows - 1) // block_rows) * block_rows
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, dp - d)))
    thetap = jnp.pad(theta.astype(jnp.float32), (0, dp - d))[None, :]
    idxp = jnp.pad(idx.astype(jnp.int32), (0, cp - c))
    delta, contrib = bright_glm_pallas(
        xp,
        t.astype(jnp.float32)[:, None],
        xi.astype(jnp.float32)[:, None],
        idxp,
        n_bright.astype(jnp.int32),
        thetap,
        family=family,
        nu=nu,
        sigma=sigma,
        block_rows=block_rows,
        interpret=interpret,
    )
    return delta[:c, 0], jnp.sum(contrib[:c, 0])
