"""Wrapper for the bright-GLM kernel: padding, layout, clamping, custom VJP.

This is the ``backend="pallas"`` entry point used by
:func:`repro.core.flymc.make_joint_logpost`. It

  * pads θ (and K for softmax) to 128-lane multiples and the index buffer
    to a ``block_rows`` multiple — the feature matrix itself is handed to
    the kernel unpadded and padded per-tile in VMEM by the DMA,
  * **clamps** every index into ``[0, N)`` before the ``pallas_call`` —
    padded buffer slots (``bright_buffer`` capacity padding, ``jnp.pad``
    fill, the candidate buffer's out-of-range sentinel ``N``) would
    otherwise reach the in-kernel DMA as reads past the end of ``x``,
    which is undefined; clamped rows are computed and then masked to zero
    by ``n_bright`` exactly like the jnp reference path,
  * pre-gathers the O(C) per-row scalars (t, ξ) so the kernel only fuses
    the O(C·D) feature gather,
  * carries a ``jax.custom_batching.custom_vmap`` rule on the pallas
    dispatch: batching over the chain axis (the driver's multi-chain step)
    lowers to ONE :func:`~repro.kernels.bright_glm.kernel
    .bright_glm_pallas_chains` launch whose grid gains a leading chain
    dimension — instead of jax's default pallas batching, which would
    broadcast the HBM-resident dataset per chain and run each chain's tiny
    workload as a degenerate launch (see :mod:`repro.kernels.common`),
  * defines a ``jax.custom_vjp`` so gradient kernels (MALA/HMC) work
    through the fused forward: the backward pass re-evaluates the gathered
    rows with the pure-jnp reference (same O(C·D) cost class, shared
    numerics) and scatters row cotangents back — Pallas forward speed,
    reference-exact gradients.
"""

from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.bright_glm.kernel import (
    FAMILIES,
    bright_glm_pallas,
    bright_glm_pallas_chains,
)
from repro.kernels.bright_glm.ref import bright_glm_ref


@lru_cache(maxsize=None)
def _pallas_dispatch(family, nu, sigma, n_classes, block_rows, interpret):
    """The pallas_call dispatch as a ``custom_vmap`` function.

    The plain call is the single-chain kernel; the vmap rule
    (:func:`repro.kernels.common.make_chain_dispatch`) coalesces a
    chain-batched trace into one ``bright_glm_pallas_chains`` launch with
    the dataset shared (never broadcast) across chains. Memoized on the
    static config so repeated traces reuse one custom_vmap object.
    """
    kw = dict(family=family, nu=nu, sigma=sigma, n_classes=n_classes,
              block_rows=block_rows, interpret=interpret)

    def plain(xp, tb, xib, idxp, nb, thetap):
        return bright_glm_pallas(xp, tb, xib, idxp, nb, thetap, **kw)

    def chains(xp, tb, xib, idxp, nb, thetap):
        return bright_glm_pallas_chains(xp, tb, xib, idxp, nb, thetap, **kw)

    return common.make_chain_dispatch(plain, chains, n_shared=1)


def _forward(cfg, x, t, xi, idx, n_bright, theta):
    family, nu, sigma, block_rows, interpret = cfg
    n, d = x.shape
    dp = common.pad_to(d, 128)
    c = idx.shape[0]
    cp = common.pad_to(max(c, block_rows), block_rows)

    # Indices ≥ N (buffer padding / candidate sentinels) are undefined for
    # the in-kernel row DMA — clamp, never trust the caller.
    idxp = common.clamp_index(jnp.pad(idx.astype(jnp.int32), (0, cp - c)), n)
    # x goes to the kernel UNPADDED (the DMA pads into VMEM): lane-padding
    # here would materialize a Dp/D-times copy of the dataset in HBM on
    # every evaluation.
    xp = x.astype(jnp.float32)
    nb = jnp.reshape(n_bright.astype(jnp.int32), (1,))

    if family == "softmax":
        k = theta.shape[0]
        kp = common.pad_to(k, 128)
        tb = jnp.take(t.astype(jnp.int32), idxp)[:, None]  # (cp, 1)
        xib = jnp.pad(
            jnp.take(xi.astype(jnp.float32), idxp, axis=0),
            ((0, 0), (0, kp - k)),
        )  # (cp, Kp)
        thetap = jnp.pad(
            theta.astype(jnp.float32), ((0, kp - k), (0, dp - d))
        )  # (Kp, Dp)
        n_classes = k
    else:
        tb = jnp.take(t.astype(jnp.float32), idxp)[:, None]
        xib = jnp.take(xi.astype(jnp.float32), idxp)[:, None]
        thetap = jnp.pad(theta.astype(jnp.float32), (0, dp - d))[None, :]
        n_classes = 0

    call = _pallas_dispatch(family, nu, sigma, n_classes, block_rows,
                            interpret)
    delta, total = call(xp, tb, xib, idxp, nb, thetap)
    return delta[:c, 0], total[0, 0]


def _ref_outputs(cfg, x, t, xi, idx, n_bright, theta):
    """(delta, total) via the pure-jnp reference — the VJP's forward."""
    family = cfg[0]
    n = x.shape[0]
    idxc = common.clamp_index(idx, n)
    mask = jnp.arange(idx.shape[0]) < n_bright
    delta, contrib = bright_glm_ref(
        x, t, xi, idxc, mask, theta, family=family, nu=cfg[1], sigma=cfg[2]
    )
    return delta, jnp.sum(contrib)


@partial(jax.custom_vjp, nondiff_argnums=(0,))
def _bright_glm_vjp(cfg, x, t, xi, idx, n_bright, theta):
    return _forward(cfg, x, t, xi, idx, n_bright, theta)


def _vjp_fwd(cfg, x, t, xi, idx, n_bright, theta):
    out = _forward(cfg, x, t, xi, idx, n_bright, theta)
    return out, (x, t, xi, idx, n_bright, theta)


def _vjp_bwd(cfg, res, cts):
    x, t, xi, idx, n_bright, theta = res
    t_is_int = jnp.issubdtype(t.dtype, jnp.integer)
    if t_is_int:
        fn = lambda x_, xi_, th: _ref_outputs(cfg, x_, t, xi_, idx, n_bright, th)
        _, vjp = jax.vjp(fn, x, xi, theta)
        dx, dxi, dth = vjp(cts)
        dt = None
    else:
        fn = lambda x_, t_, xi_, th: _ref_outputs(
            cfg, x_, t_, xi_, idx, n_bright, th
        )
        _, vjp = jax.vjp(fn, x, t, xi, theta)
        dx, dt, dxi, dth = vjp(cts)
    return dx, dt, dxi, None, None, dth


_bright_glm_vjp.defvjp(_vjp_fwd, _vjp_bwd)


def bright_glm(
    x: jax.Array,  # (N, D) features
    t: jax.Array,  # (N,) labels / responses / class ids
    xi: jax.Array,  # (N,) bound tightness, or (N, K) tangency logits
    idx: jax.Array,  # (C,) bright row ids (padding slots may be ≥ N)
    n_bright: jax.Array,  # () int — first n_bright slots of idx are valid
    theta: jax.Array,  # (D,), or (K, D) for softmax
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    block_rows: int = 8,
    interpret: bool | None = None,
):
    """Fused bright-point evaluation. Returns (delta (C,), total scalar).

    Differentiable (custom VJP); ``interpret=None`` auto-selects interpret
    mode off-TPU so the same call sites run everywhere. Under ``jax.vmap``
    over the chain axis the pallas dispatch batches into a single
    chain-grid megakernel (see :mod:`repro.kernels.common`).
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; expected {FAMILIES}")
    if interpret is None:
        interpret = common.default_interpret()
    cfg = (family, float(nu), float(sigma), int(block_rows), bool(interpret))
    return _bright_glm_vjp(cfg, x, t, xi, idx, n_bright, theta)
