"""Pallas TPU kernel: fused gather + bound-corrected likelihood (FlyMC core).

TPU adaptation of the paper's "loop over bright data" (DESIGN.md §3.1): the
bright index buffer arrives as a *scalar-prefetch* operand and the feature
matrix stays in HBM (``memory_space=ANY``). Each grid step DMAs a true
(block_rows, Dp) tile — ``block_rows`` independent row copies issued
back-to-back and awaited together, so the gather overlaps instead of
serializing one (1, Dp) pipeline slot per row — and then fuses:

    tile · θᵀ  (MXU)  →  log L, log B (VPU scalar math)  →  δ
    →  Σ masked log(expm1 δ)  (the Alg.-1 line-19 factor, reduced in-kernel)

Outputs: per-row δ (reused as the z-kernel's cache, Alg. 2) and a single
running total per chain accumulated across the sequential TPU grid — the
O(C) reduction never leaves the kernel.

Chain batching: the grid's LEADING dimension is ``num_chains``. One launch
walks ``(chain, tile)`` in row-major order, so each chain's ≤capacity
workload — far too small to fill the VPU/MXU on its own — coalesces into
one long pipeline over the shared HBM-resident dataset. All per-chain
operands (bright indices, bright counts, θ) index by ``program_id(0)``;
the feature matrix is the one operand every chain shares.
:func:`bright_glm_pallas` is the single-chain entry point — literally the
``num_chains == 1`` case of :func:`bright_glm_pallas_chains`.

Families: logistic (Jaakkola–Jordan), student_t (tangent bound), softmax
(Böhning, matrix θ). All δ formulas come from :mod:`repro.core.numerics` —
the same code the jnp reference path uses, so kernel and reference cannot
drift.

Layout: θ (and K for softmax) padded to a multiple of 128 lanes; the
feature matrix itself stays UNPADDED in HBM — rows are DMA'd into the
first D lanes of a zero-initialized padded VMEM tile, so HBM never holds
a lane-padded copy of the dataset. BR rows (8-multiple sublanes) per grid
step. VMEM per step: BR·Dp·4 for the row tile plus the θ block —
independent of ``num_chains``.

The O(C) per-row operands (t, ξ) are pre-gathered by the ops wrapper —
they are 4–Kp·4 bytes/row next to the Dp·4 bytes/row feature gather that
this kernel exists to fuse.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.numerics import (
    log_expm1,
    logistic_delta,
    softmax_delta_padded,
    student_t_delta,
)

FAMILIES = ("logistic", "student_t", "softmax")


def bright_glm_pallas_chains(
    x: jax.Array,  # (N, D) — unpadded, SHARED by all chains; stays in HBM
    t: jax.Array,  # (K, C, 1) f32 labels, or int32 class ids (softmax)
    xi: jax.Array,  # (K, C, 1) f32, or (K, C, Kp) tangency logits (softmax)
    idx: jax.Array,  # (K, C) int32 bright row ids, clamped to [0, N); C % BR == 0
    n_bright: jax.Array,  # (K, 1) int32 per-chain bright counts
    theta: jax.Array,  # (K, 1, Dp), or (K, Kp, Dp) zero-padded (softmax)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    n_classes: int = 0,
    block_rows: int = 8,
    interpret: bool = False,
):
    """Returns (delta (K, C, 1) f32, total (K, 1, 1) f32).

    ``x`` is deliberately NOT lane-padded and NOT chain-broadcast: each DMA
    copies the raw (D,) row into the first D lanes of a zero-initialized
    (BR, Dp) VMEM scratch tile, so the dataset is never duplicated — not at
    (N, Dp) for the lanes, and not at (K, N, D) for the chains (which is
    exactly what jax's default pallas batching rule would materialize).
    The scratch's padding lanes are zeroed once (the very first grid step)
    and never written again, and θ's padding lanes are zero, so the Dp-wide
    dot product is exact for every chain.
    """
    if family not in FAMILIES:
        raise ValueError(f"unknown family {family!r}; expected {FAMILIES}")
    k_chains, c = idx.shape
    d = x.shape[1]
    dp = theta.shape[2]
    kt = theta.shape[1]
    assert dp % 128 == 0 and dp >= d, (dp, d)
    assert c % block_rows == 0, (c, block_rows)
    br = block_rows

    def kernel(idx_ref, nb_ref, x_hbm, t_ref, xi_ref, theta_ref,
               delta_ref, total_ref, rows, sems):
        ch = pl.program_id(0)
        i = pl.program_id(1)
        base = i * br

        @pl.when((ch == 0) & (i == 0))
        def _zero_padding_lanes():
            rows[...] = jnp.zeros_like(rows)

        def row_dma(r):
            return pltpu.make_async_copy(
                x_hbm.at[idx_ref[ch, base + r]], rows.at[r, pl.ds(0, d)],
                sems.at[r],
            )

        for r in range(br):
            row_dma(r).start()
        for r in range(br):
            row_dma(r).wait()

        tile = rows[...]  # (BR, Dp)
        theta_v = theta_ref[0]  # (kt, Dp) — this chain's θ block
        if family == "softmax":
            eta = jax.lax.dot_general(
                tile, theta_v, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (BR, Kp)
            t_v = t_ref[0]  # (BR, 1) int32
            col = jax.lax.broadcasted_iota(jnp.int32, eta.shape, 1)
            onehot = (col == t_v).astype(eta.dtype)
            delta = softmax_delta_padded(eta, xi_ref[0], onehot, n_classes)
            delta = delta[:, None]
        else:
            s = jax.lax.dot_general(
                tile, theta_v, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (BR, 1)
            t_v = t_ref[0]
            xi_v = xi_ref[0]
            if family == "logistic":
                delta = logistic_delta(t_v * s, xi_v)
            else:
                delta = student_t_delta(t_v - s, xi_v, nu, sigma)

        row_id = base + jax.lax.broadcasted_iota(jnp.int32, (br, 1), 0)
        mask = row_id < nb_ref[ch, 0]
        delta_ref[0] = delta
        part = jnp.sum(jnp.where(mask, log_expm1(delta), 0.0))

        # TPU grid steps run sequentially in row-major (chain, tile) order,
        # so each chain's (1, 1) total block — mapped to the same slot for
        # every tile of that chain — is a race-free accumulator.
        @pl.when(i == 0)
        def _init():
            total_ref[0, 0, 0] = 0.0

        total_ref[0, 0, 0] += part

    kp = xi.shape[2] if family == "softmax" else 1
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # idx, n_bright
        grid=(k_chains, c // br),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # x: gathered by DMA
            pl.BlockSpec((1, br, 1), lambda ch, i, *_: (ch, i, 0)),  # t
            pl.BlockSpec((1, br, kp), lambda ch, i, *_: (ch, i, 0)),  # xi
            pl.BlockSpec((1, kt, dp), lambda ch, i, *_: (ch, 0, 0)),  # theta
        ],
        out_specs=[
            pl.BlockSpec((1, br, 1), lambda ch, i, *_: (ch, i, 0)),
            pl.BlockSpec((1, 1, 1), lambda ch, i, *_: (ch, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((br, dp), jnp.float32),
            pltpu.SemaphoreType.DMA((br,)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((k_chains, c, 1), jnp.float32),
            jax.ShapeDtypeStruct((k_chains, 1, 1), jnp.float32),
        ),
        interpret=interpret,
    )(idx, n_bright, x, t, xi, theta)


def bright_glm_pallas(
    x: jax.Array,  # (N, D) — unpadded; stays in HBM, rows DMA'd on demand
    t: jax.Array,  # (C, 1) f32 labels/responses, or int32 class ids (softmax)
    xi: jax.Array,  # (C, 1) f32, or (C, Kp) tangency logits (softmax)
    idx: jax.Array,  # (C,) int32 bright row ids, clamped to [0, N); C % BR == 0
    n_bright: jax.Array,  # (1,) int32
    theta: jax.Array,  # (1, Dp), or (Kp, Dp) zero-padded (softmax)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    n_classes: int = 0,
    block_rows: int = 8,
    interpret: bool = False,
):
    """Single-chain entry point: the ``num_chains == 1`` case of
    :func:`bright_glm_pallas_chains`. Returns (delta (C, 1), total (1, 1))."""
    delta, total = bright_glm_pallas_chains(
        x, t[None], xi[None], idx[None], n_bright[None], theta[None],
        family=family, nu=nu, sigma=sigma, n_classes=n_classes,
        block_rows=block_rows, interpret=interpret,
    )
    return delta[0], total[0]
