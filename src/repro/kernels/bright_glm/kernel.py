"""Pallas TPU kernel: fused gather + bound-corrected likelihood (FlyMC core).

TPU adaptation of the paper's "loop over bright data" (DESIGN.md §3.1): the
bright index buffer arrives as a *scalar-prefetch* operand, so each grid
step's BlockSpec index_map DMAs exactly the HBM rows of the bright points —
the gather never materializes in HBM. Per block of BR rows the kernel fuses:

    row · θ  (MXU)  →  log L, log B (VPU scalar math)  →  δ
    →  log(expm1 δ) masked  (the Alg.-1 line-19 factor)

Outputs per-row δ (reused as the z-kernel's cache, Alg. 2) and the masked
contribution; the O(C) reduction happens in the jit wrapper.

Layout: D is padded to a multiple of 128 lanes; BR rows (8-multiple
sublanes) per grid step. VMEM footprint per step: BR·Dp·4 + Dp·4 bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _logistic_delta(s, xi):
    """δ = log L - log B for the Jaakkola–Jordan bound, s = t·θᵀx."""
    safe = jnp.where(jnp.abs(xi) < 1e-4, 1.0, xi)
    a = -jnp.tanh(safe / 2.0) / (4.0 * safe)
    a = jnp.where(jnp.abs(xi) < 1e-4, -0.125 + xi * xi / 96.0, a)
    c = -a * xi * xi + xi / 2.0 - jax.nn.softplus(xi)
    log_l = -jax.nn.softplus(-s)
    log_b = a * s * s + 0.5 * s + c
    return log_l - log_b


def _student_t_delta(r, xi, nu, sigma):
    """δ for the tangent-in-r² Gaussian bound on the Student-t density."""
    z2 = (r / sigma) ** 2
    u0 = (xi / sigma) ** 2
    fprime = -((nu + 1.0) / 2.0) / (nu + u0)
    # log L - log B = f(z²) - [f(u₀) + f'(u₀)(z² - u₀)] with f's constants
    # cancelling:
    f_z = -((nu + 1.0) / 2.0) * jnp.log1p(z2 / nu)
    f_u0 = -((nu + 1.0) / 2.0) * jnp.log1p(u0 / nu)
    return f_z - (f_u0 + fprime * (z2 - u0))


def _log_expm1(d):
    d = jnp.maximum(d, 1e-10)
    small = d < 15.0
    d_small = jnp.where(small, d, 1.0)
    d_big = jnp.where(small, 20.0, d)
    return jnp.where(
        small,
        jnp.log(jnp.expm1(d_small)),
        d_big + jnp.log1p(-jnp.exp(-d_big)),
    )


def bright_glm_pallas(
    x: jax.Array,  # (N, Dp) — D padded to 128-lane multiple
    t: jax.Array,  # (N, 1)
    xi: jax.Array,  # (N, 1)
    idx: jax.Array,  # (C,) int32 bright row ids (padded; C % BR == 0)
    n_bright: jax.Array,  # () int32
    theta: jax.Array,  # (1, Dp)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
    block_rows: int = 8,
    interpret: bool = True,
):
    c = idx.shape[0]
    dp = x.shape[1]
    assert c % block_rows == 0, (c, block_rows)

    # One DMA per bright row: block (1, Dp) whose source row comes from the
    # scalar-prefetched index buffer. Pallas BlockSpec cannot express
    # per-sublane gathers within one block, so the row dimension is part of
    # the grid: grid = (C/BR, BR) with (1, Dp) blocks per step.
    def gather_im(i, r, idx_ref, nb_ref):
        return (idx_ref[i * block_rows + r], 0)

    grid = (c // block_rows, block_rows)

    def out_im(i, r, idx_ref, nb_ref):
        return (i * block_rows + r, 0)

    def kernel(idx_ref, nb_ref, x_ref, t_ref, xi_ref, theta_ref,
               delta_ref, contrib_ref):
        i, r = pl.program_id(0), pl.program_id(1)
        row = x_ref[...]  # (1, Dp)
        theta_v = theta_ref[...]
        s = jnp.sum(row * theta_v)
        t_v = t_ref[0, 0]
        xi_v = xi_ref[0, 0]
        if family == "logistic":
            delta = _logistic_delta(t_v * s, xi_v)
        else:
            delta = _student_t_delta(t_v - s, xi_v, nu, sigma)
        row_id = i * block_rows + r
        mask = row_id < nb_ref[0]
        delta_ref[0, 0] = delta
        contrib_ref[0, 0] = jnp.where(mask, _log_expm1(delta), 0.0)

    out_shape = (
        jax.ShapeDtypeStruct((c, 1), jnp.float32),
        jax.ShapeDtypeStruct((c, 1), jnp.float32),
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,  # idx, n_bright
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, dp), gather_im),  # x rows (gathered)
            pl.BlockSpec((1, 1), gather_im),  # t
            pl.BlockSpec((1, 1), gather_im),  # xi
            pl.BlockSpec((1, dp), lambda i, r, *_: (0, 0)),  # theta
        ],
        out_specs=[
            pl.BlockSpec((1, 1), out_im),
            pl.BlockSpec((1, 1), out_im),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(idx, jnp.reshape(n_bright, (1,)), x, t, xi, theta)
