"""Pure-jnp oracle for the bright-GLM kernel.

Computes, for a padded buffer of bright indices, the per-datum
δ_n = log L_n - log B_n and the masked pseudo-log-likelihood contribution
log(exp(δ)-1) — the inner loop of every FlyMC θ-update (paper §2, Alg. 1
line 19). Families: logistic (Jaakkola–Jordan bound) and student-t
(tangent bound); both reduce to a dot product plus scalar math per row.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bounds import LogisticBound, StudentTBound, GLMData
from repro.core.flymc import log_expm1


def bright_glm_ref(
    x: jax.Array,  # (N, D) features
    t: jax.Array,  # (N,) labels / responses
    xi: jax.Array,  # (N,) per-datum bound tightness
    idx: jax.Array,  # (C,) bright indices (padded)
    mask: jax.Array,  # (C,) validity
    theta: jax.Array,  # (D,)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
):
    """Returns (delta (C,), masked log-pseudo-likelihood contributions (C,))."""
    rows = GLMData(x=x[idx], t=t[idx], xi=xi[idx])
    if family == "logistic":
        ll = LogisticBound.log_lik(theta, rows)
        lb = LogisticBound.log_bound(theta, rows)
    elif family == "student_t":
        bound = StudentTBound(nu=nu, sigma=sigma)
        ll = bound.log_lik(theta, rows)
        lb = bound.log_bound(theta, rows)
    else:
        raise ValueError(family)
    delta = ll - lb
    contrib = jnp.where(mask, log_expm1(delta), 0.0)
    return delta, contrib
