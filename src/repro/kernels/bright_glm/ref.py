"""Pure-jnp oracle for the bright-GLM kernel.

Computes, for a padded buffer of bright indices, the per-datum
δ_n = log L_n - log B_n and the masked pseudo-log-likelihood contribution
log(exp(δ)-1) — the inner loop of every FlyMC θ-update (paper §2, Alg. 1
line 19). Families: logistic (Jaakkola–Jordan bound), student_t (tangent
bound) and softmax (Böhning bound); each reduces to a (batched) inner
product plus scalar math per row. Doubles as the backward pass of the
fused kernel's custom VJP (:mod:`repro.kernels.bright_glm.ops`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bounds import GLMData, LogisticBound, SoftmaxBound, StudentTBound
from repro.core.numerics import log_expm1


def bright_glm_ref(
    x: jax.Array,  # (N, D) features
    t: jax.Array,  # (N,) labels / responses / class ids
    xi: jax.Array,  # (N,) per-datum bound tightness ((N, K) for softmax)
    idx: jax.Array,  # (C,) bright indices (padded; entries clamped to [0, N))
    mask: jax.Array,  # (C,) validity
    theta: jax.Array,  # (D,)  ((K, D) for softmax)
    family: str = "logistic",
    nu: float = 4.0,
    sigma: float = 1.0,
):
    """Returns (delta (C,), masked log-pseudo-likelihood contributions (C,))."""
    idx = jnp.clip(idx, 0, x.shape[0] - 1)
    rows = GLMData(
        x=jnp.take(x, idx, axis=0),
        t=jnp.take(t, idx, axis=0),
        xi=jnp.take(xi, idx, axis=0),
    )
    if family == "logistic":
        ll = LogisticBound.log_lik(theta, rows)
        lb = LogisticBound.log_bound(theta, rows)
    elif family == "student_t":
        bound = StudentTBound(nu=nu, sigma=sigma)
        ll = bound.log_lik(theta, rows)
        lb = bound.log_bound(theta, rows)
    elif family == "softmax":
        ll = SoftmaxBound.log_lik(theta, rows)
        lb = SoftmaxBound.log_bound(theta, rows)
    else:
        raise ValueError(family)
    delta = ll - lb
    contrib = jnp.where(mask, log_expm1(delta), 0.0)
    return delta, contrib
