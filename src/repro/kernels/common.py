"""Utilities shared by every Pallas kernel package.

Extracted from ``kernels/bright_glm/ops.py`` once ``kernels/z_update``
started importing them cross-package: layout helpers (``pad_to``), the
off-TPU interpret-mode policy (``default_interpret``), index clamping for
padded gather buffers (``clamp_index``), and the chain-batching dispatch
switch shared by both kernel wrappers.

Chain batching
--------------
Both kernel entry points (:func:`repro.kernels.bright_glm.ops.bright_glm`
and :func:`repro.kernels.z_update.ops.z_candidates`) carry a
``jax.custom_batching.custom_vmap`` rule: when the driver batches a step
over the chain axis, each kernel lowers to ONE ``pallas_call`` whose grid
gains a leading ``num_chains`` dimension (per-chain scalars ride along as
2-D scalar-prefetch operands), instead of jax's default pallas batching —
which broadcasts every unbatched operand (a per-chain copy of the dataset
for the ANY-space feature matrix) and runs each chain's tiny workload as
its own degenerate launch.

``chain_batching(False)`` disables the rule and restores the default
vmap lowering — that is the baseline ``benchmarks/chain_scaling.py``
measures against, and what the batched-vs-vmap parity tests pin the
megakernels to, bitwise. The flag is read at trace time; callers that
toggle it must not reuse traces across values (the driver's jit cache
keys on it).

The sequential-grid-accumulator contract
----------------------------------------
Every kernel in this repo may use the *revisited-block accumulator*
idiom: an output BlockSpec whose index map ignores one grid axis, so all
steps along that axis address the same block and the kernel accumulates
into it (``pl.when(i == 0)`` init, ``ref[...] += part`` after —
bright's running total, z-update's candidate buffer and count, fused-ce's
``lse``/``tgt``, flash-decode's ``o/m/l``, the scan kernels' final
states). The idiom is exact only because TPU grids execute
**sequentially** (row-major, last axis fastest); under
``dimension_semantics=('parallel', ...)`` — or any future lowering with
parallel grid axes — the same BlockSpec is a write-write race.

Kernels therefore must (a) never mark a revisited output axis
``parallel``, and (b) *declare* each accumulator output when registering
with the analysis sweep (``repro.analysis.kernels.GridRaceRule``,
``accumulators={output_index: (revisited_axes...)}``) — the
``kernel-race`` rule flags undeclared accumulator-style writes and any
parallel-axis revisit, so the contract is checked on every commit rather
than remembered. Scratch initialization follows the same sequencing
assumption: a ``pl.when(first_step)`` init is ordered before every later
read only because the grid is sequential.
"""

from __future__ import annotations

from contextlib import contextmanager

import jax
import jax.numpy as jnp


def pad_to(d: int, mult: int) -> int:
    """Smallest multiple of ``mult`` that is >= ``d``."""
    return ((d + mult - 1) // mult) * mult


def default_interpret() -> bool:
    """Interpret-mode fallback: compile for real only on TPU backends."""
    return jax.default_backend() != "tpu"


def clamp_index(idx: jax.Array, n: int) -> jax.Array:
    """Clamp gather indices into ``[0, n)`` as int32.

    Padded buffer slots (capacity padding, candidate sentinels ``n``) are
    undefined for an in-kernel row DMA — clamp before every pallas_call,
    never trust the caller; clamped rows are computed and then masked.
    """
    return jnp.clip(idx.astype(jnp.int32), 0, n - 1)


def make_chain_dispatch(plain, chains_fn, n_shared: int = 0):
    """Wrap a single-chain pallas dispatch in the chain-batching rule.

    ``plain(*args)`` is the single-chain kernel call; ``chains_fn`` its
    chain-batched counterpart taking the same operands with a leading
    chain axis on every arg past the first ``n_shared`` (which stay
    UN-broadcast — the HBM-resident operands every chain shares). Returns
    a ``jax.custom_batching.custom_vmap`` function: unbatched calls run
    ``plain``; batching over the chain axis dispatches ONE ``chains_fn``
    launch (unbatched per-chain operands broadcast, shared ones passed
    through). Falls back to jax's default pallas batching — per-chain
    launches with every unbatched operand broadcast — when a shared
    operand is itself batched (per-chain datasets) or when
    :func:`chain_batching_enabled` is off (the benchmarked baseline).

    Shared by ``bright_glm/ops`` and ``z_update/ops`` so the dispatch
    subtleties (flag semantics, broadcast rule, fallback lowering) are
    encoded exactly once.
    """
    call = jax.custom_batching.custom_vmap(plain)

    @call.def_vmap
    def _rule(axis_size, in_batched, *args):
        flat_batched = jax.tree.leaves(in_batched)
        if any(flat_batched[:n_shared]) or not chain_batching_enabled():
            axes = tuple(0 if b else None for b in flat_batched)
            out = jax.vmap(plain, in_axes=axes)(*args)
        else:
            bcast = lambda a, b: a if b else jnp.broadcast_to(
                a[None], (axis_size,) + a.shape
            )
            out = chains_fn(
                *args[:n_shared],
                *(bcast(a, b) for a, b in zip(args[n_shared:],
                                              flat_batched[n_shared:])),
            )
        return out, jax.tree.map(lambda _: True, out)

    return call


_CHAIN_BATCHING = True


def chain_batching_enabled() -> bool:
    """Whether vmap over chains dispatches the chain-batched megakernels."""
    return _CHAIN_BATCHING


@contextmanager
def chain_batching(enabled: bool):
    """Temporarily enable/disable megakernel dispatch under vmap (trace-time
    flag; used by the chain-scaling benchmark and the parity tests)."""
    global _CHAIN_BATCHING
    prev = _CHAIN_BATCHING
    _CHAIN_BATCHING = bool(enabled)
    try:
        yield
    finally:
        _CHAIN_BATCHING = prev
