"""Pure-jnp oracle: sequential RG-LRU recurrence h_t = a_t·h_{t-1} + b_t."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_ref(log_a, bx, h0=None):
    """log_a, bx: (B, S, C); h0: (B, C). Returns (h (B,S,C), h_final)."""
    b, s, c = log_a.shape
    h = jnp.zeros((b, c), jnp.float32) if h0 is None else h0

    def step(hp, inp):
        la, bv = inp
        hn = jnp.exp(la) * hp + bv
        return hn, hn

    la = log_a.transpose(1, 0, 2).astype(jnp.float32)
    bv = bx.transpose(1, 0, 2).astype(jnp.float32)
    h, ys = jax.lax.scan(step, h, (la, bv))
    return ys.transpose(1, 0, 2), h
