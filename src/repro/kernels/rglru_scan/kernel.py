"""Pallas TPU kernel: chunked RG-LRU linear recurrence.

Grid (B, C/BC, S/c) with the seq-chunk dimension innermost and the per-
channel carry h in VMEM scratch. Within a chunk the recurrence h_t =
a_t·h_{t-1} + b_t is closed-form via cumulative log-decays (all VPU
elementwise, no MXU):

    h_i = exp(cumA_i)·h₀ + exp(cumA_i)·Σ_{j≤i} b_j·exp(-cumA_j)

The channel dimension is mapped to 128-lane blocks; the seq chunk to
sublanes (8-multiple).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def rglru_pallas(
    log_a: jax.Array,  # (B, n, c, C) f32, ≤ 0
    bx: jax.Array,  # (B, n, c, C)
    block_c: int = 128,
    interpret: bool = True,
):
    b, n, c, ch = log_a.shape
    assert ch % block_c == 0, (ch, block_c)
    grid = (b, ch // block_c, n)

    io = pl.BlockSpec((1, 1, c, block_c), lambda bi, gi, ci: (bi, ci, 0, gi))
    h_spec = pl.BlockSpec((1, 1, block_c), lambda bi, gi, ci: (bi, 0, gi))

    def kernel(a_ref, b_ref, y_ref, h_out_ref, h_scr):
        ci = pl.program_id(2)

        @pl.when(ci == 0)
        def _init():
            h_scr[...] = jnp.zeros_like(h_scr)

        la = a_ref[0, 0]  # (c, BC)
        bv = b_ref[0, 0]
        h0 = h_scr[...]  # (1, BC)

        cum = jnp.cumsum(la, axis=0)  # (c, BC), ≤ 0 decreasing
        # prefix sums of b_j·exp(-cumA_j); exp(+|cum|) bounded by clamp
        z = jnp.cumsum(bv * jnp.exp(-cum), axis=0)
        h = jnp.exp(cum) * (h0 + z)
        y_ref[0, 0] = h
        h_scr[...] = h[-1:, :]

        @pl.when(ci == pl.num_programs(2) - 1)
        def _out():
            h_out_ref[0, 0] = h_scr[...][0]

    out_shape = [
        jax.ShapeDtypeStruct((b, n, c, ch), jnp.float32),
        jax.ShapeDtypeStruct((b, 1, ch), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[io, io],
        out_specs=[io, h_spec],
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(log_a, bx)
