"""jit'd wrapper for the chunked RG-LRU kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import common
from repro.kernels.rglru_scan.kernel import rglru_pallas


@partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def rglru_scan(
    log_a: jax.Array,  # (B, S, C) ≤ 0
    bx: jax.Array,  # (B, S, C)
    chunk: int = 128,
    block_c: int = 128,
    interpret: bool | None = None,
):
    """Returns (h (B, S, C), h_final (B, C))."""
    if interpret is None:
        interpret = common.default_interpret()
    b, s, ch = log_a.shape
    c = min(chunk, s)
    assert s % c == 0
    n = s // c
    chp = common.pad_to(ch, block_c)
    pad = chp - ch

    def prep(t):
        t = t.astype(jnp.float32)
        if pad:
            t = jnp.pad(t, ((0, 0), (0, 0), (0, pad)))
        return t.reshape(b, n, c, chp)

    # padded channels have log_a = 0, b = 0 → h stays 0: harmless
    y, hf = rglru_pallas(prep(log_a), prep(bx), block_c=block_c,
                         interpret=interpret)
    return y.reshape(b, s, chp)[..., :ch], hf[:, 0, :ch]
