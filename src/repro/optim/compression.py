"""Gradient compression for the slow (DCN / pod) axis: int8 + error feedback.

At multi-pod scale the inter-pod reduction runs over DCN, an order of
magnitude slower than ICI. We compress that reduction 4× (f32 → int8):

    scale   = pmax(absmax(g + err)) over the pod axis   (shared scale)
    q       = round((g + err) / scale · 127)  ∈ int8
    g_hat   = psum(q) · scale / 127 / n_pods            (int32 accumulate)
    err'    = (g + err) − dequant(own q)                (error feedback)

Error feedback makes the *accumulated* quantization error feed into the next
step, which restores convergence to within noise of uncompressed SGD/Adam
(Karimireddy et al. 2019 — the standard result this implements).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compressed_pmean(g: jax.Array, err: jax.Array, axes):
    """Compressed mean-reduction of ``g`` over ``axes`` with error feedback.

    Returns (g_hat, err_new). With empty axes this is the identity (and err
    passes through untouched), so the same code path serves single-pod runs.
    """
    if not axes:
        return g, err
    n = 1
    for ax in axes:
        n *= jax.lax.axis_size(ax)
    gf = g.astype(jnp.float32) + err
    absmax = jnp.max(jnp.abs(gf))
    scale = jax.lax.pmax(absmax, tuple(axes)) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), tuple(axes))
    g_hat = total.astype(jnp.float32) * scale / n
    err_new = gf - q.astype(jnp.float32) * scale
    return g_hat, err_new


def init_error_state(grads_tree):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_tree
    )
