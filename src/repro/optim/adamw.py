"""AdamW, elementwise over arbitrarily sharded pytrees.

Because every parameter is stored fully sharded (ZeRO-3, DESIGN.md §5) and
gradients arrive via reduce-scatter in the same layout, the update is purely
local — zero optimizer-step communication. States are f32 regardless of the
parameter dtype.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: Any  # pytree like params, f32
    v: Any  # pytree like params, f32


def adamw_init(params, dtype=jnp.float32) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def adamw_update(
    grads,
    state: AdamWState,
    params,
    lr: jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_scale=None,
):
    t = state.step + 1
    tf = t.astype(jnp.float32)
    c1 = 1.0 - b1**tf
    c2 = 1.0 - b2**tf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        if grad_scale is not None:
            g = g * grad_scale  # fused clip: no scaled full-tree copy
        mf, vf = m.astype(jnp.float32), v.astype(jnp.float32)
        m2 = b1 * mf + (1.0 - b1) * g
        v2 = b2 * vf + (1.0 - b2) * g * g
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        p2 = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
        return m2.astype(m.dtype), v2.astype(v.dtype), p2.astype(p.dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_p = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=t, m=new_m, v=new_v)
