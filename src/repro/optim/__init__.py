"""Optimizers and gradient machinery (sharding-agnostic, elementwise)."""

from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamWState", "adamw_init", "adamw_update", "warmup_cosine"]
