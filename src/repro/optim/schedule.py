"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step,
    peak_lr: float = 3e-4,
    warmup_steps: int = 200,
    total_steps: int = 10_000,
    floor: float = 0.1,
):
    s = step.astype(jnp.float32)
    warm = peak_lr * jnp.minimum(1.0, s / max(warmup_steps, 1))
    frac = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0, 1)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(s < warmup_steps, warm, cos)
