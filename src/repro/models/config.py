"""Architecture configuration for the assigned LM families.

One frozen dataclass describes every supported architecture: dense decoder
LMs (GQA/SWA), MoE (top-k, optional dense residual), RG-LRU hybrids, RWKV6,
encoder-decoder (whisper) and VLM (llava — stub patch frontend).

Parallelism modes (DESIGN.md §5):
  * ``sp``  — sequence-parallel residual stream over the ``model`` axis.
    Attention is head-count agnostic (each shard runs all heads on its local
    seq rows against all-gathered K/V); MLP is Megatron-SP (AG → col/row
    parallel → RS). Used by all attention-dominant archs.
  * ``tp``  — replicated-seq residual stream; mixer states (RWKV/RG-LRU
    heads or features) and MLP hidden are sharded over ``model`` with one
    psum per sublayer. Used by recurrence archs where seq must stay local.
"""

from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense FFN path in parallel


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // n_heads
    qkv_bias: bool = False
    mlp: Literal["swiglu", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    rope_theta: float = 10_000.0
    swa_window: int | None = None  # sliding-window attention (mixtral)
    moe: MoEConfig | None = None
    # hybrid (recurrentgemma): repeating block pattern, e.g. ("rglru",
    # "rglru", "attn"); dense/moe archs use ("attn",) implicitly.
    block_pattern: tuple[str, ...] = ("attn",)
    local_attn_window: int | None = None  # rgemma local attention
    rnn_width: int = 0  # RG-LRU recurrence width (0 → d_model)
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stubbed frontend sequence length (audio frames)
    # vlm (llava): number of patch-embedding positions (stub frontend)
    patch_positions: int = 0
    parallel_mode: Literal["sp", "tp"] = "sp"
    # True when the architecture has a sub-quadratic decode path and should
    # run the long_500k shape (DESIGN.md §4).
    subquadratic: bool = False
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # Optimizer-state dtype: bf16 halves AdamW memory — required to fit
    # arctic-480b on 16 GB/chip at these mesh sizes (DESIGN.md §5).
    opt_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def rnn_dim(self) -> int:
        return self.rnn_width or self.d_model

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded for TP divisibility (Megatron-style)."""
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, ff, hd = self.d_model, self.d_ff, self.resolved_head_dim
        attn = d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
        if self.mlp == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        per_layer = 0.0
        for kind in _expand_pattern(self.block_pattern, self.n_layers):
            if kind == "attn":
                per_layer += attn + mlp
            elif kind == "rglru":
                r = self.rnn_dim
                per_layer += d * r * 3 + r * d + 2 * r + mlp  # in/gates/out
            elif kind == "rwkv":
                per_layer += 4 * d * d + d * d + 2 * d  # r,k,v,g,o + decay
                per_layer += mlp
        per_layer /= len(_expand_pattern(self.block_pattern, self.n_layers))
        total = self.n_layers * per_layer
        if self.moe is not None:
            moe_mlp = 3 * d * ff * self.moe.n_experts + d * self.moe.n_experts
            total += self.n_layers * (moe_mlp - (3 * d * ff if not self.moe.dense_residual else 0))
        total += self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
            total += self.n_layers * (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d)  # cross-attn
        return int(total)

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if self.moe is None:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_equiv = dataclasses.replace(self, moe=None)
        base = dense_equiv.n_params()
        # dense MLP already counted once; MoE activates top_k experts
        extra = (self.moe.top_k - 1) * 3 * d * ff * self.n_layers
        if self.moe.dense_residual:
            extra += self.moe.top_k * 3 * d * ff * self.n_layers
        return int(base + extra)


def _expand_pattern(pattern: tuple[str, ...], n_layers: int) -> tuple[str, ...]:
    reps = (n_layers + len(pattern) - 1) // len(pattern)
    return (pattern * reps)[:n_layers]


def layer_kinds(cfg: ModelConfig) -> tuple[str, ...]:
    return _expand_pattern(cfg.block_pattern, cfg.n_layers)


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A smoke-test-sized config of the same family (per the brief)."""
    small = dict(
        n_layers=min(cfg.n_layers, len(cfg.block_pattern) * 2),
        d_model=128,
        n_heads=max(2, min(cfg.n_heads, 4)),
        n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        rnn_width=128 if cfg.rnn_width else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_seq=min(cfg.encoder_seq, 32) if cfg.encoder_seq else 0,
        patch_positions=min(cfg.patch_positions, 16) if cfg.patch_positions else 0,
        swa_window=64 if cfg.swa_window else None,
        local_attn_window=32 if cfg.local_attn_window else None,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4)
        )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


# ---------------------------------------------------------------------------
# Input shapes assigned to every LM architecture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
