"""Bayesian GLMs for the paper's three experiments (§4.1–§4.3).

Bundles a collapsible bound, a prior, data and suff-stats into one object,
provides the full-data posterior (the "Regular MCMC" baseline of Table 1),
MAP estimation (for MAP-tuned bounds), and FlyMC spec construction.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import bounds as bounds_lib
from repro.core import flymc, samplers
from repro.core.bounds import GLMData


@dataclasses.dataclass
class GLMModel:
    bound: Any
    log_prior: Callable[[jax.Array], jax.Array]
    data: GLMData
    stats: bounds_lib.CollapsedStats
    theta_shape: tuple

    # ---- construction ------------------------------------------------------

    @classmethod
    def logistic(cls, data: GLMData, prior_scale: float = 1.0, xi: float = 1.5):
        """§4.1: logistic regression, Jaakkola–Jordan bound, Gaussian prior."""
        bound = bounds_lib.LogisticBound()
        data = bound.default_xi(data, xi)
        return cls(
            bound=bound,
            log_prior=partial(bounds_lib.gaussian_log_prior, scale=prior_scale),
            data=data,
            stats=bound.suffstats(data),
            theta_shape=(data.x.shape[1],),
        )

    @classmethod
    def softmax(cls, data: GLMData, n_classes: int, prior_scale: float = 1.0):
        """§4.2: softmax classification, Böhning bound, Gaussian prior."""
        bound = bounds_lib.SoftmaxBound()
        data = bound.default_xi(data, n_classes)
        return cls(
            bound=bound,
            log_prior=partial(bounds_lib.gaussian_log_prior, scale=prior_scale),
            data=data,
            stats=bound.suffstats(data),
            theta_shape=(n_classes, data.x.shape[1]),
        )

    @classmethod
    def robust(
        cls,
        data: GLMData,
        nu: float = 4.0,
        sigma: float = 1.0,
        prior_scale: float = 1.0,
    ):
        """§4.3: robust Student-t regression, tangent bound, Laplace prior."""
        bound = bounds_lib.StudentTBound(nu=nu, sigma=sigma)
        data = bound.default_xi(data)
        return cls(
            bound=bound,
            log_prior=partial(bounds_lib.laplace_log_prior, scale=prior_scale),
            data=data,
            stats=bound.suffstats(data),
            theta_shape=(data.x.shape[1],),
        )

    # ---- densities -----------------------------------------------------------

    def full_log_posterior(self, theta: jax.Array) -> jax.Array:
        """Exact full-data log posterior (the Regular-MCMC target)."""
        return self.log_prior(theta) + jnp.sum(
            self.bound.log_lik(theta, self.data)
        )

    def full_logpdf_fn(self) -> samplers.LogDensityFn:
        """(lp, aux) wrapper for core.samplers; aux is a dummy scalar."""

        def f(theta):
            return self.full_log_posterior(theta), jnp.zeros((), theta.dtype)

        return f

    # ---- MAP + bound tuning (paper §3.1 "tight in the right places") --------

    def map_estimate(
        self,
        key: jax.Array,
        steps: int = 500,
        lr: float = 0.05,
        theta0: jax.Array | None = None,
    ) -> jax.Array:
        """Adam ascent on the full-data log posterior (≈ the paper's SGD)."""
        if theta0 is None:
            theta0 = 0.01 * jax.random.normal(key, self.theta_shape)
        neg_lp = lambda th: -self.full_log_posterior(th)
        grad_fn = jax.grad(neg_lp)

        def body(carry, _):
            th, m, v, t = carry
            g = grad_fn(th)
            t = t + 1
            m = 0.9 * m + 0.1 * g
            v = 0.999 * v + 0.001 * g * g
            mh = m / (1.0 - 0.9**t)
            vh = v / (1.0 - 0.999**t)
            th = th - lr * mh / (jnp.sqrt(vh) + 1e-8)
            return (th, m, v, t), None

        init = (theta0, jnp.zeros_like(theta0), jnp.zeros_like(theta0), 0.0)
        (theta, _, _, _), _ = jax.lax.scan(body, init, None, length=steps)
        return theta

    def map_tuned(self, theta_map: jax.Array) -> "GLMModel":
        """Retighten bounds at θ_MAP and rebuild suff-stats (one-time cost)."""
        data = self.bound.tighten(theta_map, self.data)
        return dataclasses.replace(
            self, data=data, stats=self.bound.suffstats(data)
        )

    # ---- repro.api glue ------------------------------------------------------

    def algorithm(self, **kw):
        """FlyMC SamplingAlgorithm over this model (see repro.api.firefly)."""
        from repro import api

        return api.firefly(self, **kw)

    def baseline(self, **kw):
        """Full-data MCMC SamplingAlgorithm (see repro.api.regular_mcmc)."""
        from repro import api

        return api.regular_mcmc(self, **kw)

    # ---- deprecated FlyMC glue (thin wrappers over repro.api) ----------------

    def flymc_spec(
        self,
        kernel: str = "rwmh",
        capacity: int = 1024,
        cand_capacity: int = 1024,
        q_db: float = 0.01,
        mode: str = "implicit",
        **kw,
    ) -> flymc.FlyMCSpec:
        """Deprecated: use ``model.algorithm(...)`` / ``repro.api.firefly``."""
        n = self.data.x.shape[0]
        return flymc.FlyMCSpec(
            bound=self.bound,
            log_prior=self.log_prior,
            kernel=kernel,
            capacity=min(capacity, n),
            cand_capacity=min(cand_capacity, n),
            q_db=q_db,
            mode=mode,
            **kw,
        )

    def init_chain(self, spec, theta0, key, **kw):
        """Deprecated: use ``repro.api.sample`` (it initializes internally)."""
        return flymc.init_chain(spec, self.data, self.stats, theta0, key, **kw)

    def run_chain(self, spec, state, num_iters, **kw):
        """Deprecated: delegates to the repro.api device-resident driver."""
        return flymc.run_chain(
            spec, self.data, self.stats, state, num_iters, **kw
        )


def run_regular_mcmc(
    model: GLMModel,
    theta0: jax.Array,
    key: jax.Array,
    num_iters: int,
    kernel: str = "rwmh",
    step_size: float = 0.05,
    **kernel_kwargs,
):
    """Full-data MCMC baseline (deprecated shim over repro.api.regular_mcmc).

    Returns (samples, lik_queries_per_iter list) like the original host loop,
    but runs on device through the chunked-scan driver.
    """
    from repro import api

    alg = api.regular_mcmc(
        model, kernel=kernel, step_size=step_size,
        kernel_params=tuple(kernel_kwargs.items()),
    )
    trace = api.sample(alg, key, num_iters, init_position=theta0)
    samples = list(jax.device_get(trace.theta[0]))
    queries = [int(q) for q in jax.device_get(trace.stats.lik_queries[0])]
    return samples, queries
