"""Serving: prefill + single-token decode for every architecture family.

Cache design (DESIGN.md §5):
  * Attention layers — ring KV cache of capacity W (= full context for dense
    archs, = window for SWA/local-attn archs, which is what makes mixtral /
    recurrentgemma sub-quadratic at 500k). The ring is *sequence-sharded*
    over the ``model`` axis (context parallelism — head-count agnostic);
    decode computes shard-local partial attention and merges the online-
    softmax statistics with one pmax + two psums (flash-decode across chips).
    A parallel ``pos`` buffer stores absolute positions (-1 = empty) so
    causal/window masking works under ring wraparound.
  * RWKV6 — per-head WKV state (B, H_loc, hd, hd) + token-shift caches.
  * RG-LRU — per-channel state (B, r_loc) + depthwise-conv history.

Decode keeps the training parameter layout (ZeRO-3 gathers per layer) as the
*paper-faithful baseline*; §Perf swaps in the serving-optimized layout.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import par as P
from repro.distributed.par import Par, WSpec
from repro.models import layers as L
from repro.models.config import ModelConfig, layer_kinds
from repro.models.transformer import _tree_index, _unstack_spec

Tree = dict[str, Any]


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def serve_kv_heads(cfg: ModelConfig, mp: int) -> int:
    """KV heads stored per shard under TP serving: max(1, Hk/mp)."""
    h_loc = cfg.n_heads // mp
    g_global = cfg.n_heads // cfg.n_kv_heads
    return max(1, h_loc // g_global)


def attn_cache_len(cfg: ModelConfig, kind: str, seq_len: int) -> int:
    if kind == "attn" and cfg.swa_window:
        return min(cfg.swa_window, seq_len)
    if kind == "attn" and cfg.local_attn_window:
        return min(cfg.local_attn_window, seq_len)
    return seq_len


def _slot_cache_shapes(
    cfg: ModelConfig, kind: str, b: int, seq_len: int, par: Par,
    kv_dtype=jnp.bfloat16, serve_tp: bool = False,
):
    hd = cfg.resolved_head_dim
    mp = max(par.mp_size, 1)
    if kind == "attn":
        w = attn_cache_len(cfg, kind, seq_len)
        # SP archs: ring seq-sharded over model (context parallel decode).
        # TP serving (§Perf iteration C2): full window per shard but only
        # the kv-head slice this shard's query heads attend — Hk/mp heads
        # (min 1; shards within a GQA group duplicate that head).
        seq_shard = cfg.parallel_mode == "sp" and not serve_tp and w % mp == 0
        w_loc = w // mp if seq_shard else w
        kv_heads = serve_kv_heads(cfg, mp) if serve_tp else cfg.n_kv_heads
        return {
            "k": ((b, w_loc, kv_heads, hd), kv_dtype),
            "v": ((b, w_loc, kv_heads, hd), kv_dtype),
            "pos": ((w_loc,), jnp.int32),
        }
    if kind == "rwkv":
        h_loc = cfg.n_heads // mp
        d = cfg.d_model
        return {
            "state": ((b, h_loc, hd, hd), jnp.float32),
            "shift_tm": ((b, d), jnp.float32),
            "shift_cm": ((b, d), jnp.float32),
        }
    if kind == "rglru":
        r_loc = cfg.rnn_dim // mp if cfg.rnn_dim % mp == 0 else cfg.rnn_dim
        return {
            "state": ((b, r_loc), jnp.float32),
            "conv": ((b, 3, r_loc), jnp.float32),
        }
    raise ValueError(kind)


def init_cache(
    cfg: ModelConfig, b_local: int, seq_len: int, par: Par,
    kv_dtype=jnp.bfloat16, serve_tp: bool = False,
) -> Tree:
    """Zero-initialized local cache shards (pos = -1 ⇒ empty)."""
    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    kinds = layer_kinds(cfg)

    def make(shapes, groups):
        out = {}
        for name, (shape, dt) in shapes.items():
            full = (groups,) + shape if groups else shape
            init = -jnp.ones(full, dt) if name == "pos" else jnp.zeros(full, dt)
            out[name] = init
        return out

    cache: Tree = {"t": jnp.zeros((), jnp.int32)}
    if n_groups:
        cache["blocks"] = {
            f"slot{i}": make(
                _slot_cache_shapes(
                    cfg, cfg.block_pattern[i], b_local, seq_len, par,
                    kv_dtype, serve_tp,
                ),
                n_groups,
            )
            for i in range(p)
        }
    for j in range(rem):
        cache[f"extra{j}"] = make(
            _slot_cache_shapes(
                cfg, kinds[n_groups * p + j], b_local, seq_len, par,
                kv_dtype, serve_tp,
            ),
            0,
        )
    if cfg.family == "encdec":
        # Cross-attention K/V computed once from the encoder at prefill.
        mp = max(par.mp_size, 1)
        hd = cfg.resolved_head_dim
        ck = {
            "ck": jnp.zeros(
                (n_groups, b_local, cfg.encoder_seq // mp, cfg.n_kv_heads, hd),
                kv_dtype,
            ),
            "cv": jnp.zeros(
                (n_groups, b_local, cfg.encoder_seq // mp, cfg.n_kv_heads, hd),
                kv_dtype,
            ),
        }
        for i in range(p):
            cache["blocks"][f"slot{i}"].update(jax.tree.map(lambda x: x, ck))
    return cache


def cache_pspecs(cfg: ModelConfig, seq_len: int, par: Par, mesh_sizes,
                 serve_tp: bool = False):
    """PartitionSpecs matching init_cache's local shapes (for shard_map)."""
    from jax.sharding import PartitionSpec as PS

    mp = par.mp if par.mp else None
    dp = par.dp if par.dp else None

    def spec_for(name, kind, groups):
        lead = (None,) if groups else ()
        if kind == "attn":
            w = attn_cache_len(cfg, kind, seq_len)
            seq_ok = (
                cfg.parallel_mode == "sp" and not serve_tp
                and w % max(par.mp_size, 1) == 0
            )
            seq_ax = mp if (mp and seq_ok) else None
            head_ax = mp if (mp and serve_tp) else None
            if name in ("k", "v"):
                return PS(*lead, dp, seq_ax, head_ax, None)
            if name == "pos":
                return PS(*lead, seq_ax)
        if kind == "rwkv":
            if name == "state":
                return PS(*lead, dp, mp, None, None)
            return PS(*lead, dp, None)
        if kind == "rglru":
            seq_ax = mp if (mp and cfg.rnn_dim % max(par.mp_size, 1) == 0) else None
            if name == "state":
                return PS(*lead, dp, seq_ax)
            return PS(*lead, dp, None, seq_ax)
        if name in ("ck", "cv"):
            return PS(None, dp, mp, None, None)
        raise ValueError((name, kind))

    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    kinds = layer_kinds(cfg)
    specs: Tree = {"t": PS()}
    if n_groups:
        specs["blocks"] = {}
        for i in range(p):
            kind = cfg.block_pattern[i]
            names = _slot_cache_shapes(
                cfg, kind, 1, seq_len, par, serve_tp=serve_tp
            ).keys()
            d = {n: spec_for(n, kind, True) for n in names}
            if cfg.family == "encdec":
                d["ck"] = spec_for("ck", kind, True)
                d["cv"] = spec_for("cv", kind, True)
            specs["blocks"][f"slot{i}"] = d
    for j in range(rem):
        kind = kinds[n_groups * p + j]
        names = _slot_cache_shapes(
            cfg, kind, 1, seq_len, par, serve_tp=serve_tp
        ).keys()
        specs[f"extra{j}"] = {n: spec_for(n, kind, False) for n in names}
    return specs


# ---------------------------------------------------------------------------
# Decode-time sublayers
# ---------------------------------------------------------------------------


def _ring_write(buf, pos_buf, new, t, w_total, par: Par, seq_sharded: bool):
    """Write `new` (B,1,H,D) into the ring at absolute position t."""
    slot = t % w_total
    if seq_sharded and par.mp:
        w_loc = buf.shape[1]
        owner = slot // w_loc
        local = slot - owner * w_loc
        me = P.axis_index(par.mp)
        write = owner == me
    else:
        local = slot
        write = jnp.bool_(True)
    cur_k = jax.lax.dynamic_slice_in_dim(buf, local, 1, 1)
    upd = jnp.where(write, new.astype(buf.dtype), cur_k)
    buf = jax.lax.dynamic_update_slice_in_dim(buf, upd, local, 1)
    cur_p = jax.lax.dynamic_slice_in_dim(pos_buf, local, 1, 0)
    updp = jnp.where(write, jnp.full_like(cur_p, t), cur_p)
    pos_buf = jax.lax.dynamic_update_slice_in_dim(pos_buf, updp, local, 0)
    return buf, pos_buf


def _decode_attend(q, kbuf, vbuf, pos_buf, t, window, par: Par, merge_axes):
    """Flash-decode over the local ring shard + cross-shard softmax merge.

    q: (B, 1, H, D); kbuf/vbuf: (B, W_loc, Hk, D); pos_buf: (W_loc,).
    """
    b, _, h, d = q.shape
    hk = kbuf.shape[2]
    g = h // hk
    qf = q.astype(jnp.float32).reshape(b, hk, g, d) / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, kbuf.astype(jnp.float32))
    valid = (pos_buf >= 0) & (pos_buf <= t)
    if window is not None:
        valid &= pos_buf > t - window
    s = jnp.where(valid[None, None, None], s, -1e30)
    m = jnp.max(s, axis=-1)
    m_g = P.pmax(m, merge_axes)
    p = jnp.exp(s - m_g[..., None])
    l = P.psum(jnp.sum(p, -1), merge_axes)
    o = jnp.einsum("bhgc,bchd->bhgd", p, vbuf.astype(jnp.float32))
    o = P.psum(o, merge_axes)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, h, d)


def _attn_decode(x, w, ws, cache, cfg: ModelConfig, par: Par, t, seq_len,
                 kind_window, cross_enc=False, serve_tp=False):
    """x: (B,1,d) replicated over model. Returns (y, cache').

    SP archs: all heads locally, ring seq-sharded over model; partial
    softmaxes merged with pmax+psums (context-parallel flash decode).
    TP archs: heads sharded over model, replicated full-window ring;
    one psum after the (row-parallel) out-projection.
    """
    dtype = x.dtype
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    tp_attn = cfg.parallel_mode == "tp" or serve_tp
    h_loc = cfg.n_heads // max(par.mp_size, 1) if tp_attn else cfg.n_heads

    def proj(name, src):
        wt = P.gather_param(w[name], ws[name], dtype)
        y = src @ wt
        bias = "b" + name[1]
        if bias in w:
            y = y + P.gather_param(w[bias], ws[bias], dtype)
        return y

    q = proj("wq", x).reshape(b, 1, h_loc, hd)
    k = proj("wk", x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = proj("wv", x).reshape(b, 1, cfg.n_kv_heads, hd)
    pos = jnp.full((1,), t, jnp.int32)
    q = L.rope(q, pos, cfg.rope_theta)
    k = L.rope(k, pos, cfg.rope_theta)

    w_total = attn_cache_len(cfg, "attn", seq_len)
    seq_sharded = (
        not tp_attn
        and par.mp is not None
        and w_total % max(par.mp_size, 1) == 0
    )
    if serve_tp and h_loc < cfg.n_heads:
        # §Perf C2: the ring stores only this shard's kv-head slice; slice
        # the freshly projected kv before writing (GQA-aligned).
        g_global = cfg.n_heads // cfg.n_kv_heads
        n_kv_loc = max(1, h_loc // g_global)
        start = (P.axis_index(par.mp) * h_loc) // g_global
        k = jax.lax.dynamic_slice_in_dim(k, start, n_kv_loc, 2)
        v = jax.lax.dynamic_slice_in_dim(v, start, n_kv_loc, 2)
    kbuf, pbuf = _ring_write(cache["k"], cache["pos"], k, t, w_total, par, seq_sharded)
    vbuf, _ = _ring_write(cache["v"], cache["pos"], v, t, w_total, par, seq_sharded)
    merge = (par.mp,) if (par.mp and seq_sharded) else ()
    out = _decode_attend(q, kbuf, vbuf, pbuf, t, kind_window, par, merge)
    out = out.astype(dtype).reshape(b, 1, h_loc * hd)
    y = out @ P.gather_param(w["wo"], ws["wo"], dtype)
    if tp_attn:
        y = P.psum(y, (par.mp,) if par.mp else ())
    new_cache = {**cache, "k": kbuf, "v": vbuf, "pos": pbuf}
    return y, new_cache


def _cross_decode(x, w, ws, cache, cfg: ModelConfig, par: Par):
    """Whisper cross-attention at decode: q vs precomputed encoder K/V."""
    dtype = x.dtype
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    wq = P.gather_param(w["wq"], ws["wq"], dtype)
    q = (x @ wq).reshape(b, 1, cfg.n_heads, hd)
    ck, cv = cache["ck"], cache["cv"]  # (B, S_enc_loc, Hk, D)
    pos_buf = jnp.arange(ck.shape[1], dtype=jnp.int32)
    merge = (par.mp,) if par.mp else ()
    out = _decode_attend(
        q, ck, cv, pos_buf, jnp.int32(10**9), None, par, merge
    )
    out = out.astype(dtype).reshape(b, 1, cfg.q_dim)
    return out @ P.gather_param(w["wo"], ws["wo"], dtype)


def _rwkv_decode(x, w, ws, cache, cfg: ModelConfig, par: Par):
    """Single-step RWKV6: time mix + channel mix with cached shift/state."""
    dtype = x.dtype
    b, _, d = x.shape
    hd = cfg.resolved_head_dim
    h_loc = cfg.n_heads // max(par.mp_size, 1)
    g_ = lambda n: P.gather_param(w[n], ws[n], dtype)

    xt = x[:, 0].astype(jnp.float32)  # (B, d)
    mu = P.gather_param(w["mu"], ws["mu"], jnp.float32)
    xprev = cache["shift_tm"]
    mix = lambda i: (xt + mu[i] * (xprev - xt)).astype(dtype)

    r = (mix(0) @ g_("wr")).astype(jnp.float32).reshape(b, h_loc, hd)
    k = (mix(1) @ g_("wk")).astype(jnp.float32).reshape(b, h_loc, hd)
    v = (mix(2) @ g_("wv")).astype(jnp.float32).reshape(b, h_loc, hd)
    gate = mix(3) @ g_("wg")
    w0 = P.gather_param(w["w0"], ws["w0"], jnp.float32)
    lora = (jnp.tanh(mix(4) @ g_("wa")) @ g_("wb")).astype(jnp.float32)
    logw = jnp.clip(-jnp.exp(jnp.clip(w0 + lora, -8.0, 8.0)), -1.0, -1e-6)
    wdec = jnp.exp(logw).reshape(b, h_loc, hd)

    u = P.gather_param(w["u"], ws["u"], jnp.float32)
    S = cache["state"]  # (B, h_loc, hd, hd)
    y = jnp.einsum("bhd,bhde->bhe", r, S) + jnp.einsum(
        "bhd,hd,bhd,bhe->bhe", r, u, k, v
    )
    S_new = wdec[..., None] * S + jnp.einsum("bhd,bhe->bhde", k, v)

    ln = P.gather_param(w["ln_x"], ws["ln_x"], jnp.float32).reshape(h_loc, hd)
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6) * ln
    yn = yn.reshape(b, 1, h_loc * hd).astype(dtype)
    out = (yn * jax.nn.silu(gate[:, None])) @ g_("wo")
    out = P.psum(out, (par.mp,) if par.mp else ())
    new_cache = {**cache, "state": S_new, "shift_tm": xt}
    return out, new_cache


def _rwkv_cm_decode(x, w, ws, cache, cfg: ModelConfig, par: Par):
    dtype = x.dtype
    xt = x[:, 0].astype(jnp.float32)
    xk = (0.5 * (xt + cache["shift_cm"])).astype(dtype)
    r = jax.nn.sigmoid(xk @ P.gather_param(w["cm_r"], ws["cm_r"], dtype))
    h = jnp.square(jax.nn.relu(xk @ P.gather_param(w["cm_k"], ws["cm_k"], dtype)))
    y = h @ P.gather_param(w["cm_v"], ws["cm_v"], dtype)
    y = P.psum(y, (par.mp,) if par.mp else ())
    return (r * y)[:, None], {**cache, "shift_cm": xt}


def _rglru_decode(x, w, ws, cache, cfg: ModelConfig, par: Par):
    dtype = x.dtype
    b = x.shape[0]
    g_ = lambda n: P.gather_param(w[n], ws[n], dtype)
    xt = x[:, 0]
    bx = xt @ g_("wx")  # (B, r_loc)
    hist = cache["conv"]  # (B, 3, r_loc)
    kern = g_("conv")  # (4, r_loc)
    seq = jnp.concatenate([hist, bx[:, None]], axis=1)  # (B, 4, r)
    bconv = jnp.einsum("bkr,kr->br", seq, kern)
    a_gate = jax.nn.sigmoid((xt @ g_("wa")).astype(jnp.float32))
    i_gate = jax.nn.sigmoid((xt @ g_("wi")).astype(jnp.float32))
    lam = jax.nn.softplus(P.gather_param(w["lam"], ws["lam"], jnp.float32))
    log_a = jnp.clip(-L._RGLRU_C * lam * a_gate, -60.0, -1e-6)
    beta = jnp.sqrt(1.0 - jnp.exp(2.0 * log_a))
    h = jnp.exp(log_a) * cache["state"] + beta * (
        i_gate * bconv.astype(jnp.float32)
    )
    gate = jax.nn.gelu(xt @ g_("wgate"))
    y = ((h.astype(dtype) * gate) @ g_("wo"))[:, None]
    y = P.psum(y, (par.mp,) if par.mp else ())
    new_cache = {
        **cache,
        "state": h,
        "conv": jnp.concatenate([hist[:, 1:], bx[:, None].astype(jnp.float32)], 1),
    }
    return y, new_cache


# ---------------------------------------------------------------------------
# Full decode step
# ---------------------------------------------------------------------------


def _decode_block(x, w, ws, cache, cfg, par, kind, t, seq_len,
                  serve_tp=False):
    dtype = x.dtype
    if kind == "attn":
        h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
        win = cfg.swa_window or cfg.local_attn_window
        a, cache = _attn_decode(h, w["attn"], ws["attn"], cache, cfg, par, t,
                                seq_len, win, serve_tp=serve_tp)
        x = x + a
        if "cross" in w:
            h = L.apply_norm(x, w["ln_cross"], ws["ln_cross"], cfg.norm, dtype)
            x = x + _cross_decode(h, w["cross"], ws["cross"], cache, cfg, par)
        h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
        if cfg.moe is not None:
            b, _, d = h.shape
            gathered = tuple(
                P.gather_param(w["ffn"][n], ws["ffn"][n], dtype)
                for n in ("router", "w1", "w2", "w3")
            )
            y, _ = L._moe_tokens(h.reshape(b, d), gathered, cfg)
            y = y.reshape(b, 1, d)
            if "dense" in w["ffn"]:
                dw = tuple(
                    P.gather_param(w["ffn"]["dense"][n], ws["ffn"]["dense"][n], dtype)
                    for n in ("w1", "w2", "w3")
                )
                y = y + L._mlp_core(h, dw[0], dw[1], dw[2], "swiglu")
            y = P.psum(y, (par.mp,) if par.mp else ())
        else:
            y = L.mlp_tp(h, w["ffn"], ws["ffn"], cfg, par)
        return x + y, cache
    if kind == "rwkv":
        h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
        a, cache = _rwkv_decode(h, w["mix"], ws["mix"], cache, cfg, par)
        x = x + a
        h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
        y, cache = _rwkv_cm_decode(h, w["mix"], ws["mix"], cache, cfg, par)
        return x + y, cache
    if kind == "rglru":
        h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
        a, cache = _rglru_decode(h, w["mix"], ws["mix"], cache, cfg, par)
        x = x + a
        h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
        return x + L.mlp_tp(h, w["ffn"], ws["ffn"], cfg, par), cache
    raise ValueError(kind)


def vocab_parallel_argmax(logits, par: Par):
    """Greedy sampling over vocab-sharded logits. logits: (B, 1, V_loc)."""
    v_loc = logits.shape[-1]
    shard = P.axis_index(par.mp)
    local_max = jnp.max(logits, -1)
    local_arg = jnp.argmax(logits, -1).astype(jnp.int32) + shard * v_loc
    axes = (par.mp,) if par.mp else ()
    m = P.pmax(local_max, axes)
    winner = jnp.where(local_max >= m, local_arg, jnp.int32(2**30))
    return -P.pmax(-winner, axes)  # pmin


def decode_step(
    params: Tree,
    specs: Tree,
    cache: Tree,
    token: jax.Array,  # (B, 1) int32 — current input token
    cfg: ModelConfig,
    par: Par,
    seq_len: int,
    dtype=jnp.bfloat16,
    serve_tp: bool = False,
):
    """One serve step: token_t → (next_token, logits over local vocab shard,
    updated cache). ``cache['t']`` is the absolute position of `token`.

    ``serve_tp``: TP-resident serving layout (§Perf iteration C) — weights
    stay sharded over `model` (head-parallel attention, replicated window
    ring), no per-layer FSDP gathers."""
    t = cache["t"]
    x = L.embed_tokens(token, params["embed"], specs["embed"], cfg, par, dtype, sp=False)

    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    kinds = layer_kinds(cfg)
    new_cache: Tree = {"t": t + 1}

    if n_groups:
        slots = sorted(params["blocks"].keys())
        new_cache["blocks"] = {}

        def body(carry, inp):
            xg = carry
            idx = inp
            updated = []
            for si, slot in enumerate(slots):
                wsl = _tree_index(params["blocks"][slot], idx)
                cs = _tree_index(cache["blocks"][slot], idx)
                ws_ = jax.tree.map(
                    _unstack_spec, specs["blocks"][slot],
                    is_leaf=lambda s: isinstance(s, WSpec),
                )
                xg, cs2 = _decode_block(
                    xg, wsl, ws_, cs, cfg, par, cfg.block_pattern[si], t,
                    seq_len, serve_tp=serve_tp,
                )
                updated.append(cs2)
            return xg, tuple(updated)

        x, stacked = jax.lax.scan(body, x, jnp.arange(n_groups))
        for si, slot in enumerate(slots):
            new_cache["blocks"][slot] = stacked[si]

    for j in range(rem):
        x, cs2 = _decode_block(
            x, params[f"extra{j}"], specs[f"extra{j}"], cache[f"extra{j}"],
            cfg, par, kinds[n_groups * p + j], t, seq_len, serve_tp=serve_tp,
        )
        new_cache[f"extra{j}"] = cs2

    x = L.apply_norm(x, params["final_norm"], specs["final_norm"], cfg.norm, dtype)
    head = P.gather_param(params["embed"]["head"], specs["embed"]["head"], dtype)
    logits = (x @ head).astype(jnp.float32)  # (B, 1, V_loc)
    next_token = vocab_parallel_argmax(logits, par)
    return next_token, logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def _ring_from_full(kf, vf, prompt_len: int, w_total: int, par: Par):
    """Assemble ring-cache shards from full-sequence K/V.

    kf/vf: (..., B, S, Hk, D) with the prompt along axis -3. Returns the
    (k, v, pos) ring triple holding the last ``w_total`` positions, laid out
    so that slot s holds absolute position p ≡ s (mod w_total).
    """
    s = prompt_len
    mp = max(par.mp_size, 1)
    seq_sharded = par.mp is not None and w_total % mp == 0
    w_loc = w_total // mp if seq_sharded else w_total
    shard = P.axis_index(par.mp) if seq_sharded else jnp.int32(0)
    slots = shard * w_loc + jnp.arange(w_loc, dtype=jnp.int32)
    # largest p ≤ s-1 with p ≡ slot (mod W)
    p = slots + ((s - 1 - slots) // w_total) * w_total
    valid = (p >= 0) & (p < s) & (p > s - 1 - w_total)
    idx = jnp.clip(p, 0, s - 1)
    k = jnp.take(kf, idx, axis=-3)
    v = jnp.take(vf, idx, axis=-3)
    pos = jnp.where(valid, p, -1)
    return k, v, pos


def prefill(
    params: Tree,
    specs: Tree,
    batch: Tree,  # tokens (B, S) (+frames/patches)
    cfg: ModelConfig,
    par: Par,
    seq_len: int,
    dtype=jnp.bfloat16,
    kv_dtype=jnp.bfloat16,
):
    """Process a full prompt; returns (cache, hidden (B, S_loc|S, d)).

    The forward runs the normal flash/chunked training path; capture hooks
    collect per-layer K/V (attention) or final states (recurrence) and this
    function lays them out into the decode cache."""
    from repro.models import transformer as T

    h, _, captured = T.forward_hidden(
        params, specs, cfg, par, batch, dtype, remat=True, capture=True
    )
    s_prompt = batch["tokens"].shape[1]
    mp = max(par.mp_size, 1)
    cache: Tree = {"t": jnp.asarray(s_prompt, jnp.int32)}

    def assemble(cap: Tree, kind: str) -> Tree:
        out: Tree = {}
        if kind == "attn":
            kf, vf = cap["kv_full"]
            w_total = attn_cache_len(cfg, "attn", seq_len)
            k, v, pos = _ring_from_full(kf, vf, s_prompt, w_total, par)
            if kf.ndim == 5:  # stacked over groups → pos broadcast per group
                pos = jnp.broadcast_to(pos, (kf.shape[0],) + pos.shape)
            out.update({"k": k.astype(kv_dtype), "v": v.astype(kv_dtype), "pos": pos})
            if "cross_kv_full" in cap:
                ckf, cvf = cap["cross_kv_full"]  # (..., B, S_enc, Hk, D)
                s_enc = ckf.shape[-3]
                loc = s_enc // mp
                shard = P.axis_index(par.mp)
                start = shard * loc if par.mp else jnp.int32(0)
                ax = ckf.ndim - 3
                out["ck"] = jax.lax.dynamic_slice_in_dim(ckf, start, loc, ax).astype(kv_dtype)
                out["cv"] = jax.lax.dynamic_slice_in_dim(cvf, start, loc, ax).astype(kv_dtype)
            return out
        if kind == "rwkv":
            return {
                "state": cap["state"],
                "shift_tm": cap["shift_tm"],
                "shift_cm": cap["shift_cm"],
            }
        if kind == "rglru":
            return {"state": cap["state"], "conv": cap["conv"]}
        raise ValueError(kind)

    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    kinds = layer_kinds(cfg)
    if n_groups:
        cache["blocks"] = {
            slot: assemble(cap, cfg.block_pattern[int(slot[4:])])
            for slot, cap in captured["blocks"].items()
        }
    for j in range(rem):
        cache[f"extra{j}"] = assemble(
            captured[f"extra{j}"], kinds[n_groups * p + j]
        )
    return cache, h
