"""Model zoo: the paper's GLMs + the assigned LM architectures."""
