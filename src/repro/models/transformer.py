"""Transformer assembly: specs, forward, loss, train step.

A model is a dict pytree of parameters plus a mirrored dict of WSpecs.
Layers are stacked per pattern-slot and executed with one lax.scan over
layer groups (compile time independent of depth); the remainder layers of a
non-divisible pattern (e.g. recurrentgemma's 38 = 12×3 + 2) are unrolled.

Families:
  dense / moe / vlm — decoder-only, SP mode
  ssm / hybrid      — RWKV6 / RG-LRU (+ local attention), TP mode
  encdec            — whisper: SP encoder + SP decoder with cross-attention
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.distributed import par as P
from repro.distributed.par import Par, WSpec
from repro.models import layers as L
from repro.models.config import ModelConfig, layer_kinds
from repro.optim import adamw_init, adamw_update, warmup_cosine

Tree = dict[str, Any]


# ---------------------------------------------------------------------------
# Spec assembly
# ---------------------------------------------------------------------------


def _slot_defs(
    cfg: ModelConfig, kind: str, cross: bool = False, serve_tp: bool = False
) -> Tree:
    d = cfg.d_model
    if kind == "attn":
        defs: Tree = {
            "ln1": L.norm_defs(d),
            "attn": (
                L.attn_defs(cfg)
                if cfg.parallel_mode == "sp" and not serve_tp
                else L.attn_tp_defs(cfg)
            ),
            "ln2": L.norm_defs(d),
            "ffn": L.moe_defs(cfg) if cfg.moe is not None else L.mlp_defs(cfg),
        }
        if cross:
            defs["ln_cross"] = L.norm_defs(d)
            defs["cross"] = L.attn_defs(cfg, cross=True)
        return defs
    if kind == "rglru":
        return {
            "ln1": L.norm_defs(d),
            "mix": L.rglru_defs(cfg),
            "ln2": L.norm_defs(d),
            "ffn": L.mlp_defs(cfg),
        }
    if kind == "rwkv":
        return {
            "ln1": L.norm_defs(d),
            "ln2": L.norm_defs(d),
            "mix": L.rwkv_defs(cfg),
        }
    raise ValueError(kind)


def _stack_defs(defs: Tree, n: int) -> Tree:
    """Prefix a group dimension onto every WDef in a subtree."""

    def walk(x):
        if isinstance(x, dict):
            return {k: walk(v) for k, v in x.items()}
        return dataclasses.replace(
            x,
            shape=(n,) + x.shape,
            tp_dim=None if x.tp_dim is None else x.tp_dim + 1,
            fsdp_pref=tuple(d + 1 for d in x.fsdp_pref),
        )

    return walk(defs)


def model_defs(cfg: ModelConfig, serve_tp: bool = False) -> Tree:
    kinds = layer_kinds(cfg)
    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    cross = cfg.family == "encdec"

    defs: Tree = {"embed": L.embed_defs(cfg), "final_norm": L.norm_defs(cfg.d_model)}
    if n_groups:
        defs["blocks"] = {
            f"slot{i}": _stack_defs(
                _slot_defs(cfg, cfg.block_pattern[i], cross, serve_tp),
                n_groups,
            )
            for i in range(p)
        }
    for j in range(rem):
        defs[f"extra{j}"] = _slot_defs(
            cfg, kinds[n_groups * p + j], cross, serve_tp
        )

    if cfg.family == "encdec":
        enc_cfg = dataclasses.replace(cfg, moe=None)
        defs["enc_blocks"] = _stack_defs(
            _slot_defs(enc_cfg, "attn"), cfg.encoder_layers
        )
        defs["enc_norm"] = L.norm_defs(cfg.d_model)
    return defs


def build_specs(
    cfg: ModelConfig, mesh_sizes: dict[str, int], mp_axis,
    exclude_fsdp: tuple[str, ...] = (),
    serve_tp: bool = False,
) -> Tree:
    def walk(x):
        if isinstance(x, dict):
            return {k: walk(v) for k, v in x.items()}
        return P.resolve(x, mesh_sizes, mp_axis, exclude_fsdp)

    return walk(model_defs(cfg, serve_tp=serve_tp))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_fwd(
    x, w, ws, cfg: ModelConfig, par: Par, kind: str, enc=None, capture=False
):
    """One block. x: (B, S_loc, d) SP / (B, S, d) TP.

    Returns (x, aux, cache) — cache is the serving-cache contribution of
    this layer when ``capture`` (prefill), else {}.
    """
    dtype = x.dtype
    aux = {}
    cache = {}
    if kind == "attn":
        h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
        if cfg.parallel_mode == "sp":
            a = L.attn_sp(
                h, w["attn"], ws["attn"], cfg, par,
                causal=True,  # decoder self-attention (encoder has own path)
                window=cfg.swa_window, return_kv=capture,
            )
        else:
            a = L.attn_tp(
                h, w["attn"], ws["attn"], cfg, par,
                window=cfg.local_attn_window, return_kv=capture,
            )
        if capture:
            a, (kf, vf) = a
            cache["kv_full"] = (kf, vf)
        x = x + a
        if "cross" in w and enc is not None:
            h = L.apply_norm(x, w["ln_cross"], ws["ln_cross"], cfg.norm, dtype)
            c = L.attn_sp(
                h, w["cross"], ws["cross"], cfg, par,
                causal=False, kv_source=enc, use_rope=False, return_kv=capture,
            )
            if capture:
                c, (ckf, cvf) = c
                cache["cross_kv_full"] = (ckf, cvf)
            x = x + c
        h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
        if cfg.moe is not None:
            y, aux = L.moe_sp(h, w["ffn"], ws["ffn"], cfg, par)
        elif cfg.parallel_mode == "sp":
            y = L.mlp_sp(h, w["ffn"], ws["ffn"], cfg, par)
        else:
            y = L.mlp_tp(h, w["ffn"], ws["ffn"], cfg, par)
        return x + y, aux, cache
    if kind == "rglru":
        h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
        m = L.rglru_mix(h, w["mix"], ws["mix"], cfg, par, return_state=capture)
        if capture:
            m, (state, hist) = m
            cache["state"], cache["conv"] = state, hist
        x = x + m
        h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
        return x + L.mlp_tp(h, w["ffn"], ws["ffn"], cfg, par), aux, cache
    if kind == "rwkv":
        # Time-chunked whole-block processing (§Perf iteration B): bounds
        # the live working set to (B, chunk, d) while the recurrence state
        # and token-shift boundaries carry across chunks — identical math.
        x, cap = L.rwkv_block_chunked(
            x, w, ws, cfg, par, cfg.norm, chunk=512, capture=capture
        )
        if capture:
            cache.update(cap)
        return x, aux, cache
    raise ValueError(kind)


def _encoder_block_fwd(x, w, ws, cfg: ModelConfig, par: Par):
    dtype = x.dtype
    h = L.apply_norm(x, w["ln1"], ws["ln1"], cfg.norm, dtype)
    x = x + L.attn_sp(h, w["attn"], ws["attn"], cfg, par, causal=False)
    h = L.apply_norm(x, w["ln2"], ws["ln2"], cfg.norm, dtype)
    return x + L.mlp_sp(h, w["ffn"], ws["ffn"], cfg, par)


def _tree_index(tree: Tree, i) -> Tree:
    return jax.tree.map(lambda a: a[i], tree)


def _scan_groups(
    x, params, specs, cfg, par, kinds_pattern, n_groups, enc, remat,
    capture=False, unroll=False,
):
    """lax.scan over layer groups; each group runs the full block pattern."""
    slots = sorted(params.keys())  # slot0, slot1, ...

    def group_body(carry, idx):
        xg = carry

        def run(xg):
            auxes = []
            caches = {}
            for si, slot in enumerate(slots):
                w = _tree_index(params[slot], idx)
                ws_leaf = jax.tree.map(
                    _unstack_spec, specs[slot],
                    is_leaf=lambda s: isinstance(s, WSpec),
                )
                xg, aux, cache = _block_fwd(
                    xg, w, ws_leaf, cfg, par, kinds_pattern[si], enc,
                    capture=capture,
                )
                if aux:
                    auxes.append(aux)
                if capture:
                    caches[slot] = cache
            aux_out = (
                jax.tree.map(lambda *a: jnp.mean(jnp.stack(a)), *auxes)
                if auxes
                else {"lb_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}
            )
            return xg, (aux_out, caches)

        if remat:
            run = jax.checkpoint(run)
        xg, out = run(xg)
        return xg, out

    # Two-level (√L) remat: for deep stacks the per-group carry stack
    # dominates HBM (L × (B, S_loc, d)); nesting scans keeps only
    # outer + inner carries live at the cost of one extra forward.
    inner = 1
    if not unroll and not capture and n_groups >= 8:
        inner = max(
            (f for f in range(2, int(n_groups**0.5) + 1) if n_groups % f == 0),
            default=1,
        )
    if inner > 1:
        outer = n_groups // inner

        def outer_body(carry, idxs):
            def run_inner(c):
                return jax.lax.scan(group_body, c, idxs)

            return jax.checkpoint(run_inner)(carry)

        idx2 = jnp.arange(n_groups).reshape(outer, inner)
        x, (auxes, caches) = jax.lax.scan(outer_body, x, idx2)
        auxes = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), auxes)
    else:
        x, (auxes, caches) = jax.lax.scan(
            group_body, x, jnp.arange(n_groups),
            unroll=n_groups if unroll else 1,
        )
    return x, jax.tree.map(jnp.mean, auxes), caches


def _unstack_spec(s: WSpec) -> WSpec:
    """Drop the group dimension from a stacked spec (for per-layer use)."""
    return dataclasses.replace(
        s,
        shape=s.shape[1:],
        tp_dim=None if s.tp_dim is None else s.tp_dim - 1,
        fsdp_dim=None if s.fsdp_dim is None else s.fsdp_dim - 1,
    )


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def forward_hidden(
    params: Tree,
    specs: Tree,
    cfg: ModelConfig,
    par: Par,
    batch: Tree,
    dtype=jnp.bfloat16,
    remat: bool = True,
    capture: bool = False,
    unroll: bool = False,
):
    """Token ids (+ stub frontend inputs) → final-norm hidden states.

    SP: returns (B, S_loc, d) seq-sharded; TP: (B, S, d).
    Returns (hidden, aux[, capture tree]) with MoE aux metrics.
    """
    sp = cfg.parallel_mode == "sp"
    x = L.embed_tokens(
        batch["tokens"], params["embed"], specs["embed"], cfg, par, dtype, sp
    )

    if cfg.family == "vlm":
        # Stub anyres frontend: patch embeddings occupy global positions
        # [0, patch_positions); overwrite the token embeddings there.
        patches = batch["patches"].astype(dtype)  # (B, Ppos, d)
        s_loc = x.shape[1]
        shard = P.axis_index(par.mp)
        gpos = shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
        rows = jnp.take(
            patches, jnp.clip(gpos, 0, cfg.patch_positions - 1), axis=1
        )
        x = jnp.where((gpos < cfg.patch_positions)[None, :, None], rows, x)

    enc = None
    if cfg.family == "encdec":
        enc = batch["frames"].astype(dtype)  # (B, S_enc_loc, d) seq-sharded
        enc_params, enc_specs = params["enc_blocks"], specs["enc_blocks"]

        def enc_body(carry, idx):
            w = _tree_index(enc_params, idx)
            ws_ = jax.tree.map(
                _unstack_spec, enc_specs,
                is_leaf=lambda s: isinstance(s, WSpec),
            )

            def run(c):
                return _encoder_block_fwd(c, w, ws_, cfg, par)

            if remat:
                run = jax.checkpoint(run)
            return run(carry), None

        enc, _ = jax.lax.scan(enc_body, enc, jnp.arange(cfg.encoder_layers))
        enc = L.apply_norm(enc, params["enc_norm"], specs["enc_norm"], cfg.norm, dtype)

    p = len(cfg.block_pattern)
    n_groups, rem = divmod(cfg.n_layers, p)
    aux = {"lb_loss": jnp.zeros(()), "drop_frac": jnp.zeros(())}
    captured: Tree = {}
    if n_groups:
        x, aux, blk_caps = _scan_groups(
            x, params["blocks"], specs["blocks"], cfg, par,
            cfg.block_pattern, n_groups, enc, remat, capture=capture,
            unroll=unroll,
        )
        if capture:
            captured["blocks"] = blk_caps
    kinds = layer_kinds(cfg)
    for j in range(rem):
        x, _, cap = _block_fwd(
            x, params[f"extra{j}"], specs[f"extra{j}"], cfg, par,
            kinds[n_groups * p + j], enc, capture=capture,
        )
        if capture:
            captured[f"extra{j}"] = cap

    x = L.apply_norm(x, params["final_norm"], specs["final_norm"], cfg.norm, dtype)
    if capture:
        return x, aux, captured
    return x, aux


# ---------------------------------------------------------------------------
# Loss & train step
# ---------------------------------------------------------------------------


def loss_fn(
    params, specs, cfg: ModelConfig, par: Par, batch, dtype=jnp.bfloat16,
    remat: bool = True, lb_coef: float = 0.01, unroll: bool = False,
):
    h, aux = forward_hidden(
        params, specs, cfg, par, batch, dtype, remat, unroll=unroll
    )
    head_w = params["embed"]
    head_s = specs["embed"]
    if cfg.tie_embeddings:
        raise NotImplementedError("untied embeddings only")
    ce = L.ce_loss_sp if cfg.parallel_mode == "sp" else L.ce_loss_tp
    nll_sum, count_local = ce(h, batch["labels"], head_w, head_s, cfg, par)
    # both CE paths return totals replicated over model (vocab psums inside)
    sum_axes = par.dp
    total = P.psum(nll_sum, sum_axes)
    count = P.psum(jnp.asarray(count_local, jnp.float32), sum_axes)
    loss = total / count
    if cfg.moe is not None:
        loss = loss + lb_coef * aux["lb_loss"]
    metrics = {"loss": loss, "nll": total / count, **aux}
    return loss, metrics


def _replica_sizes(specs: Tree, mesh_sizes: dict[str, int]):
    return jax.tree.map(
        lambda s: float(s.replicas(mesh_sizes)),
        specs,
        is_leaf=lambda s: isinstance(s, WSpec),
    )


def global_grad_norm(grads, specs, mesh_sizes, all_axes):
    reps = _replica_sizes(specs, mesh_sizes)
    sq = jax.tree.map(
        lambda g, r: jnp.sum(jnp.square(g.astype(jnp.float32))) / r, grads, reps
    )
    total = functools.reduce(jnp.add, jax.tree.leaves(sq))
    return jnp.sqrt(P.psum(total, all_axes))


def make_train_step(
    cfg: ModelConfig,
    mesh_sizes: dict[str, int],
    par: Par,
    dtype=jnp.bfloat16,
    remat: bool = True,
    clip_norm: float = 1.0,
    peak_lr: float = 3e-4,
    unroll: bool = False,
    compress_axes: tuple[str, ...] = (),
    warmup_steps: int = 200,
) -> tuple[Callable, Tree]:
    """Build (train_step, specs).

    Default: train_step(params, opt, batch) → (params, opt, metrics).
    With ``compress_axes`` (e.g. ("pod",)): parameters stay replicated over
    those (DCN) axes and their gradient reduction is int8-compressed with
    error feedback; the step signature grows an error-state pytree:
    train_step(params, opt, err, batch) → (params, opt, err, metrics).
    """
    from repro.optim.compression import compressed_pmean

    specs = build_specs(cfg, mesh_sizes, par.mp, exclude_fsdp=compress_axes)
    all_axes = par.dp + ((par.mp,) if par.mp else ())

    def _sync(grads, err_state):
        """Per-leaf grad sync: compressed mean over compress_axes (error
        feedback), plain psum over remaining sync axes."""

        def walk(g, sp, err):
            if isinstance(g, dict):
                outs = {k: walk(g[k], sp[k], err[k]) for k in g}
                return (
                    {k: o[0] for k, o in outs.items()},
                    {k: o[1] for k, o in outs.items()},
                )
            comp = tuple(a for a in sp.sync if a in compress_axes)
            rest = tuple(a for a in sp.sync if a not in compress_axes)
            if rest:
                g = P.psum(g, rest)
            if comp:
                # pmean over the pod axis ≈ psum/n — matches the loss,
                # which averages over the global batch via its own psums.
                n = 1
                for a in comp:
                    n *= mesh_sizes.get(a, 1)
                g2, err2 = compressed_pmean(g, err, comp)
                return g2 * n, err2
            return g, err

        return walk(grads, specs, err_state)

    def train_step(params, opt_state, *rest):
        if compress_axes:
            err_state, batch = rest
        else:
            (batch,) = rest
            err_state = None
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(
                p, specs, cfg, par, batch, dtype, remat, unroll=unroll
            ),
            has_aux=True,
        )(params)
        if compress_axes:
            grads, err_state = _sync(grads, err_state)
        else:
            grads = P.sync_grads(grads, specs)
        gnorm = global_grad_norm(grads, specs, mesh_sizes, all_axes)
        scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-6))
        lr = warmup_cosine(opt_state.step, peak_lr=peak_lr, warmup_steps=warmup_steps)
        new_params, new_opt = adamw_update(
            grads, opt_state, params, lr, grad_scale=scale
        )
        metrics = {**metrics, "grad_norm": gnorm, "lr": lr}
        if compress_axes:
            return new_params, new_opt, err_state, metrics
        return new_params, new_opt, metrics

    return train_step, specs


def init_model(cfg: ModelConfig, key, mesh_sizes=None, mp_axis=None, local=False):
    """Materialize params (+ AdamW state) — smoke tests & small runs."""
    specs = build_specs(cfg, mesh_sizes or {}, mp_axis)
    params = P.init_tree(key, specs, local=local, mesh_sizes=mesh_sizes or {}, mp_axis=mp_axis)
    return params, specs


def init_opt(params, dtype=None):
    import jax.numpy as _jnp

    return adamw_init(params, dtype=_jnp.dtype(dtype or "float32"))
