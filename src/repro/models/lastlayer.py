"""FlyMC over an LM head: the paper's technique on the assigned backbones.

Full-parameter FlyMC is inapplicable to deep nets (no collapsible bound —
DESIGN.md §4), but the LM readout is exactly the paper's softmax experiment:
given frozen backbone features h ∈ R^{T×d} and next-token labels, the
per-token likelihood is softmax(θh) with θ the (V, d) head, and the Böhning
bound collapses through S = Σ h hᵀ and R = Σ h rᵀ. This module extracts the
(features, labels) GLM view from any architecture in the zoo and returns a
ready-to-sample GLMModel — exact Bayesian inference over the head with
bright-subset likelihood evaluations.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.distributed.par import Par
from repro.models import transformer as T
from repro.models.bayes_glm import GLMModel
from repro.models.config import ModelConfig


def extract_features(
    params, specs, cfg: ModelConfig, batch: dict, dtype=jnp.float32
):
    """Frozen-backbone features and shifted labels as a GLM dataset."""
    h, _ = T.forward_hidden(
        params, specs, cfg, Par(), batch, dtype=dtype, remat=False
    )
    feats = h[:, :-1].reshape(-1, cfg.d_model)
    labels = batch["tokens"][:, 1:].reshape(-1)
    return feats, labels


def lastlayer_glm(
    params, specs, cfg: ModelConfig, batch: dict, prior_scale: float = 1.0
) -> GLMModel:
    """GLMModel whose posterior is the Bayesian LM-head posterior."""
    from repro.core.bounds import GLMData

    feats, labels = extract_features(params, specs, cfg, batch)
    data = GLMData(x=feats, t=labels.astype(jnp.int32), xi=feats)  # xi reset
    model = GLMModel.softmax(
        data._replace(xi=jnp.zeros((feats.shape[0], cfg.padded_vocab()))),
        n_classes=cfg.padded_vocab(),
        prior_scale=prior_scale,
    )
    return model
