"""Model layers, written once against ``repro.distributed.par.Par``.

Every function here runs identically on a single device (trivial Par — all
collectives are identities) and inside shard_map on the production mesh
(DESIGN.md §5). Sharding conventions:

SP mode (attention archs):
  * residual stream x: (B_loc, S_loc, d) — batch over dp, seq over model
  * attention: all heads per shard on local seq rows; K/V all-gathered over
    model (head-count agnostic); Megatron-SP MLP (AG seq → ff-TP → RS seq),
    chunked over seq to bound transients
TP mode (recurrence archs):
  * residual stream x: (B_loc, S, d) — batch over dp, seq local
  * mixers (RWKV6 / RG-LRU / local attention) head- or feature-sharded over
    model with one psum per sublayer; Megatron TP MLP

Weights are declared as WDef trees (resolved to WSpec per mesh) and gathered
just-in-time (ZeRO-3); autodiff then emits the matching reduce-scatter.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed import par as P
from repro.distributed.par import Par, WDef
from repro.models.config import ModelConfig

Tree = dict[str, Any]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_defs(d: int) -> Tree:
    return {"scale": WDef((d,), fsdp_pref=(0,), init="ones")}


def apply_norm(x, w, ws, kind: str, dtype):
    scale = P.gather_param(w["scale"], ws["scale"], dtype)
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + 1e-6)
    else:  # layernorm (bias-free)
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
    return (xf * scale.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, D) with D even; positions: (S,) absolute."""
    d = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d)
    angles = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    cos = jnp.cos(angles)[None, :, None, :]
    sin = jnp.sin(angles)[None, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked (online-softmax) attention — pure JAX flash-style reference
# ---------------------------------------------------------------------------


def chunked_attention(
    q,  # (B, Sq, H, D)
    k,  # (B, Sk, Hk, D)
    v,  # (B, Sk, Hk, D)
    q_pos,  # (Sq,) absolute query positions
    k_pos,  # (Sk,) absolute key positions
    causal: bool = True,
    window: int | None = None,
    chunk: int = 1024,
):
    """Memory-efficient attention: lax.scan over KV chunks with online
    max/denominator accumulators. GQA via head grouping. O(Sq·chunk) live
    score memory instead of O(Sq·Sk)."""
    b, sq, h, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = 1.0 / math.sqrt(d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, sq, hk, g, d)

    chunk = min(chunk, sk)
    n_chunks = (sk + chunk - 1) // chunk
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)

    kc = k.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    pc = k_pos.reshape(n_chunks, chunk)

    neg = jnp.float32(-1e30)

    def body(carry, inp):
        m, l, acc = carry
        kci, vci, pci = inp
        s = jnp.einsum(
            "bqhgd,bchd->bhgqc", qg, kci.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= pci[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= pci[None, :] > q_pos[:, None] - window
        mask &= pci[None, :] < jnp.iinfo(jnp.int32).max  # padding
        s = jnp.where(mask[None, None, None], s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p, vci.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hk, g, sq), neg)
    l0 = jnp.zeros((b, hk, g, sq))
    a0 = jnp.zeros((b, hk, g, sq, d))
    # checkpoint the chunk body: the backward pass recomputes the (Sq, chunk)
    # score/probability blocks instead of stacking them per iteration --
    # the flash-attention recompute, worth ~GBs at 32k context.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), (m0, l0, a0), (kc, vc, pc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention sublayer — SP mode
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig, cross: bool = False) -> Tree:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    defs: Tree = {
        "wq": WDef((d, qd), fsdp_pref=(0, 1)),
        "wk": WDef((d, kvd), fsdp_pref=(0, 1)),
        "wv": WDef((d, kvd), fsdp_pref=(0, 1)),
        "wo": WDef((qd, d), fsdp_pref=(0, 1)),
    }
    if cfg.qkv_bias and not cross:
        defs["bq"] = WDef((qd,), init="zeros")
        defs["bk"] = WDef((kvd,), init="zeros")
        defs["bv"] = WDef((kvd,), init="zeros")
    return defs


def attn_sp(
    x,  # (B, S_loc, d) seq-sharded over model
    w: Tree,
    ws: Tree,
    cfg: ModelConfig,
    par: Par,
    *,
    causal: bool = True,
    window: int | None = None,
    kv_source=None,  # cross-attention: (B, S_enc_loc, d) seq-sharded
    use_rope: bool = True,
    return_kv: bool = False,  # also return gathered (k, v) for cache capture
):
    dtype = x.dtype
    b, s_loc, _ = x.shape
    hd = cfg.resolved_head_dim

    def proj(name, src):
        wt = P.gather_param(w[name], ws[name], dtype)
        y = src @ wt
        bias = "b" + name[1]
        if bias in w:
            y = y + P.gather_param(w[bias], ws[bias], dtype)
        return y

    kv_in = x if kv_source is None else kv_source
    s_kv_loc = kv_in.shape[1]

    q = proj("wq", x).reshape(b, s_loc, cfg.n_heads, hd)
    k = proj("wk", kv_in).reshape(b, s_kv_loc, cfg.n_kv_heads, hd)
    v = proj("wv", kv_in).reshape(b, s_kv_loc, cfg.n_kv_heads, hd)

    shard = P.axis_index(par.mp)
    q_pos = shard * s_loc + jnp.arange(s_loc, dtype=jnp.int32)
    kv_pos_local = shard * s_kv_loc + jnp.arange(s_kv_loc, dtype=jnp.int32)
    if use_rope:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, kv_pos_local, cfg.rope_theta)

    # Sequence-parallel attention: gather K/V (small for GQA) over model.
    axes = (par.mp,) if par.mp else ()
    k_full = P.all_gather(k, axes, axis=1)
    v_full = P.all_gather(v, axes, axis=1)
    s_kv = k_full.shape[1]
    k_pos = jnp.arange(s_kv, dtype=jnp.int32)

    # §Perf iteration A3: KV chunk 512 (not 1024) halves the f32 score
    # blocks that dominate the backward's live set at d_model ≥ 8k.
    out = chunked_attention(
        q, k_full, v_full, q_pos, k_pos, causal=causal, window=window,
        chunk=512,
    )
    out = out.reshape(b, s_loc, cfg.q_dim)
    y = out @ P.gather_param(w["wo"], ws["wo"], dtype)
    if return_kv:
        return y, (k_full, v_full)
    return y


def attn_tp(
    x,  # (B, S, d) seq-local, replicated over model
    w: Tree,
    ws: Tree,
    cfg: ModelConfig,
    par: Par,
    *,
    causal: bool = True,
    window: int | None = None,
    return_kv: bool = False,
):
    """Head-parallel attention for TP-mode archs (recurrentgemma local attn).

    Q/O are head-sharded over model; K/V (MQA, kv=1) are replicated-compute.
    One psum after the out-projection.
    """
    dtype = x.dtype
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    h_loc = cfg.n_heads // max(par.mp_size, 1)

    wq = P.gather_param(w["wq"], ws["wq"], dtype)  # (d, q_loc)
    wk = P.gather_param(w["wk"], ws["wk"], dtype)
    wv = P.gather_param(w["wv"], ws["wv"], dtype)
    q = (x @ wq).reshape(b, s, h_loc, hd)
    k = (x @ wk).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ wv).reshape(b, s, cfg.n_kv_heads, hd)

    pos = jnp.arange(s, dtype=jnp.int32)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    # GQA grouping requires h_loc divisible by kv heads per shard; with MQA
    # (kv=1 replicated) every local head attends the same K/V.
    out = chunked_attention(q, k, v, pos, pos, causal=causal, window=window)
    out = out.reshape(b, s, h_loc * hd)
    y = out @ P.gather_param(w["wo"], ws["wo"], dtype)  # (q_loc, d) partial
    y = P.psum(y, (par.mp,) if par.mp else ())
    if return_kv:
        return y, (k, v)
    return y


def attn_tp_defs(cfg: ModelConfig) -> Tree:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": WDef((d, qd), tp_dim=1, fsdp_pref=(0,)),
        "wk": WDef((d, kvd), fsdp_pref=(0, 1)),  # MQA: replicated compute
        "wv": WDef((d, kvd), fsdp_pref=(0, 1)),
        "wo": WDef((qd, d), tp_dim=0, fsdp_pref=(1,)),
    }


# ---------------------------------------------------------------------------
# MLP — SP (Megatron-SP AG→col/row→RS, seq-chunked) and TP variants
# ---------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig) -> Tree:
    d, ff = cfg.d_model, cfg.d_ff
    defs: Tree = {
        "w1": WDef((d, ff), tp_dim=1, fsdp_pref=(0,)),
        "w2": WDef((ff, d), tp_dim=0, fsdp_pref=(1,)),
    }
    if cfg.mlp == "swiglu":
        defs["w3"] = WDef((d, ff), tp_dim=1, fsdp_pref=(0,))
    return defs


def _mlp_core(xg, w1, w2, w3, kind: str):
    h = xg @ w1
    if kind == "swiglu":
        h = jax.nn.silu(h) * (xg @ w3)
    else:
        h = jax.nn.gelu(h)
    return h @ w2


def _auto_chunk(b: int, s_loc: int, d: int, mp: int, budget: int = 1 << 27):
    """Largest power-of-two seq chunk whose gathered (B, chunk·mp, d) bf16
    tensor stays under ``budget`` bytes (bounds Megatron-SP transients)."""
    chunk = s_loc
    while chunk > 16 and b * chunk * mp * d * 2 > budget:
        chunk //= 2
    while s_loc % chunk:
        chunk //= 2
    return max(chunk, 1)


def mlp_sp(x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par, chunk: int | None = None):
    """x: (B, S_loc, d) seq-sharded. AG chunk over model → ff-TP → RS back."""
    dtype = x.dtype
    b, s_loc, d = x.shape
    chunk = chunk or _auto_chunk(b, s_loc, d, max(par.mp_size, 1))
    w1 = P.gather_param(w["w1"], ws["w1"], dtype)
    w2 = P.gather_param(w["w2"], ws["w2"], dtype)
    w3 = P.gather_param(w["w3"], ws["w3"], dtype) if "w3" in w else None
    axes = (par.mp,) if par.mp else ()

    def one_chunk(xc):
        xg = P.all_gather(xc, axes, axis=1)
        yg = _mlp_core(xg, w1, w2, w3, cfg.mlp)
        return P.reduce_scatter(yg, axes, axis=1)

    if s_loc <= chunk:
        return one_chunk(x)
    n = s_loc // chunk
    assert s_loc % chunk == 0, (s_loc, chunk)
    xcs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    # scan + checkpoint: one chunk of gathered activations live at a time
    # (the dry-run HLO parser multiplies while-body collectives by the
    # parsed trip count, so accounting stays exact).
    _, ycs = jax.lax.scan(
        jax.checkpoint(lambda c, xc: (c, one_chunk(xc))), None, xcs
    )
    return ycs.transpose(1, 0, 2, 3).reshape(b, s_loc, d)


def mlp_tp(x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par):
    """x: (B, S, d) replicated over model. Col/row parallel + psum."""
    dtype = x.dtype
    w1 = P.gather_param(w["w1"], ws["w1"], dtype)
    w2 = P.gather_param(w["w2"], ws["w2"], dtype)
    w3 = P.gather_param(w["w3"], ws["w3"], dtype) if "w3" in w else None
    y = _mlp_core(x, w1, w2, w3, cfg.mlp)
    return P.psum(y, (par.mp,) if par.mp else ())


# ---------------------------------------------------------------------------
# MoE — capacity-based sort dispatch, expert-ff TP (works for any E)
# ---------------------------------------------------------------------------


def moe_defs(cfg: ModelConfig) -> Tree:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.n_experts
    defs: Tree = {
        "router": WDef((d, e), fsdp_pref=(0,)),
        "w1": WDef((e, d, ff), tp_dim=2, fsdp_pref=(1,)),
        "w2": WDef((e, ff, d), tp_dim=1, fsdp_pref=(2,)),
        "w3": WDef((e, d, ff), tp_dim=2, fsdp_pref=(1,)),
    }
    if cfg.moe.dense_residual:
        defs["dense"] = mlp_defs(cfg)
    return defs


def _moe_tokens(tokens, gathered, cfg: ModelConfig):
    """Dispatch (T, d) tokens to top-k experts with fixed capacity.

    Sort-based: no (T, E, C) one-hot dispatch tensors (DESIGN.md §6), so HLO
    FLOPs stay k·capacity_factor× the dense equivalent. Returns (out, aux).
    """
    w_router, w1, w2, w3 = gathered
    t, d = tokens.shape
    e, k = cfg.moe.n_experts, cfg.moe.top_k
    cap = int(cfg.moe.capacity_factor * k * t / e)
    cap = max(8, ((cap + 7) // 8) * 8)

    logits = (tokens @ w_router).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)  # (T, k)
    gate = gate / jnp.sum(gate, -1, keepdims=True)

    flat_e = expert.reshape(-1)  # (T*k,) token-major
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    pos = jnp.arange(t * k, dtype=jnp.int32) - jnp.searchsorted(
        sorted_e, sorted_e, side="left"
    ).astype(jnp.int32)
    ok = pos < cap
    slot = jnp.where(ok, sorted_e * cap + pos, e * cap)  # OOB → dropped
    token_of = (order // k).astype(jnp.int32)

    buf = (
        jnp.zeros((e * cap, d), tokens.dtype)
        .at[slot]
        .set(tokens[token_of], mode="drop")
        .reshape(e, cap, d)
    )
    h = jnp.einsum("ecd,edf->ecf", buf, w1)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", buf, w3)
    yb = jnp.einsum("ecf,efd->ecd", h, w2).reshape(e * cap, d)

    y_sorted = jnp.where(ok[:, None], yb.at[jnp.minimum(slot, e * cap - 1)].get(), 0)
    y_assign = jnp.zeros((t * k, d), tokens.dtype).at[order].set(y_sorted)
    y = (y_assign.reshape(t, k, d) * gate[..., None].astype(tokens.dtype)).sum(1)

    # Load-balancing aux loss (Switch-style) + drop fraction metric.
    frac_tokens = jnp.mean(
        (jax.nn.one_hot(expert, e, dtype=jnp.float32)).sum(1), axis=0
    )
    frac_probs = jnp.mean(probs, axis=0)
    aux = {
        "lb_loss": e * jnp.sum(frac_tokens * frac_probs) / k,
        "drop_frac": 1.0 - jnp.mean(ok.astype(jnp.float32)),
    }
    return y, aux


def moe_sp(x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par, chunk: int | None = None):
    """Seq-sharded MoE: AG chunk over model → dispatch/compute → RS back."""
    dtype = x.dtype
    b, s_loc, d = x.shape
    chunk = chunk or _auto_chunk(b, s_loc, d, max(par.mp_size, 1))
    gathered = tuple(
        P.gather_param(w[n], ws[n], dtype) for n in ("router", "w1", "w2", "w3")
    )
    dense = None
    if "dense" in w:
        dense = tuple(
            P.gather_param(w["dense"][n], ws["dense"][n], dtype)
            for n in ("w1", "w2", "w3")
        )
    axes = (par.mp,) if par.mp else ()

    def one_chunk(xc):
        xg = P.all_gather(xc, axes, axis=1)
        bsz, sg, _ = xg.shape
        y, aux = _moe_tokens(xg.reshape(bsz * sg, d), gathered, cfg)
        y = y.reshape(bsz, sg, d)
        if dense is not None:
            w1_d, w2_d, w3_d = dense
            y = y + _mlp_core(xg, w1_d, w2_d, w3_d, "swiglu")
        return P.reduce_scatter(y, axes, axis=1), aux

    if s_loc <= chunk:
        return one_chunk(x)
    n = s_loc // chunk
    assert s_loc % chunk == 0
    xcs = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    _, (ycs, auxs) = jax.lax.scan(
        jax.checkpoint(lambda c, xc: (c, one_chunk(xc))), None, xcs
    )
    y = ycs.transpose(1, 0, 2, 3).reshape(b, s_loc, d)
    return y, jax.tree.map(jnp.mean, auxs)


# ---------------------------------------------------------------------------
# RWKV6 time mix (chunked WKV) + channel mix — TP mode
# ---------------------------------------------------------------------------

_RWKV_LORA = 32


def rwkv_defs(cfg: ModelConfig) -> Tree:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h = cfg.n_heads
    ff = cfg.d_ff
    return {
        "mu": WDef((5, d), fsdp_pref=(1,), init="zeros"),  # r,k,v,g,w shifts
        "wr": WDef((d, d), tp_dim=1, fsdp_pref=(0,)),
        "wk": WDef((d, d), tp_dim=1, fsdp_pref=(0,)),
        "wv": WDef((d, d), tp_dim=1, fsdp_pref=(0,)),
        "wg": WDef((d, d), tp_dim=1, fsdp_pref=(0,)),
        # decay base: exp(w0) ≈ 0.05/step so cumulated chunk decays stay in
        # f32 range (real RWKV decays are near 1; see clip in rwkv_mix)
        "w0": WDef((d,), tp_dim=0, init="const", init_scale=-3.0),
        "wa": WDef((d, _RWKV_LORA), fsdp_pref=(0,)),  # decay lora (replicated)
        "wb": WDef((_RWKV_LORA, d), tp_dim=1, fsdp_pref=(0,), init="zeros"),
        "u": WDef((h, hd), tp_dim=0, init="zeros"),  # per-head bonus
        "ln_x": WDef((d,), tp_dim=0, init="ones"),  # per-head group norm
        "wo": WDef((d, d), tp_dim=0, fsdp_pref=(1,)),
        # channel mix
        "cm_r": WDef((d, d), fsdp_pref=(0, 1)),  # full r gate (replicated)
        "cm_k": WDef((d, ff), tp_dim=1, fsdp_pref=(0,)),
        "cm_v": WDef((ff, d), tp_dim=0, fsdp_pref=(1,)),
    }


def _wkv_chunk(r, k, v, logw, u, state):
    """One WKV chunk. r,k,v: (B,H,c,D); logw: (B,H,c,D) (≤0); u: (H,D);
    state: (B,H,D,D) f32 (key × value). Returns (y, new_state)."""
    c = r.shape[2]
    logp = jnp.cumsum(logw, axis=2)  # inclusive ∏ decay through i
    logp_excl = logp - logw  # exclusive: through i-1
    rq = r * jnp.exp(logp_excl)  # (B,H,c,D)
    kk = k * jnp.exp(-logp)  # k_j / P_j
    a = jnp.einsum("bhid,bhjd->bhij", rq, kk)  # Σ_d r_i P_{i-1}/P_j k_j
    mask = jnp.tril(jnp.ones((c, c), bool), -1)  # strictly j < i
    a = jnp.where(mask[None, None], a, 0.0)
    y = jnp.einsum("bhij,bhje->bhie", a, v)
    y = y + jnp.einsum("bhid,bhde->bhie", rq, state)  # carry-in state
    diag = jnp.einsum("bhid,hd,bhid->bhi", r, u, k)  # bonus self term
    y = y + diag[..., None] * v
    p_end = jnp.exp(logp[:, :, -1:, :])  # (B,H,1,D)
    k2 = k * jnp.exp(logp[:, :, -1:, :] - logp)  # k_j · P_c/P_j
    new_state = state * p_end[:, :, 0, :, None] + jnp.einsum(
        "bhjd,bhje->bhde", k2, v
    )
    return y, new_state


def rwkv_mix(
    x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par, chunk: int = 64,
    return_state: bool = False, state0=None, shift0=None,
):
    """RWKV6 time mixing over the local sequence (training/prefill).

    ``state0`` (B, H_loc, hd, hd) and ``shift0`` (B, d) continue the
    recurrence from a previous time chunk (rwkv_block_chunked)."""
    dtype = x.dtype
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h_loc = cfg.n_heads // max(par.mp_size, 1)

    pre = w.get("_pre") if isinstance(w, dict) else None
    g_ = (
        (lambda n: pre[n])
        if pre is not None
        else (lambda n: P.gather_param(w[n], ws[n], dtype))
    )
    mu = g_("mu") if pre is not None else P.gather_param(w["mu"], ws["mu"], dtype)
    first = (
        jnp.zeros((b, 1, d), x.dtype) if shift0 is None
        else shift0[:, None].astype(x.dtype)
    )
    xprev = jnp.concatenate([first, x[:, :-1]], axis=1)
    mix = lambda i: x + mu[i] * (xprev - x)
    r = mix(0) @ g_("wr")
    k = mix(1) @ g_("wk")
    v = mix(2) @ g_("wv")
    g = mix(3) @ g_("wg")
    w0 = (
        pre["w0"] if pre is not None
        else P.gather_param(w["w0"], ws["w0"], jnp.float32)
    )
    lora = jnp.tanh(mix(4) @ g_("wa")) @ g_("wb")
    logw = -jnp.exp(jnp.clip(w0 + lora.astype(jnp.float32), -8.0, 8.0))
    # Chunked WKV uses exp(-cumsum(logw)) inside a chunk; clamping per-step
    # log-decay to ≥ -1 keeps exp(chunk·|logw|) finite in f32 (chunk ≤ 64)
    # while still allowing sub-token half-lives.
    logw = jnp.clip(logw, -1.0, -1e-6)

    u = (
        pre["u"] if pre is not None
        else P.gather_param(w["u"], ws["u"], jnp.float32)
    )  # (h_loc, hd)

    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk

    def to_chunks(t, f32=True):
        # (B, S, d_loc) → (n, B, H_loc, chunk, hd) with a single transpose
        t = t.reshape(b, n, chunk, h_loc, hd).transpose(1, 0, 3, 2, 4)
        return t.astype(jnp.float32) if f32 else t

    rc, kc, vc, wc = to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(logw)

    def body(state, inp):
        ri, ki, vi, wi = inp
        y, state = _wkv_chunk(ri, ki, vi, wi, u, state)
        return state, y.astype(dtype)  # stash stacked outputs in bf16

    s0 = (
        jnp.zeros((b, h_loc, hd, hd), jnp.float32)
        if state0 is None else state0
    )
    # checkpoint: recompute intra-chunk decay matrices in the backward pass
    s_final, ys = jax.lax.scan(jax.checkpoint(body), s0, (rc, kc, vc, wc))
    # ys: (n_chunks, B, H_loc, chunk, hd) → (B, S, H_loc, hd)
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, h_loc, hd).astype(jnp.float32)

    # per-head group norm + silu(g) gate
    ln = (
        pre["ln_x"] if pre is not None
        else P.gather_param(w["ln_x"], ws["ln_x"], jnp.float32)
    ).reshape(h_loc, hd)
    yn = y * jax.lax.rsqrt(jnp.mean(y * y, -1, keepdims=True) + 1e-6) * ln
    yn = yn.reshape(b, s, h_loc * hd).astype(dtype)
    out = (yn * jax.nn.silu(g)) @ g_("wo")
    out = P.psum(out, (par.mp,) if par.mp else ())
    if return_state:
        # decode continuation: WKV state + last (normed) input for the shift
        return out, (s_final, x[:, -1].astype(jnp.float32))
    return out


def rwkv_channel_mix(
    x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par,
    return_state: bool = False, shift0=None,
):
    dtype = x.dtype
    b, _, d = x.shape
    first = (
        jnp.zeros((b, 1, d), x.dtype) if shift0 is None
        else shift0[:, None].astype(x.dtype)
    )
    xprev = jnp.concatenate([first, x[:, :-1]], axis=1)
    xk = 0.5 * (x + xprev)
    pre = w.get("_pre") if isinstance(w, dict) else None
    g_ = (
        (lambda n: pre[n])
        if pre is not None
        else (lambda n: P.gather_param(w[n], ws[n], dtype))
    )
    r = jax.nn.sigmoid(xk @ g_("cm_r"))
    h = jnp.square(jax.nn.relu(xk @ g_("cm_k")))
    y = h @ g_("cm_v")
    y = P.psum(y, (par.mp,) if par.mp else ())
    if return_state:
        return r * y, x[:, -1].astype(jnp.float32)
    return r * y


def rwkv_block_chunked(
    x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par, norm_kind: str,
    chunk: int = 512, capture: bool = False,
):
    """Full RWKV block (ln→time-mix→ln→channel-mix) scanned over TIME chunks.

    §Perf iteration (EXPERIMENTS): TP-mode blocks otherwise materialize ~10
    full-sequence (B, S, d) streams per layer in the backward pass; carrying
    (wkv state, shift boundaries) across S/chunk sequential chunks bounds
    the live working set to (B, chunk, d) at identical math and FLOPs.
    """
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h_loc = cfg.n_heads // max(par.mp_size, 1)
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    dtype = x.dtype

    # §Perf iteration B2: gather every weight ONCE per block, outside the
    # time-chunk scan — per-chunk re-gathers showed up as +30% memory and
    # +1.7 s collective in the B1 measurement (EXPERIMENTS §Perf).
    pre = {
        n: P.gather_param(w["mix"][n], ws["mix"][n], dtype)
        for n in ("mu", "wr", "wk", "wv", "wg", "wa", "wb", "wo",
                  "cm_r", "cm_k", "cm_v")
    }
    pre["w0"] = P.gather_param(w["mix"]["w0"], ws["mix"]["w0"], jnp.float32)
    pre["u"] = P.gather_param(w["mix"]["u"], ws["mix"]["u"], jnp.float32)
    pre["ln_x"] = P.gather_param(
        w["mix"]["ln_x"], ws["mix"]["ln_x"], jnp.float32
    )
    mix_w = {**w["mix"], "_pre": pre}

    def body(carry, xc):
        state, sh_tm, sh_cm = carry
        h = apply_norm(xc, w["ln1"], ws["ln1"], norm_kind, dtype)
        m, (state2, sh_tm2) = rwkv_mix(
            h, mix_w, ws["mix"], cfg, par,
            return_state=True, state0=state, shift0=sh_tm,
        )
        xc = xc + m
        h2 = apply_norm(xc, w["ln2"], ws["ln2"], norm_kind, dtype)
        cm, sh_cm2 = rwkv_channel_mix(
            h2, mix_w, ws["mix"], cfg, par,
            return_state=True, shift0=sh_cm,
        )
        return (state2, sh_tm2, sh_cm2), xc + cm

    init = (
        jnp.zeros((b, h_loc, hd, hd), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
        jnp.zeros((b, d), jnp.float32),
    )
    if nc == 1:
        carry, y = body(init, x)
    else:
        xcs = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
        carry, ycs = jax.lax.scan(jax.checkpoint(body), init, xcs)
        y = ycs.transpose(1, 0, 2, 3).reshape(b, s, d)
    if capture:
        state, sh_tm, sh_cm = carry
        return y, {"state": state, "shift_tm": sh_tm, "shift_cm": sh_cm}
    return y, None


# ---------------------------------------------------------------------------
# RG-LRU (Griffin) recurrence block — TP mode
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_defs(cfg: ModelConfig) -> Tree:
    d, r = cfg.d_model, cfg.rnn_dim
    return {
        "wx": WDef((d, r), tp_dim=1, fsdp_pref=(0,)),
        "wgate": WDef((d, r), tp_dim=1, fsdp_pref=(0,)),  # gelu branch
        "wa": WDef((d, r), tp_dim=1, fsdp_pref=(0,)),  # recurrence gate a_t
        "wi": WDef((d, r), tp_dim=1, fsdp_pref=(0,)),  # input gate i_t
        "conv": WDef((4, r), tp_dim=1, init="scaled", init_scale=0.5),
        "lam": WDef((r,), tp_dim=0, init="ones"),  # Λ (softplus-parameterized)
        "wo": WDef((r, d), tp_dim=0, fsdp_pref=(1,)),
    }


def _depthwise_conv(x, kern):
    """Causal depthwise conv, width K. x: (B,S,C), kern: (K,C)."""
    k = kern.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(xp[:, i : i + x.shape[1]] * kern[i] for i in range(k))


def _rglru_scan(log_a, bx):
    """h_t = a_t h_{t-1} + b_t via associative scan over seq axis 1."""

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    la, b = jax.lax.associative_scan(combine, (log_a, bx), axis=1)
    return b


def rglru_mix(
    x, w: Tree, ws: Tree, cfg: ModelConfig, par: Par, return_state: bool = False
):
    dtype = x.dtype
    g_ = lambda n: P.gather_param(w[n], ws[n], dtype)
    bx_pre = x @ g_("wx")
    bx = _depthwise_conv(bx_pre, g_("conv"))
    a_gate = jax.nn.sigmoid((x @ g_("wa")).astype(jnp.float32))
    i_gate = jax.nn.sigmoid((x @ g_("wi")).astype(jnp.float32))
    lam = jax.nn.softplus(P.gather_param(w["lam"], ws["lam"], jnp.float32))
    log_a = jnp.clip(-_RGLRU_C * lam * a_gate, -60.0, -1e-6)  # (B,S,r) ≤ 0
    beta = jnp.sqrt(1.0 - jnp.exp(2.0 * log_a))
    bterm = beta * (i_gate * bx.astype(jnp.float32))
    h32 = _rglru_scan(log_a, bterm)
    h = h32.astype(dtype)
    gate = jax.nn.gelu(x @ g_("wgate"))
    y = (h * gate) @ g_("wo")
    y = P.psum(y, (par.mp,) if par.mp else ())
    if return_state:
        # state: final h; conv history: last 3 *pre-conv* inputs
        hist = bx_pre[:, -3:].astype(jnp.float32)
        return y, (h32[:, -1], hist)
    return y


# ---------------------------------------------------------------------------
# Embedding & vocab-parallel cross-entropy head
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> Tree:
    vp, d = cfg.padded_vocab(), cfg.d_model
    defs = {"table": WDef((vp, d), tp_dim=0, fsdp_pref=(1,), init_scale=1.0)}
    if not cfg.tie_embeddings:
        defs["head"] = WDef((d, vp), tp_dim=1, fsdp_pref=(0,))
    return defs


def embed_tokens(ids, w, ws, cfg: ModelConfig, par: Par, dtype, sp: bool):
    """ids: (B, S) replicated over model. Vocab-parallel lookup; in SP mode a
    reduce-scatter over seq enters sequence parallelism (Megatron-SP)."""
    table = P.gather_param(w["table"], ws["table"], dtype)  # (V_loc, d)
    v_loc = table.shape[0]
    shard = P.axis_index(par.mp)
    local = ids - shard * v_loc
    hit = (local >= 0) & (local < v_loc)
    rows = jnp.take(table, jnp.clip(local, 0, v_loc - 1), axis=0)
    partial = jnp.where(hit[..., None], rows, 0)
    axes = (par.mp,) if par.mp else ()
    if sp:
        return P.reduce_scatter(partial, axes, axis=1)  # (B, S_loc, d)
    return P.psum(partial, axes)  # (B, S, d)


def _vp_ce_chunk(xi, li, head, v_loc, shard, axes):
    """Vocab-parallel CE for rows REPLICATED over the model axis.

    xi: (B, c, d) — identical on every model shard (Megatron rule: the
    vocab psums below combine per-vocab-slice partials of the SAME rows;
    feeding different rows per shard silently corrupts the lse).
    """
    logits = (xi @ head).astype(jnp.float32)  # (B, c, V_loc)
    # max-shift is a constant wrt the gradient (softmax is shift
    # invariant); stop_gradient also sidesteps pmax's missing JVP rule.
    m = P.pmax(jnp.max(jax.lax.stop_gradient(logits), -1), axes)
    se = jnp.sum(jnp.exp(logits - m[..., None]), -1)
    lse = m + jnp.log(P.psum(se, axes))
    local = li - shard * v_loc
    hit = (local >= 0) & (local < v_loc)
    tgt = jnp.take_along_axis(
        logits, jnp.clip(local, 0, v_loc - 1)[..., None], axis=-1
    )[..., 0]
    tgt = P.psum(jnp.where(hit, tgt, 0.0), axes)
    return jnp.sum(lse - tgt)


def ce_loss_sp(
    x,  # (B, S_loc, d) seq-sharded hidden states (post final norm)
    labels,  # (B, S) replicated over model
    w,
    ws,
    cfg: ModelConfig,
    par: Par,
    chunk: int = 256,
):
    """Vocab-parallel cross entropy for sequence-parallel hidden states.

    Each local seq chunk is all-gathered over the model axis first (the SP
    exit, mirroring Megatron-SP's head), so the vocab-parallel psums combine
    partials of identical rows. The returned total is replicated over model;
    callers psum over the data axes only. Never materializes (B, S, V)."""
    dtype = x.dtype
    head = P.gather_param(w["head"], ws["head"], dtype)  # (d, V_loc)
    v_loc = head.shape[1]
    b, s_loc, d = x.shape
    mp = max(par.mp_size, 1)
    shard = P.axis_index(par.mp)
    axes = (par.mp,) if par.mp else ()

    c_loc = max(1, min(s_loc, chunk // mp))
    while s_loc % c_loc:
        c_loc //= 2
    n = s_loc // c_loc

    def one_chunk(carry, inp):
        # Gather one chunk of rows (and their labels) over model: tiled
        # all_gather concatenates shards in axis-index order, so row/label
        # pairing is preserved; CE is row-wise so global order is free.
        xi_loc, li_loc = inp  # (B, c_loc, d), (B, c_loc)
        xi = P.all_gather(xi_loc, axes, axis=1)
        li = P.all_gather(li_loc, axes, axis=1)
        return carry + _vp_ce_chunk(xi, li, head, v_loc, shard, axes), None

    xc = x.reshape(b, n, c_loc, d).transpose(1, 0, 2, 3)
    lab_loc = jax.lax.dynamic_slice_in_dim(labels, shard * s_loc, s_loc, 1)
    lc = lab_loc.reshape(b, n, c_loc).transpose(1, 0, 2)
    total, _ = jax.lax.scan(
        jax.checkpoint(one_chunk), jnp.zeros((), jnp.float32), (xc, lc)
    )
    return total, b * s_loc * mp


def ce_loss_tp(x, labels, w, ws, cfg: ModelConfig, par: Par, chunk: int = 256):
    """TP-mode CE: x (B, S, d) seq-local; labels (B, S)."""
    dtype = x.dtype
    head = P.gather_param(w["head"], ws["head"], dtype)
    v_loc = head.shape[1]
    b, s, d = x.shape
    shard = P.axis_index(par.mp)
    chunk = min(chunk, s)
    assert s % chunk == 0
    n = s // chunk
    axes = (par.mp,) if par.mp else ()

    xc = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)
    total, _ = jax.lax.scan(
        jax.checkpoint(
            lambda c, inp: (
                c + _vp_ce_chunk(inp[0], inp[1], head, v_loc, shard, axes),
                None,
            )
        ),
        jnp.zeros((), jnp.float32), (xc, lc),
    )
    return total, b * s
