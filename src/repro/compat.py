"""Forward-compatibility polyfills for older installed jax versions.

The codebase is written against the current jax surface (``jax.shard_map``
with ``check_vma``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)``). On older runtimes (e.g. 0.4.x) those names live under
``jax.experimental.shard_map`` / don't exist; this module installs thin
adapters onto the jax namespace so the same call sites work on both. Every
patch is guarded by a feature check and is a no-op on a current jax.

Imported for its side effects from ``repro.__init__``.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax

if not hasattr(jax, "shard_map"):  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
        if check_vma is not None and "check_rep" not in kw:
            kw["check_rep"] = check_vma
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
        )

    jax.shard_map = shard_map


if not hasattr(jax.sharding, "AxisType"):  # pragma: no cover

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


if not hasattr(jax.lax, "axis_size"):  # pragma: no cover

    def _axis_size(axis_name):
        return jax.lax.psum(1, axis_name)

    jax.lax.axis_size = _axis_size


if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
    _make_mesh = jax.make_mesh

    @functools.wraps(_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
        del axis_types  # pre-AxisType jax: every mesh axis behaves as Auto
        return _make_mesh(axis_shapes, axis_names, **kw)

    jax.make_mesh = make_mesh
