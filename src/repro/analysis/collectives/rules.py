"""The four collective-level rules, packaged for the analysis engine.

Same plug-in surface as the jaxpr-generic and kernel rules: each extracts
every shard_map region from the entry point's jaxpr (cached on the
Context) and runs one analysis. All default ``require=True`` — an entry
point registered with collective rules that traces to *zero* shard_map
regions is itself a finding (a sweep that stops seeing the sharded program
is a blind sweep).

=======================  =================================================
collective-budget        trip-multiplied census counts must EQUAL the
                         declared ``kind@axes`` budget (missing collectives
                         are a stale pin, extra ones are the regression);
                         collectives inside scan/while bodies and
                         non-scalar reductions are findings by default
replication-consistency  every output's inferred device-variance must stay
                         inside its declared out_names axes
comm-bytes               the derived per-device wire-bytes model; optional
                         pinned total, exported into Report.metrics (and
                         thence BENCH_flymc.json)
shard-shape              divisibility / zero-local / pinned local shapes
=======================  =================================================
"""

from __future__ import annotations

from repro.analysis.collectives import extract, replication, shapes
from repro.analysis.collectives import wire_bytes as wire_mod
from repro.analysis.collectives.census import census as _census
from repro.analysis.collectives.census import census_counts
from repro.analysis.report import Finding
from repro.analysis.rules import Context, Rule


class _ShardedRule(Rule):
    """Shared region extraction + the require-regions honesty guard."""

    def __init__(self, require: bool = True):
        self.require = require

    def _regions(self, ctx: Context) -> list:
        cache = getattr(ctx, "_sharded_regions", None)
        if cache is None:
            cache = extract.find_sharded_regions(ctx.closed)
            try:
                ctx._sharded_regions = cache
            except Exception:
                pass
        return cache

    def _sites(self, ctx: Context) -> list:
        cache = getattr(ctx, "_collective_sites", None)
        if cache is None:
            cache = [s for r in self._regions(ctx)
                     for s in _census(r)]
            try:
                ctx._collective_sites = cache
            except Exception:
                pass
        return cache

    def _require_finding(self, ctx: Context) -> list[Finding]:
        if self.require:
            return [self._finding(
                ctx,
                "no shard_map region reachable from this entry point — "
                "collective rules were requested but there is no sharded "
                "program to verify (mesh dropped, or shard_map traced away)",
            )]
        return []


class CollectiveBudgetRule(_ShardedRule):
    """Exact per-step collective counts against a declared budget.

    ``budget`` maps ``"kind@axis1,axis2"`` (see
    :attr:`~repro.analysis.collectives.census.CollectiveSite.key`) to the
    exact trip-multiplied count per step. The comparison is two-sided:
    collectives above budget are the classic regression (an O(C) psum
    sneaking into the z-phase), collectives below budget mean the pin went
    stale and must be consciously re-derived.

    ``scalar_kinds`` reductions must operate on scalars — FlyMC's θ-psum
    reduces the shard-local log-pseudo-likelihood SUM, never an array
    (reducing an array is the accidental O(C·wire) variant). Collectives
    inside scan bodies (``forbid_in_loops``) and while bodies are findings:
    the z-update loop must be collective-free for the paper's zero-
    communication z-phase claim to hold at pod scale.
    """

    name = "collective-budget"

    def __init__(
        self,
        budget: dict[str, int],
        scalar_kinds: tuple[str, ...] = ("psum", "pmax", "pmin"),
        forbid_in_loops: bool = True,
        require: bool = True,
    ):
        super().__init__(require=require)
        self.budget = dict(budget)
        self.scalar_kinds = tuple(scalar_kinds)
        self.forbid_in_loops = forbid_in_loops

    def check(self, ctx: Context) -> list[Finding]:
        if not self._regions(ctx):
            return self._require_finding(ctx)
        findings = []
        sites = self._sites(ctx)
        counts = census_counts(sites)
        for key in sorted(set(counts) | set(self.budget)):
            found, declared = counts.get(key, 0), self.budget.get(key, 0)
            if found > declared:
                findings.append(self._finding(
                    ctx,
                    f"{key}: {found} collectives per step exceed the "
                    f"declared budget of {declared} — every extra "
                    f"collective multiplies by iterations × devices",
                    key=key, found=found, budget=declared,
                ))
            elif found < declared:
                findings.append(self._finding(
                    ctx,
                    f"{key}: {found} collectives per step, budget declares "
                    f"{declared} — the pin is stale, re-derive the budget",
                    key=key, found=found, budget=declared,
                ))
        for s in sites:
            if s.unbounded:
                findings.append(self._finding(
                    ctx,
                    f"{s.key} inside a while body at {s.scope or '/'} — "
                    f"no static trip count bounds this collective",
                    key=s.key, scope=s.scope or "/",
                ))
            elif self.forbid_in_loops and s.in_loop:
                findings.append(self._finding(
                    ctx,
                    f"{s.key} inside a scan body at {s.scope or '/'} "
                    f"(×{s.trip_multiplier} per step) — the z-phase must "
                    f"stay collective-free (brightness is per-datum)",
                    key=s.key, scope=s.scope or "/",
                    multiplier=s.trip_multiplier,
                ))
            if s.kind in self.scalar_kinds and not s.scalar:
                findings.append(self._finding(
                    ctx,
                    f"{s.key} at {s.scope or '/'} reduces a non-scalar "
                    f"({s.shard_bytes_in} B per shard) — the θ-update "
                    f"psums ONE scalar log-likelihood sum per proposal",
                    key=s.key, scope=s.scope or "/",
                    bytes_in=s.shard_bytes_in,
                ))
        return findings

    def report_metrics(self, ctx: Context) -> dict:
        sites = self._sites(ctx)
        if not self._regions(ctx):
            return {}
        return {
            "collective_census": census_counts(sites),
            "shard_map_regions": len(self._regions(ctx)),
        }


class ReplicationRule(_ShardedRule):
    """Outputs declared replicated must be provably replicated."""

    name = "replication-consistency"

    def check(self, ctx: Context) -> list[Finding]:
        regions = self._regions(ctx)
        if not regions:
            return self._require_finding(ctx)
        findings = []
        for region in regions:
            for v in replication.check_replication(region):
                findings.append(self._finding(
                    ctx, f"[{region.origin}] {v.message()}",
                    origin=region.origin, out_index=v.out_index,
                    leaked_axes=list(v.leaked_axes),
                    declared_axes=list(v.declared_axes),
                ))
        return findings


class CommBytesRule(_ShardedRule):
    """Derive the per-device wire-bytes model; pin it; export metrics.

    ``expected_total`` pins the per-step total (exact — the model is
    integer arithmetic over avals); a mismatch means the program's
    collective traffic changed without the pin following, or vice versa.
    The derived model lands in ``Report.metrics`` under
    ``collective_wire_bytes`` so BENCH_flymc.json records it, and the
    cross-validation test holds it equal to the compiled program's
    HLO-parsed wire bytes.
    """

    name = "comm-bytes"

    def __init__(self, expected_total: int | None = None,
                 require: bool = True):
        super().__init__(require=require)
        self.expected_total = expected_total

    def check(self, ctx: Context) -> list[Finding]:
        if not self._regions(ctx):
            return self._require_finding(ctx)
        findings = []
        model = wire_mod.wire_model(self._sites(ctx))
        if model["unbounded_sites"]:
            findings.append(self._finding(
                ctx,
                f"{model['unbounded_sites']} collective site(s) inside "
                f"while bodies — the wire-bytes total is a lower bound, "
                f"not a model",
                unbounded_sites=model["unbounded_sites"],
            ))
        if (self.expected_total is not None
                and int(model["total"]) != int(self.expected_total)):
            findings.append(self._finding(
                ctx,
                f"derived per-device wire bytes {model['total']} != pinned "
                f"{self.expected_total} — the collective traffic and the "
                f"recorded model have diverged",
                derived=int(model["total"]),
                expected=int(self.expected_total),
            ))
        return findings

    def report_metrics(self, ctx: Context) -> dict:
        if not self._regions(ctx):
            return {}
        return {"collective_wire_bytes": wire_mod.wire_model(
            self._sites(ctx))}


class ShardShapeRule(_ShardedRule):
    """Every sharded axis divides cleanly; optional pinned local shapes."""

    name = "shard-shape"

    def __init__(self, pin_locals: dict[int, dict[int, int]] | None = None,
                 require: bool = True):
        super().__init__(require=require)
        self.pin_locals = dict(pin_locals or {})

    def check(self, ctx: Context) -> list[Finding]:
        regions = self._regions(ctx)
        if not regions:
            return self._require_finding(ctx)
        findings = []
        for region in regions:
            for issue in shapes.check_shapes(region, self.pin_locals):
                findings.append(self._finding(
                    ctx, f"[{region.origin}] {issue.message()}",
                    origin=region.origin, kind=issue.kind,
                    where=issue.where, index=issue.index, dim=issue.dim,
                ))
        return findings


def collective_rules(
    budget: dict[str, int],
    expected_wire_bytes: int | None = None,
    pin_locals: dict[int, dict[int, int]] | None = None,
    forbid_in_loops: bool = True,
) -> list[Rule]:
    """The standard four-rule kit a sharded entry point registers with."""
    return [
        CollectiveBudgetRule(budget, forbid_in_loops=forbid_in_loops),
        ReplicationRule(),
        CommBytesRule(expected_total=expected_wire_bytes),
        ShardShapeRule(pin_locals=pin_locals),
    ]
