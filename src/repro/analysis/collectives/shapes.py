"""Shard-shape soundness: sharded axes must divide cleanly over the mesh.

``shard_map`` itself rejects indivisible axes at trace time, so for traced
regions these checks guard REGISTRY drift (an entry point re-pinned to a
new N or mesh without re-deriving the local shapes) and the synthetic /
NamedSharding-constructed regions tests build directly — where uneven
shards would mean silent truncation or padding, not an error.

Three findings per sharded dimension:

* **indivisible** — global size % (product of mesh axis sizes) ≠ 0;
* **zero-local**  — the local shard would be empty (more shards than rows);
* **local-pin**   — an optionally pinned expected local size (e.g. the
  per-shard row count a capacity must stay below) no longer matches.
"""

from __future__ import annotations

import dataclasses

from repro.analysis.collectives.extract import ShardedRegion


@dataclasses.dataclass(frozen=True)
class ShapeIssue:
    """One unsound sharded dimension."""

    kind: str          # "indivisible" | "zero-local" | "local-pin"
    where: str         # "in" | "out"
    index: int         # flat operand/result index
    dim: int
    global_size: int
    shards: int        # product of the sharding axes' sizes
    expected_local: int | None = None

    def message(self) -> str:
        if self.kind == "indivisible":
            return (
                f"{self.where}[{self.index}] dim {self.dim}: global size "
                f"{self.global_size} is not divisible by {self.shards} "
                f"shards — uneven shards truncate or pad silently"
            )
        if self.kind == "zero-local":
            return (
                f"{self.where}[{self.index}] dim {self.dim}: {self.shards} "
                f"shards of a size-{self.global_size} axis leave empty "
                f"local shards"
            )
        return (
            f"{self.where}[{self.index}] dim {self.dim}: local shard size "
            f"{self.global_size // max(self.shards, 1)} != pinned "
            f"{self.expected_local} — re-derive the per-shard geometry "
            f"(capacities are sized against it)"
        )


def _check_side(region, avals, names_tuple, where, pin_locals, issues):
    pins = pin_locals if where == "in" else {}
    for i, (aval, names) in enumerate(zip(avals, names_tuple)):
        shape = tuple(getattr(aval, "shape", ()) or ())
        for dim, axes in sorted(names.items()):
            if dim >= len(shape):
                continue
            shards = region.axis_size(axes)
            size = int(shape[dim])
            if size % shards != 0:
                issues.append(ShapeIssue("indivisible", where, i, dim,
                                         size, shards))
                continue
            if size // shards == 0:
                issues.append(ShapeIssue("zero-local", where, i, dim,
                                         size, shards))
                continue
            pinned = pins.get(i, {}).get(dim)
            if pinned is not None and size // shards != int(pinned):
                issues.append(ShapeIssue("local-pin", where, i, dim, size,
                                         shards, expected_local=int(pinned)))


def check_shapes(
    region: ShardedRegion,
    pin_locals: dict[int, dict[int, int]] | None = None,
) -> list[ShapeIssue]:
    """Shape issues for one region.

    ``pin_locals`` maps flat INPUT index -> {dim: expected local size}; a
    drifted pin is a finding (the registry's way of asserting per-shard
    geometry like "each shard owns N/8 rows ≥ capacity").
    """
    issues: list[ShapeIssue] = []
    _check_side(region, region.global_in_avals, region.in_names, "in",
                dict(pin_locals or {}), issues)
    _check_side(region, region.global_out_avals, region.out_names, "out",
                {}, issues)
    return issues
