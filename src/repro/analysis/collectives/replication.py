"""Device-variance dataflow: is each shard_map output really replicated?

With ``check_rep=False`` (every call site in this repo — jax's own checker
is skipped for trace speed), an output declared replicated
(``out_specs=P()``) is NOT verified: jax simply takes **shard 0's value**
and silently installs it on every device. If the value actually varied
across shards, every other shard's contribution is dropped — the bug class
that let ``BrightState.num`` (a per-shard bright count) be declared
replicated and collapse to shard 0's count each step.

This module proves replication instead of trusting it. For every variable
in the body we track the set of mesh axes the value may VARY over:

* a sharded input varies over the axes in its ``in_names`` entry; a
  replicated input over none;
* ``psum`` / ``pmax`` / ``pmin`` / ``all_gather`` / ``pbroadcast`` over
  axes A produce the same value on every shard along A — variance minus A;
* ``axis_index`` / ``psum_scatter`` / ``all_to_all`` / ``ppermute``
  introduce per-shard values — variance plus A;
* everything else joins its inputs' variance (including through pjit /
  custom_* calls); unknown sub-jaxprs (Pallas kernels) conservatively
  join ALL inputs into every output;
* scan / while bodies run to a fixpoint over the carry (≤ |axes| + 1
  rounds since variance sets only grow); a while whose *predicate* varies
  makes every carry varying (shards would run different trip counts);
  cond joins all branches plus the predicate's variance.

An output whose inferred variance escapes the axes its ``out_names`` entry
declares is a violation: the program would silently keep only shard 0's
value there.
"""

from __future__ import annotations

import dataclasses

import jax.extend.core as jex_core

from repro.analysis import walker
from repro.analysis.collectives.census import KINDS, axes_of
from repro.analysis.collectives.extract import _names_axes
from repro.analysis.rules import _DIRECT_CALLS

# kinds that make their output invariant along their axes vs kinds that
# introduce per-shard variance (see module doc)
_CLEARS = {"psum", "pmax", "pmin", "all_gather", "pbroadcast"}
_ADDS = {"axis_index", "psum_scatter", "all_to_all", "ppermute"}

_EMPTY: frozenset = frozenset()


@dataclasses.dataclass(frozen=True)
class RepViolation:
    """One output declared replicated along axes it actually varies over."""

    out_index: int
    leaked_axes: tuple[str, ...]   # varying axes NOT declared in out_names
    declared_axes: tuple[str, ...]
    aval: str

    def message(self) -> str:
        declared = (f"sharded over {list(self.declared_axes)}"
                    if self.declared_axes else "replicated (out_specs=P())")
        return (
            f"output {self.out_index} ({self.aval}) is declared {declared} "
            f"but varies over mesh axes {list(self.leaked_axes)} — with "
            f"check_rep=False shard 0's value silently overwrites every "
            f"other shard's (psum/pmax it, or shard the output)"
        )


def _transfer(jaxpr, in_sets):
    """Variance sets for ``jaxpr``'s outputs given its inputs' sets."""
    env: dict = {}
    for v, s in zip(jaxpr.invars, in_sets):
        env[v] = s
    for v in jaxpr.constvars:
        env[v] = _EMPTY

    def get(atom):
        if isinstance(atom, jex_core.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    for eqn in jaxpr.eqns:
        ins = [get(a) for a in eqn.invars]
        join = frozenset().union(*ins) if ins else _EMPTY
        name = eqn.primitive.name
        kind = KINDS.get(name)
        if kind in _CLEARS:
            out = join - frozenset(axes_of(eqn))
            outs = [out] * len(eqn.outvars)
        elif kind in _ADDS:
            out = join | frozenset(axes_of(eqn))
            outs = [out] * len(eqn.outvars)
        elif name == "scan":
            outs = _scan(eqn, ins)
        elif name == "while":
            outs = _while(eqn, ins)
        elif name == "cond":
            outs = _cond(eqn, ins)
        elif name in _DIRECT_CALLS:
            outs = None
            for sub in walker.eqn_subjaxprs(eqn):
                if len(sub.invars) == len(ins):
                    outs = _transfer(sub, ins)
                    break
            if outs is None:
                outs = [join] * len(eqn.outvars)
        else:
            # Unknown structure (pallas_call kernels, …): every output may
            # depend on every input — join, never drop, so unknown code can
            # only ADD variance (sound for this rule's direction).
            outs = [join] * len(eqn.outvars)
        for v, s in zip(eqn.outvars, outs):
            env[v] = s
    return [get(v) for v in jaxpr.outvars]


def _scan(eqn, ins):
    p = eqn.params
    body = walker.as_jaxpr(p["jaxpr"])
    nc, ncar = int(p["num_consts"]), int(p["num_carry"])
    consts, carry = ins[:nc], list(ins[nc:nc + ncar])
    xs = ins[nc + ncar:]  # per-iteration slice varies like the stack
    outs = carry + [_EMPTY] * (len(eqn.outvars) - ncar)
    for _ in range(64):  # variance sets only grow: terminates fast
        outs = _transfer(body, consts + carry + xs)
        new = [c | o for c, o in zip(carry, outs[:ncar])]
        if new == carry:
            break
        carry = new
    return carry + outs[ncar:]


def _while(eqn, ins):
    p = eqn.params
    cond = walker.as_jaxpr(p["cond_jaxpr"])
    body = walker.as_jaxpr(p["body_jaxpr"])
    cnc, bnc = int(p["cond_nconsts"]), int(p["body_nconsts"])
    cconsts, bconsts = ins[:cnc], ins[cnc:cnc + bnc]
    carry = list(ins[cnc + bnc:])
    for _ in range(64):
        # a varying predicate means shards run different trip counts, so
        # every carry leaves the loop varying — join it in
        pred = _transfer(cond, cconsts + carry)
        pred = pred[0] if pred else _EMPTY
        outs = _transfer(body, bconsts + carry)
        new = [c | o | pred for c, o in zip(carry, outs)]
        if new == carry:
            break
        carry = new
    return carry


def _cond(eqn, ins):
    pred, ops = ins[0], ins[1:]
    n_out = len(eqn.outvars)
    outs = [pred] * n_out
    for branch in eqn.params.get("branches", ()):
        body = walker.as_jaxpr(branch)
        if len(body.invars) == len(ops):
            br = _transfer(body, list(ops))
        else:
            join = frozenset().union(*ins) if ins else _EMPTY
            br = [join] * n_out
        outs = [o | b for o, b in zip(outs, br)]
    return outs


def output_variance(region) -> list[frozenset]:
    """The inferred varying-axes set for each of ``region``'s outputs."""
    in_sets = [frozenset(_names_axes(names)) for names in region.in_names]
    return _transfer(walker.as_jaxpr(region.jaxpr), in_sets)


def check_replication(region) -> list[RepViolation]:
    """Violations: outputs whose variance escapes their declared axes."""
    mesh_axes = frozenset(region.mesh_axes)
    violations = []
    for i, (names, varies) in enumerate(
        zip(region.out_names, output_variance(region))
    ):
        declared = _names_axes(names)
        leaked = (varies & mesh_axes) - declared
        if leaked:
            outvars = walker.as_jaxpr(region.jaxpr).outvars
            aval = str(getattr(outvars[i], "aval", "?")) \
                if i < len(outvars) else "?"
            violations.append(RepViolation(
                out_index=i,
                leaked_axes=tuple(sorted(leaked)),
                declared_axes=tuple(sorted(declared)),
                aval=aval,
            ))
    return violations
