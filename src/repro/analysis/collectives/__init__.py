"""Collective-level static verification of every sharded program.

FlyMC's locality claims are exactly what make it shardable for tall data:
brightness is per-datum so z-updates need ZERO collectives, and the
θ-update reduces to ONE scalar psum per proposal. This package turns those
claims (previously docstring-only) into checkable invariants over the
``shard_map`` regions of a traced program:

====================    ===================================================
collective-budget       per-step census of collectives (kind × mesh axis ×
                        count, scan bodies trip-multiplied) pinned against
                        a declared budget; collectives inside loop bodies
                        and non-scalar reductions are findings
                        (:mod:`.census`, :class:`.rules.CollectiveBudgetRule`)
replication-consistency device-variance dataflow proving every output
                        declared replicated (``out_specs=P()``) derives
                        only from replicated inputs and collective results
                        — the ``check_rep=False`` foot-gun where shard 0's
                        value silently overwrites every other shard's
                        (:mod:`.replication`)
comm-bytes              derived per-device wire-bytes model from the body
                        avals (all-reduce 2·in, all-gather out−in, …),
                        exported into Report.metrics for BENCH and
                        cross-validated against the post-compile HLO
                        accounting in :mod:`repro.launch.hlo_analysis`
                        (:mod:`.wire_bytes`)
shard-shape             divisibility / zero-local-shard soundness of every
                        sharded axis vs the mesh axis sizes, plus optional
                        pinned local shapes (:mod:`.shapes`)
====================    ===================================================

Everything is derived from jaxprs traced from ShapeDtypeStructs — under a
:class:`jax.sharding.AbstractMesh` no physical devices are needed, so the
registry sweep verifies 8-way-sharded programs on a 1-device CI host.
"""

from repro.analysis.collectives.census import CollectiveSite, census
from repro.analysis.collectives.extract import (
    ShardedRegion,
    find_sharded_regions,
)
from repro.analysis.collectives.replication import output_variance
from repro.analysis.collectives.rules import (
    CollectiveBudgetRule,
    CommBytesRule,
    ReplicationRule,
    ShardShapeRule,
    collective_rules,
)
from repro.analysis.collectives.shapes import check_shapes
from repro.analysis.collectives.wire_bytes import wire_model

__all__ = [
    "CollectiveSite",
    "census",
    "ShardedRegion",
    "find_sharded_regions",
    "output_variance",
    "CollectiveBudgetRule",
    "CommBytesRule",
    "ReplicationRule",
    "ShardShapeRule",
    "collective_rules",
    "check_shapes",
    "wire_model",
]
