"""Derived per-device collective wire bytes for a shard_map region.

The model is computed from the census sites' per-shard avals, using the
same per-collective formulas :func:`repro.launch.hlo_analysis` applies to
the *compiled* program's HLO text — the two are cross-validated by test
(``tests/test_collective_analysis.py``), so the static model and the
post-compile accounting cannot drift apart:

==================  ==================================================
psum / pmax / pmin  ring all-reduce: 2 · in_bytes
all_gather          out_bytes − in_bytes  (each device receives the
                    other shards' contributions)
psum_scatter        in_bytes − out_bytes  (reduce-scatter)
all_to_all          in_bytes
ppermute            in_bytes  (collective-permute)
pbroadcast          in_bytes
axis_index          0  (lowered to partition-id: no wire traffic)
==================  ==================================================

Scan sites are trip-multiplied; while-body sites have no static trip
count, so they are EXCLUDED from the total and surfaced under
``unbounded_sites`` — a nonzero count means the total is a lower bound
and the comm-bytes rule reports it.
"""

from __future__ import annotations


def site_wire_bytes(site) -> int:
    """Per-device wire bytes for one collective site (single execution)."""
    if site.kind == "axis_index":
        return 0
    if site.kind == "all_gather":
        return max(site.shard_bytes_out - site.shard_bytes_in, 0)
    if site.kind == "psum_scatter":
        return max(site.shard_bytes_in - site.shard_bytes_out, 0)
    if site.kind in ("psum", "pmax", "pmin"):
        return 2 * site.shard_bytes_in
    return site.shard_bytes_in  # all_to_all, ppermute, pbroadcast


def wire_model(sites) -> dict:
    """The per-step wire-bytes model over a list of census sites."""
    per_kind: dict[str, int] = {}
    per_axis: dict[str, int] = {}
    total = 0
    unbounded = 0
    for s in sites:
        b = site_wire_bytes(s)
        if s.unbounded:
            unbounded += 1
            continue
        b *= s.trip_multiplier
        total += b
        per_kind[s.kind] = per_kind.get(s.kind, 0) + b
        axis_key = ",".join(s.axes) or "<none>"
        per_axis[axis_key] = per_axis.get(axis_key, 0) + b
    return {
        "total": total,
        "per_kind": per_kind,
        "per_axis": per_axis,
        "sites": len(sites),
        "unbounded_sites": unbounded,
    }
