"""Find every ``shard_map`` region reachable from a traced program.

``jax.shard_map`` appears in a jaxpr as one ``shard_map`` equation whose
params carry everything the collective analyses need:

* ``jaxpr``      — the per-shard body as a *raw* ``Jaxpr`` (avals are the
  PER-SHARD shapes, which is exactly what the wire-bytes model wants);
* ``mesh``       — a ``Mesh`` or ``AbstractMesh``; only the axis-name →
  size mapping is used, so tracing needs no physical devices;
* ``in_names`` / ``out_names`` — one ``{dim: (axis, ...)}`` dict per flat
  operand/result ( ``{}`` ⇒ replicated), the flat form of
  in_specs/out_specs;
* ``check_rep``  — whether jax itself verifies replication (this repo's
  call sites all pass ``check_vma=False`` for trace speed, which is why
  :mod:`.replication` exists).

The walk descends through pjit / scan / while / cond / custom-call bodies
(the dist driver jits a scan OVER the shard-mapped step, so regions are
usually nested), recording an origin path for reporting. Tests construct
:class:`ShardedRegion` directly for shapes shard_map itself would reject at
trace time (e.g. indivisible axes).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.analysis import walker


@dataclasses.dataclass
class ShardedRegion:
    """One shard_map call site, normalized for the collective analyses."""

    origin: str                       # eqn path, e.g. "/pjit/shard_map"
    mesh_axes: dict[str, int]         # axis name -> size
    in_names: tuple[dict, ...]        # per flat operand: {dim: (axis, ...)}
    out_names: tuple[dict, ...]
    jaxpr: Any                        # per-shard body (raw Jaxpr)
    check_rep: bool = False
    global_in_avals: tuple = ()       # outer (global-shape) operand avals
    global_out_avals: tuple = ()

    @property
    def mesh_size(self) -> int:
        size = 1
        for n in self.mesh_axes.values():
            size *= int(n)
        return size

    def axis_size(self, axes) -> int:
        """Product of the named axis sizes (the shard count along them)."""
        size = 1
        for a in axes:
            size *= int(self.mesh_axes.get(a, 1))
        return size


def _names_axes(names: dict) -> frozenset:
    """Every mesh axis a {dim: (axes,)} entry shards over."""
    out: set = set()
    for axes in names.values():
        out.update(axes)
    return frozenset(out)


def find_sharded_regions(closed) -> list[ShardedRegion]:
    """Every shard_map region reachable from ``closed``, outermost first."""
    regions: list[ShardedRegion] = []

    def _walk(jaxpr, path: str):
        for eqn in walker.as_jaxpr(jaxpr).eqns:
            sub_path = f"{path}/{eqn.primitive.name}"
            if eqn.primitive.name == "shard_map":
                mesh = eqn.params["mesh"]
                regions.append(ShardedRegion(
                    origin=sub_path,
                    mesh_axes={str(k): int(v)
                               for k, v in dict(mesh.shape).items()},
                    in_names=tuple(eqn.params["in_names"]),
                    out_names=tuple(eqn.params["out_names"]),
                    jaxpr=eqn.params["jaxpr"],
                    check_rep=bool(eqn.params.get("check_rep", False)),
                    global_in_avals=tuple(
                        getattr(v, "aval", None) for v in eqn.invars
                    ),
                    global_out_avals=tuple(
                        getattr(v, "aval", None) for v in eqn.outvars
                    ),
                ))
            for sub in walker.eqn_subjaxprs(eqn):
                _walk(sub, sub_path)

    _walk(walker.as_jaxpr(closed), "")
    return regions
