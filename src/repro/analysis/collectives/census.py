"""Collective census: every cross-device primitive in a shard_map body.

Walks a region's per-shard jaxpr and records one :class:`CollectiveSite`
per collective equation — kind, mesh axes, scope path, per-shard operand /
result bytes — descending into scan / while / cond bodies. A site inside a
``lax.scan`` carries the product of enclosing trip counts
(``trip_multiplier``): one psum in a length-C z-candidate scan is C
collectives per step, which is precisely the regression the per-step
budget exists to catch. ``while`` bodies have no static trip count, so
their sites are flagged ``unbounded`` instead (counted once; the budget
and wire rules each surface the flag).

``cond`` branches are all walked (a site notes ``conditional=True`` via
its scope); exact budgets therefore treat branch collectives as if every
branch ran — conservative for programs that keep collectives out of
branches entirely, which is the only shape this repo ships.
"""

from __future__ import annotations

import dataclasses

from repro.analysis import walker

# primitive name -> canonical collective kind (the wire-model vocabulary)
KINDS = {
    "psum": "psum",
    "pmax": "pmax",
    "pmin": "pmin",
    "all_gather": "all_gather",
    "all_to_all": "all_to_all",
    "ppermute": "ppermute",
    "pbroadcast": "pbroadcast",
    "psum_scatter": "psum_scatter",
    "reduce_scatter": "psum_scatter",
    "axis_index": "axis_index",
}


def axes_of(eqn) -> tuple[str, ...]:
    """The mesh axes a collective eqn operates over (named axes only)."""
    ax = eqn.params.get("axes", eqn.params.get("axis_name"))
    if ax is None:
        return ()
    if not isinstance(ax, (tuple, list)):
        ax = (ax,)
    return tuple(a for a in ax if isinstance(a, str))


def _aval_bytes(var) -> int:
    aval = getattr(var, "aval", None)
    if aval is None or not hasattr(aval, "dtype"):
        return 0
    size = 1
    for d in getattr(aval, "shape", ()) or ():
        size *= int(d)
    return size * aval.dtype.itemsize


def _is_scalar(var) -> bool:
    aval = getattr(var, "aval", None)
    return not tuple(getattr(aval, "shape", ()) or ())


@dataclasses.dataclass(frozen=True)
class CollectiveSite:
    """One collective equation inside a shard_map body."""

    kind: str                   # canonical kind (KINDS value)
    axes: tuple[str, ...]       # mesh axes reduced / indexed over
    scope: str                  # path inside the body ("" = top level)
    trip_multiplier: int        # product of enclosing scan lengths
    unbounded: bool             # inside a while body (no static trip count)
    in_loop: bool               # inside any scan/while body
    shard_bytes_in: int         # per-shard operand bytes
    shard_bytes_out: int        # per-shard result bytes
    scalar: bool                # all operands are scalars

    @property
    def key(self) -> str:
        """Budget key: ``kind@axis1,axis2`` (the kind × mesh-axis census)."""
        return f"{self.kind}@{','.join(self.axes)}"


def census(region) -> list[CollectiveSite]:
    """Every collective site in ``region``'s body, recursively."""
    sites: list[CollectiveSite] = []

    def _walk(jaxpr, scope: str, mult: int, unbounded: bool, in_loop: bool):
        for eqn in walker.as_jaxpr(jaxpr).eqns:
            name = eqn.primitive.name
            kind = KINDS.get(name)
            if kind is not None:
                sites.append(CollectiveSite(
                    kind=kind,
                    axes=axes_of(eqn),
                    scope=scope,
                    trip_multiplier=mult,
                    unbounded=unbounded,
                    in_loop=in_loop,
                    shard_bytes_in=sum(
                        _aval_bytes(v) for v in eqn.invars
                    ),
                    shard_bytes_out=sum(
                        _aval_bytes(v) for v in eqn.outvars
                    ),
                    scalar=all(_is_scalar(v) for v in eqn.invars),
                ))
                continue
            sub_scope = f"{scope}/{name}"
            if name == "scan":
                trip = int(eqn.params.get("length", 1))
                _walk(eqn.params["jaxpr"], sub_scope, mult * trip,
                      unbounded, True)
            elif name == "while":
                _walk(eqn.params["body_jaxpr"], sub_scope, mult, True, True)
                _walk(eqn.params["cond_jaxpr"], f"{sub_scope}.cond", mult,
                      True, True)
            else:
                for sub in walker.eqn_subjaxprs(eqn):
                    _walk(sub, sub_scope, mult, unbounded, in_loop)

    _walk(region.jaxpr, "", 1, False, False)
    return sites


def census_counts(sites) -> dict[str, int]:
    """Trip-multiplied counts per ``kind@axes`` key (the budget's shape).

    Unbounded (while-body) sites count once here; the budget rule flags
    them separately since no static count exists.
    """
    counts: dict[str, int] = {}
    for s in sites:
        counts[s.key] = counts.get(s.key, 0) + s.trip_multiplier
    return counts
