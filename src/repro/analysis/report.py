"""Structured output of an analysis run: findings, reports, sweep summary.

A :class:`Finding` is one rule violation with enough detail to act on; a
:class:`Report` is one entry point's findings plus the cost metrics the
benchmark harness records (eqn counts, worst RNG/cumsum sizes, const
bytes); a :class:`Summary` is a registry sweep — what the CLI prints and
the CI lane gates on.

"Expected-fail" is first-class: the jnp z-engine exists precisely to trip
the cost-model rule (it is the sanity check that the detectors detect), so
a report carries the set of rules it is *expected* to fail and ``ok``
means "failed exactly the expected rules, no more, no fewer" — an
expected-fail rule that silently passes is itself a regression (the
detector went blind).
"""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one entry point."""

    rule: str
    entry_point: str
    message: str
    details: dict = dataclasses.field(default_factory=dict)

    def __str__(self) -> str:
        return f"[{self.rule}] {self.entry_point}: {self.message}"


@dataclasses.dataclass
class Report:
    """One entry point's analysis result.

    ``metrics`` is the cost fingerprint recorded into ``BENCH_flymc.json``
    (see :func:`repro.analysis.registry.sweep_record`); ``rules_run`` lists
    every rule name that executed so a silently-skipped rule is visible.
    """

    entry_point: str
    findings: list[Finding]
    rules_run: list[str]
    metrics: dict[str, Any] = dataclasses.field(default_factory=dict)
    expect_fail: frozenset[str] = frozenset()

    @property
    def failed_rules(self) -> frozenset[str]:
        return frozenset(f.rule for f in self.findings)

    @property
    def unexpected_failures(self) -> list[Finding]:
        return [f for f in self.findings if f.rule not in self.expect_fail]

    @property
    def missing_expected_failures(self) -> frozenset[str]:
        """Expected-fail rules that did NOT fire: the detector went blind."""
        return frozenset(self.expect_fail) - self.failed_rules

    @property
    def ok(self) -> bool:
        return not self.unexpected_failures and not self.missing_expected_failures

    def rule_status(self, rule: str) -> str:
        """'pass' | 'fail' | 'xfail' (expected and observed) | 'xpass'
        (expected to fail but passed — a regression)."""
        failed = rule in self.failed_rules
        expected = rule in self.expect_fail
        if failed:
            return "xfail" if expected else "fail"
        return "xpass" if expected else "pass"


@dataclasses.dataclass
class Summary:
    """A whole registry sweep."""

    reports: list[Report]

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.reports)

    def format_table(self) -> str:
        """The CLI's human-readable sweep table."""
        rows = [("entry point", "rules", "status", "worst finding")]
        for r in self.reports:
            statuses = ",".join(
                f"{name}:{r.rule_status(name)}" for name in r.rules_run
            )
            if r.ok:
                status = "OK"
            elif r.missing_expected_failures:
                status = "XPASS"
            else:
                status = "FAIL"
            worst = r.unexpected_failures[0].message if r.unexpected_failures else (
                f"expected-fail rule(s) passed: "
                f"{sorted(r.missing_expected_failures)}"
                if r.missing_expected_failures
                else ""
            )
            rows.append((r.entry_point, statuses, status, worst[:60]))
        widths = [max(len(row[i]) for row in rows) for i in range(3)]
        lines = []
        for i, row in enumerate(rows):
            lines.append(
                "  ".join(c.ljust(w) for c, w in zip(row[:3], widths))
                + ("  " + row[3] if row[3] else "")
            )
            if i == 0:
                lines.append("  ".join("-" * w for w in widths))
        return "\n".join(lines)

    def to_record(self) -> dict:
        """JSON-ready sweep record (the BENCH_flymc.json payload)."""
        return {
            "ok": self.ok,
            "entry_points": {
                r.entry_point: {
                    "rules": {
                        name: r.rule_status(name) for name in r.rules_run
                    },
                    "findings": [
                        {"rule": f.rule, "message": f.message}
                        for f in r.findings
                    ],
                    **r.metrics,
                }
                for r in self.reports
            },
        }
