"""Recursive jaxpr traversal — the single inspection substrate.

Every exactness/cost invariant this repo pins statically is a statement
about a jaxpr: "no length-N RNG in the fused step", "no O(num_samples)
buffer in a collectors-only chunk", "the dataset is an operand, not a
constant". Those used to be checked by ad-hoc ``_walk_eqns`` copies in the
test files; this module is the one shared walker the rule engine
(:mod:`repro.analysis.rules`) and the tests build on.

The traversal is closed under every sub-jaxpr container jax uses: scan /
while / cond bodies (``ClosedJaxpr`` params), pjit and custom_* calls, and
Pallas kernels — ``pallas_call`` carries its kernel as a *raw* ``Jaxpr``
param, so the in-kernel equations (tile-shaped threefry lanes, DMA gets)
are visible to the same sweep as the surrounding XLA program.
"""

from __future__ import annotations

from collections import Counter
from typing import Iterable, Iterator

import jax
import jax.extend.core as jex_core
import numpy as np

Jaxpr = jex_core.Jaxpr
ClosedJaxpr = jex_core.ClosedJaxpr


def as_jaxpr(obj) -> Jaxpr:
    """Normalize a ClosedJaxpr | Jaxpr to the underlying Jaxpr."""
    return obj.jaxpr if isinstance(obj, ClosedJaxpr) else obj


def subjaxprs(value) -> Iterator[Jaxpr]:
    """Yield every jaxpr reachable from one eqn-param value.

    Handles ``ClosedJaxpr`` (scan/while/cond/pjit bodies), bare ``Jaxpr``
    (``pallas_call``'s kernel), and list/tuple/dict containers of either
    (``cond``'s branches, custom-call bundles).
    """
    if isinstance(value, ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for item in value:
            yield from subjaxprs(item)
    elif isinstance(value, dict):
        for item in value.values():
            yield from subjaxprs(item)


def eqn_subjaxprs(eqn) -> Iterator[Jaxpr]:
    """Every sub-jaxpr hanging off one equation's params."""
    for value in eqn.params.values():
        yield from subjaxprs(value)


def walk_eqns(jaxpr) -> Iterator:
    """Depth-first over every eqn of ``jaxpr`` and all nested sub-jaxprs."""
    for eqn in as_jaxpr(jaxpr).eqns:
        yield eqn
        for sub in eqn_subjaxprs(eqn):
            yield from walk_eqns(sub)


def var_size(var) -> int:
    """Element count of a jaxpr atom (1 for scalars and literals)."""
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    return int(np.prod(shape)) if shape else 1


def eqn_work_size(eqn) -> int:
    """The element count that bounds one eqn's *data-dependent work*.

    For most primitives that is the largest output. Scatter is the
    exception: its output aliases the full operand (updating an (N,)
    partition array emits an (N,)-shaped result even when only O(changed)
    rows are written), so scatters are sized by their ``updates`` operand —
    the values actually written — not the pass-through buffer.
    """
    if eqn.primitive.name.startswith("scatter"):
        # (operand, scatter_indices, updates)
        return var_size(eqn.invars[2]) if len(eqn.invars) >= 3 else 0
    return max((var_size(v) for v in eqn.outvars), default=0)


def matches(eqn, prim_names: Iterable[str]) -> bool:
    """Substring match of the primitive name against any of ``prim_names``
    (the historical test-helper contract: 'cumsum' matches 'cumsum',
    'random_bits' matches 'random_bits', …)."""
    name = eqn.primitive.name
    return any(p in name for p in prim_names)


def max_eqn_size(jaxpr, prim_names: Iterable[str]) -> int:
    """Largest work size over all eqns whose primitive matches, everywhere
    in the (recursively walked) jaxpr. 0 when nothing matches."""
    prim_names = tuple(prim_names)
    return max(
        (eqn_work_size(e) for e in walk_eqns(jaxpr) if matches(e, prim_names)),
        default=0,
    )


def max_dim(jaxpr) -> int:
    """Largest single dimension appearing on any eqn input or output.

    The memory detector behind "a collectors-only chunk traces no
    O(num_samples) buffer": if no array anywhere in the program has a
    dimension of that size, the buffer is absent, not merely dead."""
    worst = 0
    for eqn in walk_eqns(jaxpr):
        for var in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(var, "aval", None)
            shape = getattr(aval, "shape", None)
            if shape:
                worst = max(worst, max(shape))
    return worst


def count_eqns(jaxpr) -> int:
    """Total eqn count, sub-jaxprs included."""
    return sum(1 for _ in walk_eqns(jaxpr))


def primitive_counts(jaxpr) -> Counter:
    """Histogram of primitive names over the whole (recursive) jaxpr."""
    return Counter(e.primitive.name for e in walk_eqns(jaxpr))


def iter_consts(closed: ClosedJaxpr):
    """Yield ``(path, const)`` for every closure constant, recursively.

    Top-level consts are the classic jit-closure captures (the PR 6
    bitwise-divergence class when a dataset lands here); nested
    ``ClosedJaxpr`` params can carry their own. ``path`` names where the
    const was found (e.g. ``"scan/pjit"``) for reporting.
    """

    def _walk(cj: ClosedJaxpr, path: str):
        for const in cj.consts:
            yield path, const
        for eqn in cj.jaxpr.eqns:
            for value in eqn.params.values():
                for sub in _closed_subs(value):
                    yield from _walk(sub, f"{path}/{eqn.primitive.name}")

    def _closed_subs(value):
        if isinstance(value, ClosedJaxpr):
            yield value
        elif isinstance(value, (list, tuple)):
            for item in value:
                yield from _closed_subs(item)
        elif isinstance(value, dict):
            for item in value.values():
                yield from _closed_subs(item)

    yield from _walk(closed, "")


def const_bytes(closed: ClosedJaxpr) -> list[tuple[str, tuple, str, int]]:
    """[(path, shape, dtype, nbytes)] for every closure constant."""
    out = []
    for path, const in iter_consts(closed):
        arr = np.asarray(const) if not hasattr(const, "dtype") else const
        shape = tuple(getattr(arr, "shape", ()) or ())
        dtype = str(getattr(arr, "dtype", type(const).__name__))
        nbytes = int(getattr(arr, "nbytes", 0) or 0)
        out.append((path or "/", shape, dtype, nbytes))
    return out


def make_jaxpr_of(fn, *args, **kwargs) -> ClosedJaxpr:
    """``jax.make_jaxpr`` with kwargs threaded — the one trace entry point
    the analyzer uses, so rules never re-implement tracing policy."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
