"""``python -m repro.analysis`` — sweep the registered hot-path entry points.

Prints the per-entry-point rule table and exits nonzero on any regression:
an unexpected finding, OR an expected-fail rule that went quiet (the jnp
engine passing cost-model would mean the detector is blind). ``--json``
emits the same record ``benchmarks/run.py`` stores under
``static_analysis`` in ``BENCH_flymc.json``; ``--annotations`` emits one
GitHub ``::error`` workflow command per regression (on stderr, so it
composes with ``--json`` redirection) — the CI static-analysis lane uses
both to surface per-rule findings directly on the PR.
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import registry


def annotation_lines(summary) -> list[str]:
    """One GitHub ``::error`` workflow command per regression.

    Workflow-command payloads are single-line; GitHub's escaping for the
    message body is %0A/%0D for newlines and %25 for literal percents.
    """

    def esc(s: str) -> str:
        return (s.replace("%", "%25").replace("\r", "%0D")
                 .replace("\n", "%0A"))

    lines = []
    for report in summary.reports:
        for f in report.unexpected_failures:
            lines.append(
                f"::error title={esc(f'[{f.rule}] {report.entry_point}')}"
                f"::{esc(f.message)}"
            )
        for rule in sorted(report.missing_expected_failures):
            lines.append(
                f"::error title={esc(f'[{rule}] {report.entry_point}')}"
                f"::expected-fail rule passed — the detector went blind "
                f"(xpass fails the sweep)"
            )
    return lines


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static exactness & cost sweep over registered jits",
    )
    parser.add_argument(
        "names", nargs="*",
        help="entry points to sweep (default: all registered)",
    )
    parser.add_argument("--list", action="store_true",
                        help="list registered entry points and exit")
    parser.add_argument("--json", action="store_true",
                        help="emit the sweep record as JSON")
    parser.add_argument("--annotations", action="store_true",
                        help="emit GitHub ::error workflow commands "
                             "(stderr) for every regression")
    args = parser.parse_args(argv)

    if args.list:
        for name in registry.REGISTRY:
            print(name)
        return 0

    unknown = [n for n in args.names if n not in registry.REGISTRY]
    if unknown:
        parser.error(
            f"unknown entry points {unknown}; see --list"
        )
    summary = registry.run_registry(args.names or None)
    if args.annotations:
        for line in annotation_lines(summary):
            print(line, file=sys.stderr)
    if args.json:
        print(json.dumps(summary.to_record(), indent=2, sort_keys=True))
    else:
        print(summary.format_table())
        for report in summary.reports:
            for finding in report.unexpected_failures:
                print(f"  {finding}")
        verdict = "OK" if summary.ok else "FAIL"
        print(f"\nstatic-analysis: {verdict} "
              f"({len(summary.reports)} entry points)")
    return 0 if summary.ok else 1


if __name__ == "__main__":
    sys.exit(main())
