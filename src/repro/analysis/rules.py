"""The rule engine: exactness & cost invariants as checkable rules.

Each rule encodes one way a hot path has historically (or could) silently
break FlyMC's *exactness at subset cost* guarantee:

=====================  =====================================================
cost-model             an O(N) primitive re-enters a fused step (length-N
                       RNG draws, full-N cumsum re-partition, N-sized
                       gathers/scatter writes) — the work class the fused
                       engines exist to kill
closure-constant       a large array (the dataset) is baked into a jit as a
                       closure constant instead of traced as an operand —
                       the PR 6 bitwise-divergence class: XLA rounds
                       data-dependent reductions differently for constants
rng-lineage            a PRNG key is reused for two draws, or a loop body
                       draws from a key that does not vary with the
                       iteration (the PR 3 resume-prefix replay class)
capacity-independence  a jaxpr that must be identical across buffer
                       capacities (the committed-chunk fold) grew a
                       capacity-dependent shape — the PR 5 retrace-
                       avoidance pin
donation               a donated carry is not actually aliased to an output
                       (shape/dtype drift turned the in-place update into a
                       silent copy, or the value stayed live)
=====================  =====================================================

Rules are pure functions of traced jaxprs (plus lowered StableHLO for
donation); they never execute the computation under analysis. A rule
returns :class:`~repro.analysis.report.Finding`\\ s — empty means the
invariant holds.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.extend.core as jex_core

from repro.analysis import walker
from repro.analysis.report import Finding, Report

# Primitives that materialize fresh random bits. `threefry2x32` is the raw
# counter cipher jax's PRNG lowers to on some paths; the in-kernel Pallas
# cipher (repro.core.numerics.threefry2x32) is plain bit arithmetic and is
# costed by the generic size sweep, not named here.
RNG_PRIMS = ("threefry2x32", "random_bits", "random_gamma")

# Key-consuming primitives that DRAW (vs derive): the lineage rule's sinks.
SAMPLING_PRIMS = ("random_bits", "threefry2x32", "random_gamma")


@dataclasses.dataclass
class Context:
    """What one entry point hands every rule."""

    name: str
    closed: jex_core.ClosedJaxpr
    fn: Callable | None = None  # for rules that must re-trace / lower
    args: tuple = ()


class Rule:
    """Base: ``check(ctx) -> list[Finding]``; ``name`` identifies the rule
    in reports, budgets, and expect_fail sets."""

    name: str = "rule"

    def check(self, ctx: Context) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError

    def _finding(self, ctx: Context, message: str, **details) -> Finding:
        return Finding(self.name, ctx.name, message, details)


# ---------------------------------------------------------------------------
# cost-model
# ---------------------------------------------------------------------------


class CostModelRule(Rule):
    """No O(N) primitive in a fused hot path.

    ``n`` is the dataset size (the budget every class defaults to): any
    RNG / cumsum / gather eqn producing ≥ budget elements, or any scatter
    *writing* ≥ budget elements (scatter outputs alias the full operand, so
    they are sized by their updates — see
    :func:`repro.analysis.walker.eqn_work_size`), is a finding. Per-class
    ``budgets`` override the default — e.g. an entry point whose legitimate
    gather is O(capacity·D) can pin a tighter gather budget than N.
    """

    name = "cost-model"

    #: class name -> primitive name substrings
    CLASSES = {
        "rng": RNG_PRIMS,
        "cumsum": ("cumsum",),
        "gather": ("gather",),
        "scatter": ("scatter",),
    }

    def __init__(self, n: int, budgets: dict[str, int] | None = None):
        self.n = int(n)
        self.budgets = dict(budgets or {})

    def check(self, ctx: Context) -> list[Finding]:
        findings = []
        for cls, prims in self.CLASSES.items():
            budget = int(self.budgets.get(cls, self.n))
            worst = walker.max_eqn_size(ctx.closed, prims)
            if worst >= budget:
                findings.append(self._finding(
                    ctx,
                    f"{cls} eqn works on {worst} elements "
                    f"(budget {budget}, N={self.n}) — O(N) work re-entered "
                    f"the hot path",
                    cls=cls, worst=worst, budget=budget, n=self.n,
                ))
        return findings

    def metrics(self, closed) -> dict:
        """The per-class worst sizes, for the benchmark record."""
        return {
            f"max_{cls}_size": walker.max_eqn_size(closed, prims)
            for cls, prims in self.CLASSES.items()
        }


# ---------------------------------------------------------------------------
# closure-constant
# ---------------------------------------------------------------------------


class ClosureConstRule(Rule):
    """No large closure constant in a hot-path jit.

    Datasets must reach compiled code as *traced operands*: a baked-in
    constant changes XLA's constant folding and hence the low-bit rounding
    of data-dependent reductions (PR 6: solo vs packed trajectories diverged
    until the driver threaded the dataset as an operand). Anything above
    ``max_bytes`` in the jaxpr's consts — at any nesting level — is flagged.
    Small captures (iota tables, capacity-sized masks) pass.
    """

    name = "closure-constant"

    def __init__(self, max_bytes: int = 8192):
        self.max_bytes = int(max_bytes)

    def check(self, ctx: Context) -> list[Finding]:
        findings = []
        for path, shape, dtype, nbytes in walker.const_bytes(ctx.closed):
            if nbytes > self.max_bytes:
                findings.append(self._finding(
                    ctx,
                    f"closure constant {dtype}{list(shape)} ({nbytes} B > "
                    f"{self.max_bytes} B) at {path} — pass it as a traced "
                    f"operand (constants change XLA reduction rounding)",
                    path=path, shape=shape, dtype=dtype, nbytes=nbytes,
                ))
        return findings


# ---------------------------------------------------------------------------
# rng-lineage
# ---------------------------------------------------------------------------

# Call-like primitives whose sub-jaxpr invars map 1:1 onto the eqn invars.
_DIRECT_CALLS = {
    "pjit", "closed_call", "core_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_vmap_call",
}

_CONST = 0    # derived only from literals / closure constants
_FRESH = 1    # derived from the entry point's own arguments
_VARYING = 2  # derived from a loop-varying value (carry / scanned xs)


class RngLineageRule(Rule):
    """Key derivations must be single-use and iteration-dependent.

    A taint walk over the jaxpr tracks, for every var, whether it derives
    from loop-varying values (scan carries / scanned inputs), from the
    entry point's arguments, or only from constants. Two findings:

    * **reused key** — one key var feeds two or more drawing primitives
      (``random_bits`` et al.) in the same scope. Correct code splits or
      folds first; drawing twice replays the stream.
    * **iteration-independent key** — inside a scan/while body, a draw
      whose key does not derive from the iteration (a fold_in with a
      constant counter, or a loop-invariant key drawn directly). This is
      the PR 3 resume bug class statically: every iteration replays the
      same randomness. Domain-separation folds (``fold_in(step_key, 3)``)
      pass because ``step_key`` itself varies.

    Conservative by construction: sub-jaxprs whose invar mapping is unknown
    (Pallas kernels, exotic calls) mark their inputs varying, so unknown
    structure can only suppress findings, never fabricate them.
    """

    name = "rng-lineage"

    # Primitives through which a value stays THE SAME logical key. Anything
    # else (fold_in, split, slicing a split's output, arithmetic on key
    # data) yields a NEW key identity — so unknown derivations can never
    # produce a false "reuse" (two fresh identities never collide), only a
    # miss.
    KEY_PASSTHROUGH = ("random_wrap", "random_unwrap", "copy")

    def check(self, ctx: Context) -> list[Finding]:
        findings: list[Finding] = []
        jaxpr = ctx.closed.jaxpr
        # draws: key identity -> (count, first scope, prim). Global across
        # scopes because jax.random wraps every draw in its own pjit — two
        # draws from one key land in sibling sub-jaxprs, so per-scope
        # counting would be blind to exactly the bug this rule exists for.
        self._fresh = 0
        draws: dict[int, list] = {}
        in_ids = [self._new_id() for _ in jaxpr.invars]
        self._analyze(
            ctx, jaxpr, [_FRESH] * len(jaxpr.invars), in_ids, "", False,
            findings, draws,
        )
        for count, scope, prim in draws.values():
            if count >= 2:
                findings.append(self._finding(
                    ctx,
                    f"key reused by {count} draws (first at "
                    f"{scope or '/'}) — split/fold_in before each draw "
                    f"(reuse replays the stream)",
                    scope=scope or "/", draws=count, primitive=prim,
                ))
        return findings

    # -- taint + key-identity machinery -------------------------------------

    def _new_id(self) -> int:
        self._fresh += 1
        return self._fresh

    def _analyze(self, ctx, jaxpr, in_taint, in_ids, scope, in_loop,
                 findings, draws):
        taint: dict[Any, int] = {}
        keyid: dict[Any, int] = {}
        for var, t, i in zip(jaxpr.invars, in_taint, in_ids):
            taint[var] = t
            keyid[var] = i
        for var in jaxpr.constvars:
            taint[var] = _CONST
            keyid[var] = self._new_id()

        def t_of(atom) -> int:
            if isinstance(atom, jex_core.Literal):
                return _CONST
            return taint.get(atom, _CONST)

        def id_of(atom):
            if isinstance(atom, jex_core.Literal):
                return None
            return keyid.get(atom)

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_ts = [t_of(a) for a in eqn.invars]
            in_is = [id_of(a) for a in eqn.invars]
            if name in SAMPLING_PRIMS and eqn.invars:
                kid = in_is[0]
                if kid is not None:
                    rec = draws.setdefault(kid, [0, scope, name])
                    rec[0] += 1
                if in_loop and (in_ts[0] if in_ts else _CONST) < _VARYING:
                    findings.append(self._finding(
                        ctx,
                        f"{name} at {scope or '/'} draws from a key that "
                        f"does not vary with the loop iteration — every "
                        f"iteration replays the same stream (fold_in the "
                        f"iteration counter)",
                        scope=scope or "/", primitive=name,
                    ))
            self._recurse(ctx, eqn, in_ts, in_is, scope, in_loop, findings,
                          draws)
            out_t = max(in_ts, default=_CONST)
            passthrough = (
                name in self.KEY_PASSTHROUGH
                and len(eqn.invars) == 1 and len(eqn.outvars) == 1
                and in_is[0] is not None
            )
            for ov in eqn.outvars:
                taint[ov] = out_t
                keyid[ov] = in_is[0] if passthrough else self._new_id()

    def _recurse(self, ctx, eqn, in_ts, in_is, scope, in_loop, findings,
                 draws):
        name = eqn.primitive.name
        params = eqn.params
        sub_scope = f"{scope}/{name}"

        def fresh(n):
            return [self._new_id() for _ in range(n)]

        if name == "scan":
            body = params["jaxpr"].jaxpr
            nc = params["num_consts"]
            extra = len(body.invars) - nc
            self._analyze(
                ctx, body, in_ts[:nc] + [_VARYING] * extra,
                in_is[:nc] + fresh(extra), sub_scope, True, findings, draws,
            )
        elif name == "while":
            cnc, bnc = params["cond_nconsts"], params["body_nconsts"]
            cond = params["cond_jaxpr"].jaxpr
            body = params["body_jaxpr"].jaxpr
            carry_n = len(body.invars) - bnc
            self._analyze(
                ctx, body, in_ts[cnc:cnc + bnc] + [_VARYING] * carry_n,
                in_is[cnc:cnc + bnc] + fresh(carry_n), sub_scope, True,
                findings, draws,
            )
            cond_extra = len(cond.invars) - cnc
            self._analyze(
                ctx, cond, in_ts[:cnc] + [_VARYING] * cond_extra,
                in_is[:cnc] + fresh(cond_extra), f"{sub_scope}.cond", True,
                findings, draws,
            )
        elif name == "cond":
            # Branches are mutually exclusive: a draw from one key in EACH
            # branch executes at most once, so branch draw counts merge by
            # max (per key), then add into the enclosing scope's counts.
            merged: dict[int, list] = {}
            for branch in params.get("branches", ()):
                body = branch.jaxpr
                branch_draws: dict[int, list] = {}
                if len(body.invars) == len(in_ts) - 1:
                    self._analyze(
                        ctx, body, in_ts[1:], in_is[1:], sub_scope, in_loop,
                        findings, branch_draws,
                    )
                else:
                    self._analyze(
                        ctx, body, [_VARYING] * len(body.invars),
                        fresh(len(body.invars)), sub_scope, in_loop,
                        findings, branch_draws,
                    )
                for kid, rec in branch_draws.items():
                    cur = merged.get(kid)
                    if cur is None or rec[0] > cur[0]:
                        merged[kid] = rec
            for kid, rec in merged.items():
                outer = draws.setdefault(kid, [0, rec[1], rec[2]])
                outer[0] += rec[0]
        elif name in _DIRECT_CALLS:
            for sub in walker.eqn_subjaxprs(eqn):
                if len(sub.invars) == len(in_ts):
                    self._analyze(
                        ctx, sub, list(in_ts), list(in_is), sub_scope,
                        in_loop, findings, draws,
                    )
        else:
            # Unknown structure (pallas_call kernels, …): assume varying,
            # fresh identities — conservative, can only suppress findings.
            for sub in walker.eqn_subjaxprs(eqn):
                self._analyze(
                    ctx, sub, [_VARYING] * len(sub.invars),
                    fresh(len(sub.invars)), sub_scope, in_loop, findings,
                    draws,
                )


# ---------------------------------------------------------------------------
# capacity-independence
# ---------------------------------------------------------------------------


class CapacityIndependenceRule(Rule):
    """A set of jaxpr variants that MUST be structurally identical.

    The committed-chunk fold is cached capacity-independently (a
    capacity-doubling overflow re-run retraces only the chain scan, never
    the fold — the PR 5 pin); that only holds while the fold's jaxpr is
    bit-identical across capacities. ``variants`` maps labels to thunks
    producing a ClosedJaxpr; the fingerprint is the pretty-printed jaxpr
    (stable var naming), so any shape, primitive, or structure drift shows.
    """

    name = "capacity-independence"

    def __init__(self, variants: dict[str, Callable[[], Any]]):
        if len(variants) < 2:
            raise ValueError("need >= 2 variants to compare")
        self.variants = dict(variants)

    def check(self, ctx: Context) -> list[Finding]:
        prints = {
            label: str(thunk()) for label, thunk in self.variants.items()
        }
        labels = list(prints)
        ref = labels[0]
        findings = []
        for label in labels[1:]:
            if prints[label] != prints[ref]:
                findings.append(self._finding(
                    ctx,
                    f"jaxpr differs between variants {ref!r} and {label!r} "
                    f"— this program must be identical across capacities "
                    f"(the fold's jit cache is keyed capacity-independently)",
                    reference=ref, variant=label,
                ))
        return findings


# ---------------------------------------------------------------------------
# donation
# ---------------------------------------------------------------------------


class DonationRule(Rule):
    """Donated inputs must actually alias outputs after lowering.

    ``jit(fn, donate_argnums=...)`` is a *request*: if a donated leaf's
    shape/dtype has no matching output (dtype promotion in the fold body,
    a dropped carry), XLA silently copies instead — the O(num_samples)
    in-place trace update becomes an O(num_samples) copy per chunk, and a
    still-live donated value is read-after-donation. Checked two ways:
    aval compatibility (every donated leaf needs an alias-compatible
    output), and the lowered StableHLO's ``tf.aliasing_output`` arg
    attributes (one per donated leaf).
    """

    name = "donation"

    def __init__(self, donate_argnums: Sequence[int] = (0,)):
        self.donate_argnums = tuple(donate_argnums)

    def check(self, ctx: Context) -> list[Finding]:
        if ctx.fn is None:
            return [self._finding(
                ctx, "donation rule needs the callable (fn=) to lower"
            )]
        findings = []
        donated = []
        for argnum in self.donate_argnums:
            donated.extend(jax.tree.leaves(ctx.args[argnum]))
        out_avals = {}
        for leaf in jax.tree.leaves(
            jax.eval_shape(ctx.fn, *ctx.args)
        ):
            sig = (tuple(leaf.shape), str(leaf.dtype))
            out_avals[sig] = out_avals.get(sig, 0) + 1
        for leaf in donated:
            sig = (tuple(leaf.shape), str(leaf.dtype))
            if out_avals.get(sig, 0) > 0:
                out_avals[sig] -= 1
            else:
                findings.append(self._finding(
                    ctx,
                    f"donated leaf {sig[1]}{list(sig[0])} has no "
                    f"alias-compatible output — the donation is a silent "
                    f"copy (shape/dtype drift in the fold body?)",
                    shape=sig[0], dtype=sig[1],
                ))
        with warnings.catch_warnings():
            # jax warns "Some donated buffers were not usable" here; the
            # findings below report the same fact structurally.
            warnings.simplefilter("ignore")
            text = (
                jax.jit(ctx.fn, donate_argnums=self.donate_argnums)
                .lower(*ctx.args)
                .as_text()
            )
        aliased = text.count("tf.aliasing_output")
        if aliased < len(donated):
            findings.append(self._finding(
                ctx,
                f"only {aliased}/{len(donated)} donated leaves are aliased "
                f"to outputs in the lowered module — the rest are copied "
                f"(read-after-donation hazard)",
                aliased=aliased, donated=len(donated),
            ))
        return findings


# ---------------------------------------------------------------------------
# check(): the library surface
# ---------------------------------------------------------------------------


def standard_metrics(closed) -> dict:
    """The cost fingerprint every Report carries (and BENCH records)."""
    consts = walker.const_bytes(closed)
    return {
        "eqn_count": walker.count_eqns(closed),
        "max_rng_size": walker.max_eqn_size(closed, RNG_PRIMS),
        "max_cumsum_size": walker.max_eqn_size(closed, ("cumsum",)),
        "max_gather_size": walker.max_eqn_size(closed, ("gather",)),
        "max_scatter_update_size": walker.max_eqn_size(closed, ("scatter",)),
        "const_bytes_total": sum(c[3] for c in consts),
        "const_bytes_max": max((c[3] for c in consts), default=0),
    }


def check(
    fn: Callable,
    *args,
    rules: Sequence[Rule],
    name: str = "<anonymous>",
    expect_fail: Sequence[str] = (),
) -> Report:
    """Trace ``fn(*args)`` and run ``rules`` over its jaxpr.

    The library API behind both the CLI sweep and the tests:

        report = analysis.check(alg.step_data, key, state, data, stats,
                                rules=[CostModelRule(n=N)], name="step")
        assert report.ok, report.findings

    ``expect_fail`` names rules this entry point is *supposed* to trip
    (the jnp z-engine vs cost-model); ``report.ok`` then also fails if an
    expected rule goes quiet — a blind detector is a regression too.
    """
    closed = walker.make_jaxpr_of(fn, *args)
    ctx = Context(name=name, closed=closed, fn=fn, args=args)
    findings: list[Finding] = []
    metrics = standard_metrics(closed)
    for rule in rules:
        findings.extend(rule.check(ctx))
        # Rules may surface derived quantities (the kernel bytes model)
        # into the report's metrics, which BENCH records per entry point.
        report_metrics = getattr(rule, "report_metrics", None)
        if report_metrics is not None:
            metrics.update(report_metrics(ctx))
    return Report(
        entry_point=name,
        findings=findings,
        rules_run=[r.name for r in rules],
        metrics=metrics,
        expect_fail=frozenset(expect_fail),
    )
