"""The registered hot-path entry points the CLI sweep gates.

Every jit the sampler's hot loop runs through is (or should be) registered
here with the rules it must satisfy: the fused / jnp / pallas steps, the
driver's chunk scan and committed-chunk fold, the serve group chunk, and
the distributed chain fleet. ``python -m repro.analysis`` sweeps them all;
the ``static-analysis`` CI lane fails on any regression. New subsystems
(data_fleet, paged bright-set memory) register here as part of landing.

Registering a new entry point::

    @entry_point("mything.step")
    def _mything():
        fn, args = ...          # what to trace (structs are fine)
        return check(fn, *args, rules=[...], name="mything.step")

Builders trace with ``jax.eval_shape``-derived structs wherever possible —
the sweep never *runs* a sampler step, it only traces and (for the
donation rule) lowers, so it stays cheap enough to gate every commit. The
jnp z-engine is registered ``expect_fail={"cost-model"}`` on purpose: it
is the known-O(N) engine, and its report going quiet would mean the
detector went blind (reported as ``xpass``, which fails the sweep).
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable

import jax
import jax.numpy as jnp

from repro.analysis.collectives import (
    CommBytesRule,
    ReplicationRule,
    collective_rules,
)
from repro.analysis.kernels import kernel_rules
from repro.analysis.report import Report, Summary
from repro.analysis.rules import (
    CapacityIndependenceRule,
    ClosureConstRule,
    CostModelRule,
    DonationRule,
    RngLineageRule,
    check,
)

# One shared problem shape for the whole sweep: big enough that O(N) work
# is unambiguous (N well above every capacity-shaped buffer), small enough
# to trace in milliseconds.
N, D, CAPACITY = 1024, 4, 64

REGISTRY: OrderedDict[str, Callable[[], Report]] = OrderedDict()


def entry_point(name: str):
    """Register a thunk producing one entry point's Report."""

    def deco(build):
        REGISTRY[name] = build
        return build

    return deco


def run_registry(names=None) -> Summary:
    """Run the sweep (all entry points, or a subset by name)."""
    selected = list(REGISTRY) if names is None else list(names)
    reports = []
    for name in selected:
        reports.append(REGISTRY[name]())
    return Summary(reports=reports)


# ---------------------------------------------------------------------------
# shared fixtures (built lazily, cached — the sweep reuses one dataset)
# ---------------------------------------------------------------------------

_CACHE: dict = {}


def _data():
    if "data" not in _CACHE:
        from repro.data import logistic_data

        _CACHE["data"] = logistic_data(jax.random.key(0), n=N, d=D,
                                       separation=1.5)
    return _CACHE["data"]


def _alg(z_backend="fused", backend="jnp", capacity=CAPACITY):
    key = ("alg", z_backend, backend, capacity)
    if key not in _CACHE:
        from repro import api
        from repro.models.bayes_glm import GLMModel

        model = GLMModel.logistic(_data(), prior_scale=2.0, xi=1.5)
        _CACHE[key] = api.firefly(
            model, kernel="rwmh", capacity=capacity, cand_capacity=capacity,
            q_db=0.01, step_size=0.1, backend=backend, z_backend=z_backend,
        )
    return _CACHE[key]


def _key_struct():
    return jax.eval_shape(lambda: jax.random.key(0))


def _state_struct(alg):
    return jax.eval_shape(alg.init, _key_struct(), alg.default_position)


def _step_rules():
    return [CostModelRule(n=N), ClosureConstRule(), RngLineageRule()]


def _check_step(alg, name, **kw):
    # The operand-data form is the form the driver/serve actually jit; it
    # is also what makes closure-constant meaningful (data is an operand).
    return check(
        alg.step_data, _key_struct(), _state_struct(alg), alg.data, alg.stats,
        rules=_step_rules(), name=name, **kw,
    )


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


@entry_point("step.fused")
def _step_fused() -> Report:
    """The production CPU/TPU step: jnp θ-engine + fused z-engine."""
    return _check_step(_alg(z_backend="fused"), "step.fused")


@entry_point("step.jnp")
def _step_jnp() -> Report:
    """The known-O(N) reference engine — the cost-model rule's sanity case:
    its (N,) uniforms and full-N cumsum MUST trip the detector."""
    return _check_step(
        _alg(z_backend="jnp"), "step.jnp", expect_fail=("cost-model",)
    )


@entry_point("step.pallas")
def _step_pallas() -> Report:
    """Fused θ-kernel (pallas_call) + fused z-engine: the walker descends
    into the Pallas inner jaxprs, so in-kernel tile RNG is costed too."""
    return _check_step(
        _alg(z_backend="fused", backend="pallas"), "step.pallas"
    )


@entry_point("driver.chunk")
def _driver_chunk() -> Report:
    """api.sample's jitted chunk scan (multi-chain, operand-data form)."""
    from repro.api import driver

    alg = _alg()
    k = 2
    chunk = driver._make_scan_fn(alg, num_chains=k, cs=8)
    keys = jax.eval_shape(lambda: jax.random.split(jax.random.key(0), k))
    states = jax.eval_shape(
        alg.batched_init(), keys,
        jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((k,) + jnp.shape(l), l.dtype),
            alg.default_position,
        ),
    )
    start = jax.ShapeDtypeStruct((), jnp.int32)
    return check(
        chunk, states, keys, start, alg.data, alg.stats,
        rules=_step_rules(), name="driver.chunk",
    )


def _fold_args(alg, colls, k=2, cs=8, num_samples=32):
    """(carries, pos, infos) structs for a committed-chunk fold of ``alg``."""
    state1 = _state_struct(alg)
    pos_s, stats_s = alg.output_structs(state1)
    carries = {
        name: jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((k,) + l.shape, l.dtype),
            col.init(num_samples, pos_s, stats_s),
        )
        for name, col in colls.items()
    }
    chunked = lambda s: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((cs, k) + l.shape, l.dtype), s
    )
    return carries, chunked(pos_s), chunked(stats_s)


@entry_point("driver.fold")
def _driver_fold() -> Report:
    """The committed-chunk collector fold: donated carries must really
    alias, and the jaxpr must be IDENTICAL across buffer capacities (the
    PR 5 pin — overflow re-runs retrace only the chain scan, never this)."""
    from repro.api import collectors as collectors_lib
    from repro.api import driver

    colls = {
        "trace": collectors_lib.FullTrace(),
        "moments": collectors_lib.OnlineMoments(),
    }
    fold = driver.make_collector_fold(colls, multi=True)
    args = _fold_args(_alg(capacity=CAPACITY), colls)

    def variant(capacity):
        return lambda: jax.make_jaxpr(fold)(
            *_fold_args(_alg(capacity=capacity), colls)
        )

    rules = [
        ClosureConstRule(),
        DonationRule(donate_argnums=(0,)),
        CapacityIndependenceRule({
            f"capacity-{c}": variant(c) for c in (CAPACITY, 2 * CAPACITY)
        }),
    ]
    return check(fold, *args, rules=rules, name="driver.fold")


@entry_point("serve.run_chunk")
def _serve_run_chunk() -> Report:
    """The serve GroupEngine's group chunk (lane axis over jobs)."""
    from repro.data import logistic_data
    from repro.serve.engine import GroupEngine
    from repro.serve.job import Job, TerminationPolicy

    if "serve_engine" not in _CACHE:
        job = Job(
            job_id="analysis-probe", family="logistic",
            data=logistic_data(jax.random.key(1), n=256, d=D,
                               separation=1.5),
            capacity=32, cand_capacity=32, z_backend="fused",
            policy=TerminationPolicy(max_samples=64),
        )
        engine = GroupEngine(job)
        engine.admit(job)
        _CACHE["serve_engine"] = engine
    engine = _CACHE["serve_engine"]
    chunk = engine._build_chunk(cs=4)
    lanes = engine._lanes
    rules = [CostModelRule(n=256), ClosureConstRule(), RngLineageRule()]
    return check(
        chunk, lanes["states"], lanes["keys"], lanes["data"], lanes["stats"],
        rules=rules, name="serve.run_chunk",
    )


# ---------------------------------------------------------------------------
# sharded entry points: every shard_map program, traced under an
# AbstractMesh (axis names + sizes, NO physical devices — the sweep
# verifies 8-way-sharded programs on a 1-device CI host). Each runs the
# four collective analyses (budget census, replication-consistency,
# comm-bytes, shard-shape) with its declared per-step budget; the dist
# step additionally pins the derived per-device wire bytes, which the
# test suite cross-validates against the compiled program's HLO.
# ---------------------------------------------------------------------------

_DATA_SHARDS = 8


def _dist_mesh():
    return jax.sharding.AbstractMesh((("data", _DATA_SHARDS),))


def _fleet_mesh():
    return jax.sharding.AbstractMesh((("chains", _DATA_SHARDS),))


def _fleet_keys_states(fleet, k):
    keys = jax.eval_shape(lambda: jax.random.split(jax.random.key(0), k))
    states = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct((k,) + l.shape, l.dtype),
        _state_struct(fleet),
    )
    return keys, states


def _fleet():
    if "fleet" not in _CACHE:
        from repro.distributed.flymc_dist import chain_fleet

        _CACHE["fleet"] = chain_fleet(_alg(), _fleet_mesh())
    return _CACHE["fleet"]


def _dist_step_fixture():
    """(step_fn, data/stats/state structs) for the data-sharded chain."""
    if "dist_step" not in _CACHE:
        from repro.distributed.flymc_dist import make_dist_flymc
        from repro.models.bayes_glm import GLMModel

        model = GLMModel.logistic(_data(), prior_scale=2.0, xi=1.5)
        _, init_fn, step_fn, _ = make_dist_flymc(
            model.bound, model.log_prior, _dist_mesh(), N,
            kernel="rwmh", capacity=CAPACITY, cand_capacity=CAPACITY,
            q_db=0.01,
        )
        data_s = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), _data()
        )
        stats_s = jax.eval_shape(model.bound.suffstats, data_s)
        theta_s = jax.ShapeDtypeStruct((D,), jnp.float32)
        state_s, _ = jax.eval_shape(
            init_fn, data_s, stats_s, theta_s, _key_struct()
        )
        _CACHE["dist_step"] = (step_fn, data_s, stats_s, state_s)
    return _CACHE["dist_step"]


# The dist step's collective contract (see flymc_dist module docstring):
# 4 scalar psums (θ-proposal, post-z refresh, n_bright, lik_queries) +
# 1 scalar pmax (overflow) + 1 axis_index (z-key fold, zero wire) — and
# NOTHING in the z-phase. Wire: 5 scalar ring all-reduces × 2·4 B = 40 B
# per device per step, cross-validated against compiled HLO by test.
DIST_STEP_BUDGET = {"psum@data": 4, "pmax@data": 1, "axis_index@data": 1}
DIST_STEP_WIRE_BYTES = 40


@entry_point("dist.step")
def _dist_step() -> Report:
    """The data-sharded FlyMC step: one scalar psum per θ-proposal, a
    collective-free z-phase, and every replicated output proven so."""
    step_fn, data_s, stats_s, state_s = _dist_step_fixture()
    rules = _step_rules() + collective_rules(
        DIST_STEP_BUDGET,
        expected_wire_bytes=DIST_STEP_WIRE_BYTES,
        # flat operand 0 is data.x: each of the 8 shards owns N/8 rows
        # (which the per-shard capacity is sized against)
        pin_locals={0: {0: N // _DATA_SHARDS}},
    )
    return check(
        step_fn, data_s, stats_s, state_s, rules=rules, name="dist.step",
    )


@entry_point("dist.step.zphase_psum")
def _dist_step_zphase_psum() -> Report:
    """Known-bad twin: a naive data-parallel z-phase that psums every
    candidate decision — the budget census must see the scan-body psum
    trip-multiplied (×n_local per step), or the detector is blind."""
    mesh = _dist_mesh()
    from jax.sharding import PartitionSpec as P

    def naive(x):
        def body(xs):
            theta_term = jax.lax.psum(jnp.sum(xs), "data")

            def zstep(carry, xi):
                # one collective PER DATUM: the O(N) communication the
                # paper's per-datum brightness exists to avoid
                return carry + jax.lax.psum(xi, "data"), xi

            z_term, _ = jax.lax.scan(zstep, 0.0, xs)
            return theta_term + z_term

        return jax.shard_map(
            body, mesh=mesh, in_specs=(P("data"),), out_specs=P(),
            check_vma=False,
        )(x)

    return check(
        naive, jax.ShapeDtypeStruct((N,), jnp.float32),
        rules=collective_rules({"psum@data": 1}),
        name="dist.step.zphase_psum",
        expect_fail=("collective-budget",),
    )


@entry_point("dist.step.wire_drift")
def _dist_step_wire_drift() -> Report:
    """Known-bad twin: the REAL dist step against a drifted wire-bytes pin
    — proves the comm-bytes model actually constrains the program."""
    step_fn, data_s, stats_s, state_s = _dist_step_fixture()
    return check(
        step_fn, data_s, stats_s, state_s,
        rules=[CommBytesRule(expected_total=DIST_STEP_WIRE_BYTES + 8)],
        name="dist.step.wire_drift",
        expect_fail=("comm-bytes",),
    )


@entry_point("dist.fleet.rep_leak")
def _dist_fleet_rep_leak() -> Report:
    """Known-bad twin: a shard-varying value escaping as replicated — the
    check_vma=False foot-gun (shard 0's value silently wins). This is the
    bug class the replication rule caught in the real state pspecs (the
    per-shard bright count was declared PS() before this analysis landed)."""
    mesh = _dist_mesh()
    from jax.sharding import PartitionSpec as P

    def leak(x):
        # per-shard mean returned with out_specs=P(): NOT replicated
        return jax.shard_map(
            lambda xs: jnp.mean(xs), mesh=mesh, in_specs=(P("data"),),
            out_specs=P(), check_vma=False,
        )(x)

    return check(
        leak, jax.ShapeDtypeStruct((N,), jnp.float32),
        rules=[ReplicationRule()],
        name="dist.fleet.rep_leak",
        expect_fail=("replication-consistency",),
    )


@entry_point("dist.chain_fleet")
def _dist_chain_fleet() -> Report:
    """The chain fleet's sharded step in its operand-data form: even across
    a mesh, the dataset must be a (replicated) traced operand, not a
    closure constant baked into every device's executable — and chains are
    independent, so the budget is ZERO cross-chain collectives."""
    fleet = _fleet()
    keys, states = _fleet_keys_states(fleet, _DATA_SHARDS)
    rules = _step_rules() + collective_rules({}, expected_wire_bytes=0)
    return check(
        fleet.step_chains_data, keys, states, fleet.data, fleet.stats,
        rules=rules, name="dist.chain_fleet",
    )


@entry_point("dist.chain_fleet.closure")
def _dist_chain_fleet_closure() -> Report:
    """The fleet's closure-data form (step_chains): the other operand form
    the driver can dispatch. Same zero-collective budget; the closure-
    constant rule is deliberately absent here — baking data is this form's
    known trade-off, and dist.chain_fleet pins the operand form instead."""
    fleet = _fleet()
    keys, states = _fleet_keys_states(fleet, _DATA_SHARDS)
    return check(
        fleet.step_chains, keys, states,
        rules=collective_rules({}, expected_wire_bytes=0),
        name="dist.chain_fleet.closure",
    )


@entry_point("dist.collector_fold")
def _dist_collector_fold() -> Report:
    """The committed-chunk collector fold shard_mapped with every spec
    replicated. The dist driver runs collector updates on the replicated
    (θ, psum'd StepStats) outputs, so the fold must be mesh-safe: zero
    collectives AND no device-varying computation (no axis_index) — its
    carries stay replicated at any mesh size, which is what makes streamed
    diagnostics free at pod scale."""
    from jax.sharding import PartitionSpec as P

    from repro.api import collectors as collectors_lib
    from repro.api import driver

    colls = {
        "trace": collectors_lib.FullTrace(),
        "moments": collectors_lib.OnlineMoments(),
    }
    fold = driver.make_collector_fold(colls, multi=True)
    args = _fold_args(_alg(capacity=CAPACITY), colls)
    sharded = jax.shard_map(
        fold, mesh=_dist_mesh(), in_specs=(P(), P(), P()), out_specs=P(),
        check_vma=False,
    )
    return check(
        sharded, *args,
        rules=collective_rules({}, expected_wire_bytes=0),
        name="dist.collector_fold",
    )


@entry_point("serve.fleet_probe")
def _serve_fleet_probe() -> Report:
    """A fake-mesh serve placement probe: the GroupEngine's group chunk
    shard_mapped over a ('lanes', 2) AbstractMesh. Lanes are independent
    jobs, so the only collective a lane-parallel serve placement needs is
    ONE scalar pmax per chunk — the shared overflow flag that keeps the
    grow-and-rerun protocol in lockstep across lane shards. Budget pinned
    exactly there (16 B wire per chunk); replication proves that flag is
    the only replicated output."""
    from jax.sharding import PartitionSpec as P

    from repro.data import logistic_data
    from repro.serve.engine import GroupEngine
    from repro.serve.job import Job, TerminationPolicy

    if "serve_probe" not in _CACHE:
        def _job(i):
            return Job(
                job_id=f"fleet-probe-{i}", family="logistic",
                data=logistic_data(jax.random.key(2 + i), n=256, d=D,
                                   separation=1.5),
                capacity=32, cand_capacity=32, z_backend="fused",
                policy=TerminationPolicy(max_samples=64),
            )

        engine = GroupEngine(_job(0))
        engine.admit(_job(0))
        engine.admit(_job(1))
        _CACHE["serve_probe"] = engine
    engine = _CACHE["serve_probe"]
    chunk = engine._build_chunk(cs=4)
    lanes = engine._lanes
    row = P(("lanes",))

    def probe(states, keys, data, stats):
        final, pos, infos, overflow, healthy = chunk(states, keys, data,
                                                     stats)
        overflow = jax.lax.pmax(
            jnp.asarray(overflow).astype(jnp.int32), "lanes"
        ).astype(bool)
        # The health sentinel is per-lane by construction — it stays
        # row-sharded, proving quarantine needs ZERO collectives.
        return final, pos, infos, overflow, healthy

    sharded = jax.shard_map(
        probe, mesh=jax.sharding.AbstractMesh((("lanes", 2),)),
        in_specs=(row, row, row, row),
        out_specs=(row, row, row, P(), row),
        check_vma=False,
    )
    return check(
        sharded, lanes["states"], lanes["keys"], lanes["data"],
        lanes["stats"],
        rules=collective_rules({"pmax@lanes": 1}, expected_wire_bytes=8),
        name="serve.fleet_probe",
    )

# ---------------------------------------------------------------------------
# kernel entry points: the four kernel-level analyses (bounds, race,
# padding-taint, bytes model) over every pallas_call in src/repro/kernels/.
# Each entry declares its sequential accumulators BY OUTPUT INDEX (inner
# kernel functions are all literally named `kernel`, so names can't key
# them) — see the sequential-grid contract in repro.kernels.common. The
# FlyMC kernels additionally pin the derived HBM byte totals the
# benchmarks record, so a BlockSpec change that silently alters traffic
# fails the sweep until the model is consciously re-pinned.
# ---------------------------------------------------------------------------

_KD = 4        # chains in the chain-batched variants
_DP = 128      # bright's lane-padded feature width


def _s(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _bright_fn(family, **kw):
    from repro.kernels.bright_glm.ops import bright_glm

    def fn(x, t, xi, idx, nb, theta):
        return bright_glm(x, t, xi, idx, nb, theta, family=family,
                          interpret=True, **kw)

    return fn


def _bright_args(family):
    x = _s((N, D))
    idx = _s((CAPACITY,), jnp.int32)
    nb = _s((), jnp.int32)
    if family == "softmax":
        k = 3
        return (x, _s((N,), jnp.int32), _s((N, k)), idx, nb, _s((k, D)))
    return (x, _s((N,)), _s((N,)), idx, nb, _s((D,)))


# bright's single-chain traffic: the (deleted) hand model's exact terms —
# row DMA C·D·4, lane-padded theta block, t/xi streams + delta out (3·C·4),
# and the 4-byte running total.
_BRIGHT_BYTES = CAPACITY * D * 4 + _DP * 4 + 3 * CAPACITY * 4 + 4


@entry_point("kernel.bright_glm.logistic")
def _kernel_bright_logistic() -> Report:
    return check(
        _bright_fn("logistic"), *_bright_args("logistic"),
        rules=kernel_rules(accumulators={1: (1,)},
                           expected_bytes={"kernel": _BRIGHT_BYTES}),
        name="kernel.bright_glm.logistic",
    )


@entry_point("kernel.bright_glm.student_t")
def _kernel_bright_student_t() -> Report:
    return check(
        _bright_fn("student_t"), *_bright_args("student_t"),
        rules=kernel_rules(accumulators={1: (1,)},
                           expected_bytes={"kernel": _BRIGHT_BYTES}),
        name="kernel.bright_glm.student_t",
    )


@entry_point("kernel.bright_glm.softmax")
def _kernel_bright_softmax() -> Report:
    return check(
        _bright_fn("softmax"), *_bright_args("softmax"),
        rules=kernel_rules(accumulators={1: (1,)}),
        name="kernel.bright_glm.softmax",
    )


@entry_point("kernel.bright_glm.chains")
def _kernel_bright_chains() -> Report:
    """The chain-batched megakernel (custom_vmap → chain-grid launch):
    grid leads with the chain axis; per-chain totals still accumulate
    along the row axis only, and traffic is exactly K× the single-chain
    model."""
    fn = jax.vmap(_bright_fn("logistic"),
                  in_axes=(None, None, None, 0, 0, 0))
    x, t, xi, idx, nb, theta = _bright_args("logistic")
    args = (x, t, xi, _s((_KD, CAPACITY), jnp.int32), _s((_KD,), jnp.int32),
            _s((_KD, D)))
    return check(
        fn, *args,
        rules=kernel_rules(accumulators={1: (1,)},
                           expected_bytes={"kernel": _KD * _BRIGHT_BYTES}),
        name="kernel.bright_glm.chains",
    )


# z-update shapes: large enough that the row-block grid axis really
# revisits the candidate accumulators (4096 ids = 4 blocks of 8×128).
_ZN = 4096


def _z_fn():
    from repro.kernels.z_update.ops import z_candidates

    def fn(arr, num, kw):
        return z_candidates(arr, num, kw, q_db=0.01,
                            cand_capacity=CAPACITY, interpret=True)

    return fn


# arr streams once (4·N after exact tiling), the compacted candidate
# buffer writes back C_pad·4, plus the 4-byte count the hand model omitted.
_Z_BYTES = _ZN * 4 + CAPACITY * 4 + 4


@entry_point("kernel.z_update")
def _kernel_z_update() -> Report:
    return check(
        _z_fn(), _s((_ZN,), jnp.int32), _s((), jnp.int32),
        _s((2,), jnp.int32),
        rules=kernel_rules(accumulators={0: (1,), 1: (1,)},
                           expected_bytes={"kernel": _Z_BYTES}),
        name="kernel.z_update",
    )


@entry_point("kernel.z_update.chains")
def _kernel_z_chains() -> Report:
    return check(
        jax.vmap(_z_fn()), _s((_KD, _ZN), jnp.int32), _s((_KD,), jnp.int32),
        _s((_KD, 2), jnp.int32),
        rules=kernel_rules(accumulators={0: (1,), 1: (1,)},
                           expected_bytes={"kernel": _KD * _Z_BYTES}),
        name="kernel.z_update.chains",
    )


@entry_point("kernel.decode_attention")
def _kernel_decode_attention() -> Report:
    """w=192 forces ring padding (pad_w=64 with pos = -1 sentinel): the
    taint analysis must see the in-kernel validity mask scrub it."""
    from repro.kernels.decode_attention.ops import decode_attention

    b, h, hk, d, w = 2, 4, 2, 128, 192
    fn = lambda q, k, v, pos, t: decode_attention(
        q, k, v, pos, t, interpret=True)
    return check(
        fn, _s((b, h, d)), _s((b, w, hk, d)), _s((b, w, hk, d)),
        _s((w,), jnp.int32), _s((), jnp.int32),
        rules=kernel_rules(accumulators={0: (2,), 1: (2,), 2: (2,)}),
        name="kernel.decode_attention",
    )


@entry_point("kernel.fused_ce")
def _kernel_fused_ce() -> Report:
    """T=10 with block_t=8 forces row padding (tp=16): the zero-padded
    rows must stay out of every vocab-axis reduction."""
    from repro.kernels.fused_ce.ops import fused_ce

    fn = lambda x, w, labels: fused_ce(x, w, labels, interpret=True)
    return check(
        fn, _s((10, 128)), _s((128, 1024)), _s((10,), jnp.int32),
        rules=kernel_rules(accumulators={0: (1,), 1: (1,)}),
        name="kernel.fused_ce",
    )


@entry_point("kernel.rglru_scan")
def _kernel_rglru_scan() -> Report:
    """100 channels pad to the 128-lane block; the final-state output
    revisits the sequence-chunk axis (axis 2) as its accumulator."""
    from repro.kernels.rglru_scan.ops import rglru_scan

    fn = lambda a, bx: rglru_scan(a, bx, interpret=True)
    return check(
        fn, _s((1, 256, 100)), _s((1, 256, 100)),
        rules=kernel_rules(accumulators={1: (2,)}),
        name="kernel.rglru_scan",
    )


@entry_point("kernel.rwkv6_scan")
def _kernel_rwkv6_scan() -> Report:
    from repro.kernels.rwkv6_scan.ops import rwkv6_scan

    fn = lambda r, k, v, lw, u: rwkv6_scan(r, k, v, lw, u, chunk=64,
                                           interpret=True)
    s4 = _s((1, 2, 128, 128))
    return check(
        fn, s4, s4, s4, s4, _s((2, 128)),
        rules=kernel_rules(accumulators={1: (2,)}),
        name="kernel.rwkv6_scan",
    )
