"""repro.analysis — a jaxpr-level exactness & cost linter for hot-path jits.

FlyMC's value proposition is *exactness at subset cost*; both halves are
invariants of traced programs, so both are checkable statically. This
package is the rule engine that checks them:

* :mod:`repro.analysis.walker` — recursive jaxpr traversal (scan/while/
  cond/pjit bodies and Pallas inner jaxprs), the shared substrate the
  tests' former ad-hoc ``_walk_eqns`` helpers migrated onto;
* :mod:`repro.analysis.rules` — the five rules (cost-model,
  closure-constant, rng-lineage, capacity-independence, donation) and the
  :func:`check` library API;
* :mod:`repro.analysis.report` — Finding / Report / Summary with
  first-class expected-fail semantics;
* :mod:`repro.analysis.registry` — the registered hot-path entry points,
  swept by ``python -m repro.analysis`` and gated by the
  ``static-analysis`` CI lane.

Library use::

    from repro import analysis
    report = analysis.check(
        alg.step_data, key, state, alg.data, alg.stats,
        rules=[analysis.CostModelRule(n=N)], name="my.step",
    )
    assert report.ok, "\\n".join(map(str, report.findings))
"""

from repro.analysis import walker  # noqa: F401
from repro.analysis.report import Finding, Report, Summary  # noqa: F401
from repro.analysis.rules import (  # noqa: F401
    CapacityIndependenceRule,
    ClosureConstRule,
    Context,
    CostModelRule,
    DonationRule,
    RngLineageRule,
    Rule,
    check,
)


def run_registry(names=None):
    """Sweep the registered entry points (lazy import: registry construction
    touches api/serve/distributed, which library users may not need)."""
    from repro.analysis import registry

    return registry.run_registry(names)
