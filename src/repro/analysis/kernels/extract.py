"""Extract ``pallas_call`` sites, with outer-jaxpr provenance, for analysis.

:func:`find_kernel_calls` walks a traced (Closed)jaxpr — descending into
``pjit``/``custom_vjp``/``scan``/``cond`` the same way
:mod:`repro.analysis.walker` does — while running a light forward dataflow
over the *outer* program. Two facts are tracked per outer value:

* an interval (see :mod:`.intervals`) — this is how
  ``repro.kernels.common.clamp_index``'s ``clamp`` eqn turns an arbitrary
  int32 index buffer into ``[0, N-1]`` *before* it becomes a
  scalar-prefetch operand, which is what makes the kernel-side DMA bounds
  provable;
* a padding taint (see :mod:`.taint`) — ``jnp.pad`` / ``pad_to`` with a
  zero or sentinel fill marks the padded axes, and the taint follows the
  value through reshapes/concats into the kernel operand.

At each ``pallas_call`` eqn the grid, BlockSpec index maps, block shapes,
array shapes and the kernel's own jaxpr are packaged into a
:class:`KernelCall` whose operands line up 1:1 with the kernel jaxpr's
invars (scalar-prefetch refs, then inputs, then outputs, then scratch).
The four kernel analyses (bounds, race, taint, bytes) all consume this
one structure.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.extend.core as jex_core
import numpy as np

from repro.analysis.kernels.intervals import (
    Interval,
    dtype_interval,
    literal_interval,
)
from repro.analysis.kernels.taint import (
    DIRTY,
    SENTINEL,
    ZERO,
    TFact,
    _join_kind,
    join as taint_join,
    remap_axes,
    reshape_remap,
)

_DIRECT_CALLS = {
    "pjit", "closed_call", "core_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_vmap_call",
}


@dataclasses.dataclass
class VarFact:
    """Outer-scope knowledge about one traced value."""

    interval: Interval | None = None
    taint: TFact | None = None

    @staticmethod
    def unknown(atom=None) -> "VarFact":
        dtype = getattr(getattr(atom, "aval", None), "dtype", None)
        iv = dtype_interval(dtype) if dtype is not None else None
        return VarFact(interval=iv, taint=TFact.clean())


@dataclasses.dataclass
class Operand:
    """One kernel-jaxpr invar: its ref, block geometry, and provenance."""

    index: int           # position among the kernel jaxpr's invars
    kind: str            # scalar_prefetch | input | output | scratch
    io_index: int        # position within its kind
    origin: str          # BlockMapping.origin or synthesized label
    ref_shape: tuple     # the kernel-side ref aval shape
    block_shape: tuple | None
    array_shape: tuple | None
    dtype: Any
    itemsize: int
    index_map: Any       # ClosedJaxpr or None
    is_any: bool         # memory_space=ANY (manual DMA) operand
    interval: Interval | None
    taint: TFact | None


@dataclasses.dataclass
class KernelCall:
    """One pallas_call: kernel jaxpr + grid + aligned operands."""

    name: str
    jaxpr: Any           # the raw kernel Jaxpr
    grid: tuple
    operands: list       # aligned with jaxpr.invars
    num_scalar_prefetch: int
    num_inputs: int
    num_outputs: int
    dimension_semantics: tuple | None
    path: tuple

    @property
    def prefetch(self):
        return [op for op in self.operands if op.kind == "scalar_prefetch"]

    @property
    def inputs(self):
        return [op for op in self.operands if op.kind == "input"]

    @property
    def outputs(self):
        return [op for op in self.operands if op.kind == "output"]

    @property
    def scratch(self):
        return [op for op in self.operands if op.kind == "scratch"]


def _aval_of(atom):
    return getattr(atom, "aval", None)


def _shape(atom) -> tuple:
    return tuple(getattr(_aval_of(atom), "shape", ()) or ())


def find_kernel_calls(closed) -> list:
    """All pallas_call sites reachable from a ClosedJaxpr, with facts."""
    jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    consts = getattr(closed, "consts", [])
    calls: list[KernelCall] = []
    const_facts = {}
    for cv, cval in zip(jaxpr.constvars, consts):
        fact = VarFact.unknown(cv)
        try:
            arr = np.asarray(cval)
            if arr.size and (np.issubdtype(arr.dtype, np.number)
                             or arr.dtype == np.bool_):
                fact.interval = Interval(float(arr.min()), float(arr.max()))
        except Exception:
            pass
        const_facts[cv] = fact
    in_facts = [VarFact.unknown(v) for v in jaxpr.invars]
    _eval_jaxpr(jaxpr, in_facts, const_facts, (), calls)
    return calls


def _fact_of(atom, env) -> VarFact:
    if isinstance(atom, jex_core.Literal):
        return VarFact(interval=literal_interval(atom.val),
                       taint=TFact.clean())
    f = env.get(atom)
    return f if f is not None else VarFact.unknown(atom)


def _eval_jaxpr(jaxpr, in_facts, const_facts, path, calls):
    env: dict[Any, VarFact] = dict(const_facts)
    for v, f in zip(jaxpr.invars, in_facts):
        env[v] = f if f is not None else VarFact.unknown(v)
    for eqn in jaxpr.eqns:
        _eval_eqn(eqn, env, path, calls)
    return [_fact_of(ov, env) for ov in jaxpr.outvars]


def _eval_eqn(eqn, env, path, calls):
    name = eqn.primitive.name
    params = eqn.params
    fact = lambda i: _fact_of(eqn.invars[i], env)

    def out(f: VarFact, i=0):
        env[eqn.outvars[i]] = f

    if name == "pallas_call":
        calls.append(_extract_call(eqn, env, path))
        for ov in eqn.outvars:
            env[ov] = VarFact.unknown(ov)
        return

    if name in _DIRECT_CALLS:
        for value in params.values():
            sub = None
            if isinstance(value, jex_core.ClosedJaxpr):
                sub = value
            elif isinstance(value, jex_core.Jaxpr):
                sub = jex_core.ClosedJaxpr(value, ())
            if sub is not None and len(sub.jaxpr.invars) == len(eqn.invars):
                sub_consts = {
                    cv: VarFact.unknown(cv)
                    for cv in sub.jaxpr.constvars
                }
                outs = _eval_jaxpr(
                    sub.jaxpr,
                    [_fact_of(a, env) for a in eqn.invars],
                    sub_consts, path + (name,), calls,
                )
                for ov, f in zip(eqn.outvars, outs):
                    env[ov] = f
                return
        for ov in eqn.outvars:
            env[ov] = VarFact.unknown(ov)
        return

    if name in ("scan", "while", "cond"):
        # Still descend to find nested pallas_calls, but with unknown
        # facts (loop-carried provenance is PR-future work).
        for value in params.values():
            subs = []
            if isinstance(value, jex_core.ClosedJaxpr):
                subs = [value]
            elif isinstance(value, (tuple, list)):
                subs = [v for v in value
                        if isinstance(v, jex_core.ClosedJaxpr)]
            for sub in subs:
                _eval_jaxpr(
                    sub.jaxpr,
                    [VarFact.unknown(v) for v in sub.jaxpr.invars],
                    {cv: VarFact.unknown(cv)
                     for cv in sub.jaxpr.constvars},
                    path + (name,), calls,
                )
        for ov in eqn.outvars:
            env[ov] = VarFact.unknown(ov)
        return

    # -- outer transfer functions (the ones provenance depends on) -----------
    if name == "clamp":  # clamp(lo, x, hi) — the clamp_index signature
        lo, x, hi = fact(0), fact(1), fact(2)
        iv = None
        if x.interval is not None and lo.interval is not None and \
                hi.interval is not None:
            iv = x.interval.max_(lo.interval).min_(hi.interval)
        # values are clamped, but padded *slots* are still padding
        out(VarFact(interval=iv, taint=(x.taint or TFact.clean()).copy()))
    elif name == "iota":
        dim = int(params.get("dimension", 0))
        shape = params.get("shape") or _shape(eqn.outvars[0])
        f = TFact.clean()
        f.pos_axes = {dim}
        out(VarFact(interval=Interval(0, float(max(int(shape[dim]) - 1,
                                                   0))), taint=f))
    elif name == "pad":
        x = fact(0)
        padval = eqn.invars[1]
        pv = None
        if isinstance(padval, jex_core.Literal):
            arr = np.asarray(padval.val)
            if arr.size == 1:
                pv = float(arr.reshape(-1)[0])
        else:
            # jnp.pad routes the fill through a scalar Var; a point
            # interval recovers the constant (0.0 for pad_to).
            pf = fact(1)
            if pf.interval is not None and pf.interval.lo == pf.interval.hi:
                pv = float(pf.interval.lo)
        t = (x.taint or TFact.clean()).copy()
        for ax, (lo_p, hi_p, interior) in enumerate(
            params.get("padding_config", ())
        ):
            if lo_p > 0 or hi_p > 0 or interior > 0:
                kind = (ZERO, 0.0) if pv == 0.0 else (
                    (SENTINEL, pv) if pv is not None else (DIRTY, None)
                )
                t.taint[ax] = _join_kind(t.taint.get(ax), kind)
        iv = None
        if x.interval is not None:
            iv = x.interval if pv is None else x.interval.join(
                Interval(pv, pv))
        out(VarFact(interval=iv, taint=t))
    elif name in ("reshape", "squeeze", "expand_dims"):
        x = fact(0)
        t = remap_axes(x.taint or TFact.clean(),
                       reshape_remap(_shape(eqn.invars[0]),
                                     _shape(eqn.outvars[0])))
        out(VarFact(interval=x.interval, taint=t))
    elif name == "broadcast_in_dim":
        x = fact(0)
        dims = params.get("broadcast_dimensions", ())
        t = remap_axes(x.taint or TFact.clean(),
                       {i: (int(d),) for i, d in enumerate(dims)})
        out(VarFact(interval=x.interval, taint=t))
    elif name == "transpose":
        x = fact(0)
        perm = params.get("permutation", ())
        t = remap_axes(x.taint or TFact.clean(),
                       {int(old): (new,) for new, old in enumerate(perm)})
        out(VarFact(interval=x.interval, taint=t))
    elif name == "concatenate":
        iv = fact(0).interval
        t = (fact(0).taint or TFact.clean()).copy()
        for i in range(1, len(eqn.invars)):
            fi = fact(i)
            if iv is not None and fi.interval is not None:
                iv = iv.join(fi.interval)
            else:
                iv = None
            t = taint_join(t, fi.taint or TFact.clean())
        out(VarFact(interval=iv, taint=t))
    elif name == "convert_element_type":
        x = fact(0)
        tgt = dtype_interval(params.get("new_dtype", np.float32))
        iv = x.interval.meet(tgt) if x.interval is not None and \
            not x.interval.empty else tgt
        out(VarFact(interval=iv, taint=(x.taint or TFact.clean()).copy()))
    elif name in ("add", "sub", "mul", "max", "min"):
        a, b = fact(0), fact(1)
        iv = None
        if a.interval is not None and b.interval is not None:
            op = {"add": Interval.add, "sub": Interval.sub,
                  "mul": Interval.mul, "max": Interval.max_,
                  "min": Interval.min_}[name]
            iv = op(a.interval, b.interval)
        ta, tb = a.taint or TFact.clean(), b.taint or TFact.clean()
        t = TFact.clean()
        for ax in set(ta.taint) | set(tb.taint):
            ka, kb = ta.taint.get(ax), tb.taint.get(ax)
            if name == "mul" and ((ka and ka[0] == ZERO)
                                  or (kb and kb[0] == ZERO)):
                t.taint[ax] = (ZERO, 0.0)
            elif ka and kb and ka[0] == ZERO and kb[0] == ZERO and \
                    name in ("add", "sub", "max", "min"):
                t.taint[ax] = (ZERO, 0.0)
            else:
                t.taint[ax] = (DIRTY, None)
        out(VarFact(interval=iv, taint=t))
    elif name in ("gather", "take"):
        # data gathered through indices: tainted indices poison the
        # batch axes of the output
        idx_fact = fact(1) if len(eqn.invars) > 1 else VarFact.unknown()
        t = TFact.clean()
        if idx_fact.taint is not None and not idx_fact.taint.is_clean:
            t.taint["*"] = (DIRTY, None)
        data = fact(0)
        out(VarFact(interval=data.interval, taint=t))
    elif name in ("slice", "dynamic_slice", "rev", "stop_gradient",
                  "copy", "reduce_precision", "device_put"):
        x = fact(0)
        out(VarFact(interval=x.interval,
                    taint=(x.taint or TFact.clean()).copy()))
    else:
        for i, ov in enumerate(eqn.outvars):
            # join same-rank operand taints (conservative default)
            t = TFact.clean()
            rank = len(_shape(ov))
            for j in range(len(eqn.invars)):
                fj = _fact_of(eqn.invars[j], env)
                if fj.taint is not None and not fj.taint.is_clean:
                    if len(_shape(eqn.invars[j])) == rank:
                        t = taint_join(t, fj.taint)
                    else:
                        t.taint["*"] = _join_kind(t.taint.get("*"),
                                                  (DIRTY, None))
            env[ov] = VarFact(
                interval=dtype_interval(getattr(_aval_of(ov), "dtype",
                                                np.float32)),
                taint=t,
            )


def _extract_call(eqn, env, path) -> KernelCall:
    params = eqn.params
    gm = params["grid_mapping"]
    grid = tuple(int(g) for g in gm.grid)
    kernel_jaxpr = params["jaxpr"]
    if hasattr(kernel_jaxpr, "jaxpr"):  # ClosedJaxpr in some versions
        kernel_jaxpr = kernel_jaxpr.jaxpr
    nsp = int(getattr(gm, "num_index_operands", 0))
    n_in = int(getattr(gm, "num_inputs", 0))
    n_out = int(getattr(gm, "num_outputs", 0))
    n_scratch = int(getattr(gm, "num_scratch_operands", 0))
    block_mappings = list(getattr(gm, "block_mappings", ()))

    nsi = params.get("name_and_src_info")
    name = getattr(nsi, "name", None) or str(nsi or "pallas_call")

    dim_sem = None
    cp = params.get("compiler_params")
    if cp is not None:
        mosaic = cp.get("mosaic", cp) if isinstance(cp, dict) else cp
        ds = getattr(mosaic, "dimension_semantics", None)
        if ds is None and isinstance(mosaic, dict):
            ds = mosaic.get("dimension_semantics")
        if ds is not None:
            dim_sem = tuple(str(s) for s in ds)

    # eqn.invars = [index (scalar-prefetch) operands..., inputs...];
    # outputs/scratch have no outer operands.
    outer_args = list(eqn.invars)
    invars = list(kernel_jaxpr.invars)
    operands: list[Operand] = []

    def ref_shape_of(invar):
        return tuple(getattr(_aval_of(invar), "shape", ()) or ())

    k = 0
    for i in range(nsp):
        invar = invars[k]
        outer = outer_args[i] if i < len(outer_args) else None
        f = _fact_of(outer, env) if outer is not None else \
            VarFact.unknown(invar)
        aval = _aval_of(outer) if outer is not None else _aval_of(invar)
        dtype = getattr(aval, "dtype", np.int32)
        operands.append(Operand(
            index=k, kind="scalar_prefetch", io_index=i,
            origin=f"scalar_prefetch[{i}]",
            ref_shape=ref_shape_of(invar),
            block_shape=None,
            array_shape=tuple(getattr(aval, "shape", ()) or ()),
            dtype=dtype, itemsize=np.dtype(dtype).itemsize,
            index_map=None, is_any=False,
            interval=f.interval or dtype_interval(dtype),
            taint=f.taint or TFact.clean(),
        ))
        k += 1

    for i in range(n_in + n_out):
        invar = invars[k]
        bm = block_mappings[i] if i < len(block_mappings) else None
        kind = "input" if i < n_in else "output"
        io_index = i if i < n_in else i - n_in
        outer = None
        if kind == "input" and nsp + i < len(outer_args):
            outer = outer_args[nsp + i]
        f = _fact_of(outer, env) if outer is not None else VarFact(
            interval=None, taint=TFact.clean())
        asd = getattr(bm, "array_shape_dtype", None)
        dtype = getattr(asd, "dtype", None)
        if dtype is None:
            dtype = getattr(_aval_of(invar), "dtype", np.float32)
        block_shape = None
        if bm is not None:
            block_shape = tuple(
                1 if b is None or not isinstance(b, (int, np.integer))
                else int(b)
                for b in getattr(bm, "block_shape", ())
            )
        is_any = "any" in str(
            getattr(bm, "transformed_block_aval", "")
        ).lower()
        origin = getattr(bm, "origin", None) or f"{kind}[{io_index}]"
        # interval/taint describe the *block contents* the kernel sees.
        interval = f.interval
        taint = f.taint or TFact.clean()
        if is_any:
            # ANY refs keep the full array shape; facts carry over as-is.
            pass
        operands.append(Operand(
            index=k, kind=kind, io_index=io_index, origin=str(origin),
            ref_shape=ref_shape_of(invar), block_shape=block_shape,
            array_shape=tuple(getattr(asd, "shape", ()) or ()) or None,
            dtype=dtype, itemsize=np.dtype(dtype).itemsize,
            index_map=getattr(bm, "index_map_jaxpr", None),
            is_any=is_any,
            interval=interval if kind == "input" else None,
            taint=taint if kind == "input" else TFact.clean(),
        ))
        k += 1

    for i in range(n_scratch):
        invar = invars[k]
        dtype = getattr(_aval_of(invar), "dtype", np.float32)
        operands.append(Operand(
            index=k, kind="scratch", io_index=i, origin=f"scratch[{i}]",
            ref_shape=ref_shape_of(invar), block_shape=None,
            array_shape=None, dtype=dtype,
            itemsize=np.dtype(dtype).itemsize if dtype is not None else 4,
            index_map=None, is_any=False,
            interval=None, taint=TFact.clean(),
        ))
        k += 1

    return KernelCall(
        name=name, jaxpr=kernel_jaxpr, grid=grid, operands=operands,
        num_scalar_prefetch=nsp, num_inputs=n_in, num_outputs=n_out,
        dimension_semantics=dim_sem, path=path,
    )
