"""Grid-race classification of Pallas output-ref writes.

TPU grids are sequential by default, so the repo's kernels freely use the
revisited-block accumulator idiom: an output BlockSpec whose index map
ignores a grid axis maps *every* step along that axis to the same block,
and the kernel does ``ref[...] += part`` across the revisits (bright's
``total``, z-update's ``cand``/``count``, fused-ce's ``lse``/``tgt``, the
flash-decode ``o/m/l`` triple, the scan kernels' final states). That
idiom is only exact under sequential grid semantics — under
``dimension_semantics=('parallel', ...)`` (or a future GPU lowering) the
same BlockSpec is a write-write race.

This analysis makes the convention checkable (the contract itself is
documented in :mod:`repro.kernels.common`):

* each output's index map is classified by which grid axes its block
  index actually depends on (transitive use of the grid-index invars of
  ``index_map_jaxpr``);
* a *revisited* axis — ``grid[axis] > 1`` and not in the dependence set —
  makes the write non-injective in that axis;
* a revisited axis explicitly marked ``parallel`` is a race: finding,
  always;
* a revisited axis under sequential/default semantics must be *declared*
  (the ``accumulators`` pin, keyed by output index since kernel function
  names are not unique) — an undeclared accumulator-style write is a
  finding, so new kernels opt into the contract consciously rather than
  by accident;
* an index map that depends on a scalar-prefetch value is dynamic: its
  injectivity cannot be established statically, which is likewise a
  finding unless declared.
"""

from __future__ import annotations

import dataclasses

import jax.extend.core as jex_core

_DIRECT_CALLS = {
    "pjit", "closed_call", "core_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vmap_call",
}


@dataclasses.dataclass
class OutputClass:
    """How one output's block index relates to the grid."""

    io_index: int
    origin: str
    dep_axes: tuple       # grid axes the index map depends on
    revisited: tuple      # grid axes with extent > 1 not in dep_axes
    dynamic: bool         # depends on scalar-prefetch contents


@dataclasses.dataclass
class RaceFinding:
    io_index: int
    origin: str
    axis: int | None
    kind: str  # parallel-race | undeclared-accumulator | dynamic-index-map

    def message(self) -> str:
        if self.kind == "parallel-race":
            return (
                f"output[{self.io_index}] ({self.origin}) is revisited "
                f"along grid axis {self.axis} which is marked 'parallel' "
                "— accumulator writes would race"
            )
        if self.kind == "dynamic-index-map":
            return (
                f"output[{self.io_index}] ({self.origin}) has an index "
                "map depending on scalar-prefetch data — injectivity "
                "cannot be established statically"
            )
        return (
            f"output[{self.io_index}] ({self.origin}) is revisited along "
            f"grid axis {self.axis} (accumulator-style write) but is not "
            "declared a sequential accumulator — see the sequential-grid "
            "contract in repro.kernels.common"
        )


def _index_map_deps(index_map, n_grid: int) -> tuple[set, bool]:
    """(grid axes the outputs depend on, depends-on-prefetch?)."""
    if index_map is None:
        return set(), False
    jaxpr = index_map.jaxpr if hasattr(index_map, "jaxpr") else index_map
    invars = list(jaxpr.invars)
    grid_vars = {v: i for i, v in enumerate(invars[:n_grid])}
    prefetch_vars = set(invars[n_grid:])
    # Transitive dependence: var -> (grid axes, prefetch?)
    deps: dict = {v: ({i}, False) for v, i in grid_vars.items()}
    for v in prefetch_vars:
        deps[v] = (set(), True)

    def dep_of(atom):
        if isinstance(atom, jex_core.Literal):
            return set(), False
        return deps.get(atom, (set(), False))

    def walk(j):
        for eqn in j.eqns:
            axes: set = set()
            pref = False
            for a in eqn.invars:
                d, p = dep_of(a)
                axes |= d
                pref = pref or p
            for sub in _sub_jaxprs(eqn):
                walk(sub)
            for ov in eqn.outvars:
                deps[ov] = (axes, pref)

    walk(jaxpr)
    out_axes: set = set()
    out_pref = False
    for ov in jaxpr.outvars:
        d, p = dep_of(ov)
        out_axes |= d
        out_pref = out_pref or p
    return out_axes, out_pref


def _sub_jaxprs(eqn):
    for value in eqn.params.values():
        if isinstance(value, jex_core.ClosedJaxpr):
            yield value.jaxpr
        elif isinstance(value, jex_core.Jaxpr):
            yield value
        elif isinstance(value, (tuple, list)):
            for v in value:
                if isinstance(v, jex_core.ClosedJaxpr):
                    yield v.jaxpr
                elif isinstance(v, jex_core.Jaxpr):
                    yield v


def classify_outputs(call) -> list[OutputClass]:
    """Dependence/revisit classification of every output of a call."""
    out = []
    for op in call.outputs:
        dep, dynamic = _index_map_deps(op.index_map, len(call.grid))
        revisited = tuple(
            ax for ax, extent in enumerate(call.grid)
            if extent > 1 and ax not in dep
        )
        out.append(OutputClass(
            io_index=op.io_index, origin=op.origin,
            dep_axes=tuple(sorted(dep)), revisited=revisited,
            dynamic=dynamic,
        ))
    return out


def check_races(call, accumulators: dict | None = None
                ) -> tuple[list[RaceFinding], list[OutputClass]]:
    """Race findings for one call, given declared sequential accumulators.

    ``accumulators`` maps output io_index -> tuple of grid axes that
    output is *allowed* to revisit under sequential semantics.
    """
    accumulators = accumulators or {}
    sem = call.dimension_semantics
    findings: list[RaceFinding] = []
    classes = classify_outputs(call)
    for oc in classes:
        declared = set(accumulators.get(oc.io_index, ()))
        if oc.dynamic and oc.io_index not in accumulators:
            findings.append(RaceFinding(
                io_index=oc.io_index, origin=oc.origin, axis=None,
                kind="dynamic-index-map",
            ))
        for ax in oc.revisited:
            marked_parallel = (
                sem is not None and ax < len(sem)
                and "parallel" in sem[ax]
            )
            if marked_parallel:
                findings.append(RaceFinding(
                    io_index=oc.io_index, origin=oc.origin, axis=ax,
                    kind="parallel-race",
                ))
            elif ax not in declared:
                findings.append(RaceFinding(
                    io_index=oc.io_index, origin=oc.origin, axis=ax,
                    kind="undeclared-accumulator",
                ))
    return findings, classes
