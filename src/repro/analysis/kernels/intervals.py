"""Interval-domain abstract interpretation over Pallas kernel jaxprs.

The bounds analysis: prove that every dynamic ref index — ``get``/``swap``
NDIndexers, ``pl.dynamic_slice`` starts, and the HBM side of every
``dma_start`` — stays inside the ref it indexes, for every grid step.

The domain is the classic integer interval lattice ``[lo, hi]`` with
±inf. Sources of precision, in the order they matter for this repo's
kernels:

* ``program_id(axis)`` is ``[0, grid[axis] - 1]`` — the grid is static.
* scalar-prefetch operands carry the *outer* jaxpr's provenance: an index
  buffer that went through :func:`repro.kernels.common.clamp_index`
  (a ``clamp`` eqn against literal bounds) enters the kernel as
  ``[0, N - 1]``, which is exactly what makes the bright-GLM row DMA
  provable (see :mod:`repro.analysis.kernels.extract`).
* ``iota`` / ``broadcasted_iota`` are ``[0, dim - 1]``; shifts, adds,
  multiplies, min/max/clamp, and reductions have exact transfer functions.
* ``pl.when`` lowers to ``cond`` whose predicate we recognize when it is a
  conjunction of direct comparisons — the taken branch refines the
  compared operand (this proves the z-update's guarded candidate store:
  ``slot`` is only written under ``slot < cand_cap``).
* ``fori_loop`` lowers to ``while``; carries are solved by a small inner
  fixpoint with widening, refined through the loop condition (this bounds
  the extraction counter ``j ∈ [0, cnt_tile - 1]``).

Mutable refs (accumulators, scratch) are handled by a store-join fixpoint
across whole-kernel passes with widening: each ref's abstract *content* is
the join of everything ever stored to it, reads see the join of prior-pass
content and same-pass stores so far. The z-update running count therefore
stabilizes at ``[0, +inf]`` — enough to prove the store's lower bound,
while its upper bound comes from the ``pl.when`` guard refinement.

Soundness posture: unknown primitives decay to the dtype's full range, so
missing transfer functions can only create false *positives* (an index we
fail to prove in-bounds), never false negatives. The one modeled
assumption is the sequential-grid scratch contract documented in
:mod:`repro.kernels.common` — first-step ``pl.when`` initialization is
assumed to precede reads, as it does under TPU's sequential grid.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.extend.core as jex_core
import numpy as np

NEG_INF = float("-inf")
POS_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class Interval:
    """Closed interval [lo, hi]; lo > hi encodes bottom (unreachable)."""

    lo: float
    hi: float

    @property
    def empty(self) -> bool:
        return self.lo > self.hi

    def join(self, o: "Interval") -> "Interval":
        if self.empty:
            return o
        if o.empty:
            return self
        return Interval(min(self.lo, o.lo), max(self.hi, o.hi))

    def meet(self, o: "Interval") -> "Interval":
        return Interval(max(self.lo, o.lo), min(self.hi, o.hi))

    def add(self, o: "Interval") -> "Interval":
        return Interval(self.lo + o.lo, self.hi + o.hi)

    def sub(self, o: "Interval") -> "Interval":
        return Interval(self.lo - o.hi, self.hi - o.lo)

    def neg(self) -> "Interval":
        return Interval(-self.hi, -self.lo)

    def mul(self, o: "Interval") -> "Interval":
        cands = [
            _mul(self.lo, o.lo), _mul(self.lo, o.hi),
            _mul(self.hi, o.lo), _mul(self.hi, o.hi),
        ]
        return Interval(min(cands), max(cands))

    def max_(self, o: "Interval") -> "Interval":
        return Interval(max(self.lo, o.lo), max(self.hi, o.hi))

    def min_(self, o: "Interval") -> "Interval":
        return Interval(min(self.lo, o.lo), min(self.hi, o.hi))

    def widen(self, newer: "Interval") -> "Interval":
        """Classic widening: any still-moving bound jumps to ±inf."""
        lo = self.lo if newer.lo >= self.lo else NEG_INF
        hi = self.hi if newer.hi <= self.hi else POS_INF
        return Interval(lo, hi)

    def __str__(self) -> str:
        def f(v):
            return str(int(v)) if math.isfinite(v) else (
                "-inf" if v < 0 else "+inf"
            )

        return f"[{f(self.lo)}, {f(self.hi)}]"


def _mul(a: float, b: float) -> float:
    if a == 0 or b == 0:
        return 0.0
    return a * b


TOP = Interval(NEG_INF, POS_INF)
BOOL = Interval(0, 1)


def dtype_interval(dtype) -> Interval:
    """The full range of a dtype — the decay value for unknown eqns."""
    dtype = np.dtype(dtype)
    if dtype == np.bool_:
        return BOOL
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return Interval(float(info.min), float(info.max))
    return TOP


def _aval_of(atom) -> Any:
    return getattr(atom, "aval", None)


def _is_ref(atom) -> bool:
    aval = _aval_of(atom)
    return aval is not None and "Ref" in type(aval).__name__


def literal_interval(value) -> Interval:
    arr = np.asarray(value)
    if arr.size == 0:
        return TOP
    if not np.issubdtype(arr.dtype, np.number) and arr.dtype != np.bool_:
        return TOP
    return Interval(float(arr.min()), float(arr.max()))


# Comparison refinements: in the TRUE branch of `op(lhs, rhs)`, what does
# lhs's interval become (given rhs's interval), and symmetrically for rhs.
_CMP_NEGATE = {"lt": "ge", "le": "gt", "gt": "le", "ge": "lt"}


def refine_cmp(op: str, iv: Interval, other: Interval, is_lhs: bool
               ) -> Interval:
    """Refine one side of a true comparison. Integer semantics (lt = le-1)
    are safe for floats too — every refined var in these kernels is int."""
    if not is_lhs:
        op = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le", "eq": "eq",
              "ne": "ne"}.get(op, op)
    if op == "lt":
        return iv.meet(Interval(NEG_INF, other.hi - 1))
    if op == "le":
        return iv.meet(Interval(NEG_INF, other.hi))
    if op == "gt":
        return iv.meet(Interval(other.lo + 1, POS_INF))
    if op == "ge":
        return iv.meet(Interval(other.lo, POS_INF))
    if op == "eq":
        return iv.meet(other)
    return iv


_CMP_OPS = {"lt", "le", "gt", "ge", "eq", "ne"}

# Float-unary primitives whose output is nonnegative.
_NONNEG_UNARY = {"exp", "abs", "square", "sqrt", "exp2", "logistic"}

# Primitives that pass their (single) operand's interval through.
_PASSTHROUGH = {
    "reshape", "broadcast_in_dim", "squeeze", "expand_dims", "transpose",
    "copy", "rev", "stop_gradient", "reduce_precision", "slice",
    "real", "device_put",
}


class _RefStore:
    """Abstract contents of the kernel's refs, shared across scopes.

    Refs are aliased through sub-jaxpr boundaries (cond branches close over
    refs as invars), so contents are keyed by a canonical var resolved
    through ``alias``. ``content[r] is None`` means ⊥ — nothing stored yet.
    """

    def __init__(self):
        self.content: dict[Any, Interval | None] = {}
        self.alias: dict[Any, Any] = {}

    def canon(self, var):
        try:
            while var in self.alias:
                var = self.alias[var]
        except TypeError:  # Literals are unhashable; they are never refs
            pass
        return var

    @staticmethod
    def _hashable(var) -> bool:
        return not isinstance(var, jex_core.Literal)

    def declare(self, var, init: Interval | None):
        self.content[self.canon(var)] = init

    def is_ref(self, var) -> bool:
        if not self._hashable(var):
            return False
        return self.canon(var) in self.content

    def read(self, var) -> Interval:
        cur = self.content.get(self.canon(var))
        if cur is None:
            aval = _aval_of(var)
            return dtype_interval(getattr(aval, "dtype", np.float32))
        return cur

    def store(self, var, value: Interval):
        var = self.canon(var)
        cur = self.content.get(var)
        self.content[var] = value if cur is None else cur.join(value)

    def snapshot(self) -> dict:
        return dict(self.content)


@dataclasses.dataclass
class BoundsFinding:
    """One unprovable (or provably-escaping) ref index."""

    ref: str          # operand origin / scratch label
    eqn: str          # primitive that performed the access
    dim: int
    index: Interval
    valid: Interval   # [0, dim - span]
    proven_bad: bool  # interval provably escapes vs merely unprovable

    def message(self) -> str:
        kind = "escapes" if self.proven_bad else "is not provably inside"
        return (
            f"{self.eqn} index into {self.ref} dim {self.dim} has interval "
            f"{self.index}, which {kind} the valid range {self.valid}"
        )


class BoundsInterpreter:
    """Run the interval analysis over one extracted KernelCall."""

    MAX_PASSES = 4
    MAX_LOOP_ITERS = 4

    def __init__(self, call):
        self.call = call
        self.findings: list[BoundsFinding] = []
        self._seen: set = set()
        self.collect = False

    # -- driver --------------------------------------------------------------

    def run(self) -> list[BoundsFinding]:
        jaxpr = self.call.jaxpr
        carry: dict | None = None
        for pass_i in range(self.MAX_PASSES):
            refs = _RefStore()
            env: dict[Any, Interval] = {}
            preds: dict[Any, list] = {}
            for invar, op in zip(jaxpr.invars, self.call.operands):
                if _is_ref(invar):
                    init = op.interval
                    if carry is not None:
                        prev = carry.get(invar)
                        if prev is not None:
                            init = prev if init is None else init.join(prev)
                    refs.declare(invar, init)
                else:
                    env[invar] = op.interval or dtype_interval(
                        getattr(_aval_of(invar), "dtype", np.float32)
                    )
            self.collect = pass_i == self.MAX_PASSES - 1
            self._eval_eqns(jaxpr.eqns, env, refs, preds)
            snap = {refs.canon(v): c for v, c in refs.snapshot().items()}
            if carry is not None:
                widened = {}
                stable = True
                for var, cur in snap.items():
                    prev = carry.get(var)
                    if prev is None or cur is None:
                        widened[var] = cur if prev is None else prev
                        stable = stable and prev == cur
                    elif pass_i >= 2:
                        widened[var] = prev.widen(cur)
                        stable = stable and widened[var] == prev
                    else:
                        widened[var] = prev.join(cur)
                        stable = stable and widened[var] == prev
                snap = widened
                if stable and not self.collect:
                    # Converged early: do one final collecting pass.
                    self.collect = True
                    refs2 = _RefStore()
                    env2: dict[Any, Interval] = {}
                    for invar, op in zip(jaxpr.invars, self.call.operands):
                        if _is_ref(invar):
                            refs2.declare(invar, snap.get(invar))
                        else:
                            env2[invar] = op.interval or dtype_interval(
                                getattr(_aval_of(invar), "dtype", np.float32)
                            )
                    self._eval_eqns(jaxpr.eqns, env2, refs2, {})
                    return self.findings
            carry = snap
        return self.findings

    # -- helpers -------------------------------------------------------------

    def _ival(self, atom, env) -> Interval:
        if isinstance(atom, jex_core.Literal):
            return literal_interval(atom.val)
        if atom in env:
            return env[atom]
        return dtype_interval(getattr(_aval_of(atom), "dtype", np.float32))

    def _ref_name(self, var, refs) -> str:
        var = refs.canon(var)
        jaxpr = self.call.jaxpr
        for invar, op in zip(jaxpr.invars, self.call.operands):
            if invar is var:
                return op.origin
        return "<local ref>"

    def _check_index(self, refs, ref_var, eqn_name, dim, span, iv: Interval):
        if not self.collect or iv.empty:
            return
        valid = Interval(0, dim - span)
        if iv.lo >= 0 and iv.hi <= dim - span:
            return
        key = (self._ref_name(ref_var, refs), eqn_name, dim,
               (iv.lo, iv.hi))
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(BoundsFinding(
            ref=key[0], eqn=eqn_name, dim=dim, index=iv, valid=valid,
            proven_bad=iv.hi < 0 or iv.lo > dim - span,
        ))

    def _check_indexer(self, refs, ref_var, eqn_name, shape, indexer, env):
        """Check one NDIndexer against ``shape``; return the result shape."""
        out_shape = []
        indices = getattr(indexer, "indices", None)
        if indices is None:
            return tuple(shape)
        for dim_i, idx in enumerate(indices):
            dim = shape[dim_i] if dim_i < len(shape) else 1
            if hasattr(idx, "size") and hasattr(idx, "start"):  # pl.Slice
                size = int(idx.size)
                stride = int(getattr(idx, "stride", 1) or 1)
                start = idx.start
                if isinstance(start, (int, np.integer)):
                    s_iv = Interval(float(start), float(start))
                else:
                    s_iv = self._ival(start, env)
                span = (size - 1) * stride + 1
                self._check_index(refs, ref_var, eqn_name, dim, span, s_iv)
                out_shape.append(size)
            elif isinstance(idx, (int, np.integer)):
                self._check_index(refs, ref_var, eqn_name, dim, 1,
                                  Interval(float(idx), float(idx)))
            else:  # dynamic scalar or advanced (array) index
                iv = self._ival(idx, env)
                self._check_index(refs, ref_var, eqn_name, dim, 1, iv)
                idx_shape = tuple(getattr(_aval_of(idx), "shape", ()) or ())
                out_shape.extend(idx_shape)
        out_shape.extend(shape[len(indices):])
        return tuple(out_shape)

    def _indexers_of(self, tree, flat):
        """Unflatten a state-primitive transforms tree; yield NDIndexers."""
        try:
            import jax.tree_util as jtu

            transforms = jtu.tree_unflatten(tree, list(flat))
        except Exception:
            return []
        out = []

        def walk(obj):
            if hasattr(obj, "indices") and hasattr(obj, "shape"):
                out.append(obj)
            elif isinstance(obj, (list, tuple)):
                for item in obj:
                    walk(item)

        walk(transforms)
        return out

    # -- the interpreter -----------------------------------------------------

    def _eval_eqns(self, eqns, env, refs, preds):
        for eqn in eqns:
            self._eval_eqn(eqn, env, refs, preds)

    def _default_out(self, eqn, env):
        for ov in eqn.outvars:
            env[ov] = dtype_interval(
                getattr(_aval_of(ov), "dtype", np.float32)
            )

    def _eval_eqn(self, eqn, env, refs, preds):
        name = eqn.primitive.name
        params = eqn.params
        iv = lambda i: self._ival(eqn.invars[i], env)

        def pred_of(atom):
            if isinstance(atom, jex_core.Literal):
                return None
            return preds.get(atom)

        def out(value: Interval, pred=None):
            env[eqn.outvars[0]] = value
            if pred is not None:
                preds[eqn.outvars[0]] = pred

        if name == "program_id":
            axis = int(params.get("axis", 0))
            grid = self.call.grid
            hi = grid[axis] - 1 if axis < len(grid) else 0
            out(Interval(0, float(max(hi, 0))))
        elif name == "num_programs":
            axis = int(params.get("axis", 0))
            grid = self.call.grid
            n = grid[axis] if axis < len(grid) else 1
            out(Interval(float(n), float(n)))
        elif name == "iota":
            dim = int(params.get("dimension", 0))
            shape = params.get("shape") or getattr(
                _aval_of(eqn.outvars[0]), "shape", (1,)
            )
            out(Interval(0, float(max(int(shape[dim]) - 1, 0))))
        elif name == "add":
            out(iv(0).add(iv(1)))
        elif name == "sub":
            out(iv(0).sub(iv(1)))
        elif name == "mul":
            out(iv(0).mul(iv(1)))
        elif name == "neg":
            out(iv(0).neg())
        elif name == "max":
            out(iv(0).max_(iv(1)))
        elif name == "min":
            out(iv(0).min_(iv(1)))
        elif name == "clamp":  # clamp(lo, x, hi)
            lo, x, hi = iv(0), iv(1), iv(2)
            out(x.max_(lo).min_(hi))
        elif name in ("div", "floor_divide"):
            out(self._div(iv(0), iv(1)))
        elif name == "rem":
            out(self._rem(iv(0), iv(1)))
        elif name == "convert_element_type":
            tgt = dtype_interval(params.get("new_dtype", np.float32))
            out(iv(0).meet(tgt) if not iv(0).empty else tgt,
                pred=pred_of(eqn.invars[0]))
        elif name in _PASSTHROUGH:
            out(iv(0), pred=pred_of(eqn.invars[0]))
        elif name == "concatenate":
            acc = self._ival(eqn.invars[0], env)
            for a in eqn.invars[1:]:
                acc = acc.join(self._ival(a, env))
            out(acc)
        elif name == "pad":
            out(iv(0).join(iv(1)))
        elif name == "select_n":
            acc = self._ival(eqn.invars[1], env)
            for a in eqn.invars[2:]:
                acc = acc.join(self._ival(a, env))
            out(acc)
        elif name in _CMP_OPS:
            out(BOOL, pred=[(name, eqn.invars[0], eqn.invars[1])])
        elif name == "and":
            p = (pred_of(eqn.invars[0]) or []) + (pred_of(eqn.invars[1]) or [])
            out(BOOL, pred=p or None)
        elif name in ("or", "not", "xor", "is_finite"):
            aval = _aval_of(eqn.outvars[0])
            out(BOOL if np.dtype(getattr(aval, "dtype", np.bool_))
                == np.bool_ else dtype_interval(aval.dtype))
        elif name == "shift_right_logical":
            rhs = iv(1)
            aval = _aval_of(eqn.invars[0])
            nbits = np.dtype(getattr(aval, "dtype", np.int32)).itemsize * 8
            if rhs.lo == rhs.hi and math.isfinite(rhs.lo):
                out(Interval(0, float(2 ** (nbits - int(rhs.lo)) - 1)))
            else:
                out(Interval(0, float(2 ** nbits - 1)))
        elif name in ("shift_left", "shift_right_arithmetic"):
            self._default_out(eqn, env)
        elif name == "reduce_sum":
            axes = params.get("axes", ())
            shape = tuple(getattr(_aval_of(eqn.invars[0]), "shape", ()) or ())
            n = 1
            for a in axes:
                if a < len(shape):
                    n *= int(shape[a])
            x = iv(0)
            out(Interval(_mul(n, min(x.lo, 0.0)) if x.lo < 0 else n * x.lo,
                         _mul(n, x.hi) if x.hi > 0 else x.hi))
        elif name in ("reduce_max", "reduce_min", "cummax", "cummin"):
            out(iv(0))
        elif name in ("reduce_and", "reduce_or"):
            out(BOOL)
        elif name in ("argmax", "argmin"):
            axes = params.get("axes", (0,))
            shape = tuple(getattr(_aval_of(eqn.invars[0]), "shape", ()) or ())
            hi = max((int(shape[a]) - 1 for a in axes if a < len(shape)),
                     default=0)
            out(Interval(0, float(hi)))
        elif name in _NONNEG_UNARY:
            out(Interval(0, POS_INF))
        elif name == "get":
            self._eval_get(eqn, env, refs)
        elif name == "swap":
            self._eval_swap(eqn, env, refs, preds)
        elif name in ("addupdate",):
            self._eval_swap(eqn, env, refs, preds, accumulate=True)
        elif name == "dma_start":
            self._eval_dma(eqn, env, refs)
        elif name in ("dma_wait", "semaphore_signal", "semaphore_wait"):
            pass
        elif name == "dynamic_slice":
            operand = eqn.invars[0]
            shape = tuple(getattr(_aval_of(operand), "shape", ()) or ())
            sizes = params.get("slice_sizes", ())
            for d, (dim, size) in enumerate(zip(shape, sizes)):
                start = self._ival(eqn.invars[1 + d], env)
                # clamped semantics in XLA, but Pallas lowers unclamped —
                # hold kernels to the strict contract
                self._check_index(refs, operand, name, dim, int(size), start) \
                    if refs.is_ref(operand) else None
            out(iv(0))
        elif name == "cond":
            self._eval_cond(eqn, env, refs, preds)
        elif name == "while":
            self._eval_while(eqn, env, refs, preds)
        elif name == "scan":
            self._eval_scan(eqn, env, refs)
        elif name in ("pjit", "closed_call", "core_call", "remat",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vmap_call"):
            self._eval_call(eqn, env, refs, preds)
        elif name == "dot_general":
            self._default_out(eqn, env)
        else:
            self._default_out(eqn, env)

    @staticmethod
    def _div(a: Interval, b: Interval) -> Interval:
        if b.lo <= 0 <= b.hi:
            return TOP
        cands = []
        for x in (a.lo, a.hi):
            for y in (b.lo, b.hi):
                if math.isinf(x) and math.isinf(y):
                    cands.extend([-1.0, 1.0])
                elif math.isinf(y):
                    cands.append(0.0)
                else:
                    cands.append(x / y)
        return Interval(min(cands), max(cands))

    @staticmethod
    def _rem(a: Interval, b: Interval) -> Interval:
        if b.lo == b.hi and math.isfinite(b.lo) and b.lo > 0:
            m = b.lo
            if a.lo >= 0:
                return Interval(0, min(a.hi, m - 1))
            return Interval(-(m - 1), m - 1)
        return TOP

    def _eval_get(self, eqn, env, refs):
        ref = eqn.invars[0]
        shape = tuple(getattr(_aval_of(ref), "shape", ()) or ())
        for idxr in self._indexers_of(eqn.params.get("tree"),
                                      eqn.invars[1:]):
            shape = self._check_indexer(refs, ref, "get", shape, idxr, env)
        env[eqn.outvars[0]] = refs.read(ref)

    def _eval_swap(self, eqn, env, refs, preds, accumulate=False):
        ref, val = eqn.invars[0], eqn.invars[1]
        shape = tuple(getattr(_aval_of(ref), "shape", ()) or ())
        for idxr in self._indexers_of(eqn.params.get("tree"),
                                      eqn.invars[2:]):
            shape = self._check_indexer(refs, ref, "swap", shape, idxr, env)
        stored = self._ival(val, env)
        if accumulate:
            stored = stored.add(refs.read(ref))
        refs.store(ref, stored)
        for ov in eqn.outvars:
            env[ov] = refs.read(ref)

    def _eval_dma(self, eqn, env, refs):
        """dma_start: check every NDIndexer against the ref it transforms."""
        try:
            import jax.tree_util as jtu

            tree = eqn.params.get("tree")
            structure = jtu.tree_unflatten(tree, list(eqn.invars))
        except Exception:
            return
        items = list(structure) if isinstance(structure, (tuple, list)) \
            else [structure]
        cur_ref = None
        src_ref = None
        dst_ref = None
        for item in items:
            if _is_ref(item) and not isinstance(item, (tuple, list)):
                cur_ref = item
                if src_ref is None:
                    src_ref = item
                elif dst_ref is None and "Semaphore" not in str(
                    _aval_of(item)
                ):
                    dst_ref = item
            elif cur_ref is not None:
                shape = tuple(getattr(_aval_of(cur_ref), "shape", ()) or ())
                for idxr in self._indexers_of_value(item):
                    shape = self._check_indexer(
                        refs, cur_ref, "dma_start", shape, idxr, env
                    )
        if dst_ref is not None and refs.is_ref(dst_ref):
            refs.store(dst_ref, refs.read(src_ref) if src_ref is not None
                       and refs.is_ref(src_ref) else
                       dtype_interval(getattr(_aval_of(dst_ref), "dtype",
                                              np.float32)))

    @staticmethod
    def _indexers_of_value(value):
        out = []

        def walk(obj):
            if hasattr(obj, "indices") and hasattr(obj, "shape"):
                out.append(obj)
            elif isinstance(obj, (list, tuple)):
                for item in obj:
                    walk(item)

        walk(value)
        return out

    def _refined_env(self, constraints, operands, inner_vars, env, truth):
        """Env for a cond branch: operand intervals, refined by the pred."""
        inner_env = {}
        for outer, inner in zip(operands, inner_vars):
            inner_env[inner] = self._ival(outer, env)
        if not constraints:
            return inner_env
        for op, lhs, rhs in constraints:
            use_op = op
            if not truth:
                if len(constraints) > 1 or op not in _CMP_NEGATE:
                    continue  # ¬(a ∧ b) is a disjunction — no refinement
                use_op = _CMP_NEGATE[op]
            lhs_iv = self._ival(lhs, env)
            rhs_iv = self._ival(rhs, env)
            for outer, inner in zip(operands, inner_vars):
                if outer is lhs:
                    inner_env[inner] = refine_cmp(
                        use_op, inner_env[inner], rhs_iv, True
                    )
                elif outer is rhs:
                    inner_env[inner] = refine_cmp(
                        use_op, inner_env[inner], lhs_iv, False
                    )
        return inner_env

    def _eval_cond(self, eqn, env, refs, preds):
        branches = eqn.params.get("branches", ())
        operands = list(eqn.invars[1:])
        constraints = preds.get(eqn.invars[0], [])
        joined: list[Interval] | None = None
        for b_i, closed in enumerate(branches):
            body = closed.jaxpr
            if len(body.invars) != len(operands):
                continue
            truth = (b_i == len(branches) - 1) if len(branches) == 2 \
                else None
            inner_env = self._refined_env(
                constraints if truth is not None else [],
                operands, body.invars, env, bool(truth),
            )
            for outer, inner in zip(operands, body.invars):
                if refs.is_ref(outer):
                    refs.alias[inner] = refs.canon(outer)
            inner_preds: dict[Any, list] = {}
            self._eval_eqns(body.eqns, inner_env, refs, inner_preds)
            outs = [
                self._ival(ov, inner_env)
                if not isinstance(ov, jex_core.Literal)
                else literal_interval(ov.val)
                for ov in body.outvars
            ]
            joined = outs if joined is None else [
                a.join(b) for a, b in zip(joined, outs)
            ]
        for i, ov in enumerate(eqn.outvars):
            env[ov] = joined[i] if joined and i < len(joined) else \
                dtype_interval(getattr(_aval_of(ov), "dtype", np.float32))

    def _cond_constraints(self, cond_jaxpr, cnc):
        """Constraints the loop condition imposes on carry positions."""
        body = cond_jaxpr.jaxpr
        local_preds: dict[Any, list] = {}
        pos_of = {v: i - cnc for i, v in enumerate(body.invars) if i >= cnc}
        for eqn in body.eqns:
            name = eqn.primitive.name
            if name in _CMP_OPS:
                local_preds[eqn.outvars[0]] = [
                    (name, eqn.invars[0], eqn.invars[1])
                ]
            elif name == "and":
                local_preds[eqn.outvars[0]] = (
                    local_preds.get(eqn.invars[0], [])
                    + local_preds.get(eqn.invars[1], [])
                )
            elif name == "convert_element_type" and eqn.invars[0] in \
                    local_preds:
                local_preds[eqn.outvars[0]] = local_preds[eqn.invars[0]]
        outv = body.outvars[0]
        out = []
        for op, lhs, rhs in local_preds.get(outv, []):
            lhs_pos = pos_of.get(lhs)
            rhs_pos = pos_of.get(rhs)
            out.append((op, lhs, lhs_pos, rhs, rhs_pos))
        return out

    def _eval_while(self, eqn, env, refs, preds):
        params = eqn.params
        cnc = params.get("cond_nconsts", 0)
        bnc = params.get("body_nconsts", 0)
        cond_jaxpr = params["cond_jaxpr"]
        body = params["body_jaxpr"].jaxpr
        cond_consts = eqn.invars[:cnc]
        body_consts = eqn.invars[cnc:cnc + bnc]
        init = eqn.invars[cnc + bnc:]
        carry = [self._ival(a, env) for a in init]
        constraints = self._cond_constraints(cond_jaxpr, cnc)

        def const_ival(atom, consts, jaxpr_invars):
            if isinstance(atom, jex_core.Literal):
                return literal_interval(atom.val)
            for outer, inner in zip(consts, jaxpr_invars):
                if inner is atom:
                    return self._ival(outer, env)
            return None

        def refine_carry(c):
            refined = list(c)
            for op, lhs, lhs_pos, rhs, rhs_pos in constraints:
                lhs_iv = refined[lhs_pos] if lhs_pos is not None else \
                    const_ival(lhs, cond_consts, cond_jaxpr.jaxpr.invars)
                rhs_iv = refined[rhs_pos] if rhs_pos is not None else \
                    const_ival(rhs, cond_consts, cond_jaxpr.jaxpr.invars)
                if lhs_pos is not None and rhs_iv is not None:
                    refined[lhs_pos] = refine_cmp(
                        op, refined[lhs_pos], rhs_iv, True
                    )
                if rhs_pos is not None and lhs_iv is not None:
                    refined[rhs_pos] = refine_cmp(
                        op, refined[rhs_pos], lhs_iv, False
                    )
            return refined

        for it in range(self.MAX_LOOP_ITERS):
            body_env: dict[Any, Interval] = {}
            for outer, inner in zip(body_consts, body.invars[:bnc]):
                body_env[inner] = self._ival(outer, env)
                if refs.is_ref(outer):
                    refs.alias[inner] = refs.canon(outer)
            refined = refine_carry(carry)
            for c_iv, inner in zip(refined, body.invars[bnc:]):
                body_env[inner] = c_iv
            inner_preds: dict[Any, list] = {}
            self._eval_eqns(body.eqns, body_env, refs, inner_preds)
            outs = [
                literal_interval(ov.val)
                if isinstance(ov, jex_core.Literal)
                else self._ival(ov, body_env)
                for ov in body.outvars
            ]
            new = [a.join(b) for a, b in zip(carry, outs)]
            if it >= 1:
                new = [a.widen(b) for a, b in zip(carry, new)]
            if new == carry:
                break
            carry = new
        for ov, c_iv in zip(eqn.outvars, carry):
            env[ov] = c_iv

    def _eval_scan(self, eqn, env, refs):
        params = eqn.params
        body = params["jaxpr"].jaxpr
        nc = params.get("num_consts", 0)
        body_env: dict[Any, Interval] = {}
        for outer, inner in zip(eqn.invars[:nc], body.invars[:nc]):
            body_env[inner] = self._ival(outer, env)
            if refs.is_ref(outer):
                refs.alias[inner] = refs.canon(outer)
        for inner in body.invars[nc:]:
            body_env[inner] = dtype_interval(
                getattr(_aval_of(inner), "dtype", np.float32)
            )
        for _ in range(2):
            self._eval_eqns(body.eqns, dict(body_env), refs, {})
        self._default_out(eqn, env)

    def _eval_call(self, eqn, env, refs, preds):
        for value in eqn.params.values():
            subs = []
            if isinstance(value, jex_core.ClosedJaxpr):
                subs = [value.jaxpr]
            elif isinstance(value, jex_core.Jaxpr):
                subs = [value]
            for sub in subs:
                if len(sub.invars) != len(eqn.invars):
                    continue
                inner_env = {}
                for outer, inner in zip(eqn.invars, sub.invars):
                    inner_env[inner] = self._ival(outer, env)
                    if not isinstance(outer, jex_core.Literal) and \
                            refs.is_ref(outer):
                        refs.alias[inner] = refs.canon(outer)
                inner_preds: dict[Any, list] = {}
                self._eval_eqns(sub.eqns, inner_env, refs, inner_preds)
                for ov, sub_ov in zip(eqn.outvars, sub.outvars):
                    env[ov] = (
                        literal_interval(sub_ov.val)
                        if isinstance(sub_ov, jex_core.Literal)
                        else self._ival(sub_ov, inner_env)
                    )
                return
        self._default_out(eqn, env)


def check_bounds(call) -> list[BoundsFinding]:
    """All bounds findings for one extracted KernelCall."""
    return BoundsInterpreter(call).run()
