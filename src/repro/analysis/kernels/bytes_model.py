"""Derive a per-kernel HBM traffic model from BlockSpecs × grid × dtype.

The hand-written ``_bytes_model`` functions the benchmarks used to carry
restated, by hand, what the BlockSpecs already say: which blocks move per
grid step. This module derives that model from the traced ``pallas_call``
itself, so benchmarks, roofline numbers, and the static verifier share
one source of truth.

For a **blocked** operand (a real BlockSpec), the pipeline fetches a
block whenever the block index changes between consecutive grid steps
(row-major order, last axis fastest — TPU's sequential schedule); an
index map that ignores the innermost axes therefore keeps its block
resident and costs nothing on revisits. We enumerate the grid (capped;
beyond the cap, the dependence-derived product bound is used and noted),
evaluate the index-map jaxpr on concrete integers, and count changes.

For a ``memory_space=ANY`` operand the data plane is explicit
``dma_start`` eqns in the kernel body: each copy's element count is the
product of its NDIndexer result shape, counted once per grid step per
(possibly ``pl.when``-guarded) eqn — an upper bound for conditional
DMAs, which is the right sign for a traffic model.

Scalar-prefetch operands are SMEM-resident and reported separately,
excluded from the headline total (matching the deleted hand models'
convention). Scratch is VMEM and costs nothing.
"""

from __future__ import annotations

import numpy as np

import jax.extend.core as jex_core

MAX_ENUM_STEPS = 1_000_000


def _eval_index_map(index_map, grid_idx: tuple, n_grid: int):
    """Evaluate an index-map jaxpr on concrete grid indices.

    Scalar-prefetch ref operands (if the map reads them) make the map
    non-evaluable — return None and let the caller fall back.
    """
    jaxpr = index_map.jaxpr if hasattr(index_map, "jaxpr") else index_map
    consts = getattr(index_map, "consts", [])
    env: dict = {}
    for cv, cval in zip(jaxpr.constvars, consts):
        try:
            env[cv] = int(np.asarray(cval))
        except Exception:
            return None
    invars = list(jaxpr.invars)
    for v, idx in zip(invars[:n_grid], grid_idx):
        env[v] = int(idx)

    def val(atom):
        if isinstance(atom, jex_core.Literal):
            return int(np.asarray(atom.val))
        if atom not in env:
            raise KeyError(atom)
        return env[atom]

    try:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            if name == "add":
                env[eqn.outvars[0]] = val(eqn.invars[0]) + val(eqn.invars[1])
            elif name == "sub":
                env[eqn.outvars[0]] = val(eqn.invars[0]) - val(eqn.invars[1])
            elif name == "mul":
                env[eqn.outvars[0]] = val(eqn.invars[0]) * val(eqn.invars[1])
            elif name in ("div", "floor_divide"):
                env[eqn.outvars[0]] = val(eqn.invars[0]) // val(
                    eqn.invars[1])
            elif name == "rem":
                env[eqn.outvars[0]] = val(eqn.invars[0]) % val(eqn.invars[1])
            elif name in ("convert_element_type", "copy", "squeeze",
                          "reshape", "broadcast_in_dim"):
                env[eqn.outvars[0]] = val(eqn.invars[0])
            elif name == "max":
                env[eqn.outvars[0]] = max(val(eqn.invars[0]),
                                          val(eqn.invars[1]))
            elif name == "min":
                env[eqn.outvars[0]] = min(val(eqn.invars[0]),
                                          val(eqn.invars[1]))
            elif name == "neg":
                env[eqn.outvars[0]] = -val(eqn.invars[0])
            else:
                return None
        return tuple(val(ov) for ov in jaxpr.outvars)
    except KeyError:
        return None


def _grid_steps(grid):
    """Row-major enumeration (last axis fastest), matching TPU order."""
    if not grid:
        yield ()
        return
    idx = [0] * len(grid)
    total = 1
    for g in grid:
        total *= int(g)
    for _ in range(total):
        yield tuple(idx)
        for ax in range(len(grid) - 1, -1, -1):
            idx[ax] += 1
            if idx[ax] < grid[ax]:
                break
            idx[ax] = 0


def _dep_axes(index_map, n_grid):
    from repro.analysis.kernels.race import _index_map_deps

    dep, dynamic = _index_map_deps(index_map, n_grid)
    return dep, dynamic


def _blocked_operand_bytes(op, grid) -> dict:
    block_elems = 1
    for b in (op.block_shape or ()):
        block_elems *= int(b)
    block_bytes = block_elems * op.itemsize
    total_steps = 1
    for g in grid:
        total_steps *= int(g)

    note = None
    if total_steps <= MAX_ENUM_STEPS:
        fetches = 0
        prev = None
        exact = True
        for step in _grid_steps(grid):
            bi = _eval_index_map(op.index_map, step, len(grid))
            if bi is None:
                exact = False
                break
            if bi != prev:
                fetches += 1
                prev = bi
        if not exact:
            fetches = None
    else:
        fetches = None
        note = f"grid has {total_steps} steps; used dependence bound"

    if fetches is None:
        dep, dynamic = _dep_axes(op.index_map, len(grid))
        fetches = 1
        for ax in sorted(dep):
            fetches *= int(grid[ax])
        if dynamic:
            note = "index map reads scalar-prefetch data; bound assumes " \
                   "one fetch per dependent-axis step"
    return {
        "bytes": fetches * block_bytes,
        "fetches": fetches,
        "block_bytes": block_bytes,
        "note": note,
    }


def _indexer_elems(indexer) -> int:
    elems = 1
    for idx in getattr(indexer, "indices", ()):
        if hasattr(idx, "size"):
            elems *= int(idx.size)
        elif isinstance(idx, (int, np.integer)):
            pass
        else:
            shape = tuple(getattr(getattr(idx, "aval", None), "shape",
                                  ()) or ())
            for s in shape:
                elems *= int(s)
    return elems


def _dma_bytes_per_step(call) -> dict:
    """Per-grid-step DMA traffic for each ANY operand, by origin."""
    import jax.tree_util as jtu

    any_ops = {op.index: op for op in call.operands if op.is_any}
    if not any_ops:
        return {}
    invar_to_op = {}
    for op in call.operands:
        invar_to_op[call.jaxpr.invars[op.index]] = op
    per_op: dict = {op.origin: 0 for op in any_ops.values()}

    def aval_of(atom):
        return getattr(atom, "aval", None)

    def is_ref(atom):
        aval = aval_of(atom)
        return aval is not None and "Ref" in type(aval).__name__

    def walk_indexers(value, out):
        if hasattr(value, "indices") and hasattr(value, "shape"):
            out.append(value)
        elif isinstance(value, (list, tuple)):
            for item in value:
                walk_indexers(item, out)

    def visit(jaxpr, alias):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "dma_start":
                try:
                    structure = jtu.tree_unflatten(
                        eqn.params.get("tree"), list(eqn.invars))
                except Exception:
                    continue
                items = list(structure) if isinstance(
                    structure, (tuple, list)) else [structure]
                cur = None
                for item in items:
                    if is_ref(item) and not isinstance(item,
                                                       (tuple, list)):
                        cur = alias.get(item, item)
                    elif cur is not None and cur in invar_to_op and \
                            invar_to_op[cur].is_any:
                        op = invar_to_op[cur]
                        idxrs: list = []
                        walk_indexers(item, idxrs)
                        elems = 1
                        shape = op.ref_shape
                        if idxrs:
                            for idxr in idxrs:
                                elems = _indexer_elems(idxr)
                                # trailing unindexed dims
                                n_idx = len(getattr(idxr, "indices", ()))
                                for s in shape[n_idx:]:
                                    elems *= int(s)
                                shape = ()
                        else:
                            for s in shape:
                                elems *= int(s)
                        per_op[op.origin] += elems * op.itemsize
            else:
                for value in eqn.params.values():
                    subs = []
                    if isinstance(value, jex_core.ClosedJaxpr):
                        subs = [value.jaxpr]
                    elif isinstance(value, jex_core.Jaxpr):
                        subs = [value]
                    elif isinstance(value, (tuple, list)):
                        for v in value:
                            if isinstance(v, jex_core.ClosedJaxpr):
                                subs.append(v.jaxpr)
                    for sub in subs:
                        sub_alias = dict(alias)
                        if len(sub.invars) == len(eqn.invars):
                            for outer, inner in zip(eqn.invars,
                                                    sub.invars):
                                if not isinstance(outer,
                                                  jex_core.Literal):
                                    sub_alias[inner] = alias.get(outer,
                                                                 outer)
                        visit(sub, sub_alias)

    visit(call.jaxpr, {})
    return per_op


def derive(call) -> dict:
    """The full derived traffic model for one KernelCall."""
    grid = call.grid
    total_steps = 1
    for g in grid:
        total_steps *= int(g)
    per_operand: dict = {}
    total = 0

    dma_per_step = _dma_bytes_per_step(call)
    for op in call.inputs + call.outputs:
        if op.is_any:
            per_step = dma_per_step.get(op.origin, 0)
            entry = {
                "kind": "dma",
                "bytes": per_step * total_steps,
                "per_step": per_step,
                "note": "explicit dma_start traffic "
                        "(pl.when-guarded copies counted — upper bound)"
                        if per_step else "ANY operand with no dma_start",
            }
        else:
            entry = _blocked_operand_bytes(op, grid)
            entry["kind"] = "read" if op.kind == "input" else "write"
        key = op.origin
        if key in per_operand:
            key = f"{op.origin}#{op.index}"
        per_operand[key] = entry
        total += entry["bytes"]

    prefetch_bytes = 0
    for op in call.prefetch:
        n = 1
        for s in (op.array_shape or op.ref_shape):
            n *= int(s)
        prefetch_bytes += n * op.itemsize

    return {
        "name": call.name,
        "grid": tuple(grid),
        "steps": total_steps,
        "total": int(total),
        "per_operand": per_operand,
        "scalar_prefetch_bytes": int(prefetch_bytes),
    }


def derive_traffic(fn, *args, **kwargs) -> dict:
    """Trace ``fn`` and derive the traffic model of every kernel in it.

    Returns ``{kernel_name: model}`` (names deduped with ``#i``). This is
    the helper the benchmarks use instead of hand-written byte formulas.
    """
    import jax

    from repro.analysis.kernels.extract import find_kernel_calls

    closed = jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args)
    out: dict = {}
    for call in find_kernel_calls(closed):
        key = call.name
        i = 1
        while key in out:
            key = f"{call.name}#{i}"
            i += 1
        out[key] = derive(call)
    return out
