"""Padding-taint analysis for Pallas kernel jaxprs.

The PR 2 bug class: a kernel block is larger than the logical data (rows
padded to a sublane multiple, lanes padded to 128, positions padded with a
sentinel), and a reduction sums/maxes the padding lanes *without masking
them first*. Zero-padding survives ``sum`` and ``dot`` but corrupts
``max``; sentinel padding corrupts everything; and zero-padding stops
being zero the moment a non-multiplicative op touches it (``exp(0) = 1``).

This module tracks, per value and per axis, where padding could be hiding:

* ``('zero', 0.0)`` — lanes known to hold the pad value 0 (from
  ``jnp.pad`` / ``repro.kernels.common.pad_to`` with zero fill),
* ``('sentinel', c)`` — lanes holding a known constant sentinel (the
  decode ring's ``pos = -1``, the z-update's ``arr = n`` fill),
* ``('dirty', None)`` — lanes holding arbitrary junk (sentinels after
  arithmetic, zero-pad after a non-linear op, data gathered through
  out-of-range-but-clamped indices).

Absence of an axis entry means the axis is fully valid. The special axis
key ``'*'`` taints the whole value (used when a dynamic scalar index
could select a padded lane, collapsing axis structure).

Masks are recognized structurally: a comparison between an iota-derived
position vector (or a sentinel-tainted value) and a threshold yields a
per-axis *pad-lane truth value* (do padded lanes make this predicate
``False``/``True``?); ``jnp.where(pred, x, fallback)`` with a known
pad-lane branch whose fallback is untainted clears the taint. This is how
``jnp.where(row_id < n_bright, ..., 0.0)`` in the bright kernel and
``jnp.where((posv >= 0) & (posv <= t), s, NEG)`` in decode attention are
proven to scrub their padding before the reduction.

Findings fire only at reductions (``reduce_sum``/``max``/``min``,
``dot_general`` contractions, ``cum*`` feeding them is tracked but not a
finding site): a *store* of tainted lanes to an output ref is the
caller's documented slice-off-the-padding contract, exercised by the
parity tests, not a kernel bug.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.extend.core as jex_core
import numpy as np

ZERO = "zero"
SENTINEL = "sentinel"
DIRTY = "dirty"

_CMP_OPS = {"lt", "le", "gt", "ge", "eq", "ne"}

# Unary float ops that do NOT map 0 → 0 (zero-pad stops being zero).
_NONZERO_PRESERVING = {
    "exp", "exp2", "cos", "cosh", "log", "log1p", "logistic", "rsqrt",
    "erfc", "digamma", "lgamma",
}
# Unary ops that map 0 → 0, so zero-pad survives.
_ZERO_PRESERVING = {
    "neg", "abs", "sign", "sin", "sinh", "tan", "tanh", "sqrt", "square",
    "expm1", "erf", "floor", "ceil", "round", "real", "imag",
    "stop_gradient", "reduce_precision", "copy", "integer_pow",
}
_SHAPE_PASSTHROUGH = {"copy", "stop_gradient", "reduce_precision",
                      "convert_element_type", "device_put"}


@dataclasses.dataclass
class TFact:
    """Taint of one value: per-axis pad kinds + mask-recognition metadata."""

    taint: dict  # axis (int or '*') -> (kind, value)
    pos_axes: set  # axes whose values are iota-derived positions
    padbool: dict  # axis -> bool: predicate value on padded lanes

    @staticmethod
    def clean() -> "TFact":
        return TFact({}, set(), {})

    def copy(self) -> "TFact":
        return TFact(dict(self.taint), set(self.pos_axes), dict(self.padbool))

    @property
    def is_clean(self) -> bool:
        return not self.taint

    def worst(self):
        """The most severe kind present (dirty > sentinel > zero)."""
        kinds = {k for k, _ in self.taint.values()}
        for k in (DIRTY, SENTINEL, ZERO):
            if k in kinds:
                return k
        return None


def _join_kind(a, b):
    """Join two (kind, value) taints on the same axis."""
    if a is None:
        return b
    if b is None:
        return a
    if a == b:
        return a
    if a[0] == ZERO and b[0] == ZERO:
        return (ZERO, 0.0)
    return (DIRTY, None)


def join(a: TFact, b: TFact) -> TFact:
    taint = {}
    for ax in set(a.taint) | set(b.taint):
        taint[ax] = _join_kind(a.taint.get(ax), b.taint.get(ax))
    padbool = {
        ax: a.padbool[ax]
        for ax in set(a.padbool) & set(b.padbool)
        if a.padbool[ax] == b.padbool[ax]
    }
    return TFact(taint, a.pos_axes & b.pos_axes, padbool)


def _aval_of(atom):
    return getattr(atom, "aval", None)


def _shape(atom) -> tuple:
    return tuple(getattr(_aval_of(atom), "shape", ()) or ())


def _is_ref(atom) -> bool:
    aval = _aval_of(atom)
    return aval is not None and "Ref" in type(aval).__name__


def remap_axes(fact: TFact, mapping: dict) -> TFact:
    """Rebuild a fact with axes renumbered; unmapped axes drop to '*' only
    if tainted with something non-zero (zero pad in a vanished axis is
    harmless), else drop."""
    out = TFact.clean()
    for ax, kind in fact.taint.items():
        if ax == "*":
            out.taint["*"] = _join_kind(out.taint.get("*"), kind)
        elif ax in mapping:
            for new_ax in mapping[ax]:
                out.taint[new_ax] = _join_kind(out.taint.get(new_ax), kind)
        elif kind[0] != ZERO:
            out.taint["*"] = _join_kind(out.taint.get("*"), (DIRTY, None))
    out.pos_axes = {
        na for ax in fact.pos_axes if ax in mapping
        for na in mapping[ax] if len(mapping[ax]) == 1
    }
    out.padbool = {
        mapping[ax][0]: v for ax, v in fact.padbool.items()
        if ax in mapping and len(mapping[ax]) == 1
    }
    return out


def broadcast_remap(in_shape, out_shape, bcast_dims) -> dict:
    return {i: (int(d),) for i, d in enumerate(bcast_dims)}


def reshape_remap(in_shape, out_shape) -> dict:
    """Axis mapping for a reshape via prefix-product factorization: an
    input axis maps to the output axes its extent factors into; a merged
    or ambiguous factorization maps the axis to all covering out axes."""
    in_shape = [int(s) for s in in_shape]
    out_shape = [int(s) for s in out_shape]
    mapping: dict = {}
    # Greedy segment matching: walk both shapes, matching equal products.
    i = j = 0
    while i < len(in_shape) and j < len(out_shape):
        in_seg, out_seg = [i], [j]
        pi, pj = in_shape[i], out_shape[j]
        i += 1
        j += 1
        while pi != pj:
            if pi < pj and i < len(in_shape):
                pi *= in_shape[i]
                in_seg.append(i)
                i += 1
            elif pj < pi and j < len(out_shape):
                pj *= out_shape[j]
                out_seg.append(j)
                j += 1
            else:
                break
        for ax in in_seg:
            mapping[ax] = tuple(out_seg)
    # trailing unit axes
    while i < len(in_shape):
        mapping[i] = ()
        i += 1
    return mapping


@dataclasses.dataclass
class TaintFinding:
    """One reduction consuming unmasked padding."""

    ref: str
    eqn: str
    kind: str
    axes: tuple

    def message(self) -> str:
        where = f"axes {tuple(self.axes)}" if self.axes else "operand"
        return (
            f"{self.eqn} reduces over {self.kind}-padded {where} "
            f"({self.ref}) without masking the padding lanes first"
        )


class TaintInterpreter:
    """Run the padding-taint analysis over one extracted KernelCall."""

    MAX_PASSES = 3

    def __init__(self, call):
        self.call = call
        self.findings: list[TaintFinding] = []
        self._seen: set = set()
        self.collect = False

    def run(self) -> list[TaintFinding]:
        jaxpr = self.call.jaxpr
        carry: dict | None = None
        for pass_i in range(self.MAX_PASSES):
            self.collect = pass_i == self.MAX_PASSES - 1
            refs: dict[Any, TFact] = {}
            alias: dict[Any, Any] = {}
            env: dict[Any, TFact] = {}
            for invar, op in zip(jaxpr.invars, self.call.operands):
                fact = (op.taint or TFact.clean()).copy()
                if _is_ref(invar):
                    if carry is not None and invar in carry:
                        fact = join(fact, carry[invar])
                    refs[invar] = fact
                else:
                    env[invar] = fact
            self._refs, self._alias = refs, alias
            self._eval_eqns(jaxpr.eqns, env)
            new_carry = {v: f for v, f in refs.items()}
            if carry is not None and all(
                v in carry and carry[v].taint == f.taint
                for v, f in new_carry.items()
            ):
                if not self.collect:
                    self.collect = True
                    # converged: rerun once to collect findings
                    refs2 = {}
                    env2 = {}
                    for invar, op in zip(jaxpr.invars, self.call.operands):
                        fact = (op.taint or TFact.clean()).copy()
                        if _is_ref(invar):
                            refs2[invar] = join(fact, new_carry.get(
                                invar, TFact.clean()))
                        else:
                            env2[invar] = fact
                    self._refs, self._alias = refs2, {}
                    self._eval_eqns(jaxpr.eqns, env2)
                return self.findings
            carry = new_carry
        return self.findings

    # -- plumbing ------------------------------------------------------------

    def _canon(self, var):
        while var in self._alias:
            var = self._alias[var]
        return var

    def _fact(self, atom, env) -> TFact:
        if isinstance(atom, jex_core.Literal):
            return TFact.clean()
        return env.get(atom, TFact.clean())

    def _ref_fact(self, var) -> TFact:
        return self._refs.setdefault(self._canon(var), TFact.clean())

    def _ref_name(self, var) -> str:
        var = self._canon(var)
        for invar, op in zip(self.call.jaxpr.invars, self.call.operands):
            if invar is var:
                return op.origin
        return "<local>"

    def _emit(self, eqn_name, label, kind, axes):
        if not self.collect:
            return
        key = (eqn_name, label, kind, tuple(sorted(axes)))
        if key in self._seen:
            return
        self._seen.add(key)
        self.findings.append(TaintFinding(
            ref=label, eqn=eqn_name, kind=kind, axes=key[3]
        ))

    # -- the interpreter -----------------------------------------------------

    def _eval_eqns(self, eqns, env):
        for eqn in eqns:
            self._eval_eqn(eqn, env)

    def _eval_eqn(self, eqn, env):
        name = eqn.primitive.name
        params = eqn.params
        fact = lambda i: self._fact(eqn.invars[i], env)

        def out(f: TFact, i=0):
            env[eqn.outvars[i]] = f

        if name == "iota":
            f = TFact.clean()
            f.pos_axes = {int(params.get("dimension", 0))}
            out(f)
        elif name in _SHAPE_PASSTHROUGH:
            out(fact(0).copy())
        elif name == "broadcast_in_dim":
            in_shape = _shape(eqn.invars[0])
            out_shape = params.get("shape", _shape(eqn.outvars[0]))
            dims = params.get("broadcast_dimensions", ())
            out(remap_axes(fact(0), broadcast_remap(in_shape, out_shape,
                                                    dims)))
        elif name in ("reshape", "squeeze", "expand_dims"):
            out(remap_axes(fact(0), reshape_remap(_shape(eqn.invars[0]),
                                                  _shape(eqn.outvars[0]))))
        elif name == "transpose":
            perm = params.get("permutation", ())
            mapping = {int(old): (new,) for new, old in enumerate(perm)}
            out(remap_axes(fact(0), mapping))
        elif name in ("slice", "rev", "dynamic_slice"):
            # Conservative: padding may or may not survive a static slice;
            # keep the taint (sound — can only over-report).
            out(fact(0).copy())
        elif name == "concatenate":
            acc = fact(0)
            for i in range(1, len(eqn.invars)):
                acc = join(acc, fact(i))
            out(acc)
        elif name == "pad":
            f = fact(0).copy()
            padval = eqn.invars[1]
            if isinstance(padval, jex_core.Literal):
                v = float(np.asarray(padval.val).reshape(-1)[0])
            else:
                v = None
            for ax, (lo, hi, interior) in enumerate(
                params.get("padding_config", ())
            ):
                if hi > 0 or lo > 0 or interior > 0:
                    kind = (ZERO, 0.0) if v == 0.0 else (
                        (SENTINEL, v) if v is not None else (DIRTY, None)
                    )
                    f.taint[ax] = _join_kind(f.taint.get(ax), kind)
            out(f)
        elif name in _CMP_OPS:
            out(self._compare(eqn, env))
        elif name == "and":
            a, b = fact(0), fact(1)
            f = self._binary_arith(eqn, env, name)
            f.padbool = {}
            for ax in set(a.padbool) | set(b.padbool):
                va, vb = a.padbool.get(ax), b.padbool.get(ax)
                if va is False or vb is False:
                    f.padbool[ax] = False
                elif va is True and vb is True:
                    f.padbool[ax] = True
            out(f)
        elif name == "or":
            a, b = fact(0), fact(1)
            f = self._binary_arith(eqn, env, name)
            f.padbool = {}
            for ax in set(a.padbool) | set(b.padbool):
                va, vb = a.padbool.get(ax), b.padbool.get(ax)
                if va is True or vb is True:
                    f.padbool[ax] = True
                elif va is False and vb is False:
                    f.padbool[ax] = False
            out(f)
        elif name == "not":
            a = fact(0)
            f = a.copy()
            f.padbool = {ax: not v for ax, v in a.padbool.items()}
            out(f)
        elif name == "select_n":
            out(self._select(eqn, env))
        elif name in ("add", "sub", "mul", "max", "min", "div", "rem",
                      "pow", "atan2", "nextafter", "xor",
                      "shift_left", "shift_right_logical",
                      "shift_right_arithmetic"):
            out(self._binary_arith(eqn, env, name))
        elif name in _ZERO_PRESERVING:
            out(fact(0).copy())
        elif name in _NONZERO_PRESERVING:
            f = fact(0).copy()
            for ax, kind in list(f.taint.items()):
                f.taint[ax] = (DIRTY, None)
            out(f)
        elif name in ("reduce_sum", "reduce_max", "reduce_min",
                      "reduce_and", "reduce_or", "argmax", "argmin"):
            self._reduction(eqn, env, name)
        elif name in ("cumsum", "cumprod", "cummax", "cummin",
                      "cumlogsumexp"):
            f = fact(0).copy()
            axis = int(params.get("axis", 0))
            k = f.taint.get(axis)
            if k is not None and not (
                k[0] == ZERO and name in ("cumsum", "cummax", "cummin")
            ):
                f.taint[axis] = (DIRTY, None)
            out(f)
        elif name == "dot_general":
            self._dot_general(eqn, env)
        elif name == "get":
            self._eval_get(eqn, env)
        elif name == "swap":
            self._eval_swap(eqn, env)
        elif name == "addupdate":
            self._eval_swap(eqn, env)
        elif name == "dma_start":
            self._eval_dma(eqn, env)
        elif name in ("dma_wait", "semaphore_signal", "semaphore_wait",
                      "program_id", "num_programs"):
            for ov in eqn.outvars:
                env[ov] = TFact.clean()
        elif name == "cond":
            self._eval_cond(eqn, env)
        elif name == "while":
            self._eval_while(eqn, env)
        elif name == "scan":
            self._eval_scan(eqn, env)
        elif name in ("pjit", "closed_call", "core_call", "remat",
                      "checkpoint", "custom_jvp_call", "custom_vjp_call",
                      "custom_vmap_call"):
            self._eval_call(eqn, env)
        else:
            # Unknown op: join all operand taints if ranks line up, else
            # collapse to whole-value taint of the worst operand kind.
            out_rank = len(_shape(eqn.outvars[0])) if eqn.outvars else 0
            acc = TFact.clean()
            collapsed = False
            for i in range(len(eqn.invars)):
                f = fact(i)
                if f.is_clean:
                    continue
                if len(_shape(eqn.invars[i])) == out_rank:
                    acc = join(acc, f)
                else:
                    collapsed = True
            if collapsed and acc.worst() is None:
                acc.taint["*"] = (DIRTY, None)
            for ov in eqn.outvars:
                env[ov] = acc.copy()

    # -- op families ---------------------------------------------------------

    def _binary_arith(self, eqn, env, name) -> TFact:
        a = self._fact(eqn.invars[0], env)
        b = self._fact(eqn.invars[1], env)
        f = TFact.clean()
        for ax in set(a.taint) | set(b.taint):
            ka, kb = a.taint.get(ax), b.taint.get(ax)
            if name == "mul":
                # zero wins: anything times zero-pad lanes is still zero
                if (ka and ka[0] == ZERO) or (kb and kb[0] == ZERO):
                    f.taint[ax] = (ZERO, 0.0)
                else:
                    f.taint[ax] = (DIRTY, None)
            else:
                if ka and kb and ka[0] == ZERO and kb[0] == ZERO and \
                        name in ("add", "sub", "max", "min"):
                    f.taint[ax] = (ZERO, 0.0)
                else:
                    # clean + pad, sentinel + anything, etc: lanes diverge
                    f.taint[ax] = (DIRTY, None)
        # position lineage survives affine ops with untainted other side
        if name in ("add", "sub", "mul"):
            if a.pos_axes and b.is_clean:
                f.pos_axes |= a.pos_axes
            if b.pos_axes and a.is_clean and name != "sub":
                f.pos_axes |= b.pos_axes
        return f

    def _compare(self, eqn, env) -> TFact:
        name = eqn.primitive.name
        a = self._fact(eqn.invars[0], env)
        b = self._fact(eqn.invars[1], env)
        f = TFact.clean()
        for ax in set(a.taint) | set(b.taint):
            f.taint[ax] = (DIRTY, None)  # bool lanes differ on padding

        def lit_value(atom):
            if isinstance(atom, jex_core.Literal):
                arr = np.asarray(atom.val)
                if arr.size == 1:
                    return float(arr.reshape(-1)[0])
            return None

        # Sentinel vs known literal: evaluate the predicate on pad lanes.
        for lhs, rhs, swap in ((a, b, False), (b, a, True)):
            other_atom = eqn.invars[0 if swap else 1]
            lit = lit_value(other_atom)
            for ax, kind in lhs.taint.items():
                if ax == "*":
                    continue
                if kind[0] == SENTINEL and lit is not None:
                    c = kind[1]
                    op = name
                    if swap:
                        op = {"lt": "gt", "le": "ge", "gt": "lt",
                              "ge": "le"}.get(op, op)
                    val = {
                        "lt": c < lit, "le": c <= lit, "gt": c > lit,
                        "ge": c >= lit, "eq": c == lit, "ne": c != lit,
                    }[op]
                    f.padbool[ax] = bool(val)
        # Positions (iota-derived) vs an untainted bound: the canonical
        # row_id < n_valid mask. Heuristic (documented): we verify a mask
        # EXISTS, not that its bound is correct — that is the parity
        # tests' job.
        for lhs, other, swap in ((a, b, False), (b, a, True)):
            if other.worst() in (DIRTY, SENTINEL):
                continue
            op = name
            if swap:
                op = {"lt": "gt", "le": "ge", "gt": "lt", "ge": "le"}.get(
                    op, op)
            for ax in lhs.pos_axes:
                if ax in f.padbool:
                    continue
                if op in ("lt", "le", "eq"):
                    f.padbool[ax] = False
                elif op in ("gt", "ge"):
                    f.padbool[ax] = True
        return f

    def _select(self, eqn, env) -> TFact:
        # select_n(pred, case_false, case_true): jnp.where(p, x, y) lowers
        # with cases (y, x).
        pred = self._fact(eqn.invars[0], env)
        cases = [self._fact(v, env) for v in eqn.invars[1:]]
        if len(cases) == 2 and pred.padbool:
            f = TFact.clean()
            false_c, true_c = cases
            axes = (set(false_c.taint) | set(true_c.taint)
                    | set(pred.taint))
            for ax in axes:
                pb = pred.padbool.get(ax)
                if pb is not None:
                    taken = true_c if pb else false_c
                    k = taken.taint.get(ax)
                    if k is None:
                        continue  # pad lanes take an untainted branch
                    f.taint[ax] = k
                else:
                    f.taint[ax] = _join_kind(
                        _join_kind(false_c.taint.get(ax),
                                   true_c.taint.get(ax)),
                        (DIRTY, None) if ax in pred.taint else None,
                    )
            return f
        acc = pred.copy()
        acc.padbool = {}
        acc.pos_axes = set()
        for c in cases:
            acc = join(acc, c)
        return acc

    def _reduction(self, eqn, env, name):
        f = self._fact(eqn.invars[0], env)
        axes = tuple(int(a) for a in eqn.params.get("axes", ()))
        bad_axes = []
        bad_kind = None
        for ax in axes:
            k = f.taint.get(ax)
            if k is None:
                continue
            if name in ("reduce_sum",) and k[0] == ZERO:
                continue  # summing zeros is exact
            bad_axes.append(ax)
            bad_kind = k[0] if bad_kind is None else DIRTY \
                if bad_kind != k[0] else bad_kind
        star = f.taint.get("*")
        if star is not None and not (name == "reduce_sum"
                                     and star[0] == ZERO):
            bad_axes = bad_axes or ["*"]
            bad_kind = bad_kind or star[0]
        if bad_axes:
            self._emit(name, self._taint_source(eqn.invars[0]), bad_kind,
                       [a for a in bad_axes if a != "*"])
        # result: non-reduced axes keep their taint
        keep = {ax: k for ax, k in f.taint.items()
                if ax not in axes and ax != "*"}
        rank = len(_shape(eqn.invars[0]))
        remaining = [ax for ax in range(rank) if ax not in axes]
        mapping = {old: (new,) for new, old in enumerate(remaining)}
        outf = remap_axes(TFact(keep, f.pos_axes - set(axes), {}), mapping)
        if star is not None:
            outf.taint["*"] = star
        for ov in eqn.outvars:
            env[ov] = outf.copy()

    def _taint_source(self, atom) -> str:
        return "value"

    def _dot_general(self, eqn, env):
        a = self._fact(eqn.invars[0], env)
        b = self._fact(eqn.invars[1], env)
        dnums = eqn.params.get("dimension_numbers")
        try:
            (lc, rc), (lb, rb) = dnums
        except Exception:
            lc = rc = lb = rb = ()
        for la, ra in zip(lc, rc):
            ka, kb = a.taint.get(int(la)), b.taint.get(int(ra))
            if ka is None and kb is None:
                continue
            # one side zero-padded, other side anything → products vanish
            if (ka and ka[0] == ZERO) or (kb and kb[0] == ZERO):
                continue
            kind = (ka or kb)[0]
            self._emit("dot_general", "contraction", kind,
                       [int(la)])
        star = a.taint.get("*") or b.taint.get("*")
        if star is not None and star[0] != ZERO:
            self._emit("dot_general", "contraction", star[0], [])
        # output taint: batch axes, then lhs free, then rhs free
        la_rank = len(_shape(eqn.invars[0]))
        rb_rank = len(_shape(eqn.invars[1]))
        l_free = [ax for ax in range(la_rank)
                  if ax not in lc and ax not in lb]
        r_free = [ax for ax in range(rb_rank)
                  if ax not in rc and ax not in rb]
        out = TFact.clean()
        pos = 0
        for la, _ in zip(lb, rb):
            k = _join_kind(a.taint.get(int(la)), None)
            if k:
                out.taint[pos] = k
            pos += 1
        for ax in l_free:
            k = a.taint.get(ax)
            if k:
                out.taint[pos] = (ZERO, 0.0) if k[0] == ZERO else (
                    DIRTY, None)
            pos += 1
        for ax in r_free:
            k = b.taint.get(ax)
            if k:
                out.taint[pos] = (ZERO, 0.0) if k[0] == ZERO else (
                    DIRTY, None)
            pos += 1
        env[eqn.outvars[0]] = out

    # -- refs ----------------------------------------------------------------

    def _indexers_of(self, tree, flat):
        try:
            import jax.tree_util as jtu

            transforms = jtu.tree_unflatten(tree, list(flat))
        except Exception:
            return []
        out = []

        def walk(obj):
            if hasattr(obj, "indices") and hasattr(obj, "shape"):
                out.append(obj)
            elif isinstance(obj, (list, tuple)):
                for item in obj:
                    walk(item)

        walk(transforms)
        return out

    def _index_taint(self, ref_fact: TFact, indexers, env,
                     ref_shape) -> TFact:
        """Map a ref's content taint through NDIndexers to the loaded
        value's taint."""
        f = ref_fact.copy()
        f.padbool = {}
        for indexer in indexers:
            indices = getattr(indexer, "indices", ())
            out = TFact.clean()
            out_ax = 0
            star = f.taint.get("*")
            for dim_i, idx in enumerate(indices):
                k = f.taint.get(dim_i)
                if hasattr(idx, "size"):  # pl.Slice keeps the axis
                    if k is not None:
                        out.taint[out_ax] = k
                    if dim_i in f.pos_axes:
                        out.pos_axes.add(out_ax)
                    out_ax += 1
                elif isinstance(idx, (int, np.integer)):
                    pass  # static scalar drops the axis; taint vanishes
                         # only if the index provably hits valid lanes —
                         # conservatively keep as whole-value taint below
                else:
                    idx_shape = _shape(idx)
                    idx_fact = self._fact(idx, env)
                    tainted_index = not idx_fact.is_clean
                    for _ in idx_shape:
                        if k is not None or tainted_index:
                            out.taint[out_ax] = (DIRTY, None) \
                                if tainted_index else k
                        out_ax += 1
                    if not idx_shape and (k is not None or tainted_index):
                        # dynamic scalar over a tainted axis: any lane
                        # could be padding → whole-value taint
                        out.taint["*"] = _join_kind(
                            out.taint.get("*"),
                            (DIRTY, None) if tainted_index else k,
                        )
            # trailing unindexed axes
            n_idx = len(indices)
            rank = len(ref_shape)
            for dim_i in range(n_idx, rank):
                k = f.taint.get(dim_i)
                if k is not None:
                    out.taint[out_ax] = k
                if dim_i in f.pos_axes:
                    out.pos_axes.add(out_ax)
                out_ax += 1
            if star is not None:
                out.taint["*"] = _join_kind(out.taint.get("*"), star)
            f = out
        return f

    def _eval_get(self, eqn, env):
        ref = eqn.invars[0]
        rf = self._ref_fact(ref)
        idxrs = self._indexers_of(eqn.params.get("tree"), eqn.invars[1:])
        env[eqn.outvars[0]] = self._index_taint(rf, idxrs, env, _shape(ref))

    def _eval_swap(self, eqn, env):
        ref, val = eqn.invars[0], eqn.invars[1]
        vf = self._fact(val, env)
        rf = self._ref_fact(ref)
        # Stores join into ref content at whole-ref granularity; axis ids
        # only survive full-shape stores (the common o_ref[...] = x case).
        ref_rank = len(_shape(ref))
        if len(_shape(val)) == ref_rank:
            self._refs[self._canon(ref)] = join(rf, vf)
        elif not vf.is_clean:
            nrf = rf.copy()
            nrf.taint["*"] = _join_kind(nrf.taint.get("*"), (DIRTY, None)
                                        if vf.worst() == DIRTY
                                        else (vf.worst(), None))
            self._refs[self._canon(ref)] = nrf
        for ov in eqn.outvars:
            env[ov] = self._ref_fact(ref).copy()

    def _eval_dma(self, eqn, env):
        """A DMA lands remote data into a local ref. If the *source index*
        is tainted (clamped padding indices re-fetching real rows, as in
        bright's row gather), the landed rows are valid data in the wrong
        lanes: DIRTY on the dst axes selected per-row."""
        try:
            import jax.tree_util as jtu

            structure = jtu.tree_unflatten(eqn.params.get("tree"),
                                           list(eqn.invars))
        except Exception:
            return
        items = list(structure) if isinstance(structure, (tuple, list)) \
            else [structure]
        refs_seen = []
        cur_ref = None
        tainted_idx = False
        for item in items:
            if _is_ref(item) and not isinstance(item, (tuple, list)):
                cur_ref = item
                refs_seen.append(item)
            elif cur_ref is not None:
                for idxr in self._walk_indexers(item):
                    for idx in getattr(idxr, "indices", ()):
                        if not isinstance(idx, (int, np.integer)) and \
                                not hasattr(idx, "size"):
                            if not self._fact(idx, env).is_clean:
                                tainted_idx = True
        dst = None
        for r in refs_seen[1:]:
            if "Semaphore" not in str(_aval_of(r)):
                dst = r
                break
        if dst is not None:
            src = refs_seen[0]
            landed = self._ref_fact(src).copy() if src in self._refs \
                else TFact.clean()
            landed.padbool = {}
            if tainted_idx:
                landed.taint["*"] = _join_kind(landed.taint.get("*"),
                                               (DIRTY, None))
            self._refs[self._canon(dst)] = join(self._ref_fact(dst),
                                                landed)

    @staticmethod
    def _walk_indexers(value):
        out = []

        def walk(obj):
            if hasattr(obj, "indices") and hasattr(obj, "shape"):
                out.append(obj)
            elif isinstance(obj, (list, tuple)):
                for item in obj:
                    walk(item)

        walk(value)
        return out

    # -- control flow --------------------------------------------------------

    def _eval_cond(self, eqn, env):
        branches = eqn.params.get("branches", ())
        operands = list(eqn.invars[1:])
        joined = None
        for closed in branches:
            body = closed.jaxpr
            if len(body.invars) != len(operands):
                continue
            inner_env = {}
            for outer, inner in zip(operands, body.invars):
                inner_env[inner] = self._fact(outer, env).copy()
                if not isinstance(outer, jex_core.Literal) and \
                        _is_ref(outer):
                    self._alias[inner] = self._canon(outer)
            self._eval_eqns(body.eqns, inner_env)
            outs = [self._fact(ov, inner_env) for ov in body.outvars]
            joined = outs if joined is None else [
                join(a, b) for a, b in zip(joined, outs)
            ]
        for i, ov in enumerate(eqn.outvars):
            env[ov] = joined[i] if joined and i < len(joined) \
                else TFact.clean()

    def _eval_while(self, eqn, env):
        params = eqn.params
        cnc = params.get("cond_nconsts", 0)
        bnc = params.get("body_nconsts", 0)
        body = params["body_jaxpr"].jaxpr
        body_consts = eqn.invars[cnc:cnc + bnc]
        init = eqn.invars[cnc + bnc:]
        carry = [self._fact(a, env) for a in init]
        for _ in range(3):
            body_env = {}
            for outer, inner in zip(body_consts, body.invars[:bnc]):
                body_env[inner] = self._fact(outer, env).copy()
                if not isinstance(outer, jex_core.Literal) and \
                        _is_ref(outer):
                    self._alias[inner] = self._canon(outer)
            for cf, inner in zip(carry, body.invars[bnc:]):
                body_env[inner] = cf.copy()
            self._eval_eqns(body.eqns, body_env)
            outs = [self._fact(ov, body_env) for ov in body.outvars]
            new = [join(a, b) for a, b in zip(carry, outs)]
            if all(a.taint == b.taint for a, b in zip(carry, new)):
                break
            carry = new
        for ov, cf in zip(eqn.outvars, carry):
            env[ov] = cf

    def _eval_scan(self, eqn, env):
        params = eqn.params
        body = params["jaxpr"].jaxpr
        nc = params.get("num_consts", 0)
        body_env = {}
        for outer, inner in zip(eqn.invars[:nc], body.invars[:nc]):
            body_env[inner] = self._fact(outer, env).copy()
            if not isinstance(outer, jex_core.Literal) and _is_ref(outer):
                self._alias[inner] = self._canon(outer)
        for inner in body.invars[nc:]:
            body_env[inner] = TFact.clean()
        for _ in range(2):
            self._eval_eqns(body.eqns, dict(body_env))
        for ov in eqn.outvars:
            env[ov] = TFact.clean()

    def _eval_call(self, eqn, env):
        for value in eqn.params.values():
            subs = []
            if isinstance(value, jex_core.ClosedJaxpr):
                subs = [value.jaxpr]
            elif isinstance(value, jex_core.Jaxpr):
                subs = [value]
            for sub in subs:
                if len(sub.invars) != len(eqn.invars):
                    continue
                inner_env = {}
                for outer, inner in zip(eqn.invars, sub.invars):
                    inner_env[inner] = self._fact(outer, env).copy()
                    if not isinstance(outer, jex_core.Literal) and \
                            _is_ref(outer):
                        self._alias[inner] = self._canon(outer)
                self._eval_eqns(sub.eqns, inner_env)
                for ov, sub_ov in zip(eqn.outvars, sub.outvars):
                    env[ov] = self._fact(sub_ov, inner_env)
                return
        for ov in eqn.outvars:
            env[ov] = TFact.clean()


def check_taint(call) -> list[TaintFinding]:
    """All padding-taint findings for one extracted KernelCall."""
    return TaintInterpreter(call).run()
