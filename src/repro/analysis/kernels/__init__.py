"""Kernel-level static verification for the repo's Pallas kernels.

Four analyses over every reachable ``pallas_call`` (grid + BlockSpecs +
operand provenance + the inner kernel jaxpr):

* :mod:`.intervals` — interval-domain bounds proof for dynamic ref
  indices and DMAs (``kernel-bounds``),
* :mod:`.race` — revisited-block accumulator writes vs grid semantics
  (``kernel-race``),
* :mod:`.taint` — ``pad_to`` padding lanes must be masked before any
  reduction consumes them (``kernel-padding``),
* :mod:`.bytes_model` — the BlockSpec-derived HBM traffic model the
  benchmarks record instead of hand-written byte formulas
  (``kernel-bytes``).

See :mod:`repro.kernels.common` for the sequential-grid-accumulator
contract these rules enforce.
"""

from repro.analysis.kernels.bytes_model import derive, derive_traffic
from repro.analysis.kernels.extract import KernelCall, Operand, find_kernel_calls
from repro.analysis.kernels.rules import (
    BytesModelRule,
    GridRaceRule,
    KernelBoundsRule,
    PaddingTaintRule,
    kernel_rules,
)

__all__ = [
    "BytesModelRule",
    "GridRaceRule",
    "KernelBoundsRule",
    "KernelCall",
    "Operand",
    "PaddingTaintRule",
    "derive",
    "derive_traffic",
    "find_kernel_calls",
    "kernel_rules",
]
