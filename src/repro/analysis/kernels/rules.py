"""The four kernel-level rules, packaged for the `repro.analysis` engine.

These plug into the same ``check()`` / registry / sweep machinery as the
jaxpr-generic rules: each extracts every ``pallas_call`` from the entry
point's jaxpr (with outer provenance — see :mod:`.extract`) and runs one
analysis over it.

=================  ========================================================
kernel-bounds      interval abstract interpretation proves every dynamic
                   ref index and DMA in bounds (:mod:`.intervals`)
kernel-race        revisited-block output writes must be declared
                   sequential accumulators; parallel-axis revisits are
                   races (:mod:`.race`)
kernel-padding     reductions must mask `pad_to` padding lanes first
                   (:mod:`.taint`)
kernel-bytes       the BlockSpec-derived HBM traffic model; optional
                   expected totals pin it, and the derived model is
                   surfaced into Report.metrics for BENCH
                   (:mod:`.bytes_model`)
=================  ========================================================

All four default ``require=True``: an entry point registered with kernel
rules that traces to *zero* pallas_calls is itself a finding — a sweep
that silently stops seeing kernels is a blind sweep.
"""

from __future__ import annotations

from repro.analysis.kernels import bytes_model, extract, intervals, race, taint
from repro.analysis.report import Finding
from repro.analysis.rules import Context, Rule


class _KernelRule(Rule):
    """Shared pallas_call extraction + the require-kernels honesty guard."""

    def __init__(self, require: bool = True):
        self.require = require

    def _calls(self, ctx: Context) -> list:
        cache = getattr(ctx, "_kernel_calls", None)
        if cache is None:
            cache = extract.find_kernel_calls(ctx.closed)
            try:
                ctx._kernel_calls = cache
            except Exception:
                pass
        return cache

    def _require_finding(self, ctx: Context) -> list[Finding]:
        if self.require:
            return [self._finding(
                ctx,
                "no pallas_call reachable from this entry point — kernel "
                "rules were requested but there is nothing to verify "
                "(wrong backend selected, or the kernel was traced away)",
            )]
        return []


class KernelBoundsRule(_KernelRule):
    """Every dynamic ref index / DMA provably in bounds (interval domain)."""

    name = "kernel-bounds"

    def check(self, ctx: Context) -> list[Finding]:
        calls = self._calls(ctx)
        if not calls:
            return self._require_finding(ctx)
        findings = []
        for call in calls:
            for f in intervals.check_bounds(call):
                findings.append(self._finding(
                    ctx, f"[{call.name}] {f.message()}",
                    kernel=call.name, ref=f.ref, dim=f.dim,
                    index=str(f.index), proven_bad=f.proven_bad,
                ))
        return findings


class GridRaceRule(_KernelRule):
    """Revisited-block output writes follow the sequential-grid contract.

    ``accumulators`` maps output io_index -> grid axes that output may
    revisit as a sequential accumulator (keyed by index, not kernel name —
    the inner functions are all literally named ``kernel``). With several
    pallas_calls under one entry point, ``per_kernel`` keys declarations
    by kernel name instead.
    """

    name = "kernel-race"

    def __init__(self, accumulators: dict | None = None,
                 per_kernel: dict | None = None, require: bool = True):
        super().__init__(require=require)
        self.accumulators = dict(accumulators or {})
        self.per_kernel = dict(per_kernel or {})

    def check(self, ctx: Context) -> list[Finding]:
        calls = self._calls(ctx)
        if not calls:
            return self._require_finding(ctx)
        findings = []
        for call in calls:
            declared = self.per_kernel.get(call.name, self.accumulators)
            fs, _classes = race.check_races(call, declared)
            for f in fs:
                findings.append(self._finding(
                    ctx, f"[{call.name}] {f.message()}",
                    kernel=call.name, output=f.io_index, origin=f.origin,
                    axis=f.axis, kind=f.kind,
                ))
        return findings

    def classes(self, ctx: Context) -> dict:
        """The raw output classification, for pinning tests."""
        return {
            call.name: race.classify_outputs(call)
            for call in self._calls(ctx)
        }


class PaddingTaintRule(_KernelRule):
    """Reductions over pad_to padding must be masked first."""

    name = "kernel-padding"

    def check(self, ctx: Context) -> list[Finding]:
        calls = self._calls(ctx)
        if not calls:
            return self._require_finding(ctx)
        findings = []
        for call in calls:
            for f in taint.check_taint(call):
                findings.append(self._finding(
                    ctx, f"[{call.name}] {f.message()}",
                    kernel=call.name, reduction=f.eqn, kind=f.kind,
                    axes=list(f.axes),
                ))
        return findings


class BytesModelRule(_KernelRule):
    """Derive the HBM traffic model; pin expected totals; export metrics.

    ``expected`` maps kernel name -> expected total bytes; a mismatch is a
    finding (the BlockSpec changed without the benchmark model following,
    or vice versa). The derived models land in ``Report.metrics`` under
    ``kernel_bytes`` via the engine's ``report_metrics`` hook, so
    ``benchmarks/static_analysis.py`` records them in BENCH_flymc.json.
    """

    name = "kernel-bytes"

    def __init__(self, expected: dict | None = None, require: bool = True):
        super().__init__(require=require)
        self.expected = dict(expected or {})

    def _models(self, ctx: Context) -> dict:
        models: dict = {}
        for call in self._calls(ctx):
            key = call.name
            i = 1
            while key in models:
                key = f"{call.name}#{i}"
                i += 1
            models[key] = bytes_model.derive(call)
        return models

    def check(self, ctx: Context) -> list[Finding]:
        calls = self._calls(ctx)
        if not calls:
            return self._require_finding(ctx)
        findings = []
        models = self._models(ctx)
        for name, model in models.items():
            for origin, entry in model["per_operand"].items():
                if entry.get("note") and "no dma_start" in entry["note"]:
                    findings.append(self._finding(
                        ctx,
                        f"[{name}] operand {origin} is memory_space=ANY "
                        "but the kernel issues no dma_start for it — "
                        "traffic is not derivable",
                        kernel=name, operand=origin,
                    ))
            exp = self.expected.get(name)
            if exp is not None and int(exp) != int(model["total"]):
                findings.append(self._finding(
                    ctx,
                    f"[{name}] derived HBM bytes {model['total']} != "
                    f"expected {exp} — BlockSpecs and the recorded traffic "
                    "model have diverged",
                    kernel=name, derived=int(model["total"]),
                    expected=int(exp),
                ))
        return findings

    def report_metrics(self, ctx: Context) -> dict:
        models = self._models(ctx)
        return {
            "kernel_bytes": {
                name: {
                    "total": m["total"],
                    "steps": m["steps"],
                    "grid": list(m["grid"]),
                    "scalar_prefetch_bytes": m["scalar_prefetch_bytes"],
                    "per_operand": {
                        origin: {
                            "bytes": e["bytes"],
                            "kind": e["kind"],
                        }
                        for origin, e in m["per_operand"].items()
                    },
                }
                for name, m in models.items()
            }
        } if models else {}


def kernel_rules(accumulators: dict | None = None,
                 expected_bytes: dict | None = None,
                 per_kernel: dict | None = None) -> list[Rule]:
    """The standard four-rule kit a kernel entry point registers with."""
    return [
        KernelBoundsRule(),
        GridRaceRule(accumulators=accumulators, per_kernel=per_kernel),
        PaddingTaintRule(),
        BytesModelRule(expected=expected_bytes),
    ]
