"""Atomic, async, elastic, *verified* checkpointing (DESIGN.md §5).

Layout per step::

    <dir>/step_000123.tmp/        # written fully, fsync'd, then renamed
        manifest.json             # step, tree structure, shapes, dtypes, crcs
        leaf_000.npy ...          # one file per leaf (logical, full arrays)
    <dir>/step_000123/
    <dir>/step_000123.old/        # transient: previous copy parked during a
                                  # same-step re-save; swept at startup

Properties:
  * **Atomic** — a checkpoint is visible only after the rename; a crash
    mid-write leaves a ``.tmp`` that restore ignores and cleanup removes.
  * **Durable** — every leaf file and the manifest are fsync'd, then the tmp
    directory and finally the parent directory, so a "committed" step
    survives power loss (write-back caches cannot reorder it away).
  * **Verified** — the manifest records a CRC-32 per leaf; :meth:`verify`
    re-reads a step and reports every problem (torn manifest, missing or
    truncated leaf, bit-flipped bytes), :meth:`restore` refuses corrupt
    steps (:class:`CheckpointCorruptError`) and — when no step is pinned —
    falls back to the newest *intact* step rather than silently loading
    damaged state.
  * **Async** — ``save`` snapshots device arrays to host then hands the disk
    write to a background thread; ``wait()`` joins before the next save (one
    outstanding write, bounded memory) and re-raises any write failure.
  * **Elastic** — leaves are stored as *logical* (unsharded) arrays with
    their tree paths; ``restore(shardings=...)`` device_puts onto ANY mesh,
    so a job restarted on a different pod count resumes bit-exact.

Crash consistency is proven, not assumed: ``_kill_hook`` lets the chaos
harness (``repro.testing.chaos``) abort the write at named points between
tmp-write and rename; the recovery sweep + verify/fallback must then land
every survivor on an intact step (pinned in ``tests/test_faults.py``).

Works for any pytree of arrays: train (params, AdamWState) and FlyMC chain
state (θ, z-partition, δ cache, rng) checkpoints identically — restart
resumes the exact Markov chain.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import zlib
from pathlib import Path
from typing import Any, Callable

import jax
import numpy as np
from jax.numpy import asarray as jnp_asarray


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification.

    ``problems`` lists the findings per step (missing/torn manifest, missing
    or truncated leaf files, CRC mismatches). Raised by ``restore`` when an
    explicitly requested step is corrupt, or when *every* on-disk step is.
    """

    def __init__(self, message: str, problems: list[str]):
        super().__init__(message + (": " + "; ".join(problems) if problems else ""))
        self.problems = problems


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _fsync_path(p: Path):
    """fsync a file or directory by path (O_RDONLY works for both on Linux)."""
    fd = os.open(p, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 keep_last: int | None = None):
        """``keep_last`` (alias ``keep``): retain the newest N completed
        checkpoints, GC'ing older ones after every save; 0 disables GC (keep
        everything). An always-on service cannot grow disk without bound, so
        startup also sweeps crash debris: stale ``step_*.tmp`` dirs, and
        half-finished same-step re-saves (a ``step_*.old`` parking dir with
        no final dir is rolled back to the final name — the previous intact
        checkpoint wins over a tmp of unknown provenance)."""
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep if keep_last is None else keep_last
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # Chaos seam: called with a named point during the write sequence;
        # raising from it simulates a crash at exactly that point.
        self._kill_hook: Callable[[str], None] | None = None
        # Steps skipped as corrupt by the most recent fallback scan.
        self.last_skipped: list[int] = []
        self._sweep_tmp()

    def _sweep_tmp(self):
        for p in sorted(self.dir.iterdir()):
            if not p.is_dir() or not p.name.startswith("step_"):
                continue
            if p.name.endswith(".old"):
                final = self.dir / p.name[:-4]
                if final.exists():
                    # Promote completed; the parked copy is redundant.
                    shutil.rmtree(p, ignore_errors=True)
                else:
                    # Crashed between parking and promote: roll the previous
                    # intact checkpoint back into place.
                    os.rename(p, final)
            elif p.name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)

    def _kill(self, point: str):
        if self._kill_hook is not None:
            self._kill_hook(point)

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, extra_metadata: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory, then write+fsync+rename on a worker
        thread. Write order (kill points in brackets): [begin] leaf files
        fsync'd one by one [leaves_written], manifest fsync'd
        [manifest_written], tmp dir fsync'd [pre_rename], any existing final
        parked to ``.old`` [parked], tmp renamed to final and the parent dir
        fsync'd [renamed], parking dir removed, GC. A crash at any point
        leaves either the old step or the new one fully intact."""
        self.wait()
        leaves = _flatten_with_paths(tree)
        host, is_key = [], []
        for p, a in leaves:
            key_leaf = hasattr(a, "dtype") and jax.dtypes.issubdtype(
                a.dtype, jax.dtypes.prng_key
            )
            if key_leaf:  # typed PRNG keys: store raw key data
                a = jax.random.key_data(a)
            host.append((p, np.asarray(jax.device_get(a))))
            is_key.append(bool(key_leaf))
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "leaves": [
                {"path": p, "file": f"leaf_{i:04d}.npy",
                 "shape": list(a.shape), "dtype": str(a.dtype),
                 "prng_key": is_key[i]}
                for i, (p, a) in enumerate(host)
            ],
            "extra": extra_metadata or {},
        }

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            old = self.dir / f"step_{step:08d}.old"
            self._kill("begin")
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, (_, a) in enumerate(host):
                fpath = tmp / f"leaf_{i:04d}.npy"
                with open(fpath, "wb") as f:
                    np.save(f, a)
                    f.flush()
                    os.fsync(f.fileno())
                # Checksum the FILE bytes (header included), read back after
                # the fsync: any later single-bit flip anywhere in the file
                # — npy magic, header padding, or array data — fails verify.
                manifest["leaves"][i]["crc32"] = zlib.crc32(
                    fpath.read_bytes()
                )
            self._kill("leaves_written")
            with open(tmp / "manifest.json", "w") as f:
                f.write(json.dumps(manifest, indent=1))
                f.flush()
                os.fsync(f.fileno())
            self._kill("manifest_written")
            _fsync_path(tmp)
            self._kill("pre_rename")
            if final.exists():
                if old.exists():
                    shutil.rmtree(old)
                os.rename(final, old)
                self._kill("parked")
            os.rename(tmp, final)
            _fsync_path(self.dir)
            self._kill("renamed")
            if old.exists():
                shutil.rmtree(old, ignore_errors=True)
            self._gc()

        if blocking:
            write()
        else:
            def runner():
                try:
                    write()
                except BaseException as e:  # surfaced by the next wait()
                    self._error = e

            self._thread = threading.Thread(target=runner, daemon=True)
            self._thread.start()

    def wait(self):
        """Join any in-flight write; re-raise its failure instead of letting
        a broken save masquerade as committed."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------------- verify

    def verify(self, step: int) -> list[str]:
        """Integrity-check one checkpoint; return a list of problems (empty
        means intact). Catches torn/unparseable manifests, missing leaf
        files, truncated arrays (np.load fails or shape differs), and any
        bit-flip (per-leaf CRC-32). Manifests written before checksums were
        recorded verify structurally only."""
        cdir = self.dir / f"step_{step:08d}"
        if not cdir.is_dir():
            return [f"step {step}: directory missing"]
        problems: list[str] = []
        try:
            manifest = json.loads((cdir / "manifest.json").read_text())
        except FileNotFoundError:
            return [f"step {step}: manifest.json missing"]
        except (json.JSONDecodeError, UnicodeDecodeError) as e:
            return [f"step {step}: manifest unreadable ({e})"]
        if manifest.get("step") != step:
            problems.append(
                f"step {step}: manifest claims step {manifest.get('step')}"
            )
        for meta in manifest.get("leaves", []):
            fpath = cdir / meta["file"]
            try:
                raw = fpath.read_bytes()
            except FileNotFoundError:
                problems.append(f"step {step}: {meta['file']} missing")
                continue
            want = meta.get("crc32")
            if want is not None and zlib.crc32(raw) != want:
                problems.append(
                    f"step {step}: {meta['file']} ({meta['path']}) crc32 "
                    f"{zlib.crc32(raw):#010x} != manifest {want:#010x}"
                )
                continue
            try:
                arr = np.load(io.BytesIO(raw))
            except Exception as e:
                problems.append(f"step {step}: {meta['file']} unreadable ({e})")
                continue
            if list(arr.shape) != list(meta["shape"]):
                problems.append(
                    f"step {step}: {meta['file']} shape {list(arr.shape)} "
                    f"!= manifest {meta['shape']}"
                )
        return problems

    def latest_intact_step(self) -> int | None:
        """Newest step that passes :meth:`verify`; corrupt steps skipped on
        the way down are recorded in ``self.last_skipped`` (newest first) so
        callers can surface the fallback instead of hiding it."""
        self.wait()
        skipped: list[int] = []
        for s in sorted(self.all_steps(), reverse=True):
            if not self.verify(s):
                self.last_skipped = skipped
                return s
            skipped.append(s)
        self.last_skipped = skipped
        return None

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name[5:]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int | None = None, verify: bool = True) -> dict:
        """Parsed manifest.json of a checkpoint (latest *intact* by default).

        Lets a caller read ``extra`` metadata — e.g. the serve layer's job
        registry — *before* it can build the restore target tree, which is
        exactly the bootstrapping order a service restart needs. With
        ``verify`` (default), an unspecified step resolves through
        :meth:`latest_intact_step`, so the manifest a restart plans from is
        the manifest restore will actually load.
        """
        self.wait()
        if step is None:
            if verify:
                step = self.latest_intact_step()
                if step is None and self.all_steps():
                    raise CheckpointCorruptError(
                        f"no intact checkpoint under {self.dir}",
                        [p for s in self.all_steps() for p in self.verify(s)],
                    )
            else:
                step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text()
        )

    def restore(self, target_tree, step: int | None = None, shardings=None,
                verify: bool = True):
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional pytree (matching target) of jax.sharding
        objects — the elastic path: arrays are placed onto the *new* mesh
        regardless of the mesh they were saved from.

        ``verify`` (default True): an explicitly requested corrupt step
        raises :class:`CheckpointCorruptError`; with ``step=None`` the
        newest *intact* step is loaded instead (skipped corrupt steps land
        in ``self.last_skipped``), and if every step is corrupt the restore
        refuses rather than silently loading damaged state.
        """
        self.wait()
        self.last_skipped = []
        if step is None:
            if verify:
                step = self.latest_intact_step()
                if step is None and self.all_steps():
                    raise CheckpointCorruptError(
                        f"no intact checkpoint under {self.dir}",
                        [p for s in self.all_steps() for p in self.verify(s)],
                    )
            else:
                step = self.latest_step()
        elif verify:
            problems = self.verify(step)
            if problems:
                raise CheckpointCorruptError(
                    f"checkpoint step {step} is corrupt", problems
                )
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        by_path = {m["path"]: m for m in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_flat = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(flat)
        )
        out = []
        for (path, ref), sh in zip(flat, shard_flat):
            key = jax.tree_util.keystr(path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            meta = by_path[key]
            raw = (cdir / meta["file"]).read_bytes()
            want = meta.get("crc32")
            if verify and want is not None and zlib.crc32(raw) != want:
                raise CheckpointCorruptError(
                    f"checkpoint step {step} is corrupt",
                    [f"step {step}: {meta['file']} ({key}) crc32 "
                     f"{zlib.crc32(raw):#010x} != manifest {want:#010x}"],
                )
            arr = np.load(io.BytesIO(raw))
            if meta.get("prng_key"):
                restored = jax.random.wrap_key_data(jnp_asarray(arr))
                out.append(restored)
                continue
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {ref.shape}"
                )
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest
