"""Atomic, async, elastic checkpointing (DESIGN.md §5 fault tolerance).

Layout per step::

    <dir>/step_000123.tmp/        # written fully, then atomically renamed
        manifest.json             # step, tree structure, shapes, dtypes
        leaf_000.npy ...          # one file per leaf (logical, full arrays)
    <dir>/step_000123/

Properties:
  * **Atomic** — a checkpoint is visible only after the rename; a crash
    mid-write leaves a ``.tmp`` that restore ignores and cleanup removes.
  * **Async** — ``save`` snapshots device arrays to host then hands the disk
    write to a background thread; ``wait()`` joins before the next save (one
    outstanding write, bounded memory).
  * **Elastic** — leaves are stored as *logical* (unsharded) arrays with
    their tree paths; ``restore(shardings=...)`` device_puts onto ANY mesh,
    so a job restarted on a different pod count resumes bit-exact (the
    multi-pod dry-run meshes and the 8-device test mesh round-trip).
  * On a real multi-host pod each host writes only its addressable shards
    (shard-per-host manifest); this single-controller implementation keeps
    the same on-disk contract with one host owning all shards.

Works for any pytree of arrays: train (params, AdamWState) and FlyMC chain
state (θ, z-partition, δ cache, rng) checkpoints identically — restart
resumes the exact Markov chain.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np
from jax.numpy import asarray as jnp_asarray


def _flatten_with_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class Checkpointer:
    def __init__(self, directory: str | os.PathLike, keep: int = 3,
                 keep_last: int | None = None):
        """``keep_last`` (alias ``keep``): retain the newest N completed
        checkpoints, GC'ing older ones after every save; 0 disables GC (keep
        everything). An always-on service cannot grow disk without bound, so
        startup also sweeps stale ``step_*.tmp`` dirs — debris a crash
        mid-write leaves behind that restore already ignores but that would
        otherwise accumulate forever."""
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep if keep_last is None else keep_last
        self._thread: threading.Thread | None = None
        self._sweep_tmp()

    def _sweep_tmp(self):
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and p.name.endswith(".tmp"):
                shutil.rmtree(p, ignore_errors=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, extra_metadata: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory, then write+rename on a worker thread."""
        self.wait()
        leaves = _flatten_with_paths(tree)
        host, is_key = [], []
        for p, a in leaves:
            key_leaf = hasattr(a, "dtype") and jax.dtypes.issubdtype(
                a.dtype, jax.dtypes.prng_key
            )
            if key_leaf:  # typed PRNG keys: store raw key data
                a = jax.random.key_data(a)
            host.append((p, np.asarray(jax.device_get(a))))
            is_key.append(bool(key_leaf))
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "treedef": str(treedef),
            "leaves": [
                {"path": p, "file": f"leaf_{i:04d}.npy",
                 "shape": list(a.shape), "dtype": str(a.dtype),
                 "prng_key": is_key[i]}
                for i, (p, a) in enumerate(host)
            ],
            "extra": extra_metadata or {},
        }

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, (_, a) in enumerate(host):
                np.save(tmp / f"leaf_{i:04d}.npy", a)
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.iterdir():
            if p.is_dir() and p.name.startswith("step_") and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name[5:]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest(self, step: int | None = None) -> dict:
        """Parsed manifest.json of a checkpoint (latest by default).

        Lets a caller read ``extra`` metadata — e.g. the serve layer's job
        registry — *before* it can build the restore target tree, which is
        exactly the bootstrapping order a service restart needs.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text()
        )

    def restore(self, target_tree, step: int | None = None, shardings=None):
        """Restore into the structure of ``target_tree``.

        ``shardings``: optional pytree (matching target) of jax.sharding
        objects — the elastic path: arrays are placed onto the *new* mesh
        regardless of the mesh they were saved from.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        cdir = self.dir / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        by_path = {m["path"]: m for m in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
        shard_flat = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None
            else [None] * len(flat)
        )
        out = []
        for (path, ref), sh in zip(flat, shard_flat):
            key = jax.tree_util.keystr(path)
            if key not in by_path:
                raise KeyError(f"checkpoint missing leaf {key}")
            meta = by_path[key]
            arr = np.load(cdir / meta["file"])
            if meta.get("prng_key"):
                restored = jax.random.wrap_key_data(jnp_asarray(arr))
                out.append(restored)
                continue
            if list(arr.shape) != list(ref.shape):
                raise ValueError(
                    f"shape mismatch for {key}: {arr.shape} vs {ref.shape}"
                )
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest
