"""Fault-tolerant checkpointing: atomic, durable, verified, elastic."""

from repro.checkpoint.checkpointer import CheckpointCorruptError, Checkpointer

__all__ = ["CheckpointCorruptError", "Checkpointer"]
