"""Fault-tolerant checkpointing: atomic, async, elastic-reshard on restore."""

from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["Checkpointer"]
