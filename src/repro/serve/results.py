"""Per-job result surfaces: status, streamed updates, finished results.

The service's read side. While a job runs, the client sees
:class:`StreamUpdate`s at chunk boundaries (committed counts plus
non-destructive collector peeks — :func:`repro.api.collectors.peek`, so
observing a job never perturbs it). When it retires, the client gets a
:class:`JobResult` holding exactly what a solo ``api.sample`` call with the
same seed would have returned in ``Trace.results`` — bitwise, that is the
service's whole exactness contract.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any


class JobStatus(enum.Enum):
    QUEUED = "queued"        # submitted, not yet packed into a group
    RUNNING = "running"      # occupying lanes in a group engine
    SUSPENDED = "suspended"  # evicted for capacity (device loss); will repack
    DONE = "done"
    CANCELLED = "cancelled"
    FAILED = "failed"        # quarantined (non-finite lane) or retries exhausted


@dataclasses.dataclass(frozen=True)
class StreamUpdate:
    """One chunk boundary's view of one running job.

    ``peeks`` maps collector names to peeked (would-be) results for the
    collectors the caller subscribed to via ``Service.submit(stream=...)``
    — plus, always, any peeks the termination policy consumed this
    boundary (they were already computed; the client may as well see the
    convergence trail).
    """

    job_id: str
    committed: int
    peeks: dict
    done: bool = False
    reason: str | None = None


@dataclasses.dataclass(frozen=True)
class JobResult:
    """A retired job. ``results`` = finalized ``{name: collector result}``,
    bitwise the solo run's ``Trace.results``. ``reason`` ∈
    {"max_samples", "converged", "cancelled", "quarantined", "failed"};
    ``committed`` counts folded samples (== ``policy.max_samples`` unless
    stopped early — convergence stops FOLDING at the next boundary, it never
    unfolds). A "quarantined" job tripped the numerical-health sentinel
    (NaN/Inf in its lane); a "failed" job's group exhausted its chunk
    retries. Both hold the last CLEAN committed prefix — the poisoned or
    failed chunk was never folded, so even a faulted job's results are
    bitwise a prefix of its fault-free solo run."""

    job_id: str
    results: dict
    committed: int
    reason: str

    def samples(self, name: str = "trace"):
        """The (num_chains, committed, ...) θ trajectory of a trace-type
        collector result, sliced to the committed prefix (an
        early-terminated job's trace buffer is sized for ``max_samples``;
        the tail past ``committed`` was never written)."""
        theta = self.results[name]["theta"]
        return theta[:, : self.committed]


class JobHandle:
    """The client's grip on a submitted job. Thin: every read delegates to
    the service's live registry, so a handle is never stale."""

    def __init__(self, service, job_id: str):
        self._service = service
        self.job_id = job_id

    @property
    def status(self) -> JobStatus:
        return self._service.status(self.job_id)

    @property
    def committed(self) -> int:
        return self._service.committed(self.job_id)

    def peek(self, name: str) -> Any:
        """Non-destructive mid-run read of one collector (running jobs)."""
        return self._service.peek(self.job_id, name)

    def result(self) -> JobResult | None:
        """The JobResult once DONE/CANCELLED; None while in flight."""
        return self._service.result(self.job_id)

    def cancel(self) -> bool:
        return self._service.cancel(self.job_id)

    def __repr__(self):
        return (f"JobHandle({self.job_id!r}, {self.status.value}, "
                f"committed={self.committed})")
