"""GroupEngine: one batching group's jobs, packed on a lane axis.

One engine owns every admitted job of one :func:`repro.serve.job.group_key`
equivalence class. A **lane** is one whole job: its K chains stacked on a
chain axis, its dataset stored once and shared by those chains. A chunk is
ONE jitted call that advances every lane ``chunk_size`` steps — jobs at
wildly different progress points, each following exactly its own solo
trajectory.

Exactness contract (pinned in ``tests/test_serve.py``): every job's
trajectory and every collector result is bitwise the solo
``api.sample(build_algorithm(job), jax.random.key(job.seed), max_samples,
num_chains=K)`` run — regardless of which neighbors share the group, when
the job joined or left, how often the group re-packed, or a neighbor's
capacity overflow. The load-bearing pieces:

  * **Lane-local compute.** The default lane backend is ``lax.map`` over
    lanes: each lane runs the SAME per-job computation a solo driver run
    compiles — an unbatched chunk scan for K = 1, the driver's
    vmap-over-K body for K > 1 — so its floating-point rounding cannot
    depend on who else is packed. This is forced, not a style choice: XLA
    codegen (and hence low-bit rounding) varies with the batched extent,
    so ``vmap`` over a slot axis of heterogeneous jobs is bitwise
    REPRODUCIBLE only at one fixed width — a non-starter under continuous
    join/leave. (Verified empirically on CPU: identical chain states
    stepped at widths 2/3/4 differ in final bits.) ``lane_backend="vmap"``
    exists for throughput on accelerators where the packed launch wins and
    bit-stability across packings is not required — same chain law, same
    key streams, low-bit rounding tied to the group width; the exactness
    tests pin the default.
  * **Per-lane key streams come from the state, not the schedule.** Each
    lane scans ``i = state.iteration[0] + arange(cs)`` and keys with
    ``fold_in(chain_key, i)`` — the driver's exact discipline at whatever
    progress point the lane is at (``FlyMCState.iteration`` is carried in
    the state, so a lane can't desync).
  * **Admission replicates ``api.sample``'s init discipline** via
    :func:`repro.serve.job.chain_rows` (same ``split``/init-key layout).
  * **Capacity is a group property.** Members run at one (capacity,
    cand_capacity); overflow doubles the group (clamped to N) and re-runs
    the chunk from the saved pre-chunk states. Trajectories are bitwise
    capacity-invariant (the repo's core exactness property), so neither
    normalizing a member up on admit nor growing the whole group on one
    member's overflow perturbs anyone.
  * **Folds are masked per lane** (:func:`repro.api.driver.
    make_collector_fold` with ``max_count``): a chunk that overshoots a
    job's ``max_samples`` contributes nothing past it, so carries equal
    the solo run's bitwise.
  * **Padding replicates lane 0.** The lane axis is padded to a power-of-2
    bucket, bounding recompiles under continuous join/leave to
    O(log max_lanes); pad lanes are copies of lane 0 with saturated fold
    counts — same key stream as lane 0, so no novel overflow, and never
    folded. (Under the ``map`` backend pad lanes do cost sequential
    compute; the bucket trades that for compile time, which dominates.)

Chunk executables, folds and resizers are cached in
:func:`repro.api.driver.cached_jit` keyed on ``(group_key, capacity,
cand_capacity, bucket, chunk_size)`` — the group key is a pure value, so an
engine torn down (device loss, service restart) and rebuilt re-enters a
warm cache instead of recompiling.

Host-side state is "lanes": pytrees with a leading lane axis, typed PRNG
leaves held as raw ``key_data`` (uint32) so gather/concat/checkpoint are
plain array ops; keys are wrapped on the way into the jitted chunk.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.api import collectors as collectors_lib
from repro.api import driver
from repro.core import flymc
from repro.serve import job as job_lib


def bucket_size(n: int) -> int:
    """Lane-axis padding: the next power of two ≥ n (≥ 1)."""
    b = 1
    while b < n:
        b *= 2
    return b


def _cat_lanes(trees: list):
    return jax.tree.map(lambda *ls: jnp.concatenate(ls, axis=0), *trees)


def _take_lanes(tree, idx):
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda l: jnp.take(l, idx, axis=0), tree)


def _raw(state):
    """FlyMCState with the typed rng leaf lowered to raw key_data."""
    return state._replace(rng=jax.random.key_data(state.rng))


def _wrap(state):
    return state._replace(rng=jax.random.wrap_key_data(state.rng))


class GroupEngine:
    """The packed lanes of one group key. See the module docstring.

    ``template`` is any member job: it supplies the spec construction and
    the collector instances (the group key pins both, so every member
    yields the identical spec and collector configuration — instances only
    matter through their config). Lane pytrees:

    ==========  =====================================================
    states      FlyMCState, leaves ``(L, K, ...)``, rng as key_data
    keys        ``(L, K, *keyshape)`` uint32 chain-key data
    data        GLMData, leaves ``(L, N, ...)`` — one copy per job
    stats       CollapsedStats, leaves ``(L, ...)``
    carries     {collector: leaves ``(L, K, ...)``}
    counts      ``(L,)`` int32 folded (committed) samples per lane
    ==========  =====================================================
    """

    def __init__(self, template: job_lib.Job, capacity: int | None = None,
                 cand_capacity: int | None = None,
                 lane_backend: str = "map"):
        if lane_backend not in ("map", "vmap"):
            raise ValueError(f"unknown lane_backend {lane_backend!r}")
        self.group_key = job_lib.group_key(template)
        self.template = template
        self.num_chains = template.num_chains
        self.max_samples = template.policy.max_samples
        self.lane_backend = lane_backend
        self.colls = collectors_lib.validate_collectors(template.collectors)
        alg = job_lib.build_algorithm(
            template,
            capacity=template.capacity if capacity is None else capacity,
            cand_capacity=(template.cand_capacity if cand_capacity is None
                           else cand_capacity),
        )
        self._spec = alg.spec  # capacities already clamped to N
        self._n = template.data.x.shape[0]
        self._members: list[str] = []  # lane order == membership order
        self._jobs: dict[str, job_lib.Job] = {}
        self._lanes: dict | None = None  # the lane pytrees, padded to bucket
        self._quarantined: list[str] = []  # sentinel hits, pending eviction

    # ------------------------------------------------------------ geometry

    @property
    def capacity(self) -> int:
        return self._spec.capacity

    @property
    def cand_capacity(self) -> int:
        return self._spec.cand_capacity

    @property
    def num_slots(self) -> int:
        """Budgeted chain slots (lanes × chains); padding is not billed."""
        return len(self._members) * self.num_chains

    @property
    def job_ids(self) -> list[str]:
        return list(self._members)

    def job(self, job_id: str) -> job_lib.Job:
        return self._jobs[job_id]

    def _lane_of(self, job_id: str) -> int:
        try:
            return self._members.index(job_id)
        except ValueError:
            raise KeyError(f"job {job_id!r} is not in this group") from None

    # ------------------------------------------------------------- packing

    def _repack(self, real: dict):
        """Install real lanes, padded to the bucket with copies of lane 0
        whose counts saturate at ``max_samples`` (never folded)."""
        n_real = real["counts"].shape[0]
        pad = bucket_size(n_real) - n_real
        if pad:
            zeros = jnp.zeros((pad,), jnp.int32)
            real = {
                name: (jnp.concatenate(
                    [t, jnp.full((pad,), self.max_samples, jnp.int32)])
                    if name == "counts"
                    else _cat_lanes([t, _take_lanes(t, zeros)]))
                for name, t in real.items()
            }
        self._lanes = real

    def _real_lanes(self) -> dict:
        n = len(self._members)
        return {k: jax.tree.map(lambda l: l[:n], t)
                for k, t in self._lanes.items()}

    # ------------------------------------------------------------ capacity

    def _grow_spec(self):
        """Double the group capacities (clamped to N) — spec only."""
        alg = job_lib.build_algorithm(
            self.template,
            capacity=min(2 * self.capacity, self._n),
            cand_capacity=min(2 * self.cand_capacity, self._n),
        )
        self._spec = alg.spec

    def _resize_fn(self):
        """Lane×chain-batched ``flymc.resize_state`` at the current
        capacity: zero likelihood queries, bitwise-identical chain law."""
        spec = self._spec
        return driver.cached_jit(
            ("serve_resize", self.group_key, spec.capacity),
            lambda: jax.jit(jax.vmap(jax.vmap(
                functools.partial(flymc.resize_state, spec)
            ))),
        )

    def _resize_states(self, states):
        return _raw(self._resize_fn()(_wrap(states)))

    def _grow(self):
        self._grow_spec()
        if self._lanes is not None:
            self._lanes["states"] = self._resize_states(self._lanes["states"])

    # ----------------------------------------------------------- admission

    def build_lane(self, job: job_lib.Job) -> tuple[dict, bool]:
        """One fresh lane for ``job`` at the CURRENT group capacity (leading
        axis 1), plus whether its initial bright set overflowed. The single
        encoding of a lane's structure: admission runs it under the grow
        loop (:meth:`_init_lane`); service restore runs it once on a
        placeholder job purely as the checkpoint-restore target skeleton
        (every value is then overwritten by ``Checkpointer.restore``)."""
        alg = job_lib.build_algorithm(
            job, capacity=self.capacity, cand_capacity=self.cand_capacity
        )
        states, chain_keys = job_lib.chain_rows(job, alg)
        over = bool(jax.device_get(
            jnp.any(jax.vmap(alg.init_overflow)(states))
        ))
        single = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), states
        )
        pos_s, stats_s = alg.output_structs(single)
        k = job.num_chains
        carries = {
            name: jax.tree.map(
                lambda l: jnp.broadcast_to(l, (k,) + l.shape),
                col.init(self.max_samples, pos_s, stats_s),
            )
            for name, col in self.colls.items()
        }
        model = job_lib.build_model(job)
        lane = lambda t: jax.tree.map(lambda l: jnp.asarray(l)[None], t)
        return {
            "states": lane(_raw(states)),
            "keys": jax.random.key_data(chain_keys)[None],
            "data": lane(model.data),
            "stats": lane(model.stats),
            "carries": lane(carries),
            "counts": jnp.zeros((1,), jnp.int32),
        }, over

    def _init_lane(self, job: job_lib.Job) -> dict:
        """A fresh job's lane, grown until the initial bright set fits —
        the driver's init-overflow loop lifted to group scope."""
        while True:
            lane, over = self.build_lane(job)
            if not over:
                return lane
            if self.capacity >= self._n and self.cand_capacity >= self._n:
                raise RuntimeError("initial bright set exceeds data size")
            self._grow()

    def admit(self, job: job_lib.Job):
        """Join a fresh job at the next chunk boundary."""
        if job_lib.group_key(job) != self.group_key:
            raise ValueError(f"job {job.job_id!r} does not match this group")
        if job.job_id in self._jobs:
            raise ValueError(f"job {job.job_id!r} already admitted")
        self._append(job, self._init_lane(job))

    def admit_restored(self, job: job_lib.Job, lane: dict):
        """Re-join a job from checkpointed/suspended lane trees (leading
        axis 1, states possibly at a different saved capacity): the states
        carry their iteration counters and the keys are the originals, so
        the per-lane key stream continues exactly where it left off."""
        if job.job_id in self._jobs:
            raise ValueError(f"job {job.job_id!r} already admitted")
        saved_cap = lane["states"].sampler.aux.shape[-1]
        if saved_cap > self.capacity:
            # Normalize the GROUP up — shrinking a state would lose aux rows.
            while self.capacity < min(saved_cap, self._n):
                self._grow()
        if saved_cap != self.capacity:
            lane = dict(lane)
            lane["states"] = self._resize_states(lane["states"])
        self._append(job, lane)

    def _append(self, job: job_lib.Job, lane: dict):
        if self._lanes is None:
            merged = lane
        else:
            real = self._real_lanes()
            merged = {
                name: (jnp.concatenate([real[name], lane[name]])
                       if name == "counts"
                       else _cat_lanes([real[name], lane[name]]))
                for name in real
            }
        self._members.append(job.job_id)
        self._jobs[job.job_id] = job
        self._repack(merged)

    def lane_of(self, job_id: str) -> dict:
        """A job's lane trees (leading axis 1), without removing it —
        the checkpoint export. Plain gathers of live device arrays."""
        i = self._lane_of(job_id)
        return {k: _take_lanes(t, [i]) for k, t in self._real_lanes().items()}

    def evict(self, job_id: str) -> dict:
        """Remove a job at a chunk boundary; returns its lane trees
        (leading axis 1) for result finalization, suspension, or
        checkpointing."""
        i = self._lane_of(job_id)
        lane = self.lane_of(job_id)
        keep = [j for j in range(len(self._members)) if j != i]
        self._members.pop(i)
        del self._jobs[job_id]
        if not self._members:
            self._lanes = None
        else:
            self._repack(
                {k: _take_lanes(t, keep) for k, t in self._lanes.items()}
            )
        return lane

    # ------------------------------------------------------------ the chunk

    def _map_lanes(self, fn, args):
        if self.lane_backend == "map":
            return jax.lax.map(fn, args)
        return jax.vmap(fn)(args)

    def _build_chunk(self, cs: int):
        """One jitted group chunk: every lane advances ``cs`` steps.

        The per-lane body reproduces :func:`repro.api.driver._make_scan_fn`
        exactly — unbatched for K = 1, the chain-batched step for K > 1,
        per-iteration keys ``fold_in(chain_key, start + i)`` — with the
        lane's own (data, stats) in place of the solo closure's.
        """
        spec = self._spec
        k = self.num_chains

        def per_lane(args):
            st_raw, keys_raw, data, stats = args
            step1 = lambda key, s: flymc.flymc_step(
                spec, data, stats, s._replace(rng=key)
            )
            st = _wrap(st_raw)
            if k == 1:
                st1 = jax.tree.map(lambda l: l[0], st)
                key = jax.random.wrap_key_data(keys_raw)[0]

                def body(s, i):
                    new, info = step1(jax.random.fold_in(key, i), s)
                    return new, (new.sampler.theta, info)

                iters = st1.iteration + jnp.arange(cs, dtype=jnp.int32)
                fin, (pos, infos) = jax.lax.scan(body, st1, iters)
                fin = jax.tree.map(lambda l: l[None], fin)
                pos = pos[:, None]
                infos = jax.tree.map(lambda l: l[:, None], infos)
            else:
                keys = jax.random.wrap_key_data(keys_raw)
                step = jax.vmap(step1)
                fold_keys = jax.vmap(jax.random.fold_in, in_axes=(0, None))
                position = jax.vmap(lambda s: s.sampler.theta)

                def body(s, i):
                    new, info = step(fold_keys(keys, i), s)
                    return new, (position(new), info)

                iters = st.iteration[0] + jnp.arange(cs, dtype=jnp.int32)
                fin, (pos, infos) = jax.lax.scan(body, st, iters)
            return _raw(fin), pos, infos

        def chunk(states_raw, key_rows, data, stats):
            fin, pos, infos = self._map_lanes(
                per_lane, (states_raw, key_rows, data, stats)
            )
            # Numerical-health sentinel, per lane. θ/log-joint alone are not
            # enough: a NaN'd dataset makes every proposal log-ratio compare
            # False — the lane keeps "running" with finite θ while its
            # trajectory silently leaves its law — so the δ cache, sampler
            # log-prob and the lane's own float data leaves are checked too.
            # Poison is caught at the very next boundary and the chunk is
            # never folded for that lane (quarantine in run_chunk).
            healthy = driver.finite_lanes(
                [pos, infos.joint_lp, fin.delta_full, fin.sampler.lp,
                 fin.sampler.theta]
                + [l for l in jax.tree.leaves(data)
                   if jnp.issubdtype(l.dtype, jnp.floating)]
            )
            # A poisoned lane must not drive capacity growth either: NaN
            # comparisons can assert overflow forever, and growth is a
            # group-wide re-run. Only healthy lanes' overflow counts.
            overflow = jnp.any(infos.overflow & healthy[:, None, None])
            return fin, pos, infos, overflow, healthy

        return jax.jit(chunk)

    def _build_fold(self):
        """Lane-mapped committed-chunk fold: per lane, exactly the driver's
        :func:`repro.api.driver.make_collector_fold` masked at
        ``max_samples`` (vmap-over-K updates for K > 1, unbatched for
        K = 1) — the one shared encoding of the collector fold."""
        k = self.num_chains
        lane_fold = driver.make_collector_fold(
            self.colls, multi=(k > 1), max_count=self.max_samples
        )

        def per_lane(args):
            carries, count, pos, infos = args
            if k == 1:
                cars, cnt = lane_fold(
                    jax.tree.map(lambda l: l[0], carries),
                    count, pos[:, 0],
                    jax.tree.map(lambda l: l[:, 0], infos),
                )
                return jax.tree.map(lambda l: l[None], cars), cnt
            cars, cnts = lane_fold(
                carries, jnp.full((k,), count, jnp.int32), pos, infos
            )
            return cars, cnts[0]

        def fold(carries, counts, pos, infos):
            return self._map_lanes(per_lane, (carries, counts, pos, infos))

        return jax.jit(fold)

    def run_chunk(self, chunk_size: int) -> int:
        """Advance every lane ``chunk_size`` steps and fold the committed
        outputs (masked at ``max_samples``). Returns the number of
        overflow re-runs (0 on the happy path) — the scheduler's
        congestion signal.

        Transactional at the host level: the lane trees are reassigned only
        after the chunk committed, so a raise anywhere in here leaves the
        engine at the previous boundary and the supervised service path can
        simply re-run the chunk (identical keys → bitwise the same chunk).

        **Quarantine.** Lanes the chunk sentinel marks unhealthy are NOT
        folded and NOT advanced: the masked fold is fed saturated counts for
        them (its ``active`` select then passes their carries through
        bitwise — the same mechanism that protects pad lanes — which also
        sidesteps the carry donation: the blend happens inside the fold's
        output, never by re-reading a donated buffer), their counts and
        states are restored from the pre-chunk values, and their job_ids
        land in :meth:`take_quarantined` for the service to evict. Healthy
        neighbors commit this chunk exactly as if the sick lane had never
        been admitted — lane compute is lane-local under the ``map``
        backend, so nothing of a neighbor's trajectory ever depended on it.
        """
        if self._lanes is None:
            return 0
        cs = int(chunk_size)
        bucket = self._lanes["counts"].shape[0]
        lanes = self._lanes
        reruns = 0
        cache_key = lambda: ("serve_scan", self.group_key, self.lane_backend,
                             self.capacity, self.cand_capacity, bucket, cs)
        scan = driver.cached_jit(cache_key(), lambda: self._build_chunk(cs))
        prev = lanes["states"]
        final, pos, infos, overflow, healthy = scan(
            prev, lanes["keys"], lanes["data"], lanes["stats"]
        )
        # The chunk's one host sync fetches overflow and lane health together.
        over, ok = jax.device_get((overflow, healthy))
        while bool(over):
            reruns += 1
            if self.capacity >= self._n and self.cand_capacity >= self._n:
                raise RuntimeError(
                    "overflow at full-data capacity — sampler bug"
                )
            # Grow and re-run THIS chunk from the saved pre-chunk states:
            # identical keys (they derive from the states' iteration
            # counters), bigger buffers — bitwise the infinite-capacity
            # trajectory, exactly the driver's overflow protocol.
            self._grow_spec()
            prev = self._resize_states(prev)
            scan = driver.cached_jit(cache_key(),
                                     lambda: self._build_chunk(cs))
            final, pos, infos, overflow, healthy = scan(
                prev, lanes["keys"], lanes["data"], lanes["stats"]
            )
            over, ok = jax.device_get((overflow, healthy))
        fold = driver.cached_jit(
            ("serve_fold", self.group_key, self.lane_backend),
            self._build_fold,
        )
        sick = [self._members[i] for i in range(len(self._members))
                if not bool(ok[i])]
        if not sick:
            new_carries, new_counts = fold(
                lanes["carries"], lanes["counts"], pos, infos
            )
            lanes["carries"], lanes["counts"] = new_carries, new_counts
            lanes["states"] = final
        else:
            lane_ok = jnp.asarray(ok)
            old_counts = lanes["counts"]
            counts_in = jnp.where(
                lane_ok, old_counts, jnp.int32(self.max_samples)
            )
            new_carries, folded_counts = fold(
                lanes["carries"], counts_in, pos, infos
            )
            blend = lambda new, old: jnp.where(
                lane_ok.reshape((-1,) + (1,) * (new.ndim - 1)), new, old
            )
            lanes["carries"] = new_carries
            lanes["counts"] = jnp.where(lane_ok, folded_counts, old_counts)
            lanes["states"] = jax.tree.map(blend, final, prev)
            self._quarantined.extend(sick)
        return reruns

    def take_quarantined(self) -> list[str]:
        """Job ids quarantined by the last chunk's health sentinel (their
        lanes hold the pre-chunk committed state); clears the list. The
        service evicts and retires them as FAILED at this boundary."""
        out, self._quarantined = self._quarantined, []
        return out

    # ------------------------------------------------------------- readouts

    def committed(self, job_id: str) -> int:
        """Folded samples for this job (chains advance in lockstep)."""
        i = self._lane_of(job_id)
        return int(jax.device_get(self._lanes["counts"][i]))

    def peek(self, job_id: str, name: str):
        """Stream a collector's would-be result for one job, mid-run,
        without touching its carry (:func:`repro.api.collectors.peek`).
        The carry is handed over with its leading (K,) chain axis — the
        same contract as ``finalize``."""
        i = self._lane_of(job_id)
        carry = jax.tree.map(lambda l: l[i], self._carries_tree()[name])
        return collectors_lib.peek(self.colls[name], carry)

    def _carries_tree(self):
        return self._lanes["carries"]

    def finalize_lane(self, lane: dict) -> dict:
        """{name: finalized result} for an evicted lane (leading chain
        axis, exactly what a solo ``Trace.results`` holds)."""
        return {
            name: col.finalize(
                jax.tree.map(lambda l: l[0], lane["carries"][name])
            )
            for name, col in self.colls.items()
        }
