"""Job: one tenant's posterior-sampling request, and what makes jobs batchable.

A :class:`Job` is everything the service needs to run one FlyMC posterior:
a dataset, a GLM family with its hyperparameters, the FlyMC spec knobs
(kernel, buffer capacities, backends), a seed, a convergence
:class:`TerminationPolicy`, and the requested collectors. Jobs are pure
descriptions — :func:`build_algorithm` turns one into the same
:class:`~repro.api.algorithm.SamplingAlgorithm` a direct
:func:`repro.api.sample` caller would get, which is what makes the service's
exactness contract checkable: a job's trajectory in a packed batch must be
bitwise the trajectory of ``api.sample`` run alone with the same seed.

:func:`group_key` decides which jobs may share a batching group (one slot =
one chain on the chain axis of the PR-5 batched megakernels). The key pins
every *static* property of the traced step — family and its
hyperparameters, (N, D), θ-kernel, q_db, backends, adaptation schedule,
trace length, collector signature — so one compiled chunk executable serves
every member. Deliberately NOT in the key:

  * **capacity / cand_capacity** — trajectories are bitwise
    capacity-invariant (the repo's core exactness property), so the engine
    normalizes members up to one group capacity and grows it on overflow
    without fragmenting groups.
  * **step_size** — the step size lives in the chain state (``log_step``),
    not the trace, so jobs with different step sizes batch together.
  * **the dataset values** — each lane carries its own dataset as a traced
    operand, stacked along the lane axis. Only the shape (N, D) is static.

``num_chains`` IS in the key: a group lane is one whole job (its K chains
stepped by the same vmap-over-K body a solo ``api.sample(num_chains=K)``
run uses), because XLA's low-bit rounding depends on the batched extent —
a K-chain computation is only bitwise reproducible by the identical
K-chain computation, so jobs with different chain counts cannot share a
lane shape (see ``repro.serve.engine``).

:func:`chain_rows` replicates ``api.sample``'s key discipline exactly
(``split(key) → (k_init, k_steps)``, per-chain ``split`` for multi-chain)
so the per-iteration key stream — ``fold_in(chain_key, iteration)`` — is
identical in and out of the service.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.api import collectors as collectors_lib
from repro.api.algorithm import SamplingAlgorithm, firefly
from repro.core.bounds import GLMData


@dataclasses.dataclass(frozen=True)
class TerminationPolicy:
    """When a job's chains stop sampling (checked at chunk boundaries).

    A job retires when ``num_samples >= max_samples`` (always), or — once
    ``min_samples`` have committed — when every enabled convergence
    criterion holds: peeked split-R̂ ``<= target_rhat`` (requires an "rhat"
    collector) and peeked batch-means ESS ``>= min_ess`` (requires an "ess"
    collector). ``check_every`` throttles convergence peeks to every k-th
    chunk; the max_samples stop is checked every chunk regardless.
    """

    max_samples: int = 2000
    min_samples: int = 0
    target_rhat: float | None = None
    min_ess: float | None = None
    check_every: int = 1

    def __post_init__(self):
        if self.max_samples < 1:
            raise ValueError("max_samples must be >= 1")
        if self.check_every < 1:
            raise ValueError("check_every must be >= 1")


def default_collectors() -> dict:
    """The service default: full trace plus streamed R̂ (termination food)."""
    return {"trace": collectors_lib.FullTrace(), "rhat": collectors_lib.RHat()}


@dataclasses.dataclass(eq=False)
class Job:
    """One posterior-sampling request. ``family`` ∈ {logistic, softmax,
    robust}; the family hyperparameters below it apply per family (the rest
    are ignored). ``collectors`` defaults to :func:`default_collectors`;
    instances are sized by the engine (trace buffers get the group's
    ``max_samples`` plus one chunk of slack, so a terminating chunk may
    overshoot without clipping)."""

    job_id: str
    family: str
    data: GLMData
    seed: int = 0
    num_chains: int = 1
    init_position: Any = None
    # family hyperparameters
    prior_scale: float = 1.0
    xi: float = 1.5          # logistic: bound tangency
    n_classes: int = 3       # softmax
    nu: float = 4.0          # robust: Student-t dof
    sigma: float = 1.0       # robust: noise scale
    # FlyMC spec knobs
    kernel: str = "rwmh"
    step_size: float = 0.1
    q_db: float = 0.01
    mode: str = "implicit"
    resample_fraction: float = 0.1
    capacity: int = 256
    cand_capacity: int = 256
    backend: str = "jnp"
    z_backend: str = "jnp"
    adapt_target: Any = None
    num_warmup: int = 1000
    # service-level
    policy: TerminationPolicy = dataclasses.field(default_factory=TerminationPolicy)
    collectors: dict | None = None

    def __post_init__(self):
        if self.family not in ("logistic", "softmax", "robust"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.num_chains < 1:
            raise ValueError("num_chains must be >= 1")
        if self.collectors is None:
            self.collectors = default_collectors()
        self.collectors = collectors_lib.validate_collectors(self.collectors)
        if self.policy.target_rhat is not None and "rhat" not in self.collectors:
            raise ValueError(
                f"job {self.job_id!r}: target_rhat termination needs an "
                f"'rhat' collector (e.g. api.RHat())"
            )
        if self.policy.min_ess is not None and "ess" not in self.collectors:
            raise ValueError(
                f"job {self.job_id!r}: min_ess termination needs an 'ess' "
                f"collector (e.g. api.BatchMeansESS())"
            )


def build_model(job: Job):
    """The job's GLMModel — same constructor path a direct user takes."""
    from repro.models.bayes_glm import GLMModel

    if job.family == "logistic":
        return GLMModel.logistic(job.data, prior_scale=job.prior_scale,
                                 xi=job.xi)
    if job.family == "softmax":
        return GLMModel.softmax(job.data, n_classes=job.n_classes,
                                prior_scale=job.prior_scale)
    return GLMModel.robust(job.data, nu=job.nu, sigma=job.sigma,
                           prior_scale=job.prior_scale)


def build_algorithm(
    job: Job, capacity: int | None = None, cand_capacity: int | None = None
) -> SamplingAlgorithm:
    """The job as a SamplingAlgorithm — bitwise the solo-run construction.

    ``capacity``/``cand_capacity`` override the job's request (the engine
    runs every group member at the group capacity; trajectories don't care).
    """
    return firefly(
        build_model(job),
        kernel=job.kernel,
        capacity=job.capacity if capacity is None else capacity,
        cand_capacity=(job.cand_capacity if cand_capacity is None
                       else cand_capacity),
        q_db=job.q_db,
        mode=job.mode,
        resample_fraction=job.resample_fraction,
        step_size=job.step_size,
        adapt_target=job.adapt_target,
        num_warmup=job.num_warmup,
        backend=job.backend,
        z_backend=job.z_backend,
    )


def collector_sig(colls: dict) -> tuple:
    """Hashable signature of a collector set: type + static config per name.

    Array-valued fields (e.g. ``PosteriorPredictive.x_eval``) contribute
    shape/dtype only — two jobs whose collectors differ solely in array
    *values* still share a compiled fold (the arrays ride in the carry or
    the closure; different values never change the jaxpr... but they DO
    change closure-captured constants, so such collectors also fragment on
    ``id``). Sorted by name so dict order never splits a group.
    """
    out = []
    for name in sorted(colls):
        col = colls[name]
        fields = []
        if dataclasses.is_dataclass(col):
            for f in dataclasses.fields(col):
                v = getattr(col, f.name)
                if hasattr(v, "shape") and hasattr(v, "dtype"):
                    fields.append((f.name, ("array", tuple(v.shape),
                                            str(v.dtype), id(v))))
                elif callable(v):
                    fields.append((f.name, ("fn", id(v))))
                else:
                    fields.append((f.name, v))
        out.append((name, type(col).__name__, tuple(fields)))
    return tuple(out)


def group_key(job: Job) -> tuple:
    """The batching-group key: jobs with equal keys share one engine (and
    its compiled chunk executables). See the module docstring for what is
    deliberately excluded."""
    n, d = job.data.x.shape
    fam = (job.family,)
    if job.family == "logistic":
        fam += (job.prior_scale, job.xi)
    elif job.family == "softmax":
        fam += (job.prior_scale, job.n_classes)
    else:
        fam += (job.prior_scale, job.nu, job.sigma)
    return (
        fam, n, d, job.num_chains,
        job.kernel, job.q_db, job.mode, job.resample_fraction,
        job.backend, job.z_backend, job.adapt_target, job.num_warmup,
        job.policy.max_samples,
        collector_sig(job.collectors),
    )


def chain_rows(job: Job, alg: SamplingAlgorithm):
    """Per-chain initial states and chain keys, ``api.sample``'s discipline.

    Returns ``(states, chain_keys)`` with a leading ``(num_chains,)`` axis
    on both — single-chain jobs replicate the solo path's unsplit
    ``k_steps`` as a length-1 axis (``fold_in`` of the same key by the same
    iteration gives the same per-step keys either way).
    """
    key = jax.random.key(job.seed)
    k_init, k_steps = jax.random.split(key)
    position = (job.init_position if job.init_position is not None
                else alg.default_position)
    if position is None:
        raise ValueError(f"job {job.job_id!r} has no initial position")
    if job.num_chains == 1:
        states = jax.tree.map(lambda l: l[None],
                              jax.jit(alg.init)(k_init, position))
        chain_keys = k_steps[None]
    else:
        init_keys = jax.random.split(k_init, job.num_chains)
        positions = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (job.num_chains,) + jnp.shape(l)),
            position,
        )
        states = jax.jit(alg.batched_init())(init_keys, positions)
        chain_keys = jax.random.split(k_steps, job.num_chains)
    return states, chain_keys
