"""Continuous-batching scheduler: jobs → group engines, under a slot budget.

The scheduler owns the packing decisions and nothing else — engines do the
math, the service does the policy. Its invariants:

  * **One engine per live group key** (:func:`repro.serve.job.group_key`);
    an engine exists exactly while it has members, and its compiled chunk
    executables outlive it in the driver's jit cache (the key is a pure
    value), so churn is cheap.
  * **A slot budget in chains.** A job costs ``num_chains`` slots
    (:func:`repro.launch.elastic.plan_chain_slots` converts devices to
    slots); lane padding is compile-time geometry, not billed occupancy.
  * **FIFO with skip.** Admission scans the queue in arrival order and
    admits every job that fits the remaining budget — a wide job at the
    head does not block narrow jobs behind it (head-of-line skip), but
    arrival order still decides ties, so nothing starves: the head is
    always first in line for freed slots.
  * **Suspended jobs outrank the queue.** A job evicted for capacity
    (device loss) holds committed work; on any freed slots it is repacked
    before fresh admissions, via :meth:`GroupEngine.admit_restored` — its
    lanes carry their iteration counters, so it resumes its exact solo
    trajectory (bitwise, pinned in tests).

Packing never affects results — that is the engines' exactness contract —
so the scheduler is free to be greedy.
"""

from __future__ import annotations

from repro.serve import job as job_lib
from repro.serve.engine import GroupEngine


class Scheduler:
    def __init__(self, slot_budget: int, lane_backend: str = "map"):
        if slot_budget < 1:
            raise ValueError("slot_budget must be >= 1")
        self.slot_budget = slot_budget
        self.lane_backend = lane_backend
        self.engines: dict[tuple, GroupEngine] = {}  # group_key -> engine
        self.queue: list[job_lib.Job] = []           # arrival order
        # job_id -> (job, lane trees): capacity-evicted, awaiting repack
        self.suspended: dict[str, tuple] = {}

    # ------------------------------------------------------------- accounting

    @property
    def slots_used(self) -> int:
        return sum(e.num_slots for e in self.engines.values())

    @property
    def slots_free(self) -> int:
        return self.slot_budget - self.slots_used

    def engine_of(self, job_id: str) -> GroupEngine | None:
        for eng in self.engines.values():
            if job_id in eng.job_ids:
                return eng
        return None

    # -------------------------------------------------------------- admission

    def enqueue(self, job: job_lib.Job):
        self.queue.append(job)

    def _engine_for(self, job: job_lib.Job,
                    capacity: int | None = None,
                    cand_capacity: int | None = None) -> GroupEngine:
        key = job_lib.group_key(job)
        eng = self.engines.get(key)
        if eng is None:
            eng = self.engines[key] = GroupEngine(
                job, capacity=capacity, cand_capacity=cand_capacity,
                lane_backend=self.lane_backend,
            )
        return eng

    def admit_pending(self) -> list[str]:
        """One admission round: suspended first, then the queue, FIFO with
        skip. Returns the admitted job ids (their groups repack at the next
        chunk boundary — callers run this BETWEEN chunks only)."""
        admitted = []
        for job_id in list(self.suspended):
            job, lane, caps = self.suspended[job_id]
            if job.num_chains > self.slots_free:
                continue
            eng = self._engine_for(job, capacity=caps[0],
                                   cand_capacity=caps[1])
            eng.admit_restored(job, lane)
            del self.suspended[job_id]
            admitted.append(job_id)
        remaining = []
        for job in self.queue:
            if job.num_chains <= self.slots_free:
                self._engine_for(job).admit(job)
                admitted.append(job.job_id)
            else:
                remaining.append(job)
        self.queue = remaining
        return admitted

    # --------------------------------------------------------------- eviction

    def evict(self, job_id: str) -> tuple[GroupEngine, dict]:
        """Remove a finished/cancelled job; returns (engine, lane trees).
        Drops the engine when its last member leaves."""
        eng = self.engine_of(job_id)
        if eng is None:
            raise KeyError(f"job {job_id!r} is not running")
        lane = eng.evict(job_id)
        if not eng.job_ids:
            del self.engines[eng.group_key]
        return eng, lane

    def suspend(self, job_id: str):
        """Evict a RUNNING job but keep its lanes for later repack — the
        capacity-pressure path. Suspension order is the reverse of a
        group's membership (newest member first), so the longest-running
        work is the last to yield its slots."""
        eng = self.engine_of(job_id)
        job = eng.job(job_id)
        caps = (eng.capacity, eng.cand_capacity)
        _, lane = self.evict(job_id)
        self.suspended[job_id] = (job, lane, caps)

    def shrink_to_budget(self, slot_budget: int) -> list[str]:
        """Apply a new (smaller or larger) budget; suspend newest-first
        until occupancy fits. Returns the suspended job ids. The caller
        (service) checkpoints BEFORE shrinking — suspension itself is
        lossless, but the checkpoint is what survives a process death."""
        self.slot_budget = int(slot_budget)
        out = []
        while self.slots_used > self.slot_budget:
            eng = max(self.engines.values(), key=lambda e: e.num_slots)
            victim = eng.job_ids[-1]  # newest member of the widest group
            self.suspend(victim)
            out.append(victim)
        return out
