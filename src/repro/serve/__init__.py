"""repro.serve — multi-tenant posterior sampling as a service.

Jobs (dataset + GLM family + FlyMC spec + convergence policy) arrive in a
queue; the scheduler packs compatible jobs onto the lane axis of shared
group engines (continuous batching: join/leave at chunk boundaries);
results stream per job through non-destructive collector peeks; R̂/ESS
policies auto-terminate; checkpoints restore bit-exact.

The contract that makes multi-tenancy safe: every job's trajectory and
every result is bitwise what a solo ``api.sample`` call with the same seed
produces, regardless of packing, neighbors, re-packs, or restore — see
``repro.serve.engine`` for how.

    svc = Service(chunk_size=64)
    h = svc.submit(Job(job_id="a", family="logistic", data=data, seed=0,
                       policy=TerminationPolicy(max_samples=2000,
                                                target_rhat=1.01)))
    results = svc.run()          # {job_id: JobResult}
    theta = results["a"].samples()
"""

from repro.serve.engine import GroupEngine
from repro.serve.faults import FaultEvent, RetryPolicy
from repro.serve.job import (
    Job,
    TerminationPolicy,
    build_algorithm,
    default_collectors,
    group_key,
)
from repro.serve.results import JobHandle, JobResult, JobStatus, StreamUpdate
from repro.serve.scheduler import Scheduler
from repro.serve.service import Service

__all__ = [
    "FaultEvent",
    "GroupEngine",
    "Job",
    "JobHandle",
    "JobResult",
    "JobStatus",
    "RetryPolicy",
    "Scheduler",
    "Service",
    "StreamUpdate",
    "TerminationPolicy",
    "build_algorithm",
    "default_collectors",
    "group_key",
]
