"""Fault taxonomy and structured fault records for the sampling service.

The serve stack's whole pitch is *exactness*, so its fault story cannot be
"retry and hope": every recovery path must provably leave surviving chains
bitwise on their fault-free trajectories. The repo's chunk/capacity
invariance pins make that cheap — a chunk is re-runnable from its committed
boundary with identical keys (they derive from the states' iteration
counters), so exact replay IS the recovery primitive. This module defines
the shared vocabulary:

=====================  ====================================================
kind                   meaning / response
=====================  ====================================================
``nonfinite``          a lane's θ / log-joint / δ-cache / dataset went
                       non-finite — the per-chunk health sentinel
                       quarantines THAT job lane (pre-chunk state restored,
                       poisoned chunk never folded); neighbors untouched
``chunk_error``        a group chunk raised — retried from the last
                       committed boundary under :class:`RetryPolicy`
                       (exact by chunk invariance)
``group_failed``       retries exhausted — the group's jobs retire FAILED
                       with their committed (clean) prefixes
``straggler``          a group's chunk wall-time EWMA exceeds the fleet
                       median × threshold (:class:`repro.launch.elastic.
                       StragglerMonitor`)
``device_loss``        the elastic shrink ran (checkpoint → shrink budget →
                       suspend newest-first → repack)
``checkpoint_fallback``  restore skipped one or more corrupt/torn steps and
                       fell back to the newest intact checkpoint
=====================  ====================================================

:class:`FaultEvent` records stream through the service's existing update
channel (``Service.step`` returns them interleaved with ``StreamUpdate``\\ s,
``Service.run``'s ``on_update`` sees both) and accumulate on
``Service.faults`` for post-hoc inspection.
"""

from __future__ import annotations

import dataclasses

# The closed set of fault kinds the service emits (the chaos harness in
# repro.testing.chaos injects the matching failures).
FAULT_KINDS = (
    "nonfinite",
    "chunk_error",
    "group_failed",
    "straggler",
    "device_loss",
    "checkpoint_fallback",
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One detected fault and the service's response to it.

    ``step`` is the service step counter at detection time; ``job_id`` names
    the affected job when the fault is job-scoped (quarantine), ``group``
    labels the batching group when it is group-scoped (chunk errors,
    stragglers). ``detail`` carries kind-specific structured fields (error
    reprs, retry attempt numbers, skipped checkpoint steps, ...).
    """

    kind: str
    step: int
    job_id: str | None = None
    group: str | None = None
    detail: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-and-backoff for failed group chunks.

    A failed chunk is re-run from the last committed boundary — per-lane
    keys derive from the states' iteration counters, so a retry is bitwise
    the trajectory an un-faulted run would have produced (the repo's chunk
    invariance contract, not an approximation). ``max_retries`` bounds the
    re-runs per chunk; ``backoff_s`` sleeps ``backoff_s * multiplier**(k-1)``
    before retry ``k`` (0 disables sleeping — tests and the chaos suite).
    """

    max_retries: int = 2
    backoff_s: float = 0.05
    multiplier: float = 2.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_s < 0:
            raise ValueError("backoff_s must be >= 0")

    def delay(self, attempt: int) -> float:
        """Seconds to back off before retry number ``attempt`` (1-based)."""
        return self.backoff_s * self.multiplier ** (attempt - 1)


def group_label(key: tuple) -> str:
    """A short human-readable label for a batching-group key (fault events
    and straggler accounting want a stable name, not a 14-tuple)."""
    fam, n, d, k = key[0][0], key[1], key[2], key[3]
    return f"{fam}-n{n}-d{d}-K{k}"
