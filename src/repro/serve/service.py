"""The always-on posterior-sampling service.

``Service`` ties the serve stack together: clients :meth:`~Service.submit`
:class:`~repro.serve.job.Job`s and get :class:`~repro.serve.results.
JobHandle`s back; :meth:`~Service.step` advances every batching group one
chunk (continuous batching: jobs join and leave BETWEEN chunks, never
mid-scan); :meth:`~Service.run` loops until the work drains. Per step:

    1. admission — the scheduler packs suspended + queued jobs into group
       engines, FIFO with head-of-line skip, under the chain-slot budget
       (:func:`repro.launch.elastic.plan_chain_slots`);
    2. one :meth:`GroupEngine.run_chunk` per engine — each a single jitted
       call advancing every member ``chunk_size`` steps;
    3. termination — every running job is checked against its
       :class:`~repro.serve.job.TerminationPolicy`: the ``max_samples``
       stop always, convergence (peeked split-R̂ / batch-means ESS —
       non-destructive, so a peek never perturbs the chain) once
       ``min_samples`` committed, throttled by ``check_every``. Retiring
       jobs are evicted and finalized into
       :class:`~repro.serve.results.JobResult`s whose contents are bitwise
       the solo ``api.sample`` run's;
    4. optionally, a checkpoint (``checkpoint_every`` steps).

**Checkpoint/restore.** :meth:`checkpoint` snapshots every admitted job's
lane trees (chain states with their iteration counters, chain keys,
dataset, collector carries, fold counts) through
:class:`repro.checkpoint.Checkpointer` — one atomic step directory — with
the job registry (hyperparameters, policies, collector configs, progress)
in the manifest's ``extra``. :meth:`Service.restore` reads the manifest
FIRST (that is why ``Checkpointer.manifest`` exists), rebuilds the jobs,
constructs the restore target from the engines' own lane-structure code
(:meth:`GroupEngine.build_lane` on placeholder data — every value is then
overwritten), and re-admits each job via ``admit_restored``. A restored
job continues its exact solo trajectory — bitwise, because per-iteration
keys derive from the checkpointed iteration counters (pinned in tests).

**Device loss.** :meth:`handle_device_loss` is the elastic path:
checkpoint, shrink the slot budget to the surviving devices
(``plan_chain_slots``), suspend newest-first until occupancy fits, repack.
Suspended jobs hold their lanes host-side and outrank the queue for freed
slots; nothing loses committed work. Shrinking to ZERO devices is legal:
every job suspends cleanly and waits for capacity to return.

**Fault supervision** (see :mod:`repro.serve.faults` for the taxonomy).
Every group chunk runs supervised: an exception re-runs the chunk from the
last committed boundary under a bounded :class:`~repro.serve.faults.
RetryPolicy` — exact, not approximate, because ``GroupEngine.run_chunk`` is
transactional and per-iteration keys derive from the states' iteration
counters (a retried chunk IS the chunk, bitwise). Exhausted retries retire
the group's jobs as FAILED with their clean committed prefixes. Lanes the
engines' numerical-health sentinel quarantines are evicted here and retired
as FAILED (reason "quarantined") — their neighbors never notice. Chunk wall
times feed a :class:`repro.launch.elastic.StragglerMonitor` per group;
passing ``straggler_threshold`` escalates flagged groups to
:class:`~repro.serve.faults.FaultEvent` records. All fault events stream
through the existing update channel — :meth:`step` returns them interleaved
with the ``StreamUpdate``\\ s — and accumulate on ``Service.faults``.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.api import collectors as collectors_lib
from repro.launch import elastic
from repro.serve import faults as faults_lib
from repro.serve import job as job_lib
from repro.serve.engine import GroupEngine
from repro.serve.faults import FaultEvent, RetryPolicy
from repro.serve.results import JobHandle, JobResult, JobStatus, StreamUpdate
from repro.serve.scheduler import Scheduler

_JOB_META_FIELDS = (
    "job_id", "family", "seed", "num_chains", "prior_scale", "xi",
    "n_classes", "nu", "sigma", "kernel", "step_size", "q_db", "mode",
    "resample_fraction", "capacity", "cand_capacity", "backend",
    "z_backend", "adapt_target", "num_warmup",
)


def _collector_specs(colls: dict) -> list:
    """JSON-able (name, class, config) triples — the checkpointable subset:
    dataclass fields must be plain values (a collector closing over arrays
    or callables, e.g. PosteriorPredictive, cannot ride in a manifest)."""
    out = []
    for name in sorted(colls):
        col = colls[name]
        fields = {}
        if dataclasses.is_dataclass(col):
            for f in dataclasses.fields(col):
                v = getattr(col, f.name)
                if callable(v) or hasattr(v, "shape"):
                    raise ValueError(
                        f"collector {name!r} ({type(col).__name__}) holds a "
                        f"{'callable' if callable(v) else 'array'} field "
                        f"{f.name!r} and cannot be checkpointed; drop it or "
                        f"run the job without service checkpointing"
                    )
                fields[f.name] = v
        out.append([name, type(col).__name__, fields])
    return out


def _collectors_from_specs(specs: list) -> dict:
    return {
        name: getattr(collectors_lib, cls)(**fields)
        for name, cls, fields in specs
    }


def _finalize_lane_with(colls: dict, lane: dict) -> dict:
    """Finalized {name: result} for a lane outside any engine (suspended/
    cancelled jobs) — the same (K, ...)-carry finalize contract."""
    return {
        name: col.finalize(
            jax.tree.map(lambda l: l[0], lane["carries"][name])
        )
        for name, col in colls.items()
    }


class Service:
    def __init__(self, slot_budget: int | None = None, chunk_size: int = 64,
                 lane_backend: str = "map", checkpointer=None,
                 checkpoint_every: int | None = None,
                 retry: RetryPolicy | None = None,
                 straggler_threshold: float | None = None):
        """``retry`` bounds the per-chunk retry-and-backoff (default
        :class:`RetryPolicy`()). ``straggler_threshold`` opts into straggler
        escalation: chunk wall times are always recorded per group, but a
        ``FaultEvent`` fires only when a group's EWMA exceeds the fleet
        median by this factor — wall time is noisy, so escalation must be a
        deliberate choice, not a default source of stream chatter."""
        if slot_budget is None:
            slot_budget = elastic.plan_chain_slots(len(jax.devices()))
        if chunk_size < 1:
            raise ValueError("chunk_size must be >= 1")
        self.chunk_size = int(chunk_size)
        self.scheduler = Scheduler(slot_budget, lane_backend=lane_backend)
        self.checkpointer = checkpointer
        self.checkpoint_every = checkpoint_every
        if checkpoint_every is not None and checkpointer is None:
            raise ValueError("checkpoint_every needs a checkpointer")
        self.retry = retry if retry is not None else RetryPolicy()
        self.straggler_threshold = straggler_threshold
        self.faults: list[FaultEvent] = []  # every event ever emitted
        self.monitor = elastic.StragglerMonitor(
            threshold=(straggler_threshold if straggler_threshold is not None
                       else 1.5)
        )
        self._flagged: set[str] = set()  # groups already escalated
        self.restored_from_step = None   # set by Service.restore
        # Chaos/test seams: the wall clock and the backoff sleep.
        self._clock = time.monotonic
        self._sleep = time.sleep
        self._jobs: dict[str, job_lib.Job] = {}
        self._status: dict[str, JobStatus] = {}
        self._results: dict[str, JobResult] = {}
        self._chunks: dict[str, int] = {}   # chunks run, for check_every
        self._stream: dict[str, tuple] = {}  # subscribed peek names
        self._step_count = 0

    # ---------------------------------------------------------------- submit

    def submit(self, job: job_lib.Job, stream: tuple = ()) -> JobHandle:
        """Queue a job; it joins a group at the next chunk boundary.
        ``stream`` names collectors to peek into every StreamUpdate."""
        if job.job_id in self._jobs:
            raise ValueError(f"job id {job.job_id!r} already submitted")
        if job.num_chains > self.scheduler.slot_budget:
            raise ValueError(
                f"job {job.job_id!r} needs {job.num_chains} chain slots; "
                f"the service budget is {self.scheduler.slot_budget}"
            )
        unknown = set(stream) - set(job.collectors)
        if unknown:
            raise ValueError(f"stream names {sorted(unknown)} are not "
                             f"collectors of job {job.job_id!r}")
        self._jobs[job.job_id] = job
        self._status[job.job_id] = JobStatus.QUEUED
        self._chunks[job.job_id] = 0
        self._stream[job.job_id] = tuple(stream)
        self.scheduler.enqueue(job)
        return JobHandle(self, job.job_id)

    # --------------------------------------------------------------- queries

    def status(self, job_id: str) -> JobStatus:
        return self._status[job_id]

    def committed(self, job_id: str) -> int:
        st = self._status[job_id]
        if st is JobStatus.RUNNING:
            return self.scheduler.engine_of(job_id).committed(job_id)
        if st is JobStatus.SUSPENDED:
            _, lane, _ = self.scheduler.suspended[job_id]
            return int(jax.device_get(lane["counts"][0]))
        if st in (JobStatus.DONE, JobStatus.CANCELLED, JobStatus.FAILED):
            return self._results[job_id].committed
        return 0

    def peek(self, job_id: str, name: str):
        if self._status[job_id] is not JobStatus.RUNNING:
            raise ValueError(f"job {job_id!r} is not running "
                             f"({self._status[job_id].value})")
        return self.scheduler.engine_of(job_id).peek(job_id, name)

    def result(self, job_id: str) -> JobResult | None:
        return self._results.get(job_id)

    def active(self) -> bool:
        return any(
            s in (JobStatus.QUEUED, JobStatus.RUNNING, JobStatus.SUSPENDED)
            for s in self._status.values()
        )

    # ---------------------------------------------------------------- cancel

    def cancel(self, job_id: str) -> bool:
        """Stop a job at the current boundary; partial results are
        finalized (committed prefix only). Safe in any state."""
        st = self._status[job_id]
        job = self._jobs[job_id]
        if st is JobStatus.QUEUED:
            self.scheduler.queue = [
                j for j in self.scheduler.queue if j.job_id != job_id
            ]
            self._retire(job_id, {}, 0, "cancelled")
            return True
        if st is JobStatus.RUNNING:
            eng, lane = self.scheduler.evict(job_id)
            n = int(jax.device_get(lane["counts"][0]))
            self._retire(job_id, eng.finalize_lane(lane), n, "cancelled")
            return True
        if st is JobStatus.SUSPENDED:
            _, lane, _ = self.scheduler.suspended.pop(job_id)
            n = int(jax.device_get(lane["counts"][0]))
            self._retire(job_id, _finalize_lane_with(job.collectors, lane),
                         n, "cancelled")
            return True
        return False  # already DONE/CANCELLED

    def _retire(self, job_id: str, results: dict, committed: int,
                reason: str):
        self._results[job_id] = JobResult(
            job_id=job_id, results=results, committed=committed,
            reason=reason,
        )
        if reason == "cancelled":
            self._status[job_id] = JobStatus.CANCELLED
        elif reason in ("quarantined", "failed"):
            self._status[job_id] = JobStatus.FAILED
        else:
            self._status[job_id] = JobStatus.DONE

    # ------------------------------------------------------------ scheduling

    def _stop_reason(self, job: job_lib.Job, eng: GroupEngine,
                     committed: int):
        """(reason | None, peeks-consumed): the TerminationPolicy check."""
        p = job.policy
        if committed >= p.max_samples:
            return "max_samples", {}
        if p.target_rhat is None and p.min_ess is None:
            return None, {}
        if committed < max(p.min_samples, 1):
            return None, {}
        if self._chunks[job.job_id] % p.check_every:
            return None, {}
        peeks, ok = {}, True
        if p.target_rhat is not None:
            r = peeks["rhat"] = eng.peek(job.job_id, "rhat")
            ok = ok and (r["r_hat"] <= p.target_rhat)
        if p.min_ess is not None:
            e = peeks["ess"] = eng.peek(job.job_id, "ess")
            ess = np.asarray(e["ess"], dtype=np.float64)
            total = float(np.nansum(ess)) if np.isfinite(ess).any() else 0.0
            ok = ok and (total >= p.min_ess)
        return ("converged" if ok else None), peeks

    def _fault(self, kind: str, **kw) -> FaultEvent:
        ev = FaultEvent(kind=kind, step=self._step_count, **kw)
        self.faults.append(ev)
        return ev

    def _supervised_chunk(self, eng: GroupEngine, label: str,
                          updates: list) -> bool:
        """Run one group chunk under the retry policy. A retry re-enters
        from the last committed boundary (``run_chunk`` is transactional)
        and replays the identical chunk bitwise — per-lane keys derive from
        the states' iteration counters, not from the attempt count. Returns
        False when retries are exhausted."""
        attempt = 0
        while True:
            t0 = self._clock()
            try:
                eng.run_chunk(self.chunk_size)
            except Exception as e:
                attempt += 1
                retrying = attempt <= self.retry.max_retries
                updates.append(self._fault(
                    "chunk_error", group=label,
                    detail={"error": repr(e), "attempt": attempt,
                            "retrying": retrying},
                ))
                if not retrying:
                    return False
                if self.retry.backoff_s:
                    self._sleep(self.retry.delay(attempt))
                continue
            self.monitor.record(label, self._clock() - t0)
            return True

    def _fail_group(self, eng: GroupEngine, label: str, updates: list):
        """Retries exhausted: retire every member FAILED with its clean
        committed prefix (the failing chunk never committed). Retiring —
        rather than suspending — is what bounds the blast radius: a
        suspended job would be re-admitted next step and a persistent fault
        would loop forever."""
        members = list(eng.job_ids)
        updates.append(self._fault(
            "group_failed", group=label,
            detail={"jobs": members, "retries": self.retry.max_retries},
        ))
        for job_id in members:
            committed = eng.committed(job_id)
            _, lane = self.scheduler.evict(job_id)
            self._retire(job_id, eng.finalize_lane(lane), committed,
                         "failed")
            updates.append(StreamUpdate(
                job_id=job_id, committed=committed, peeks={},
                done=True, reason="failed",
            ))

    def step(self) -> list:
        """One service round: admit → chunk every group (supervised) →
        quarantine sweep → check termination → straggler check → (maybe)
        checkpoint. Returns this boundary's stream updates, interleaved
        with any :class:`FaultEvent` records (the fault stream rides the
        same channel; ``isinstance(u, StreamUpdate)`` separates them)."""
        for job_id in self.scheduler.admit_pending():
            self._status[job_id] = JobStatus.RUNNING
        updates = []
        for eng in list(self.scheduler.engines.values()):
            label = faults_lib.group_label(eng.group_key)
            if not self._supervised_chunk(eng, label, updates):
                self._fail_group(eng, label, updates)
                continue
            for job_id in eng.job_ids:
                self._chunks[job_id] += 1
            # Quarantine sweep: the sentinel already rolled the sick lanes
            # back to their pre-chunk committed state; evict them before
            # the termination pass so a poisoned lane can neither "finish"
            # nor be peeked at.
            for job_id in eng.take_quarantined():
                committed = eng.committed(job_id)
                _, lane = self.scheduler.evict(job_id)
                self._retire(job_id, eng.finalize_lane(lane), committed,
                             "quarantined")
                updates.append(self._fault(
                    "nonfinite", job_id=job_id, group=label,
                    detail={"response": "lane quarantined",
                            "committed": committed},
                ))
                updates.append(StreamUpdate(
                    job_id=job_id, committed=committed, peeks={},
                    done=True, reason="quarantined",
                ))
            for job_id in list(eng.job_ids):
                job = self._jobs[job_id]
                committed = eng.committed(job_id)
                reason, peeks = self._stop_reason(job, eng, committed)
                for name in self._stream[job_id]:
                    if name not in peeks:
                        peeks[name] = eng.peek(job_id, name)
                if reason is not None:
                    _, lane = self.scheduler.evict(job_id)
                    self._retire(job_id, eng.finalize_lane(lane),
                                 committed, reason)
                updates.append(StreamUpdate(
                    job_id=job_id, committed=committed, peeks=peeks,
                    done=reason is not None, reason=reason,
                ))
        if self.straggler_threshold is not None:
            lagging = set(self.monitor.stragglers())
            for label in sorted(lagging - self._flagged):
                updates.append(self._fault(
                    "straggler", group=label,
                    detail={"ewma_s": self.monitor.ewma[label],
                            "threshold": self.monitor.threshold},
                ))
            # A group that catches back up may be flagged again later.
            self._flagged = lagging
        self._step_count += 1
        if (self.checkpoint_every
                and self._step_count % self.checkpoint_every == 0
                and (self.scheduler.engines or self.scheduler.suspended)):
            self.checkpoint()
        return updates

    def run(self, on_update=None, max_steps: int | None = None) -> dict:
        """Step until every submitted job retires; returns
        ``{job_id: JobResult}``. ``on_update`` sees every StreamUpdate and
        every FaultEvent, in boundary order."""
        steps = 0
        while self.active():
            if max_steps is not None and steps >= max_steps:
                raise RuntimeError(f"run() did not drain in {max_steps} steps")
            before = self._progress_mark()
            for u in self.step():
                if on_update is not None:
                    on_update(u)
            steps += 1
            if not self.scheduler.engines and self._progress_mark() == before:
                raise RuntimeError(
                    "service stalled: queued/suspended jobs cannot fit the "
                    f"slot budget ({self.scheduler.slot_budget})"
                )
        return dict(self._results)

    def _progress_mark(self):
        return (len(self._results), len(self.scheduler.queue),
                len(self.scheduler.suspended),
                len(self.scheduler.engines))

    # ------------------------------------------------------------ checkpoint

    def checkpoint(self, blocking: bool = True):
        """One atomic checkpoint of every admitted (running or suspended)
        job: lane trees as array leaves, the job registry + progress in the
        manifest ``extra``. Queued jobs are not yet state — clients
        resubmit them after a restart."""
        if self.checkpointer is None:
            raise ValueError("service has no checkpointer")
        tree, jobs_meta = {}, {}
        for eng in self.scheduler.engines.values():
            for job_id in eng.job_ids:
                tree[job_id] = eng.lane_of(job_id)
                jobs_meta[job_id] = self._job_meta(
                    self._jobs[job_id], (eng.capacity, eng.cand_capacity)
                )
        for job_id, (job, lane, caps) in self.scheduler.suspended.items():
            tree[job_id] = lane
            jobs_meta[job_id] = self._job_meta(job, caps)
        self.checkpointer.save(
            self._step_count, tree,
            extra_metadata={
                "serve": {
                    "jobs": jobs_meta,
                    "slot_budget": self.scheduler.slot_budget,
                    "chunk_size": self.chunk_size,
                    "step_count": self._step_count,
                }
            },
            blocking=blocking,
        )

    def _job_meta(self, job: job_lib.Job, caps: tuple) -> dict:
        meta = {f: getattr(job, f) for f in _JOB_META_FIELDS}
        meta["policy"] = dataclasses.asdict(job.policy)
        meta["collectors"] = _collector_specs(job.collectors)
        meta["group_caps"] = list(caps)
        meta["chunks"] = self._chunks[job.job_id]
        meta["stream"] = list(self._stream[job.job_id])
        return meta

    @classmethod
    def restore(cls, checkpointer, step: int | None = None,
                slot_budget: int | None = None, chunk_size: int | None = None,
                lane_backend: str = "map", checkpoint_every=None,
                verify: bool = True, retry: RetryPolicy | None = None,
                straggler_threshold: float | None = None):
        """Rebuild a service from a checkpoint; every restored job resumes
        its exact chain (bitwise — the states carry their iteration
        counters, the keys their original chain keys). Restored jobs enter
        SUSPENDED and repack on the first :meth:`step`.

        With ``verify`` (the default), corrupt state is never loaded
        silently: an explicitly requested corrupt ``step`` raises
        :class:`repro.checkpoint.CheckpointCorruptError`; with ``step=None``
        the newest checkpoint that passes integrity verification is loaded
        and any skipped corrupt steps are reported as a
        ``checkpoint_fallback`` :class:`FaultEvent` on ``svc.faults``."""
        man = checkpointer.manifest(step, verify=verify)
        skipped = list(getattr(checkpointer, "last_skipped", []))
        step = man["step"]  # pin the verified choice for the leaf restore
        serve = man["extra"]["serve"]
        svc = cls(
            slot_budget=(serve["slot_budget"] if slot_budget is None
                         else slot_budget),
            chunk_size=(serve["chunk_size"] if chunk_size is None
                        else chunk_size),
            lane_backend=lane_backend, checkpointer=checkpointer,
            checkpoint_every=checkpoint_every, retry=retry,
            straggler_threshold=straggler_threshold,
        )
        svc._step_count = serve["step_count"]
        svc.restored_from_step = step
        if skipped:
            svc._fault(
                "checkpoint_fallback",
                detail={"loaded_step": step, "skipped_steps": skipped},
            )
        # Build the restore target from the engines' own lane-structure
        # code, on placeholder jobs with zero datasets of the saved shapes
        # (the manifest records every leaf's shape) — Checkpointer.restore
        # then overwrites every value and validates shapes leaf-by-leaf.
        leaf_shapes = {
            m["path"]: (tuple(m["shape"]), m["dtype"]) for m in man["leaves"]
        }
        target, jobs, caps_of = {}, {}, {}
        for job_id, meta in serve["jobs"].items():
            data = _placeholder_data(job_id, meta, leaf_shapes)
            job = job_lib.Job(
                data=data,
                policy=job_lib.TerminationPolicy(**meta["policy"]),
                collectors=_collectors_from_specs(meta["collectors"]),
                **{f: meta[f] for f in _JOB_META_FIELDS},
            )
            caps = tuple(meta["group_caps"])
            skeleton = GroupEngine(job, capacity=caps[0],
                                   cand_capacity=caps[1])
            target[job_id], _ = skeleton.build_lane(job)
            jobs[job_id], caps_of[job_id] = job, caps
        restored, _ = checkpointer.restore(target, step, verify=verify)
        for job_id, meta in serve["jobs"].items():
            lane = restored[job_id]
            job = dataclasses.replace(
                jobs[job_id],
                data=jax.tree.map(lambda l: l[0], lane["data"]),
            )
            svc._jobs[job_id] = job
            svc._status[job_id] = JobStatus.SUSPENDED
            svc._chunks[job_id] = meta["chunks"]
            svc._stream[job_id] = tuple(meta["stream"])
            svc.scheduler.suspended[job_id] = (job, lane, caps_of[job_id])
        return svc

    # --------------------------------------------------------- device loss

    def handle_device_loss(self, n_devices: int,
                           slots_per_device: int = 8) -> list[str]:
        """The elastic response: checkpoint (when configured), shrink the
        slot budget to the surviving devices, suspend newest-first until
        occupancy fits, repack what still fits. Returns the ids suspended
        by the shrink (they outrank the queue for future slots).

        ``n_devices=0`` (total loss) is legal: the budget drops to zero,
        every running job suspends cleanly with its committed work intact,
        and a later call with surviving devices repacks them."""
        budget = elastic.plan_chain_slots(n_devices, slots_per_device)
        if self.checkpointer is not None:
            self.checkpoint()
        suspended = self.scheduler.shrink_to_budget(budget)
        for job_id in suspended:
            self._status[job_id] = JobStatus.SUSPENDED
        admitted = []
        for job_id in self.scheduler.admit_pending():
            self._status[job_id] = JobStatus.RUNNING
            admitted.append(job_id)
        self._fault(
            "device_loss", detail={
                "n_devices": n_devices, "new_budget": budget,
                "suspended": suspended, "readmitted": admitted,
            },
        )
        return suspended


def _placeholder_data(job_id: str, meta: dict, leaf_shapes: dict):
    """Zeros GLMData with the checkpointed lane's shapes (sans the lane
    axis) — enough structure to rebuild the Job and the restore target."""
    import jax.numpy as jnp

    from repro.core.bounds import GLMData

    leaves = {}
    for field in ("x", "t", "xi"):
        path = f"['{job_id}']['data'].{field}"
        if path not in leaf_shapes:
            raise KeyError(f"checkpoint missing {path}")
        shape, dtype = leaf_shapes[path]
        leaves[field] = jnp.zeros(shape[1:], dtype)
    return GLMData(**leaves)
