"""qwen1.5-110b — dense decoder LM with QKV bias [hf:Qwen/Qwen1.5-0.5B].

80L, d_model=8192, 64 heads (GQA kv=8), d_ff=49152, vocab 152064.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    parallel_mode="sp",
    subquadratic=False,
    # §Perf iteration A2: f32 AdamW moments put args at 4.9 GiB/chip and the
    # cell over HBM; bf16 moments (with f32 master params retained) recover
    # 1.6 GiB at equal convergence in the 8-device integration test.
    opt_dtype="bfloat16",
)
