"""rwkv6-7b ("Finch") — attention-free RNN with data-dependent decay
[arXiv:2404.05892].

32L, d_model=4096 (64 heads × 64 head-dim time-mixing), d_ff=14336,
vocab 65536. Constant-size WKV state → runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # WKV heads (head_dim 64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv",),
    parallel_mode="tp",
    subquadratic=True,
)
