"""arctic-480b — dense+MoE hybrid, 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads (GQA kv=8), d_ff=4864, vocab 32000.
Every layer runs a dense FFN residual path in parallel with the MoE.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(
        n_experts=128, top_k=2, capacity_factor=1.25, dense_residual=True
    ),
    parallel_mode="sp",
    subquadratic=False,
    # 480B params × 12 B/param of f32 AdamW state does not fit 256×16 GB;
    # bf16 moments bring resident state to 8 B/param (EXPERIMENTS §Dry-run).
    opt_dtype="bfloat16",
)
