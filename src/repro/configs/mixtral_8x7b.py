"""mixtral-8x7b — MoE decoder LM, 8 experts top-2, SWA [arXiv:2401.04088].

32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=14336, vocab 32000.
Sliding-window attention (4096) gives a bounded KV cache, so this arch
runs the long_500k shape (DESIGN.md §4).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    swa_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, capacity_factor=1.25),
    rope_theta=1_000_000.0,
    parallel_mode="sp",
    subquadratic=True,  # SWA: O(window) cache
)
