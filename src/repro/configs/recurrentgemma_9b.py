"""recurrentgemma-9b — RG-LRU + local attention hybrid, 1 attn : 2 rglru
[arXiv:2402.19427].

38L (pattern rglru,rglru,attn — 26 recurrence + 12 local-attn layers; we
round the published 1:2 ratio onto 38 layers), d_model=4096, 16 heads
(MQA kv=1), d_ff=12288, rnn width 4096, local window 2048, vocab 256000.
Constant-size recurrence state → runs long_500k.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    mlp="gelu",
    block_pattern=("rglru", "rglru", "attn"),
    local_attn_window=2048,
    rnn_width=4096,
    parallel_mode="tp",
    subquadratic=True,
)
