"""Config registry: ``--arch <id>`` resolution for all assigned archs.

``get_config(arch_id)`` returns the full published configuration;
``get_reduced(arch_id)`` returns the smoke-test-sized family twin.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, ShapeConfig, SHAPES, reduced

# arch id → module name
_REGISTRY = {
    "whisper-tiny": "whisper_tiny",
    "qwen1.5-110b": "qwen1_5_110b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen2-7b": "qwen2_7b",
    "llama3.2-3b": "llama3_2_3b",
    "mixtral-8x7b": "mixtral_8x7b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_IDS = tuple(_REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    mod = importlib.import_module(f"repro.configs.{_REGISTRY[arch_id]}")
    return mod.CONFIG


def get_reduced(arch_id: str, **overrides) -> ModelConfig:
    return reduced(get_config(arch_id), **overrides)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Which (arch × shape) cells are defined (DESIGN.md §4).

    long_500k requires a sub-quadratic decode path; pure full-attention
    archs skip it (recorded, not silently dropped).
    """
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "get_config",
    "get_reduced",
    "shape_applicable",
]
