"""whisper-tiny — encoder-decoder audio transformer [arXiv:2212.04356].

4L enc + 4L dec, d_model=384, 6 heads (kv=6), d_ff=1536, vocab 51865.
The conv audio frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings (B, 1500, 384) per the brief. GeLU MLP, LayerNorm,
learned positions (we use RoPE-free sinusoidal-equivalent: plain learned
table folded into the stub embeddings for the encoder; decoder uses RoPE
for simplicity of the shared stack — noted in DESIGN.md §7).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp="gelu",
    norm="layernorm",
    encoder_layers=4,
    encoder_seq=1504,  # 1500 audio frames padded to a multiple of 16
    parallel_mode="sp",
    subquadratic=False,
)
