"""llava-next-mistral-7b — VLM: mistral-7b backbone + anyres patch tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab 32000.
The vision tower/anyres tiling is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, 576, d_model) occupying the leading
positions of the sequence (brief: frontend is a stub, backbone only).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    patch_positions=576,
    rope_theta=1_000_000.0,
    parallel_mode="sp",
    subquadratic=False,
)
