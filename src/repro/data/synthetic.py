"""Synthetic data generators matching the paper's three experiments (§4).

The paper's claim under test is a *systems* claim — likelihood queries per
iteration and effective samples per unit compute — which depends on (N, D,
class structure, bound tightness at the posterior mode), not on the
particular pixels of MNIST. Each generator reproduces the shape and
separability regime of its experiment:

  * :func:`logistic_data` — MNIST 7-vs-9 on 50 PCA components + bias
    (N≈12,214, D=51): two moderately-separated Gaussian class clouds in a
    low-rank subspace, labels in {-1, +1}.
  * :func:`softmax_data` — 3-class CIFAR-10 on 256 *binary* deep-autoencoder
    features (N=18,000, D=256, K=3): class-prototype Bernoulli features.
  * :func:`robust_data` — OPV HOMO-LUMO regression (N≈1.8M, D=57): linear
    response with Student-t noise and a fraction of gross outliers.

All generators return :class:`repro.core.GLMData` with ``xi`` left at zeros
(callers pick untuned/MAP-tuned bounds explicitly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bounds import GLMData


def _with_bias(x: jax.Array) -> jax.Array:
    return jnp.concatenate([x, jnp.ones((x.shape[0], 1), x.dtype)], axis=1)


def logistic_data(
    key: jax.Array,
    n: int = 12214,
    d: int = 51,
    separation: float = 2.0,
    dtype=jnp.float32,
) -> GLMData:
    """Two-class Gaussian clouds in a PCA-like spectrum, labels in {-1,+1}."""
    k_x, k_t, k_dir = jax.random.split(key, 3)
    d_feat = d - 1  # last column is the bias feature
    t = jnp.where(jax.random.bernoulli(k_t, 0.5, (n,)), 1.0, -1.0).astype(dtype)
    # PCA-like decaying spectrum, then a class-mean shift along a random dir.
    spectrum = 1.0 / jnp.sqrt(1.0 + jnp.arange(d_feat, dtype=dtype))
    x = jax.random.normal(k_x, (n, d_feat), dtype) * spectrum
    direction = jax.random.normal(k_dir, (d_feat,), dtype)
    direction = direction / jnp.linalg.norm(direction)
    x = x + 0.5 * separation * t[:, None] * direction * spectrum
    x = _with_bias(x)
    return GLMData(x=x, t=t, xi=jnp.zeros(n, dtype))


def softmax_data(
    key: jax.Array,
    n: int = 18000,
    d: int = 256,
    k: int = 3,
    sharpness: float = 3.0,
    dtype=jnp.float32,
) -> GLMData:
    """K-class binary-feature data (deep-autoencoder-code regime)."""
    k_proto, k_t, k_x = jax.random.split(key, 3)
    t = jax.random.randint(k_t, (n,), 0, k)
    # Class prototypes: per-class Bernoulli rates pushed toward 0/1.
    logits = sharpness * jax.random.normal(k_proto, (k, d), dtype)
    rates = jax.nn.sigmoid(logits)
    u = jax.random.uniform(k_x, (n, d), dtype)
    x = (u < rates[t]).astype(dtype)
    return GLMData(x=x, t=t, xi=jnp.zeros((n, k), dtype))


def robust_data(
    key: jax.Array,
    n: int = 1_800_000,
    d: int = 57,
    nu: float = 4.0,
    outlier_frac: float = 0.01,
    outlier_scale: float = 10.0,
    sparsity: float = 0.5,
    dtype=jnp.float32,
) -> tuple[GLMData, jax.Array]:
    """Sparse linear response + Student-t noise + gross outliers.

    Returns (data, theta_true). ``data.t`` holds the real-valued response.
    """
    k_x, k_w, k_mask, k_noise, k_out, k_osel = jax.random.split(key, 6)
    x = jax.random.normal(k_x, (n, d - 1), dtype)
    x = _with_bias(x)
    theta_true = jax.random.normal(k_w, (d,), dtype)
    mask = jax.random.bernoulli(k_mask, sparsity, (d,))
    theta_true = jnp.where(mask, theta_true, 0.0)
    noise = jax.random.t(k_noise, nu, (n,), dtype)
    gross = outlier_scale * jax.random.normal(k_out, (n,), dtype)
    is_out = jax.random.bernoulli(k_osel, outlier_frac, (n,))
    y = x @ theta_true + jnp.where(is_out, gross, noise)
    return GLMData(x=x, t=y, xi=jnp.zeros(n, dtype)), theta_true
