"""Data pipeline: synthetic generators at paper scale + sharded global arrays."""

from repro.data.synthetic import (
    logistic_data,
    robust_data,
    softmax_data,
)

__all__ = ["logistic_data", "robust_data", "softmax_data"]
