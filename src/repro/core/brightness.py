"""Bright/dark set data structure (paper §3.3, Fig. 3) as JAX arrays.

The paper's structure is two length-N arrays plus a counter:

  arr : a permutation of 0..N-1 with all *bright* indices before dark ones
  tab : inverse permutation — tab[n] is the position of datum n inside arr
  num : number of bright data points (arr[:num] are bright)

``brighten``/``darken`` are the paper's O(1) swap updates, kept for fidelity
and for host-side use. On TPU the per-round update is batched, two ways:

  * :func:`from_z` — full rebuild from a boolean z via one stable cumsum
    compaction. O(N) memory-bound sweep; the ``z_backend="jnp"`` engine's
    path (and the one-time init path).
  * :func:`apply_flips` — the swap updates *vectorized over a round's
    flips*: O(changed) masked scatters with fixed (capacity-shaped)
    intermediates, no length-N cumsum. The ``z_backend="fused"`` engine's
    path, which keeps per-step non-likelihood work proportional to the
    touched subset (Angelino et al.'s streaming prescription).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BrightState(NamedTuple):
    arr: jax.Array  # (N,) int32 permutation, bright indices first
    tab: jax.Array  # (N,) int32 inverse permutation
    num: jax.Array  # ()   int32 bright count


def init(n: int, bright: bool = False) -> BrightState:
    """All-dark (default) or all-bright initial state."""
    idx = jnp.arange(n, dtype=jnp.int32)
    num = jnp.asarray(n if bright else 0, jnp.int32)
    return BrightState(arr=idx, tab=idx, num=num)


def from_z(z: jax.Array) -> BrightState:
    """Build the partition from a boolean brightness vector (stable order)."""
    z = z.astype(bool)
    n = z.shape[0]
    num = jnp.sum(z).astype(jnp.int32)
    # Stable partition: bright points keep relative order, then dark points.
    pos_b = jnp.cumsum(z) - 1
    pos_d = num + jnp.cumsum(~z) - 1
    tab = jnp.where(z, pos_b, pos_d).astype(jnp.int32)
    arr = jnp.zeros(n, jnp.int32).at[tab].set(jnp.arange(n, dtype=jnp.int32))
    return BrightState(arr=arr, tab=tab, num=num)


def z_of(state: BrightState) -> jax.Array:
    """Boolean brightness vector: z[n] = (position of n) < num."""
    return state.tab < state.num


def brighten(state: BrightState, n: jax.Array) -> BrightState:
    """Paper-faithful O(1) swap update: set z_n = 1 (no-op if already bright)."""
    pos = state.tab[n]
    already = pos < state.num
    boundary = state.num  # first dark slot
    other = state.arr[boundary]

    def do(s: BrightState) -> BrightState:
        arr = s.arr.at[boundary].set(n).at[pos].set(other)
        tab = s.tab.at[n].set(boundary).at[other].set(pos)
        return BrightState(arr=arr, tab=tab, num=s.num + 1)

    return jax.lax.cond(already, lambda s: s, do, state)


def darken(state: BrightState, n: jax.Array) -> BrightState:
    """Paper-faithful O(1) swap update: set z_n = 0 (no-op if already dark)."""
    pos = state.tab[n]
    already = pos >= state.num
    boundary = state.num - 1  # last bright slot
    other = state.arr[boundary]

    def do(s: BrightState) -> BrightState:
        arr = s.arr.at[boundary].set(n).at[pos].set(other)
        tab = s.tab.at[n].set(boundary).at[other].set(pos)
        return BrightState(arr=arr, tab=tab, num=s.num - 1)

    return jax.lax.cond(already, lambda s: s, do, state)


def batch_update(state: BrightState, z_new: jax.Array) -> BrightState:
    """Replace the whole partition given a new boolean z (vectorized round)."""
    del state
    return from_z(z_new)


def apply_flips(
    state: BrightState,
    darken: jax.Array,
    brighten_idx: jax.Array,
    brighten_mask: jax.Array,
) -> BrightState:
    """Batched O(changed) partition update — the paper's Fig.-3 swap updates
    vectorized over one z-round, replacing the O(N) ``from_z`` cumsum rebuild
    on the fused z-engine path.

    ``darken`` is a (C,) bool over *bright-buffer slots*: slot ``s`` is
    position ``s`` of ``arr`` and darkens datum ``arr[s]`` (entries at
    ``s >= num`` are ignored). ``brighten_idx``/``brighten_mask`` name
    currently-dark data to brighten ((S,) int32 ids; masked entries ignored
    and may be out-of-range padding). The two sets must be disjoint, which
    Algorithm 2 guarantees (darken proposals come from the bright set,
    brighten proposals from the dark set).

    The update is a pairwise swap matching: items that must *enter* the new
    bright region ``[0, num')`` (brightened items stranded at positions
    ``>= num'``, plus still-bright items stranded in a shrinking boundary
    window ``[num', num)``) are paired one-to-one with items that must
    *leave* it (darkened items at positions ``< num'``, plus still-dark
    items overtaken by a growing window ``[num, num')``) — the two lists
    provably have equal length — and each pair swaps positions. Everything
    is masked fixed-shape arithmetic over the (C,)/(S,) buffers plus
    O(changed) scatters into ``arr``/``tab``: no length-N uniform, cumsum,
    or compaction ever materializes.

    Matching order is buffer-slot order, which is ``arr``-position order —
    independent of the buffer capacities — so the resulting partition (and
    hence the realized chain) is bitwise capacity-invariant, matching the
    overflow-re-run contract of the drivers.
    """
    n = state.arr.shape[0]
    sd = darken.shape[0]
    sb = brighten_idx.shape[0]
    slots = jnp.arange(sd, dtype=jnp.int32)
    darken = darken & (slots < state.num)
    k = jnp.sum(darken).astype(jnp.int32)
    m = jnp.sum(brighten_mask).astype(jnp.int32)
    num2 = state.num - k + m

    b_idx = jnp.clip(brighten_idx.astype(jnp.int32), 0, n - 1)
    pos_b = jnp.take(state.tab, b_idx)

    # --- movers INTO [0, num') ---------------------------------------------
    # (a) brightened items currently parked at positions >= num'
    ma_mask = brighten_mask & (pos_b >= num2)
    # (b) shrink window [num', num): still-bright residents must relocate.
    #     Window positions are < num <= C, i.e. bright-buffer slots, so
    #     "still bright" is just ~darken at that slot.
    w = num2 + slots
    w_in = w < state.num  # empty when the bright set grows (num' >= num)
    w_cl = jnp.clip(w, 0, sd - 1)
    wb_mask = w_in & ~jnp.take(darken, w_cl)
    wb_item = jnp.take(state.arr, jnp.clip(w, 0, n - 1))
    in_item = jnp.concatenate([jnp.where(ma_mask, b_idx, n),
                               jnp.where(wb_mask, wb_item, n)])
    in_pos = jnp.concatenate([jnp.where(ma_mask, pos_b, n),
                              jnp.where(wb_mask, w, n)])
    in_mask = jnp.concatenate([ma_mask, wb_mask])

    # --- movers OUT of [0, num') -------------------------------------------
    # (a) darkened items currently inside the new bright region
    da_mask = darken & (slots < num2)
    da_item = jnp.take(state.arr, jnp.minimum(slots, n - 1))
    # (b) growth window [num, num'): still-dark residents must relocate.
    #     Membership "was this position's item brightened" via an O(S)
    #     scatter of the brighten positions into window coordinates
    #     (masked / out-of-window entries go to the sentinel slot and drop).
    v = state.num + jnp.arange(sb, dtype=jnp.int32)
    v_in = v < num2  # empty when the bright set shrinks
    v_brightened = (
        jnp.zeros(sb, bool)
        .at[jnp.where(brighten_mask, pos_b - state.num, sb)]
        .set(True, mode="drop")
    )
    vd_mask = v_in & ~v_brightened
    vd_item = jnp.take(state.arr, jnp.clip(v, 0, n - 1))
    out_item = jnp.concatenate([jnp.where(da_mask, da_item, n),
                                jnp.where(vd_mask, vd_item, n)])
    out_pos = jnp.concatenate([jnp.where(da_mask, slots, n),
                               jnp.where(vd_mask, v, n)])
    out_mask = jnp.concatenate([da_mask, vd_mask])

    # --- compact to prefix order and swap pairwise -------------------------
    def compact(item, pos, mask):
        size = item.shape[0]
        dest = jnp.where(mask, jnp.cumsum(mask) - 1, size)
        pad = jnp.full(size, n, jnp.int32)
        return (pad.at[dest].set(item, mode="drop"),
                pad.at[dest].set(pos, mode="drop"))

    bi, bp = compact(in_item, in_pos, in_mask)
    di, dp = compact(out_item, out_pos, out_mask)
    # |in| == |out| always, so pairing i-th with i-th is a clean swap;
    # sentinel (n) positions/items beyond the pair count drop harmlessly.
    arr = state.arr.at[dp].set(bi, mode="drop").at[bp].set(di, mode="drop")
    tab = state.tab.at[bi].set(dp, mode="drop").at[di].set(bp, mode="drop")
    return BrightState(arr=arr, tab=tab, num=num2)


def bright_buffer(state: BrightState, capacity: int):
    """Padded gather buffer over the bright set.

    Returns (idx, mask): idx is arr[:capacity] (static shape), mask marks the
    first ``num`` entries valid. Padding rows index arbitrary dark data whose
    contributions are masked to exactly zero by callers.
    """
    idx = jax.lax.dynamic_slice_in_dim(state.arr, 0, capacity)
    mask = jnp.arange(capacity, dtype=jnp.int32) < state.num
    return idx, mask


def dark_buffer(state: BrightState, capacity: int):
    """Padded gather buffer over the *dark* tail (arr[num : num+capacity]).

    Robust to ``capacity > N``: the slice start is clamped to [0, N - cap]
    (``min(num, n - capacity)`` went negative there, which XLA silently
    re-clamps — masking the bug — and a slice wider than N is a trace
    error), and the buffer is padded out to ``capacity`` with masked slots.
    """
    n = state.arr.shape[0]
    cap = min(capacity, n)
    start = jnp.clip(state.num, 0, n - cap)
    idx = jax.lax.dynamic_slice_in_dim(state.arr, start, cap)
    offset = jnp.arange(cap, dtype=jnp.int32) + start
    mask = offset >= state.num
    if capacity > n:
        idx = jnp.pad(idx, (0, capacity - n))
        mask = jnp.pad(mask, (0, capacity - n))
    return idx, mask


def check_invariants(state: BrightState) -> bool:
    """Host-side invariant check (used by tests & property tests)."""
    arr = jax.device_get(state.arr)
    tab = jax.device_get(state.tab)
    num = int(state.num)
    n = arr.shape[0]
    import numpy as np

    ok = bool(np.all(np.sort(arr) == np.arange(n)))
    ok &= bool(np.all(arr[tab] == np.arange(n)))
    ok &= 0 <= num <= n
    return ok
