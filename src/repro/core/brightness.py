"""Bright/dark set data structure (paper §3.3, Fig. 3) as JAX arrays.

The paper's structure is two length-N arrays plus a counter:

  arr : a permutation of 0..N-1 with all *bright* indices before dark ones
  tab : inverse permutation — tab[n] is the position of datum n inside arr
  num : number of bright data points (arr[:num] are bright)

``brighten``/``darken`` are the paper's O(1) swap updates, kept for fidelity
and for host-side use. On TPU the per-round update is *batched*: given the new
boolean z vector we rebuild the partition with one stable cumsum compaction —
an O(N) memory-bound vector sweep whose cost is negligible next to the
O(M·D) likelihood work it enables (DESIGN.md §3.2, §7.6).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class BrightState(NamedTuple):
    arr: jax.Array  # (N,) int32 permutation, bright indices first
    tab: jax.Array  # (N,) int32 inverse permutation
    num: jax.Array  # ()   int32 bright count


def init(n: int, bright: bool = False) -> BrightState:
    """All-dark (default) or all-bright initial state."""
    idx = jnp.arange(n, dtype=jnp.int32)
    num = jnp.asarray(n if bright else 0, jnp.int32)
    return BrightState(arr=idx, tab=idx, num=num)


def from_z(z: jax.Array) -> BrightState:
    """Build the partition from a boolean brightness vector (stable order)."""
    z = z.astype(bool)
    n = z.shape[0]
    num = jnp.sum(z).astype(jnp.int32)
    # Stable partition: bright points keep relative order, then dark points.
    pos_b = jnp.cumsum(z) - 1
    pos_d = num + jnp.cumsum(~z) - 1
    tab = jnp.where(z, pos_b, pos_d).astype(jnp.int32)
    arr = jnp.zeros(n, jnp.int32).at[tab].set(jnp.arange(n, dtype=jnp.int32))
    return BrightState(arr=arr, tab=tab, num=num)


def z_of(state: BrightState) -> jax.Array:
    """Boolean brightness vector: z[n] = (position of n) < num."""
    return state.tab < state.num


def brighten(state: BrightState, n: jax.Array) -> BrightState:
    """Paper-faithful O(1) swap update: set z_n = 1 (no-op if already bright)."""
    pos = state.tab[n]
    already = pos < state.num
    boundary = state.num  # first dark slot
    other = state.arr[boundary]

    def do(s: BrightState) -> BrightState:
        arr = s.arr.at[boundary].set(n).at[pos].set(other)
        tab = s.tab.at[n].set(boundary).at[other].set(pos)
        return BrightState(arr=arr, tab=tab, num=s.num + 1)

    return jax.lax.cond(already, lambda s: s, do, state)


def darken(state: BrightState, n: jax.Array) -> BrightState:
    """Paper-faithful O(1) swap update: set z_n = 0 (no-op if already dark)."""
    pos = state.tab[n]
    already = pos >= state.num
    boundary = state.num - 1  # last bright slot
    other = state.arr[boundary]

    def do(s: BrightState) -> BrightState:
        arr = s.arr.at[boundary].set(n).at[pos].set(other)
        tab = s.tab.at[n].set(boundary).at[other].set(pos)
        return BrightState(arr=arr, tab=tab, num=s.num - 1)

    return jax.lax.cond(already, lambda s: s, do, state)


def batch_update(state: BrightState, z_new: jax.Array) -> BrightState:
    """Replace the whole partition given a new boolean z (vectorized round)."""
    del state
    return from_z(z_new)


def bright_buffer(state: BrightState, capacity: int):
    """Padded gather buffer over the bright set.

    Returns (idx, mask): idx is arr[:capacity] (static shape), mask marks the
    first ``num`` entries valid. Padding rows index arbitrary dark data whose
    contributions are masked to exactly zero by callers.
    """
    idx = jax.lax.dynamic_slice_in_dim(state.arr, 0, capacity)
    mask = jnp.arange(capacity, dtype=jnp.int32) < state.num
    return idx, mask


def dark_buffer(state: BrightState, capacity: int):
    """Padded gather buffer over the *dark* tail (arr[num : num+capacity]).

    Robust to ``capacity > N``: the slice start is clamped to [0, N - cap]
    (``min(num, n - capacity)`` went negative there, which XLA silently
    re-clamps — masking the bug — and a slice wider than N is a trace
    error), and the buffer is padded out to ``capacity`` with masked slots.
    """
    n = state.arr.shape[0]
    cap = min(capacity, n)
    start = jnp.clip(state.num, 0, n - cap)
    idx = jax.lax.dynamic_slice_in_dim(state.arr, start, cap)
    offset = jnp.arange(cap, dtype=jnp.int32) + start
    mask = offset >= state.num
    if capacity > n:
        idx = jnp.pad(idx, (0, capacity - n))
        mask = jnp.pad(mask, (0, capacity - n))
    return idx, mask


def check_invariants(state: BrightState) -> bool:
    """Host-side invariant check (used by tests & property tests)."""
    arr = jax.device_get(state.arr)
    tab = jax.device_get(state.tab)
    num = int(state.num)
    n = arr.shape[0]
    import numpy as np

    ok = bool(np.all(np.sort(arr) == np.arange(n)))
    ok &= bool(np.all(arr[tab] == np.arange(n)))
    ok &= 0 <= num <= n
    return ok
