"""Parameter-update kernels for the θ | z conditional (paper §2, §4).

FlyMC composes with any conventional MCMC operator. We implement the three
the paper evaluates — random-walk Metropolis–Hastings (§4.1), MALA (§4.2),
and slice sampling (§4.3) — plus HMC as a bonus, all as pure JAX kernels over
a user-supplied log-density.

Interface: the target is ``f(θ) -> (logpdf, aux)``. ``aux`` is an arbitrary
pytree recomputed at every density evaluation; FlyMC uses it to cache the
bright-point log-likelihood gap δ_n = log L_n - log B_n at the *current* θ so
the z-update can reuse those evaluations (Algorithm 2 line 4: "cached from θ
update"). Every kernel returns the number of density evaluations it made —
FlyMC converts that into likelihood-query counts (Table 1's cost metric).

All kernels are shard-agnostic: run replicated with identical RNG keys, they
make identical decisions on every shard while ``f`` internally psums
shard-local likelihood sums (DESIGN.md §5).
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

LogDensityFn = Callable[[jax.Array], tuple[jax.Array, Any]]


class SamplerState(NamedTuple):
    theta: jax.Array
    lp: jax.Array  # cached log-density at theta
    grad: jax.Array  # cached gradient (zeros for gradient-free kernels)
    aux: Any  # cached aux from the last evaluation at theta


class StepInfo(NamedTuple):
    accept_prob: jax.Array  # acceptance probability (or 1.0 for slice)
    accepted: jax.Array  # bool — proposal accepted
    n_evals: jax.Array  # int32 — density evaluations this step


def init_state(
    f: LogDensityFn, theta: jax.Array, with_grad: bool = False
) -> SamplerState:
    if with_grad:
        (lp, aux), grad = jax.value_and_grad(f, has_aux=True)(theta)
    else:
        lp, aux = f(theta)
        grad = jnp.zeros_like(theta)
    return SamplerState(theta, lp, grad, aux)


# ---------------------------------------------------------------------------
# Random-walk Metropolis–Hastings
# ---------------------------------------------------------------------------


def rwmh_step(
    f: LogDensityFn, key: jax.Array, state: SamplerState, step_size: jax.Array
) -> tuple[SamplerState, StepInfo]:
    k_prop, k_acc = jax.random.split(key)
    eta = step_size * jax.random.normal(k_prop, state.theta.shape, state.theta.dtype)
    theta_p = state.theta + eta
    lp_p, aux_p = f(theta_p)
    log_ratio = lp_p - state.lp
    accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
    accepted = jnp.log(jax.random.uniform(k_acc, (), state.lp.dtype)) < log_ratio
    new = jax.tree.map(
        lambda a, b: jnp.where(accepted, a, b),
        SamplerState(theta_p, lp_p, state.grad, aux_p),
        state,
    )
    return new, StepInfo(accept_prob, accepted, jnp.int32(1))


# ---------------------------------------------------------------------------
# Metropolis-adjusted Langevin (MALA)
# ---------------------------------------------------------------------------


def mala_step(
    f: LogDensityFn, key: jax.Array, state: SamplerState, step_size: jax.Array
) -> tuple[SamplerState, StepInfo]:
    vg = jax.value_and_grad(f, has_aux=True)
    k_prop, k_acc = jax.random.split(key)
    eps2 = step_size * step_size
    mean_fwd = state.theta + 0.5 * eps2 * state.grad
    theta_p = mean_fwd + step_size * jax.random.normal(
        k_prop, state.theta.shape, state.theta.dtype
    )
    (lp_p, aux_p), grad_p = vg(theta_p)
    mean_rev = theta_p + 0.5 * eps2 * grad_p
    log_q_fwd = -jnp.sum(jnp.square(theta_p - mean_fwd)) / (2.0 * eps2)
    log_q_rev = -jnp.sum(jnp.square(state.theta - mean_rev)) / (2.0 * eps2)
    log_ratio = (lp_p - state.lp) + (log_q_rev - log_q_fwd)
    accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
    accepted = jnp.log(jax.random.uniform(k_acc, (), state.lp.dtype)) < log_ratio
    new = jax.tree.map(
        lambda a, b: jnp.where(accepted, a, b),
        SamplerState(theta_p, lp_p, grad_p, aux_p),
        state,
    )
    return new, StepInfo(accept_prob, accepted, jnp.int32(1))


# ---------------------------------------------------------------------------
# Slice sampling (Neal 2003) along a random direction
# ---------------------------------------------------------------------------


def slice_step(
    f: LogDensityFn,
    key: jax.Array,
    state: SamplerState,
    width: jax.Array,
    max_step_out: int = 8,
    max_shrink: int = 32,
) -> tuple[SamplerState, StepInfo]:
    """One slice-sampling update along a uniformly random direction.

    Stepping-out + shrinkage per Neal (2003) §4, run in lax.while_loops so the
    (variable) number of likelihood evaluations is data-dependent exactly as
    in the paper's OPV experiment. Loops are capped (``max_step_out``,
    ``max_shrink``); at the shrinkage cap we return the current point, which
    is always inside the slice.
    """
    k_dir, k_h, k_u, k_shrink = jax.random.split(key, 4)
    d = jax.random.normal(k_dir, state.theta.shape, state.theta.dtype)
    d = d / jnp.sqrt(jnp.sum(jnp.square(d)))
    log_y = state.lp + jnp.log(jax.random.uniform(k_h, (), state.lp.dtype))

    f_at = lambda s: f(state.theta + s * d)

    # --- stepping out -----------------------------------------------------
    u = jax.random.uniform(k_u, (), state.lp.dtype)
    lo0, hi0 = -width * u, width * (1.0 - u)

    def expand(bound, sign):
        def cond(c):
            b, lp_b, i = c
            return (lp_b > log_y) & (i < max_step_out)

        def body(c):
            b, _, i = c
            b2 = b + sign * width
            lp2, _ = f_at(b2)
            return (b2, lp2, i + 1)

        lp_b, _ = f_at(bound)
        b, _, i = jax.lax.while_loop(cond, body, (bound, lp_b, jnp.int32(0)))
        return b, i + 1  # +1 for the initial edge evaluation

    lo, n_lo = expand(lo0, -1.0)
    hi, n_hi = expand(hi0, +1.0)

    # --- shrinkage ---------------------------------------------------------
    def cond(c):
        _, _, _, _, _, done, i = c
        return (~done) & (i < max_shrink)

    def body(c):
        lo_, hi_, s, lp_s, aux_s, _, i = c
        k = jax.random.fold_in(k_shrink, i)
        s2 = lo_ + (hi_ - lo_) * jax.random.uniform(k, (), state.lp.dtype)
        lp2, aux2 = f_at(s2)
        ok = lp2 > log_y
        lo2 = jnp.where(ok | (s2 >= 0.0), lo_, s2)
        hi2 = jnp.where(ok | (s2 < 0.0), hi_, s2)
        s_n = jnp.where(ok, s2, s)
        lp_n = jnp.where(ok, lp2, lp_s)
        aux_n = jax.tree.map(lambda a, b: jnp.where(ok, a, b), aux2, aux_s)
        return (lo2, hi2, s_n, lp_n, aux_n, ok, i + 1)

    init = (lo, hi, jnp.zeros((), state.lp.dtype), state.lp, state.aux,
            jnp.bool_(False), jnp.int32(0))
    lo, hi, s, lp_new, aux_new, done, n_shrink = jax.lax.while_loop(
        cond, body, init
    )
    theta_new = state.theta + s * d
    n_evals = n_lo + n_hi + n_shrink
    new = SamplerState(theta_new, lp_new, state.grad, aux_new)
    return new, StepInfo(jnp.ones((), state.lp.dtype), done, n_evals)


# ---------------------------------------------------------------------------
# Hamiltonian Monte Carlo (bonus operator)
# ---------------------------------------------------------------------------


def hmc_step(
    f: LogDensityFn,
    key: jax.Array,
    state: SamplerState,
    step_size: jax.Array,
    n_leapfrog: int = 10,
) -> tuple[SamplerState, StepInfo]:
    vg = jax.value_and_grad(f, has_aux=True)
    k_mom, k_acc = jax.random.split(key)
    p0 = jax.random.normal(k_mom, state.theta.shape, state.theta.dtype)

    def leapfrog(carry, _):
        theta, p, grad = carry
        p_half = p + 0.5 * step_size * grad
        theta_n = theta + step_size * p_half
        (_, _), grad_n = vg(theta_n)
        p_n = p_half + 0.5 * step_size * grad_n
        return (theta_n, p_n, grad_n), None

    (theta_p, p_p, grad_p), _ = jax.lax.scan(
        leapfrog, (state.theta, p0, state.grad), None, length=n_leapfrog
    )
    (lp_p, aux_p) = f(theta_p)
    h0 = -state.lp + 0.5 * jnp.sum(jnp.square(p0))
    h1 = -lp_p + 0.5 * jnp.sum(jnp.square(p_p))
    log_ratio = h0 - h1
    accept_prob = jnp.minimum(1.0, jnp.exp(jnp.minimum(log_ratio, 0.0)))
    accepted = jnp.log(jax.random.uniform(k_acc, (), state.lp.dtype)) < log_ratio
    new = jax.tree.map(
        lambda a, b: jnp.where(accepted, a, b),
        SamplerState(theta_p, lp_p, grad_p, aux_p),
        state,
    )
    return new, StepInfo(accept_prob, accepted, jnp.int32(n_leapfrog + 1))


# ---------------------------------------------------------------------------
# Step-size adaptation (burn-in only; paper tunes to 0.234 / 0.574)
# ---------------------------------------------------------------------------


def adapt_step_size(
    log_step: jax.Array,
    accept_prob: jax.Array,
    target: float,
    iteration: jax.Array,
    gain: float = 0.05,
) -> jax.Array:
    """Robbins–Monro update of log step size toward a target accept rate."""
    lr = gain / jnp.sqrt(1.0 + iteration.astype(log_step.dtype))
    return log_step + lr * (accept_prob - target)


# ---------------------------------------------------------------------------
# Kernel registry (repro.api dispatches through this, not through strings)
# ---------------------------------------------------------------------------


class KernelSpec(NamedTuple):
    """Registry entry: a θ-kernel plus the metadata the drivers need.

    ``step_fn(f, key, state, <scale_param>=..., **static_kwargs)`` is the raw
    kernel; ``scale_param`` names its tuning-scale argument ("step_size" or
    "width"), which :func:`bind` normalizes away so callers never special-case
    individual kernels.
    """

    step_fn: Callable
    needs_grad: bool
    target_accept: float
    scale_param: str = "step_size"


KERNEL_REGISTRY: dict[str, KernelSpec] = {}

# Legacy views, kept in sync by register_kernel().
KERNELS: dict[str, Callable] = {}
NEEDS_GRAD: dict[str, bool] = {}
TARGET_ACCEPT: dict[str, float] = {}


def register_kernel(
    name: str,
    step_fn: Callable,
    *,
    needs_grad: bool,
    target_accept: float,
    scale_param: str = "step_size",
) -> None:
    """Register a θ-kernel under ``name`` for use by specs and repro.api."""
    KERNEL_REGISTRY[name] = KernelSpec(
        step_fn, needs_grad, target_accept, scale_param
    )
    KERNELS[name] = step_fn
    NEEDS_GRAD[name] = needs_grad
    TARGET_ACCEPT[name] = target_accept


def get_kernel(name: str) -> KernelSpec:
    try:
        return KERNEL_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown θ-kernel {name!r}; registered: {sorted(KERNEL_REGISTRY)}"
        ) from None


def bind(name: str, f: LogDensityFn, static_kwargs=()) -> Callable:
    """Uniform ``(key, state, scale) -> (state, info)`` for a registered kernel.

    The kernel's own scale-parameter name (``step_size`` vs slice's ``width``)
    is resolved from the registry, so drivers need no per-kernel branches.
    """
    ks = get_kernel(name)
    kw = dict(static_kwargs)

    def kernel(key: jax.Array, state: SamplerState, scale: jax.Array):
        return ks.step_fn(f, key, state, **{ks.scale_param: scale}, **kw)

    return kernel


register_kernel(
    "rwmh", rwmh_step, needs_grad=False, target_accept=0.234
)
register_kernel(
    "mala", mala_step, needs_grad=True, target_accept=0.574
)
register_kernel(
    "slice", slice_step, needs_grad=False, target_accept=1.0,
    scale_param="width",
)
register_kernel(
    "hmc", hmc_step, needs_grad=True, target_accept=0.8
)


def make_kernel(name: str, f: LogDensityFn, **kwargs) -> Callable:
    """Bind a named θ-kernel to a log-density; returns (key, state, step)->(state, info)."""
    step_fn = get_kernel(name).step_fn
    return partial(step_fn, f, **kwargs)
