"""FlyMC core: the paper's contribution as composable JAX modules.

  bounds          — collapsible likelihood lower bounds (§3.1)
  brightness      — O(1) bright/dark partition structure (§3.3, Fig. 3)
  samplers        — θ-kernels: RWMH, MALA, slice, HMC (§4)
  flymc           — the FlyMC chain: padded bright buffer, implicit/explicit
                    z-resampling, exactness-preserving capacity growth (§2–3)
  pseudo_marginal — the Bernoulli(½) pseudo-marginal special case (§5)
  diagnostics     — ESS / autocorrelation / R-hat (Table 1 metrics)
"""

from repro.core import brightness, diagnostics, samplers
from repro.core.bounds import (
    Bound,
    CollapsedStats,
    FusedBound,
    GLMData,
    LogisticBound,
    SoftmaxBound,
    StudentTBound,
    fused_family_of,
    gaussian_log_prior,
    get_bound,
    laplace_log_prior,
    psum_stats,
    register_bound,
)
from repro.core.flymc import (
    FlyMCSpec,
    FlyMCState,
    StepStats,
    flymc_step,
    init_chain,
    init_chain_state,
    log_expm1,
    make_joint_logpost,
    resize_state,
    run_chain,
)
from repro.core.samplers import get_kernel, register_kernel

__all__ = [
    "Bound",
    "CollapsedStats",
    "FusedBound",
    "GLMData",
    "LogisticBound",
    "SoftmaxBound",
    "StudentTBound",
    "FlyMCSpec",
    "FlyMCState",
    "StepStats",
    "brightness",
    "diagnostics",
    "flymc_step",
    "fused_family_of",
    "gaussian_log_prior",
    "get_bound",
    "get_kernel",
    "init_chain",
    "init_chain_state",
    "laplace_log_prior",
    "log_expm1",
    "make_joint_logpost",
    "psum_stats",
    "register_bound",
    "register_kernel",
    "resize_state",
    "run_chain",
    "samplers",
]
