"""FlyMC core: the paper's contribution as composable JAX modules.

  bounds          — collapsible likelihood lower bounds (§3.1)
  brightness      — O(1) bright/dark partition structure (§3.3, Fig. 3)
  samplers        — θ-kernels: RWMH, MALA, slice, HMC (§4)
  flymc           — the FlyMC chain: padded bright buffer, implicit/explicit
                    z-resampling, exactness-preserving capacity growth (§2–3)
  pseudo_marginal — the Bernoulli(½) pseudo-marginal special case (§5)
  diagnostics     — ESS / autocorrelation / R-hat (Table 1 metrics)
"""

from repro.core import brightness, diagnostics, samplers
from repro.core.bounds import (
    CollapsedStats,
    GLMData,
    LogisticBound,
    SoftmaxBound,
    StudentTBound,
    gaussian_log_prior,
    laplace_log_prior,
    psum_stats,
)
from repro.core.flymc import (
    FlyMCSpec,
    FlyMCState,
    StepStats,
    flymc_step,
    init_chain,
    log_expm1,
    make_joint_logpost,
    resize_state,
    run_chain,
)

__all__ = [
    "CollapsedStats",
    "GLMData",
    "LogisticBound",
    "SoftmaxBound",
    "StudentTBound",
    "FlyMCSpec",
    "FlyMCState",
    "StepStats",
    "brightness",
    "diagnostics",
    "flymc_step",
    "gaussian_log_prior",
    "init_chain",
    "laplace_log_prior",
    "log_expm1",
    "make_joint_logpost",
    "psum_stats",
    "resize_state",
    "run_chain",
    "samplers",
]
