"""Pseudo-marginal MCMC as a FlyMC special case (paper §5).

"If we sampled each of the variables {z_n} as a Bernoulli random variable
with success probability 0.5, then the joint posterior we have been using
becomes an unbiased estimator of the original posterior over θ, up to
normalization. Running pseudo-marginal MCMC using this unbiased estimator
would be a special case of FlyMC: namely FlyMC with z and θ updated
simultaneously with Metropolis–Hastings updates."

We implement exactly that joint-update kernel. The z proposal is iid
Bernoulli(½), independent of the current state, so the proposal ratio for z
cancels and the MH ratio is the plain joint-density ratio. This module is a
validity check (the marginal over θ must match the FlyMC/full-data
posterior), not a performance path: with p=½ half the data is bright.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.bounds import CollapsedStats, GLMData
from repro.core.flymc import log_expm1


class PMState(NamedTuple):
    theta: jax.Array
    z: jax.Array  # (N,) bool
    lp: jax.Array
    rng: jax.Array


def joint_log_density(
    bound: Any,
    log_prior: Callable,
    data: GLMData,
    stats: CollapsedStats,
    theta: jax.Array,
    z: jax.Array,
) -> jax.Array:
    """log p̃(θ) + Σ_{z=1} log L̃_n — evaluated densely (validity harness)."""
    delta = bound.log_lik(theta, data) - bound.log_bound(theta, data)
    s = jnp.sum(jnp.where(z, log_expm1(delta), 0.0))
    return log_prior(theta) + bound.collapsed(theta, stats) + s


def init(
    bound, log_prior, data, stats, theta0: jax.Array, key: jax.Array
) -> PMState:
    k_z, k_chain = jax.random.split(key)
    z0 = jax.random.bernoulli(k_z, 0.5, (data.x.shape[0],))
    lp0 = joint_log_density(bound, log_prior, data, stats, theta0, z0)
    return PMState(theta0, z0, lp0, k_chain)


def step(
    bound,
    log_prior,
    data: GLMData,
    stats: CollapsedStats,
    state: PMState,
    step_size: float,
) -> tuple[PMState, jax.Array]:
    """One joint (θ, z) MH update with z' ~ Bernoulli(½)^N."""
    k_theta, k_z, k_acc, k_next = jax.random.split(state.rng, 4)
    theta_p = state.theta + step_size * jax.random.normal(
        k_theta, state.theta.shape, state.theta.dtype
    )
    z_p = jax.random.bernoulli(k_z, 0.5, state.z.shape)
    lp_p = joint_log_density(bound, log_prior, data, stats, theta_p, z_p)
    log_ratio = lp_p - state.lp  # symmetric θ proposal; z proposal cancels
    accepted = jnp.log(jax.random.uniform(k_acc, (), state.lp.dtype)) < log_ratio
    new = PMState(
        theta=jnp.where(accepted, theta_p, state.theta),
        z=jnp.where(accepted, z_p, state.z),
        lp=jnp.where(accepted, lp_p, state.lp),
        rng=k_next,
    )
    return new, accepted
