"""MCMC output analysis: autocorrelation, ESS, R-hat (paper §4, Table 1).

The paper reports "effective samples per 1000 iterations" computed with
R-CODA. We implement the standard initial-monotone-positive-sequence
estimator (Geyer 1992) of the integrated autocorrelation time τ, giving
ESS = n/τ; it is validated against the analytic τ of an AR(1) process in
``tests/test_diagnostics.py``. Host-side numpy: diagnostics are offline.
"""

from __future__ import annotations

import numpy as np


def autocovariance(x: np.ndarray, max_lag: int | None = None) -> np.ndarray:
    """Biased autocovariance estimates via FFT, lags 0..max_lag."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if max_lag is None:
        max_lag = n - 1
    xc = x - x.mean()
    size = 1 << (2 * n - 1).bit_length()
    f = np.fft.rfft(xc, size)
    acov = np.fft.irfft(f * np.conj(f), size)[: max_lag + 1].real / n
    return acov


def integrated_autocorr_time(x: np.ndarray) -> float:
    """Geyer initial monotone positive sequence estimator of τ."""
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if n < 4 or np.allclose(x, x[0]):
        return float(n)  # degenerate chain: no information
    acov = autocovariance(x)
    if acov[0] <= 0:
        return float(n)
    rho = acov / acov[0]
    # Pair sums Γ_k = ρ_{2k} + ρ_{2k+1}; keep while positive and monotone.
    max_pairs = (len(rho) - 1) // 2
    tau = 0.0
    prev = np.inf
    for k in range(max_pairs):
        gamma = rho[2 * k] + rho[2 * k + 1]
        if gamma <= 0:
            break
        gamma = min(gamma, prev)  # enforce monotone decrease
        prev = gamma
        tau += 2.0 * gamma
    tau -= 1.0  # τ = -1 + 2 Σ_k Γ_k  (Γ_0 = ρ_0 + ρ_1; iid chain → τ = 1)
    return float(max(tau, 1.0))


def effective_sample_size(x: np.ndarray) -> float:
    """ESS of a 1-D chain; for multi-dim, apply per-coordinate and min."""
    x = np.asarray(x)
    if x.ndim == 1:
        return x.shape[0] / integrated_autocorr_time(x)
    return float(
        min(
            x.shape[0] / integrated_autocorr_time(x[:, j])
            for j in range(x.shape[1])
        )
    )


def ess_per_1000_iters(x: np.ndarray) -> float:
    """The paper's Table-1 metric."""
    x = np.asarray(x)
    return 1000.0 * effective_sample_size(x) / x.shape[0]


def split_r_hat(chains: np.ndarray) -> float:
    """Split-R̂ (Gelman et al.) over chains of shape (n_chains, n_iters)."""
    chains = np.asarray(chains, np.float64)
    m, n = chains.shape
    half = n // 2
    splits = np.concatenate([chains[:, :half], chains[:, half : 2 * half]], 0)
    k, h = splits.shape
    means = splits.mean(axis=1)
    w = splits.var(axis=1, ddof=1).mean()
    b = h * means.var(ddof=1)
    var_plus = (h - 1) / h * w + b / h
    return float(np.sqrt(var_plus / w)) if w > 0 else float("inf")
