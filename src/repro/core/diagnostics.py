"""MCMC output analysis: autocorrelation, ESS, R-hat (paper §4, Table 1).

The paper reports "effective samples per 1000 iterations" computed with
R-CODA. We implement the standard initial-monotone-positive-sequence
estimator (Geyer 1992) of the integrated autocorrelation time τ, giving
ESS = n/τ; it is validated against the analytic τ of an AR(1) process in
``tests/test_diagnostics.py``. Host-side numpy: these are the offline
estimators. The streaming path (:mod:`repro.api.collectors`) reuses the
moment→estimate functions here (:func:`rhat_from_split_moments`,
:func:`tau_from_batch_means`) so online and offline results cannot drift.

Everything is vectorized over a trailing coordinate axis: ``(n,)`` chains
behave exactly as before (bitwise — the batched FFT and the masked lag loop
perform the identical per-column operations), and ``(n, D)`` inputs run one
batched rfft instead of D Python-loop FFT passes.
"""

from __future__ import annotations

import numpy as np


def autocovariance(x: np.ndarray, max_lag: int | None = None) -> np.ndarray:
    """Biased autocovariance estimates via FFT, lags 0..max_lag.

    ``x`` is ``(n,)`` or ``(n, D)``; the transform runs along axis 0 (one
    batched rfft for all D coordinates).
    """
    x = np.asarray(x, np.float64)
    n = x.shape[0]
    if max_lag is None:
        max_lag = n - 1
    xc = x - x.mean(axis=0)
    size = 1 << (2 * n - 1).bit_length()
    f = np.fft.rfft(xc, size, axis=0)
    acov = np.fft.irfft(f * np.conj(f), size, axis=0)[: max_lag + 1].real / n
    return acov


def _taus(x: np.ndarray) -> np.ndarray:
    """Geyer τ per coordinate of an (n, D) chain array, vectorized.

    One batched FFT; the initial-monotone-positive-sequence truncation runs
    as a masked loop over lag pairs (early exit once every coordinate has
    terminated), performing per-column exactly the scalar estimator's
    operations — a 1-column input reproduces the scalar path bitwise.
    Degenerate coordinates (n < 4, constant chain, non-positive variance)
    report τ = n, as before.
    """
    x = np.asarray(x, np.float64)
    n, d = x.shape
    fallback = np.full(d, float(n))
    if n < 4:
        return fallback
    # per-coordinate np.allclose(x, x[0]) (rtol=1e-5, atol=1e-8)
    degenerate = np.all(
        np.abs(x - x[0]) <= 1e-8 + 1e-5 * np.abs(x[0]), axis=0
    )
    acov = autocovariance(x)
    ok = ~degenerate & (acov[0] > 0)
    if not ok.any():
        return fallback
    rho = acov / np.where(acov[0] > 0, acov[0], 1.0)
    # Pair sums Γ_k = ρ_{2k} + ρ_{2k+1}; keep while positive and monotone.
    max_pairs = (rho.shape[0] - 1) // 2
    tau = np.zeros(d)
    prev = np.full(d, np.inf)
    active = ok.copy()
    for k in range(max_pairs):
        if not active.any():
            break
        gamma = rho[2 * k] + rho[2 * k + 1]
        active &= gamma > 0
        gamma = np.minimum(gamma, prev)  # enforce monotone decrease
        prev = np.where(active, gamma, prev)
        tau = np.where(active, tau + 2.0 * gamma, tau)
    # τ = -1 + 2 Σ_k Γ_k  (Γ_0 = ρ_0 + ρ_1; iid chain → τ = 1)
    return np.where(ok, np.maximum(tau - 1.0, 1.0), fallback)


def integrated_autocorr_time(x: np.ndarray) -> float:
    """Geyer initial monotone positive sequence estimator of τ (1-D chain)."""
    x = np.asarray(x, np.float64)
    if x.ndim != 1:
        raise ValueError("integrated_autocorr_time expects a 1-D chain; "
                         "effective_sample_size handles (n, D)")
    return float(_taus(x[:, None])[0])


def effective_sample_size(x: np.ndarray) -> float:
    """ESS of a 1-D chain; for (n, D), the per-coordinate minimum."""
    x = np.asarray(x)
    n = x.shape[0]
    if x.ndim == 1:
        return n / integrated_autocorr_time(x)
    return float((n / _taus(x)).min())


def ess_per_1000_iters(x: np.ndarray) -> float:
    """The paper's Table-1 metric."""
    x = np.asarray(x)
    return 1000.0 * effective_sample_size(x) / x.shape[0]


def rhat_from_split_moments(count, means, variances):
    """Split-R̂ from per-split first/second moments — the shared estimator.

    ``count`` is the per-split length h; ``means``/``variances`` are the
    per-split sample means and ``ddof=1`` variances, shape ``(k,)`` or
    ``(k, D)`` for k splits. Both the offline :func:`split_r_hat` (two-pass
    numpy moments) and the streaming :class:`repro.api.collectors.RHat`
    (Welford carries) feed this same function.
    """
    means = np.asarray(means, np.float64)
    variances = np.asarray(variances, np.float64)
    w = variances.mean(axis=0)
    b = count * means.var(axis=0, ddof=1)
    var_plus = (count - 1) / count * w + b / count
    with np.errstate(divide="ignore", invalid="ignore"):
        out = np.where(w > 0, np.sqrt(var_plus / w), np.inf)
    return out if means.ndim > 1 else float(out)


def split_r_hat(chains: np.ndarray) -> float:
    """Split-R̂ (Gelman et al.) over chains of shape (n_chains, n_iters).

    A ``(n_chains, n_iters, D)`` input reduces per-coordinate and returns
    the maximum R̂ — the coordinate that binds convergence.
    """
    chains = np.asarray(chains, np.float64)
    half = chains.shape[1] // 2
    splits = np.concatenate([chains[:, :half], chains[:, half : 2 * half]], 0)
    means = splits.mean(axis=1)
    variances = splits.var(axis=1, ddof=1)
    if chains.ndim == 3:  # one vectorized pass over the coordinate axis
        return float(np.max(rhat_from_split_moments(half, means, variances)))
    return float(rhat_from_split_moments(half, means, variances))


def tau_from_batch_means(batch_means, batch_len: int, chain_var):
    """Batch-means τ̂ = batch_len · Var(batch means) / Var(chain).

    ``batch_means`` is ``(B,)`` or ``(B, D)``; ``chain_var`` the matching
    whole-chain ``ddof=1`` variance. Shared by the offline
    :func:`batch_means_ess` and the streaming
    :class:`repro.api.collectors.BatchMeansESS`. Zero-variance chains report
    τ = B·batch_len (one effective sample), matching the Geyer convention.
    """
    batch_means = np.asarray(batch_means, np.float64)
    chain_var = np.asarray(chain_var, np.float64)
    vb = batch_means.var(axis=0, ddof=1)
    n_total = float(batch_means.shape[0] * batch_len)
    with np.errstate(divide="ignore", invalid="ignore"):
        tau = np.where(chain_var > 0, batch_len * vb / chain_var, n_total)
    return tau


def batch_means_ess(x: np.ndarray, num_batches: int = 32) -> float:
    """Offline batch-means ESS of a chain ``(n,)`` or ``(n, D)``.

    Mirrors the streaming collector's truncation exactly: batches are
    ``batch_len = max(1, n // num_batches)`` long and iterations past
    ``num_batches · batch_len`` are dropped. Coarser than the Geyer
    estimator but computable as a pure streaming reduction; the two agree
    on well-behaved chains.
    """
    x = np.asarray(x, np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = x.shape[0]
    batch_len = max(1, n // num_batches)
    n_used = min(n, num_batches * batch_len)
    nb = n_used // batch_len
    if nb < 2 or n_used < 2:
        return float("nan")
    used = x[: nb * batch_len]
    batch_means = used.reshape(nb, batch_len, -1).mean(axis=1)
    chain_var = x[:n_used].var(axis=0, ddof=1)
    tau = np.maximum(
        tau_from_batch_means(batch_means, batch_len, chain_var), 1.0
    )
    return float((n_used / tau).min())
