"""Shared FlyMC numerics — the single source of truth for δ and log L̃ math.

Everything here is consumed by *both* the pure-jnp reference path
(:mod:`repro.core.bounds`, :mod:`repro.core.flymc`,
:mod:`repro.kernels.bright_glm.ref`) and the fused Pallas kernel
(:mod:`repro.kernels.bright_glm.kernel`). Keeping one copy is a correctness
requirement, not a style choice: the two paths feed the same MH accept
decisions, so a guard present on one side and missing on the other (as
happened with the ``min(d, 80)`` clamp in ``log_expm1``) silently changes
the realized chain for extreme δ.

All functions are plain jnp element-wise math — safe to trace inside a
Pallas kernel body and under jit/vmap/shard_map alike.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_DELTA_FLOOR = 1e-10  # δ = logL - logB ≥ 0 in exact math; clamp FP noise.


def log_expm1(delta: jax.Array) -> jax.Array:
    """Stable log(exp(δ) - 1) = log L̃ for δ ≥ 0.

    Both branches receive guarded inputs (double-where): in f32,
    exp(-δ) rounds to 1.0 for δ ≲ 1e-8 and log1p(-1.0) = -inf would poison
    the gradient of the *unselected* branch (0 · inf = NaN). The inner
    ``min(d, 80)`` keeps exp(-δ) from flushing to a denormal-zero whose
    log1p gradient is garbage for extreme δ.
    """
    d = jnp.maximum(delta, _DELTA_FLOOR)
    small = d < 15.0
    d_small = jnp.where(small, d, 1.0)
    d_big = jnp.where(small, 20.0, d)
    return jnp.where(
        small,
        jnp.log(jnp.expm1(d_small)),
        d_big + jnp.log1p(-jnp.exp(-jnp.minimum(d_big, 80.0))),
    )


# ---------------------------------------------------------------------------
# Jaakkola–Jordan (logistic) bound pieces
# ---------------------------------------------------------------------------


def jj_a(xi: jax.Array) -> jax.Array:
    """a(ξ) = -tanh(ξ/2)/(4ξ), with the ξ→0 limit -1/8 handled exactly."""
    safe = jnp.where(jnp.abs(xi) < 1e-4, 1.0, xi)
    a = -jnp.tanh(safe / 2.0) / (4.0 * safe)
    # Taylor: -1/8 + ξ²/96 + O(ξ⁴)
    return jnp.where(jnp.abs(xi) < 1e-4, -0.125 + xi * xi / 96.0, a)


def jj_c(xi: jax.Array) -> jax.Array:
    """c(ξ) = -a·ξ² + ξ/2 - log(eᶻ+1); tightness: log B(±ξ) = log σ(±ξ)."""
    return -jj_a(xi) * xi * xi + xi / 2.0 - jax.nn.softplus(xi)


def logistic_delta(s: jax.Array, xi: jax.Array) -> jax.Array:
    """δ = log L - log B for the Jaakkola–Jordan bound, s = t·θᵀx."""
    log_l = -jax.nn.softplus(-s)
    log_b = jj_a(xi) * s * s + 0.5 * s + jj_c(xi)
    return log_l - log_b


# ---------------------------------------------------------------------------
# Student-t tangent bound
# ---------------------------------------------------------------------------


def student_t_delta(
    r: jax.Array, xi: jax.Array, nu: float, sigma: float
) -> jax.Array:
    """δ for the tangent-in-r² Gaussian bound on the Student-t density.

    ``r`` is the residual t - θᵀx. The density's additive constants cancel
    in log L - log B, so only the log1p terms and the tangent remain.
    """
    z2 = (r / sigma) ** 2
    u0 = (xi / sigma) ** 2
    fprime = -((nu + 1.0) / 2.0) / (nu + u0)
    f_z = -((nu + 1.0) / 2.0) * jnp.log1p(z2 / nu)
    f_u0 = -((nu + 1.0) / 2.0) * jnp.log1p(u0 / nu)
    return f_z - (f_u0 + fprime * (z2 - u0))


# ---------------------------------------------------------------------------
# Böhning (softmax) bound — lane-padded variant for the Pallas kernel
# ---------------------------------------------------------------------------


def softmax_delta_padded(
    eta: jax.Array,  # (B, Kp) logits θx, columns ≥ n_classes are padding
    eta0: jax.Array,  # (B, Kp) tangency logits (data.xi), same padding
    t_onehot: jax.Array,  # (B, Kp) one-hot labels (0 on padding)
    n_classes: int,
) -> jax.Array:
    """δ = log L - log B for the Böhning bound on lane-padded (B, Kp) logits.

    Padding columns (k ≥ n_classes) are excluded from every reduction, so
    the result equals :class:`repro.core.bounds.SoftmaxBound`'s
    ``log_lik - log_bound`` on the unpadded (B, K) arrays. Kept next to the
    other δ formulas so kernel and reference share one definition of the
    masked math.
    """
    valid = (
        jax.lax.broadcasted_iota(jnp.int32, eta.shape, eta.ndim - 1) < n_classes
    )
    neg = jnp.asarray(-1e30, eta.dtype)

    def lse(e):  # masked logsumexp over the valid columns, (B, 1)
        e_m = jnp.where(valid, e, neg)
        m = jnp.max(e_m, axis=-1, keepdims=True)
        return m + jnp.log(
            jnp.sum(jnp.where(valid, jnp.exp(e_m - m), 0.0), axis=-1,
                    keepdims=True)
        )

    lse0 = lse(eta0)
    at_t = lambda e: jnp.sum(t_onehot * jnp.where(valid, e, 0.0), axis=-1)
    ll_eta = at_t(eta) - lse(eta)[..., 0]  # log L(η) = η[t] - lse(η)
    ll_eta0 = at_t(eta0) - lse0[..., 0]
    g = t_onehot - jnp.where(valid, jnp.exp(eta0 - lse0), 0.0)
    d = jnp.where(valid, eta - eta0, 0.0)
    # A = ½(I - 𝟙𝟙ᵀ/K) over the *valid* columns only (d is 0 on padding).
    a_d = 0.5 * (d - jnp.sum(d, axis=-1, keepdims=True) / n_classes)
    quad = jnp.sum(d * a_d, axis=-1)
    log_b = ll_eta0 + jnp.sum(g * d, axis=-1) - 0.5 * quad
    return ll_eta - log_b
