"""Shared FlyMC numerics — the single source of truth for δ and log L̃ math.

Everything here is consumed by *both* the pure-jnp reference path
(:mod:`repro.core.bounds`, :mod:`repro.core.flymc`,
:mod:`repro.kernels.bright_glm.ref`) and the fused Pallas kernel
(:mod:`repro.kernels.bright_glm.kernel`). Keeping one copy is a correctness
requirement, not a style choice: the two paths feed the same MH accept
decisions, so a guard present on one side and missing on the other (as
happened with the ``min(d, 80)`` clamp in ``log_expm1``) silently changes
the realized chain for extreme δ.

All functions are plain jnp element-wise math — safe to trace inside a
Pallas kernel body and under jit/vmap/shard_map alike.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_DELTA_FLOOR = 1e-10  # δ = logL - logB ≥ 0 in exact math; clamp FP noise.


def log_expm1(delta: jax.Array) -> jax.Array:
    """Stable log(exp(δ) - 1) = log L̃ for δ ≥ 0.

    Both branches receive guarded inputs (double-where): in f32,
    exp(-δ) rounds to 1.0 for δ ≲ 1e-8 and log1p(-1.0) = -inf would poison
    the gradient of the *unselected* branch (0 · inf = NaN). The inner
    ``min(d, 80)`` keeps exp(-δ) from flushing to a denormal-zero whose
    log1p gradient is garbage for extreme δ.
    """
    d = jnp.maximum(delta, _DELTA_FLOOR)
    small = d < 15.0
    d_small = jnp.where(small, d, 1.0)
    d_big = jnp.where(small, 20.0, d)
    return jnp.where(
        small,
        jnp.log(jnp.expm1(d_small)),
        d_big + jnp.log1p(-jnp.exp(-jnp.minimum(d_big, 80.0))),
    )


# ---------------------------------------------------------------------------
# Counter-based per-datum RNG (shared by the fused z-update kernel & its ref)
# ---------------------------------------------------------------------------
#
# The z-kernel's exactness story needs per-*datum* randomness (flymc.py's
# capacity/chunk-invariance contract), but materializing three (N,) uniform
# arrays per step is exactly the O(N) work the fused engine exists to kill.
# Instead each uniform is a pure function  u = f(step_key, draw_id, datum):
# one Threefry-2x32 block (Salmon et al. 2011, the same cipher behind jax's
# PRNG) whose counter words are (draw_id, datum_index). The Pallas kernel
# evaluates it on streamed (block, 128) tiles, the jnp side on whatever
# small buffer it holds (bright slots, compacted candidates) — same bits
# either way, never a length-N intermediate.
#
# Everything is carried in int32 lanes (Mosaic's native integer width):
# adds wrap mod 2^32 identically to uint32, and right shifts go through
# lax.shift_right_logical so sign bits never smear.

# Draw-id words: one independent stream per Algorithm-2 decision.
DRAW_DARKEN = 0  # bright → dark accept uniform (u1)
DRAW_CAND = 1  # dark → bright candidate selection (u2)
DRAW_BRIGHT = 2  # candidate brighten accept uniform (u3)

_UNIFORM_BITS = 24  # bits24 ∈ [0, 2^24): exact in f32, u = bits24 · 2⁻²⁴


def _rotl32(x: jax.Array, d: int) -> jax.Array:
    return (x << d) | jax.lax.shift_right_logical(x, 32 - d)


def threefry2x32(
    k0: jax.Array, k1: jax.Array, x0: jax.Array, x1: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Threefry-2x32, 20 rounds, on int32 lanes (bit-compatible with uint32).

    Safe to trace inside a Pallas kernel body (adds/xors/shifts only) and in
    plain jnp — the fused z-update kernel and its pure-jnp reference import
    this one definition, so their bit streams cannot drift.
    """
    rotations = ((13, 15, 26, 6), (17, 29, 16, 24))
    k0 = k0.astype(jnp.int32)
    k1 = k1.astype(jnp.int32)
    ks = (k0, k1, k0 ^ k1 ^ jnp.int32(0x1BD11BDA))
    x0 = (x0.astype(jnp.int32) + k0).astype(jnp.int32)
    x1 = (x1.astype(jnp.int32) + k1).astype(jnp.int32)
    for r in range(5):
        for d in rotations[r % 2]:
            x0 = x0 + x1
            x1 = _rotl32(x1, d) ^ x0
        x0 = x0 + ks[(r + 1) % 3]
        x1 = x1 + ks[(r + 2) % 3] + jnp.int32(r + 1)
    return x0, x1


def counter_bits24(
    key_words: jax.Array, draw_id: int, datum: jax.Array
) -> jax.Array:
    """24-bit random integers keyed on (step key, draw stream, datum index).

    ``key_words`` is a (2,) int32 array (bitcast PRNG key data); ``datum``
    any int32 array of datum indices. Returns int32 in [0, 2^24) with the
    same shape as ``datum``.
    """
    x0 = jnp.full(datum.shape, draw_id, jnp.int32)
    b0, _ = threefry2x32(key_words[0], key_words[1], x0, datum.astype(jnp.int32))
    return jax.lax.shift_right_logical(b0, 32 - _UNIFORM_BITS)


def counter_uniform(
    key_words: jax.Array, draw_id: int, datum: jax.Array
) -> jax.Array:
    """Per-datum U[0, 1) floats (24-bit grid) from :func:`counter_bits24`."""
    return counter_bits24(key_words, draw_id, datum).astype(jnp.float32) * (
        1.0 / (1 << _UNIFORM_BITS)
    )


def key_words_of(key: jax.Array) -> jax.Array:
    """(2,) int32 counter-RNG key words from a jax PRNG key (typed or raw)."""
    data = key
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    return jax.lax.bitcast_convert_type(data.reshape(-1)[:2], jnp.int32)


# ---------------------------------------------------------------------------
# Jaakkola–Jordan (logistic) bound pieces
# ---------------------------------------------------------------------------


def jj_a(xi: jax.Array) -> jax.Array:
    """a(ξ) = -tanh(ξ/2)/(4ξ), with the ξ→0 limit -1/8 handled exactly."""
    safe = jnp.where(jnp.abs(xi) < 1e-4, 1.0, xi)
    a = -jnp.tanh(safe / 2.0) / (4.0 * safe)
    # Taylor: -1/8 + ξ²/96 + O(ξ⁴)
    return jnp.where(jnp.abs(xi) < 1e-4, -0.125 + xi * xi / 96.0, a)


def jj_c(xi: jax.Array) -> jax.Array:
    """c(ξ) = -a·ξ² + ξ/2 - log(eᶻ+1); tightness: log B(±ξ) = log σ(±ξ)."""
    return -jj_a(xi) * xi * xi + xi / 2.0 - jax.nn.softplus(xi)


def logistic_delta(s: jax.Array, xi: jax.Array) -> jax.Array:
    """δ = log L - log B for the Jaakkola–Jordan bound, s = t·θᵀx."""
    log_l = -jax.nn.softplus(-s)
    log_b = jj_a(xi) * s * s + 0.5 * s + jj_c(xi)
    return log_l - log_b


# ---------------------------------------------------------------------------
# Student-t tangent bound
# ---------------------------------------------------------------------------


def student_t_delta(
    r: jax.Array, xi: jax.Array, nu: float, sigma: float
) -> jax.Array:
    """δ for the tangent-in-r² Gaussian bound on the Student-t density.

    ``r`` is the residual t - θᵀx. The density's additive constants cancel
    in log L - log B, so only the log1p terms and the tangent remain.
    """
    z2 = (r / sigma) ** 2
    u0 = (xi / sigma) ** 2
    fprime = -((nu + 1.0) / 2.0) / (nu + u0)
    f_z = -((nu + 1.0) / 2.0) * jnp.log1p(z2 / nu)
    f_u0 = -((nu + 1.0) / 2.0) * jnp.log1p(u0 / nu)
    return f_z - (f_u0 + fprime * (z2 - u0))


# ---------------------------------------------------------------------------
# Böhning (softmax) bound — lane-padded variant for the Pallas kernel
# ---------------------------------------------------------------------------


def softmax_delta_padded(
    eta: jax.Array,  # (B, Kp) logits θx, columns ≥ n_classes are padding
    eta0: jax.Array,  # (B, Kp) tangency logits (data.xi), same padding
    t_onehot: jax.Array,  # (B, Kp) one-hot labels (0 on padding)
    n_classes: int,
) -> jax.Array:
    """δ = log L - log B for the Böhning bound on lane-padded (B, Kp) logits.

    Padding columns (k ≥ n_classes) are excluded from every reduction, so
    the result equals :class:`repro.core.bounds.SoftmaxBound`'s
    ``log_lik - log_bound`` on the unpadded (B, K) arrays. Kept next to the
    other δ formulas so kernel and reference share one definition of the
    masked math.
    """
    valid = (
        jax.lax.broadcasted_iota(jnp.int32, eta.shape, eta.ndim - 1) < n_classes
    )
    neg = jnp.asarray(-1e30, eta.dtype)

    def lse(e):  # masked logsumexp over the valid columns, (B, 1)
        e_m = jnp.where(valid, e, neg)
        m = jnp.max(e_m, axis=-1, keepdims=True)
        return m + jnp.log(
            jnp.sum(jnp.where(valid, jnp.exp(e_m - m), 0.0), axis=-1,
                    keepdims=True)
        )

    lse0 = lse(eta0)
    at_t = lambda e: jnp.sum(t_onehot * jnp.where(valid, e, 0.0), axis=-1)
    ll_eta = at_t(eta) - lse(eta)[..., 0]  # log L(η) = η[t] - lse(η)
    ll_eta0 = at_t(eta0) - lse0[..., 0]
    g = t_onehot - jnp.where(valid, jnp.exp(eta0 - lse0), 0.0)
    d = jnp.where(valid, eta - eta0, 0.0)
    # A = ½(I - 𝟙𝟙ᵀ/K) over the *valid* columns only (d is 0 on padding).
    a_d = 0.5 * (d - jnp.sum(d, axis=-1, keepdims=True) / n_classes)
    quad = jnp.sum(d * a_d, axis=-1)
    log_b = ll_eta0 + jnp.sum(g * d, axis=-1) - 0.5 * quad
    return ll_eta - log_b
