"""Firefly Monte Carlo (paper §2–§3): exact MCMC with subsets of data.

The augmented target over (θ, z) is

    p(θ, z | x) ∝ p̃(θ) · ∏_{n: z_n=1} L̃_n(θ)
    p̃(θ)   = p(θ) · ∏_n B_n(θ)            (pseudo-prior; collapsed, O(D²))
    L̃_n(θ) = (L_n(θ) - B_n(θ)) / B_n(θ)    (pseudo-likelihood of bright n)

and marginalizing z recovers the exact posterior (paper Eq. 2). A FlyMC
iteration alternates a θ-kernel on the conditional (any operator from
``core.samplers``) with a z-kernel (implicit MH resampling, Algorithm 2, or
explicit Gibbs resampling, Algorithm 1 lines 3–6).

TPU/XLA adaptation (DESIGN.md §3): the dynamic bright set becomes a
capacity-``C`` padded gather over the Fig.-3 partition array, so a θ-update
costs O(C·D) likelihood work instead of O(N·D). Capacity overflow is detected
*before* a step is committed and the step is deterministically re-run at a
doubled capacity from the same RNG key, so truncation can never bias the
chain. A full-length ``delta_full`` cache holds δ_n = log L_n - log B_n at
the current θ for every point whose likelihood has been evaluated there,
which is exactly the set the z-kernel is allowed to touch for free
(Algorithm 2's "cached from θ update").

Likelihood-query accounting follows Table 1: every per-datum L_n evaluation
is counted; bound evaluations ride along for free (paper §3.1) and the
collapsed bound product is O(D²), independent of N.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import brightness, samplers
from repro.core.bounds import CollapsedStats, GLMData

# Numerics are single-sourced in repro.core.numerics (shared with the fused
# Pallas kernel); log_expm1 stays re-exported here for backward compat.
from repro.core.numerics import _DELTA_FLOOR, log_expm1  # noqa: F401


def _tree_gather(data: GLMData, idx: jax.Array) -> GLMData:
    return jax.tree.map(lambda a: jnp.take(a, idx, axis=0), data)


# ---------------------------------------------------------------------------
# Spec / state
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FlyMCSpec:
    """Static configuration of a FlyMC chain (hashable; jit-static)."""

    bound: Any  # bound object from core.bounds
    log_prior: Callable[[jax.Array], jax.Array]
    kernel: str = "rwmh"  # θ-operator: rwmh | mala | slice | hmc
    capacity: int = 1024  # bright-buffer capacity C
    cand_capacity: int = 1024  # dark→bright candidate buffer capacity
    q_db: float = 0.01  # dark→bright proposal probability (Alg. 2)
    mode: str = "implicit"  # z-kernel: implicit (Alg. 2) | explicit (Alg. 1)
    resample_fraction: float = 0.1  # explicit mode: fraction of data per round
    kernel_kwargs: tuple = ()  # extra static kwargs for the θ-kernel
    axis_names: tuple = ()  # mesh axes carrying data shards (psum)
    adapt_target: float | None = None  # accept-rate target during warmup
    backend: str = "jnp"  # θ-update likelihood engine: jnp | pallas
    z_backend: str = "jnp"  # z-update engine: jnp | fused (implicit mode)
    num_warmup: int = 1000  # step-size adaptation window (iterations)

    def needs_grad(self) -> bool:
        return samplers.get_kernel(self.kernel).needs_grad


class FlyMCState(NamedTuple):
    sampler: samplers.SamplerState  # θ, joint lp, grad, δ-buffer aux
    bright: brightness.BrightState
    delta_full: jax.Array  # (N,) δ at current θ; valid for bright & just-evaluated
    log_step: jax.Array  # log step size (adapted during warmup)
    rng: jax.Array
    iteration: jax.Array  # int32


class StepStats(NamedTuple):
    n_bright: jax.Array  # bright count after the step
    lik_queries: jax.Array  # per-datum likelihood evaluations this step
    accept_prob: jax.Array
    overflow: jax.Array  # bool — step must be re-run at larger capacity
    joint_lp: jax.Array


# ---------------------------------------------------------------------------
# Joint log-posterior over the padded bright buffer
# ---------------------------------------------------------------------------


def make_joint_logpost(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    bright_idx: jax.Array,
    bright_mask: jax.Array,
) -> samplers.LogDensityFn:
    """f(θ) -> (joint log posterior, δ on the bright buffer).

    Evaluates only the ``C`` gathered rows (the paper's bright minibatch) plus
    the O(D²) collapsed bound product. Under shard_map the bright sum is
    psum'd; prior + collapsed terms are replicated and added once.

    ``bright_mask`` must be a PREFIX mask (first ``k`` slots valid, the rest
    padding) as produced by :func:`repro.core.brightness.bright_buffer`: the
    pallas backend hands the kernel only the valid-slot *count*, so a
    non-prefix mask would be honored by the jnp path but silently
    misinterpreted by the fused one.

    ``spec.backend`` selects the likelihood engine. ``"jnp"`` materializes
    the gathered rows and evaluates the bound in plain XLA; ``"pallas"``
    routes through the fused :func:`repro.kernels.bright_glm.ops.bright_glm`
    kernel (gather + δ + masked log L̃ reduction in one pass, gradient via
    its custom VJP) for bounds exposing the
    :class:`~repro.core.bounds.FusedBound` hook — with interpret-mode
    fallback off-TPU so both paths run everywhere.
    """

    if spec.backend == "pallas":
        from repro.core.bounds import fused_family_of

        fam = fused_family_of(spec.bound)
        if fam is None:
            raise ValueError(
                f"backend='pallas' needs a FusedBound, but "
                f"{type(spec.bound).__name__} has no usable fused_family "
                "hook (missing, or log_lik/log_bound overridden without "
                "re-declaring it)"
            )
        kernel_kwargs = spec.bound.fused_kernel_kwargs()
        # Prefix-mask contract (see docstring): count == first-k-valid.
        n_bright = jnp.sum(bright_mask).astype(jnp.int32)

        def f_pallas(theta: jax.Array):
            from repro.kernels.bright_glm.ops import bright_glm

            delta, s = bright_glm(
                data.x, data.t, data.xi, bright_idx, n_bright, theta,
                family=fam, **kernel_kwargs,
            )
            for ax in spec.axis_names:
                s = jax.lax.psum(s, ax)
            lp = spec.log_prior(theta) + spec.bound.collapsed(theta, stats) + s
            return lp, delta

        return f_pallas
    if spec.backend != "jnp":
        raise ValueError(
            f"unknown backend {spec.backend!r}; expected 'jnp' or 'pallas'"
        )

    rows = _tree_gather(data, bright_idx)

    def f(theta: jax.Array):
        ll = spec.bound.log_lik(theta, rows)
        lb = spec.bound.log_bound(theta, rows)
        delta = ll - lb
        s = jnp.sum(jnp.where(bright_mask, log_expm1(delta), 0.0))
        for ax in spec.axis_names:
            s = jax.lax.psum(s, ax)
        lp = spec.log_prior(theta) + spec.bound.collapsed(theta, stats) + s
        return lp, delta

    return f


def _refresh_sampler(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    theta: jax.Array,
    bright: brightness.BrightState,
    delta_full: jax.Array,
) -> tuple[samplers.SamplerState, jax.Array]:
    """Rebuild SamplerState after a z-move *without* new likelihood queries
    (gradient kernels excepted — they re-evaluate and the cost is counted).

    Returns (state, extra_queries).
    """
    idx, mask = brightness.bright_buffer(bright, spec.capacity)
    delta = jnp.take(delta_full, idx)
    if spec.needs_grad():
        f = make_joint_logpost(spec, data, stats, idx, mask)
        (lp, aux), grad = jax.value_and_grad(f, has_aux=True)(theta)
        return samplers.SamplerState(theta, lp, grad, aux), bright.num
    # lp from cached δ — zero new likelihood queries.
    s = jnp.sum(jnp.where(mask, log_expm1(delta), 0.0))
    for ax in spec.axis_names:
        s = jax.lax.psum(s, ax)
    lp = spec.log_prior(theta) + spec.bound.collapsed(theta, stats) + s
    zeros_grad = jnp.zeros_like(theta)
    return samplers.SamplerState(theta, lp, zeros_grad, delta), jnp.int32(0)


# ---------------------------------------------------------------------------
# z-kernels
# ---------------------------------------------------------------------------


def _implicit_z_update(
    spec: FlyMCSpec,
    data: GLMData,
    key: jax.Array,
    theta: jax.Array,
    bright: brightness.BrightState,
    delta_full: jax.Array,
    delta_bright: jax.Array,
):
    """Algorithm 2, vectorized. Returns (z_new, delta_full, queries, overflow).

    Per-datum MH moves are conditionally independent given θ, so the parallel
    sweep simulates exactly the paper's kernel. q_{b→d}=1: every bright point
    proposes to darken, using the δ cached from the θ-update; dark points
    propose to brighten with prob q_{d→b} (geometric thinning) and only those
    *candidates* pay a likelihood evaluation.

    All uniforms are drawn per *datum* (length-N vectors, gathered by index),
    never per buffer slot: jax's counter-based PRNG is not prefix-stable
    across shapes, so capacity-shaped draws would make the realized chain
    depend on the buffer size. Per-datum draws keep the trajectory bitwise
    identical across capacities, which is what lets the driver re-run an
    overflowed chunk at doubled capacity without perturbing the chain.
    (:func:`_fused_z_update` keeps the same per-datum keying — uniforms are
    a pure function of ``(step_key, draw, datum_index)`` — while never
    materializing the length-N arrays this engine pays for.)
    """
    n = data.x.shape[0]
    k_bd, k_cand, k_db = jax.random.split(key, 3)
    z = brightness.z_of(bright)
    log_q = jnp.log(jnp.asarray(spec.q_db, delta_full.dtype))

    # --- bright → dark (free: reuses cached δ) -----------------------------
    idx_b, mask_b = brightness.bright_buffer(bright, spec.capacity)
    u1 = jnp.take(jax.random.uniform(k_bd, (n,), delta_full.dtype), idx_b)
    # accept darkening iff u·L̃ < q_db  ⇔  log u + log L̃ < log q_db
    darken = mask_b & (jnp.log(u1) + log_expm1(delta_bright) < log_q)
    z = z.at[idx_b].set(jnp.where(darken, False, z[idx_b]))

    # --- dark → bright (candidates pay a likelihood query each) ------------
    u2 = jax.random.uniform(k_cand, (n,), delta_full.dtype)
    was_dark = ~brightness.z_of(bright)
    cand = was_dark & (u2 < spec.q_db)
    n_cand = jnp.sum(cand).astype(jnp.int32)
    overflow_c = n_cand > spec.cand_capacity
    pos = jnp.cumsum(cand) - 1
    scatter_to = jnp.where(cand, pos, spec.cand_capacity)  # OOB rows dropped
    # Padding slots index n (out of bounds): their gathers clamp harmlessly
    # and their scatters are dropped, so they can never collide with slot 0.
    cand_idx = (
        jnp.full(spec.cand_capacity, n, jnp.int32)
        .at[scatter_to]
        .set(jnp.arange(n, dtype=jnp.int32), mode="drop")
    )
    mask_c = jnp.arange(spec.cand_capacity) < n_cand

    rows = _tree_gather(data, cand_idx)
    delta_c = spec.bound.log_lik(theta, rows) - spec.bound.log_bound(theta, rows)
    u3 = jnp.take(
        jax.random.uniform(k_db, (n,), delta_full.dtype), cand_idx, mode="clip"
    )
    # accept brightening iff u·q_db < L̃  ⇔  log u + log q_db < log L̃
    brighten = mask_c & (jnp.log(u3) + log_q < log_expm1(delta_c))
    z = z.at[cand_idx].set(jnp.where(brighten, True, z[cand_idx]), mode="drop")
    delta_full = delta_full.at[cand_idx].set(
        jnp.where(mask_c, delta_c, delta_full[cand_idx]), mode="drop"
    )
    return z, delta_full, n_cand, overflow_c


def _candidate_delta(
    spec: FlyMCSpec,
    data: GLMData,
    theta: jax.Array,
    cand_idx: jax.Array,
    n_cand: jax.Array,
) -> jax.Array:
    """δ = log L - log B on the compacted candidate buffer.

    Dispatches on ``spec.backend`` exactly like the θ-update: with
    ``backend="pallas"`` the candidate rows go through the same fused
    :func:`repro.kernels.bright_glm.ops.bright_glm` kernel (FusedBound
    family), so the pallas backend covers the *whole* step's likelihood
    work; otherwise the jnp gather path. Padded slots (``idx >= N``) clamp
    harmlessly — callers mask them.
    """
    if spec.backend == "pallas":
        from repro.core.bounds import fused_family_of
        from repro.kernels.bright_glm.ops import bright_glm

        fam = fused_family_of(spec.bound)
        delta, _ = bright_glm(
            data.x, data.t, data.xi, cand_idx, n_cand, theta,
            family=fam, **spec.bound.fused_kernel_kwargs(),
        )
        return delta
    rows = _tree_gather(data, cand_idx)
    return spec.bound.log_lik(theta, rows) - spec.bound.log_bound(theta, rows)


def _fused_z_update(
    spec: FlyMCSpec,
    data: GLMData,
    key: jax.Array,
    theta: jax.Array,
    bright: brightness.BrightState,
    delta_full: jax.Array,
    delta_bright: jax.Array,
):
    """Algorithm 2 via the fused z-engine (``spec.z_backend = "fused"``).

    Same per-datum MH law as :func:`_implicit_z_update`, with every O(N)
    non-likelihood intermediate eliminated:

      * uniforms come from the counter-based RNG
        (:func:`repro.core.numerics.counter_uniform`, keyed on
        ``(step_key, draw, datum_index)``) — evaluated on the O(C) bright
        buffer and O(cand) candidate buffer here, and on streamed tiles
        inside the candidate kernel, never as (N,) arrays;
      * dark→bright candidate selection + compaction is one streamed pass
        (:func:`repro.kernels.z_update.ops.z_candidates`);
      * candidate δ routes through :func:`_candidate_delta` (the fused
        bright-GLM kernel under ``backend="pallas"``);
      * the partition is maintained incrementally by
        :func:`repro.core.brightness.apply_flips` — O(changed) swaps, no
        full-N cumsum rebuild.

    Keying on datum indices keeps the trajectory bitwise invariant to
    capacity and chunk size (the same contract as the jnp engine), but the
    realized stream differs from the jnp engine's ``jax.random.uniform``
    draws: the two engines produce *law-equivalent*, not bitwise-equal,
    chains.

    Returns (bright_new, delta_full, queries, overflow).
    """
    from repro.core.numerics import (
        DRAW_BRIGHT,
        DRAW_DARKEN,
        counter_uniform,
        key_words_of,
    )
    from repro.kernels.z_update.ops import z_candidates

    n = data.x.shape[0]
    kw = key_words_of(key)
    log_q = jnp.log(jnp.asarray(spec.q_db, delta_full.dtype))

    # --- bright → dark (free: cached δ + O(C) counter uniforms) ------------
    idx_b, mask_b = brightness.bright_buffer(bright, spec.capacity)
    u1 = counter_uniform(kw, DRAW_DARKEN, idx_b)
    darken = mask_b & (jnp.log(u1) + log_expm1(delta_bright) < log_q)

    # --- dark → bright (streamed selection, then O(cand) work) -------------
    cand_idx, n_cand = z_candidates(
        bright.arr, bright.num, kw, spec.q_db, spec.cand_capacity
    )
    overflow_c = n_cand > spec.cand_capacity
    mask_c = jnp.arange(spec.cand_capacity, dtype=jnp.int32) < n_cand
    nb = jnp.minimum(n_cand, spec.cand_capacity)
    delta_c = _candidate_delta(spec, data, theta, cand_idx, nb)
    u3 = counter_uniform(kw, DRAW_BRIGHT, jnp.clip(cand_idx, 0, n - 1))
    brighten = mask_c & (jnp.log(u3) + log_q < log_expm1(delta_c))
    delta_full = delta_full.at[cand_idx].set(
        jnp.where(mask_c, delta_c, delta_full[jnp.clip(cand_idx, 0, n - 1)]),
        mode="drop",
    )
    bright_new = brightness.apply_flips(bright, darken, cand_idx, brighten)
    return bright_new, delta_full, n_cand, overflow_c


def _explicit_z_update(
    spec: FlyMCSpec,
    data: GLMData,
    key: jax.Array,
    theta: jax.Array,
    bright: brightness.BrightState,
    delta_full: jax.Array,
):
    """Algorithm 1 lines 3–6: Gibbs resampling of a random fixed-size subset.

    The subset is drawn WITHOUT replacement (a permutation slice): with
    replacement, a datum appearing twice in ``idx`` makes the
    ``z.at[idx].set`` scatter order-nondeterministic — the realized z (and
    cached δ) for that datum would be whichever duplicate the scatter
    happened to apply last, which XLA does not define.
    """
    n = data.x.shape[0]
    r = max(1, int(round(n * spec.resample_fraction)))
    k_idx, k_z = jax.random.split(key)
    idx = jax.lax.slice_in_dim(
        jax.random.permutation(k_idx, jnp.arange(n, dtype=jnp.int32)), 0, r
    )
    rows = _tree_gather(data, idx)
    delta = spec.bound.log_lik(theta, rows) - spec.bound.log_bound(theta, rows)
    # p(z=1) = (L-B)/L = -expm1(-δ)
    p_bright = -jnp.expm1(-jnp.maximum(delta, _DELTA_FLOOR))
    z_idx = jax.random.uniform(k_z, (r,), delta.dtype) < p_bright
    z = brightness.z_of(bright).at[idx].set(z_idx)
    delta_full = delta_full.at[idx].set(delta)
    return z, delta_full, jnp.int32(r), jnp.bool_(False)


# ---------------------------------------------------------------------------
# One FlyMC iteration
# ---------------------------------------------------------------------------


def flymc_step(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    state: FlyMCState,
) -> tuple[FlyMCState, StepStats]:
    """θ-update followed by z-update (paper §2 alternation).

    Distributed (spec.axis_names non-empty, inside shard_map): the θ-kernel
    runs replicated with identical keys on every shard (identical proposals
    and accept decisions; likelihood sums are psum'd inside the joint), while
    the z-kernel folds the shard index into its key so per-datum Bernoulli
    decisions are independent across shards.
    """
    key_theta, key_z, key_next = jax.random.split(state.rng, 3)
    for ax in spec.axis_names:
        key_z = jax.random.fold_in(key_z, jax.lax.axis_index(ax))

    # ---- θ | z -------------------------------------------------------------
    idx, mask = brightness.bright_buffer(state.bright, spec.capacity)
    f = make_joint_logpost(spec, data, stats, idx, mask)
    kernel = samplers.bind(spec.kernel, f, spec.kernel_kwargs)
    new_sampler, info = kernel(key_theta, state.sampler, jnp.exp(state.log_step))
    queries_theta = info.n_evals * state.bright.num
    # δ at (possibly) new θ for the bright buffer, from the kernel's aux cache.
    delta_full = state.delta_full.at[idx].set(
        jnp.where(mask, new_sampler.aux, state.delta_full[idx])
    )

    # ---- z | θ -------------------------------------------------------------
    if spec.mode == "implicit" and spec.z_backend == "fused":
        bright_new, delta_full, queries_z, overflow_c = _fused_z_update(
            spec, data, key_z, new_sampler.theta, state.bright, delta_full,
            new_sampler.aux,
        )
    elif spec.mode == "implicit":
        z_new, delta_full, queries_z, overflow_c = _implicit_z_update(
            spec, data, key_z, new_sampler.theta, state.bright, delta_full,
            new_sampler.aux,
        )
        bright_new = brightness.from_z(z_new)
    elif spec.z_backend == "fused":
        raise ValueError(
            "z_backend='fused' requires mode='implicit' (Algorithm 1's "
            "explicit Gibbs resampling re-evaluates a dense subset, so "
            "there is no sparse candidate stream to fuse)"
        )
    else:
        z_new, delta_full, queries_z, overflow_c = _explicit_z_update(
            spec, data, key_z, new_sampler.theta, state.bright, delta_full
        )
        bright_new = brightness.from_z(z_new)
    overflow = overflow_c | (bright_new.num > spec.capacity)
    if spec.axis_names:
        overflow = jax.lax.pmax(overflow.astype(jnp.int32),
                                spec.axis_names).astype(bool)

    refreshed, extra_q = _refresh_sampler(
        spec, data, stats, new_sampler.theta, bright_new, delta_full
    )

    log_step = state.log_step
    if spec.adapt_target is not None:
        # Adaptation is WARMUP-ONLY: a kernel whose step size keeps moving
        # is not a fixed Markov kernel, so the post-warmup chain would lose
        # detailed balance (diminishing or not). Freeze bitwise after
        # spec.num_warmup iterations.
        adapted = samplers.adapt_step_size(
            log_step, info.accept_prob, spec.adapt_target, state.iteration
        )
        log_step = jnp.where(
            state.iteration < spec.num_warmup, adapted, log_step
        )

    new_state = FlyMCState(
        sampler=refreshed,
        bright=bright_new,
        delta_full=delta_full,
        log_step=log_step,
        rng=key_next,
        iteration=state.iteration + 1,
    )
    n_bright = bright_new.num
    lik_queries = queries_theta + queries_z + extra_q
    if spec.axis_names:
        n_bright = jax.lax.psum(n_bright, spec.axis_names)
        lik_queries = jax.lax.psum(lik_queries, spec.axis_names)
    stats_out = StepStats(
        n_bright=n_bright,
        lik_queries=lik_queries,
        accept_prob=info.accept_prob,
        overflow=overflow,
        joint_lp=refreshed.lp,
    )
    return new_state, stats_out


# ---------------------------------------------------------------------------
# Initialization & host driver (capacity doubling keeps the chain exact)
# ---------------------------------------------------------------------------


def init_chain_state(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    theta0: jax.Array,
    key: jax.Array,
    z0: jax.Array | None = None,
    step_size: float = 0.1,
) -> FlyMCState:
    """Pure chain initialization: no host syncs, no capacity growth.

    If the initial bright set exceeds ``spec.capacity`` the returned state's
    δ buffer is truncated; callers (the repro.api driver, or the legacy
    ``init_chain`` wrapper) detect ``state.bright.num > capacity`` and
    rebuild at a grown capacity from the same key, which is deterministic.
    """
    n = data.x.shape[0]
    k_z, k_chain = jax.random.split(key)
    for ax in spec.axis_names:
        k_z = jax.random.fold_in(k_z, jax.lax.axis_index(ax))
    if z0 is None:
        z0 = jax.random.bernoulli(k_z, min(2.0 * spec.q_db, 1.0), (n,))
    bright = brightness.from_z(z0)
    idx, mask = brightness.bright_buffer(bright, spec.capacity)
    f = make_joint_logpost(spec, data, stats, idx, mask)
    sampler = samplers.init_state(f, theta0, with_grad=spec.needs_grad())
    delta_full = jnp.zeros(n, sampler.lp.dtype).at[idx].set(
        jnp.where(mask, sampler.aux, 0.0)
    )
    return FlyMCState(
        sampler=sampler,
        bright=bright,
        delta_full=delta_full,
        log_step=jnp.log(jnp.asarray(step_size, sampler.lp.dtype)),
        rng=k_chain,
        iteration=jnp.int32(0),
    )


def init_chain(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    theta0: jax.Array,
    key: jax.Array,
    z0: jax.Array | None = None,
    step_size: float = 0.1,
) -> tuple[FlyMCState, int, FlyMCSpec]:
    """Deprecated host-side init; prefer ``repro.api.firefly(...)``.

    Returns (state, setup likelihood queries, spec). The returned spec may
    have grown capacities if the initial bright set did not fit the
    requested buffer.
    """
    n = data.x.shape[0]
    state = init_chain_state(spec, data, stats, theta0, key, z0, step_size)
    if spec.axis_names:
        return state, state.bright.num, spec
    while int(jax.device_get(state.bright.num)) > spec.capacity:
        spec = _grow(spec, n)
        state = init_chain_state(spec, data, stats, theta0, key, z0, step_size)
    return state, int(jax.device_get(state.bright.num)), spec


def _grow(spec: FlyMCSpec, n: int) -> FlyMCSpec:
    return dataclasses.replace(
        spec,
        capacity=min(2 * spec.capacity, n),
        cand_capacity=min(2 * spec.cand_capacity, n),
    )


def resize_state(spec: FlyMCSpec, state: FlyMCState) -> FlyMCState:
    """Rebuild the capacity-shaped δ buffer after a capacity change.

    θ, joint lp, gradient and the bright partition are capacity-independent;
    the (C,)-shaped aux is re-gathered from ``delta_full`` — zero likelihood
    queries, bitwise-identical chain law.
    """
    idx, _ = brightness.bright_buffer(state.bright, spec.capacity)
    aux = jnp.take(state.delta_full, idx)
    return state._replace(sampler=state.sampler._replace(aux=aux))


def run_chain(
    spec: FlyMCSpec,
    data: GLMData,
    stats: CollapsedStats,
    state: FlyMCState,
    num_iters: int,
    collect: Callable[[FlyMCState], Any] | None = None,
):
    """Deprecated shim over the device-resident driver (``repro.api.sample``).

    Preserves the old return shape (samples, trace dicts, total_queries,
    possibly-grown spec). A custom ``collect`` callable needs per-iteration
    host access, so that path falls back to a host-side step loop; the
    default θ-collection runs entirely on device via chunked ``lax.scan``
    with the same exactness-preserving capacity-doubling re-run semantics.
    """
    from repro import api  # local import: api is built on this module

    alg = api.algorithm_from_spec(spec, data, stats)
    if collect is not None:
        return _run_chain_host(alg, state, num_iters, collect)
    trace = api.sample(alg, state.rng, num_iters, init_state=state)
    theta, st = jax.device_get((trace.theta[0], trace.stats))
    samples = list(theta)
    trace_dicts = [
        {
            "n_bright": int(st.n_bright[0, i]),
            "lik_queries": int(st.lik_queries[0, i]),
            "accept_prob": float(st.accept_prob[0, i]),
            "joint_lp": float(st.joint_lp[0, i]),
        }
        for i in range(num_iters)
    ]
    total_queries = int(jax.device_get(trace.total_queries))
    return samples, trace_dicts, total_queries, trace.algorithm.spec


def _run_chain_host(alg, state: FlyMCState, num_iters: int, collect):
    """Host loop fallback for run_chain(collect=...): one sync per iteration."""
    key = state.rng
    samples, trace = [], []
    total_queries = 0
    step = jax.jit(alg.step)
    # Same resume contract as repro.api.sample: the fold-in counter continues
    # from the state's iteration so a resumed segment never replays the
    # prefix's key stream.
    offset = int(jax.device_get(state.iteration))
    for i in range(offset, offset + num_iters):
        prev = state
        new_state, st = step(jax.random.fold_in(key, i), state)
        while bool(jax.device_get(st.overflow)):
            alg = alg.grow()
            prev = alg.resize(prev)
            step = jax.jit(alg.step)
            new_state, st = step(jax.random.fold_in(key, i), prev)
        state = new_state
        total_queries += int(jax.device_get(st.lik_queries))
        samples.append(collect(state))
        trace.append(
            {
                "n_bright": int(jax.device_get(st.n_bright)),
                "lik_queries": int(jax.device_get(st.lik_queries)),
                "accept_prob": float(jax.device_get(st.accept_prob)),
                "joint_lp": float(jax.device_get(st.joint_lp)),
            }
        )
    return samples, trace, total_queries, alg.spec
