"""Collapsible likelihood lower bounds (paper §3.1).

A FlyMC bound ``B_n(θ)`` must satisfy two properties:

  1. ``0 < B_n(θ) <= L_n(θ)`` for all θ (exactness requirement);
  2. the *product* ``∏_n B_n(θ)`` must collapse to an O(D²) quadratic form
     computed from sufficient statistics that are built once (and psum-able
     across data shards).

All three of the paper's bounds are scaled exponential-family functions of a
GLM inner product, so their log-products collapse to

    log ∏_n B_n(θ) = θᵀ Q θ + qᵀ θ + c            (vector θ, logistic/robust)
    log ∏_n B_n(θ) = -½ tr(A θ S θᵀ) + tr(θ R) + c (matrix θ, softmax/Böhning)

Implemented bounds:
  * :class:`LogisticBound`  — Jaakkola–Jordan (1997) scaled-Gaussian bound on
    the logistic likelihood, per-datum tightness parameter ξ_n.
  * :class:`SoftmaxBound`   — Böhning (1992) fixed-curvature quadratic bound
    on the softmax log-likelihood, per-datum tangency logits η₀_n.
  * :class:`StudentTBound`  — tangent-in-r² Gaussian bound on the Student-t
    density (log t_ν is convex in r², so the tangent is a global lower bound),
    per-datum tangency residual ξ_n.

Every bound exposes the same surface:

    log_lik(theta, data)          -> per-datum log L_n(θ)
    log_bound(theta, data)        -> per-datum log B_n(θ)
    suffstats(data)               -> CollapsedStats  (one-time, O(N·D²))
    collapsed(theta, stats)       -> Σ_n log B_n(θ)  (O(D²) per θ)
    tighten(theta_map, data)      -> data with per-datum tightness at θ_MAP
"""

from __future__ import annotations

from typing import NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core.numerics import jj_a as _jj_a
from repro.core.numerics import jj_c as _jj_c


class GLMData(NamedTuple):
    """A batch of GLM data rows.

    x  : (N, D) features
    t  : (N,)   targets — labels in {-1,+1} (logistic), class id (softmax),
                or real-valued response (robust regression)
    xi : per-datum bound-tightness parameter. Shape (N,) for logistic/robust,
         (N, K) tangency logits for softmax.
    """

    x: jax.Array
    t: jax.Array
    xi: jax.Array


class CollapsedStats(NamedTuple):
    """Sufficient statistics of a product of quadratic log-bounds.

    For vector-parameter bounds: ``Σ log B = θᵀ·Q·θ + q·θ + c``.
    For the softmax (matrix θ of shape (K, D)): ``Q`` holds S=Σxxᵀ (D,D),
    ``q`` holds R=Σ x rᵀ (D,K) and the quadratic is -½tr(AθSθᵀ)+tr(θR)+c.
    """

    Q: jax.Array
    q: jax.Array
    c: jax.Array


def psum_stats(stats: CollapsedStats, axis_name) -> CollapsedStats:
    """All-reduce suff-stats across data shards (one-time setup collective)."""
    return CollapsedStats(*(jax.lax.psum(s, axis_name) for s in stats))


# ---------------------------------------------------------------------------
# Bound protocol + registry
# ---------------------------------------------------------------------------


@runtime_checkable
class Bound(Protocol):
    """The surface every collapsible FlyMC bound must implement (§3.1).

    Exactness contract: ``0 < exp(log_bound) <= exp(log_lik)`` everywhere, and
    ``collapsed(θ, suffstats(data)) == Σ_n log_bound(θ, data_n)``.
    """

    name: str

    def log_lik(self, theta: jax.Array, data: GLMData) -> jax.Array: ...

    def log_bound(self, theta: jax.Array, data: GLMData) -> jax.Array: ...

    def suffstats(self, data: GLMData) -> CollapsedStats: ...

    def collapsed(self, theta: jax.Array, stats: CollapsedStats) -> jax.Array: ...

    def tighten(self, theta_map: jax.Array, data: GLMData) -> GLMData: ...

    # Optional fused-delta hook (see FusedBound): bounds that additionally
    # expose ``fused_family``/``fused_kernel_kwargs`` can route θ-updates
    # through the fused Pallas kernel (FlyMCSpec.backend = "pallas").


@runtime_checkable
class FusedBound(Bound, Protocol):
    """A Bound with a fused Pallas δ-kernel (the backend="pallas" hot path).

    ``fused_family`` names the family implemented by
    :mod:`repro.kernels.bright_glm` ("logistic" | "student_t" | "softmax");
    ``fused_kernel_kwargs()`` returns the static scalar parameters the kernel
    needs beyond (x, t, ξ, θ) — e.g. (ν, σ) for the Student-t bound. The hook
    is optional: plain Bounds keep working on the jnp backend, and
    ``FlyMCSpec.backend = "pallas"`` is rejected up front for bounds that
    don't implement it.

    One hook, both hot paths: ``backend="pallas"`` routes the θ-update's
    bright-buffer evaluation AND the z-update's candidate-δ evaluation
    (:func:`repro.core.flymc._candidate_delta`) through the same fused
    kernel, so a bound that declares a family covers every per-datum
    likelihood query a FlyMC step makes.
    """

    fused_family: str

    def fused_kernel_kwargs(self) -> dict: ...


def fused_family_of(bound) -> str | None:
    """The bound's fused-kernel family, or None if it must use the jnp path.

    Guards against an inheritance accident: a subclass that overrides
    ``log_lik``/``log_bound`` but merely *inherits* ``fused_family`` would
    dispatch θ-updates to a fused kernel hard-coding the parent's math while
    z-updates use the overridden jnp math — silently sampling the wrong
    posterior. The hook therefore only counts if no likelihood method is
    overridden below the class that declared it; a subclass that changes the
    math opts back in by re-declaring ``fused_family`` (asserting its
    overrides are kernel-compatible).
    """
    cls = type(bound)
    declarer = next(
        (k for k in cls.__mro__ if "fused_family" in vars(k)), None
    )
    if declarer is None or getattr(cls, "fused_family", None) is None:
        return None
    for meth in ("log_lik", "log_bound"):
        effective = next((k for k in cls.__mro__ if meth in vars(k)), None)
        # The method only counts as vouched-for if the fused_family
        # declaration sits at or below it in the MRO (declarer is a
        # subclass of the provider). Anything else — an override below the
        # declaration OR a sibling mixin ahead of it in the MRO — changes
        # the math without re-asserting kernel compatibility.
        if effective is not None and not issubclass(declarer, effective):
            return None
    return cls.fused_family


BOUND_REGISTRY: dict[str, type] = {}


def register_bound(cls: type, *aliases: str) -> type:
    """Register a Bound class under its ``name`` attribute plus aliases."""
    for key in (cls.name, *aliases):
        BOUND_REGISTRY[key] = cls
    return cls


def get_bound(bound) -> Bound:
    """Resolve a bound: pass through instances, instantiate registered names."""
    if isinstance(bound, str):
        try:
            cls = BOUND_REGISTRY[bound]
        except KeyError:
            raise KeyError(
                f"unknown bound {bound!r}; registered: {sorted(BOUND_REGISTRY)}"
            ) from None
        return cls()
    if not isinstance(bound, Bound):
        raise TypeError(
            f"{type(bound).__name__} does not implement the Bound protocol "
            "(log_lik/log_bound/suffstats/collapsed/tighten)"
        )
    return bound


# ---------------------------------------------------------------------------
# Jaakkola–Jordan bound for logistic regression
# ---------------------------------------------------------------------------


# _jj_a/_jj_c live in repro.core.numerics (shared with the Pallas kernel so
# the two likelihood paths cannot drift); re-imported above under the old
# names for backward compatibility.


class LogisticBound:
    """Jaakkola–Jordan scaled-Gaussian lower bound on logit⁻¹(t·θᵀx).

    log B_n(s) = a(ξ_n)·s² + s/2 + c(ξ_n)   with  s = t_n·θᵀx_n.

    Tight at s = ±ξ_n, so MAP-tuning uses ξ_n = |θ_MAPᵀ x_n|.
    """

    name = "jaakkola-jordan"
    fused_family = "logistic"

    @staticmethod
    def fused_kernel_kwargs() -> dict:
        return {}

    @staticmethod
    def log_lik(theta: jax.Array, data: GLMData) -> jax.Array:
        s = data.t * (data.x @ theta)
        return -jax.nn.softplus(-s)

    @staticmethod
    def log_bound(theta: jax.Array, data: GLMData) -> jax.Array:
        s = data.t * (data.x @ theta)
        return _jj_a(data.xi) * s * s + 0.5 * s + _jj_c(data.xi)

    @staticmethod
    def suffstats(data: GLMData) -> CollapsedStats:
        a = _jj_a(data.xi)
        # s² = (θᵀx)² (t²=1), so Q = Σ a_n x xᵀ; the linear term keeps t.
        Q = jnp.einsum("n,nd,ne->de", a, data.x, data.x)
        q = 0.5 * jnp.einsum("n,nd->d", data.t.astype(data.x.dtype), data.x)
        c = jnp.sum(_jj_c(data.xi))
        return CollapsedStats(Q, q, c)

    @staticmethod
    def collapsed(theta: jax.Array, stats: CollapsedStats) -> jax.Array:
        return theta @ stats.Q @ theta + stats.q @ theta + stats.c

    @staticmethod
    def tighten(theta_map: jax.Array, data: GLMData) -> GLMData:
        return data._replace(xi=jnp.abs(data.x @ theta_map))

    @staticmethod
    def default_xi(data: GLMData, xi: float = 1.5) -> GLMData:
        return data._replace(xi=jnp.full(data.x.shape[0], xi, data.x.dtype))


# ---------------------------------------------------------------------------
# Böhning bound for softmax classification
# ---------------------------------------------------------------------------


def _a_mul(v: jax.Array) -> jax.Array:
    """Apply Böhning curvature A = ½(I - 𝟙𝟙ᵀ/K) along the last axis."""
    return 0.5 * (v - jnp.mean(v, axis=-1, keepdims=True))


def _softmax_log_lik_eta(eta: jax.Array, t: jax.Array) -> jax.Array:
    """log softmax(η)[t] for per-row class ids t."""
    return jnp.take_along_axis(
        jax.nn.log_softmax(eta, axis=-1), t[..., None], axis=-1
    )[..., 0]


class SoftmaxBound:
    """Böhning (1992) quadratic lower bound for the softmax likelihood.

    θ is (K, D); per-datum logits η_n = θ x_n. With tangency logits η₀_n
    (= data.xi, shape (N, K)):

        log B_n = log L_n(η₀) + g_nᵀ(η-η₀) - ½(η-η₀)ᵀ A (η-η₀)
        g_n = e_{t_n} - softmax(η₀_n),   A = ½(I - 𝟙𝟙ᵀ/K)

    A ⪰ H(η) for every η (Böhning), so B_n ≤ L_n globally, and A is constant,
    which makes the product collapse: S = Σ x xᵀ and R = Σ x r_nᵀ with
    r_n = g_n + A η₀_n.
    """

    name = "bohning"
    fused_family = "softmax"

    @staticmethod
    def fused_kernel_kwargs() -> dict:
        return {}

    @staticmethod
    def log_lik(theta: jax.Array, data: GLMData) -> jax.Array:
        eta = data.x @ theta.T  # (N, K)
        return _softmax_log_lik_eta(eta, data.t)

    @staticmethod
    def log_bound(theta: jax.Array, data: GLMData) -> jax.Array:
        eta = data.x @ theta.T
        eta0 = data.xi
        K = eta.shape[-1]
        g = jax.nn.one_hot(data.t, K, dtype=eta.dtype) - jax.nn.softmax(eta0)
        d = eta - eta0
        quad = jnp.sum(d * _a_mul(d), axis=-1)
        return (
            _softmax_log_lik_eta(eta0, data.t)
            + jnp.sum(g * d, axis=-1)
            - 0.5 * quad
        )

    @staticmethod
    def suffstats(data: GLMData) -> CollapsedStats:
        x, t, eta0 = data.x, data.t, data.xi
        K = eta0.shape[-1]
        g = jax.nn.one_hot(t, K, dtype=x.dtype) - jax.nn.softmax(eta0)
        r = g + _a_mul(eta0)  # (N, K)
        S = jnp.einsum("nd,ne->de", x, x)  # (D, D)
        R = jnp.einsum("nd,nk->dk", x, r)  # (D, K)
        c = jnp.sum(
            _softmax_log_lik_eta(eta0, t)
            - jnp.sum(g * eta0, axis=-1)
            - 0.5 * jnp.sum(eta0 * _a_mul(eta0), axis=-1)
        )
        return CollapsedStats(S, R, c)

    @staticmethod
    def collapsed(theta: jax.Array, stats: CollapsedStats) -> jax.Array:
        S, R, c = stats
        quad = jnp.sum((_a_mul(theta.T).T @ S) * theta)  # tr(AθSθᵀ)
        lin = jnp.sum(theta.T * R)  # tr(θR)
        return -0.5 * quad + lin + c

    @staticmethod
    def tighten(theta_map: jax.Array, data: GLMData) -> GLMData:
        return data._replace(xi=data.x @ theta_map.T)

    @staticmethod
    def default_xi(data: GLMData, n_classes: int) -> GLMData:
        return data._replace(
            xi=jnp.zeros((data.x.shape[0], n_classes), data.x.dtype)
        )


# ---------------------------------------------------------------------------
# Gaussian bound for Student-t robust regression
# ---------------------------------------------------------------------------


class StudentTBound:
    """Tangent-in-r² Gaussian lower bound on the Student-t likelihood.

    With z = (t_n - θᵀx_n)/σ and u = z², the log-density
    f(u) = const - ((ν+1)/2)·log(1 + u/ν) is convex in u, so its tangent at
    u₀ = (ξ/σ)² is a global lower bound — a scaled Gaussian in the residual:

        log B_n(z) = f(u₀) + f'(u₀)·(z² - u₀),  f'(u₀) = -((ν+1)/2)/(ν+u₀).

    Tight at z = ±ξ/σ; MAP-tuning: ξ_n = t_n - θ_MAPᵀ x_n.
    """

    name = "student-t-tangent"
    fused_family = "student_t"

    def __init__(self, nu: float = 4.0, sigma: float = 1.0):
        self.nu = float(nu)
        self.sigma = float(sigma)

    def fused_kernel_kwargs(self) -> dict:
        return {"nu": self.nu, "sigma": self.sigma}

    def _log_t_const(self, dtype) -> jax.Array:
        nu = self.nu
        return jnp.asarray(
            jax.scipy.special.gammaln((nu + 1.0) / 2.0)
            - jax.scipy.special.gammaln(nu / 2.0)
            - 0.5 * jnp.log(nu * jnp.pi)
            - jnp.log(self.sigma),
            dtype,
        )

    def _f(self, u: jax.Array) -> jax.Array:
        return self._log_t_const(u.dtype) - ((self.nu + 1.0) / 2.0) * jnp.log1p(
            u / self.nu
        )

    def _fprime(self, u: jax.Array) -> jax.Array:
        return -((self.nu + 1.0) / 2.0) / (self.nu + u)

    def log_lik(self, theta: jax.Array, data: GLMData) -> jax.Array:
        z = (data.t - data.x @ theta) / self.sigma
        return self._f(z * z)

    def log_bound(self, theta: jax.Array, data: GLMData) -> jax.Array:
        z = (data.t - data.x @ theta) / self.sigma
        u0 = (data.xi / self.sigma) ** 2
        return self._f(u0) + self._fprime(u0) * (z * z - u0)

    def suffstats(self, data: GLMData) -> CollapsedStats:
        x, y = data.x, data.t
        u0 = (data.xi / self.sigma) ** 2
        A = self._fprime(u0) / (self.sigma**2)  # coefficient of r² (negative)
        Q = jnp.einsum("n,nd,ne->de", A, x, x)
        q = -2.0 * jnp.einsum("n,n,nd->d", A, y, x)
        c = jnp.sum(A * y * y) + jnp.sum(self._f(u0) - self._fprime(u0) * u0)
        return CollapsedStats(Q, q, c)

    @staticmethod
    def collapsed(theta: jax.Array, stats: CollapsedStats) -> jax.Array:
        return theta @ stats.Q @ theta + stats.q @ theta + stats.c

    def tighten(self, theta_map: jax.Array, data: GLMData) -> GLMData:
        return data._replace(xi=data.t - data.x @ theta_map)

    @staticmethod
    def default_xi(data: GLMData) -> GLMData:
        return data._replace(xi=jnp.zeros(data.x.shape[0], data.x.dtype))


# ---------------------------------------------------------------------------
# Priors
# ---------------------------------------------------------------------------


register_bound(LogisticBound, "logistic")
register_bound(SoftmaxBound, "softmax")
register_bound(StudentTBound, "student-t", "robust")


def gaussian_log_prior(theta: jax.Array, scale: float) -> jax.Array:
    """Isotropic Gaussian prior (normalization constant dropped)."""
    return -0.5 * jnp.sum(jnp.square(theta)) / (scale**2)


def laplace_log_prior(theta: jax.Array, scale: float) -> jax.Array:
    """Sparsity-inducing Laplace prior (paper §4.3)."""
    return -jnp.sum(jnp.abs(theta)) / scale
