"""Serving driver: batched prefill + autoregressive decode.

CPU-scale end-to-end path (reduced configs): prefill a batch of prompts,
then greedy-decode continuations with the ring-cache / recurrent-state
serving stack (models.serving). The same decode_step is what the dry run
lowers for decode_32k / long_500k on the production meshes.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b \
        --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.distributed.par import Par
from repro.models import serving as SV
from repro.models import transformer as T


def serve_reduced(
    arch: str,
    batch: int = 4,
    prompt_len: int = 32,
    gen: int = 16,
    seed: int = 0,
):
    cfg = get_reduced(arch)
    par = Par()
    params, specs = T.init_model(cfg, jax.random.key(seed))
    seq_cap = prompt_len + gen

    k1, k2 = jax.random.split(jax.random.key(seed + 1))
    prompts = jax.random.randint(k1, (batch, prompt_len), 0, cfg.vocab_size)
    b = {"tokens": prompts}
    if cfg.family == "encdec":
        b["frames"] = 0.1 * jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        b["patches"] = 0.1 * jax.random.normal(
            k2, (batch, cfg.patch_positions, cfg.d_model)
        )

    t0 = time.time()
    cache, h = SV.prefill(
        params, specs, b, cfg, par, seq_cap, dtype=jnp.float32,
        kv_dtype=jnp.float32,
    )
    head = params["embed"]["head"].astype(jnp.float32)
    first = jnp.argmax((h[:, -1:] @ head), -1).astype(jnp.int32)
    t_prefill = time.time() - t0

    step = jax.jit(
        lambda c, tok: SV.decode_step(
            params, specs, c, tok, cfg, par, seq_cap, dtype=jnp.float32
        )
    )
    tok = first
    out = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(gen - 1):
        tok, _, cache = step(cache, tok)
        out.append(np.asarray(tok))
    t_decode = time.time() - t0
    generated = np.concatenate(out, axis=1)
    return generated, {"prefill_s": t_prefill, "decode_s": t_decode,
                       "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    gen, stats = serve_reduced(
        args.arch, args.batch, args.prompt_len, args.gen
    )
    print(f"generated shape {gen.shape}")
    print(
        f"prefill {stats['prefill_s']:.2f}s decode {stats['decode_s']:.2f}s "
        f"({stats['tok_per_s']:.1f} tok/s incl. jit)"
    )
    print("first sequences:", gen[:2, :10].tolist())


if __name__ == "__main__":
    main()
