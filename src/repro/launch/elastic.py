"""Elastic scaling + straggler mitigation (DESIGN.md §5).

Elastic restarts: ``plan_mesh`` picks the largest production-shaped mesh
that fits the devices that survive a failure; the checkpointer stores
logical (unsharded) arrays so ``Checkpointer.restore(shardings=new)``
resumes on the new mesh bit-exact. The controller loop is:

    while True:
        devices = discover()                 # runtime/SRE signal
        mesh = plan_mesh(len(devices))
        state = ckpt.restore(target, shardings=shardings_for(mesh))
        run_until_failure(mesh, state, ckpt)

Straggler mitigation:
  * FlyMC — per-shard bright-capacity C bounds data-dependent work: no
    shard ever evaluates more than C likelihood rows per θ-update, so skew
    between shards is bounded by construction (core.flymc).
  * Training — the StragglerMonitor tracks per-step durations and flags
    hosts whose EWMA exceeds the fleet median by a threshold; the launcher
    responds by checkpoint + elastic restart without the flagged host
    (backup-worker semantics). On a single-controller simulation the
    monitor consumes recorded step times.
"""

from __future__ import annotations

import dataclasses

import jax


def plan_mesh(n_devices: int, model_parallel: int = 16):
    """Largest (pod, data, model) mesh with full model-parallel groups.

    Keeps `model` fixed (TP degree is a property of the checkpointed layout)
    and absorbs device loss into the data axes — the elastic dimension.
    """
    groups = n_devices // model_parallel
    if groups < 1:
        raise ValueError(
            f"{n_devices} devices cannot host model_parallel={model_parallel}"
        )
    if groups >= 32 and groups % 16 == 0:
        shape = (groups // 16, 16, model_parallel)
        axes = ("pod", "data", "model")
    else:
        shape = (groups, model_parallel)
        axes = ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types,
                         devices=jax.devices()[: groups * model_parallel])


def plan_chain_slots(n_devices: int, slots_per_device: int = 8) -> int:
    """Chain-slot budget per batching group for the sampling service.

    The serve scheduler packs jobs onto the chain axis of the batched
    megakernels; the chain axis is the elastic dimension (chains shard with
    zero cross-chain collectives — ``flymc_dist.chain_fleet``), so device
    loss translates linearly into slot loss. On loss the service
    checkpoints, shrinks every group to the surviving budget, and repacks —
    the chain-level analogue of :func:`plan_mesh` absorbing device loss
    into the data axes.

    ``n_devices=0`` is a legal degenerate case — total device loss plans a
    zero budget, under which the service suspends every job cleanly and
    waits for capacity — so only a negative count is a caller bug.
    """
    if n_devices < 0:
        raise ValueError(f"device count cannot be negative, got {n_devices}")
    return n_devices * slots_per_device


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA step-time tracker; flags hosts slower than median × threshold."""

    alpha: float = 0.2
    threshold: float = 1.5
    ewma: dict = dataclasses.field(default_factory=dict)

    def record(self, host: str, step_seconds: float):
        prev = self.ewma.get(host)
        self.ewma[host] = (
            step_seconds
            if prev is None
            else (1 - self.alpha) * prev + self.alpha * step_seconds
        )

    def stragglers(self) -> list[str]:
        if len(self.ewma) < 2:
            return []
        times = sorted(self.ewma.values())
        median = times[len(times) // 2]
        return [
            h for h, t in self.ewma.items() if t > self.threshold * median
        ]
