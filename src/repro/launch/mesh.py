"""Production mesh construction (brief: function, not module constant).

Single pod : (data=16, model=16) — 256 chips of TPU v5e.
Multi-pod  : (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis maps
to DCN and carries only FSDP/DP traffic (gradient reduce-scatters and weight
gathers), never per-layer TP collectives.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=axis_types)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
