"""Post-compile HLO analysis with while-loop trip-count accounting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies **once**, which
silently undercounts anything inside a ``lax.scan`` (layers, seq chunks) by
the trip count. This module parses the optimized HLO text instead and walks
the call graph:

  * dot FLOPs       — 2 · |out| · contraction, per ``dot`` op (incl. inside
    fusion computations);
  * HBM traffic     — Σ (operand + output bytes) over *materializing* ops
    (fusions, dots, collectives, copies…), treating fusion bodies as on-chip;
  * collective wire bytes per device — all-gather (out−in), all-reduce
    (2·in, ring), reduce-scatter (in), all-to-all (in), collective-permute
    (in) — split into ICI (intra-pod axes) vs DCN ("pod" axis) when the
    replica groups make that inferable (heuristic: group count).

Each while op multiplies its body's totals by the trip count parsed from the
loop condition (canonical ``lt(counter, constant)`` emitted by lax.scan);
unparseable conditions fall back to trip=1 with a warning flag.
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s*(\w[\w\-]*)\(")
_CALLED_RE = re.compile(
    r"(?:to_apply|body|condition|calls)=%?([\w.\-]+)"
)
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")

_COLLECTIVES = {
    "all-gather", "all-gather-start", "all-reduce", "all-reduce-start",
    "reduce-scatter", "all-to-all", "collective-permute",
    "collective-permute-start",
}

_MATERIALIZING = _COLLECTIVES | {
    "fusion", "dot", "convolution", "copy", "transpose", "reshape",
    "broadcast", "concatenate", "slice", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "sort", "pad",
    "select", "iota", "convert", "add", "multiply", "rng-bit-generator",
    "custom-call",
}


def _shapes_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


def _first_shape(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dt, dims = m.groups()
    shape = [int(d) for d in dims.split(",") if d] if dims else []
    return dt, shape


@dataclasses.dataclass
class OpInfo:
    kind: str
    out_bytes: int
    operand_bytes: int
    line: str


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    traffic: float = 0.0
    # bf16-corrected traffic: CPU XLA promotes the bf16 compute stream to
    # f32; big f32 tensors are halved for the TPU-expected number (genuinely-
    # f32 optimizer traffic is small against the activation/weight stream).
    traffic_corr: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    # bf16-corrected collective bytes: CPU XLA promotes bf16 collectives to
    # f32 (hoisted converts); every model-path collective is bf16 by
    # construction (params cast before gather, grads RS in bf16), so f32
    # collectives above 4 KiB are halved. Genuine f32 collectives (scalar
    # loss/metric psums, CE partials) are below the cutoff or negligible.
    coll_bytes_corr: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    calls: list = dataclasses.field(default_factory=list)  # (kind, name, extra)


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _operands(line: str, op_end: int) -> list[str]:
    """Operand op-names inside the call parens (types are not inlined)."""
    inner = line[op_end:].split(")", 1)[0]
    return _OPERAND_RE.findall(inner)


def _parse_dot_flops(line: str, out_shape, symtab) -> float:
    out_elems = math.prod(out_shape) if out_shape else 1
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    ops = _operands(line, line.index("dot(") + 4)
    lhs_type = symtab.get(ops[0], "") if ops else ""
    _, lhs_dims = _first_shape(lhs_type)
    if not cdims or not lhs_dims:
        return 2.0 * out_elems  # degenerate / unresolvable
    contraction = 1
    for ci in cdims.group(1).split(","):
        if ci and int(ci) < len(lhs_dims):
            contraction *= lhs_dims[int(ci)]
    return 2.0 * out_elems * contraction


def _aliased_traffic(line, op_end, type_str, out_bytes, operand_bytes,
                     symtab, kind) -> int:
    """operand+output bytes with in-place aliasing: when an operand's type
    equals the output type (dynamic-update-slice accumulators, elementwise
    add-into), XLA reuses the buffer — count that operand once, not twice."""
    ops = _operands(line, op_end)
    for o in ops:
        if symtab.get(o, "") == type_str:
            return out_bytes + operand_bytes - _shapes_bytes(symtab[o])
    return out_bytes + operand_bytes


def _bf16_corr_bytes(line, op_end, type_str, symtab, kind) -> float:
    """Aliased traffic with big f32 tensors halved (CPU promotes bf16→f32;
    on TPU the activation/weight stream stays bf16)."""

    def adj(ts: str) -> float:
        total = 0.0
        for dt, dims in _SHAPE_RE.findall(ts):
            nbytes = _DTYPE_BYTES.get(dt, 0)
            n = 1
            if dims:
                for d in dims.split(","):
                    if d:
                        n *= int(d)
            b = n * nbytes
            if dt == "f32" and b > 4096:
                b /= 2.0
            total += b
        return total

    ops = _operands(line, op_end)
    out = adj(type_str)
    aliased = False
    opsum = 0.0
    for o in ops:
        ts = symtab.get(o, "")
        if not aliased and ts == type_str:
            aliased = True  # in-place: count once
            continue
        opsum += adj(ts)
    return out + opsum


def _collective_wire_bytes(kind: str, out_bytes: int, operand_bytes: int):
    kind = kind.replace("-start", "")
    if kind == "all-gather":
        return max(out_bytes - operand_bytes, 0)
    if kind == "all-reduce":
        return 2 * operand_bytes
    if kind == "reduce-scatter":
        return max(operand_bytes - out_bytes, 0)
    return operand_bytes  # all-to-all, collective-permute


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        stripped = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{$", stripped)
        if m and ("->" in stripped or stripped.startswith("ENTRY")):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}" or stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    return comps


_TRIP_RE = re.compile(r'known_trip_count"?\s*:\s*\{"?n"?:"?(\d+)')


def _trip_from_backend_config(line: str) -> int | None:
    """XLA annotates scheduled while ops with known_trip_count — exact."""
    m = _TRIP_RE.search(line)
    return int(m.group(1)) if m else None


def _trip_count(cond_lines: list[str]) -> int | None:
    """Parse canonical lax.scan condition: compare(counter, constant), LT."""
    consts = {}
    for line in cond_lines:
        m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=.*constant\((\d+)\)", line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        if "compare(" in line and "direction=LT" in line:
            args = line.split("compare(", 1)[1].split(")", 1)[0]
            names = re.findall(r"%?([\w.\-]+)(?:,|$)", args)
            for n in names:
                n = n.strip().split(" ")[-1].lstrip("%")
                if n in consts:
                    return consts[n]
    return None


def analyze_hlo(text: str, pod_axis_size: int = 1):
    """Returns dict with flops, traffic bytes, collective bytes (per device),
    per-collective-kind breakdown, and parse diagnostics."""
    comps = _split_computations(text)
    stats: dict[str, CompStats] = {}
    warnings: list[str] = []
    trip_fallbacks: list[str] = []

    for name, lines in comps.items():
        st = CompStats()
        # first pass: symbol table op-name → output type string
        symtab: dict[str, str] = {}
        for line in lines:
            m = _OP_RE.match(line)
            if m:
                symtab[m.group(1)] = m.group(2)
        for line in lines:
            m = _OP_RE.match(line)
            if not m:
                continue
            _, type_str, kind = m.groups()
            out_bytes = _shapes_bytes(type_str)
            operand_bytes = sum(
                _shapes_bytes(symtab.get(o, "")) for o in _operands(line, m.end())
            )
            if kind == "dot":
                _, out_shape = _first_shape(type_str)
                st.flops += _parse_dot_flops(line, out_shape, symtab)
            if kind in _COLLECTIVES:
                wire = _collective_wire_bytes(kind, out_bytes, operand_bytes)
                k = kind.replace("-start", "")
                st.coll_bytes[k] += wire
                dt, _ = _first_shape(type_str)
                corr = wire
                if dt == "f32" and wire > 4096:
                    corr = wire / 2.0  # promoted-from-bf16 (module doc)
                st.coll_bytes_corr[k] += corr
            if kind in _MATERIALIZING and kind != "fusion":
                tb = _aliased_traffic(line, m.end(), type_str, out_bytes,
                                      operand_bytes, symtab, kind)
                st.traffic += tb
                st.traffic_corr += _bf16_corr_bytes(
                    line, m.end(), type_str, symtab, kind
                )
            called = _CALLED_RE.findall(line)
            branches = _BRANCHES_RE.search(line)
            if kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", line)
                mc = re.search(r"condition=%?([\w.\-]+)", line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                st.calls.append(
                    ("while", body, (cond, _trip_from_backend_config(line)))
                )
            elif kind == "fusion":
                for c in called:
                    st.calls.append(("fusion", c, None))
                tb = _aliased_traffic(line, m.end(), type_str, out_bytes,
                                      operand_bytes, symtab, kind)
                st.traffic += tb
                st.traffic_corr += _bf16_corr_bytes(
                    line, m.end(), type_str, symtab, kind
                )
            elif kind == "conditional":
                names = (
                    [x.strip().lstrip("%") for x in branches.group(1).split(",")]
                    if branches
                    else called
                )
                for c in names:
                    st.calls.append(("branch", c, None))
            elif called:
                for c in called:
                    st.calls.append(("call", c, None))
        stats[name] = st

    memo: dict[str, tuple] = {}

    def total(name: str, depth=0):
        if name not in stats or depth > 64:
            return 0.0, 0.0, 0.0, defaultdict(float), defaultdict(float)
        if name in memo:
            return memo[name]
        st = stats[name]
        fl, tr, trc = st.flops, st.traffic, st.traffic_corr
        cb = defaultdict(float, st.coll_bytes)
        cbc = defaultdict(float, st.coll_bytes_corr)
        for kind, callee, extra in st.calls:
            if callee is None or callee not in stats:
                continue
            cfl, ctr, ctrc, ccb, ccbc = total(callee, depth + 1)
            mult = 1
            if kind == "while":
                cond_name, bc_trip = extra if isinstance(extra, tuple) else (extra, None)
                trip = bc_trip
                if trip is None:
                    trip = _trip_count(comps.get(cond_name, []))
                if trip is None:
                    # structured, un-capped record of every fallback: a
                    # trip=1 guess UNDERCOUNTS everything inside the loop,
                    # so consumers must be able to see it happened even
                    # when the warnings list is truncated
                    trip_fallbacks.append(callee)
                    warnings.append(f"unparsed trip count for {callee}")
                    trip = 1
                mult = trip
            if kind == "fusion":
                # fusion body: count dots (flops) but not traffic (on-chip)
                fl += cfl
                for k, v in ccb.items():
                    cb[k] += v
                for k, v in ccbc.items():
                    cbc[k] += v
                continue
            fl += mult * cfl
            tr += mult * ctr
            trc += mult * ctrc
            for k, v in ccb.items():
                cb[k] += mult * v
            for k, v in ccbc.items():
                cbc[k] += mult * v
        memo[name] = (fl, tr, trc, cb, cbc)
        return memo[name]

    entry = None
    for name in comps:
        if name.startswith("main") or name == "entry":
            entry = name
    if entry is None:  # ENTRY marker line match fallback: pick largest
        entry = max(comps, key=lambda n: len(comps[n]))
    fl, tr, trc, cb, cbc = total(entry)
    return {
        "entry": entry,
        "flops": fl,
        "traffic_bytes": tr,
        "traffic_bytes_bf16corr": trc,
        "collective_bytes": dict(cb),
        "collective_total": float(sum(cb.values())),
        "collective_bytes_bf16corr": dict(cbc),
        "collective_total_bf16corr": float(sum(cbc.values())),
        "warnings": warnings[:10],
        "trip_count_fallbacks": trip_fallbacks,
        "trip_counts_ok": not trip_fallbacks,
        "n_computations": len(comps),
    }


def collective_wire_bytes(compiled_text: str, axis_sizes=None):
    """Per-device collective wire bytes of a compiled program's HLO text.

    The counterpart of the STATIC model in
    :mod:`repro.analysis.collectives.wire_bytes`: both use the same
    size-independent payload formulas (all-reduce = 2x payload,
    all-gather = out - in, ...), so on a program whose trip counts all
    parse, ``total`` here must EQUAL the static model's total exactly —
    the cross-validation the collective-analysis tests pin.

    ``axis_sizes`` (mapping mesh axis name -> size, or a bare int device
    count) additionally derives ``ring_total``: the 2x model rescaled by
    the ring factor (k-1)/k for k total devices — the tighter estimate
    for actual ring all-reduces, kept separate so the headline number
    stays comparable across both models.
    """
    rec = analyze_hlo(compiled_text)
    out = {
        "per_kind": dict(rec["collective_bytes"]),
        "total": rec["collective_total"],
        "total_bf16corr": rec["collective_total_bf16corr"],
        "trip_counts_ok": rec["trip_counts_ok"],
        "trip_count_fallbacks": rec["trip_count_fallbacks"],
        "warnings": rec["warnings"],
    }
    if axis_sizes:
        if isinstance(axis_sizes, dict):
            k = 1
            for v in axis_sizes.values():
                k *= int(v)
        else:
            k = int(axis_sizes)
        out["ring_total"] = rec["collective_total"] * (k - 1) / k if k else 0.0
        out["n_devices"] = k
    return out
