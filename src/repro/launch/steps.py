"""shard_map-wrapped train / prefill / decode steps on a production mesh.

These builders return (jitted_fn, abstract_inputs) pairs: the abstract
inputs are ShapeDtypeStructs with NamedShardings attached, so callers can
either materialize real arrays (training) or ``.lower()`` directly
(dry-run — no allocation, per the brief).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.distributed import par as parlib
from repro.distributed.par import Par
from repro.launch.mesh import data_axes, mesh_axis_sizes
from repro.models import serving as SV
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWState

Tree = dict[str, Any]


def make_par(mesh) -> Par:
    import math

    sizes = mesh_axis_sizes(mesh)
    dp = data_axes(mesh)
    return Par(
        dp=dp,
        mp="model" if "model" in sizes else None,
        dp_size=math.prod(sizes[a] for a in dp) if dp else 1,
        mp_size=sizes.get("model", 1),
    )


def _named(tree_sds, tree_ps, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, p)
        ),
        tree_sds,
        tree_ps,
    )


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, par: Par, batch_sharded: bool):
    dp = par.dp if (par.dp and batch_sharded) else None
    specs: Tree = {"tokens": PS(dp, None)}
    if shape.kind == "train":
        specs["labels"] = PS(dp, None)
    if cfg.family == "encdec":
        specs["frames"] = PS(dp, par.mp, None)  # seq-sharded stub embeddings
    if cfg.family == "vlm":
        specs["patches"] = PS(dp, None, None)
    return specs


def batch_abstract(cfg: ModelConfig, shape: ShapeConfig, seq_len: int):
    b = shape.global_batch
    sds: Tree = {"tokens": jax.ShapeDtypeStruct((b, seq_len), jnp.int32)}
    if shape.kind == "train":
        sds["labels"] = jax.ShapeDtypeStruct((b, seq_len), jnp.int32)
    if cfg.family == "encdec":
        sds["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        sds["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.patch_positions, cfg.d_model), jnp.bfloat16
        )
    return sds


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_sharded_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                            dtype=jnp.bfloat16, remat: bool = True):
    par = make_par(mesh)
    sizes = mesh_axis_sizes(mesh)
    step, specs = T.make_train_step(cfg, sizes, par, dtype=dtype, remat=remat)
    params_ps = parlib.spec_tree_to_pspecs(specs, par.mp)
    opt_ps = AdamWState(step=PS(), m=params_ps, v=params_ps)
    batch_sharded = shape.global_batch % max(par.dp_size, 1) == 0
    b_ps = batch_pspecs(cfg, shape, par, batch_sharded)
    metrics_ps = {
        k: PS()
        for k in ("loss", "nll", "lb_loss", "drop_frac", "grad_norm", "lr")
    }

    sharded = jax.shard_map(
        step,
        mesh=mesh,
        in_specs=(params_ps, opt_ps, b_ps),
        out_specs=(params_ps, opt_ps, metrics_ps),
        check_vma=False,
    )

    params_sds = _named(parlib.abstract_tree(specs), params_ps, mesh)
    opt_dt = jnp.dtype(cfg.opt_dtype)
    opt_sds = AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, PS())),
        m=_named(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, opt_dt),
                parlib.abstract_tree(specs),
            ),
            params_ps, mesh,
        ),
        v=_named(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, opt_dt),
                parlib.abstract_tree(specs),
            ),
            params_ps, mesh,
        ),
    )
    batch_sds = _named(batch_abstract(cfg, shape, shape.seq_len), b_ps, mesh)
    # Donate params + optimizer state: outputs alias inputs (in-place
    # update), halving the resident footprint — standard for real training.
    return (
        jax.jit(sharded, donate_argnums=(0, 1)),
        (params_sds, opt_sds, batch_sds),
        specs,
    )


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def make_sharded_prefill(cfg: ModelConfig, mesh, shape: ShapeConfig,
                         dtype=jnp.bfloat16):
    par = make_par(mesh)
    sizes = mesh_axis_sizes(mesh)
    specs = T.build_specs(cfg, sizes, par.mp)
    params_ps = parlib.spec_tree_to_pspecs(specs, par.mp)
    batch_sharded = shape.global_batch % max(par.dp_size, 1) == 0
    b_ps = batch_pspecs(cfg, shape, par, batch_sharded)
    cache_ps = SV.cache_pspecs(cfg, shape.seq_len, par, sizes)
    if not batch_sharded:  # strip dp from cache batch dims
        cache_ps = _strip_dp(cache_ps, par)
    hidden_ps = PS(
        par.dp if batch_sharded else None,
        par.mp if cfg.parallel_mode == "sp" else None,
        None,
    )

    def fn(params, batch):
        return SV.prefill(params, specs, batch, cfg, par, shape.seq_len, dtype)

    sharded = jax.shard_map(
        fn, mesh=mesh, in_specs=(params_ps, b_ps),
        out_specs=(cache_ps, hidden_ps), check_vma=False,
    )
    params_sds = _named(parlib.abstract_tree(specs), params_ps, mesh)
    batch_sds = _named(batch_abstract(cfg, shape, shape.seq_len), b_ps, mesh)
    return jax.jit(sharded), (params_sds, batch_sds), specs


def _strip_dp(cache_ps, par: Par):
    """Remove dp axes from cache specs (unsharded batch, e.g. long_500k B=1)."""
    dp_names = set(par.dp)

    def is_dp(e):
        if e is None:
            return False
        if isinstance(e, (tuple, list)):
            return any(x in dp_names for x in e)
        return e in dp_names

    def strip(p):
        if not isinstance(p, PS):
            return p
        return PS(*[None if is_dp(e) else e for e in p])

    return jax.tree.map(strip, cache_ps, is_leaf=lambda x: isinstance(x, PS))


def make_sharded_decode(cfg: ModelConfig, mesh, shape: ShapeConfig,
                        dtype=jnp.bfloat16, layout: str = "fsdp"):
    """layout='fsdp' — training parameter layout (ZeRO-3 gathers/step);
    layout='tp'   — serving-resident layout (§Perf iteration C): weights
    bf16, TP over `model` (head-parallel attention, col/row MLP, vocab-
    parallel head), replicated over the data axes — zero FSDP gathers.
    Requires n_heads % model_parallel == 0 and a windowed/ring cache small
    enough to replicate over `model` (SWA / local-attn / recurrent archs).
    """
    par = make_par(mesh)
    sizes = mesh_axis_sizes(mesh)
    serve_tp = layout == "tp"
    if serve_tp:
        assert cfg.n_heads % max(par.mp_size, 1) == 0, (
            cfg.name, "tp layout needs head divisibility")
    specs = T.build_specs(
        cfg, sizes, par.mp,
        exclude_fsdp=par.dp if serve_tp else (),
        serve_tp=serve_tp,
    )
    params_ps = parlib.spec_tree_to_pspecs(specs, par.mp)
    batch_sharded = shape.global_batch % max(par.dp_size, 1) == 0
    cache_ps = SV.cache_pspecs(cfg, shape.seq_len, par, sizes,
                               serve_tp=serve_tp)
    if not batch_sharded:
        cache_ps = _strip_dp(cache_ps, par)
    dp = par.dp if batch_sharded else None
    tok_ps = PS(dp, None)
    out_ps = (tok_ps, PS(dp, None, par.mp), cache_ps)

    def fn(params, cache, token):
        return SV.decode_step(
            params, specs, cache, token, cfg, par, shape.seq_len, dtype,
            serve_tp=serve_tp,
        )

    sharded = jax.shard_map(
        fn, mesh=mesh, in_specs=(params_ps, cache_ps, tok_ps),
        out_specs=out_ps, check_vma=False,
    )

    abstract = parlib.abstract_tree(specs)
    if serve_tp:  # serving weights live in bf16 (no optimizer states)
        abstract = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), abstract
        )
    params_sds = _named(abstract, params_ps, mesh)
    # Global cache shapes = local shard shapes × the mesh axes each dim is
    # sharded over (handles the kv-head duplication of the TP serve ring).
    b_local = (
        shape.global_batch // max(par.dp_size, 1)
        if batch_sharded else shape.global_batch
    )
    cache_local = jax.eval_shape(
        lambda: SV.init_cache(
            cfg, b_local, shape.seq_len, par, serve_tp=serve_tp
        )
    )
    sizes_map = mesh_axis_sizes(mesh)

    def globalize(sd, ps):
        dims = list(sd.shape)
        for i, entry in enumerate(ps):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                dims[i] *= sizes_map.get(a, 1)
        return jax.ShapeDtypeStruct(tuple(dims), sd.dtype)

    cache_global = jax.tree.map(
        globalize, cache_local, cache_ps,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    cache_sds = _named(cache_global, cache_ps, mesh)
    tok_sds = jax.ShapeDtypeStruct(
        (shape.global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, tok_ps),
    )
    return jax.jit(sharded), (params_sds, cache_sds, tok_sds), specs
