import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (brief: deliverable e).

Lowers + compiles every (architecture × input shape) cell on the single-pod
(16×16) and multi-pod (2×16×16) production meshes, printing
``memory_analysis()`` / ``cost_analysis()`` and recording the parsed HLO
terms (dot FLOPs, HBM traffic, collective wire bytes — with while-loop trip
counts applied) to JSON for the roofline (benchmarks/roofline.py).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun \
        [--mesh single|multi|both] [--arch <id>|all] [--shape <name>|all] \
        [--out benchmarks/results]

The first two lines of this file force 512 host devices BEFORE any jax
import, as required — jax locks the device count at first init.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch import steps
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh

V5E = {
    "peak_flops": 197e12,  # bf16 / chip
    "hbm_bw": 819e9,  # bytes/s
    "ici_bw": 50e9,  # bytes/s/link
    "hbm_bytes": 16 * 2**30,
}


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D (train), 2·N·D (prefill), 2·N·B (decode);
    N = active params for MoE (global, whole step)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


def build_cell(cfg, mesh, shape):
    if shape.kind == "train":
        fn, sds, _ = steps.make_sharded_train_step(cfg, mesh, shape)
    elif shape.kind == "prefill":
        fn, sds, _ = steps.make_sharded_prefill(cfg, mesh, shape)
    else:
        fn, sds, _ = steps.make_sharded_decode(cfg, mesh, shape)
    return fn, sds


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: Path,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_chips = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": mesh_name,
        "n_chips": n_chips,
        "status": "skipped",
    }
    if not shape_applicable(cfg, shape):
        rec["reason"] = "long_500k undefined for pure full-attention arch"
        return rec
    t0 = time.time()
    try:
        fn, sds = build_cell(cfg, mesh, shape)
        lowered = fn.lower(*sds)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze_hlo(compiled.as_text())

        per_dev_bytes = ma.temp_size_in_bytes + ma.argument_size_in_bytes
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "temp_bytes": ma.temp_size_in_bytes,
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "per_device_bytes": per_dev_bytes,
                "fits_16g": bool(per_dev_bytes <= V5E["hbm_bytes"]),
            },
            cost_analysis_flops=float(ca.get("flops", 0.0)),
            hlo_flops_per_device=hlo["flops"],
            hlo_traffic_bytes_per_device=hlo["traffic_bytes"],
            hlo_traffic_bytes_bf16corr=hlo["traffic_bytes_bf16corr"],
            collective_bytes=hlo["collective_bytes"],
            collective_bytes_bf16corr=hlo["collective_bytes_bf16corr"],
            collective_total=hlo["collective_total"],
            collective_total_bf16corr=hlo["collective_total_bf16corr"],
            hlo_warnings=hlo["warnings"],
            model_flops_global=mf,
            model_flops_per_device=mf / n_chips,
            n_params=cfg.n_params(),
            n_active_params=cfg.n_active_params(),
            roofline={
                "compute_s": hlo["flops"] / V5E["peak_flops"],
                "memory_s": hlo["traffic_bytes_bf16corr"] / V5E["hbm_bw"],
                "memory_s_raw": hlo["traffic_bytes"] / V5E["hbm_bw"],
                "collective_s": hlo["collective_total_bf16corr"] / V5E["ici_bw"],
                "model_vs_hlo_flops": (
                    (mf / n_chips) / hlo["flops"] if hlo["flops"] else 0.0
                ),
            },
        )
        terms = rec["roofline"]
        rec["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
        )
        if verbose:
            print(f"  memory_analysis: {ma}")
            print(f"  cost_analysis flops={ca.get('flops')}")
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    out_dir.mkdir(parents=True, exist_ok=True)
    fname = f"dryrun_{mesh_name}_{arch.replace('.', '_')}_{shape_name}.json"
    (out_dir / fname).write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    out_dir = Path(args.out)

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                label = f"[{mesh_name}] {arch} × {shape_name}"
                print(f"== {label}", flush=True)
                rec = run_cell(arch, shape_name, mesh_name, out_dir,
                               verbose=not args.quiet)
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    fit = "fits" if rec["memory"]["fits_16g"] else "OVER-HBM"
                    print(
                        f"   ok compile={rec['compile_s']}s {fit} "
                        f"per-dev={rec['memory']['per_device_bytes']/2**30:.2f}GiB "
                        f"compute={r['compute_s']*1e3:.1f}ms "
                        f"mem={r['memory_s']*1e3:.1f}ms "
                        f"coll={r['collective_s']*1e3:.1f}ms "
                        f"dominant={r['dominant']}",
                        flush=True,
                    )
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"   skipped: {rec['reason']}")
                else:
                    n_err += 1
                    print(f"   ERROR: {rec['error']}")
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
