"""End-to-end training driver.

Two modes:
  * CPU-scale (default): reduced config of any assigned arch, single device,
    synthetic token stream, a few hundred steps with checkpointing — the
    runnable end-to-end path (examples/train_lm.py uses this).
  * Mesh mode (``--mesh single|multi`` on real hardware): the shard_map step
    from launch.steps with checkpoint/restore, straggler monitoring and
    optional compressed pod gradients.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --steps 200 --batch 8 --seq 128 [--reduced] [--ckpt-dir ckpt]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_reduced
from repro.distributed.par import Par
from repro.models import transformer as T


def synthetic_batch(key, cfg, batch: int, seq: int):
    """Markov-ish synthetic token stream (learnable structure, not iid)."""
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
    # inject copy structure so loss visibly falls below log V
    shifted = jnp.roll(base, 7, axis=1)
    use_copy = jax.random.bernoulli(k2, 0.5, (batch, seq))
    tokens = jnp.where(use_copy, shifted, base)
    batch_dict = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if cfg.family == "encdec":
        batch_dict["frames"] = 0.1 * jax.random.normal(
            k2, (batch, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.family == "vlm":
        batch_dict["patches"] = 0.1 * jax.random.normal(
            k2, (batch, cfg.patch_positions, cfg.d_model)
        )
    return batch_dict


def train_reduced(
    arch: str,
    steps: int = 100,
    batch: int = 8,
    seq: int = 129,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    peak_lr: float = 1e-3,
    warmup_steps: int = 20,
    seed: int = 0,
):
    cfg = get_reduced(arch)
    par = Par()
    params, specs = T.init_model(cfg, jax.random.key(seed))
    opt = T.init_opt(params, dtype=cfg.opt_dtype)
    step_fn, _ = T.make_train_step(
        cfg, {}, par, dtype=jnp.float32, remat=False, peak_lr=peak_lr,
        warmup_steps=warmup_steps,
    )
    step_fn = jax.jit(step_fn)
    ck = Checkpointer(ckpt_dir) if ckpt_dir else None

    start = 0
    if ck and ck.latest_step() is not None:
        (params, opt), manifest = ck.restore((params, opt))
        start = manifest["step"]
        print(f"resumed from step {start}")

    key = jax.random.key(seed + 1)
    history = []
    t0 = time.time()
    for i in range(start, steps):
        key, sub = jax.random.split(key)
        b = synthetic_batch(sub, cfg, batch, seq)
        params, opt, metrics = step_fn(params, opt, b)
        loss = float(metrics["loss"])
        history.append(loss)
        if not np.isfinite(loss):
            raise FloatingPointError(f"loss diverged at step {i}")
        if i % log_every == 0 or i == steps - 1:
            print(
                f"step {i:5d} loss {loss:7.4f} "
                f"gnorm {float(metrics['grad_norm']):7.3f} "
                f"lr {float(metrics['lr']):.2e} "
                f"({(time.time()-t0):.1f}s)",
                flush=True,
            )
        if ck and (i + 1) % ckpt_every == 0:
            ck.save(i + 1, (params, opt))
    if ck:
        ck.wait()
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=129)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    _, history = train_reduced(
        args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, peak_lr=args.lr,
    )
    print(f"final loss {history[-1]:.4f} (started {history[0]:.4f})")


if __name__ == "__main__":
    main()
